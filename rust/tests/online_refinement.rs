//! Online profile refinement properties (DESIGN.md §9):
//!
//! * seeded property sweep — injected gap inflation is detected within
//!   a bounded number of observations and the published prediction
//!   re-converges to the new truth, across inflation factors, jitter
//!   levels and smoothing factors;
//! * persistence — a refined profile saved through the versioned store
//!   resolves to the *identical* `ResolvedProfile` after a reload (the
//!   daemon-restart contract; the daemon-level variant lives in
//!   `src/daemon/mod.rs` tests);
//! * driver-level re-convergence — a full `GpuSim` run with injected
//!   interference ends with the scheduler on a refreshed epoch.

use fikit::config::{ExperimentConfig, ServiceConfig};
use fikit::coordinator::driver::{profile_service, GpuSim};
use fikit::coordinator::Mode;
use fikit::core::{Dim3, Duration, Interner, KernelId, Priority, SimTime, TaskKey};
use fikit::profile::{OnlineConfig, OnlineRefiner, ProfileStore, ResolvedProfile, TaskProfile};
use fikit::util::rng::Rng;
use fikit::workload::ModelKind;

fn kid(name: &str) -> KernelId {
    KernelId::new(name, Dim3::x(4), Dim3::x(128))
}

/// Baseline: one kernel with SK = 120 µs and SG = `sg_us` µs.
fn world(sg_us: u64, cfg: OnlineConfig) -> (OnlineRefiner, Interner, ResolvedProfile) {
    let mut p = TaskProfile::new(TaskKey::new("svc"));
    p.record(
        &kid("k"),
        Duration::from_micros(120),
        Some(Duration::from_micros(sg_us)),
    );
    p.finish_run(1);
    let mut interner = Interner::new();
    let th = interner.intern_task(&TaskKey::new("svc"));
    let rp = ResolvedProfile::resolve(&p, &mut interner);
    let mut refiner = OnlineRefiner::new(cfg);
    refiner.register(th, &rp);
    (refiner, interner, rp)
}

/// Property: for every `(inflation factor, jitter, alpha)` combination,
/// drift is detected within `min_samples + 24` inflated observations
/// and the last published SG lands within 35 % of the new true mean.
/// Failures print the parameter triple.
#[test]
fn gap_inflation_detected_and_reconverges_across_parameters() {
    let base_sg_us = 400.0f64;
    for (case, &(factor, jitter, alpha)) in [
        (1.5f64, 0.10f64, 0.2f64),
        (2.0, 0.20, 0.2),
        (2.0, 0.35, 0.1),
        (3.0, 0.35, 0.2),
        (2.5, 0.05, 0.3),
    ]
    .iter()
    .enumerate()
    {
        let cfg = OnlineConfig {
            enabled: true,
            alpha,
            ..Default::default()
        };
        let min_samples = cfg.min_samples as usize;
        let (mut refiner, mut interner, _) = world(base_sg_us as u64, cfg);
        let th = interner.intern_task(&TaskKey::new("svc"));
        let kh = interner.kernel_handle(&kid("k")).unwrap();
        let mut rng = Rng::new(0xD21F7 + case as u64);

        // Warm up at the profiled truth. High-jitter cases may trip a
        // benign early publish while the EWMA settles (it republishes a
        // near-truth value); what the property forbids is a publish
        // *storm* at the truth.
        let mut warmup_publishes = 0u32;
        for _ in 0..64 {
            let g = rng.range_f64(
                base_sg_us * (1.0 - jitter),
                base_sg_us * (1.0 + jitter),
            );
            if refiner
                .observe(
                    th,
                    kh,
                    Duration::from_micros(120),
                    Some(Duration::from_nanos((g * 1_000.0) as u64)),
                )
                .is_some()
            {
                warmup_publishes += 1;
            }
        }
        assert!(
            warmup_publishes <= 4,
            "publish storm at truth: {warmup_publishes} \
             (factor {factor}, jitter {jitter}, alpha {alpha})"
        );

        // Inflate: detection must come within min_samples + 24 obs.
        let new_mean = base_sg_us * factor;
        let mut detected_after = None;
        let mut last_snapshot: Option<ResolvedProfile> = None;
        for i in 0..(min_samples + 24) {
            let g = rng.range_f64(new_mean * (1.0 - jitter), new_mean * (1.0 + jitter));
            if let Some(snap) = refiner.observe(
                th,
                kh,
                Duration::from_micros(120),
                Some(Duration::from_nanos((g * 1_000.0) as u64)),
            ) {
                detected_after.get_or_insert(i + 1);
                last_snapshot = Some(snap);
            }
        }
        let detected_after = detected_after.unwrap_or_else(|| {
            panic!("drift undetected (factor {factor}, jitter {jitter}, alpha {alpha})")
        });

        // Keep observing: the published prediction converges to truth.
        for _ in 0..300 {
            let g = rng.range_f64(new_mean * (1.0 - jitter), new_mean * (1.0 + jitter));
            if let Some(snap) = refiner.observe(
                th,
                kh,
                Duration::from_micros(120),
                Some(Duration::from_nanos((g * 1_000.0) as u64)),
            ) {
                last_snapshot = Some(snap);
            }
        }
        let sg = last_snapshot
            .expect("at least one snapshot")
            .sg(kh)
            .expect("gap still predicted")
            .as_micros_f64();
        let rel = (sg - new_mean).abs() / new_mean;
        assert!(
            rel < 0.35,
            "published SG {sg:.0}us vs truth {new_mean:.0}us (rel {rel:.2}) \
             after detection at obs {detected_after} \
             (factor {factor}, jitter {jitter}, alpha {alpha})"
        );
    }
}

/// Persistence round trip at the profile layer: a refined profile
/// (epoch > 0, origin Refined) written through the versioned store
/// resolves to the identical `ResolvedProfile` after reload — same
/// handles, same SK/SG, same epoch metadata.
#[test]
fn refined_profile_resolves_identically_after_save_load() {
    let mut p = TaskProfile::new(TaskKey::new("svc"));
    p.record(
        &kid("a"),
        Duration::from_micros(120),
        Some(Duration::from_micros(400)),
    );
    p.record(&kid("b"), Duration::from_micros(50), None);
    p.finish_run(2);
    p.epoch = 3;
    p.origin = fikit::profile::ProfileOrigin::Refined;

    let mut store = ProfileStore::new();
    store.insert(p);
    let dir = std::env::temp_dir().join(format!("fikit-online-rt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("profiles.json");
    store.save(&path).unwrap();
    let loaded = ProfileStore::load(&path).unwrap();
    std::fs::remove_dir_all(&dir).ok();

    let key = TaskKey::new("svc");
    let before = store.get(&key).unwrap();
    let after = loaded.get(&key).unwrap();
    assert_eq!(after.epoch, 3);
    assert_eq!(after.origin, fikit::profile::ProfileOrigin::Refined);

    let mut i1 = Interner::new();
    let rp1 = ResolvedProfile::resolve(before, &mut i1);
    let mut i2 = Interner::new();
    let rp2 = ResolvedProfile::resolve(after, &mut i2);
    assert_eq!(i1.kernel_count(), i2.kernel_count());
    for name in ["a", "b"] {
        let h1 = i1.kernel_handle(&kid(name)).unwrap();
        let h2 = i2.kernel_handle(&kid(name)).unwrap();
        assert_eq!(h1, h2, "handle for {name} drifted across save/load");
        assert_eq!(rp1.sk(h1), rp2.sk(h2));
        assert_eq!(rp1.sg(h1), rp2.sg(h2));
    }
}

/// Driver-level: after injected interference and re-convergence, the
/// scheduler is serving from a refreshed epoch, and the refinement
/// overhead accounting stays within the paper's 5 % budget.
#[test]
fn gpu_sim_reconverges_onto_refreshed_epoch() {
    let mut cfg = ExperimentConfig::default();
    cfg.mode = Mode::Fikit;
    cfg.online.enabled = true;
    cfg.services.push(
        ServiceConfig::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0)
            .tasks(30)
            .with_key("hot"),
    );
    cfg.services.push(
        ServiceConfig::new(ModelKind::FcnResnet50, Priority::P5)
            .tasks(30)
            .with_key("cold"),
    );
    let mut store = ProfileStore::new();
    for svc in &cfg.services {
        store.insert(profile_service(&cfg, svc).unwrap().profile);
    }

    let mut sim = GpuSim::new(&cfg, &store).unwrap();
    sim.run_until(SimTime(150_000_000));
    sim.inject_gap_scale(&TaskKey::new("hot"), 2.5).unwrap();
    sim.run_until(SimTime::MAX);

    let refiner = sim.refiner().expect("online refinement enabled");
    let stats = refiner.stats();
    assert!(stats.drifts >= 1, "injected drift undetected");
    assert!(stats.snapshots_published >= 1);
    assert!(stats.max_epoch >= 1, "scheduler never saw a refreshed epoch");
    let overhead = refiner.modeled_overhead().as_secs_f64();
    assert!(
        overhead / sim.now().as_secs_f64() < 0.05,
        "refinement overhead over the 5% budget"
    );
}
