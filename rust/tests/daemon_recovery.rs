//! Crash-consistent replay of the durable scheduler daemon (ADR-004).
//!
//! Every test here follows the same shape: generate a seeded message
//! script, run it through an *unjournaled* daemon to get the reference
//! state image (`SchedulerDaemon::state_json`), then run it through a
//! journaled daemon that is killed at a scripted [`CrashPoint`],
//! recovered from its journal directory, and fed the rest of the script
//! the way real hook clients would (retransmitting the last in-flight
//! request). The recovered daemon's final image must be byte-identical
//! to the reference — for every crash point, including a torn final
//! journal record, across multiple seeds.
//!
//! Times are synthetic and scripted (`SchedulerDaemon::handle_at`), so
//! the runs are fully deterministic; online refinement stays off, as its
//! in-flight accumulators are deliberately not journaled (ADR-004).

use fikit::core::{Dim3, Duration, Priority, SimTime, TaskId, TaskKey};
use fikit::daemon::{CrashPoint, DaemonConfig, FaultPlan, JournalConfig, SchedulerDaemon};
use fikit::hook::protocol::{ClientMsg, SchedulerMsg};
use fikit::profile::{ProfileStore, TaskProfile};
use fikit::util::json::Json;
use fikit::util::rng::Rng;
use std::net::SocketAddr;
use std::path::PathBuf;

const SEEDS: [u64; 3] = [1, 0xF1C1, 0x5EED_5EED];

/// (task_key, priority, client port, kernel name) — the script's cast.
const CLIENTS: [(&str, Priority, u16, &str); 3] = [
    ("hi", Priority::P0, 9001, "hk"),
    ("md", Priority::P2, 9002, "mk"),
    ("lo", Priority::P4, 9003, "lk"),
];

fn addr(port: u16) -> SocketAddr {
    format!("127.0.0.1:{port}").parse().unwrap()
}

fn kid(name: &str) -> fikit::core::KernelId {
    fikit::core::KernelId::new(name, Dim3::x(8), Dim3::x(128))
}

fn profiles() -> ProfileStore {
    let mut store = ProfileStore::new();
    for (key, _, _, kernel) in CLIENTS {
        let mut p = TaskProfile::new(TaskKey::new(key));
        p.record(
            &kid(kernel),
            Duration::from_micros(300),
            Some(Duration::from_micros(2_000)),
        );
        p.finish_run(1);
        store.insert(p);
    }
    store
}

/// One scripted datagram: what a hook client would have sent, with the
/// daemon-side processing time pinned so replay is comparable.
#[derive(Clone)]
struct Step {
    msg_seq: u64,
    msg: ClientMsg,
    addr: SocketAddr,
    now: SimTime,
}

/// Script builder: per-client `msg_seq` counters plus a synthetic clock
/// ticking 150µs per datagram.
struct ScriptState {
    steps: Vec<Step>,
    msg_seq: [u64; CLIENTS.len()],
    now: u64,
}

impl ScriptState {
    fn new() -> ScriptState {
        ScriptState {
            steps: Vec::new(),
            msg_seq: [0; CLIENTS.len()],
            now: 1_000_000,
        }
    }

    /// The processing time the NEXT pushed step will carry — used as
    /// `issued_at` / `finished_at` inside that step's message.
    fn next_now(&self) -> SimTime {
        SimTime(self.now + 150_000)
    }

    fn push(&mut self, c: usize, msg: ClientMsg) {
        self.msg_seq[c] += 1;
        self.now += 150_000;
        self.steps.push(Step {
            msg_seq: self.msg_seq[c],
            msg,
            addr: addr(CLIENTS[c].2),
            now: SimTime(self.now),
        });
    }
}

/// Generate a seeded session script: every client registers and starts
/// a task, then `events` random launch / completion / release-query /
/// task-churn actions interleave across clients. The scheduling
/// semantics of any individual interleaving are irrelevant here — what
/// matters is that the daemon's response to the stream is deterministic,
/// so replay must reproduce it exactly.
fn script(seed: u64, events: usize) -> Vec<Step> {
    let mut rng = Rng::new(seed);
    let mut st = ScriptState::new();
    let mut task_id = [0u64; CLIENTS.len()];
    let mut kseq = [0u32; CLIENTS.len()];
    // Kernel seqs launched but not yet completed, per client.
    let mut outstanding: [Vec<u32>; CLIENTS.len()] = [Vec::new(), Vec::new(), Vec::new()];

    for (c, (key, prio, _, _)) in CLIENTS.iter().enumerate() {
        st.push(
            c,
            ClientMsg::Register {
                task_key: TaskKey::new(key),
                priority: *prio,
                has_symbols: true,
                model: None,
            },
        );
        st.push(
            c,
            ClientMsg::TaskStart {
                task_key: TaskKey::new(key),
                task_id: TaskId(0),
            },
        );
    }

    for _ in 0..events {
        let c = rng.index(CLIENTS.len());
        let (key, _, _, kernel) = CLIENTS[c];
        let key = TaskKey::new(key);
        let roll = rng.below(10);
        if roll < 5 {
            // Launch the next kernel seq.
            let seq = kseq[c];
            kseq[c] += 1;
            outstanding[c].push(seq);
            let issued_at = st.next_now();
            st.push(
                c,
                ClientMsg::Launch {
                    task_key: key,
                    task_id: TaskId(task_id[c]),
                    kernel_name: kernel.to_string(),
                    grid: Dim3::x(8),
                    block: Dim3::x(128),
                    seq,
                    issued_at,
                },
            );
        } else if roll < 8 && !outstanding[c].is_empty() {
            // Complete the oldest outstanding launch.
            let seq = outstanding[c].remove(0);
            let finished_at = st.next_now();
            st.push(
                c,
                ClientMsg::Completion {
                    task_key: key,
                    task_id: TaskId(task_id[c]),
                    seq,
                    exec: Duration::from_micros(200 + rng.below(400)),
                    finished_at,
                },
            );
        } else if roll < 9 && kseq[c] > 0 {
            // Loss-recovery poll for some already-launched seq.
            let seq = rng.below(kseq[c] as u64) as u32;
            st.push(c, ClientMsg::ReleaseQuery { task_key: key, seq });
        } else {
            // Task churn: end the current task, start the next one.
            st.push(
                c,
                ClientMsg::TaskEnd {
                    task_key: key.clone(),
                    task_id: TaskId(task_id[c]),
                },
            );
            task_id[c] += 1;
            outstanding[c].clear();
            st.push(
                c,
                ClientMsg::TaskStart {
                    task_key: key,
                    task_id: TaskId(task_id[c]),
                },
            );
        }
    }
    st.steps
}

/// The reference image: the script applied by a daemon with no journal.
fn reference_state(steps: &[Step]) -> Json {
    let mut d = SchedulerDaemon::new(DaemonConfig::default(), profiles());
    for s in steps {
        d.handle_at(s.msg_seq, s.msg.clone(), s.addr, s.now);
    }
    d.state_json()
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "fikit-recovery-{}-{tag}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn no_snapshots() -> JournalConfig {
    JournalConfig {
        fsync: false,
        snapshot_every: 0,
    }
}

fn journaled(dir: &PathBuf, jcfg: &JournalConfig) -> SchedulerDaemon {
    SchedulerDaemon::with_journal(DaemonConfig::default(), profiles(), dir, jcfg.clone())
        .expect("journal recovery must succeed")
}

/// Feed `steps` until an armed crash trips (or the script ends).
/// Returns the index of the step being processed when the daemon died.
fn feed_until_crash(d: &mut SchedulerDaemon, steps: &[Step]) -> Option<usize> {
    for (i, s) in steps.iter().enumerate() {
        d.handle_at(s.msg_seq, s.msg.clone(), s.addr, s.now);
        if d.crashed() {
            return Some(i);
        }
    }
    None
}

/// Recover from `dir` and feed the remainder of the script the way real
/// clients would: the step in flight at the crash is retransmitted
/// (byte-identical, same `msg_seq`) and everything after it follows.
/// `resume_from` points at the first step to (re)send.
fn recover_and_resume(dir: &PathBuf, jcfg: &JournalConfig, steps: &[Step], resume_from: usize) -> Json {
    let mut d = journaled(dir, jcfg);
    assert!(!d.crashed(), "a recovered daemon starts alive");
    for s in &steps[resume_from..] {
        d.handle_at(s.msg_seq, s.msg.clone(), s.addr, s.now);
        assert!(!d.crashed(), "no fault armed in the second incarnation");
    }
    d.state_json()
}

/// Baseline: journaling changes nothing observable, and a clean restart
/// (no crash at all) reconstructs the exact image.
#[test]
fn journaled_run_matches_unjournaled_reference() {
    for (i, seed) in SEEDS.into_iter().enumerate() {
        let steps = script(seed, 20);
        let reference = reference_state(&steps);
        let dir = fresh_dir(&format!("clean-{i}"));

        let mut d = journaled(&dir, &no_snapshots());
        for s in &steps {
            d.handle_at(s.msg_seq, s.msg.clone(), s.addr, s.now);
        }
        assert!(!d.crashed());
        assert_eq!(d.state_json(), reference, "journaling is observation-free (seed {seed})");
        let live = d.clients();
        drop(d);

        let d2 = journaled(&dir, &no_snapshots());
        assert_eq!(d2.state_json(), reference, "clean restart replays the image (seed {seed})");
        assert_eq!(d2.clients(), live, "every live session survived (seed {seed})");
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Clean-cut kills ([`CrashPoint::AfterProcess`]): the process dies
/// between datagrams, after step `k` was fully processed. For EVERY cut
/// point the recovered daemon, re-fed from step `k` on (the client
/// retransmits its last acknowledged request first, exercising the
/// rebuilt dedup cache), converges to the reference image.
#[test]
fn clean_cut_crash_at_every_step_replays_deterministically() {
    for (i, seed) in SEEDS.into_iter().enumerate() {
        let steps = script(seed, 14);
        let reference = reference_state(&steps);
        for k in 1..=steps.len() {
            let _ = CrashPoint::AfterProcess(k as u64); // harness-level cut
            let dir = fresh_dir(&format!("cut-{i}-{k}"));
            let mut d = journaled(&dir, &no_snapshots());
            for s in &steps[..k] {
                d.handle_at(s.msg_seq, s.msg.clone(), s.addr, s.now);
            }
            drop(d); // the "kill"
            // Retransmit of step k-1 first: must be absorbed, not re-applied.
            let state = recover_and_resume(&dir, &no_snapshots(), &steps, k - 1);
            assert_eq!(
                state, reference,
                "seed {seed}: clean cut after step {k} must replay to the reference"
            );
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}

/// [`CrashPoint::AfterAppend`]: the record is durable but the daemon
/// dies before applying the mutation. Replay applies it; the client's
/// retransmit is absorbed by the replay-rebuilt dedup state. Swept over
/// every append the clean run performs (Apply AND Admit records).
#[test]
fn durable_append_crash_at_every_append() {
    for (i, seed) in SEEDS.into_iter().enumerate() {
        let steps = script(seed, 14);
        let reference = reference_state(&steps);

        // Discover how many appends a clean journaled run performs.
        let dir = fresh_dir(&format!("aa-count-{i}"));
        let mut d = journaled(&dir, &no_snapshots());
        for s in &steps {
            d.handle_at(s.msg_seq, s.msg.clone(), s.addr, s.now);
        }
        let total_appends = d.journal().unwrap().appends();
        assert!(total_appends > steps.len() as u64 / 2, "script must journal");
        drop(d);
        std::fs::remove_dir_all(&dir).ok();

        for n in 1..=total_appends {
            let dir = fresh_dir(&format!("aa-{i}-{n}"));
            let mut d = journaled(&dir, &no_snapshots());
            d.journal_mut()
                .unwrap()
                .arm(FaultPlan::new(CrashPoint::AfterAppend(n)));
            let crash_idx = feed_until_crash(&mut d, &steps)
                .expect("every append index within the total must trip");
            assert!(d.journal().unwrap().tripped());
            drop(d);
            let state = recover_and_resume(&dir, &no_snapshots(), &steps, crash_idx);
            assert_eq!(
                state, reference,
                "seed {seed}: crash after durable append {n} must replay to the reference"
            );
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}

/// [`CrashPoint::MidAppend`]: the process dies partway through the
/// write, leaving a torn frame on disk — including the torn FINAL
/// record when `record == total_appends`. Recovery truncates the torn
/// tail and the client's retransmit re-applies the lost mutation.
/// Swept over every append at three tear offsets (empty, 1 byte into
/// the length prefix, and into the payload).
#[test]
fn torn_write_crash_at_every_append_replays_deterministically() {
    for (i, seed) in SEEDS.into_iter().enumerate() {
        let steps = script(seed, 10);
        let reference = reference_state(&steps);

        let dir = fresh_dir(&format!("ma-count-{i}"));
        let mut d = journaled(&dir, &no_snapshots());
        for s in &steps {
            d.handle_at(s.msg_seq, s.msg.clone(), s.addr, s.now);
        }
        let total_appends = d.journal().unwrap().appends();
        drop(d);
        std::fs::remove_dir_all(&dir).ok();

        for n in 1..=total_appends {
            for keep in [0usize, 1, 9] {
                let dir = fresh_dir(&format!("ma-{i}-{n}-{keep}"));
                let mut d = journaled(&dir, &no_snapshots());
                d.journal_mut()
                    .unwrap()
                    .arm(FaultPlan::new(CrashPoint::MidAppend { record: n, keep }));
                let crash_idx = feed_until_crash(&mut d, &steps)
                    .expect("every append index within the total must trip");
                drop(d);
                let state = recover_and_resume(&dir, &no_snapshots(), &steps, crash_idx);
                assert_eq!(
                    state, reference,
                    "seed {seed}: torn append {n} (keep {keep}) must replay to the reference"
                );
                std::fs::remove_dir_all(&dir).ok();
            }
        }
    }
}

/// A preemption-heavy variant of [`script`]: alongside launches,
/// completions and churn, clients report coordinator preemptions of
/// already-launched kernels (`ClientMsg::Preempted`), whose remnants the
/// shard re-parks. The wire remnant path must replay exactly like every
/// other mutation.
fn preempt_script(seed: u64, events: usize) -> Vec<Step> {
    let mut rng = Rng::new(seed);
    let mut st = ScriptState::new();
    let mut task_id = [0u64; CLIENTS.len()];
    let mut kseq = [0u32; CLIENTS.len()];

    for (c, (key, prio, _, _)) in CLIENTS.iter().enumerate() {
        st.push(
            c,
            ClientMsg::Register {
                task_key: TaskKey::new(key),
                priority: *prio,
                has_symbols: true,
                model: None,
            },
        );
        st.push(
            c,
            ClientMsg::TaskStart {
                task_key: TaskKey::new(key),
                task_id: TaskId(0),
            },
        );
    }

    for _ in 0..events {
        let c = rng.index(CLIENTS.len());
        let (key, _, _, kernel) = CLIENTS[c];
        let key = TaskKey::new(key);
        let roll = rng.below(10);
        if roll < 4 {
            let seq = kseq[c];
            kseq[c] += 1;
            let issued_at = st.next_now();
            st.push(
                c,
                ClientMsg::Launch {
                    task_key: key,
                    task_id: TaskId(task_id[c]),
                    kernel_name: kernel.to_string(),
                    grid: Dim3::x(8),
                    block: Dim3::x(128),
                    seq,
                    issued_at,
                },
            );
        } else if roll < 8 && kseq[c] > 0 {
            // The coordinator preempted one of this client's in-flight
            // kernels; the remnant re-parks with its remaining time.
            let seq = rng.below(kseq[c] as u64) as u32;
            st.push(
                c,
                ClientMsg::Preempted {
                    task_key: key,
                    task_id: TaskId(task_id[c]),
                    kernel_name: kernel.to_string(),
                    grid: Dim3::x(8),
                    block: Dim3::x(128),
                    seq,
                    remaining: Duration::from_micros(50 + rng.below(400)),
                },
            );
        } else if roll < 9 && kseq[c] > 0 {
            let seq = rng.below(kseq[c] as u64) as u32;
            st.push(
                c,
                ClientMsg::Completion {
                    task_key: key,
                    task_id: TaskId(task_id[c]),
                    seq,
                    exec: Duration::from_micros(200 + rng.below(400)),
                    finished_at: st.next_now(),
                },
            );
        } else {
            st.push(
                c,
                ClientMsg::TaskEnd {
                    task_key: key.clone(),
                    task_id: TaskId(task_id[c]),
                },
            );
            task_id[c] += 1;
            st.push(
                c,
                ClientMsg::TaskStart {
                    task_key: key,
                    task_id: TaskId(task_id[c]),
                },
            );
        }
    }
    st.steps
}

/// Preemption-heavy trace: the reference run actually re-parks remnants,
/// and for every clean cut point the recovered daemon — including its
/// shard queues holding re-parked remnants and the `reparked` counter —
/// reconstructs the byte-identical image.
#[test]
fn preemption_heavy_trace_replays_deterministically() {
    for (i, seed) in SEEDS.into_iter().enumerate() {
        let steps = preempt_script(seed, 18);
        assert!(
            steps
                .iter()
                .any(|s| matches!(s.msg, ClientMsg::Preempted { .. })),
            "seed {seed}: script must contain preemptions"
        );
        let reference = reference_state(&steps);

        // The reference image really contains re-parked remnants.
        let mut d = SchedulerDaemon::new(DaemonConfig::default(), profiles());
        for s in &steps {
            d.handle_at(s.msg_seq, s.msg.clone(), s.addr, s.now);
        }
        assert!(
            d.shard_stats(0).reparked > 0,
            "seed {seed}: no remnant was re-parked"
        );
        drop(d);

        for k in 1..=steps.len() {
            let dir = fresh_dir(&format!("preempt-{i}-{k}"));
            let mut d = journaled(&dir, &no_snapshots());
            for s in &steps[..k] {
                d.handle_at(s.msg_seq, s.msg.clone(), s.addr, s.now);
            }
            drop(d); // the "kill"
            let state = recover_and_resume(&dir, &no_snapshots(), &steps, k - 1);
            assert_eq!(
                state, reference,
                "seed {seed}: preemption-heavy cut after step {k} must replay to the reference"
            );
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}

/// The snapshot + truncate cycle composes with crash recovery: with an
/// aggressive snapshot cadence the recovered image (snapshot + tail
/// replay) still matches the reference at every clean cut point.
#[test]
fn snapshot_cadence_preserves_replay_determinism() {
    let jcfg = JournalConfig {
        fsync: false,
        snapshot_every: 3,
    };
    for (i, seed) in SEEDS.into_iter().enumerate() {
        let steps = script(seed, 14);
        let reference = reference_state(&steps);
        for k in 1..=steps.len() {
            let dir = fresh_dir(&format!("snap-{i}-{k}"));
            let mut d = journaled(&dir, &jcfg);
            for s in &steps[..k] {
                d.handle_at(s.msg_seq, s.msg.clone(), s.addr, s.now);
            }
            drop(d);
            let state = recover_and_resume(&dir, &jcfg, &steps, k - 1);
            assert_eq!(
                state, reference,
                "seed {seed}: snapshot cadence must not change the cut-{k} replay image"
            );
            std::fs::remove_dir_all(&dir).ok();
        }
    }
}

/// The acceptance property stated directly: after a restart, no
/// previously admitted, still-live session is rejected — each one can
/// keep sending traffic under its existing registration, while a
/// session that disconnected before the crash stays gone.
#[test]
fn restarted_daemon_rejects_no_live_session() {
    let dir = fresh_dir("live");
    let mut d = journaled(&dir, &no_snapshots());
    let steps = script(7, 12);
    for s in &steps {
        d.handle_at(s.msg_seq, s.msg.clone(), s.addr, s.now);
    }
    // One session leaves cleanly before the crash.
    let next_seq = steps
        .iter()
        .filter(|s| s.addr == addr(CLIENTS[2].2))
        .map(|s| s.msg_seq)
        .max()
        .unwrap()
        + 1;
    d.handle_at(
        next_seq,
        ClientMsg::Disconnect {
            task_key: TaskKey::new("lo"),
        },
        addr(CLIENTS[2].2),
        SimTime(900_000_000),
    );
    assert_eq!(d.clients(), 2);
    drop(d); // kill

    let mut d2 = journaled(&dir, &no_snapshots());
    assert_eq!(d2.clients(), 2, "both live sessions survived the restart");
    // Each live session keeps operating under its pre-crash registration.
    for (c, (key, _, port, kernel)) in CLIENTS.iter().enumerate().take(2) {
        let last_seq = steps
            .iter()
            .filter(|s| s.addr == addr(*port))
            .map(|s| s.msg_seq)
            .max()
            .unwrap();
        let last_task = steps
            .iter()
            .filter_map(|s| match &s.msg {
                ClientMsg::TaskStart { task_id, .. } if s.addr == addr(*port) => Some(task_id.0),
                _ => None,
            })
            .max()
            .unwrap();
        let replies = d2.handle_at(
            last_seq + 1,
            ClientMsg::Launch {
                task_key: TaskKey::new(key),
                task_id: TaskId(last_task),
                kernel_name: kernel.to_string(),
                grid: Dim3::x(8),
                block: Dim3::x(128),
                seq: 1_000 + c as u32,
                issued_at: SimTime(901_000_000),
            },
            addr(*port),
            SimTime(901_000_000),
        );
        assert!(
            !replies.is_empty()
                && replies
                    .iter()
                    .all(|(_, m)| !matches!(m, SchedulerMsg::Error { .. })),
            "live session {key:?} must not be rejected after the restart"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
