//! Distributed-deployment integration: real hook clients talking to the
//! UDP scheduler daemon over loopback — the paper's client-server split.

use fikit::core::{Dim3, Duration, KernelId, Priority, SimTime, TaskId, TaskKey};
use fikit::hook::client::{HookClient, LaunchDecision};
use fikit::hook::protocol::ClientMsg;
use fikit::hook::transport::UdpTransport;
use fikit::profile::{ProfileStore, SymbolResolver, SymbolTableModel, TaskProfile};
use fikit::server::{SchedulerServer, ServerConfig};
use std::time::Duration as StdDuration;

fn kid(name: &str) -> KernelId {
    KernelId::new(name, Dim3::x(8), Dim3::x(128))
}

fn profiles() -> ProfileStore {
    let mut store = ProfileStore::new();
    let mut hi = TaskProfile::new(TaskKey::new("hi"));
    hi.record(&kid("hk"), Duration::from_micros(300), Some(Duration::from_millis(5)));
    hi.finish_run(1);
    store.insert(hi);
    let mut lo = TaskProfile::new(TaskKey::new("lo"));
    lo.record(&kid("lk"), Duration::from_micros(500), Some(Duration::from_micros(30)));
    lo.finish_run(1);
    store.insert(lo);
    store
}

fn spawn_server() -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let cfg = ServerConfig {
        bind: "127.0.0.1:0".to_string(),
        ..Default::default()
    };
    let mut server = SchedulerServer::bind(cfg, profiles()).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || {
        server.run_for(Some(StdDuration::from_secs(8))).unwrap();
    });
    (addr, handle)
}

fn client(addr: std::net::SocketAddr, key: &str, prio: Priority) -> HookClient<UdpTransport> {
    let transport = UdpTransport::connect(&addr.to_string()).unwrap();
    HookClient::new(
        transport,
        TaskKey::new(key),
        prio,
        SymbolResolver::new(SymbolTableModel::default()),
    )
}

#[test]
fn udp_register_reports_stage() {
    let (addr, handle) = spawn_server();
    // Profiled service → sharing stage.
    let mut hi = client(addr, "hi", Priority::P0);
    assert!(hi.register().unwrap());
    // Unprofiled service → measurement stage.
    let mut unknown = client(addr, "brand-new", Priority::P4);
    assert!(!unknown.register().unwrap());
    drop(handle); // server thread exits after its deadline
}

#[test]
fn udp_priority_scheduling_round_trip() {
    let (addr, _handle) = spawn_server();

    let mut hi = client(addr, "hi", Priority::P0);
    let mut lo = client(addr, "lo", Priority::P4);
    assert!(hi.register().unwrap());
    assert!(lo.register().unwrap());

    // Both start a task; the high-priority service holds the GPU.
    hi.task_start(TaskId(0)).unwrap();
    lo.task_start(TaskId(0)).unwrap();

    // Holder launch: immediate release.
    let d = hi
        .intercept_launch(&kid("hk"), TaskId(0), 0, SimTime(0))
        .unwrap();
    assert_eq!(d, LaunchDecision::LaunchNow);

    // Low-priority launch: held.
    let d = lo
        .intercept_launch(&kid("lk"), TaskId(0), 0, SimTime(0))
        .unwrap();
    assert_eq!(d, LaunchDecision::Held);

    // Holder kernel completes → window (SG=5ms) opens → the held 500µs
    // kernel fits and is released to the low-priority client.
    hi.report_completion(TaskId(0), 0, Duration::from_micros(300), SimTime(1_000_000))
        .unwrap();
    lo.wait_release(0).unwrap();

    // Tear down cleanly.
    hi.task_end(TaskId(0)).unwrap();
    lo.task_end(TaskId(0)).unwrap();
    hi.disconnect().unwrap();
    lo.disconnect().unwrap();
}

#[test]
fn udp_holder_change_releases_waiters() {
    let (addr, _handle) = spawn_server();
    let mut hi = client(addr, "hi", Priority::P0);
    let mut lo = client(addr, "lo", Priority::P4);
    hi.register().unwrap();
    lo.register().unwrap();
    hi.task_start(TaskId(0)).unwrap();
    lo.task_start(TaskId(0)).unwrap();

    // Low-priority launch parks.
    assert_eq!(
        lo.intercept_launch(&kid("lk"), TaskId(0), 3, SimTime(0)).unwrap(),
        LaunchDecision::Held
    );
    // Holder's task ends → low becomes holder → release arrives.
    hi.task_end(TaskId(0)).unwrap();
    lo.wait_release(3).unwrap();
}

#[test]
fn udp_server_rejects_garbage() {
    let (addr, _handle) = spawn_server();
    let sock = std::net::UdpSocket::bind("127.0.0.1:0").unwrap();
    sock.connect(addr).unwrap();
    sock.send(&[0xFF, 0xFF, b'x']).unwrap();
    sock.set_read_timeout(Some(StdDuration::from_secs(2))).unwrap();
    let mut buf = [0u8; 4096];
    let n = sock.recv(&mut buf).unwrap();
    let reply = fikit::hook::protocol::SchedulerMsg::decode(&buf[..n]).unwrap();
    assert!(matches!(reply, fikit::hook::protocol::SchedulerMsg::Error { .. }));
}

#[test]
fn udp_wire_is_inspectable_json() {
    // Operational property the protocol docs promise: frames after the
    // 2-byte header are plain JSON (tcpdump-debuggable).
    let msg = ClientMsg::TaskStart {
        task_key: TaskKey::new("svc"),
        task_id: TaskId(7),
    };
    let bytes = msg.encode().unwrap();
    let body = std::str::from_utf8(&bytes[2..]).unwrap();
    let parsed = fikit::util::json::Json::parse(body).unwrap();
    assert_eq!(parsed.req_str("type").unwrap(), "task_start");
}
