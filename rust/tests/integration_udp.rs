//! Distributed-deployment integration: real hook clients talking to the
//! sharded UDP scheduler daemon over loopback — the paper's
//! client-server split — plus the deterministic in-process lossy-fabric
//! runs that prove dropped-datagram recovery (DESIGN.md §Daemon).

use fikit::cluster::placement::PlacementPolicy;
use fikit::core::{Dim3, Duration, KernelId, Priority, SimTime, TaskId, TaskKey};
use fikit::daemon::{DaemonConfig, SchedulerDaemon};
use fikit::hook::client::{HookClient, LaunchDecision};
use fikit::hook::protocol::ClientMsg;
use fikit::hook::transport::{LossyNet, UdpTransport};
use fikit::profile::{ProfileStore, SymbolResolver, SymbolTableModel, TaskProfile};
use fikit::server::{SchedulerServer, ServerConfig};
use std::time::Duration as StdDuration;

fn kid(name: &str) -> KernelId {
    KernelId::new(name, Dim3::x(8), Dim3::x(128))
}

fn profile(key: &str, kernel: &str, exec_us: u64, gap_us: u64) -> TaskProfile {
    let mut p = TaskProfile::new(TaskKey::new(key));
    p.record(
        &kid(kernel),
        Duration::from_micros(exec_us),
        Some(Duration::from_micros(gap_us)),
    );
    p.finish_run(1);
    p
}

fn profiles() -> ProfileStore {
    let mut store = ProfileStore::new();
    store.insert(profile("hi", "hk", 300, 5_000));
    store.insert(profile("lo", "lk", 500, 30));
    store
}

fn spawn_server() -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
    let cfg = ServerConfig {
        bind: "127.0.0.1:0".to_string(),
        ..Default::default()
    };
    let mut server = SchedulerServer::bind(cfg, profiles()).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || {
        server.run_for(Some(StdDuration::from_secs(8))).unwrap();
    });
    (addr, handle)
}

fn client(addr: std::net::SocketAddr, key: &str, prio: Priority) -> HookClient<UdpTransport> {
    let transport = UdpTransport::connect(&addr.to_string()).unwrap();
    HookClient::new(
        transport,
        TaskKey::new(key),
        prio,
        SymbolResolver::new(SymbolTableModel::default()),
    )
}

#[test]
fn udp_register_reports_stage() {
    let (addr, handle) = spawn_server();
    // Profiled service → sharing stage.
    let mut hi = client(addr, "hi", Priority::P0);
    assert!(hi.register().unwrap());
    // Unprofiled service → measurement stage.
    let mut unknown = client(addr, "brand-new", Priority::P4);
    assert!(!unknown.register().unwrap());
    drop(handle); // server thread exits after its deadline
}

#[test]
fn udp_priority_scheduling_round_trip() {
    let (addr, _handle) = spawn_server();

    let mut hi = client(addr, "hi", Priority::P0);
    let mut lo = client(addr, "lo", Priority::P4);
    assert!(hi.register().unwrap());
    assert!(lo.register().unwrap());

    // Both start a task; the high-priority service holds the GPU.
    hi.task_start(TaskId(0)).unwrap();
    lo.task_start(TaskId(0)).unwrap();

    // Holder launch: immediate release.
    let d = hi
        .intercept_launch(&kid("hk"), TaskId(0), 0, SimTime(0))
        .unwrap();
    assert_eq!(d, LaunchDecision::LaunchNow);

    // Low-priority launch: held.
    let d = lo
        .intercept_launch(&kid("lk"), TaskId(0), 0, SimTime(0))
        .unwrap();
    assert_eq!(d, LaunchDecision::Held);

    // Holder kernel completes → window (SG=5ms) opens → the held 500µs
    // kernel fits and is released to the low-priority client.
    hi.report_completion(TaskId(0), 0, Duration::from_micros(300), SimTime(1_000_000))
        .unwrap();
    lo.wait_release(0).unwrap();

    // Tear down cleanly.
    hi.task_end(TaskId(0)).unwrap();
    lo.task_end(TaskId(0)).unwrap();
    hi.disconnect().unwrap();
    lo.disconnect().unwrap();
}

#[test]
fn udp_holder_change_releases_waiters() {
    let (addr, _handle) = spawn_server();
    let mut hi = client(addr, "hi", Priority::P0);
    let mut lo = client(addr, "lo", Priority::P4);
    hi.register().unwrap();
    lo.register().unwrap();
    hi.task_start(TaskId(0)).unwrap();
    lo.task_start(TaskId(0)).unwrap();

    // Low-priority launch parks.
    assert_eq!(
        lo.intercept_launch(&kid("lk"), TaskId(0), 3, SimTime(0)).unwrap(),
        LaunchDecision::Held
    );
    // Holder's task ends → low becomes holder → release arrives.
    hi.task_end(TaskId(0)).unwrap();
    lo.wait_release(3).unwrap();
}

/// `fikit serve --devices 2` shape over real UDP: two high/low service
/// pairs land on different device shards and fill independently.
#[test]
fn udp_two_device_daemon_fills_per_device() {
    let mut store = ProfileStore::new();
    store.insert(profile("hi1", "hk", 300, 5_000));
    store.insert(profile("hi2", "hk", 300, 5_000));
    store.insert(profile("lo1", "lk", 500, 30));
    store.insert(profile("lo2", "lk", 500, 30));
    let cfg = ServerConfig {
        bind: "127.0.0.1:0".to_string(),
        devices: 2,
        capacity: 2,
        policy: PlacementPolicy::LeastLoaded,
        ..Default::default()
    };
    let mut server = SchedulerServer::bind(cfg, store).unwrap();
    let addr = server.local_addr().unwrap();
    let handle = std::thread::spawn(move || {
        server
            .run_until_drained(Some(StdDuration::from_secs(10)))
            .unwrap();
        server
    });

    let mut hi1 = client(addr, "hi1", Priority::P0);
    let mut hi2 = client(addr, "hi2", Priority::P0);
    let mut lo1 = client(addr, "lo1", Priority::P5);
    let mut lo2 = client(addr, "lo2", Priority::P5);
    // Registration order + equal demands → LeastLoaded alternates
    // devices: (hi1, lo1) on shard 0, (hi2, lo2) on shard 1.
    for c in [&mut hi1, &mut hi2, &mut lo1, &mut lo2] {
        c.register().unwrap();
        c.task_start(TaskId(0)).unwrap();
    }
    // Each hi is its own device's holder; each lo parks behind it.
    for hi in [&mut hi1, &mut hi2] {
        assert_eq!(
            hi.intercept_launch(&kid("hk"), TaskId(0), 0, SimTime(0)).unwrap(),
            LaunchDecision::LaunchNow
        );
    }
    for lo in [&mut lo1, &mut lo2] {
        assert_eq!(
            lo.intercept_launch(&kid("lk"), TaskId(0), 0, SimTime(0)).unwrap(),
            LaunchDecision::Held
        );
    }
    // Both holders complete → a window opens on EACH device and fills
    // its own parked launch.
    hi1.report_completion(TaskId(0), 0, Duration::from_micros(300), SimTime(1)).unwrap();
    hi2.report_completion(TaskId(0), 0, Duration::from_micros(300), SimTime(1)).unwrap();
    lo1.wait_release(0).unwrap();
    lo2.wait_release(0).unwrap();
    for c in [&mut hi1, &mut hi2, &mut lo1, &mut lo2] {
        c.task_end(TaskId(0)).unwrap();
        c.disconnect().unwrap();
    }

    let server = handle.join().unwrap();
    let daemon = server.daemon();
    for device in [0, 1] {
        let s = daemon.shard_stats(device);
        assert_eq!(s.windows, 1, "each device opened its own window");
        assert_eq!(s.holds, 1);
        assert_eq!(s.releases_filled, 1, "fills happened per device");
        assert_eq!(s.releases_drained, 0);
    }
    // Clean teardown left no daemon-side state behind.
    assert_eq!(daemon.clients(), 0);
    for sizes in daemon.shard_sizes() {
        assert_eq!(sizes.active, 0);
        assert_eq!(sizes.queued, 0);
        assert_eq!(sizes.launched_kernels, 0);
    }
}

#[test]
fn udp_server_rejects_garbage() {
    let (addr, _handle) = spawn_server();
    let sock = std::net::UdpSocket::bind("127.0.0.1:0").unwrap();
    sock.connect(addr).unwrap();
    sock.send(&[0xFF, 0xFF, b'x']).unwrap();
    sock.set_read_timeout(Some(StdDuration::from_secs(2))).unwrap();
    let mut buf = [0u8; 4096];
    let n = sock.recv(&mut buf).unwrap();
    let reply = fikit::hook::protocol::SchedulerMsg::decode(&buf[..n]).unwrap();
    assert!(matches!(reply, fikit::hook::protocol::SchedulerMsg::Error { .. }));
}

#[test]
fn udp_wire_is_inspectable_json() {
    // Operational property the protocol docs promise: frames after the
    // 2-byte header are plain JSON (tcpdump-debuggable), including the
    // v2 retransmit envelope.
    let msg = ClientMsg::TaskStart {
        task_key: TaskKey::new("svc"),
        task_id: TaskId(7),
    };
    let bytes = msg.encode_seq(42).unwrap();
    let body = std::str::from_utf8(&bytes[2..]).unwrap();
    let parsed = fikit::util::json::Json::parse(body).unwrap();
    assert_eq!(parsed.req_str("type").unwrap(), "task_start");
    assert_eq!(parsed.req_u64("msg_seq").unwrap(), 42);
}

// ---------------------------------------------------------------------
// Lossy-fabric convergence runs
// ---------------------------------------------------------------------

/// What one client observed during a scenario run. Note what this can
/// and cannot prove: the clients are stop-and-wait, so a run that
/// *completes* necessarily granted every seq in order — the release
/// sequence differing between runs is impossible without a panic. The
/// trace's value is (a) documenting that observable, and (b) the
/// completeness check `releases == 0..K` failing loudly if a client
/// loop is ever restructured to skip or duplicate a grant. The real
/// loss-tolerance evidence is the lossy run finishing at all, plus the
/// daemon-side conservation and drain assertions below.
#[derive(Debug, PartialEq, Eq)]
struct ClientTrace {
    /// Kernel seqs in the order their release was granted.
    releases: Vec<u32>,
}

/// Sizes + stats snapshot after a fully drained phase.
#[derive(Debug, PartialEq, Eq, Clone, Copy)]
struct DrainSnapshot {
    queued: usize,
    launched_kernels: usize,
    interned_tasks: usize,
    interned_kernels: usize,
    clients: usize,
}

const KERNELS_PER_TASK: u32 = 6;

/// Drive the canonical hi/lo scenario over an in-process fabric with the
/// given drop rate; returns per-client traces plus the daemon after one
/// fully drained phase.
fn run_scenario(
    net: &std::sync::Arc<LossyNet>,
    mut daemon: SchedulerDaemon,
) -> (ClientTrace, ClientTrace, SchedulerDaemon) {
    let server_t = net.server_endpoint();
    let daemon_thread = std::thread::spawn(move || {
        daemon
            .serve(&server_t, Some(StdDuration::from_secs(30)), true)
            .unwrap();
        daemon
    });

    let mk = |port: u16, key: &str, prio: Priority| {
        let mut c = HookClient::new(
            net.client_endpoint(port),
            TaskKey::new(key),
            prio,
            SymbolResolver::new(SymbolTableModel::default()),
        );
        // Short per-attempt waits, many attempts: convergence under 20%
        // loss needs retries, not patience.
        c.set_retry(StdDuration::from_millis(40), 25);
        c
    };
    let mut hi = mk(9001, "hi", Priority::P0);
    let mut lo = mk(9002, "lo", Priority::P4);
    // Register from this thread, serially, so the daemon cannot observe
    // an "everyone disconnected" instant between the two registrations.
    hi.register().unwrap();
    lo.register().unwrap();

    let hi_thread = std::thread::spawn(move || {
        hi.task_start(TaskId(0)).unwrap();
        let mut trace = ClientTrace { releases: Vec::new() };
        for seq in 0..KERNELS_PER_TASK {
            match hi.intercept_launch(&kid("hk"), TaskId(0), seq, SimTime(0)).unwrap() {
                LaunchDecision::LaunchNow => {}
                LaunchDecision::Held => hi.wait_release(seq).unwrap(),
            }
            trace.releases.push(seq);
            hi.report_completion(TaskId(0), seq, Duration::from_micros(300), SimTime(1)).unwrap();
        }
        hi.task_end(TaskId(0)).unwrap();
        // Best-effort: once the last Disconnect is processed the daemon
        // drains and exits, so the final ack (or its retransmit window)
        // may be unanswerable. `assert_drained` checks the daemon side.
        let _ = hi.disconnect();
        trace
    });
    let lo_thread = std::thread::spawn(move || {
        lo.task_start(TaskId(0)).unwrap();
        let mut trace = ClientTrace { releases: Vec::new() };
        for seq in 0..KERNELS_PER_TASK {
            match lo.intercept_launch(&kid("lk"), TaskId(0), seq, SimTime(0)).unwrap() {
                LaunchDecision::LaunchNow => {}
                LaunchDecision::Held => lo.wait_release(seq).unwrap(),
            }
            trace.releases.push(seq);
        }
        lo.task_end(TaskId(0)).unwrap();
        let _ = lo.disconnect();
        trace
    });

    let hi_trace = hi_thread.join().expect("hi client panicked");
    let lo_trace = lo_thread.join().expect("lo client panicked");
    let daemon = daemon_thread.join().expect("daemon panicked");
    (hi_trace, lo_trace, daemon)
}

fn snapshot(daemon: &SchedulerDaemon) -> DrainSnapshot {
    let sizes = daemon.shard_sizes()[0];
    DrainSnapshot {
        queued: sizes.queued,
        launched_kernels: sizes.launched_kernels,
        interned_tasks: sizes.interned_tasks,
        interned_kernels: sizes.interned_kernels,
        clients: daemon.clients(),
    }
}

/// `rounds` = scenario phases this daemon has served so far (its stats
/// are cumulative across phases).
fn assert_drained(daemon: &SchedulerDaemon, rounds: u64) {
    let snap = snapshot(daemon);
    assert_eq!(snap.clients, 0, "every client disconnected");
    assert_eq!(snap.queued, 0, "no orphaned held launches");
    assert_eq!(snap.launched_kernels, 0, "completion-lookup map purged");
    // The interner is append-only by design, but bounded by holder
    // identities — NOT by traffic volume.
    assert!(snap.interned_tasks <= 1, "only the holder service is interned");
    // Conservation: every parked launch was released exactly one way.
    let s = daemon.stats_total();
    assert_eq!(
        s.holds,
        s.releases_filled + s.releases_drained,
        "every held launch eventually released (none purged, none lost)"
    );
    assert_eq!(
        s.releases_immediate + s.releases_filled + s.releases_drained,
        rounds * 2 * KERNELS_PER_TASK as u64,
        "each kernel launch released exactly once despite retransmits"
    );
}

/// The loss-tolerance acceptance run: the same scenario over a lossless
/// and a seeded 20%-drop fabric converges to the same per-client release
/// sequence, with zero daemon-side map growth after all clients
/// disconnect — asserted on `launched_kernels`, queue and interner
/// sizes. A second phase (same services reconnect) proves the maps do
/// not grow across churn either.
#[test]
fn lossy_transport_converges_to_lossless_outcome() {
    // Phase A: lossless reference.
    let lossless = LossyNet::new(0xF1C1, 0);
    let daemon = SchedulerDaemon::new(DaemonConfig::default(), profiles());
    let (hi_ref, lo_ref, daemon) = run_scenario(&lossless, daemon);
    assert_drained(&daemon, 1);
    assert_eq!(lossless.dropped(), (0, 0));

    // Phase B: seeded 20% drops in both directions, fresh daemon.
    let lossy = LossyNet::new(0xF1C1, 200);
    let daemon = SchedulerDaemon::new(DaemonConfig::default(), profiles());
    let (hi_lossy, lo_lossy, daemon) = run_scenario(&lossy, daemon);
    assert_drained(&daemon, 1);
    let (up, down) = lossy.dropped();
    assert!(up + down > 0, "the fabric must actually have dropped datagrams");

    // Convergence: loss changed nothing observable at the clients —
    // both runs granted the complete in-order release sequence (see the
    // ClientTrace docs for what this does and does not prove).
    let expected: Vec<u32> = (0..KERNELS_PER_TASK).collect();
    assert_eq!(hi_ref.releases, expected, "lossless run granted every seq in order");
    assert_eq!(lo_ref.releases, expected);
    assert_eq!(hi_lossy, hi_ref, "holder release sequence identical under loss");
    assert_eq!(lo_lossy, lo_ref, "waiter release sequence identical under loss");

    // Phase C: the SAME daemon serves the same services again (churn
    // round 2) — map sizes must be identical after draining, i.e. zero
    // growth across reconnect cycles.
    let after_first = snapshot(&daemon);
    let net2 = LossyNet::new(0xBEEF, 200);
    let (_, _, daemon) = run_scenario(&net2, daemon);
    assert_drained(&daemon, 2);
    assert_eq!(
        snapshot(&daemon),
        after_first,
        "no daemon-side map grew across a full reconnect/traffic/drain cycle"
    );
}

/// Durable-daemon acceptance over a lossy fabric (ADR-004): a journaled
/// daemon is killed abruptly mid-scenario (fixed datagram budget, no
/// clean shutdown) and a second incarnation recovers from the same
/// journal directory while the clients keep retransmitting into the
/// restart gap. Both admitted sessions must survive the restart, the
/// run must converge to the same complete release sequence as a
/// lossless run, and the recovered daemon must satisfy the exact same
/// conservation + zero-map-growth drain asserts as the single-process
/// lossy run above.
#[test]
fn daemon_restart_under_loss_converges() {
    use fikit::daemon::JournalConfig;

    let dir = std::env::temp_dir().join(format!("fikit-udp-restart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let jcfg = JournalConfig {
        fsync: false,
        snapshot_every: 4, // exercise snapshot + truncate mid-run too
    };

    let net = LossyNet::new(0xD00D, 200);
    let server_t = net.server_endpoint();
    let dir_d = dir.clone();
    let jcfg_d = jcfg.clone();
    let daemon_thread = std::thread::spawn(move || {
        // Incarnation 1: dies after a fixed datagram budget lands the
        // cut mid-traffic — registrations done, kernels in flight.
        let mut d1 = SchedulerDaemon::with_journal(
            DaemonConfig::default(),
            profiles(),
            &dir_d,
            jcfg_d.clone(),
        )
        .unwrap();
        d1.serve_limited(&server_t, Some(StdDuration::from_secs(30)), false, Some(12))
            .unwrap();
        let admitted = d1.clients();
        drop(d1); // the kill: no clean shutdown, sessions still live

        // Incarnation 2: recover and finish the scenario.
        let mut d2 = SchedulerDaemon::with_journal(
            DaemonConfig::default(),
            profiles(),
            &dir_d,
            jcfg_d,
        )
        .unwrap();
        assert_eq!(
            d2.clients(),
            admitted,
            "every session admitted before the kill survived the restart"
        );
        d2.serve(&server_t, Some(StdDuration::from_secs(30)), true)
            .unwrap();
        d2
    });

    let mk = |port: u16, key: &str, prio: Priority| {
        let mut c = HookClient::new(
            net.client_endpoint(port),
            TaskKey::new(key),
            prio,
            SymbolResolver::new(SymbolTableModel::default()),
        );
        // Generous retry budget: retransmits must ride out both 20%
        // loss AND the restart gap.
        c.set_retry(StdDuration::from_millis(40), 50);
        c
    };
    let mut hi = mk(9001, "hi", Priority::P0);
    let mut lo = mk(9002, "lo", Priority::P4);
    hi.register().unwrap();
    lo.register().unwrap();

    let hi_thread = std::thread::spawn(move || {
        hi.task_start(TaskId(0)).unwrap();
        let mut releases = Vec::new();
        for seq in 0..KERNELS_PER_TASK {
            match hi.intercept_launch(&kid("hk"), TaskId(0), seq, SimTime(0)).unwrap() {
                LaunchDecision::LaunchNow => {}
                LaunchDecision::Held => hi.wait_release(seq).unwrap(),
            }
            releases.push(seq);
            hi.report_completion(TaskId(0), seq, Duration::from_micros(300), SimTime(1)).unwrap();
        }
        hi.task_end(TaskId(0)).unwrap();
        let _ = hi.disconnect();
        releases
    });
    let lo_thread = std::thread::spawn(move || {
        lo.task_start(TaskId(0)).unwrap();
        let mut releases = Vec::new();
        for seq in 0..KERNELS_PER_TASK {
            match lo.intercept_launch(&kid("lk"), TaskId(0), seq, SimTime(0)).unwrap() {
                LaunchDecision::LaunchNow => {}
                LaunchDecision::Held => lo.wait_release(seq).unwrap(),
            }
            releases.push(seq);
        }
        lo.task_end(TaskId(0)).unwrap();
        let _ = lo.disconnect();
        releases
    });

    let hi_releases = hi_thread.join().expect("hi client panicked");
    let lo_releases = lo_thread.join().expect("lo client panicked");
    let daemon = daemon_thread.join().expect("daemon panicked");

    // Convergence: the restart changed nothing observable — both clients
    // were granted the complete in-order release sequence.
    let expected: Vec<u32> = (0..KERNELS_PER_TASK).collect();
    assert_eq!(hi_releases, expected, "holder granted every seq across the restart");
    assert_eq!(lo_releases, expected, "waiter granted every seq across the restart");

    // The recovered daemon drains to the same conservation + map-size
    // image as an unbroken run (stats are journal-reconstructed, so the
    // cross-incarnation totals must balance exactly).
    assert_drained(&daemon, 1);
    std::fs::remove_dir_all(&dir).ok();
}
