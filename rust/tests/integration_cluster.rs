//! Cross-layer cluster tests: incremental placement invariants
//! (DESIGN.md §7/§8) and dynamic service membership on a live GPU sim.
//!
//! The invariants under test:
//!
//! 1. **Capacity**: no place → depart → re-place sequence ever leaves a
//!    device hosting more services than its capacity, and load
//!    accounting never goes negative.
//! 2. **Compatibility dominance**: on mixed detector/filler sequences,
//!    the compatibility-aware BestMatch policy never ends up with a
//!    worse *predicted* high-priority slowdown than workload-blind
//!    LeastLoaded on the same sequence.
//! 3. **Dynamic membership**: a service attached to a running GPU sim
//!    does real work; detaching drains (never cuts) its in-flight task;
//!    a drained key can be reattached (migration back).

use fikit::cluster::{CompatMatrix, FleetState, PlacementPolicy, Resident};
use fikit::config::{ExperimentConfig, ServiceConfig};
use fikit::coordinator::driver::{DetachOutcome, GpuSim};
use fikit::coordinator::Mode;
use fikit::core::{Duration, Priority, SimTime, TaskKey};
use fikit::profile::ProfileStore;
use fikit::util::rng::Rng;
use fikit::workload::{InvocationPattern, ModelKind};

/// Every model a fleet test draws from, split by role.
const DETECTORS: [ModelKind; 3] = [
    ModelKind::KeypointRcnnResnet50Fpn,
    ModelKind::MaskrcnnResnet50Fpn,
    ModelKind::FasterrcnnResnet50Fpn,
];
const FILLERS: [ModelKind; 4] = [
    ModelKind::FcnResnet50,
    ModelKind::Resnet101,
    ModelKind::Vgg16,
    ModelKind::Googlenet,
];

fn check_fleet_invariants(fleet: &FleetState) {
    for gpu in 0..fleet.gpus() {
        assert!(
            fleet.residents_on(gpu).len() <= fleet.capacity(),
            "GPU {gpu} over capacity: {} > {}",
            fleet.residents_on(gpu).len(),
            fleet.capacity()
        );
        assert!(
            fleet.load_ms(gpu) >= 0.0,
            "GPU {gpu} negative load {}",
            fleet.load_ms(gpu)
        );
    }
}

#[test]
fn random_place_depart_replace_respects_capacity() {
    let compat = CompatMatrix::new();
    for seed in 0..8u64 {
        let mut rng = Rng::new(0xCAFE + seed);
        let mut fleet = FleetState::new(3, 2);
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        let mut placed = 0usize;
        let mut refused = 0usize;
        for _ in 0..200 {
            let arrive = live.is_empty() || rng.chance(0.55);
            if arrive {
                let model = if rng.chance(0.4) {
                    DETECTORS[rng.index(DETECTORS.len())]
                } else {
                    FILLERS[rng.index(FILLERS.len())]
                };
                let prio = Priority::from_index(rng.index(10)).unwrap();
                let id = next_id;
                next_id += 1;
                let policy = match rng.index(3) {
                    0 => PlacementPolicy::RoundRobin,
                    1 => PlacementPolicy::LeastLoaded,
                    _ => PlacementPolicy::BestMatch,
                };
                match fleet.place(policy, Resident::per_task(id, model, prio), &compat) {
                    Some(gpu) => {
                        assert_eq!(fleet.gpu_of(id), Some(gpu));
                        live.push(id);
                        placed += 1;
                    }
                    None => {
                        // Refusal is only legal when the fleet really is full.
                        assert_eq!(
                            fleet.total_residents(),
                            fleet.gpus() * fleet.capacity(),
                            "placement refused with free capacity (seed {seed})"
                        );
                        refused += 1;
                    }
                }
            } else {
                let pos = rng.index(live.len());
                let id = live.swap_remove(pos);
                assert!(fleet.evict(id).is_some(), "live service {id} not resident");
                assert_eq!(fleet.gpu_of(id), None);
            }
            check_fleet_invariants(&fleet);
        }
        assert!(placed > 50, "seed {seed}: degenerate sequence ({placed} placements)");
        // Both outcomes should occur over a 200-op random walk on a 6-slot fleet.
        assert!(refused > 0, "seed {seed}: capacity never binding");
    }
}

#[test]
fn best_match_dominates_least_loaded_on_predicted_qos() {
    let compat = CompatMatrix::new();
    let mut bm_total = 0.0f64;
    let mut ll_total = 0.0f64;
    for seed in 0..10u64 {
        let mut rng = Rng::new(0xBEEF + seed);
        // Mixed sequence: two high-priority detectors plus low-priority
        // fillers, arriving interleaved; enough slack that no policy is
        // ever forced into a bad pairing.
        let mut residents: Vec<Resident> = vec![
            Resident::per_task(0, DETECTORS[rng.index(DETECTORS.len())], Priority::P0),
            Resident::per_task(1, DETECTORS[rng.index(DETECTORS.len())], Priority::P1),
        ];
        for id in 2..7u64 {
            residents.push(Resident::per_task(
                id,
                FILLERS[rng.index(FILLERS.len())],
                Priority::from_index(4 + rng.index(6)).unwrap(),
            ));
        }
        // Shuffle the fillers' arrival order (Fisher–Yates on the seeded
        // rng); the detectors arrive first, as real fleets pin their
        // latency-critical tenants before backfilling.
        for i in (3..residents.len()).rev() {
            let j = 2 + rng.index(i - 1);
            residents.swap(i, j);
        }

        let play = |policy: PlacementPolicy| -> f64 {
            let mut fleet = FleetState::new(3, 3);
            for r in &residents {
                fleet
                    .place(policy, r.clone(), &compat)
                    .expect("9 slots for 7 services");
                check_fleet_invariants(&fleet);
            }
            fleet.worst_predicted_high_slowdown(&compat)
        };
        let bm = play(PlacementPolicy::BestMatch);
        let ll = play(PlacementPolicy::LeastLoaded);
        bm_total += bm;
        ll_total += ll;
        assert!(
            bm <= ll * 1.05 + 1e-9,
            "seed {seed}: BestMatch predicted slowdown {bm:.3} worse than LeastLoaded {ll:.3}"
        );
    }
    assert!(
        bm_total <= ll_total + 1e-9,
        "aggregate: BestMatch {bm_total:.3} vs LeastLoaded {ll_total:.3}"
    );
}

#[test]
fn depart_then_replace_reuses_freed_capacity() {
    let compat = CompatMatrix::new();
    let mut fleet = FleetState::new(2, 1);
    assert!(fleet
        .place(
            PlacementPolicy::LeastLoaded,
            Resident::per_task(0, ModelKind::Resnet50, Priority::P0),
            &compat
        )
        .is_some());
    assert!(fleet
        .place(
            PlacementPolicy::LeastLoaded,
            Resident::per_task(1, ModelKind::Vgg16, Priority::P5),
            &compat
        )
        .is_some());
    // Full. A third service is refused until someone leaves.
    assert!(fleet
        .place(
            PlacementPolicy::LeastLoaded,
            Resident::per_task(2, ModelKind::Alexnet, Priority::P2),
            &compat
        )
        .is_none());
    let freed = fleet.evict(0).unwrap();
    let gpu = fleet
        .place(
            PlacementPolicy::LeastLoaded,
            Resident::per_task(2, ModelKind::Alexnet, Priority::P2),
            &compat,
        )
        .unwrap();
    assert_eq!(gpu, freed, "replacement lands on the freed device");
    check_fleet_invariants(&fleet);
}

// ---------------------------------------------------------------------
// Dynamic membership on a live GpuSim
// ---------------------------------------------------------------------

fn continuous(model: ModelKind, prio: Priority, key: &str) -> ServiceConfig {
    let mut svc = ServiceConfig::new(model, prio).with_key(key);
    svc.pattern = InvocationPattern::ContinuousUntil {
        until: SimTime::MAX,
    };
    svc
}

#[test]
fn attach_detach_drains_and_allows_reattach() {
    let cfg = ExperimentConfig {
        mode: Mode::Sharing,
        ..ExperimentConfig::default()
    };
    let store = ProfileStore::new();
    let mut sim = GpuSim::new(&cfg, &store).unwrap();
    assert!(sim.is_idle());
    assert_eq!(sim.live_services(), 0);

    let svc = continuous(ModelKind::Alexnet, Priority::P0, "dyn");
    let key = TaskKey::new("dyn");
    sim.attach(&svc, SimTime::ZERO).unwrap();
    assert_eq!(sim.live_services(), 1);
    assert!(!sim.can_attach(&key), "live key must be refused");
    assert!(
        sim.attach(&svc, SimTime::ZERO).is_err(),
        "duplicate live key rejected"
    );

    // Run 50 ms of serving: alexnet (~1.4 ms JCT) completes many tasks.
    let t1 = SimTime::ZERO + Duration::from_millis(50);
    sim.run_until(t1);
    let after_50ms = sim.outcomes().len();
    assert!(after_50ms >= 10, "only {after_50ms} tasks in 50ms");
    assert_eq!(sim.now(), t1);

    // Detach mid-run: the in-flight task drains, nothing new starts.
    let outcome = sim.detach(&key).unwrap();
    assert!(matches!(
        outcome,
        DetachOutcome::Draining | DetachOutcome::Idle
    ));
    assert_eq!(sim.live_services(), 0);
    sim.run_until(SimTime::MAX);
    let drained = sim.outcomes().len();
    assert!(
        drained == after_50ms || drained == after_50ms + 1,
        "drain may finish at most the one in-flight task: {after_50ms} -> {drained}"
    );
    assert!(sim.is_idle());
    assert!(!sim.is_draining(&key));

    // The drained key is reusable: attach again (migration back).
    assert!(sim.can_attach(&key));
    sim.attach(&svc, sim.now() + Duration::from_millis(1)).unwrap();
    assert_eq!(sim.live_services(), 1);
    let t2 = sim.now() + Duration::from_millis(20);
    sim.run_until(t2);
    assert!(
        sim.outcomes().len() > drained,
        "reattached service does no work"
    );
}

#[test]
fn attach_in_fikit_mode_requires_a_profile() {
    let cfg = ExperimentConfig {
        mode: Mode::Fikit,
        ..ExperimentConfig::default()
    };
    let store = ProfileStore::new();
    let mut sim = GpuSim::new(&cfg, &store).unwrap();
    let svc = continuous(ModelKind::Alexnet, Priority::P0, "unprofiled");
    assert!(
        sim.attach(&svc, SimTime::ZERO).is_err(),
        "FIKIT attach without a preloaded profile must fail"
    );
}

#[test]
fn detached_service_stops_consuming_device_time() {
    let cfg = ExperimentConfig {
        mode: Mode::Sharing,
        ..ExperimentConfig::default()
    };
    let store = ProfileStore::new();
    let mut sim = GpuSim::new(&cfg, &store).unwrap();
    sim.attach(
        &continuous(ModelKind::Alexnet, Priority::P5, "bg"),
        SimTime::ZERO,
    )
    .unwrap();
    sim.run_until(SimTime::ZERO + Duration::from_millis(20));
    sim.detach(&TaskKey::new("bg")).unwrap();
    sim.run_until(SimTime::MAX);
    let busy_after_drain = sim.device_stats().busy;
    let end_after_drain = sim.now();

    // Idle long after the drain: no further device time accrues.
    assert!(sim.is_idle());
    assert_eq!(sim.device_stats().busy, busy_after_drain);
    // The drain finished shortly after the detach (one task ≈ 1.4 ms),
    // not at some far-future point.
    assert!(
        end_after_drain < SimTime::ZERO + Duration::from_millis(40),
        "drain ran too long: {end_after_drain}"
    );
}
