//! Fleet fault-injection acceptance: the scripted node-failure churn
//! scenarios from DESIGN.md §Fleet-federation, run over real federated
//! daemons on the seeded lossy fabric.
//!
//! These are the closing-the-loop tests for the federation control
//! plane: a node is killed (or partitioned) mid-traffic and every
//! in-flight client session must either complete on a failover node or
//! end in an explicit shed reply — no silent loss, no hangs, no
//! duplicate side effects. [`run_node_churn`] asserts held-launch
//! conservation and bounded per-operation latency internally; the
//! scenarios here assert the fleet-level outcomes on top.

use fikit::cluster::{run_node_churn, NodeChurnConfig};
use fikit::core::Duration;
use std::time::Duration as StdDuration;

/// 3 nodes, 20% packet loss, node 2 killed abruptly mid-traffic and
/// restarted from its journal 2.5 s later.
fn kill_restart_cfg(seed: u64) -> NodeChurnConfig {
    let mut cfg = NodeChurnConfig::new(seed);
    cfg.nodes = 3;
    cfg.capacity = 3;
    cfg.clients = 6;
    cfg.tasks_per_client = 6;
    cfg.kernels_per_task = 6;
    cfg.drop_permille = 200;
    // Stretch sessions past the kill point so node 2's clients are
    // genuinely in flight when their node vanishes.
    cfg.kernel_pace = StdDuration::from_millis(25);
    cfg.kill_node = Some(2);
    // Late enough that incarnation 1 has emitted well over
    // `restart_seq_gap` beacons, so incarnation 2's seq regression is
    // folded as a restart by the survivors.
    cfg.kill_after = StdDuration::from_millis(1_200);
    // Orphans need ~1 s of timed-out retries to declare the node dead
    // and fail over; restarting only after that window keeps the
    // scenario honest (no transparent-restart racing the failover).
    cfg.restart_after = Some(StdDuration::from_millis(2_500));
    cfg
}

#[test]
fn killed_node_fails_over_and_rejoins_from_journal() {
    for seed in [0xfee7_0001u64, 0xfee7_0002, 0xfee7_0003] {
        let cfg = kill_restart_cfg(seed);
        let report = run_node_churn(&cfg).unwrap();

        // Every session is accounted for: completed (possibly on a
        // failover node) or explicitly shed. run_node_churn already
        // failed the run on any other outcome.
        assert_eq!(
            report.completed + report.shed,
            cfg.clients,
            "seed {seed:#x}: lost sessions — outcomes {:?}",
            report.outcomes
        );
        // Node 2's two home clients were mid-session at the kill; both
        // must have switched endpoints.
        assert!(
            report.failovers >= 2,
            "seed {seed:#x}: expected both orphans to fail over, saw {}",
            report.failovers
        );
        // With 9 fleet slots for 6 clients the orphans find room; at
        // most a transient race sheds one.
        assert!(
            report.completed >= cfg.clients - 1,
            "seed {seed:#x}: too many sheds — outcomes {:?}",
            report.outcomes
        );
        // The restarted incarnation replayed its journal: the orphaned
        // sessions were re-admitted, not forgotten.
        assert!(
            report.rejoined_sessions > 0,
            "seed {seed:#x}: journal replay re-admitted no sessions"
        );
        // Survivors folded the beacon-seq regression as a peer restart
        // and let incarnation 2 back into their fleet views.
        assert!(
            report.restarts_observed >= 1,
            "seed {seed:#x}: no survivor observed the restart"
        );
        for (i, lp) in report.live_peers.iter().enumerate() {
            if i == 2 {
                assert!(lp.is_some(), "seed {seed:#x}: restarted node not running");
            } else {
                assert_eq!(
                    *lp,
                    Some(2),
                    "seed {seed:#x}: node {i} does not see the full fleet"
                );
            }
        }
    }
}

#[test]
fn partitioned_node_heals_and_reenters_the_fleet() {
    let mut cfg = NodeChurnConfig::new(0x9a27_1710);
    cfg.nodes = 3;
    cfg.capacity = 3;
    cfg.clients = 6;
    cfg.tasks_per_client = 6;
    cfg.kernels_per_task = 6;
    cfg.drop_permille = 150;
    cfg.kernel_pace = StdDuration::from_millis(25);
    cfg.partition_node = Some(1);
    cfg.partition_after = StdDuration::from_millis(500);
    // Heal only after the orphans' ~1 s retry budget has expired, so
    // failover genuinely happens before the partition lifts.
    cfg.partition_for = StdDuration::from_millis(2_000);
    cfg.beacon_interval = Duration::from_millis(25);

    let report = run_node_churn(&cfg).unwrap();
    assert_eq!(
        report.completed + report.shed,
        cfg.clients,
        "lost sessions — outcomes {:?}",
        report.outcomes
    );
    assert!(
        report.failovers >= 2,
        "expected node 1's clients to fail over, saw {}",
        report.failovers
    );
    // A partition is not a restart: the node's beacon seq stays
    // monotone through the outage, so nobody folds a restart.
    assert_eq!(
        report.restarts_observed, 0,
        "partition misread as a restart"
    );
    // After healing plus a settle window every node sees every other
    // node alive again — the partitioned node re-entered placement.
    for (i, lp) in report.live_peers.iter().enumerate() {
        assert_eq!(*lp, Some(2), "node {i} still isolated after heal");
    }
}
