//! Acceptance gate for the zero-allocation scheduler hot path
//! (DESIGN.md §Perf "hot-path data structures").
//!
//! Two independent instruments:
//!
//! * a **counting global allocator** proves the steady-state sharing
//!   loop — `on_launch` (enqueue with pre-resolved SK) → holder
//!   completion (`on_kernel_done`, SG lookup, window open) → BestPrioFit
//!   fill selection — performs literally zero heap allocations once
//!   container capacities are warm;
//! * the **`canonical()` call counter** (debug builds count every call)
//!   proves no canonical-string materialization is reachable from that
//!   loop — the strings exist only at JSON persistence boundaries.
//!
//! Both tests share the process-global allocation and canonical
//! counters, so they serialize on `GATE` — the default parallel test
//! harness must never let one test's setup allocations bleed into the
//! other's measurement window.

use fikit::benchsuite::bench_world;
use fikit::coordinator::best_prio_fit::best_prio_fit;
use fikit::coordinator::queues::PriorityQueues;
use fikit::coordinator::scheduler::{FikitScheduler, SchedulerConfig};
use fikit::core::{
    Dim3, Duration, Interner, KernelId, KernelLaunch, KernelRecord, LaunchSource, Priority,
    SimTime, TaskId, TaskKey,
};
use fikit::profile::{OnlineConfig, OnlineRefiner, ResolvedProfile, TaskProfile};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Serializes the measuring tests (see module docs).
static GATE: Mutex<()> = Mutex::new(());

/// `canonical()` call count — tracked in debug builds only (the audit
/// counter is compiled out of release, where this check degrades to a
/// no-op rather than a compile error).
fn canonical_count() -> u64 {
    #[cfg(debug_assertions)]
    {
        fikit::core::canonical_audit::count()
    }
    #[cfg(not(debug_assertions))]
    {
        0
    }
}

static ALLOCS: AtomicU64 = AtomicU64::new(0);

std::thread_local! {
    /// Counting is armed per thread: the libtest harness thread may
    /// format/report results (allocating) while a test thread measures,
    /// so a process-global flag would pick up unrelated allocations and
    /// fail the strict zero gates spuriously.
    static COUNTING_HERE: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

struct CountingAlloc;

/// Is the current thread inside a `count_allocs` window? (`try_with`:
/// allocator calls can arrive during TLS teardown.)
fn counting_here() -> bool {
    COUNTING_HERE.try_with(|c| c.get()).unwrap_or(false)
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if counting_here() {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if counting_here() {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Run `f` with allocation counting armed on this thread; returns how
/// many allocations it performed.
fn count_allocs(f: impl FnOnce()) -> u64 {
    let before = ALLOCS.load(Ordering::SeqCst);
    COUNTING_HERE.with(|c| c.set(true));
    f();
    COUNTING_HERE.with(|c| c.set(false));
    let after = ALLOCS.load(Ordering::SeqCst);
    after - before
}

/// The raw queue + select cycle at 512 queued requests: zero allocations
/// and zero canonical() calls once capacities are warm. The world is the
/// shared bench fixture (`fikit::benchsuite::bench_world`) — the gate
/// measures exactly what `BENCH_sched.json` benchmarks.
#[test]
fn best_prio_fit_cycle_is_allocation_free() {
    let _gate = GATE.lock().unwrap();
    let mut w = bench_world(400);
    let mut q = PriorityQueues::new();
    for i in 0..512usize {
        let prio = Priority::from_index(1 + i % 9).unwrap();
        let l = w.launch(i, prio);
        let predicted = w.resolved[l.task_handle.index()].sk(l.kernel_handle);
        assert!(predicted.is_some());
        q.push_predicted(l, predicted, SimTime(i as u64));
    }

    // Warm every container (freelists, fit-index capacity).
    for _ in 0..64 {
        let fit = best_prio_fit(&mut q, Duration::from_micros(500)).unwrap();
        let predicted = fit.predicted;
        q.push_predicted(fit.launch, Some(predicted), SimTime(0));
        let _ = best_prio_fit(&mut q, Duration::from_nanos(1)); // pure probe
    }

    let canonical_before = canonical_count();
    let allocs = count_allocs(|| {
        for _ in 0..10_000 {
            // Steady-state fill decision: select the longest fitting
            // request, dispatch it (here: requeue to keep state stable),
            // plus a no-fit probe (the common "gap too small" case).
            let fit = best_prio_fit(&mut q, Duration::from_micros(500)).unwrap();
            let predicted = fit.predicted;
            q.push_predicted(fit.launch, Some(predicted), SimTime(0));
            assert!(best_prio_fit(&mut q, Duration::from_nanos(1)).is_none());
        }
    });
    let canonical_calls = canonical_count() - canonical_before;

    assert_eq!(allocs, 0, "fill loop allocated {allocs} times");
    assert_eq!(
        canonical_calls, 0,
        "canonical() reachable from the fill loop"
    );
    assert_eq!(q.len(), 512);
}

/// The online-refinement observation path (DESIGN.md §9): in steady
/// state — observations inside the confidence band, so no drift, no
/// snapshot publish — `OnlineRefiner::observe` must perform zero heap
/// allocations and reach zero `canonical()` calls: it is on the
/// per-completion path of every FIKIT event loop with refinement on.
/// (Snapshot publishing allocates, by design: it happens only on
/// drift-triggered epoch boundaries, never in steady state.)
#[test]
fn refinement_observe_path_is_allocation_free() {
    let _gate = GATE.lock().unwrap();
    let mut interner = Interner::new();
    let k = KernelId::new("rk", Dim3::x(16), Dim3::x(128));
    let mut profile = TaskProfile::new(TaskKey::new("svc"));
    profile.record(
        &k,
        Duration::from_micros(100),
        Some(Duration::from_micros(500)),
    );
    profile.finish_run(1);
    let th = interner.intern_task(&TaskKey::new("svc"));
    let rp = ResolvedProfile::resolve(&profile, &mut interner);
    let kh = interner.kernel_handle(&k).unwrap();

    let mut refiner = OnlineRefiner::new(OnlineConfig {
        enabled: true,
        ..Default::default()
    });
    refiner.register(th, &rp);

    // Warm up past min_samples at the profiled truth (no drift).
    for _ in 0..64 {
        let snap = refiner.observe(
            th,
            kh,
            Duration::from_micros(100),
            Some(Duration::from_micros(500)),
        );
        assert!(snap.is_none(), "steady state must not publish");
    }

    let canonical_before = canonical_count();
    let allocs = count_allocs(|| {
        for _ in 0..10_000 {
            let snap = refiner.observe(
                th,
                kh,
                Duration::from_micros(100),
                Some(Duration::from_micros(500)),
            );
            assert!(snap.is_none());
        }
    });
    let canonical_calls = canonical_count() - canonical_before;

    assert_eq!(allocs, 0, "refinement observe path allocated {allocs} times");
    assert_eq!(
        canonical_calls, 0,
        "canonical() reachable from the refinement observe path"
    );
    assert_eq!(refiner.stats().snapshots_published, 0);
    assert_eq!(refiner.stats().exec_observations, 10_064);
}

/// The full scheduler path — IssueKernel routing (`on_launch`), holder
/// completion with SG lookup and window open (`on_kernel_done`), fill
/// pump. The decision structures must not allocate; the only permitted
/// allocations are the submission vectors the scheduler API returns
/// (one batch per dispatch — bounded and counted exactly).
#[test]
fn scheduler_sharing_loop_is_allocation_free() {
    let _gate = GATE.lock().unwrap();
    // Uniform world: holder svc "hi" with SG = 400us after kernel hk;
    // tenant "lo" whose kernel lk costs SK = 300us — each window fits
    // exactly one fill (400 - 300 = 100us leftover < 300us).
    let mut interner = Interner::new();
    let hk = KernelId::new("hk", Dim3::x(64), Dim3::x(256));
    let lk = KernelId::new("lk", Dim3::x(64), Dim3::x(256));

    let mut hi = TaskProfile::new(TaskKey::new("hi"));
    hi.record(&hk, Duration::from_micros(200), Some(Duration::from_micros(400)));
    hi.finish_run(1);
    let th_hi = interner.intern_task(&TaskKey::new("hi"));
    let rp_hi = ResolvedProfile::resolve(&hi, &mut interner);

    let mut lo = TaskProfile::new(TaskKey::new("lo"));
    lo.record(&lk, Duration::from_micros(300), None);
    lo.finish_run(1);
    let th_lo = interner.intern_task(&TaskKey::new("lo"));
    let rp_lo = ResolvedProfile::resolve(&lo, &mut interner);

    let mut sched = FikitScheduler::new(SchedulerConfig::default());
    sched.register_service(th_hi, rp_hi);
    sched.register_service(th_lo, rp_lo);
    sched.task_started(th_hi, Priority::P0, SimTime::ZERO);
    sched.task_started(th_lo, Priority::P5, SimTime::ZERO);

    let hh = interner.intern_kernel(&hk);
    let lh = interner.intern_kernel(&lk);
    let hi_key = TaskKey::new("hi");
    let lo_key = TaskKey::new("lo");

    let mut step = |sched: &mut FikitScheduler, i: u64| -> usize {
        let now = SimTime(i * 1_000);
        // Tenant launch → parked with resolved SK (300us ≥ any leftover
        // window budget, so this call dispatches nothing: empty vec,
        // no allocation).
        let l = KernelLaunch {
            task_key: lo_key.clone(),
            task_handle: th_lo,
            task_id: TaskId(i),
            kernel: lk.clone(),
            kernel_handle: lh,
            priority: Priority::P5,
            seq: i as u32,
            true_duration: Duration::from_micros(300),
            issued_at: now,
        };
        let parked = sched.on_launch(l, now);
        assert!(parked.is_empty());
        // Holder kernel completes → SG lookup → fresh 400us window →
        // exactly one fill selected (the parked 300us request).
        let rec = KernelRecord {
            task_key: hi_key.clone(),
            task_handle: th_hi,
            task_id: TaskId(i),
            kernel: hk.clone(),
            kernel_handle: hh,
            priority: Priority::P0,
            seq: i as u32,
            source: LaunchSource::Direct,
            issued_at: now,
            started_at: now,
            finished_at: now + Duration::from_micros(200),
        };
        let fills = sched.on_kernel_done(&rec, now + Duration::from_micros(200));
        fills.len()
    };

    // Warm up queue capacities.
    for i in 0..64 {
        assert_eq!(step(&mut sched, i), 1, "steady state is one fill/step");
    }

    let steps = 4_000u64;
    let canonical_before = canonical_count();
    let allocs = count_allocs(|| {
        for i in 64..64 + steps {
            step(&mut sched, i);
        }
    });
    let canonical_calls = canonical_count() - canonical_before;

    // Per step the scheduler returns one non-empty fill batch: the
    // `fikit_fill` result vector plus its mapping into submissions — two
    // bounded API-surface allocations. The decision structures (queues,
    // fit index, resolved lookups, window bookkeeping) contribute zero.
    assert!(
        allocs <= steps * 2,
        "scheduler loop allocated {allocs} times over {steps} steps \
         (> 2 submission-batch vectors per step: decision structures leaked \
         allocations into the hot path)"
    );
    assert_eq!(
        canonical_calls, 0,
        "canonical() reachable from the scheduler sharing loop"
    );
}

/// The learned-interference update path (ADR-006): `observe` (the
/// per-completion EWMA step, run once per co-resident on every harvest)
/// and `high_slowdown` (the per-scan predicted-dilation blend) operate
/// on dense fixed-size pair tables — zero heap allocations, zero
/// `canonical()` calls, from the first observation on (no warm-up
/// needed, but one is run anyway to match the other gates).
#[test]
fn interference_observe_path_is_allocation_free() {
    let _gate = GATE.lock().unwrap();
    use fikit::cluster::InterferenceModel;
    use fikit::workload::ModelKind;

    let mut model = InterferenceModel::default();
    let pairs = [
        (ModelKind::KeypointRcnnResnet50Fpn, ModelKind::Googlenet),
        (ModelKind::FcnResnet50, ModelKind::Vgg16),
        (ModelKind::MaskrcnnResnet50Fpn, ModelKind::Resnet101),
    ];
    for (victim, aggressor) in pairs {
        for _ in 0..64 {
            model.observe(victim, aggressor, 1.3);
        }
    }

    let canonical_before = canonical_count();
    let allocs = count_allocs(|| {
        for i in 0..10_000usize {
            let (victim, aggressor) = pairs[i % pairs.len()];
            model.observe(victim, aggressor, 1.3);
            assert!(model.high_slowdown(victim, aggressor) >= 1.0);
        }
    });
    let canonical_calls = canonical_count() - canonical_before;

    assert_eq!(allocs, 0, "interference observe path allocated {allocs} times");
    assert_eq!(
        canonical_calls, 0,
        "canonical() reachable from the interference observe path"
    );
    assert_eq!(model.observations(), 3 * 64 + 10_000);
}

/// The preemption decision cycle (ADR-007): policy probe → device cut →
/// arena tombstone → remnant re-queue → stale completion draining
/// through the tombstone so the slot is reused next cycle. This is the
/// extra work a high-priority launch pays when it reclaims an
/// overrunning fill mid-execution; once device heaps, the arena slab,
/// and queue freelists are warm it must allocate nothing — the launch
/// identity travels by `Arc` refcount bumps only.
#[test]
fn preempt_decision_cycle_is_allocation_free() {
    let _gate = GATE.lock().unwrap();
    use fikit::coordinator::best_prio_fit::{plan_preempt, PreemptAction};
    use fikit::coordinator::fikit::{PreemptionPolicy, DEFAULT_PREEMPT_COST};
    use fikit::simulator::{DeviceConfig, KernelArena, SimDevice};

    let mut w = bench_world(400);
    let fill = w.launch(0, Priority::P5);
    let mut device = SimDevice::new(DeviceConfig::default());
    let mut arena = KernelArena::new();
    let mut q = PriorityQueues::new();

    let mut cycle = |device: &mut SimDevice, arena: &mut KernelArena, q: &mut PriorityQueues, i: u64| {
        // Spaced so the device drains between cycles: every iteration
        // sees the same submit/preempt geometry.
        let now = SimTime(i * 200_000);
        let rec = device.submit(fill.clone(), now, LaunchSource::GapFill);
        let (started, finished) = (rec.started_at, rec.finished_at);
        let slot = arena.insert(rec);
        // A high-priority launch lands mid-execution of the 50 µs fill.
        let ready = now + Duration::from_micros(35);
        let PreemptAction::Cut { cut_at } =
            plan_preempt(PreemptionPolicy::Evict, ready, started, finished)
        else {
            panic!("mid-execution evict must plan a cut");
        };
        assert!(device.preempt(arena.get(slot).expect("fill is live"), cut_at, DEFAULT_PREEMPT_COST));
        let _cut_record = arena.cancel(slot);
        // Remnant re-queue + immediate re-selection.
        q.push_predicted(fill.clone(), Some(Duration::from_micros(20)), cut_at);
        assert!(q.pop_highest().is_some());
        // The stale completion pops through the tombstone, freeing the
        // slot for reuse.
        assert!(arena.take_if_live(slot).is_none());
    };

    // Warm device heaps, arena slab, and queue freelists.
    for i in 1..65u64 {
        cycle(&mut device, &mut arena, &mut q, i);
    }

    let canonical_before = canonical_count();
    let allocs = count_allocs(|| {
        for i in 65..10_065u64 {
            cycle(&mut device, &mut arena, &mut q, i);
        }
    });
    let canonical_calls = canonical_count() - canonical_before;

    assert_eq!(allocs, 0, "preempt decision cycle allocated {allocs} times");
    assert_eq!(
        canonical_calls, 0,
        "canonical() reachable from the preempt decision cycle"
    );
    assert_eq!(arena.len(), 0, "every tombstoned slot reclaimed");
    assert!(q.is_empty(), "every remnant re-selected");
}

/// The event core (ADR-003): steady-state traffic through the calendar
/// wheel — near-future pushes, far-future pushes riding the overflow
/// ring until they mature, pops, plus one arena insert/take per cycle —
/// performs zero heap allocations once bucket, heap, and slab
/// capacities are warm. This is the per-event cost of every `GpuSim`
/// run and the reason `SimScratch` reuse pays off across sweeps.
#[test]
fn event_core_cycle_is_allocation_free() {
    let _gate = GATE.lock().unwrap();
    use fikit::simulator::{Event, EventQueue, KernelArena};

    let mut interner = Interner::new();
    let key = TaskKey::new("svc");
    let kid = KernelId::new("ek", Dim3::x(64), Dim3::x(256));
    let th = interner.intern_task(&key);
    let kh = interner.intern_kernel(&kid);

    let mut q = EventQueue::new();
    let mut arena = KernelArena::new();

    // Cycle period: exactly 3 wheel ticks (3 << 16 ns), so the bucket
    // occupancy pattern is periodic in 1024 cycles (3072 ticks = three
    // full rotations) and the warm-up provably visits every bucket
    // state the measured loop will.
    const PERIOD: u64 = 3 << 16;
    // Far-future completion: 449 cycles out = 1347 ticks, beyond the
    // wheel's 1024-tick span — rides the overflow ring, matures (drains
    // into a bucket) as the cursor advances, and pops exactly at the
    // cycle-(i+449) boundary.
    const FAR: u64 = 449 * PERIOD;

    let mut cycle = |q: &mut EventQueue, arena: &mut KernelArena, i: u64| -> u32 {
        let now = SimTime(i * PERIOD);
        let done_at = now + Duration::from_micros(100);
        q.push(SimTime(now.0 + FAR), Event::TaskArrival { svc: 1 });
        q.push(now + Duration::from_micros(40), Event::IssueKernel { svc: 0 });
        // Park the completion payload in the arena; the event carries
        // only the slot handle. Arc-backed identity clones — refcount
        // bumps, no allocation.
        let rec = arena.insert(KernelRecord {
            task_key: key.clone(),
            task_handle: th,
            task_id: TaskId(i),
            kernel: kid.clone(),
            kernel_handle: kh,
            priority: Priority::P0,
            seq: i as u32,
            source: LaunchSource::Direct,
            issued_at: now,
            started_at: now + Duration::from_micros(40),
            finished_at: done_at,
        });
        q.push(done_at, Event::KernelDone { svc: 0, rec });

        let mut popped = 0;
        while let Some((_, ev)) = q.pop_if_before(done_at) {
            if let Event::KernelDone { rec, .. } = ev {
                assert_eq!(arena.take(rec).finished_at, done_at);
            }
            popped += 1;
        }
        popped
    };

    // Warm-up: cycles 0..449 ramp the overflow ring to its steady
    // 449-entry depth (2 pops/cycle); from 449 the loop is in steady
    // state (3 pops/cycle) and 449 + 1024 < 1_500 covers one full
    // bucket-phase period.
    for i in 0..1_500 {
        cycle(&mut q, &mut arena, i);
    }

    let allocs = count_allocs(|| {
        for i in 1_500..9_500u64 {
            assert_eq!(cycle(&mut q, &mut arena, i), 3);
        }
    });

    assert_eq!(allocs, 0, "event core cycle allocated {allocs} times");
    assert_eq!(arena.len(), 0, "every KernelDone slot taken back");
    // The 449 in-flight far-future events are still queued.
    assert_eq!(q.len(), 449);
}
