//! Acceptance gates for the fleet-scale simulator core (ADR-003,
//! DESIGN.md §Perf):
//!
//! * **Differential property test** — the calendar wheel's pop order is
//!   bit-identical to the reference binary heap's `(time, seq)` order on
//!   randomized schedules: same-tick bursts, mid-rotation spreads,
//!   far-future pushes that ride the overflow ring, and pops interleaved
//!   with pushes so cursor advance and overflow refill happen mid-stream.
//! * **Shard-merge determinism** — `run_churn` produces byte-identical
//!   reports at `--sim-threads 1/2/4`. Device shards share nothing and
//!   advance to the same merge horizons; all cross-device logic runs
//!   serially on the main thread in device order, so thread count must
//!   be unobservable in every output.

use fikit::cluster::{run_churn, ChurnConfig, CompatMatrix, PlacementPolicy};
use fikit::core::{Duration, Priority, SimTime};
use fikit::simulator::{BaselineHeapQueue, CalendarWheel};
use fikit::util::rng::Rng;
use fikit::workload::{ArrivalProcess, MixEntry, ModelKind};

/// Drive a wheel and the reference heap through one randomized
/// push/pop schedule, asserting identical `(time, item)` pops
/// throughout. Pushes never go backwards past a popped time — the
/// simulator's monotonicity contract, which the wheel's cursor relies
/// on.
fn differential_schedule(seed: u64) {
    let mut rng = Rng::new(seed);
    let mut wheel: CalendarWheel<u32> = CalendarWheel::default();
    let mut heap: BaselineHeapQueue<u32> = BaselineHeapQueue::new();

    let mut now = 0u64;
    let mut id = 0u32;
    for round in 0..2_000 {
        // A burst of 1..=4 events with offsets spanning every band the
        // wheel treats differently: exact ties, the near-future dense
        // band, mid-rotation, and beyond the 67 ms span (overflow ring).
        for _ in 0..1 + rng.index(4) {
            let offset = match rng.index(4) {
                0 => 0,
                1 => rng.below(50_000),
                2 => rng.below(5_000_000),
                _ => 60_000_000 + rng.below(400_000_000),
            };
            let t = SimTime(now + offset);
            wheel.push(t, id);
            heap.push(t, id);
            id += 1;
        }
        // Interleaved pops: cursor advance and overflow refill must
        // agree with the heap mid-stream, not only in a final drain.
        for _ in 0..rng.index(4) {
            let got = wheel.pop();
            let want = heap.pop();
            assert_eq!(got, want, "mid-stream divergence (seed {seed}, round {round})");
            if let Some((t, _)) = got {
                now = now.max(t.0);
            }
        }
        now += rng.below(200_000);
    }

    loop {
        let got = wheel.pop();
        let want = heap.pop();
        assert_eq!(got, want, "drain divergence (seed {seed})");
        if got.is_none() {
            break;
        }
    }
    assert!(wheel.is_empty());
}

#[test]
fn wheel_matches_heap_on_randomized_schedules() {
    for seed in [1, 42, 7_777, 0xDEAD_BEEF, 0x5EED_F00D] {
        differential_schedule(seed);
    }
}

/// Degenerate tie storm: hundreds of events on one tick must pop in
/// exact insertion order (the in-bucket min-scan ranks by `seq`), with
/// stragglers on neighboring ticks landing where the heap puts them.
#[test]
fn wheel_matches_heap_on_same_tick_bursts() {
    let mut wheel: CalendarWheel<u32> = CalendarWheel::default();
    let mut heap: BaselineHeapQueue<u32> = BaselineHeapQueue::new();
    let t = SimTime(1_000_000);
    for id in 0..300u32 {
        // Every third event lands one tick earlier or later; the rest
        // pile onto the same instant.
        let time = match id % 3 {
            0 => t,
            1 => SimTime(t.0 + (1 << 16)),
            _ => t,
        };
        wheel.push(time, id);
        heap.push(time, id);
    }
    loop {
        let got = wheel.pop();
        assert_eq!(got, heap.pop());
        if got.is_none() {
            break;
        }
    }
}

/// `clear()` keeps storage but fully resets ordering state: a reused
/// wheel must replay a schedule identically to a fresh one, including
/// the insertion-order tie-break restarting from zero.
#[test]
fn cleared_wheel_replays_like_fresh() {
    let mut reused: CalendarWheel<u32> = CalendarWheel::default();
    // Dirty it across every band, pop a few to move the cursor deep.
    for id in 0..64u32 {
        reused.push(SimTime(id as u64 * 3_000_000), id);
    }
    reused.push(SimTime(500_000_000), 64);
    for _ in 0..40 {
        reused.pop();
    }
    reused.clear();
    assert!(reused.is_empty());

    let mut fresh: CalendarWheel<u32> = CalendarWheel::default();
    let mut rng = Rng::new(9);
    let mut now = 0u64;
    for id in 0..500u32 {
        let t = SimTime(now + rng.below(100_000_000));
        reused.push(t, id);
        fresh.push(t, id);
        if rng.chance(0.4) {
            let got = reused.pop();
            let want = fresh.pop();
            assert_eq!(got, want);
            if let Some((t, _)) = got {
                now = now.max(t.0);
            }
        }
    }
    loop {
        let got = reused.pop();
        assert_eq!(got, fresh.pop());
        if got.is_none() {
            break;
        }
    }
}

fn churn_cfg(sim_threads: usize) -> ChurnConfig {
    let mix = vec![
        MixEntry::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0, 1.0),
        MixEntry::new(ModelKind::FcnResnet50, Priority::P5, 1.0),
        MixEntry::new(ModelKind::Vgg16, Priority::P7, 1.0),
    ];
    let arrivals = ArrivalProcess::Poisson {
        mean_interarrival: Duration::from_millis(120),
        mean_lifetime: Duration::from_millis(250),
        mix,
        horizon: Duration::from_millis(800),
    };
    let mut cfg = ChurnConfig::new(4, PlacementPolicy::BestMatch, arrivals);
    cfg.seed = 0x5EED;
    cfg.sim_threads = sim_threads;
    cfg
}

/// The acceptance criterion for the sharded serving loop: the
/// `ChurnReport` — summary line, fleet counters, and every per-service
/// outcome — is identical whether devices advance serially or on 2 or 4
/// worker threads.
#[test]
fn churn_reports_identical_across_sim_threads() {
    let serial = run_churn(&churn_cfg(1), &CompatMatrix::new()).unwrap();
    // The scenario must actually exercise the fleet for the equality to
    // mean anything.
    assert!(serial.completed_total > 0, "scenario completed no work");
    assert_eq!(serial.fleet.len(), 4);

    for threads in [2usize, 4] {
        let parallel = run_churn(&churn_cfg(threads), &CompatMatrix::new()).unwrap();
        assert_eq!(
            serial.summary(),
            parallel.summary(),
            "summary diverged at sim_threads={threads}"
        );
        assert_eq!(serial.completed_total, parallel.completed_total);
        assert_eq!(serial.sim_end, parallel.sim_end);
        assert_eq!(serial.qos_violations, parallel.qos_violations);
        assert_eq!(serial.migrations, parallel.migrations);
        assert_eq!(serial.scans, parallel.scans);
        assert_eq!(serial.rejected, parallel.rejected);
        assert_eq!(serial.fleet.len(), parallel.fleet.len());
        assert_eq!(serial.services.len(), parallel.services.len());
        for (a, b) in serial.services.iter().zip(&parallel.services) {
            assert_eq!(a.id, b.id, "service order diverged at sim_threads={threads}");
            assert_eq!(a.model, b.model);
            assert_eq!(a.arrived, b.arrived);
            assert_eq!(a.departed, b.departed);
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.migrations, b.migrations);
            assert_eq!(a.rejected, b.rejected);
        }
    }
}

/// Thread counts above the device count clamp instead of erroring.
#[test]
fn sim_threads_clamp_to_device_count() {
    let serial = run_churn(&churn_cfg(1), &CompatMatrix::new()).unwrap();
    let oversubscribed = run_churn(&churn_cfg(16), &CompatMatrix::new()).unwrap();
    assert_eq!(serial.summary(), oversubscribed.summary());
}

/// Two-service FIKIT config in the shapes the paper sweeps share: batch
/// back-to-back (figs 13–20) or continuous + periodic inserts (fig 21).
fn preempt_cfg(seed: u64, continuous: bool) -> fikit::config::ExperimentConfig {
    use fikit::config::{ExperimentConfig, ServiceConfig};
    use fikit::coordinator::Mode;
    let mut cfg = ExperimentConfig {
        mode: Mode::Fikit,
        seed,
        ..ExperimentConfig::default()
    };
    cfg.measurement.runs = 3;
    if continuous {
        cfg.services.push(
            ServiceConfig::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0)
                .continuous_ms(2_000)
                .with_key("h"),
        );
        cfg.services.push(
            ServiceConfig::new(ModelKind::FcnResnet50, Priority::P3)
                .every_ms(250, 7)
                .with_key("l"),
        );
    } else {
        cfg.services.push(
            ServiceConfig::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0)
                .tasks(20)
                .with_key("h"),
        );
        cfg.services.push(
            ServiceConfig::new(ModelKind::FcnResnet50, Priority::P3)
                .tasks(20)
                .with_key("l"),
        );
    }
    cfg
}

/// The preemption tier's differential gate: `PreemptionPolicy::None` is
/// the pre-preemption simulator byte for byte. The default config, an
/// explicit `None`, and a hybrid policy whose modeled cost is
/// astronomically high (the probe arms but can never fire) must all
/// render identical reports, with every preemption counter at zero.
#[test]
fn preemption_none_pins_seed_reports_byte_identical() {
    use fikit::coordinator::driver::run_experiment;
    use fikit::coordinator::fikit::PreemptionPolicy;
    for continuous in [false, true] {
        for seed in [0xF1C1u64, 7, 99] {
            let tag = format!("seed {seed} continuous={continuous}");
            let base = run_experiment(&preempt_cfg(seed, continuous)).unwrap();
            let sched = base.scheduler.as_ref().expect("fikit mode has a scheduler");
            assert_eq!(sched.preempt.requeues, 0, "{tag}: default never preempts");
            assert!(
                !base.summary().contains("preempt:"),
                "{tag}: no preempt line in a preemption-free report"
            );

            let mut none_cfg = preempt_cfg(seed, continuous);
            none_cfg.preempt = PreemptionPolicy::None;
            let none = run_experiment(&none_cfg).unwrap();
            assert_eq!(base.summary(), none.summary(), "{tag}: explicit None diverged");

            let mut inert = preempt_cfg(seed, continuous);
            inert.preempt = PreemptionPolicy::hybrid();
            inert.preempt_cost = Duration::from_millis(3_600_000);
            let hybrid = run_experiment(&inert).unwrap();
            assert_eq!(
                base.summary(),
                hybrid.summary(),
                "{tag}: armed-but-unfired hybrid diverged"
            );
        }
    }
}

/// The opposite pole of the differential gate: an eager policy (evict at
/// any modeled gain) actually fires on the same workload, re-queues
/// work, and surfaces its accounting in the report.
#[test]
fn eager_eviction_engages_on_seed_workload() {
    use fikit::coordinator::driver::run_experiment;
    use fikit::coordinator::fikit::PreemptionPolicy;
    let mut cfg = preempt_cfg(0xF1C1, false);
    cfg.preempt = PreemptionPolicy::Evict;
    cfg.preempt_cost = Duration::ZERO;
    let report = run_experiment(&cfg).unwrap();
    let p = &report.scheduler.as_ref().unwrap().preempt;
    assert!(
        p.requeues > 0,
        "zero-cost eviction never fired: {:?}",
        report.summary()
    );
    assert!(report.summary().contains("preempt:"), "accounting line missing");
}

/// Shard-merge determinism holds with the preemption tier live: hybrid
/// churn reports are byte-identical at 1/2/4 sim threads, and the
/// explicit-`None` churn matches the plain config exactly.
#[test]
fn churn_reports_identical_across_sim_threads_with_preemption() {
    use fikit::coordinator::fikit::PreemptionPolicy;
    let mut cfg1 = churn_cfg(1);
    cfg1.preempt = PreemptionPolicy::hybrid();
    let serial = run_churn(&cfg1, &CompatMatrix::new()).unwrap();
    assert!(serial.completed_total > 0, "scenario completed no work");
    for threads in [2usize, 4] {
        let mut cfg = churn_cfg(threads);
        cfg.preempt = PreemptionPolicy::hybrid();
        let parallel = run_churn(&cfg, &CompatMatrix::new()).unwrap();
        assert_eq!(
            serial.summary(),
            parallel.summary(),
            "hybrid summary diverged at sim_threads={threads}"
        );
    }
    let mut none_cfg = churn_cfg(1);
    none_cfg.preempt = PreemptionPolicy::None;
    let plain = run_churn(&churn_cfg(1), &CompatMatrix::new()).unwrap();
    let none = run_churn(&none_cfg, &CompatMatrix::new()).unwrap();
    assert_eq!(plain.summary(), none.summary(), "None churn diverged from plain");
}
