//! Integration tests over the full simulation stack: the three task
//! scheduling cases of the paper's Fig 11, mode semantics, the
//! measurement→sharing lifecycle, and cross-mode conservation laws.

use fikit::config::{ExperimentConfig, ServiceConfig};
use fikit::coordinator::driver::{profile_service, run_experiment, run_with_profiles};
use fikit::coordinator::Mode;
use fikit::core::{Priority, TaskKey};
use fikit::profile::ProfileStore;
use fikit::workload::ModelKind;

fn cfg_with(mode: Mode, services: Vec<ServiceConfig>) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        mode,
        ..ExperimentConfig::default()
    };
    cfg.measurement.runs = 5;
    cfg.services = services;
    cfg
}

/// Fig 11 case B: high-priority A running, low-priority B arrives —
/// B's kernels only run inside A's gaps; A stays near its solo JCT.
#[test]
fn fig11_case_b_low_priority_fills_gaps() {
    let services = vec![
        ServiceConfig::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0)
            .tasks(20)
            .with_key("A-high"),
        ServiceConfig::new(ModelKind::FcnResnet50, Priority::P4)
            .tasks(20)
            .with_key("B-low"),
    ];
    let report = run_experiment(&cfg_with(Mode::Fikit, services.clone())).unwrap();

    // Solo baseline for A.
    let solo = run_experiment(&cfg_with(Mode::Sharing, vec![services[0].clone()])).unwrap();
    let a_shared = report.service(&TaskKey::new("A-high")).unwrap().jct.mean_ms();
    let a_solo = solo.services[0].jct.mean_ms();
    assert!(
        a_shared / a_solo < 1.35,
        "high-priority task must stay near solo JCT: {a_shared:.2} vs {a_solo:.2}"
    );

    // B made progress through fills.
    let sched = report.scheduler.as_ref().unwrap();
    assert!(sched.fills > 100, "expected many gap fills, got {}", sched.fills);
    assert!(report.service(&TaskKey::new("B-low")).unwrap().completed > 0);
}

/// Fig 11 case A: low-priority A is running alone; a high-priority B
/// arrives later and preempts at kernel granularity. Preemption latency
/// is bounded by the *non-recallable* device backlog (kernels A already
/// launched ahead) — so the guarantee is "far better than sharing",
/// not "equal to solo".
#[test]
fn fig11_case_a_preemption_on_late_arrival() {
    let services = vec![
        // A starts immediately and grinds continuously.
        ServiceConfig::new(ModelKind::FcnResnet50, Priority::P5)
            .continuous_ms(2_000)
            .with_key("A-low"),
        // B arrives every 200ms.
        ServiceConfig::new(ModelKind::Alexnet, Priority::P0)
            .every_ms(200, 8)
            .with_key("B-high"),
    ];
    let fikit = run_experiment(&cfg_with(Mode::Fikit, services.clone())).unwrap();
    let share = run_experiment(&cfg_with(Mode::Sharing, services)).unwrap();
    let sched = fikit.scheduler.as_ref().unwrap();
    assert!(
        sched.preemptions >= 8,
        "each high-priority arrival should preempt: {}",
        sched.preemptions
    );
    let b_fikit = fikit.service(&TaskKey::new("B-high")).unwrap().jct.mean_ms();
    let b_share = share.service(&TaskKey::new("B-high")).unwrap().jct.mean_ms();
    assert!(
        b_fikit < b_share,
        "preemption must beat sharing: {b_fikit:.2}ms vs {b_share:.2}ms"
    );
    // And the preemption latency stays bounded by the backlog, not the
    // whole co-tenant task stream.
    let solo_ms = ModelKind::Alexnet.spec().mean_jct().as_millis_f64();
    assert!(
        b_fikit < solo_ms + ModelKind::FcnResnet50.spec().mean_exec().as_millis_f64(),
        "preemption latency beyond one backlog: {b_fikit:.2}ms"
    );
}

/// Fig 11 case C: equal priorities degrade to FIFO sharing — FIKIT and
/// default sharing give statistically similar JCTs.
#[test]
fn fig11_case_c_equal_priority_behaves_like_sharing() {
    let services = |key_suffix: &str| {
        vec![
            ServiceConfig::new(ModelKind::Resnet50, Priority::P2)
                .tasks(30)
                .with_key(&format!("r50-{key_suffix}")),
            ServiceConfig::new(ModelKind::Googlenet, Priority::P2)
                .tasks(30)
                .with_key(&format!("gn-{key_suffix}")),
        ]
    };
    let fikit = run_experiment(&cfg_with(Mode::Fikit, services("x"))).unwrap();
    let share = run_experiment(&cfg_with(Mode::Sharing, services("x"))).unwrap();
    for (f, s) in fikit.services.iter().zip(&share.services) {
        let ratio = f.jct.mean_ms() / s.jct.mean_ms();
        assert!(
            (0.8..1.25).contains(&ratio),
            "equal-priority FIKIT should track sharing: {} ratio {ratio:.2}",
            f.key
        );
    }
    // No fills happen between equal priorities (nothing is ever queued).
    assert_eq!(fikit.scheduler.as_ref().unwrap().fills, 0);
}

/// The measurement→sharing lifecycle: profiles from the measuring stage
/// make the sharing stage work; JCT_measuring / JCT_sharing matches the
/// paper's 1.2–1.8 overhead band.
#[test]
fn measurement_lifecycle() {
    let svc = ServiceConfig::new(ModelKind::Vgg16, Priority::P0).tasks(10);
    let cfg = cfg_with(Mode::Fikit, vec![svc.clone()]);

    let profiling = profile_service(&cfg, &svc).unwrap();
    assert!(profiling.profile.is_ready(cfg.measurement.runs));
    assert!(profiling.profile.num_unique() >= 3);

    // Persist + reload, then serve with the loaded store.
    let dir = std::env::temp_dir().join(format!("fikit-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("store.json");
    let mut store = ProfileStore::new();
    store.insert(profiling.profile);
    store.save(&path).unwrap();
    let loaded = ProfileStore::load(&path).unwrap();
    let report = run_with_profiles(&cfg, &loaded).unwrap();
    assert_eq!(report.services[0].completed, 10);
    std::fs::remove_dir_all(&dir).ok();

    // Overhead band.
    let measuring_ms = profiling
        .outcomes
        .iter()
        .map(|o| o.jct().as_millis_f64())
        .sum::<f64>()
        / profiling.outcomes.len() as f64;
    let sharing_ms = report.services[0].jct.mean_ms();
    let ratio = measuring_ms / sharing_ms;
    assert!(
        (1.15..2.0).contains(&ratio),
        "JCT_m/JCT_f = {ratio:.2} outside the paper's 1.3–1.7 band (±tolerance)"
    );
}

/// Running FIKIT sharing stage without a profile is a hard error (the
/// scheduler cannot predict gaps it never measured).
#[test]
fn sharing_stage_requires_profiles() {
    let cfg = cfg_with(
        Mode::Fikit,
        vec![ServiceConfig::new(ModelKind::Alexnet, Priority::P0).tasks(3)],
    );
    let err = run_with_profiles(&cfg, &ProfileStore::new()).unwrap_err();
    assert!(err.to_string().contains("no profile"));
}

/// Conservation: in every mode, all tasks complete, every kernel runs
/// exactly once, and device busy time is consistent with utilization.
#[test]
fn conservation_across_modes() {
    for mode in [Mode::Sharing, Mode::Exclusive, Mode::Fikit] {
        let services = vec![
            ServiceConfig::new(ModelKind::Alexnet, Priority::P0)
                .tasks(15)
                .with_key("a"),
            ServiceConfig::new(ModelKind::Googlenet, Priority::P3)
                .tasks(15)
                .with_key("b"),
        ];
        let report = run_experiment(&cfg_with(mode, services)).unwrap();
        assert_eq!(report.outcomes.len(), 30, "{mode}: all tasks complete");
        let expected_kernels: u64 = report
            .outcomes
            .iter()
            .map(|o| o.kernels as u64)
            .sum();
        assert_eq!(
            report.device.kernels, expected_kernels,
            "{mode}: every kernel executed exactly once"
        );
        let util = report.device.utilization(report.sim_end);
        assert!(util > 0.0 && util <= 1.0 + 1e-9, "{mode}: utilization {util}");
    }
}

/// Exclusive mode serializes whole tasks in arrival order: a task's JCT
/// includes the full runtime of whatever was queued ahead of it.
#[test]
fn exclusive_mode_waits_for_whole_tasks() {
    let mk = |first: ModelKind| {
        let services = vec![
            ServiceConfig::new(first, Priority::P0).tasks(10).with_key("a"),
            ServiceConfig::new(ModelKind::Alexnet, Priority::P3)
                .every_ms(1, 3)
                .with_key("b"),
        ];
        run_experiment(&cfg_with(Mode::Exclusive, services)).unwrap()
    };
    // B arrives just after A's first task: its wait scales with A's
    // whole-task duration (no kernel-level interleaving exists).
    let short = mk(ModelKind::Alexnet); // ~1.4ms tasks
    let long = mk(ModelKind::MaskrcnnResnet50Fpn); // ~33ms tasks
    let b_short = short.service(&TaskKey::new("b")).unwrap().jct.mean_ms();
    let b_long = long.service(&TaskKey::new("b")).unwrap().jct.mean_ms();
    assert!(
        b_long > b_short * 3.0,
        "exclusive-mode wait should scale with queued task length: {b_short:.2} -> {b_long:.2}"
    );
}

/// The paper's §5 software-defined exclusive mode: one task at a time,
/// but chosen by priority — high-priority tasks jump the queue that
/// plain exclusive mode would make them wait in.
#[test]
fn soft_exclusive_prioritizes_waiting_tasks() {
    let services = vec![
        // A floods the queue with low-priority work: arrivals outpace
        // service (5.8ms tasks arriving every 1ms), building a backlog.
        ServiceConfig::new(ModelKind::Vgg16, Priority::P7)
            .every_ms(1, 30)
            .with_key("bulk-low"),
        // B's high-priority tasks arrive periodically.
        ServiceConfig::new(ModelKind::Alexnet, Priority::P0)
            .every_ms(20, 10)
            .with_key("rt-high"),
    ];
    let soft = run_experiment(&cfg_with(Mode::SoftExclusive, services.clone())).unwrap();
    let hard = run_experiment(&cfg_with(Mode::Exclusive, services)).unwrap();
    let b_soft = soft.service(&TaskKey::new("rt-high")).unwrap().jct.mean_ms();
    let b_hard = hard.service(&TaskKey::new("rt-high")).unwrap().jct.mean_ms();
    // Under soft-exclusive, B waits at most for the in-flight task; under
    // arrival-ordered exclusive it waits behind queued bulk work.
    assert!(
        b_soft < b_hard,
        "soft-exclusive must prioritize: {b_soft:.2}ms vs exclusive {b_hard:.2}ms"
    );
    // One task at a time still holds (serialization invariant).
    let mut spans: Vec<_> = soft.outcomes.iter().map(|o| (o.started, o.finished)).collect();
    spans.sort();
    for w in spans.windows(2) {
        assert!(w[0].1 <= w[1].0 + fikit::core::Duration::from_micros(10));
    }
}

/// Paper §2.1: FIKIT applies within a MIG instance. On a half-compute
/// slice (kernels 2× longer, CPU gaps unchanged) the priority protection
/// must still hold.
#[test]
fn fikit_works_on_mig_instance() {
    let build = |mode: Mode| {
        let mut cfg = cfg_with(
            mode,
            vec![
                ServiceConfig::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0)
                    .tasks(15)
                    .with_key("h"),
                ServiceConfig::new(ModelKind::FcnResnet50, Priority::P4)
                    .tasks(15)
                    .with_key("l"),
            ],
        );
        cfg.device = fikit::simulator::DeviceConfig::mig_instance(0.5);
        cfg
    };
    let fikit = run_experiment(&build(Mode::Fikit)).unwrap();
    let share = run_experiment(&build(Mode::Sharing)).unwrap();
    let h_fikit = fikit.service(&TaskKey::new("h")).unwrap().jct.mean_ms();
    let h_share = share.service(&TaskKey::new("h")).unwrap().jct.mean_ms();
    assert!(
        h_fikit < h_share,
        "FIKIT must still protect high-prio on a MIG slice: {h_fikit:.2} vs {h_share:.2}"
    );
    // Execution stretched ~2x vs the full-GPU spec (gaps unchanged).
    let full_exec = ModelKind::KeypointRcnnResnet50Fpn.spec().mean_exec().as_millis_f64();
    let gaps = ModelKind::KeypointRcnnResnet50Fpn.spec().mean_sync_gap().as_millis_f64();
    let expect = 2.0 * full_exec + gaps;
    assert!(
        (h_fikit - expect).abs() / expect < 0.4,
        "MIG JCT {h_fikit:.1}ms vs expected ~{expect:.1}ms"
    );
}

/// Determinism across the whole stack: identical config ⇒ identical
/// reports, different seed ⇒ different timing.
#[test]
fn full_stack_determinism() {
    let services = vec![
        ServiceConfig::new(ModelKind::FcosResnet50Fpn, Priority::P0)
            .tasks(10)
            .with_key("a"),
        ServiceConfig::new(ModelKind::Resnet101, Priority::P2)
            .tasks(10)
            .with_key("b"),
    ];
    let a = run_experiment(&cfg_with(Mode::Fikit, services.clone())).unwrap();
    let b = run_experiment(&cfg_with(Mode::Fikit, services.clone())).unwrap();
    assert_eq!(a.events, b.events);
    assert_eq!(a.sim_end, b.sim_end);
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.finished, y.finished);
    }
    let mut cfg = cfg_with(Mode::Fikit, services);
    cfg.seed ^= 0xDEAD;
    let c = run_experiment(&cfg).unwrap();
    assert_ne!(a.sim_end, c.sim_end, "different seed must change timing");
}
