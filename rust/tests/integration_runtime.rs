//! PJRT runtime integration: load every AOT artifact, verify numerics
//! against both the manifest self-checks and independently-computed
//! references, and run the real-time engine end to end.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise —
//! `make test` always builds artifacts first).

use fikit::coordinator::Mode;
use fikit::core::{Priority, TaskKey};
use fikit::runtime::engine::{EngineConfig, RealTimeEngine, RtKernelStep, RtService};
use fikit::runtime::executor::PjrtRuntime;
use fikit::runtime::manifest::{test_input, Manifest};
use std::time::Duration as StdDuration;

fn manifest() -> Option<Manifest> {
    match Manifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping runtime integration test (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn all_artifacts_load_and_self_verify() {
    let Some(manifest) = manifest() else { return };
    let mut rt = PjrtRuntime::cpu().unwrap();
    rt.load_all(&manifest).unwrap();
    assert_eq!(rt.loaded_names().len(), manifest.artifacts.len());
    rt.verify_all(1e-3).unwrap();
}

/// Independent numerics check: execute the matmul artifact and compare
/// against a plain-Rust matrix multiply of the same inputs — catching
/// any transposition/layout bug the mean-abs self-check could miss.
#[test]
fn matmul_artifact_matches_rust_reference() {
    let Some(manifest) = manifest() else { return };
    let name = "matmul_128x256x128";
    let spec = manifest.get(name).expect("manifest has matmul").clone();
    let (m, k) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let n = spec.inputs[1].shape[1];

    let mut rt = PjrtRuntime::cpu().unwrap();
    rt.load(&manifest, name).unwrap();

    let a = test_input(&spec.inputs[0], 0, spec.check.seed);
    let b = test_input(&spec.inputs[1], 1, spec.check.seed);
    let outputs = rt.execute_f32(name, &[a.clone(), b.clone()]).unwrap();
    assert_eq!(outputs.len(), 1);
    let got = &outputs[0];
    assert_eq!(got.len(), m * n);

    // Plain-Rust reference (f64 accumulation).
    let mut worst = 0.0f64;
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for p in 0..k {
                acc += a[i * k + p] as f64 * b[p * n + j] as f64;
            }
            let diff = (got[i * n + j] as f64 - acc).abs();
            let denom = acc.abs().max(1.0);
            worst = worst.max(diff / denom);
        }
    }
    assert!(
        worst < 1e-4,
        "Pallas matmul vs Rust reference: worst rel err {worst:.2e}"
    );
}

/// Softmax artifact: rows must sum to one (independent invariant).
#[test]
fn softmax_artifact_rows_sum_to_one() {
    let Some(manifest) = manifest() else { return };
    let name = "softmax_128x512";
    let spec = manifest.get(name).unwrap().clone();
    let mut rt = PjrtRuntime::cpu().unwrap();
    rt.load(&manifest, name).unwrap();
    let x = test_input(&spec.inputs[0], 0, spec.check.seed);
    let out = &rt.execute_f32(name, &[x]).unwrap()[0];
    let (rows, cols) = (spec.outputs[0].shape[0], spec.outputs[0].shape[1]);
    for r in 0..rows {
        let sum: f32 = out[r * cols..(r + 1) * cols].iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "row {r} sums to {sum}");
    }
}

#[test]
fn executor_rejects_bad_inputs() {
    let Some(manifest) = manifest() else { return };
    let mut rt = PjrtRuntime::cpu().unwrap();
    rt.load(&manifest, "softmax_128x512").unwrap();
    // Wrong arity.
    assert!(rt.execute_f32("softmax_128x512", &[]).is_err());
    // Wrong element count.
    assert!(rt
        .execute_f32("softmax_128x512", &[vec![0.0; 7]])
        .is_err());
    // Unknown artifact.
    assert!(rt.execute_f32("nope", &[]).is_err());
    // Unknown artifact load.
    assert!(rt.load(&manifest, "nope").is_err());
}

/// End-to-end: real services over real compute through the FIKIT
/// engine; priority ordering must hold.
#[test]
fn realtime_engine_serves_with_priority() {
    let Some(manifest) = manifest() else { return };
    let ms = StdDuration::from_millis;
    let services = vec![
        RtService {
            key: TaskKey::new("rt-high"),
            priority: Priority::P0,
            steps: vec![
                RtKernelStep { artifact: "layernorm_128x512".into(), think_gap: ms(8) },
                RtKernelStep { artifact: "softmax_128x512".into(), think_gap: ms(0) },
            ],
            requests: 6,
            inter_request: ms(4),
        },
        RtService {
            key: TaskKey::new("batch-low"),
            priority: Priority::P5,
            steps: vec![
                RtKernelStep { artifact: "matmul_128x256x128".into(), think_gap: ms(0) },
                RtKernelStep { artifact: "fused_linear_64x256x512_relu".into(), think_gap: ms(0) },
            ],
            requests: 10,
            inter_request: ms(0),
        },
    ];
    let engine = RealTimeEngine::new(EngineConfig::default(), services, &manifest).unwrap();
    let profiles = engine.profile().unwrap();
    // Profiles exist and carry the think gap.
    let p = profiles.get(&TaskKey::new("rt-high")).unwrap();
    assert!(p.num_unique() >= 2);

    let report = engine.serve(&profiles).unwrap();
    assert_eq!(report.mode, Mode::Fikit);
    let high = report.service(&TaskKey::new("rt-high")).unwrap();
    let low = report.service(&TaskKey::new("batch-low")).unwrap();
    assert_eq!(high.completed, 6);
    assert_eq!(low.completed, 10);
    assert!(high.jct.mean_ms() > 0.0 && low.jct.mean_ms() > 0.0);
    assert!(report.kernels_executed >= 6 * 2 + 10 * 2);
}
