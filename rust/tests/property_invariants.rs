//! Randomized property tests over coordinator invariants (the offline
//! environment has no proptest; `fikit::util::rng::Rng` drives seeded
//! case generation — failures print the seed for replay).
//!
//! Invariants (DESIGN.md §7):
//!  1. BestPrioFit optimality: the fit is the longest fitting request of
//!     the highest fitting priority; it never exceeds the idle window.
//!  2. FIKIT fill budget: Σ predicted durations of launched fills ≤ the
//!     predicted gap at open time.
//!  3. Scheduler routing: no queued request ever has priority ≥ the
//!     holder's; with no holder, queues are empty.
//!  4. End-to-end conservation: every launched kernel completes exactly
//!     once; device busy time = Σ true durations.
//!  5. Priority protection: in FIKIT mode the high-priority service's
//!     JCT never exceeds its default-sharing JCT by more than the
//!     overhead-2 bound (one fill kernel per gap window).
//!  6. Wire protocol: arbitrary messages round-trip bit-exactly.

use fikit::config::{ExperimentConfig, ServiceConfig};
use fikit::coordinator::best_prio_fit::best_prio_fit;
use fikit::coordinator::driver::run_experiment;
use fikit::coordinator::fikit::{fikit_fill, FillWindow, DEFAULT_EPSILON};
use fikit::coordinator::queues::PriorityQueues;
use fikit::coordinator::Mode;
use fikit::core::{
    Dim3, Duration, KernelHandle, KernelId, KernelLaunch, Priority, SimTime, TaskHandle, TaskId,
    TaskKey,
};
use fikit::hook::protocol::{ClientMsg, SchedulerMsg};
use fikit::profile::TaskProfile;
use fikit::util::rng::Rng;
use fikit::workload::ModelKind;

const CASES: usize = 60;

fn kid(i: u64) -> KernelId {
    KernelId::new(format!("k{i}"), Dim3::x(4), Dim3::x(64))
}

/// Random queues seeded from per-service profiles. Requests are
/// enqueued with their profiled `SK` pre-resolved, exactly as the
/// scheduler does from the attach-time ResolvedProfile.
fn random_state(rng: &mut Rng) -> (PriorityQueues, Vec<(Priority, Duration)>) {
    let n_services = 1 + rng.index(6);
    let mut queues = PriorityQueues::new();
    let mut contents = Vec::new();
    for s in 0..n_services {
        let key = TaskKey::new(format!("svc{s}"));
        let mut profile = TaskProfile::new(key.clone());
        let n_kernels = 1 + rng.index(5);
        for k in 0..n_kernels {
            let dur = Duration::from_micros(1 + rng.below(800));
            profile.record(&kid(k as u64), dur, Some(Duration::from_micros(50)));
        }
        profile.finish_run(n_kernels);
        // Queue up to 4 pending requests for this service.
        let prio = Priority::from_index(1 + rng.index(9)).unwrap();
        for q in 0..rng.index(4) {
            let k = rng.index(n_kernels) as u64;
            let predicted = profile.sk(&kid(k)).unwrap();
            queues.push_predicted(
                KernelLaunch {
                    task_key: key.clone(),
                    task_handle: TaskHandle::UNBOUND,
                    task_id: TaskId(q as u64),
                    kernel: kid(k),
                    kernel_handle: KernelHandle::UNBOUND,
                    priority: prio,
                    seq: q as u32,
                    true_duration: predicted,
                    issued_at: SimTime::ZERO,
                },
                Some(predicted),
                SimTime::ZERO,
            );
            contents.push((prio, predicted));
        }
    }
    (queues, contents)
}

#[test]
fn prop_best_prio_fit_is_optimal() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed);
        let (mut queues, contents) = random_state(&mut rng);
        let idle = Duration::from_micros(1 + rng.below(1_000));
        let before = queues.len();

        match best_prio_fit(&mut queues, idle) {
            Some(fit) => {
                assert!(fit.predicted < idle, "seed {seed}: fit exceeds window");
                assert_eq!(queues.len(), before - 1, "seed {seed}: exactly one removed");
                // Optimality: no request of strictly higher priority fits,
                // and no same-priority request is longer yet still fits.
                for (prio, predicted) in &contents {
                    if *predicted >= idle {
                        continue;
                    }
                    assert!(
                        !prio.is_higher_than(fit.launch.priority),
                        "seed {seed}: higher-priority fitting request ignored"
                    );
                    if *prio == fit.launch.priority {
                        assert!(
                            *predicted <= fit.predicted,
                            "seed {seed}: longer same-priority fit ignored"
                        );
                    }
                }
            }
            None => {
                // Nothing fits: every queued request's prediction ≥ idle.
                for (_, predicted) in &contents {
                    assert!(
                        *predicted >= idle,
                        "seed {seed}: fitting request {predicted:?} not selected for idle {idle:?}"
                    );
                }
                assert_eq!(queues.len(), before, "seed {seed}: None must not mutate");
            }
        }
    }
}

#[test]
fn prop_fikit_fill_respects_budget() {
    for seed in 100..100 + CASES as u64 {
        let mut rng = Rng::new(seed);
        let (mut queues, _) = random_state(&mut rng);
        let gap = Duration::from_micros(150 + rng.below(3_000));
        let Some(mut window) =
            FillWindow::open(TaskHandle::from_index(0), SimTime::ZERO, gap, DEFAULT_EPSILON)
        else {
            continue;
        };
        let fills = fikit_fill(&mut window, SimTime::ZERO, &mut queues);
        let spent: Duration = fills.iter().map(|f| f.predicted).collect::<Vec<_>>().iter().copied().sum();
        assert!(
            spent.nanos() <= gap.nanos(),
            "seed {seed}: fills {spent:?} exceed predicted gap {gap:?}"
        );
        assert_eq!(window.fills as usize, fills.len());
        // Fills come out in non-ascending priority order.
        for w in fills.windows(2) {
            assert!(
                !w[1].launch.priority.is_higher_than(w[0].launch.priority),
                "seed {seed}: fill priority order violated"
            );
        }
    }
}

#[test]
fn prop_simulation_conservation_random_configs() {
    let models = [
        ModelKind::Alexnet,
        ModelKind::Googlenet,
        ModelKind::Resnet50,
        ModelKind::Vgg16,
        ModelKind::FcosResnet50Fpn,
    ];
    for seed in 0..12u64 {
        let mut rng = Rng::new(seed);
        let mode = match rng.index(3) {
            0 => Mode::Sharing,
            1 => Mode::Exclusive,
            _ => Mode::Fikit,
        };
        let mut cfg = ExperimentConfig {
            mode,
            seed,
            ..ExperimentConfig::default()
        };
        cfg.measurement.runs = 3;
        let n_services = 2 + rng.index(2);
        for s in 0..n_services {
            let model = models[rng.index(models.len())];
            let prio = Priority::from_index(rng.index(10)).unwrap();
            let tasks = 3 + rng.below(8) as u32;
            cfg.services.push(
                ServiceConfig::new(model, prio)
                    .tasks(tasks)
                    .with_key(&format!("svc{s}")),
            );
        }
        let total_tasks: u32 = cfg
            .services
            .iter()
            .map(|s| match s.pattern {
                fikit::workload::InvocationPattern::BackToBack { count } => count,
                _ => 0,
            })
            .sum();

        let report = run_experiment(&cfg).unwrap_or_else(|e| panic!("seed {seed} ({mode}): {e}"));
        assert_eq!(
            report.outcomes.len() as u32,
            total_tasks,
            "seed {seed} ({mode}): all tasks complete"
        );
        let kernels: u64 = report.outcomes.iter().map(|o| o.kernels as u64).sum();
        assert_eq!(
            report.device.kernels, kernels,
            "seed {seed} ({mode}): kernel conservation"
        );
        // JCTs are positive and finite.
        for o in &report.outcomes {
            assert!(o.jct() > Duration::ZERO, "seed {seed}: zero JCT");
            assert!(o.finished >= o.started, "seed {seed}: time travel");
        }
    }
}

#[test]
fn prop_priority_protection_bound() {
    // In FIKIT mode, the high-priority service is never *worse* than
    // default sharing by more than 25% (overhead-2 is bounded by one
    // fill kernel per window).
    let pairs = [
        (ModelKind::KeypointRcnnResnet50Fpn, ModelKind::FcnResnet50),
        (ModelKind::FasterrcnnResnet50Fpn, ModelKind::Vgg16),
        (ModelKind::Alexnet, ModelKind::Resnet101),
        (ModelKind::FcosResnet50Fpn, ModelKind::Deeplabv3Resnet50),
    ];
    for (seed, (high, low)) in pairs.iter().enumerate() {
        let build = |mode: Mode| {
            let mut cfg = ExperimentConfig {
                mode,
                seed: seed as u64,
                ..ExperimentConfig::default()
            };
            cfg.measurement.runs = 5;
            cfg.services
                .push(ServiceConfig::new(*high, Priority::P0).tasks(15).with_key("h"));
            cfg.services
                .push(ServiceConfig::new(*low, Priority::P5).tasks(15).with_key("l"));
            cfg
        };
        let fikit = run_experiment(&build(Mode::Fikit)).unwrap();
        let share = run_experiment(&build(Mode::Sharing)).unwrap();
        let f = fikit.service(&TaskKey::new("h")).unwrap().jct.mean_ms();
        let s = share.service(&TaskKey::new("h")).unwrap().jct.mean_ms();
        assert!(
            f < s * 1.25,
            "{high}/{low}: FIKIT high-prio {f:.2}ms vs sharing {s:.2}ms"
        );
    }
}

#[test]
fn prop_preemption_grid_conservation_and_protection() {
    // Every (FillPolicy × PreemptionPolicy) combination preserves the
    // core invariants:
    //  * every launched kernel completes exactly once — task outcomes
    //    and kernel counts are conserved, with each cut/split adding
    //    exactly one extra device submission (the remnant);
    //  * remnant durations sum back to the original execution — device
    //    busy time equals the no-preemption busy plus the re-executed
    //    wasted slices (± 1 ns rounding per split remnant);
    //  * preemption never hurts the high-priority tenant — hybrid mean
    //    JCT stays within noise of fill-only.
    use fikit::coordinator::best_prio_fit::FillPolicy;
    use fikit::coordinator::fikit::PreemptionPolicy;
    let pairs = [
        (ModelKind::KeypointRcnnResnet50Fpn, ModelKind::FcnResnet50),
        (ModelKind::Alexnet, ModelKind::Vgg16),
        (ModelKind::MaskrcnnResnet50Fpn, ModelKind::FcosResnet50Fpn),
    ];
    for (seed, (high, low)) in pairs.iter().enumerate() {
        let build = |fill: FillPolicy, preempt: PreemptionPolicy| {
            let mut cfg = ExperimentConfig {
                mode: Mode::Fikit,
                seed: seed as u64,
                ..ExperimentConfig::default()
            };
            cfg.measurement.runs = 3;
            cfg.fill_policy = fill;
            cfg.preempt = preempt;
            cfg.services
                .push(ServiceConfig::new(*high, Priority::P0).tasks(10).with_key("h"));
            cfg.services
                .push(ServiceConfig::new(*low, Priority::P4).tasks(10).with_key("l"));
            cfg
        };
        for fill in [FillPolicy::LongestFit, FillPolicy::FirstFit, FillPolicy::ShortestFit] {
            let mut none_baseline = None;
            for preempt in [
                PreemptionPolicy::None,
                PreemptionPolicy::Evict,
                PreemptionPolicy::split(),
                PreemptionPolicy::hybrid(),
            ] {
                let tag = format!("{high}/{low} {fill:?} {preempt}");
                let report = run_experiment(&build(fill, preempt)).unwrap();
                assert_eq!(report.outcomes.len(), 20, "{tag}: all tasks complete");
                let base: u64 = report.outcomes.iter().map(|o| o.kernels as u64).sum();
                let p = report
                    .scheduler
                    .as_ref()
                    .map(|s| s.preempt.clone())
                    .unwrap_or_default();
                assert_eq!(
                    report.device.kernels,
                    base + p.cuts + p.splits,
                    "{tag}: kernel conservation (requeues={})",
                    p.requeues
                );
                let h = report.service(&TaskKey::new("h")).unwrap().jct.mean_ms();
                let busy = report.device.busy.nanos();
                match (preempt, none_baseline) {
                    (PreemptionPolicy::None, _) => {
                        assert_eq!(p.requeues, 0, "{tag}: None never preempts");
                        none_baseline = Some((h, busy));
                    }
                    (_, Some((none_h, none_busy))) => {
                        // Busy = baseline + re-executed wasted work, up to
                        // 1 ns scaling round-off per split remnant.
                        let expected = none_busy + p.wasted.nanos();
                        let tol = p.splits.max(1);
                        let diff = if busy > expected { busy - expected } else { expected - busy };
                        assert!(
                            diff <= tol,
                            "{tag}: busy {busy} vs baseline {none_busy} + wasted {} (tol {tol})",
                            p.wasted.nanos()
                        );
                        if matches!(preempt, PreemptionPolicy::Hybrid { .. }) {
                            assert!(
                                h <= none_h * 1.05 + 0.05,
                                "{tag}: hybrid high-prio JCT {h:.3}ms worse than fill-only {none_h:.3}ms"
                            );
                        }
                    }
                    _ => unreachable!("None runs first"),
                }
            }
        }
    }
}

#[test]
fn prop_protocol_round_trip_random() {
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed);
        let key = TaskKey::new(format!("svc-{}", rng.below(1000)));
        let msg = match rng.index(8) {
            0 => {
                let model = if rng.chance(0.5) {
                    Some(format!("model-{}", rng.below(50)))
                } else {
                    None
                };
                ClientMsg::Register {
                    task_key: key,
                    priority: Priority::from_index(rng.index(10)).unwrap(),
                    has_symbols: rng.chance(0.5),
                    model,
                }
            }
            1 => ClientMsg::TaskStart {
                task_key: key,
                task_id: TaskId(rng.next_u64() >> 1),
            },
            2 => ClientMsg::Launch {
                task_key: key,
                task_id: TaskId(rng.below(1 << 40)),
                kernel_name: format!("kern<{}, \"квант\\n\">", rng.below(100)),
                grid: Dim3::new(rng.below(65536) as u32, 1 + rng.below(64) as u32, 1),
                block: Dim3::new(1 + rng.below(1024) as u32, 1, 1),
                seq: rng.below(1 << 20) as u32,
                issued_at: SimTime(rng.next_u64() >> 2),
            },
            3 => ClientMsg::Completion {
                task_key: key,
                task_id: TaskId(rng.below(1 << 30)),
                seq: rng.below(1 << 16) as u32,
                exec: Duration::from_nanos(rng.next_u64() >> 3),
                finished_at: SimTime(rng.next_u64() >> 3),
            },
            4 => ClientMsg::TaskEnd {
                task_key: key,
                task_id: TaskId(rng.below(1 << 30)),
            },
            5 => ClientMsg::ReleaseQuery {
                task_key: key,
                seq: rng.below(1 << 20) as u32,
            },
            6 => ClientMsg::Preempted {
                task_key: key,
                task_id: TaskId(rng.below(1 << 30)),
                kernel_name: format!("kern<{}, \"остаток\\t\">", rng.below(100)),
                grid: Dim3::new(1 + rng.below(256) as u32, 1, 1),
                block: Dim3::new(1 + rng.below(1024) as u32, 1, 1),
                seq: rng.below(1 << 20) as u32,
                remaining: Duration::from_nanos(rng.next_u64() >> 3),
            },
            _ => ClientMsg::Disconnect { task_key: key },
        };
        // The v2 retransmit envelope survives the round trip too.
        let msg_seq = rng.next_u64() >> 1;
        let (seq_back, back) = ClientMsg::decode_seq(&msg.encode_seq(msg_seq).unwrap())
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(seq_back, msg_seq, "seed {seed}");
        assert_eq!(back, msg, "seed {seed}");
    }
    for seed in 0..60u64 {
        let mut rng = Rng::new(seed + 999);
        let key = TaskKey::new("svc");
        let msg = match rng.index(4) {
            0 => SchedulerMsg::Registered {
                task_key: key,
                sharing_stage: rng.chance(0.5),
            },
            1 => SchedulerMsg::LaunchNow {
                task_key: key,
                task_id: TaskId(rng.below(1 << 30)),
                seq: rng.below(1 << 16) as u32,
            },
            2 => SchedulerMsg::Ack {
                msg_seq: rng.next_u64() >> 1,
            },
            _ => SchedulerMsg::Hold {
                task_key: key,
                task_id: TaskId(rng.below(1 << 30)),
                seq: rng.below(1 << 16) as u32,
            },
        };
        assert_eq!(SchedulerMsg::decode(&msg.encode().unwrap()).unwrap(), msg);
    }
}

#[test]
fn prop_json_round_trip_random_documents() {
    use fikit::util::json::Json;
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.index(4) } else { rng.index(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Int(rng.next_u64() as i64),
            3 => Json::Str(format!("s{}\"\\\n→{}", rng.below(100), rng.below(100))),
            4 => Json::Arr((0..rng.index(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut obj = Json::obj();
                for i in 0..rng.index(5) {
                    obj = obj.set(&format!("k{i}"), random_json(rng, depth - 1));
                }
                obj
            }
        }
    }
    for seed in 0..300u64 {
        let mut rng = Rng::new(seed);
        let doc = random_json(&mut rng, 4);
        let compact = Json::parse(&doc.encode()).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(compact, doc, "seed {seed} (compact)");
        let pretty = Json::parse(&doc.encode_pretty()).unwrap();
        assert_eq!(pretty, doc, "seed {seed} (pretty)");
    }
}
