//! `cargo bench --bench scheduler_hotpath` — microbenchmarks of the L3
//! hot paths (DESIGN.md §Perf).
//!
//! Budget reasoning: the paper's ε = 0.1 ms is the smallest gap worth
//! filling, so every scheduling decision (BestPrioFit lookup + queue ops
//! + window bookkeeping) must cost ≪ 100 µs — the indexed hot path is
//! budgeted at ≤ 1 µs per decision (enforced per case; see
//! `fikit::benchsuite` and `scripts/check_bench.py`).
//!
//! Set `BENCH_JSON=path` to write the machine-readable `BENCH_sched.json`
//! artifact (same shape as `fikit bench --json`).

use fikit::benchsuite::{run_hotpath_suite, run_sim_suite};
use fikit::config::{ExperimentConfig, ServiceConfig};
use fikit::coordinator::driver::run_experiment;
use fikit::coordinator::Mode;
use fikit::core::{Dim3, Priority, SimTime, TaskId, TaskKey};
use fikit::hook::protocol::ClientMsg;
use fikit::util::bench::{black_box, Bencher};
use fikit::util::json::Json;
use fikit::workload::{ModelKind, TraceGenerator};

fn main() {
    // --- shared scheduler hot-path suite (budgeted cases) ---
    let suite = run_hotpath_suite(false);

    // --- surrounding-system cases (wire protocol, JSON, workload, sim) ---
    let mut b = Bencher::new();

    let msg = ClientMsg::Launch {
        task_key: TaskKey::new("svc0"),
        task_id: TaskId(42),
        kernel_name: "resnet50_fpn_backbone_conv".into(),
        grid: Dim3::x(512),
        block: Dim3::x(256),
        seq: 17,
        issued_at: SimTime(123_456_789),
    };
    let encoded = msg.encode().unwrap();
    b.bench("protocol/encode_launch", || black_box(msg.encode().unwrap()));
    b.bench("protocol/decode_launch", || {
        black_box(ClientMsg::decode(&encoded).unwrap())
    });

    let doc = Json::parse(&format!(
        r#"{{"a": [{}], "b": {{"c": 1.5, "d": "text"}}}}"#,
        (0..64).map(|i| i.to_string()).collect::<Vec<_>>().join(",")
    ))
    .unwrap();
    let doc_text = doc.encode();
    b.bench("json/parse_1kb", || black_box(Json::parse(&doc_text).unwrap()));

    let spec = ModelKind::KeypointRcnnResnet50Fpn.spec();
    let mut gen = TraceGenerator::new(&spec, 7);
    b.bench("workload/trace_keypointrcnn_790k", || {
        black_box(gen.next_trace().len())
    });

    // --- end-to-end simulation throughput ---
    for (name, mode) in [("fikit", Mode::Fikit), ("sharing", Mode::Sharing)] {
        b.bench(&format!("sim/two_service_20_tasks_{name}"), || {
            let mut cfg = ExperimentConfig {
                mode,
                ..ExperimentConfig::default()
            };
            cfg.measurement.runs = 3;
            cfg.services.push(
                ServiceConfig::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0).tasks(20),
            );
            cfg.services
                .push(ServiceConfig::new(ModelKind::FcnResnet50, Priority::P3).tasks(20));
            let r = run_experiment(&cfg).unwrap();
            black_box(r.events)
        });
    }

    // Report events/sec for the sim (headline L3 perf number).
    {
        let mut cfg = ExperimentConfig::default();
        cfg.mode = Mode::Fikit;
        cfg.measurement.runs = 5;
        cfg.services.push(
            ServiceConfig::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0).tasks(100),
        );
        cfg.services
            .push(ServiceConfig::new(ModelKind::FcnResnet50, Priority::P3).tasks(100));
        let r = run_experiment(&cfg).unwrap();
        println!(
            "sim throughput: {} events in {:.3}s = {:.2}M events/s\n",
            r.events,
            r.wall.as_secs_f64(),
            r.events as f64 / r.wall.as_secs_f64() / 1e6
        );
    }

    // --- shared simulator event-core suite (events/sec headline) ---
    let sim_suite = run_sim_suite(false);

    println!("{}", suite.table);
    println!("{}", sim_suite.table);
    println!("{}", b.report());

    // Machine-readable perf trajectory (budgets embedded per case).
    if let Ok(path) = std::env::var("BENCH_JSON") {
        suite.write_json(&path).expect("write BENCH_JSON");
        println!("wrote bench results -> {path}");
        let sim_path = std::path::Path::new(&path)
            .with_file_name("BENCH_sim.json")
            .to_string_lossy()
            .into_owned();
        sim_suite.write_json(&sim_path).expect("write BENCH_sim.json");
        println!("wrote bench results -> {sim_path}");
    }

    // Per-case budget gate (ε-floor reasoning in module docs).
    let mut violations = suite.violations();
    violations.extend(sim_suite.violations());
    for v in &violations {
        eprintln!("BUDGET VIOLATION: {v}");
    }
    if !violations.is_empty() {
        std::process::exit(1);
    }
}
