//! `cargo bench --bench scheduler_hotpath` — microbenchmarks of the L3
//! hot paths (DESIGN.md §Perf).
//!
//! Budget reasoning: the paper's ε = 0.1 ms is the smallest gap worth
//! filling, so every scheduling decision (BestPrioFit scan + queue ops +
//! window bookkeeping) must cost ≪ 100 µs — ideally ≲ 1 µs — or the
//! scheduler itself eats the gaps it is trying to fill.

use fikit::config::{ExperimentConfig, ServiceConfig};
use fikit::coordinator::best_prio_fit::best_prio_fit;
use fikit::coordinator::driver::run_experiment;
use fikit::coordinator::fikit::{fikit_fill, FillWindow, DEFAULT_EPSILON};
use fikit::coordinator::queues::PriorityQueues;
use fikit::coordinator::Mode;
use fikit::core::{Dim3, Duration, KernelId, KernelLaunch, Priority, SimTime, TaskId, TaskKey};
use fikit::hook::protocol::ClientMsg;
use fikit::profile::{ProfileStore, TaskProfile};
use fikit::util::bench::{black_box, Bencher};
use fikit::util::json::Json;
use fikit::util::rng::Rng;
use fikit::workload::{ModelKind, TraceGenerator};

fn kid(i: usize) -> KernelId {
    KernelId::new(format!("kernel_{i}"), Dim3::x(64), Dim3::x(256))
}

fn launch(i: usize, prio: Priority) -> KernelLaunch {
    KernelLaunch {
        task_key: TaskKey::new(format!("svc{}", i % 8)),
        task_id: TaskId(i as u64),
        kernel: kid(i % 32),
        priority: prio,
        seq: i as u32,
        true_duration: Duration::from_micros(50),
        issued_at: SimTime(i as u64),
    }
}

/// Profile store covering svc0..svc7 × kernel_0..kernel_31.
fn store() -> ProfileStore {
    let mut s = ProfileStore::new();
    for svc in 0..8 {
        let mut p = TaskProfile::new(TaskKey::new(format!("svc{svc}")));
        for k in 0..32 {
            p.record(
                &kid(k),
                Duration::from_micros(20 + (k as u64 * 13) % 300),
                Some(Duration::from_micros(40)),
            );
        }
        p.finish_run(32);
        s.insert(p);
    }
    s
}

/// Production path: predictions resolved at enqueue time.
fn filled_queues(n: usize) -> PriorityQueues {
    let mut q = PriorityQueues::new();
    let mut rng = Rng::new(42);
    for i in 0..n {
        let prio = Priority::from_index(1 + rng.index(9)).unwrap();
        let predicted = Some(Duration::from_micros(20 + ((i % 32) as u64 * 13) % 300));
        q.push_predicted(launch(i, prio), predicted, SimTime(i as u64));
    }
    q
}

/// Legacy path: every scan falls back to a string-keyed store lookup
/// (kept to quantify the §Perf optimization).
fn filled_queues_unresolved(n: usize) -> PriorityQueues {
    let mut q = PriorityQueues::new();
    let mut rng = Rng::new(42);
    for i in 0..n {
        let prio = Priority::from_index(1 + rng.index(9)).unwrap();
        q.push(launch(i, prio), SimTime(i as u64));
    }
    q
}

fn main() {
    let mut b = Bencher::new();
    let profiles = store();

    // --- queue operations ---
    for n in [16usize, 128, 1024] {
        let base = filled_queues(n);
        b.bench(&format!("queues/push_pop_n{n}"), || {
            let mut q = PriorityQueues::new();
            for i in 0..16 {
                q.push(launch(i, Priority::P5), SimTime(0));
            }
            while let Some(r) = q.pop_highest() {
                black_box(r);
            }
            black_box(base.len())
        });
    }

    // --- BestPrioFit scan cost vs queue depth (the core decision) ---
    // Pure scan: an idle window smaller than every profiled SK, so the
    // full Q0→Q9 walk happens but nothing is removed (steady state).
    for n in [8usize, 64, 512, 2048] {
        let mut q = filled_queues(n);
        b.bench(&format!("best_prio_fit/scan_n{n}"), || {
            black_box(best_prio_fit(&mut q, Duration::from_nanos(1), &profiles))
        });
        let mut q = filled_queues_unresolved(n);
        b.bench(&format!("best_prio_fit/scan_unresolved_n{n}"), || {
            black_box(best_prio_fit(&mut q, Duration::from_nanos(1), &profiles))
        });
    }
    // Successful fit: select + remove, then re-queue to keep the state
    // stable across iterations.
    {
        let mut q = filled_queues(64);
        b.bench("best_prio_fit/fit_and_requeue_n64", || {
            if let Some(fit) = best_prio_fit(&mut q, Duration::from_micros(500), &profiles) {
                q.push(fit.launch, SimTime(0));
            }
        });
    }

    // --- full FIKIT fill window (Algorithm 1 loop) ---
    b.bench("fikit_fill/window_1ms_q64", || {
        let mut q = filled_queues(64);
        let mut w = FillWindow::open(
            TaskKey::new("holder"),
            SimTime::ZERO,
            Duration::from_millis(1),
            DEFAULT_EPSILON,
        )
        .unwrap();
        black_box(fikit_fill(&mut w, SimTime::ZERO, &mut q, &profiles))
    });

    // --- profile lookups (per-completion SG lookup) ---
    let profile = profiles.get(&TaskKey::new("svc0")).unwrap();
    let k = kid(7);
    b.bench("profile/sg_lookup", || black_box(profile.sg(&k)));

    // --- wire protocol encode/decode ---
    let msg = ClientMsg::Launch {
        task_key: TaskKey::new("svc0"),
        task_id: TaskId(42),
        kernel_name: "resnet50_fpn_backbone_conv".into(),
        grid: Dim3::x(512),
        block: Dim3::x(256),
        seq: 17,
        issued_at: SimTime(123_456_789),
    };
    let encoded = msg.encode().unwrap();
    b.bench("protocol/encode_launch", || black_box(msg.encode().unwrap()));
    b.bench("protocol/decode_launch", || {
        black_box(ClientMsg::decode(&encoded).unwrap())
    });

    // --- JSON substrate ---
    let doc = Json::parse(&format!(
        r#"{{"a": [{}], "b": {{"c": 1.5, "d": "text"}}}}"#,
        (0..64).map(|i| i.to_string()).collect::<Vec<_>>().join(",")
    ))
    .unwrap();
    let doc_text = doc.encode();
    b.bench("json/parse_1kb", || black_box(Json::parse(&doc_text).unwrap()));

    // --- trace generation (per-task workload sampling) ---
    let spec = ModelKind::KeypointRcnnResnet50Fpn.spec();
    let mut gen = TraceGenerator::new(&spec, 7);
    b.bench("workload/trace_keypointrcnn_790k", || {
        black_box(gen.next_trace().len())
    });

    // --- end-to-end simulation throughput ---
    for (name, mode) in [("fikit", Mode::Fikit), ("sharing", Mode::Sharing)] {
        b.bench(&format!("sim/two_service_20_tasks_{name}"), || {
            let mut cfg = ExperimentConfig {
                mode,
                ..ExperimentConfig::default()
            };
            cfg.measurement.runs = 3;
            cfg.services.push(
                ServiceConfig::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0).tasks(20),
            );
            cfg.services
                .push(ServiceConfig::new(ModelKind::FcnResnet50, Priority::P3).tasks(20));
            let r = run_experiment(&cfg).unwrap();
            black_box(r.events)
        });
    }

    // Report events/sec for the sim (headline L3 perf number).
    {
        let mut cfg = ExperimentConfig::default();
        cfg.mode = Mode::Fikit;
        cfg.measurement.runs = 5;
        cfg.services.push(
            ServiceConfig::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0).tasks(100),
        );
        cfg.services
            .push(ServiceConfig::new(ModelKind::FcnResnet50, Priority::P3).tasks(100));
        let r = run_experiment(&cfg).unwrap();
        println!(
            "sim throughput: {} events in {:.3}s = {:.2}M events/s\n",
            r.events,
            r.wall.as_secs_f64(),
            r.events as f64 / r.wall.as_secs_f64() / 1e6
        );
    }

    println!("{}", b.report());

    // Budget assertion: decisions must stay far under the ε = 100 µs gap
    // floor (see module docs).
    let worst_decision = b
        .results()
        .iter()
        .filter(|r| {
            (r.name.starts_with("best_prio_fit") || r.name.starts_with("fikit_fill"))
                // The "unresolved" variants measure the pre-optimization
                // fallback path for §Perf comparison, not production.
                && !r.name.contains("unresolved")
        })
        .map(|r| r.mean_ns())
        .fold(0.0f64, f64::max);
    println!(
        "worst scheduling-decision mean: {:.1}us (budget: << 100us)",
        worst_decision / 1000.0
    );
    if worst_decision > 50_000.0 {
        eprintln!("WARNING: scheduling decision cost approaching the gap floor");
        std::process::exit(1);
    }
}

