//! `cargo bench --bench paper_experiments` — regenerates every table and
//! figure of the paper's evaluation section (DESIGN.md §5) and prints
//! the same rows/series the paper reports, plus the shape checks.
//!
//! Scale via env: `FIKIT_BENCH_SCALE=1.0` (default; 0.1 = smoke).

use fikit::experiments::{self, Options};

fn main() {
    let scale: f64 = std::env::var("FIKIT_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    let seed: u64 = std::env::var("FIKIT_BENCH_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xF1C1);
    let opts = Options { scale, seed };
    println!("paper experiment harness — scale={scale} seed={seed:#x}\n");

    let mut failures = 0usize;
    let t_all = std::time::Instant::now();
    for id in experiments::ALL {
        let t0 = std::time::Instant::now();
        match experiments::run(id, opts) {
            Ok(result) => {
                println!("{}", result.render());
                println!("  ({:.2}s)\n", t0.elapsed().as_secs_f64());
                if !result.all_checks_pass() {
                    failures += 1;
                }
            }
            Err(e) => {
                println!("== {id} == ERROR: {e}\n");
                failures += 1;
            }
        }
    }
    println!(
        "total: {:.1}s, {} experiment(s) with failing shape checks",
        t_all.elapsed().as_secs_f64(),
        failures
    );
    if failures > 0 {
        std::process::exit(1);
    }
}
