//! In-tree infrastructure substrates.
//!
//! This reproduction runs in an offline build environment with a pinned
//! crate set, so the usual ecosystem pieces are implemented here from
//! scratch:
//!
//! * [`json`] — a complete JSON value model, parser and writer (profile
//!   persistence, wire protocol, config files).
//! * [`rng`] — a seeded xoshiro256++ PRNG with uniform / Gaussian /
//!   log-normal sampling (workload jitter, property tests).
//! * [`cli`] — a small declarative command-line argument parser.
//! * [`bench`] — a measurement harness (warmup, iterations, robust
//!   statistics) used by the `cargo bench` targets.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
