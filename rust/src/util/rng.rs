//! Seeded PRNG and the sampling distributions the workload models need.
//!
//! xoshiro256++ seeded via splitmix64: tiny, fast, excellent statistical
//! quality for simulation purposes, and fully deterministic per seed —
//! the property every experiment in this repo depends on.

/// Deterministic xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian sample from Box–Muller.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Build from a 64-bit seed (expanded via splitmix64 so nearby seeds
    /// yield uncorrelated streams).
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng {
            s,
            gauss_spare: None,
        }
    }

    /// Derive an independent child stream (per-service seeding).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64 bits (xoshiro256++).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`. 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(hi >= lo);
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in `[0, n)` (Lemire's method, unbiased enough for
    /// simulation use).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// `true` with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached spare).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid ln(0).
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Log-normal sample with the given *distribution mean* and shape
    /// σ: solves μ so that `E[X] = mean` (i.e. μ = ln(mean) − σ²/2).
    pub fn lognormal_with_mean(&mut self, mean: f64, sigma: f64) -> f64 {
        debug_assert!(mean > 0.0);
        if sigma <= 0.0 {
            return mean;
        }
        let mu = mean.ln() - sigma * sigma / 2.0;
        (mu + sigma * self.gaussian()).exp()
    }

    /// Exponential sample with the given mean (arrival processes).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|s| *s), "all buckets hit");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.gaussian();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "gaussian mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "gaussian var {var}");
    }

    #[test]
    fn lognormal_mean_matches_target() {
        let mut r = Rng::new(4);
        let n = 200_000;
        let target = 250.0;
        let sigma = 0.4;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.lognormal_with_mean(target, sigma);
            assert!(v > 0.0);
            sum += v;
        }
        let mean = sum / n as f64;
        assert!(
            (mean - target).abs() / target < 0.02,
            "lognormal mean {mean} vs target {target}"
        );
        // σ = 0 degenerates to the mean exactly.
        assert_eq!(r.lognormal_with_mean(target, 0.0), target);
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += r.exponential(10.0);
        }
        let mean = sum / n as f64;
        assert!((mean - 10.0).abs() < 0.2, "exp mean {mean}");
    }

    #[test]
    fn fork_decorrelates() {
        let mut parent = Rng::new(6);
        let mut a = parent.fork(1);
        let mut b = parent.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
