//! A small declarative CLI argument parser (the offline environment has
//! no clap). Supports subcommands, `--flag`, `--key value` /
//! `--key=value` options, and positional arguments, with generated help.

use crate::core::{Error, Result};
use std::collections::BTreeMap;

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse raw arguments. Anything starting with `--` is an option or
    /// flag; `--key=value` and `--key value` are both accepted; a `--key`
    /// followed by another `--...` (or nothing) is a boolean flag.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Was the boolean flag `--name` given?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw value of option `--name`, if present.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Typed option with a default.
    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Parse(format!("invalid value for --{name}: {s:?}"))),
        }
    }

    /// All positional arguments, in order.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Positional argument `idx` (0 is the subcommand).
    pub fn pos(&self, idx: usize) -> Option<&str> {
        self.positional.get(idx).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn options_flags_positionals() {
        let a = parse(&[
            "run", "extra", "--mode", "fikit", "--seed=42", "--verbose",
        ]);
        assert_eq!(a.pos(0), Some("run"));
        assert_eq!(a.opt("mode"), Some("fikit"));
        assert_eq!(a.opt("seed"), Some("42"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.pos(1), Some("extra"));
    }

    #[test]
    fn typed_options() {
        let a = parse(&["--tasks", "100"]);
        assert_eq!(a.opt_parse("tasks", 5u32).unwrap(), 100);
        assert_eq!(a.opt_parse("missing", 7u32).unwrap(), 7);
        let bad = parse(&["--tasks", "abc"]);
        assert!(bad.opt_parse("tasks", 5u32).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--fast"]);
        assert!(a.flag("fast"));
    }
}
