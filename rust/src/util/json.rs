//! A complete, dependency-free JSON implementation: value model,
//! recursive-descent parser, and writer.
//!
//! Used for profile persistence ([`crate::profile::ProfileStore`]), the
//! hook↔scheduler wire protocol, experiment config files, and bench
//! result dumps. Integers are kept as `i64` (not lossy `f64`) because
//! durations are nanosecond counts.

use crate::core::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Integral number (i64 range preserved exactly).
    Int(i64),
    /// Non-integral (or out-of-i64-range) number.
    Float(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with deterministic (sorted) key order on output.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ----- constructors -----

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics if not an object — builder misuse).
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(map) => {
                map.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ----- accessors -----

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name on miss — config/profile
    /// loading wants good messages.
    pub fn require(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Parse(format!("missing JSON key {key:?}")))
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Float(f) if f.fract() == 0.0 && f.abs() < 9.3e18 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|i| u64::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ----- typed requires -----

    pub fn req_u64(&self, key: &str) -> Result<u64> {
        self.require(key)?
            .as_u64()
            .ok_or_else(|| Error::Parse(format!("JSON key {key:?} is not a u64")))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.require(key)?
            .as_f64()
            .ok_or_else(|| Error::Parse(format!("JSON key {key:?} is not a number")))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.require(key)?
            .as_str()
            .ok_or_else(|| Error::Parse(format!("JSON key {key:?} is not a string")))
    }

    pub fn req_bool(&self, key: &str) -> Result<bool> {
        self.require(key)?
            .as_bool()
            .ok_or_else(|| Error::Parse(format!("JSON key {key:?} is not a bool")))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.require(key)?
            .as_arr()
            .ok_or_else(|| Error::Parse(format!("JSON key {key:?} is not an array")))
    }

    // ----- encode / decode -----

    /// Compact single-line encoding.
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty-printed encoding (2-space indent).
    pub fn encode_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Float(f) => {
                if f.is_finite() {
                    // Guarantee a re-parsable numeric token.
                    if f.fract() == 0.0 && f.abs() < 1e15 {
                        let _ = write!(out, "{f:.1}");
                    } else {
                        let _ = write!(out, "{f}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Parse(format!(
                "trailing garbage at byte {} of JSON document",
                p.pos
            )));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        out.extend(std::iter::repeat(' ').take(n * depth));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ----- conversions -----

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Int(v)
    }
}
impl From<u32> for Json {
    fn from(v: u32) -> Json {
        Json::Int(v as i64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        if v <= i64::MAX as u64 {
            Json::Int(v as i64)
        } else {
            Json::Float(v as f64)
        }
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::from(v as u64)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Float(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

// ----- parser -----

/// Maximum nesting depth accepted by the parser. The parser is
/// recursive; without a cap, hostile wire input (the UDP protocol feeds
/// attacker-controllable bytes here) could overflow the stack.
const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Parse(format!("JSON parse error at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected {lit}")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.literal("null", Json::Null),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            other => Err(self.err(&format!("unexpected character {:?}", other as char))),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => return Ok(out),
                b'\\' => match self.bump().ok_or_else(|| self.err("bad escape"))? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(
                                char::from_u32(c).ok_or_else(|| self.err("bad surrogate pair"))?,
                            );
                        } else {
                            out.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                    }
                    other => {
                        return Err(self.err(&format!("bad escape \\{}", other as char)));
                    }
                },
                b if b < 0x80 => out.push(b as char),
                b => {
                    // Multi-byte UTF-8: re-decode from the original slice.
                    let start = self.pos - 1;
                    let width = utf8_width(b).ok_or_else(|| self.err("invalid UTF-8"))?;
                    let end = start + width;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated UTF-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Float)
            .map_err(|_| self.err(&format!("bad number {text:?}")))
    }

    fn enter(&mut self) -> Result<()> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        Ok(())
    }

    fn array(&mut self) -> Result<Json> {
        self.enter()?;
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => {
                    self.depth -= 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.enter()?;
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => {
                    self.depth -= 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_width(first: u8) -> Option<usize> {
    match first {
        0xC0..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF7 => Some(4),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_round_trips() {
        for text in ["null", "true", "false", "0", "-42", "3.5", "1e3"] {
            let v = Json::parse(text).unwrap();
            let back = Json::parse(&v.encode()).unwrap();
            assert_eq!(v, back, "{text}");
        }
        assert_eq!(Json::parse("42").unwrap(), Json::Int(42));
        assert_eq!(Json::parse("42.0").unwrap(), Json::Float(42.0));
    }

    #[test]
    fn i64_precision_preserved() {
        let big = i64::MAX - 7;
        let v = Json::Int(big);
        let back = Json::parse(&v.encode()).unwrap();
        assert_eq!(back.as_i64(), Some(big));
    }

    #[test]
    fn string_escapes() {
        let s = "he said \"hi\"\n\ttab\\slash ünïcödé 🎉";
        let v = Json::Str(s.to_string());
        let enc = v.encode();
        let back = Json::parse(&enc).unwrap();
        assert_eq!(back.as_str(), Some(s));
    }

    #[test]
    fn unicode_escape_parsing() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap().as_str(),
            Some("Aé")
        );
        // Surrogate pair: U+1F389 🎉
        assert_eq!(
            Json::parse(r#""🎉""#).unwrap().as_str(),
            Some("🎉")
        );
        assert!(Json::parse(r#""\ud83c""#).is_err()); // lone surrogate
    }

    #[test]
    fn nested_structures() {
        let text = r#"{"a": [1, 2.5, {"b": null}], "c": {"d": [true, false]}, "e": ""}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("c").unwrap().get("d").unwrap().as_arr().unwrap()[0],
            Json::Bool(true)
        );
        // Round trip compact and pretty.
        assert_eq!(Json::parse(&v.encode()).unwrap(), v);
        assert_eq!(Json::parse(&v.encode_pretty()).unwrap(), v);
    }

    #[test]
    fn builder_and_accessors() {
        let v = Json::obj()
            .set("name", "fikit")
            .set("runs", 20u32)
            .set("ratio", 1.5)
            .set("on", true)
            .set("list", vec![Json::Int(1), Json::Int(2)]);
        assert_eq!(v.req_str("name").unwrap(), "fikit");
        assert_eq!(v.req_u64("runs").unwrap(), 20);
        assert_eq!(v.req_f64("ratio").unwrap(), 1.5);
        assert!(v.req_bool("on").unwrap());
        assert_eq!(v.req_arr("list").unwrap().len(), 2);
        assert!(v.require("missing").is_err());
        assert!(v.req_u64("name").is_err());
    }

    #[test]
    fn rejects_garbage() {
        for text in ["", "{", "[1,", "{\"a\":}", "tru", "1 2", "{'a':1}", "[1,]"] {
            assert!(Json::parse(text).is_err(), "{text:?} should fail");
        }
    }

    #[test]
    fn hostile_depth_rejected_without_stack_overflow() {
        // 100k nested arrays would blow the stack in a naive recursive
        // parser; the depth cap turns it into a parse error.
        let deep = "[".repeat(100_000) + &"]".repeat(100_000);
        assert!(Json::parse(&deep).is_err());
        // Depth just under the cap still parses.
        let ok = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn random_bytes_never_panic() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        for _ in 0..500 {
            let len = rng.index(64);
            let bytes: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
            if let Ok(text) = std::str::from_utf8(&bytes) {
                let _ = Json::parse(text); // must not panic
            }
        }
    }

    #[test]
    fn whitespace_tolerated() {
        let v = Json::parse(" \n\t{ \"a\" : [ 1 , 2 ] } \r\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn deterministic_output_order() {
        let a = Json::parse(r#"{"z":1,"a":2}"#).unwrap();
        let b = Json::parse(r#"{"a":2,"z":1}"#).unwrap();
        assert_eq!(a.encode(), b.encode()); // BTreeMap sorts keys
    }
}
