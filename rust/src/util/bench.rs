//! A measurement harness for the `cargo bench` targets (the offline
//! environment has no criterion): warmup, timed iterations, robust
//! statistics, and aligned text output.

use std::time::{Duration as StdDuration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Case name as printed in the report.
    pub name: String,
    /// Total iterations measured (across all samples).
    pub iters: u64,
    /// Mean time per iteration.
    pub mean: StdDuration,
    /// Median per-sample time per iteration.
    pub median: StdDuration,
    /// 95th-percentile per-sample time per iteration.
    pub p95: StdDuration,
    /// Fastest sample (closest to noise-free cost).
    pub min: StdDuration,
}

impl BenchResult {
    /// Mean time per iteration in nanoseconds.
    pub fn mean_ns(&self) -> f64 {
        self.mean.as_nanos() as f64
    }

    /// Serialize to the `BENCH_*.json` case shape (see
    /// `scripts/check_bench.py` for the consumed schema).
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj()
            .set("name", self.name.as_str())
            .set("iters", self.iters)
            .set("mean_ns", self.mean.as_nanos() as u64)
            .set("median_ns", self.median.as_nanos() as u64)
            .set("p95_ns", self.p95.as_nanos() as u64)
            .set("min_ns", self.min.as_nanos() as u64)
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    /// Minimum total measurement time per case.
    pub measure_time: StdDuration,
    /// Warmup time per case.
    pub warmup_time: StdDuration,
    /// Max sample count (each sample may batch many iterations).
    pub max_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Bencher {
        Bencher {
            measure_time: StdDuration::from_millis(900),
            warmup_time: StdDuration::from_millis(150),
            max_samples: 200,
            results: Vec::new(),
        }
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bencher {
    /// Default full-fidelity harness (~1 s per case).
    pub fn new() -> Bencher {
        Bencher::default()
    }

    /// Quick harness for smoke runs (CI): ~100 ms per case.
    pub fn quick() -> Bencher {
        Bencher {
            measure_time: StdDuration::from_millis(120),
            warmup_time: StdDuration::from_millis(30),
            max_samples: 50,
            results: Vec::new(),
        }
    }

    /// Measure `f`, which performs ONE logical iteration per call.
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &BenchResult {
        // Warmup & per-iteration cost estimate.
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup_time {
            black_box(f());
            warm_iters += 1;
        }
        let est = warm_start.elapsed().as_nanos() as f64 / warm_iters.max(1) as f64;

        // Choose a batch size so each sample is ≥ ~50µs (amortize timer
        // overhead) and we get up to max_samples samples.
        let target_sample_ns = (self.measure_time.as_nanos() as f64 / self.max_samples as f64)
            .max(50_000.0);
        let batch = ((target_sample_ns / est.max(1.0)).ceil() as u64).max(1);

        let mut samples: Vec<f64> = Vec::with_capacity(self.max_samples);
        let mut total_iters = 0u64;
        let run_start = Instant::now();
        while run_start.elapsed() < self.measure_time && samples.len() < self.max_samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            let per_iter = t0.elapsed().as_nanos() as f64 / batch as f64;
            samples.push(per_iter);
            total_iters += batch;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pick = |q: f64| -> StdDuration {
            let idx = ((samples.len() as f64 - 1.0) * q).round() as usize;
            StdDuration::from_nanos(samples[idx.min(samples.len() - 1)] as u64)
        };
        let mean_ns = samples.iter().sum::<f64>() / samples.len() as f64;
        let result = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean: StdDuration::from_nanos(mean_ns as u64),
            median: pick(0.5),
            p95: pick(0.95),
            min: pick(0.0),
        };
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Render the collected results as an aligned table.
    pub fn report(&self) -> String {
        let mut t = crate::metrics::TextTable::new(&["benchmark", "mean", "median", "p95", "min", "iters"]);
        for r in &self.results {
            t.row(vec![
                r.name.clone(),
                fmt_ns(r.mean.as_nanos() as f64),
                fmt_ns(r.median.as_nanos() as f64),
                fmt_ns(r.p95.as_nanos() as f64),
                fmt_ns(r.min.as_nanos() as f64),
                r.iters.to_string(),
            ]);
        }
        t.render()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3}us", ns / 1e3)
    } else {
        format!("{ns:.0}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_known_workload() {
        let mut b = Bencher::quick();
        let r = b.bench("sum_1k", || (0..1000u64).sum::<u64>());
        assert!(r.iters > 0);
        assert!(r.mean.as_nanos() > 0);
        assert!(r.min <= r.median && r.median <= r.p95);
    }

    #[test]
    fn report_renders() {
        let mut b = Bencher::quick();
        b.bench("noop", || 1u64);
        let rep = b.report();
        assert!(rep.contains("noop"));
        assert!(rep.contains("mean"));
    }

    #[test]
    fn result_serializes_to_json() {
        let mut b = Bencher::quick();
        b.bench("case_a", || 1u64);
        let j = b.results()[0].to_json();
        assert_eq!(j.req_str("name").unwrap(), "case_a");
        assert!(j.req_u64("iters").unwrap() > 0);
        for field in ["mean_ns", "median_ns", "p95_ns", "min_ns"] {
            assert!(j.req_u64(field).is_ok(), "missing {field}");
        }
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_ns(12.0), "12ns");
        assert_eq!(fmt_ns(12_500.0), "12.500us");
        assert_eq!(fmt_ns(12_500_000.0), "12.500ms");
        assert_eq!(fmt_ns(2_500_000_000.0), "2.500s");
    }
}
