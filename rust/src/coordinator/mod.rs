//! The FIKIT coordinator — the paper's system contribution.
//!
//! Components map one-to-one onto the paper's §3.2 design:
//!
//! * [`queues`] — the ten priority message queues Q0–Q9 (Fig 7).
//! * [`best_prio_fit`] — **Algorithm 2**, the sharing-stage idling-gap
//!   filling policy: pick the highest-priority request whose profiled
//!   duration is the longest that still fits the remaining gap.
//! * [`fikit`] — **Algorithm 1**, the FIKIT procedure: on a
//!   high-priority kernel completion, look up the profiled idle gap and
//!   repeatedly invoke BestPrioFit until the gap budget is exhausted.
//! * [`feedback`] — the real-time feedback / early-stop mechanism
//!   (Fig 12) that truncates a fill window the moment the next
//!   high-priority kernel actually arrives.
//! * [`scheduler`] — ties the above together: tracks which task holds
//!   the GPU (the highest-priority active task), routes direct vs queued
//!   launches (the three cases of Fig 11), and reacts to kernel
//!   completions.
//! * [`driver`] — the simulation event loop ([`driver::GpuSim`]) that
//!   runs a set of services under a [`Mode`] and produces an
//!   [`driver::ExperimentReport`]. Besides the one-shot experiment path
//!   it supports **dynamic membership** — services attach and detach
//!   mid-run — which the cluster churn loop (DESIGN.md §8) drives.

pub mod best_prio_fit;
pub mod driver;
pub mod feedback;
pub mod fikit;
pub mod queues;
pub mod scheduler;


/// GPU multi-tasking mode under evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Mode {
    /// The paper's contribution: priority preemption + inter-kernel gap
    /// filling driven by offline profiles.
    #[default]
    Fikit,
    /// NVIDIA default time-slice sharing: one FIFO device queue, kernels
    /// interleave in launch order, no priorities, no preemption.
    Sharing,
    /// NVIDIA exclusive mode: one task owns the GPU at a time; tasks are
    /// serialized in arrival order by an external orchestrator.
    Exclusive,
    /// The paper's §5 "software-defined GPU exclusive mode": multiple
    /// services may be allocated to the GPU, but exactly one task runs
    /// at a time — selected by *priority* (then arrival), not arrival
    /// order. Built on the FIKIT allocation machinery without gap
    /// filling.
    SoftExclusive,
}

impl std::fmt::Display for Mode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Mode::Fikit => write!(f, "fikit"),
            Mode::Sharing => write!(f, "sharing"),
            Mode::Exclusive => write!(f, "exclusive"),
            Mode::SoftExclusive => write!(f, "soft-exclusive"),
        }
    }
}

impl std::str::FromStr for Mode {
    type Err = crate::core::Error;
    fn from_str(s: &str) -> crate::core::Result<Mode> {
        match s.to_ascii_lowercase().as_str() {
            "fikit" => Ok(Mode::Fikit),
            "sharing" | "share" | "default" => Ok(Mode::Sharing),
            "exclusive" => Ok(Mode::Exclusive),
            "soft-exclusive" | "softexclusive" | "soft_exclusive" => Ok(Mode::SoftExclusive),
            other => Err(crate::core::Error::Parse(format!("unknown mode: {other:?}"))),
        }
    }
}
