//! **Algorithm 2 — `BestPrioFit`**: the sharing-stage idling-gap filling
//! policy (paper Fig 10).
//!
//! Given a remaining idle duration, scan priorities Q0 → Q9; at the first
//! priority level holding at least one request whose *profiled* duration
//! (`SK`) fits the gap, select the request with the **longest** fitting
//! duration, remove it from its queue, and return it together with its
//! predicted duration. Lower priority levels are only examined when no
//! request at a higher level fits ("best fit" = highest priority first,
//! then closest-to-gap among candidates of that priority).
//!
//! Predictions are resolved **once at enqueue time** (from the service's
//! attach-time [`crate::profile::ResolvedProfile`]); selection here is a
//! binary search over each lane's duration-ordered fit index — O(log n)
//! per level, no hashing, no allocation (DESIGN.md §Perf). Requests with
//! no prediction (unprofiled tasks) are invisible to the index and thus
//! never gamble a high-priority task's gap.

use super::fikit::{PreemptionPolicy, DEFAULT_SPLIT_SLICE};
use super::queues::PriorityQueues;
use crate::core::{Duration, KernelLaunch, Priority, SimTime};

/// The selection made by one `BestPrioFit` invocation.
#[derive(Debug, Clone)]
pub struct Fit {
    pub launch: KernelLaunch,
    /// The profiled (predicted) execution duration `SK` used to charge
    /// the fill budget — NOT the true duration, which the scheduler
    /// cannot know.
    pub predicted: Duration,
}

/// Within-priority selection rule for gap filling. The paper's
/// Algorithm 2 uses LongestFit; the alternatives are kept as explicit
/// ablations (bench `ablation_fill_policy`) for the design-choice
/// analysis in DESIGN.md §Perf.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FillPolicy {
    /// Paper Algorithm 2: the longest request that still fits (maximizes
    /// utilization per BestPrioFit invocation).
    #[default]
    LongestFit,
    /// The first (oldest) fitting request — FIFO fairness, cheapest scan.
    FirstFit,
    /// The shortest fitting request — minimizes overrun risk at the cost
    /// of utilization.
    ShortestFit,
}

impl std::str::FromStr for FillPolicy {
    type Err = crate::core::Error;
    fn from_str(s: &str) -> crate::core::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "longest" | "longest-fit" | "best" => Ok(FillPolicy::LongestFit),
            "first" | "first-fit" => Ok(FillPolicy::FirstFit),
            "shortest" | "shortest-fit" => Ok(FillPolicy::ShortestFit),
            other => Err(crate::core::Error::Parse(format!(
                "unknown fill policy {other:?}"
            ))),
        }
    }
}

/// Run Algorithm 2 over the message queues (paper policy: LongestFit).
pub fn best_prio_fit(queues: &mut PriorityQueues, idle_time: Duration) -> Option<Fit> {
    select_fit(queues, idle_time, FillPolicy::LongestFit)
}

/// Policy-parameterized variant of Algorithm 2.
pub fn select_fit(
    queues: &mut PriorityQueues,
    idle_time: Duration,
    policy: FillPolicy,
) -> Option<Fit> {
    if idle_time.is_zero() {
        return None;
    }
    // From the highest priority to the lowest (Algorithm 2, line 5); the
    // first level with a fitting candidate wins — lower priorities are
    // not considered (lines 20-23). The strict `predicted < idle_time`
    // bound (line 13) lives in the lane selectors.
    for priority in Priority::ALL {
        let taken = match policy {
            FillPolicy::LongestFit => queues.take_longest_fit_at(priority, idle_time),
            FillPolicy::FirstFit => queues.take_first_fit_at(priority, idle_time),
            FillPolicy::ShortestFit => queues.take_shortest_fit_at(priority, idle_time),
        };
        if let Some((req, predicted)) = taken {
            return Some(Fit {
                launch: req.launch,
                predicted,
            });
        }
    }
    None
}

/// What [`plan_preempt`] decided for one in-flight fill kernel.
///
/// Pure geometry over `(ready, started_at, finished_at)`; the caller
/// (the driver's preempt probe) owns the economics — it only invokes the
/// planner when the high-priority launch would miss its gap by more than
/// the modeled preemption cost, and only commits a cut that strictly
/// improves the projected start.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PreemptAction {
    /// Leave the kernel alone.
    Skip,
    /// Not yet started at `ready`: cancel it whole (cut at its start —
    /// no executed work exists, nothing is wasted).
    Cancel,
    /// Evict mid-flight at `cut_at` (= `ready`): the executed prefix is
    /// wasted and the *full* kernel re-queues.
    Cut { cut_at: SimTime },
    /// Shorten at the slice boundary `cut_at`: the executed prefix is
    /// kept and the remnant re-queues with its remaining duration.
    Split { cut_at: SimTime },
}

/// Decide how an in-flight fill kernel `(started_at, finished_at)` yields
/// to a high-priority launch that becomes runnable at `ready`
/// (DESIGN.md §8).
///
/// * not started by `ready` → [`PreemptAction::Cancel`] under every
///   active policy (rolling back an unstarted kernel is free);
/// * running under `Evict` → cut right at `ready`, wasting the prefix;
/// * running under `Split { min_slice }` → cut at the first boundary
///   `started_at + k·min_slice ≥ ready` that still precedes the natural
///   finish (otherwise the kernel is nearly done — let it run);
/// * running under `Hybrid { threshold }` → evict while the executed
///   fraction at `ready` is below `threshold`, split (at the default
///   slice granularity) once enough work has accumulated to be worth
///   keeping.
pub fn plan_preempt(
    policy: PreemptionPolicy,
    ready: SimTime,
    started_at: SimTime,
    finished_at: SimTime,
) -> PreemptAction {
    if policy == PreemptionPolicy::None || ready >= finished_at {
        return PreemptAction::Skip;
    }
    if ready <= started_at {
        return PreemptAction::Cancel;
    }
    let split_at = |min_slice: Duration| -> PreemptAction {
        // First slice boundary at or after `ready`: ceil((ready-start)/slice).
        let elapsed = (ready - started_at).nanos();
        let slice = min_slice.nanos().max(1);
        let k = ((elapsed + slice - 1) / slice).max(1);
        let cut_at = started_at + Duration(k * slice);
        if cut_at >= finished_at {
            PreemptAction::Skip
        } else {
            PreemptAction::Split { cut_at }
        }
    };
    match policy {
        PreemptionPolicy::None => PreemptAction::Skip, // unreachable (early return)
        PreemptionPolicy::Evict => PreemptAction::Cut { cut_at: ready },
        PreemptionPolicy::Split { min_slice } => split_at(min_slice),
        PreemptionPolicy::Hybrid { threshold } => {
            let executed = (ready - started_at).nanos() as f64;
            let total = (finished_at - started_at).nanos().max(1) as f64;
            if executed / total < threshold {
                PreemptAction::Cut { cut_at: ready }
            } else {
                split_at(DEFAULT_SPLIT_SLICE)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dim3, KernelHandle, KernelId, SimTime, TaskHandle, TaskId, TaskKey};

    fn kid(name: &str) -> KernelId {
        KernelId::new(name, Dim3::x(8), Dim3::x(128))
    }

    fn launch(key: &str, kernel: &str, prio: Priority) -> KernelLaunch {
        KernelLaunch {
            task_key: TaskKey::new(key),
            task_handle: TaskHandle::UNBOUND,
            task_id: TaskId(0),
            kernel: kid(kernel),
            kernel_handle: KernelHandle::UNBOUND,
            priority: prio,
            seq: 0,
            true_duration: Duration::from_micros(999), // scheduler must not read this
            issued_at: SimTime::ZERO,
        }
    }

    /// Enqueue with the prediction pre-resolved, as the scheduler does.
    fn push(q: &mut PriorityQueues, key: &str, kernel: &str, prio: Priority, us: u64) {
        q.push_predicted(
            launch(key, kernel, prio),
            Some(Duration::from_micros(us)),
            SimTime::ZERO,
        );
    }

    #[test]
    fn picks_longest_fit_within_priority() {
        let mut q = PriorityQueues::new();
        push(&mut q, "a", "short", Priority::P5, 100);
        push(&mut q, "a", "long", Priority::P5, 400);
        push(&mut q, "a", "toolong", Priority::P5, 900);

        let fit = best_prio_fit(&mut q, Duration::from_micros(500)).unwrap();
        assert_eq!(fit.launch.kernel.name.as_ref(), "long");
        assert_eq!(fit.predicted, Duration::from_micros(400));
        assert_eq!(q.len(), 2); // selected request removed, others kept
    }

    #[test]
    fn higher_priority_wins_even_if_shorter() {
        let mut q = PriorityQueues::new();
        push(&mut q, "hi", "small", Priority::P1, 50);
        push(&mut q, "lo", "big", Priority::P7, 450);

        let fit = best_prio_fit(&mut q, Duration::from_micros(500)).unwrap();
        assert_eq!(fit.launch.task_key, TaskKey::new("hi"));
    }

    #[test]
    fn falls_through_to_lower_priority_when_nothing_fits() {
        let mut q = PriorityQueues::new();
        push(&mut q, "hi", "huge", Priority::P1, 2_000);
        push(&mut q, "lo", "small", Priority::P7, 100);

        let fit = best_prio_fit(&mut q, Duration::from_micros(500)).unwrap();
        assert_eq!(fit.launch.task_key, TaskKey::new("lo"));
        // The non-fitting high-priority request stays queued.
        assert_eq!(q.len_at(Priority::P1), 1);
    }

    #[test]
    fn strict_fit_boundary() {
        // predicted must be strictly less than idle (line 13).
        let mut q = PriorityQueues::new();
        push(&mut q, "a", "exact", Priority::P3, 500);
        assert!(best_prio_fit(&mut q, Duration::from_micros(500)).is_none());
        assert!(best_prio_fit(&mut q, Duration::from_micros(501)).is_some());
    }

    #[test]
    fn unprofiled_requests_are_skipped() {
        let mut q = PriorityQueues::new();
        // "unknown" has no profile → enqueued without a prediction.
        q.push(launch("unknown", "k", Priority::P2), SimTime::ZERO);
        push(&mut q, "known", "k", Priority::P6, 100);
        let fit = best_prio_fit(&mut q, Duration::from_micros(500)).unwrap();
        assert_eq!(fit.launch.task_key, TaskKey::new("known"));
        // The unprofiled one is left in place.
        assert_eq!(q.len_at(Priority::P2), 1);
    }

    #[test]
    fn fill_policy_variants() {
        use super::FillPolicy;
        let build = || {
            let mut q = PriorityQueues::new();
            push(&mut q, "a", "mid", Priority::P5, 250);
            push(&mut q, "a", "short", Priority::P5, 100);
            push(&mut q, "a", "long", Priority::P5, 400);
            q
        };
        let idle = Duration::from_micros(500);

        let fit = select_fit(&mut build(), idle, FillPolicy::LongestFit).unwrap();
        assert_eq!(fit.launch.kernel.name.as_ref(), "long");
        let fit = select_fit(&mut build(), idle, FillPolicy::FirstFit).unwrap();
        assert_eq!(fit.launch.kernel.name.as_ref(), "mid"); // FIFO head
        let fit = select_fit(&mut build(), idle, FillPolicy::ShortestFit).unwrap();
        assert_eq!(fit.launch.kernel.name.as_ref(), "short");

        // All policies respect the fit bound.
        let tiny = Duration::from_micros(50);
        for policy in [FillPolicy::LongestFit, FillPolicy::FirstFit, FillPolicy::ShortestFit] {
            assert!(select_fit(&mut build(), tiny, policy).is_none());
        }
        assert!("longest".parse::<FillPolicy>().is_ok());
        assert!("bogus".parse::<FillPolicy>().is_err());
    }

    #[test]
    fn empty_queues_or_zero_idle_yield_none() {
        let mut q = PriorityQueues::new();
        assert!(best_prio_fit(&mut q, Duration::from_micros(100)).is_none());
        push(&mut q, "a", "k", Priority::P1, 10);
        assert!(best_prio_fit(&mut q, Duration::ZERO).is_none());
    }

    // --- plan_preempt geometry ---

    const START: SimTime = SimTime(1_000_000); // 1 ms
    const FINISH: SimTime = SimTime(2_000_000); // 1 ms kernel

    #[test]
    fn plan_none_always_skips() {
        for ready_ns in [0u64, 1_000_000, 1_500_000, 2_000_000] {
            assert_eq!(
                plan_preempt(PreemptionPolicy::None, SimTime(ready_ns), START, FINISH),
                PreemptAction::Skip
            );
        }
    }

    #[test]
    fn plan_unstarted_kernels_cancel_whole() {
        for policy in [
            PreemptionPolicy::Evict,
            PreemptionPolicy::split(),
            PreemptionPolicy::hybrid(),
        ] {
            assert_eq!(
                plan_preempt(policy, SimTime(500_000), START, FINISH),
                PreemptAction::Cancel,
                "{policy}: ready before start"
            );
            assert_eq!(
                plan_preempt(policy, START, START, FINISH),
                PreemptAction::Cancel,
                "{policy}: ready exactly at start"
            );
        }
    }

    #[test]
    fn plan_finished_kernels_are_left_alone() {
        for policy in [
            PreemptionPolicy::Evict,
            PreemptionPolicy::split(),
            PreemptionPolicy::hybrid(),
        ] {
            assert_eq!(plan_preempt(policy, FINISH, START, FINISH), PreemptAction::Skip);
            assert_eq!(
                plan_preempt(policy, SimTime(9_000_000), START, FINISH),
                PreemptAction::Skip
            );
        }
    }

    #[test]
    fn plan_evict_cuts_at_ready() {
        let ready = SimTime(1_300_000);
        assert_eq!(
            plan_preempt(PreemptionPolicy::Evict, ready, START, FINISH),
            PreemptAction::Cut { cut_at: ready }
        );
    }

    #[test]
    fn plan_split_snaps_to_next_slice_boundary() {
        let policy = PreemptionPolicy::Split {
            min_slice: Duration::from_micros(250),
        };
        // Ready 300 µs in → next boundary is 500 µs after start.
        assert_eq!(
            plan_preempt(policy, SimTime(1_300_000), START, FINISH),
            PreemptAction::Split { cut_at: SimTime(1_500_000) }
        );
        // Ready exactly on a boundary cuts there.
        assert_eq!(
            plan_preempt(policy, SimTime(1_500_000), START, FINISH),
            PreemptAction::Split { cut_at: SimTime(1_500_000) }
        );
        // No boundary left before the natural finish → let it run.
        assert_eq!(
            plan_preempt(policy, SimTime(1_900_000), START, FINISH),
            PreemptAction::Skip
        );
        // Boundary == finish is not a cut either.
        assert_eq!(
            plan_preempt(policy, SimTime(1_750_001), START, FINISH),
            PreemptAction::Skip
        );
    }

    #[test]
    fn plan_hybrid_evicts_young_and_splits_old() {
        let policy = PreemptionPolicy::Hybrid { threshold: 0.5 };
        // 30% executed < 50% → cheap to discard.
        assert_eq!(
            plan_preempt(policy, SimTime(1_300_000), START, FINISH),
            PreemptAction::Cut { cut_at: SimTime(1_300_000) }
        );
        // 60% executed ≥ 50% → keep the prefix, cut at the next default
        // slice boundary (250 µs grid → 750 µs after start).
        assert_eq!(
            plan_preempt(policy, SimTime(1_600_000), START, FINISH),
            PreemptAction::Split { cut_at: SimTime(1_750_000) }
        );
    }
}
