//! **Algorithm 2 — `BestPrioFit`**: the sharing-stage idling-gap filling
//! policy (paper Fig 10).
//!
//! Given a remaining idle duration, scan priorities Q0 → Q9; at the first
//! priority level holding at least one request whose *profiled* duration
//! (`SK`) fits the gap, select the request with the **longest** fitting
//! duration, remove it from its queue, and return it together with its
//! predicted duration. Lower priority levels are only examined when no
//! request at a higher level fits ("best fit" = highest priority first,
//! then closest-to-gap among candidates of that priority).
//!
//! Predictions are resolved **once at enqueue time** (from the service's
//! attach-time [`crate::profile::ResolvedProfile`]); selection here is a
//! binary search over each lane's duration-ordered fit index — O(log n)
//! per level, no hashing, no allocation (DESIGN.md §Perf). Requests with
//! no prediction (unprofiled tasks) are invisible to the index and thus
//! never gamble a high-priority task's gap.

use super::queues::PriorityQueues;
use crate::core::{Duration, KernelLaunch, Priority};

/// The selection made by one `BestPrioFit` invocation.
#[derive(Debug, Clone)]
pub struct Fit {
    pub launch: KernelLaunch,
    /// The profiled (predicted) execution duration `SK` used to charge
    /// the fill budget — NOT the true duration, which the scheduler
    /// cannot know.
    pub predicted: Duration,
}

/// Within-priority selection rule for gap filling. The paper's
/// Algorithm 2 uses LongestFit; the alternatives are kept as explicit
/// ablations (bench `ablation_fill_policy`) for the design-choice
/// analysis in DESIGN.md §Perf.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FillPolicy {
    /// Paper Algorithm 2: the longest request that still fits (maximizes
    /// utilization per BestPrioFit invocation).
    #[default]
    LongestFit,
    /// The first (oldest) fitting request — FIFO fairness, cheapest scan.
    FirstFit,
    /// The shortest fitting request — minimizes overrun risk at the cost
    /// of utilization.
    ShortestFit,
}

impl std::str::FromStr for FillPolicy {
    type Err = crate::core::Error;
    fn from_str(s: &str) -> crate::core::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "longest" | "longest-fit" | "best" => Ok(FillPolicy::LongestFit),
            "first" | "first-fit" => Ok(FillPolicy::FirstFit),
            "shortest" | "shortest-fit" => Ok(FillPolicy::ShortestFit),
            other => Err(crate::core::Error::Parse(format!(
                "unknown fill policy {other:?}"
            ))),
        }
    }
}

/// Run Algorithm 2 over the message queues (paper policy: LongestFit).
pub fn best_prio_fit(queues: &mut PriorityQueues, idle_time: Duration) -> Option<Fit> {
    select_fit(queues, idle_time, FillPolicy::LongestFit)
}

/// Policy-parameterized variant of Algorithm 2.
pub fn select_fit(
    queues: &mut PriorityQueues,
    idle_time: Duration,
    policy: FillPolicy,
) -> Option<Fit> {
    if idle_time.is_zero() {
        return None;
    }
    // From the highest priority to the lowest (Algorithm 2, line 5); the
    // first level with a fitting candidate wins — lower priorities are
    // not considered (lines 20-23). The strict `predicted < idle_time`
    // bound (line 13) lives in the lane selectors.
    for priority in Priority::ALL {
        let taken = match policy {
            FillPolicy::LongestFit => queues.take_longest_fit_at(priority, idle_time),
            FillPolicy::FirstFit => queues.take_first_fit_at(priority, idle_time),
            FillPolicy::ShortestFit => queues.take_shortest_fit_at(priority, idle_time),
        };
        if let Some((req, predicted)) = taken {
            return Some(Fit {
                launch: req.launch,
                predicted,
            });
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dim3, KernelHandle, KernelId, SimTime, TaskHandle, TaskId, TaskKey};

    fn kid(name: &str) -> KernelId {
        KernelId::new(name, Dim3::x(8), Dim3::x(128))
    }

    fn launch(key: &str, kernel: &str, prio: Priority) -> KernelLaunch {
        KernelLaunch {
            task_key: TaskKey::new(key),
            task_handle: TaskHandle::UNBOUND,
            task_id: TaskId(0),
            kernel: kid(kernel),
            kernel_handle: KernelHandle::UNBOUND,
            priority: prio,
            seq: 0,
            true_duration: Duration::from_micros(999), // scheduler must not read this
            issued_at: SimTime::ZERO,
        }
    }

    /// Enqueue with the prediction pre-resolved, as the scheduler does.
    fn push(q: &mut PriorityQueues, key: &str, kernel: &str, prio: Priority, us: u64) {
        q.push_predicted(
            launch(key, kernel, prio),
            Some(Duration::from_micros(us)),
            SimTime::ZERO,
        );
    }

    #[test]
    fn picks_longest_fit_within_priority() {
        let mut q = PriorityQueues::new();
        push(&mut q, "a", "short", Priority::P5, 100);
        push(&mut q, "a", "long", Priority::P5, 400);
        push(&mut q, "a", "toolong", Priority::P5, 900);

        let fit = best_prio_fit(&mut q, Duration::from_micros(500)).unwrap();
        assert_eq!(fit.launch.kernel.name.as_ref(), "long");
        assert_eq!(fit.predicted, Duration::from_micros(400));
        assert_eq!(q.len(), 2); // selected request removed, others kept
    }

    #[test]
    fn higher_priority_wins_even_if_shorter() {
        let mut q = PriorityQueues::new();
        push(&mut q, "hi", "small", Priority::P1, 50);
        push(&mut q, "lo", "big", Priority::P7, 450);

        let fit = best_prio_fit(&mut q, Duration::from_micros(500)).unwrap();
        assert_eq!(fit.launch.task_key, TaskKey::new("hi"));
    }

    #[test]
    fn falls_through_to_lower_priority_when_nothing_fits() {
        let mut q = PriorityQueues::new();
        push(&mut q, "hi", "huge", Priority::P1, 2_000);
        push(&mut q, "lo", "small", Priority::P7, 100);

        let fit = best_prio_fit(&mut q, Duration::from_micros(500)).unwrap();
        assert_eq!(fit.launch.task_key, TaskKey::new("lo"));
        // The non-fitting high-priority request stays queued.
        assert_eq!(q.len_at(Priority::P1), 1);
    }

    #[test]
    fn strict_fit_boundary() {
        // predicted must be strictly less than idle (line 13).
        let mut q = PriorityQueues::new();
        push(&mut q, "a", "exact", Priority::P3, 500);
        assert!(best_prio_fit(&mut q, Duration::from_micros(500)).is_none());
        assert!(best_prio_fit(&mut q, Duration::from_micros(501)).is_some());
    }

    #[test]
    fn unprofiled_requests_are_skipped() {
        let mut q = PriorityQueues::new();
        // "unknown" has no profile → enqueued without a prediction.
        q.push(launch("unknown", "k", Priority::P2), SimTime::ZERO);
        push(&mut q, "known", "k", Priority::P6, 100);
        let fit = best_prio_fit(&mut q, Duration::from_micros(500)).unwrap();
        assert_eq!(fit.launch.task_key, TaskKey::new("known"));
        // The unprofiled one is left in place.
        assert_eq!(q.len_at(Priority::P2), 1);
    }

    #[test]
    fn fill_policy_variants() {
        use super::FillPolicy;
        let build = || {
            let mut q = PriorityQueues::new();
            push(&mut q, "a", "mid", Priority::P5, 250);
            push(&mut q, "a", "short", Priority::P5, 100);
            push(&mut q, "a", "long", Priority::P5, 400);
            q
        };
        let idle = Duration::from_micros(500);

        let fit = select_fit(&mut build(), idle, FillPolicy::LongestFit).unwrap();
        assert_eq!(fit.launch.kernel.name.as_ref(), "long");
        let fit = select_fit(&mut build(), idle, FillPolicy::FirstFit).unwrap();
        assert_eq!(fit.launch.kernel.name.as_ref(), "mid"); // FIFO head
        let fit = select_fit(&mut build(), idle, FillPolicy::ShortestFit).unwrap();
        assert_eq!(fit.launch.kernel.name.as_ref(), "short");

        // All policies respect the fit bound.
        let tiny = Duration::from_micros(50);
        for policy in [FillPolicy::LongestFit, FillPolicy::FirstFit, FillPolicy::ShortestFit] {
            assert!(select_fit(&mut build(), tiny, policy).is_none());
        }
        assert!("longest".parse::<FillPolicy>().is_ok());
        assert!("bogus".parse::<FillPolicy>().is_err());
    }

    #[test]
    fn empty_queues_or_zero_idle_yield_none() {
        let mut q = PriorityQueues::new();
        assert!(best_prio_fit(&mut q, Duration::from_micros(100)).is_none());
        push(&mut q, "a", "k", Priority::P1, 10);
        assert!(best_prio_fit(&mut q, Duration::ZERO).is_none());
    }
}
