//! **Algorithm 2 — `BestPrioFit`**: the sharing-stage idling-gap filling
//! policy (paper Fig 10).
//!
//! Given a remaining idle duration, scan priorities Q0 → Q9; at the first
//! priority level holding at least one request whose *profiled* duration
//! (`SK`) fits the gap, select the request with the **longest** fitting
//! duration, remove it from its queue, and return it together with its
//! predicted duration. Lower priority levels are only examined when no
//! request at a higher level fits ("best fit" = highest priority first,
//! then closest-to-gap among candidates of that priority).

use super::queues::PriorityQueues;
use crate::core::{Duration, KernelLaunch, Priority};
use crate::profile::ProfileStore;

/// The selection made by one `BestPrioFit` invocation.
#[derive(Debug, Clone)]
pub struct Fit {
    pub launch: KernelLaunch,
    /// The profiled (predicted) execution duration `SK` used to charge
    /// the fill budget — NOT the true duration, which the scheduler
    /// cannot know.
    pub predicted: Duration,
}

/// Within-priority selection rule for gap filling. The paper's
/// Algorithm 2 uses LongestFit; the alternatives are kept as explicit
/// ablations (bench `ablation_fill_policy`) for the design-choice
/// analysis in DESIGN.md §Perf.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FillPolicy {
    /// Paper Algorithm 2: the longest request that still fits (maximizes
    /// utilization per BestPrioFit invocation).
    #[default]
    LongestFit,
    /// The first (oldest) fitting request — FIFO fairness, cheapest scan.
    FirstFit,
    /// The shortest fitting request — minimizes overrun risk at the cost
    /// of utilization.
    ShortestFit,
}

impl std::str::FromStr for FillPolicy {
    type Err = crate::core::Error;
    fn from_str(s: &str) -> crate::core::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "longest" | "longest-fit" | "best" => Ok(FillPolicy::LongestFit),
            "first" | "first-fit" => Ok(FillPolicy::FirstFit),
            "shortest" | "shortest-fit" => Ok(FillPolicy::ShortestFit),
            other => Err(crate::core::Error::Parse(format!(
                "unknown fill policy {other:?}"
            ))),
        }
    }
}

/// Run Algorithm 2 over the message queues (paper policy: LongestFit).
///
/// Requests whose task has no profile, or whose kernel id was never seen
/// during measurement, are skipped — the scheduler cannot predict their
/// duration, so it must not gamble a high-priority task's gap on them.
pub fn best_prio_fit(
    queues: &mut PriorityQueues,
    idle_time: Duration,
    profiles: &ProfileStore,
) -> Option<Fit> {
    select_fit(queues, idle_time, profiles, FillPolicy::LongestFit)
}

/// Policy-parameterized variant of Algorithm 2.
pub fn select_fit(
    queues: &mut PriorityQueues,
    idle_time: Duration,
    profiles: &ProfileStore,
    policy: FillPolicy,
) -> Option<Fit> {
    if idle_time.is_zero() {
        return None;
    }
    // From the highest priority to the lowest (Algorithm 2, line 5).
    for priority in Priority::ALL {
        let mut best_time = Duration::ZERO;
        let mut best_idx: Option<usize> = None;
        let mut shortest = Duration(u64::MAX);
        // Examine every kernel request at this priority (line 7). The
        // profiled duration was resolved at enqueue time; fall back to a
        // store lookup only for requests enqueued without one.
        for (idx, req) in queues.iter_at(priority).enumerate() {
            let predicted = match req.predicted {
                Some(p) => p,
                None => {
                    let Some(p) = profiles
                        .get(&req.launch.task_key)
                        .and_then(|prof| prof.sk(&req.launch.kernel))
                    else {
                        continue;
                    };
                    p
                }
            };
            if predicted >= idle_time {
                continue; // does not fit the gap
            }
            match policy {
                // Longest so far AND fits (Algorithm 2 line 13:
                // bestKernelTime < predictedKernelTime < idleTime).
                FillPolicy::LongestFit => {
                    if predicted > best_time {
                        best_time = predicted;
                        best_idx = Some(idx);
                    }
                }
                FillPolicy::FirstFit => {
                    best_time = predicted;
                    best_idx = Some(idx);
                    break;
                }
                FillPolicy::ShortestFit => {
                    if predicted < shortest {
                        shortest = predicted;
                        best_time = predicted;
                        best_idx = Some(idx);
                    }
                }
            }
        }
        // Found the longest fitting kernel at this priority level: stop —
        // lower priorities are not considered (line 20-23).
        if let Some(idx) = best_idx {
            let req = queues
                .remove_at(priority, idx)
                .expect("index valid: found during scan");
            return Some(Fit {
                launch: req.launch,
                predicted: best_time,
            });
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dim3, KernelId, SimTime, TaskId, TaskKey};
    use crate::profile::TaskProfile;

    fn kid(name: &str) -> KernelId {
        KernelId::new(name, Dim3::x(8), Dim3::x(128))
    }

    fn launch(key: &str, kernel: &str, prio: Priority) -> KernelLaunch {
        KernelLaunch {
            task_key: TaskKey::new(key),
            task_id: TaskId(0),
            kernel: kid(kernel),
            priority: prio,
            seq: 0,
            true_duration: Duration::from_micros(999), // scheduler must not read this
            issued_at: SimTime::ZERO,
        }
    }

    /// Store with one profile per (key, kernel → duration µs) entry.
    fn store(entries: &[(&str, &str, u64)]) -> ProfileStore {
        let mut s = ProfileStore::new();
        for (key, kernel, us) in entries {
            let tk = TaskKey::new(*key);
            let mut p = s.remove(&tk).unwrap_or_else(|| TaskProfile::new(tk));
            p.record(&kid(kernel), Duration::from_micros(*us), None);
            p.finish_run(1);
            s.insert(p);
        }
        s
    }

    #[test]
    fn picks_longest_fit_within_priority() {
        let mut q = PriorityQueues::new();
        q.push(launch("a", "short", Priority::P5), SimTime::ZERO);
        q.push(launch("a", "long", Priority::P5), SimTime::ZERO);
        q.push(launch("a", "toolong", Priority::P5), SimTime::ZERO);
        let s = store(&[("a", "short", 100), ("a", "long", 400), ("a", "toolong", 900)]);

        let fit = best_prio_fit(&mut q, Duration::from_micros(500), &s).unwrap();
        assert_eq!(fit.launch.kernel.name.as_ref(), "long");
        assert_eq!(fit.predicted, Duration::from_micros(400));
        assert_eq!(q.len(), 2); // selected request removed, others kept
    }

    #[test]
    fn higher_priority_wins_even_if_shorter() {
        let mut q = PriorityQueues::new();
        q.push(launch("hi", "small", Priority::P1), SimTime::ZERO);
        q.push(launch("lo", "big", Priority::P7), SimTime::ZERO);
        let s = store(&[("hi", "small", 50), ("lo", "big", 450)]);

        let fit = best_prio_fit(&mut q, Duration::from_micros(500), &s).unwrap();
        assert_eq!(fit.launch.task_key, TaskKey::new("hi"));
    }

    #[test]
    fn falls_through_to_lower_priority_when_nothing_fits() {
        let mut q = PriorityQueues::new();
        q.push(launch("hi", "huge", Priority::P1), SimTime::ZERO);
        q.push(launch("lo", "small", Priority::P7), SimTime::ZERO);
        let s = store(&[("hi", "huge", 2_000), ("lo", "small", 100)]);

        let fit = best_prio_fit(&mut q, Duration::from_micros(500), &s).unwrap();
        assert_eq!(fit.launch.task_key, TaskKey::new("lo"));
        // The non-fitting high-priority request stays queued.
        assert_eq!(q.len_at(Priority::P1), 1);
    }

    #[test]
    fn strict_fit_boundary() {
        // predicted must be strictly less than idle (line 13).
        let mut q = PriorityQueues::new();
        q.push(launch("a", "exact", Priority::P3), SimTime::ZERO);
        let s = store(&[("a", "exact", 500)]);
        assert!(best_prio_fit(&mut q, Duration::from_micros(500), &s).is_none());
        assert!(best_prio_fit(&mut q, Duration::from_micros(501), &s).is_some());
    }

    #[test]
    fn unprofiled_requests_are_skipped() {
        let mut q = PriorityQueues::new();
        q.push(launch("unknown", "k", Priority::P2), SimTime::ZERO);
        q.push(launch("known", "k", Priority::P6), SimTime::ZERO);
        let s = store(&[("known", "k", 100)]);
        let fit = best_prio_fit(&mut q, Duration::from_micros(500), &s).unwrap();
        assert_eq!(fit.launch.task_key, TaskKey::new("known"));
        // The unprofiled one is left in place.
        assert_eq!(q.len_at(Priority::P2), 1);
    }

    #[test]
    fn fill_policy_variants() {
        use super::FillPolicy;
        let build = || {
            let mut q = PriorityQueues::new();
            q.push(launch("a", "mid", Priority::P5), SimTime::ZERO);
            q.push(launch("a", "short", Priority::P5), SimTime::ZERO);
            q.push(launch("a", "long", Priority::P5), SimTime::ZERO);
            q
        };
        let s = store(&[("a", "mid", 250), ("a", "short", 100), ("a", "long", 400)]);
        let idle = Duration::from_micros(500);

        let fit = select_fit(&mut build(), idle, &s, FillPolicy::LongestFit).unwrap();
        assert_eq!(fit.launch.kernel.name.as_ref(), "long");
        let fit = select_fit(&mut build(), idle, &s, FillPolicy::FirstFit).unwrap();
        assert_eq!(fit.launch.kernel.name.as_ref(), "mid"); // FIFO head
        let fit = select_fit(&mut build(), idle, &s, FillPolicy::ShortestFit).unwrap();
        assert_eq!(fit.launch.kernel.name.as_ref(), "short");

        // All policies respect the fit bound.
        let tiny = Duration::from_micros(50);
        for policy in [FillPolicy::LongestFit, FillPolicy::FirstFit, FillPolicy::ShortestFit] {
            assert!(select_fit(&mut build(), tiny, &s, policy).is_none());
        }
        assert!("longest".parse::<FillPolicy>().is_ok());
        assert!("bogus".parse::<FillPolicy>().is_err());
    }

    #[test]
    fn empty_queues_or_zero_idle_yield_none() {
        let mut q = PriorityQueues::new();
        let s = store(&[]);
        assert!(best_prio_fit(&mut q, Duration::from_micros(100), &s).is_none());
        q.push(launch("a", "k", Priority::P1), SimTime::ZERO);
        let s = store(&[("a", "k", 10)]);
        assert!(best_prio_fit(&mut q, Duration::ZERO, &s).is_none());
    }
}
