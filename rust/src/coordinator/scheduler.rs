//! The FIKIT scheduler: GPU-holder tracking, launch routing (the three
//! cases of Fig 11), window management and queue dispatch.
//!
//! ## Holder model
//!
//! The *holder* is the highest-priority task currently mid-invocation
//! (ties broken by acquisition order). Holder launches go **direct** to
//! the device; lower-priority launches are queued in Q0–Q9 and only reach
//! the device through gap filling (Algorithm 1) or when the holder
//! changes. Equal-priority launches also go direct — the paper's case C
//! degrades to default FIFO sharing among equals.
//!
//! This single rule yields all three Fig 11 cases:
//!
//! * **Case A** (running low-prio A, high-prio B arrives): B's task start
//!   makes B the holder; A's *next* launch is now lower-priority → queued
//!   → A proceeds only inside B's gaps. Priority inversion solved at
//!   kernel granularity (the in-flight kernel finishes; kernels are not
//!   preempted mid-execution).
//! * **Case B** (running high-prio A, low-prio B arrives): A stays
//!   holder, B is queued and gap-filled.
//! * **Case C** (equal priorities): both launch direct, FIFO interleave.

use super::best_prio_fit::{FillPolicy, Fit};
use super::feedback::{FeedbackController, FeedbackStats};
use super::fikit::{fikit_fill_with, FillWindow};
use super::queues::PriorityQueues;
use crate::core::{
    Duration, KernelLaunch, KernelRecord, LaunchSource, Priority, SimTime, TaskKey,
};
use crate::profile::ProfileStore;

/// Scheduler tuning knobs.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Small-gap threshold ε (Algorithm 1).
    pub epsilon: Duration,
    /// Runtime feedback early stop (Fig 12). Disable only for ablations.
    pub feedback: bool,
    /// Within-priority fill selection rule (paper: LongestFit).
    pub fill_policy: FillPolicy,
}

impl Default for SchedulerConfig {
    fn default() -> SchedulerConfig {
        SchedulerConfig {
            epsilon: super::fikit::DEFAULT_EPSILON,
            feedback: true,
            fill_policy: FillPolicy::LongestFit,
        }
    }
}

/// Counters exposed for experiments and perf work.
#[derive(Debug, Clone, Default)]
pub struct SchedulerStats {
    /// Launches routed straight to the device (holder / equal priority).
    pub direct: u64,
    /// Launches parked in the priority queues.
    pub queued: u64,
    /// Kernels launched as gap fills.
    pub fills: u64,
    /// Kernels dispatched when the holder changed.
    pub drained: u64,
    /// Holder changes caused by a higher-priority task starting.
    pub preemptions: u64,
    /// Feedback telemetry.
    pub feedback: FeedbackStats,
}

/// A launch the scheduler wants submitted to the device, with its source
/// tag (direct / gap fill / drain).
#[derive(Debug, Clone)]
pub struct Submission {
    pub launch: KernelLaunch,
    pub source: LaunchSource,
}

#[derive(Debug, Clone)]
struct ActiveTask {
    key: TaskKey,
    priority: Priority,
    acquired: u64,
}

/// The sharing-stage FIKIT scheduler.
pub struct FikitScheduler {
    cfg: SchedulerConfig,
    queues: PriorityQueues,
    window: Option<FillWindow>,
    feedback: FeedbackController,
    active: Vec<ActiveTask>,
    acquire_seq: u64,
    stats: SchedulerStats,
}

impl FikitScheduler {
    pub fn new(cfg: SchedulerConfig) -> FikitScheduler {
        let feedback = FeedbackController::new(cfg.feedback);
        FikitScheduler {
            cfg,
            queues: PriorityQueues::new(),
            window: None,
            feedback,
            active: Vec::new(),
            acquire_seq: 0,
            stats: SchedulerStats::default(),
        }
    }

    /// The current GPU holder: highest-priority active task, earliest
    /// acquisition breaking ties.
    pub fn holder(&self) -> Option<(&TaskKey, Priority)> {
        self.active
            .iter()
            .min_by_key(|t| (t.priority, t.acquired))
            .map(|t| (&t.key, t.priority))
    }

    /// A service began a new task (invocation).
    pub fn task_started(&mut self, key: &TaskKey, priority: Priority, _now: SimTime) {
        let prev_holder_prio = self.holder().map(|(_, p)| p);
        self.active.push(ActiveTask {
            key: key.clone(),
            priority,
            acquired: self.acquire_seq,
        });
        self.acquire_seq += 1;
        // Preemption (case A): a strictly higher-priority task takes the
        // holder role; any fill window belonging to the old holder's gap
        // is stale — the GPU is about to serve the new holder.
        if let Some(prev) = prev_holder_prio {
            if priority.is_higher_than(prev) {
                self.stats.preemptions += 1;
                self.window = None;
            }
        }
    }

    /// A service's task completed. Returns kernels to dispatch now that
    /// the holder may have changed.
    pub fn task_finished(&mut self, key: &TaskKey, now: SimTime) -> Vec<Submission> {
        if let Some(pos) = self.active.iter().position(|t| &t.key == key) {
            self.active.swap_remove(pos);
        }
        // The finished task's gap (if a window was open for it) is over.
        if self.window.as_ref().is_some_and(|w| &w.holder == key) {
            self.window = None;
        }

        let mut out = Vec::new();
        // Dispatch the new holder-priority class's waiting kernels.
        if let Some((_, new_prio)) = self.holder() {
            for req in self.queues.drain_at(new_prio) {
                self.stats.drained += 1;
                out.push(Submission {
                    launch: req.launch,
                    source: LaunchSource::Drain,
                });
            }
        } else {
            // No active tasks: every queued request belongs to an active
            // task by construction, so the queues must be empty.
            debug_assert!(
                self.queues.is_empty(),
                "queued requests without any active task"
            );
            let _ = now;
        }
        out
    }

    /// Route an intercepted kernel launch (hook → scheduler message).
    pub fn on_launch(
        &mut self,
        launch: KernelLaunch,
        now: SimTime,
        profiles: &ProfileStore,
    ) -> Vec<Submission> {
        let Some((holder_key, holder_prio)) = self.holder() else {
            // Defensive: no active task should mean no launches, but if a
            // stray one appears, let it through.
            self.stats.direct += 1;
            return vec![Submission {
                launch,
                source: LaunchSource::Direct,
            }];
        };

        if &launch.task_key == holder_key {
            // The holder's next kernel: ground-truth end of the current
            // gap — the feedback early-stop signal (Fig 12).
            self.feedback.on_holder_arrival(&mut self.window, now);
            if self.feedback.enabled {
                debug_assert!(self.window.is_none());
            }
            self.stats.direct += 1;
            return vec![Submission {
                launch,
                source: LaunchSource::Direct,
            }];
        }

        if launch.priority == holder_prio {
            // Case C: equal priority shares FIFO like default CUDA.
            self.stats.direct += 1;
            return vec![Submission {
                launch,
                source: LaunchSource::Direct,
            }];
        }

        // Strictly lower priority: park in the message queues, resolving
        // the profiled duration once here (not per BestPrioFit scan).
        self.stats.queued += 1;
        let predicted = profiles
            .get(&launch.task_key)
            .and_then(|p| p.sk(&launch.kernel));
        self.queues.push_predicted(launch, predicted, now);
        // …and, if a fill window is open, immediately re-run the FIKIT
        // procedure — the new request may fit the remaining gap (this is
        // the "when a kernel is added to any priority queue, the
        // scheduler triggers a priority scan" rule of Fig 7/8).
        self.pump_fills(now, profiles)
    }

    /// React to a kernel completion on the device.
    pub fn on_kernel_done(
        &mut self,
        record: &KernelRecord,
        now: SimTime,
        profiles: &ProfileStore,
    ) -> Vec<Submission> {
        let Some((holder_key, _)) = self.holder() else {
            return Vec::new();
        };

        if &record.task_key == holder_key && record.source != LaunchSource::GapFill {
            // A holder kernel finished: its profiled following gap starts
            // now. Open a fill window if the gap is worth filling.
            let predicted_gap = profiles
                .get(&record.task_key)
                .and_then(|p| p.sg(&record.kernel));
            if let Some(gap) = predicted_gap {
                self.window =
                    FillWindow::open(record.task_key.clone(), now, gap, self.cfg.epsilon);
                if self.window.is_some() {
                    self.feedback.on_window_open();
                }
            } else {
                self.window = None;
            }
            return self.pump_fills(now, profiles);
        }

        if record.source == LaunchSource::GapFill {
            // A fill kernel completed; the window may still have budget
            // for more (requests that arrived since the last pump).
            return self.pump_fills(now, profiles);
        }
        Vec::new()
    }

    /// Run Algorithm 1 against the open window (if any).
    fn pump_fills(&mut self, now: SimTime, profiles: &ProfileStore) -> Vec<Submission> {
        let Some(window) = self.window.as_mut() else {
            return Vec::new();
        };
        let fills: Vec<Fit> =
            fikit_fill_with(window, now, &mut self.queues, profiles, self.cfg.fill_policy);
        self.stats.fills += fills.len() as u64;
        fills
            .into_iter()
            .map(|fit| Submission {
                launch: fit.launch,
                source: LaunchSource::GapFill,
            })
            .collect()
    }

    pub fn stats(&self) -> &SchedulerStats {
        let _ = &self.stats.feedback; // keep field referenced
        &self.stats
    }

    /// Consolidated stats including feedback telemetry.
    pub fn final_stats(&self) -> SchedulerStats {
        let mut s = self.stats.clone();
        s.feedback = self.feedback.stats().clone();
        s
    }

    /// Number of queued (waiting) kernel requests.
    pub fn queued_len(&self) -> usize {
        self.queues.len()
    }

    /// Active task count.
    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Is a fill window currently open?
    pub fn window_open(&self) -> bool {
        self.window.is_some()
    }

    /// Debug invariants, used by property tests.
    pub fn check_invariants(&self) {
        // Every queued request's priority must be strictly lower than the
        // holder's (higher-or-equal launches are always routed direct).
        if let Some((_, hp)) = self.holder() {
            for p in Priority::ALL {
                if self.queues.len_at(p) > 0 {
                    assert!(
                        hp.is_higher_than(p),
                        "queued request at {p} not lower than holder {hp}"
                    );
                }
            }
        } else {
            assert!(self.queues.is_empty(), "queued requests with no holder");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dim3, KernelId, TaskId};
    use crate::profile::TaskProfile;

    fn kid(name: &str) -> KernelId {
        KernelId::new(name, Dim3::x(1), Dim3::x(64))
    }

    fn launch(key: &str, kernel: &str, prio: Priority, seq: u32, now: SimTime) -> KernelLaunch {
        KernelLaunch {
            task_key: TaskKey::new(key),
            task_id: TaskId(0),
            kernel: kid(kernel),
            priority: prio,
            seq,
            true_duration: Duration::from_micros(100),
            issued_at: now,
        }
    }

    fn record(l: &KernelLaunch, source: LaunchSource, start: SimTime, dur_us: u64) -> KernelRecord {
        KernelRecord {
            task_key: l.task_key.clone(),
            task_id: l.task_id,
            kernel: l.kernel.clone(),
            priority: l.priority,
            seq: l.seq,
            source,
            issued_at: l.issued_at,
            started_at: start,
            finished_at: start + Duration::from_micros(dur_us),
        }
    }

    /// Profile store: holder "hi" has kernel hk (exec 200us, gap 1ms);
    /// low-prio "lo" has kernel lk (exec 300us).
    fn profiles() -> ProfileStore {
        let mut s = ProfileStore::new();
        let mut hi = TaskProfile::new(TaskKey::new("hi"));
        hi.record(&kid("hk"), Duration::from_micros(200), Some(Duration::from_millis(1)));
        hi.finish_run(1);
        s.insert(hi);
        let mut lo = TaskProfile::new(TaskKey::new("lo"));
        lo.record(&kid("lk"), Duration::from_micros(300), Some(Duration::from_micros(50)));
        lo.finish_run(1);
        s.insert(lo);
        s
    }

    #[test]
    fn holder_launches_direct_lower_queued() {
        let p = profiles();
        let mut s = FikitScheduler::new(SchedulerConfig::default());
        s.task_started(&TaskKey::new("hi"), Priority::P0, SimTime::ZERO);
        s.task_started(&TaskKey::new("lo"), Priority::P3, SimTime::ZERO);
        assert_eq!(s.holder().unwrap().0, &TaskKey::new("hi"));

        let subs = s.on_launch(launch("hi", "hk", Priority::P0, 0, SimTime::ZERO), SimTime::ZERO, &p);
        assert_eq!(subs.len(), 1);
        assert_eq!(subs[0].source, LaunchSource::Direct);

        let subs = s.on_launch(launch("lo", "lk", Priority::P3, 0, SimTime::ZERO), SimTime::ZERO, &p);
        assert!(subs.is_empty(), "no window open yet: low-prio waits");
        assert_eq!(s.queued_len(), 1);
        s.check_invariants();
    }

    #[test]
    fn gap_fill_cycle_and_feedback_close() {
        let p = profiles();
        let mut s = FikitScheduler::new(SchedulerConfig::default());
        s.task_started(&TaskKey::new("hi"), Priority::P0, SimTime::ZERO);
        s.task_started(&TaskKey::new("lo"), Priority::P3, SimTime::ZERO);

        // Low-prio request arrives first, parks.
        let l0 = launch("lo", "lk", Priority::P3, 0, SimTime::ZERO);
        assert!(s.on_launch(l0, SimTime::ZERO, &p).is_empty());

        // Holder kernel hk completes at t=1ms → SG(hk)=1ms window opens,
        // queued lk (SK=300us) fits → launched as fill.
        let hl = launch("hi", "hk", Priority::P0, 0, SimTime::ZERO);
        let rec = record(&hl, LaunchSource::Direct, SimTime(800_000), 200);
        let done_at = rec.finished_at;
        let subs = s.on_kernel_done(&rec, done_at, &p);
        assert_eq!(subs.len(), 1);
        assert_eq!(subs[0].source, LaunchSource::GapFill);
        assert!(s.window_open());
        assert_eq!(s.queued_len(), 0);

        // Holder's next kernel arrives before predicted end → early stop.
        let next = launch("hi", "hk", Priority::P0, 1, done_at + Duration::from_micros(400));
        let at = next.issued_at;
        let subs = s.on_launch(next, at, &p);
        assert_eq!(subs[0].source, LaunchSource::Direct);
        assert!(!s.window_open(), "feedback must close the window");
        let stats = s.final_stats();
        assert_eq!(stats.fills, 1);
        assert_eq!(stats.feedback.windows, 1);
        assert_eq!(stats.feedback.early_stops, 1);
    }

    #[test]
    fn preemption_case_a() {
        let p = profiles();
        let mut s = FikitScheduler::new(SchedulerConfig::default());
        // Low-prio task holds the GPU first (it is the only active task).
        s.task_started(&TaskKey::new("lo"), Priority::P3, SimTime::ZERO);
        let subs = s.on_launch(launch("lo", "lk", Priority::P3, 0, SimTime::ZERO), SimTime::ZERO, &p);
        assert_eq!(subs[0].source, LaunchSource::Direct);

        // High-priority task arrives: becomes holder (preemption).
        s.task_started(&TaskKey::new("hi"), Priority::P0, SimTime(100));
        assert_eq!(s.holder().unwrap().0, &TaskKey::new("hi"));
        assert_eq!(s.final_stats().preemptions, 1);

        // lo's next launch is now lower than the holder: queued.
        let subs = s.on_launch(launch("lo", "lk", Priority::P3, 1, SimTime(200)), SimTime(200), &p);
        assert!(subs.is_empty());
        assert_eq!(s.queued_len(), 1);
        s.check_invariants();
    }

    #[test]
    fn holder_change_drains_new_priority_class() {
        let p = profiles();
        let mut s = FikitScheduler::new(SchedulerConfig::default());
        s.task_started(&TaskKey::new("hi"), Priority::P0, SimTime::ZERO);
        s.task_started(&TaskKey::new("lo"), Priority::P3, SimTime::ZERO);
        assert!(s
            .on_launch(launch("lo", "lk", Priority::P3, 0, SimTime::ZERO), SimTime::ZERO, &p)
            .is_empty());

        // Holder's task finishes: lo becomes holder, its parked kernel
        // is dispatched as a drain.
        let subs = s.task_finished(&TaskKey::new("hi"), SimTime(1_000));
        assert_eq!(subs.len(), 1);
        assert_eq!(subs[0].source, LaunchSource::Drain);
        assert_eq!(s.holder().unwrap().0, &TaskKey::new("lo"));
        assert_eq!(s.queued_len(), 0);
        s.check_invariants();
    }

    #[test]
    fn equal_priority_case_c_goes_direct() {
        let p = profiles();
        let mut s = FikitScheduler::new(SchedulerConfig::default());
        s.task_started(&TaskKey::new("hi"), Priority::P2, SimTime::ZERO);
        s.task_started(&TaskKey::new("lo"), Priority::P2, SimTime::ZERO);
        let subs = s.on_launch(launch("lo", "lk", Priority::P2, 0, SimTime::ZERO), SimTime::ZERO, &p);
        assert_eq!(subs[0].source, LaunchSource::Direct);
        assert_eq!(s.queued_len(), 0);
    }

    #[test]
    fn no_window_for_small_or_unknown_gaps() {
        let mut p = profiles();
        // Add a holder kernel with a tiny gap.
        let mut hi = p.remove(&TaskKey::new("hi")).unwrap();
        hi.record(&kid("tiny"), Duration::from_micros(10), Some(Duration::from_micros(20)));
        hi.finish_run(1);
        p.insert(hi);

        let mut s = FikitScheduler::new(SchedulerConfig::default());
        s.task_started(&TaskKey::new("hi"), Priority::P0, SimTime::ZERO);
        s.task_started(&TaskKey::new("lo"), Priority::P3, SimTime::ZERO);
        let _ = s.on_launch(launch("lo", "lk", Priority::P3, 0, SimTime::ZERO), SimTime::ZERO, &p);

        // Tiny gap (20us < ε=100us): no window, no fills.
        let hl = launch("hi", "tiny", Priority::P0, 0, SimTime::ZERO);
        let rec = record(&hl, LaunchSource::Direct, SimTime::ZERO, 10);
        let t = rec.finished_at;
        assert!(s.on_kernel_done(&rec, t, &p).is_empty());
        assert!(!s.window_open());

        // Unknown kernel (no SG): no window either.
        let ul = launch("hi", "unseen", Priority::P0, 1, SimTime::ZERO);
        let rec = record(&ul, LaunchSource::Direct, SimTime::ZERO, 10);
        let t = rec.finished_at;
        assert!(s.on_kernel_done(&rec, t, &p).is_empty());
        assert!(!s.window_open());
        assert_eq!(s.queued_len(), 1, "low-prio stays parked");
    }
}
