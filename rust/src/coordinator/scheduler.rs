//! The FIKIT scheduler: GPU-holder tracking, launch routing (the three
//! cases of Fig 11), window management and queue dispatch.
//!
//! ## Holder model
//!
//! The *holder* is the highest-priority task currently mid-invocation
//! (ties broken by acquisition order). Holder launches go **direct** to
//! the device; lower-priority launches are queued in Q0–Q9 and only reach
//! the device through gap filling (Algorithm 1) or when the holder
//! changes. Equal-priority launches also go direct — the paper's case C
//! degrades to default FIFO sharing among equals.
//!
//! This single rule yields all three Fig 11 cases:
//!
//! * **Case A** (running low-prio A, high-prio B arrives): B's task start
//!   makes B the holder; A's *next* launch is now lower-priority → queued
//!   → A proceeds only inside B's gaps. Priority inversion solved at
//!   kernel granularity (the in-flight kernel finishes; kernels are not
//!   preempted mid-execution).
//! * **Case B** (running high-prio A, low-prio B arrives): A stays
//!   holder, B is queued and gap-filled.
//! * **Case C** (equal priorities): both launch direct, FIFO interleave.

use super::best_prio_fit::{FillPolicy, Fit};
use super::feedback::{FeedbackController, FeedbackStats};
use super::fikit::{fikit_fill_with, FillWindow};
use super::queues::PriorityQueues;
use crate::core::{
    Duration, KernelLaunch, KernelRecord, LaunchSource, Priority, SimTime, TaskHandle,
};
use crate::profile::ResolvedProfile;

/// Scheduler tuning knobs.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Small-gap threshold ε (Algorithm 1).
    pub epsilon: Duration,
    /// Runtime feedback early stop (Fig 12). Disable only for ablations.
    pub feedback: bool,
    /// Within-priority fill selection rule (paper: LongestFit).
    pub fill_policy: FillPolicy,
}

impl Default for SchedulerConfig {
    fn default() -> SchedulerConfig {
        SchedulerConfig {
            epsilon: super::fikit::DEFAULT_EPSILON,
            feedback: true,
            fill_policy: FillPolicy::LongestFit,
        }
    }
}

/// Counters exposed for experiments and perf work. All fields —
/// including `feedback` — are live: the controller accumulates its
/// telemetry directly into this struct, so any borrowed view is always
/// current.
#[derive(Debug, Clone, Default)]
pub struct SchedulerStats {
    /// Launches routed straight to the device (holder / equal priority).
    pub direct: u64,
    /// Launches parked in the priority queues.
    pub queued: u64,
    /// Kernels launched as gap fills.
    pub fills: u64,
    /// Kernels dispatched when the holder changed.
    pub drained: u64,
    /// Holder changes caused by a higher-priority task starting.
    pub preemptions: u64,
    /// Refreshed profile snapshots swapped in by the online refiner
    /// (epoch swaps; DESIGN.md §9).
    pub profile_refreshes: u64,
    /// Kernel-level preemption telemetry (ADR-007). All zero when
    /// [`super::fikit::PreemptionPolicy::None`] is active.
    pub preempt: PreemptStats,
    /// Feedback telemetry.
    pub feedback: FeedbackStats,
}

/// Counters for the kernel-level preemption tier (ADR-007). Distinct
/// from [`SchedulerStats::preemptions`], which counts *holder changes*
/// (the paper's case A); these count in-flight fill kernels reclaimed
/// by the driver's preempt probe.
#[derive(Debug, Clone, Default)]
pub struct PreemptStats {
    /// Fill kernels evicted before their modeled start (full rollback,
    /// zero wasted execution).
    pub evictions: u64,
    /// Running fill kernels cut at the probe point (Evict / young
    /// Hybrid): the partial execution is discarded and the original
    /// launch re-queued whole.
    pub cuts: u64,
    /// Running fill kernels split at a slice boundary (Split / old
    /// Hybrid): the executed prefix is kept and a remnant re-queued.
    pub splits: u64,
    /// Preempted launches re-parked in the priority queues
    /// (= evictions + cuts + splits).
    pub requeues: u64,
    /// Device time handed back to the holder (cut point → modeled
    /// finish, summed over all preemptions).
    pub reclaimed: Duration,
    /// Partial execution discarded by cuts (start → cut point); the
    /// model's price for evicting mid-kernel.
    pub wasted: Duration,
}

/// A launch the scheduler wants submitted to the device, with its source
/// tag (direct / gap fill / drain).
#[derive(Debug, Clone)]
pub struct Submission {
    pub launch: KernelLaunch,
    pub source: LaunchSource,
}

#[derive(Debug, Clone, Copy)]
struct ActiveTask {
    handle: TaskHandle,
    priority: Priority,
    acquired: u64,
}

/// The sharing-stage FIKIT scheduler.
pub struct FikitScheduler {
    cfg: SchedulerConfig,
    queues: PriorityQueues,
    window: Option<FillWindow>,
    feedback: FeedbackController,
    active: Vec<ActiveTask>,
    acquire_seq: u64,
    /// Attach-time resolved predictions, indexed by [`TaskHandle`]. The
    /// only profile view the hot path ever touches — see
    /// [`FikitScheduler::register_service`].
    resolved: Vec<Option<ResolvedProfile>>,
    stats: SchedulerStats,
}

impl FikitScheduler {
    pub fn new(cfg: SchedulerConfig) -> FikitScheduler {
        let feedback = FeedbackController::new(cfg.feedback);
        FikitScheduler {
            cfg,
            queues: PriorityQueues::new(),
            window: None,
            feedback,
            active: Vec::new(),
            acquire_seq: 0,
            resolved: Vec::new(),
            stats: SchedulerStats::default(),
        }
    }

    /// Register a service's attach-time [`ResolvedProfile`] under its
    /// interned handle. Called once per attach by the driver — after
    /// this, every `SK`/`SG` lookup for the service is a handle-keyed
    /// probe of its own resolved table (zero hashing, zero allocation
    /// on the hot path).
    pub fn register_service(&mut self, handle: TaskHandle, profile: ResolvedProfile) {
        let idx = handle.index();
        if idx >= self.resolved.len() {
            self.resolved.resize_with(idx + 1, || None);
        }
        self.resolved[idx] = Some(profile);
    }

    /// Swap in a refreshed snapshot for an already-registered service —
    /// the online refiner's epoch swap (DESIGN.md §9). Single-writer
    /// double-buffering: the driver calls this between events, so no
    /// launch ever observes a half-written table; a snapshot for a
    /// service that already drained is dropped (its slot is `None`
    /// again and must not be resurrected).
    pub fn refresh_service(&mut self, handle: TaskHandle, profile: ResolvedProfile) {
        if let Some(slot) = self.resolved.get_mut(handle.index()) {
            if let Some(current) = slot.as_mut() {
                debug_assert!(
                    profile.epoch() > current.epoch(),
                    "epoch must advance on refresh"
                );
                *current = profile;
                self.stats.profile_refreshes += 1;
            }
        }
    }

    /// Current profile epoch of a service (0 = offline attach-time
    /// resolution or unregistered).
    pub fn profile_epoch(&self, handle: TaskHandle) -> u64 {
        self.resolved
            .get(handle.index())
            .and_then(|s| s.as_ref())
            .map_or(0, |p| p.epoch())
    }

    /// Drop a departed service's resolved profile (driver calls this
    /// when a detached service has fully drained). The handle itself
    /// stays valid — the interner is append-only — but its slot reads
    /// as unprofiled again, so a long churn run's memory tracks *live*
    /// services, not every service ever attached.
    pub fn unregister_service(&mut self, handle: TaskHandle) {
        if let Some(slot) = self.resolved.get_mut(handle.index()) {
            *slot = None;
        }
    }

    /// Predicted execution time `SK` for a launch (hot path).
    #[inline]
    fn sk(&self, launch: &KernelLaunch) -> Option<Duration> {
        self.resolved
            .get(launch.task_handle.index())?
            .as_ref()?
            .sk(launch.kernel_handle)
    }

    /// Predicted execution time `SK` for a launch, exposed for the
    /// driver's preempt probe (which must remember the prediction a
    /// fill was parked with so a preempted launch re-enters the queues
    /// at the same index).
    #[inline]
    pub fn predicted_sk(&self, launch: &KernelLaunch) -> Option<Duration> {
        self.sk(launch)
    }

    /// Predicted following gap `SG` for a completed kernel (hot path).
    #[inline]
    fn sg(&self, record: &KernelRecord) -> Option<Duration> {
        self.resolved
            .get(record.task_handle.index())?
            .as_ref()?
            .sg(record.kernel_handle)
    }

    /// The current GPU holder: highest-priority active task, earliest
    /// acquisition breaking ties.
    pub fn holder(&self) -> Option<(TaskHandle, Priority)> {
        self.active
            .iter()
            .min_by_key(|t| (t.priority, t.acquired))
            .map(|t| (t.handle, t.priority))
    }

    /// A service began a new task (invocation).
    pub fn task_started(&mut self, handle: TaskHandle, priority: Priority, _now: SimTime) {
        let prev_holder_prio = self.holder().map(|(_, p)| p);
        self.active.push(ActiveTask {
            handle,
            priority,
            acquired: self.acquire_seq,
        });
        self.acquire_seq += 1;
        // Preemption (case A): a strictly higher-priority task takes the
        // holder role; any fill window belonging to the old holder's gap
        // is stale — the GPU is about to serve the new holder.
        if let Some(prev) = prev_holder_prio {
            if priority.is_higher_than(prev) {
                self.stats.preemptions += 1;
                self.window = None;
            }
        }
    }

    /// A service's task completed. Returns kernels to dispatch now that
    /// the holder may have changed.
    pub fn task_finished(&mut self, handle: TaskHandle, now: SimTime) -> Vec<Submission> {
        if let Some(pos) = self.active.iter().position(|t| t.handle == handle) {
            self.active.swap_remove(pos);
        }
        // The finished task's gap (if a window was open for it) is over.
        if self.window.as_ref().is_some_and(|w| w.holder == handle) {
            self.window = None;
        }

        let mut out = Vec::new();
        // Dispatch the new holder-priority class's waiting kernels.
        if let Some((_, new_prio)) = self.holder() {
            for req in self.queues.drain_at(new_prio) {
                self.stats.drained += 1;
                out.push(Submission {
                    launch: req.launch,
                    source: LaunchSource::Drain,
                });
            }
        } else {
            // No active tasks: every queued request belongs to an active
            // task by construction, so the queues must be empty.
            debug_assert!(
                self.queues.is_empty(),
                "queued requests without any active task"
            );
            let _ = now;
        }
        out
    }

    /// Route an intercepted kernel launch (hook → scheduler message).
    ///
    /// Steady-state cost: two integer compares (holder / priority), one
    /// dense `SK` lookup, one indexed enqueue — no hashing, no
    /// allocation beyond retained queue capacity (DESIGN.md §Perf).
    pub fn on_launch(&mut self, launch: KernelLaunch, now: SimTime) -> Vec<Submission> {
        let Some((holder_handle, holder_prio)) = self.holder() else {
            // Defensive: no active task should mean no launches, but if a
            // stray one appears, let it through.
            self.stats.direct += 1;
            return vec![Submission {
                launch,
                source: LaunchSource::Direct,
            }];
        };

        if launch.task_handle == holder_handle {
            // The holder's next kernel: ground-truth end of the current
            // gap — the feedback early-stop signal (Fig 12).
            self.feedback
                .on_holder_arrival(&mut self.window, now, &mut self.stats.feedback);
            if self.feedback.enabled {
                debug_assert!(self.window.is_none());
            }
            self.stats.direct += 1;
            return vec![Submission {
                launch,
                source: LaunchSource::Direct,
            }];
        }

        if launch.priority == holder_prio {
            // Case C: equal priority shares FIFO like default CUDA.
            self.stats.direct += 1;
            return vec![Submission {
                launch,
                source: LaunchSource::Direct,
            }];
        }

        // Strictly lower priority: park in the message queues, resolving
        // the profiled duration once here (not per BestPrioFit scan).
        self.stats.queued += 1;
        let predicted = self.sk(&launch);
        self.queues.push_predicted(launch, predicted, now);
        // …and, if a fill window is open, immediately re-run the FIKIT
        // procedure — the new request may fit the remaining gap (this is
        // the "when a kernel is added to any priority queue, the
        // scheduler triggers a priority scan" rule of Fig 7/8).
        self.pump_fills(now)
    }

    /// Re-park a preempted fill launch (ADR-007). The driver has
    /// already rolled the device model back; here the launch simply
    /// re-enters the priority queues — at the tail of its lane, indexed
    /// by `predicted` (the remaining duration for a split remnant, the
    /// original `SK` for an evicted whole). No fill pump runs: the
    /// probe only fires when a higher-priority launch is about to
    /// occupy the device, so any open window is about to be consumed.
    pub fn park_preempted(
        &mut self,
        launch: KernelLaunch,
        predicted: Option<Duration>,
        now: SimTime,
    ) {
        self.stats.preempt.requeues += 1;
        match predicted {
            Some(remaining) => self.queues.push_remnant(launch, remaining, now),
            // Fills are only ever selected when profiled, so this arm
            // is defensive: an unprofiled launch re-parks unprofiled.
            None => self.queues.push_predicted(launch, None, now),
        }
    }

    /// Mutable preemption counters, for the driver's preempt probe
    /// (the probe owns the decision; the scheduler owns the telemetry).
    pub fn preempt_stats_mut(&mut self) -> &mut PreemptStats {
        &mut self.stats.preempt
    }

    /// React to a kernel completion on the device.
    pub fn on_kernel_done(&mut self, record: &KernelRecord, now: SimTime) -> Vec<Submission> {
        let Some((holder_handle, _)) = self.holder() else {
            return Vec::new();
        };

        if record.task_handle == holder_handle && record.source != LaunchSource::GapFill {
            // A holder kernel finished: its profiled following gap starts
            // now. Open a fill window if the gap is worth filling.
            if let Some(gap) = self.sg(record) {
                self.window =
                    FillWindow::open(record.task_handle, now, gap, self.cfg.epsilon);
                if self.window.is_some() {
                    self.feedback.on_window_open(&mut self.stats.feedback);
                }
            } else {
                self.window = None;
            }
            return self.pump_fills(now);
        }

        if record.source == LaunchSource::GapFill {
            // A fill kernel completed; the window may still have budget
            // for more (requests that arrived since the last pump).
            return self.pump_fills(now);
        }
        Vec::new()
    }

    /// Run Algorithm 1 against the open window (if any).
    fn pump_fills(&mut self, now: SimTime) -> Vec<Submission> {
        let Some(window) = self.window.as_mut() else {
            return Vec::new();
        };
        let fills: Vec<Fit> =
            fikit_fill_with(window, now, &mut self.queues, self.cfg.fill_policy);
        self.stats.fills += fills.len() as u64;
        fills
            .into_iter()
            .map(|fit| Submission {
                launch: fit.launch,
                source: LaunchSource::GapFill,
            })
            .collect()
    }

    /// Live counters, borrowed — no per-call clone (the old accessor
    /// cloned the whole struct every call). Every field, `feedback`
    /// included, is current.
    pub fn stats(&self) -> &SchedulerStats {
        &self.stats
    }

    /// Live feedback telemetry, borrowed (shorthand for
    /// `stats().feedback`).
    pub fn feedback_stats(&self) -> &FeedbackStats {
        &self.stats.feedback
    }

    /// Consume the scheduler, yielding its counters (end-of-run report).
    pub fn into_stats(self) -> SchedulerStats {
        self.stats
    }

    /// Number of queued (waiting) kernel requests.
    pub fn queued_len(&self) -> usize {
        self.queues.len()
    }

    /// Active task count.
    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Is a fill window currently open?
    pub fn window_open(&self) -> bool {
        self.window.is_some()
    }

    /// Debug invariants, used by property tests.
    pub fn check_invariants(&self) {
        // Every queued request's priority must be strictly lower than the
        // holder's (higher-or-equal launches are always routed direct).
        if let Some((_, hp)) = self.holder() {
            for p in Priority::ALL {
                if self.queues.len_at(p) > 0 {
                    assert!(
                        hp.is_higher_than(p),
                        "queued request at {p} not lower than holder {hp}"
                    );
                }
            }
        } else {
            assert!(self.queues.is_empty(), "queued requests with no holder");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dim3, Interner, KernelId, KernelRecord, TaskId, TaskKey};
    use crate::profile::TaskProfile;

    fn kid(name: &str) -> KernelId {
        KernelId::new(name, Dim3::x(1), Dim3::x(64))
    }

    /// Scheduler + interner with "hi" (kernel hk: exec 200us, gap 1ms)
    /// and "lo" (kernel lk: exec 300us, gap 50us) registered the way the
    /// driver does at attach time.
    struct Harness {
        sched: FikitScheduler,
        interner: Interner,
    }

    fn harness() -> Harness {
        harness_with(|p| p)
    }

    fn harness_with(extend: impl Fn(TaskProfile) -> TaskProfile) -> Harness {
        let mut interner = Interner::new();
        let mut sched = FikitScheduler::new(SchedulerConfig::default());

        let mut hi = TaskProfile::new(TaskKey::new("hi"));
        hi.record(&kid("hk"), Duration::from_micros(200), Some(Duration::from_millis(1)));
        hi.finish_run(1);
        let hi = extend(hi);
        let th = interner.intern_task(&TaskKey::new("hi"));
        let rp = ResolvedProfile::resolve(&hi, &mut interner);
        sched.register_service(th, rp);

        let mut lo = TaskProfile::new(TaskKey::new("lo"));
        lo.record(&kid("lk"), Duration::from_micros(300), Some(Duration::from_micros(50)));
        lo.finish_run(1);
        let tl = interner.intern_task(&TaskKey::new("lo"));
        let rp = ResolvedProfile::resolve(&lo, &mut interner);
        sched.register_service(tl, rp);

        Harness { sched, interner }
    }

    impl Harness {
        fn th(&mut self, key: &str) -> TaskHandle {
            self.interner.intern_task(&TaskKey::new(key))
        }

        fn launch(&mut self, key: &str, kernel: &str, prio: Priority, seq: u32, now: SimTime) -> KernelLaunch {
            KernelLaunch {
                task_key: TaskKey::new(key),
                task_handle: self.interner.intern_task(&TaskKey::new(key)),
                task_id: TaskId(0),
                kernel: kid(kernel),
                kernel_handle: self.interner.intern_kernel(&kid(kernel)),
                priority: prio,
                seq,
                true_duration: Duration::from_micros(100),
                issued_at: now,
            }
        }
    }

    fn record(l: &KernelLaunch, source: LaunchSource, start: SimTime, dur_us: u64) -> KernelRecord {
        KernelRecord {
            task_key: l.task_key.clone(),
            task_handle: l.task_handle,
            task_id: l.task_id,
            kernel: l.kernel.clone(),
            kernel_handle: l.kernel_handle,
            priority: l.priority,
            seq: l.seq,
            source,
            issued_at: l.issued_at,
            started_at: start,
            finished_at: start + Duration::from_micros(dur_us),
        }
    }

    #[test]
    fn holder_launches_direct_lower_queued() {
        let mut h = harness();
        let (hi, lo) = (h.th("hi"), h.th("lo"));
        h.sched.task_started(hi, Priority::P0, SimTime::ZERO);
        h.sched.task_started(lo, Priority::P3, SimTime::ZERO);
        assert_eq!(h.sched.holder().unwrap().0, hi);

        let l = h.launch("hi", "hk", Priority::P0, 0, SimTime::ZERO);
        let subs = h.sched.on_launch(l, SimTime::ZERO);
        assert_eq!(subs.len(), 1);
        assert_eq!(subs[0].source, LaunchSource::Direct);

        let l = h.launch("lo", "lk", Priority::P3, 0, SimTime::ZERO);
        let subs = h.sched.on_launch(l, SimTime::ZERO);
        assert!(subs.is_empty(), "no window open yet: low-prio waits");
        assert_eq!(h.sched.queued_len(), 1);
        h.sched.check_invariants();
    }

    #[test]
    fn gap_fill_cycle_and_feedback_close() {
        let mut h = harness();
        let (hi, lo) = (h.th("hi"), h.th("lo"));
        h.sched.task_started(hi, Priority::P0, SimTime::ZERO);
        h.sched.task_started(lo, Priority::P3, SimTime::ZERO);

        // Low-prio request arrives first, parks.
        let l0 = h.launch("lo", "lk", Priority::P3, 0, SimTime::ZERO);
        assert!(h.sched.on_launch(l0, SimTime::ZERO).is_empty());

        // Holder kernel hk completes at t=1ms → SG(hk)=1ms window opens,
        // queued lk (SK=300us) fits → launched as fill.
        let hl = h.launch("hi", "hk", Priority::P0, 0, SimTime::ZERO);
        let rec = record(&hl, LaunchSource::Direct, SimTime(800_000), 200);
        let done_at = rec.finished_at;
        let subs = h.sched.on_kernel_done(&rec, done_at);
        assert_eq!(subs.len(), 1);
        assert_eq!(subs[0].source, LaunchSource::GapFill);
        assert!(h.sched.window_open());
        assert_eq!(h.sched.queued_len(), 0);

        // Holder's next kernel arrives before predicted end → early stop.
        let next = h.launch("hi", "hk", Priority::P0, 1, done_at + Duration::from_micros(400));
        let at = next.issued_at;
        let subs = h.sched.on_launch(next, at);
        assert_eq!(subs[0].source, LaunchSource::Direct);
        assert!(!h.sched.window_open(), "feedback must close the window");
        assert_eq!(h.sched.stats().fills, 1);
        let fb = h.sched.feedback_stats();
        assert_eq!(fb.windows, 1);
        assert_eq!(fb.early_stops, 1);
        // End-of-run consolidation stitches feedback into the counters.
        let final_stats = h.sched.into_stats();
        assert_eq!(final_stats.fills, 1);
        assert_eq!(final_stats.feedback.early_stops, 1);
    }

    #[test]
    fn preemption_case_a() {
        let mut h = harness();
        let (hi, lo) = (h.th("hi"), h.th("lo"));
        // Low-prio task holds the GPU first (it is the only active task).
        h.sched.task_started(lo, Priority::P3, SimTime::ZERO);
        let l = h.launch("lo", "lk", Priority::P3, 0, SimTime::ZERO);
        let subs = h.sched.on_launch(l, SimTime::ZERO);
        assert_eq!(subs[0].source, LaunchSource::Direct);

        // High-priority task arrives: becomes holder (preemption).
        h.sched.task_started(hi, Priority::P0, SimTime(100));
        assert_eq!(h.sched.holder().unwrap().0, hi);
        assert_eq!(h.sched.stats().preemptions, 1);

        // lo's next launch is now lower than the holder: queued.
        let l = h.launch("lo", "lk", Priority::P3, 1, SimTime(200));
        let subs = h.sched.on_launch(l, SimTime(200));
        assert!(subs.is_empty());
        assert_eq!(h.sched.queued_len(), 1);
        h.sched.check_invariants();
    }

    /// A preempted fill re-parks through [`FikitScheduler::park_preempted`]:
    /// it lands back in its priority lane (indexed by the remaining
    /// duration), bumps only the requeue counter, and keeps the queue
    /// invariants intact.
    #[test]
    fn park_preempted_requeues_below_holder() {
        let mut h = harness();
        let (hi, lo) = (h.th("hi"), h.th("lo"));
        h.sched.task_started(hi, Priority::P0, SimTime::ZERO);
        h.sched.task_started(lo, Priority::P3, SimTime::ZERO);

        let l = h.launch("lo", "lk", Priority::P3, 0, SimTime::ZERO);
        h.sched
            .park_preempted(l, Some(Duration::from_micros(120)), SimTime(500));
        assert_eq!(h.sched.queued_len(), 1);
        assert_eq!(h.sched.stats().preempt.requeues, 1);
        assert_eq!(h.sched.stats().queued, 0, "a re-park is not a fresh queue");
        h.sched.check_invariants();

        // The defensive unprofiled arm also parks.
        let l = h.launch("lo", "lk", Priority::P3, 1, SimTime(600));
        h.sched.park_preempted(l, None, SimTime(600));
        assert_eq!(h.sched.queued_len(), 2);
        assert_eq!(h.sched.stats().preempt.requeues, 2);
    }

    #[test]
    fn holder_change_drains_new_priority_class() {
        let mut h = harness();
        let (hi, lo) = (h.th("hi"), h.th("lo"));
        h.sched.task_started(hi, Priority::P0, SimTime::ZERO);
        h.sched.task_started(lo, Priority::P3, SimTime::ZERO);
        let l = h.launch("lo", "lk", Priority::P3, 0, SimTime::ZERO);
        assert!(h.sched.on_launch(l, SimTime::ZERO).is_empty());

        // Holder's task finishes: lo becomes holder, its parked kernel
        // is dispatched as a drain.
        let subs = h.sched.task_finished(hi, SimTime(1_000));
        assert_eq!(subs.len(), 1);
        assert_eq!(subs[0].source, LaunchSource::Drain);
        assert_eq!(h.sched.holder().unwrap().0, lo);
        assert_eq!(h.sched.queued_len(), 0);
        h.sched.check_invariants();
    }

    #[test]
    fn equal_priority_case_c_goes_direct() {
        let mut h = harness();
        let (hi, lo) = (h.th("hi"), h.th("lo"));
        h.sched.task_started(hi, Priority::P2, SimTime::ZERO);
        h.sched.task_started(lo, Priority::P2, SimTime::ZERO);
        let l = h.launch("lo", "lk", Priority::P2, 0, SimTime::ZERO);
        let subs = h.sched.on_launch(l, SimTime::ZERO);
        assert_eq!(subs[0].source, LaunchSource::Direct);
        assert_eq!(h.sched.queued_len(), 0);
    }

    #[test]
    fn no_window_for_small_or_unknown_gaps() {
        // Holder profile additionally has a kernel with a tiny gap.
        let mut h = harness_with(|mut hi| {
            hi.record(&kid("tiny"), Duration::from_micros(10), Some(Duration::from_micros(20)));
            hi.finish_run(1);
            hi
        });
        let (hi, lo) = (h.th("hi"), h.th("lo"));
        h.sched.task_started(hi, Priority::P0, SimTime::ZERO);
        h.sched.task_started(lo, Priority::P3, SimTime::ZERO);
        let l = h.launch("lo", "lk", Priority::P3, 0, SimTime::ZERO);
        let _ = h.sched.on_launch(l, SimTime::ZERO);

        // Tiny gap (20us < ε=100us): no window, no fills.
        let hl = h.launch("hi", "tiny", Priority::P0, 0, SimTime::ZERO);
        let rec = record(&hl, LaunchSource::Direct, SimTime::ZERO, 10);
        let t = rec.finished_at;
        assert!(h.sched.on_kernel_done(&rec, t).is_empty());
        assert!(!h.sched.window_open());

        // Unknown kernel (no SG): no window either.
        let ul = h.launch("hi", "unseen", Priority::P0, 1, SimTime::ZERO);
        let rec = record(&ul, LaunchSource::Direct, SimTime::ZERO, 10);
        let t = rec.finished_at;
        assert!(h.sched.on_kernel_done(&rec, t).is_empty());
        assert!(!h.sched.window_open());
        assert_eq!(h.sched.queued_len(), 1, "low-prio stays parked");
    }

    /// Unregistering a departed service frees its resolved profile: its
    /// handle stays valid but reads as unprofiled; re-registering
    /// restores predictions (the churn attach→drain→re-attach cycle).
    #[test]
    fn unregister_releases_resolved_profile() {
        let mut h = harness();
        let (hi, lo) = (h.th("hi"), h.th("lo"));
        h.sched.task_started(hi, Priority::P0, SimTime::ZERO);
        h.sched.task_started(lo, Priority::P3, SimTime::ZERO);
        h.sched.unregister_service(lo);

        // lo's launch now parks unprofiled: a holder gap will not fill it.
        let l = h.launch("lo", "lk", Priority::P3, 0, SimTime::ZERO);
        assert!(h.sched.on_launch(l, SimTime::ZERO).is_empty());
        let hl = h.launch("hi", "hk", Priority::P0, 0, SimTime::ZERO);
        let rec = record(&hl, LaunchSource::Direct, SimTime::ZERO, 200);
        let t = rec.finished_at;
        assert!(h.sched.on_kernel_done(&rec, t).is_empty());
        assert_eq!(h.sched.queued_len(), 1, "unprofiled request stays parked");

        // Out-of-range / unknown handles are a no-op.
        h.sched.unregister_service(TaskHandle::from_index(999));
    }

    /// The online refiner's epoch swap: a refreshed snapshot replaces
    /// the registered profile in place, the epoch advances, and a
    /// refresh for a drained (unregistered) service is dropped.
    #[test]
    fn refresh_service_swaps_snapshot_in_place() {
        let mut h = harness();
        let (hi, lo) = (h.th("hi"), h.th("lo"));
        h.sched.task_started(hi, Priority::P0, SimTime::ZERO);
        h.sched.task_started(lo, Priority::P3, SimTime::ZERO);
        assert_eq!(h.sched.profile_epoch(hi), 0);

        // Refreshed prediction: hk's gap doubled to 2 ms.
        let hk = h.interner.intern_kernel(&kid("hk"));
        let snap = ResolvedProfile::from_rows(
            vec![(hk, Duration::from_micros(200), Some(Duration::from_millis(2)))],
            1,
        );
        h.sched.refresh_service(hi, snap);
        assert_eq!(h.sched.profile_epoch(hi), 1);
        assert_eq!(h.sched.stats().profile_refreshes, 1);

        // The next window opens with the refreshed gap: a parked 300 µs
        // fill plus a second one still fit the 2 ms budget.
        let l0 = h.launch("lo", "lk", Priority::P3, 0, SimTime::ZERO);
        assert!(h.sched.on_launch(l0, SimTime::ZERO).is_empty());
        let hl = h.launch("hi", "hk", Priority::P0, 0, SimTime::ZERO);
        let rec = record(&hl, LaunchSource::Direct, SimTime::ZERO, 200);
        let t = rec.finished_at;
        let subs = h.sched.on_kernel_done(&rec, t);
        assert_eq!(subs.len(), 1);
        assert!(h.sched.window_open(), "2 ms refreshed gap leaves budget");

        // A refresh for an unregistered handle must not resurrect it.
        h.sched.unregister_service(lo);
        let ghost = ResolvedProfile::from_rows(Vec::new(), 1);
        h.sched.refresh_service(lo, ghost);
        assert_eq!(h.sched.stats().profile_refreshes, 1);
        assert_eq!(h.sched.profile_epoch(lo), 0);
    }

    /// A launch whose task never registered a profile (unbound handles)
    /// is enqueued unprofiled and never selected for filling.
    #[test]
    fn unregistered_task_is_unprofiled() {
        let mut h = harness();
        let (hi, ghost) = (h.th("hi"), h.th("ghost"));
        h.sched.task_started(hi, Priority::P0, SimTime::ZERO);
        h.sched.task_started(ghost, Priority::P7, SimTime::ZERO);
        let l = h.launch("ghost", "gk", Priority::P7, 0, SimTime::ZERO);
        assert!(h.sched.on_launch(l, SimTime::ZERO).is_empty());
        assert_eq!(h.sched.queued_len(), 1);

        // Holder completion opens a window, but the unprofiled request
        // must not be gambled into it.
        let hl = h.launch("hi", "hk", Priority::P0, 0, SimTime::ZERO);
        let rec = record(&hl, LaunchSource::Direct, SimTime::ZERO, 200);
        let t = rec.finished_at;
        let subs = h.sched.on_kernel_done(&rec, t);
        assert!(subs.is_empty(), "unprofiled request must stay parked");
        assert_eq!(h.sched.queued_len(), 1);
    }
}
