//! Real-time feedback / early stopping (paper Fig 12).
//!
//! Profiled gap predictions (`SG`) are averages; individual gaps vary
//! (Fig 5), and naive profile-only filling lets prediction error
//! accumulate linearly — the controller ends up scheduling low-priority
//! kernels out of sync with the real gaps. FIKIT's fix: the arrival of
//! the holder's *next* kernel launch is the ground-truth end of the gap.
//! On that signal the controller immediately closes the fill window —
//! no further fills are issued ("overhead 1" eliminated). Fills already
//! committed to the device FIFO cannot be recalled; the residual delay
//! they impose on the arriving kernel is the paper's "overhead 2",
//! which we account explicitly.

use super::fikit::FillWindow;
use crate::core::{Duration, SimTime};

/// Aggregated feedback telemetry for one scheduler run.
#[derive(Debug, Clone, Default)]
pub struct FeedbackStats {
    /// Fill windows opened.
    pub windows: u64,
    /// Windows closed early by holder-arrival feedback while fill budget
    /// remained (the prediction overestimated the gap).
    pub early_stops: u64,
    /// Windows where the holder's kernel arrived *after* the predicted
    /// end (the prediction underestimated the gap — fills stopped too
    /// conservatively, some idle time was wasted).
    pub underestimates: u64,
    /// Σ |predicted gap end − actual arrival| over closed windows.
    pub abs_error: Duration,
    /// Σ unfilled predicted-idle budget at early stop.
    pub reclaimed_budget: Duration,
}

impl FeedbackStats {
    /// Mean absolute gap-prediction error per window.
    pub fn mean_abs_error(&self) -> Duration {
        if self.windows == 0 {
            Duration::ZERO
        } else {
            Duration::from_nanos(self.abs_error.nanos() / self.windows)
        }
    }
}

/// The feedback controller. With `enabled = false` it degrades to the
/// pure profile-driven scheduler of the paper's Fig 12 case C — kept as
/// an explicit ablation (bench `ablation_feedback`).
///
/// The controller is stateless policy: telemetry accumulates into the
/// caller-owned [`FeedbackStats`] (the scheduler keeps it inside its
/// `SchedulerStats`, so the live counters view is never stale).
#[derive(Debug, Clone, Copy)]
pub struct FeedbackController {
    pub enabled: bool,
}

impl FeedbackController {
    pub fn new(enabled: bool) -> FeedbackController {
        FeedbackController { enabled }
    }

    /// Record that a fill window was opened.
    pub fn on_window_open(&self, stats: &mut FeedbackStats) {
        stats.windows += 1;
    }

    /// The holder's next kernel launch arrived at `now`. If feedback is
    /// enabled, close the window (early-stop signal); always record the
    /// prediction error. Returns `true` if an open window was closed.
    pub fn on_holder_arrival(
        &self,
        window: &mut Option<FillWindow>,
        now: SimTime,
        stats: &mut FeedbackStats,
    ) -> bool {
        let Some(w) = window.as_mut() else {
            return false;
        };
        // Prediction error bookkeeping (over- or under-estimate).
        if w.predicted_end > now {
            let remaining = w.remaining(now);
            if !remaining.is_zero() {
                stats.early_stops += 1;
                stats.reclaimed_budget += remaining;
            }
            stats.abs_error += w.predicted_end - now;
        } else {
            stats.underestimates += 1;
            stats.abs_error += now - w.predicted_end;
        }

        if self.enabled {
            *window = None;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::TaskHandle;
    use crate::coordinator::fikit::DEFAULT_EPSILON;

    fn window(gap_us: u64) -> Option<FillWindow> {
        FillWindow::open(
            TaskHandle::from_index(0),
            SimTime::ZERO,
            Duration::from_micros(gap_us),
            DEFAULT_EPSILON,
        )
    }

    #[test]
    fn early_stop_closes_window_and_reclaims_budget() {
        let fc = FeedbackController::new(true);
        let mut s = FeedbackStats::default();
        let mut w = window(1_000); // predicted 1ms
        fc.on_window_open(&mut s);
        // Holder's next kernel arrives at 0.4ms — 0.6ms overestimated.
        let closed = fc.on_holder_arrival(&mut w, SimTime(400_000), &mut s);
        assert!(closed);
        assert!(w.is_none());
        assert_eq!(s.early_stops, 1);
        assert_eq!(s.underestimates, 0);
        assert_eq!(s.abs_error, Duration::from_micros(600));
        assert_eq!(s.reclaimed_budget, Duration::from_micros(600));
        assert_eq!(s.mean_abs_error(), Duration::from_micros(600));
    }

    #[test]
    fn underestimate_recorded() {
        let fc = FeedbackController::new(true);
        let mut s = FeedbackStats::default();
        let mut w = window(1_000);
        fc.on_window_open(&mut s);
        // Holder arrives 0.5ms *after* the predicted end.
        fc.on_holder_arrival(&mut w, SimTime(1_500_000), &mut s);
        assert_eq!(s.early_stops, 0);
        assert_eq!(s.underestimates, 1);
        assert_eq!(s.abs_error, Duration::from_micros(500));
    }

    #[test]
    fn disabled_feedback_leaves_window_open() {
        let fc = FeedbackController::new(false);
        let mut s = FeedbackStats::default();
        let mut w = window(1_000);
        fc.on_window_open(&mut s);
        let closed = fc.on_holder_arrival(&mut w, SimTime(100_000), &mut s);
        assert!(!closed);
        assert!(w.is_some(), "ablation: window must stay open");
        // Error is still recorded for telemetry.
        assert_eq!(s.early_stops, 1);
    }

    #[test]
    fn no_window_is_a_noop() {
        let fc = FeedbackController::new(true);
        let mut s = FeedbackStats::default();
        let mut w: Option<FillWindow> = None;
        assert!(!fc.on_holder_arrival(&mut w, SimTime::ZERO, &mut s));
        assert_eq!(s.windows, 0);
    }
}
