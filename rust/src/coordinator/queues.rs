//! The ten priority message queues Q0–Q9 (paper Fig 7).
//!
//! Each waiting kernel request sits in the queue matching its task's
//! priority. Within a queue, requests keep FIFO order. The scheduler
//! always scans Q0 → Q9, so high-priority requests are always considered
//! first — the structural guarantee behind the paper's "high-priority
//! tasks will be scheduled first".
//!
//! ## Hot-path layout (DESIGN.md §Perf)
//!
//! Each priority lane is a **linked slab**: requests live in a slab of
//! slots threaded into a doubly-linked FIFO, with a freelist recycling
//! vacated slots. On top sits `fit`, a duration-ordered index over the
//! *profiled* requests, sorted by `(predicted asc, arrival desc)`.
//!
//! * LongestFit ("longest request strictly under the gap, oldest wins
//!   ties" — Algorithm 2) is one `partition_point` binary search —
//!   O(log n) instead of the old full FIFO scan;
//! * removing the selected request is an O(1) FIFO unlink plus an
//!   in-place memmove of 24-byte fit-index triples — the old
//!   `VecDeque::remove` memmoved O(n) ~130-byte queued requests;
//! * every container reuses retained capacity (slab via freelist, index
//!   via in-place memmoves of 24-byte triples), so a steady-state
//!   enqueue → select → dispatch cycle performs **zero heap
//!   allocations** — asserted by a counting allocator in
//!   `tests/hotpath_alloc.rs`.
//!
//! Requests are stamped with a per-lane monotone arrival counter; stamps
//! order FIFO tie-breaks in the fit index deterministically.

use crate::core::{Duration, KernelLaunch, Priority, SimTime, NUM_PRIORITIES};

/// A kernel request waiting in a priority queue.
#[derive(Debug, Clone)]
pub struct QueuedRequest {
    pub launch: KernelLaunch,
    /// When the request entered the queue (for wait metrics).
    pub enqueued_at: SimTime,
    /// Profiled execution time `SK`, resolved **once** at enqueue time
    /// (from the attach-time [`crate::profile::ResolvedProfile`]), so
    /// BestPrioFit is a pure index lookup — no hashing or string work on
    /// the hot path. `None` = unprofiled: never selected for gap filling
    /// (the scheduler cannot predict it, so it must not gamble a
    /// high-priority task's gap on it).
    pub predicted: Option<crate::core::Duration>,
}

/// Niche link value for "no slot".
const NIL: u32 = u32::MAX;

#[derive(Debug)]
struct Slot {
    /// `None` = free slot (on the freelist).
    req: Option<QueuedRequest>,
    prev: u32,
    next: u32,
    /// Arrival stamp (monotone per lane) — FIFO tie-break key.
    stamp: u64,
}

/// One priority lane: linked-slab FIFO + duration-ordered fit index.
#[derive(Debug)]
struct Lane {
    slab: Vec<Slot>,
    free: Vec<u32>,
    /// Oldest live slot (`NIL` when empty).
    head: u32,
    /// Newest live slot (`NIL` when empty).
    tail: u32,
    next_stamp: u64,
    /// `(predicted, stamp, slot)` of every live profiled request, sorted
    /// by `(predicted asc, stamp desc)` — see [`Lane::fit_pos`].
    fit: Vec<(Duration, u64, u32)>,
    live: usize,
}

impl Default for Lane {
    fn default() -> Lane {
        Lane {
            slab: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            next_stamp: 0,
            fit: Vec::new(),
            live: 0,
        }
    }
}

impl Lane {
    /// Position of / insertion point for `(d, stamp)` in the fit index.
    /// Sorting stamps *descending* within equal durations puts the
    /// oldest request last in its duration run, so "longest fitting,
    /// FIFO tie-break" is always the element just before the partition
    /// point — identical selection to the old strict `predicted > best`
    /// scan.
    #[inline]
    fn fit_pos(&self, d: Duration, stamp: u64) -> usize {
        self.fit
            .partition_point(|&(fd, fs, _)| (fd, !fs) < (d, !stamp))
    }

    fn push(&mut self, req: QueuedRequest) {
        let stamp = self.next_stamp;
        self.next_stamp += 1;
        let predicted = req.predicted;
        let prev_tail = self.tail;
        let slot = match self.free.pop() {
            Some(s) => {
                let sl = &mut self.slab[s as usize];
                debug_assert!(sl.req.is_none());
                *sl = Slot {
                    req: Some(req),
                    prev: prev_tail,
                    next: NIL,
                    stamp,
                };
                s
            }
            None => {
                let s = self.slab.len() as u32;
                debug_assert!(s < NIL, "lane slab exhausted");
                self.slab.push(Slot {
                    req: Some(req),
                    prev: prev_tail,
                    next: NIL,
                    stamp,
                });
                s
            }
        };
        if prev_tail != NIL {
            self.slab[prev_tail as usize].next = slot;
        } else {
            self.head = slot;
        }
        self.tail = slot;
        if let Some(d) = predicted {
            let pos = self.fit_pos(d, stamp);
            self.fit.insert(pos, (d, stamp, slot));
        }
        self.live += 1;
    }

    /// Unlink a live slot from the FIFO and free it. The caller must
    /// have already removed any fit-index entry for it.
    fn unlink(&mut self, slot: u32) -> QueuedRequest {
        let (prev, next) = {
            let sl = &self.slab[slot as usize];
            (sl.prev, sl.next)
        };
        if prev != NIL {
            self.slab[prev as usize].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slab[next as usize].prev = prev;
        } else {
            self.tail = prev;
        }
        let req = self.slab[slot as usize].req.take().expect("live slot");
        self.free.push(slot);
        self.live -= 1;
        req
    }

    /// Drop the fit entry of a live slot (no-op for unprofiled slots).
    fn unfit(&mut self, slot: u32) {
        let sl = &self.slab[slot as usize];
        let stamp = sl.stamp;
        if let Some(d) = sl.req.as_ref().and_then(|r| r.predicted) {
            let pos = self.fit_pos(d, stamp);
            debug_assert!(
                matches!(self.fit.get(pos), Some(&(fd, fs, _)) if fd == d && fs == stamp),
                "fit index desync"
            );
            self.fit.remove(pos);
        }
    }

    fn pop_front(&mut self) -> Option<QueuedRequest> {
        if self.head == NIL {
            return None;
        }
        let slot = self.head;
        self.unfit(slot);
        Some(self.unlink(slot))
    }

    /// Remove the fit entry at `pos` and its request.
    fn take_fit(&mut self, pos: usize) -> (QueuedRequest, Duration) {
        let (d, _stamp, slot) = self.fit.remove(pos);
        (self.unlink(slot), d)
    }

    /// Live requests in FIFO order.
    fn iter(&self) -> LaneIter<'_> {
        LaneIter {
            lane: self,
            cur: self.head,
        }
    }

    /// Empty the lane in FIFO order. O(n): walks the links once and
    /// clears the fit index wholesale (per-element `unfit` would memmove
    /// the index per pop — O(n²) on the holder-change drain path).
    fn drain(&mut self) -> Vec<QueuedRequest> {
        let mut out = Vec::with_capacity(self.live);
        let mut slot = self.head;
        while slot != NIL {
            let sl = &mut self.slab[slot as usize];
            out.push(sl.req.take().expect("linked slots are live"));
            let next = sl.next;
            self.free.push(slot);
            slot = next;
        }
        self.head = NIL;
        self.tail = NIL;
        self.live = 0;
        self.fit.clear();
        out
    }
}

struct LaneIter<'a> {
    lane: &'a Lane,
    cur: u32,
}

impl<'a> Iterator for LaneIter<'a> {
    type Item = &'a QueuedRequest;

    fn next(&mut self) -> Option<&'a QueuedRequest> {
        if self.cur == NIL {
            return None;
        }
        let sl = &self.lane.slab[self.cur as usize];
        self.cur = sl.next;
        Some(sl.req.as_ref().expect("linked slots are live"))
    }
}

/// The Q0–Q9 message-queue array.
#[derive(Debug, Default)]
pub struct PriorityQueues {
    lanes: [Lane; NUM_PRIORITIES],
    len: usize,
}

impl PriorityQueues {
    pub fn new() -> PriorityQueues {
        PriorityQueues::default()
    }

    /// Enqueue a request with no resolved prediction (unprofiled: it can
    /// drain or dispatch on holder change, but never gap-fills).
    pub fn push(&mut self, launch: KernelLaunch, now: SimTime) {
        self.push_predicted(launch, None, now);
    }

    /// Enqueue with the profiled duration pre-resolved (hot path).
    pub fn push_predicted(
        &mut self,
        launch: KernelLaunch,
        predicted: Option<crate::core::Duration>,
        now: SimTime,
    ) {
        let idx = launch.priority.index();
        self.lanes[idx].push(QueuedRequest {
            launch,
            enqueued_at: now,
            predicted,
        });
        self.len += 1;
    }

    /// Re-queue the remnant of a preempted kernel (DESIGN.md §8): the
    /// launch re-enters its priority lane at the FIFO tail, indexed by
    /// its **remaining** duration — a split fill whose leftover shrank
    /// below the next gap becomes selectable where the original would
    /// not fit. Delegates to [`PriorityQueues::push_predicted`]; the
    /// dedicated name exists so call sites and tests state intent.
    pub fn push_remnant(&mut self, launch: KernelLaunch, remaining: Duration, now: SimTime) {
        self.push_predicted(launch, Some(remaining), now);
    }

    /// Total queued requests across all priorities.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of requests queued at one priority.
    pub fn len_at(&self, p: Priority) -> usize {
        self.lanes[p.index()].live
    }

    /// Highest (numerically smallest) non-empty priority, scanning
    /// Q0 → Q9.
    pub fn highest_nonempty(&self) -> Option<Priority> {
        Priority::ALL
            .into_iter()
            .find(|p| self.lanes[p.index()].live > 0)
    }

    /// Iterate requests at one priority in FIFO order.
    pub fn iter_at(&self, p: Priority) -> impl Iterator<Item = &QueuedRequest> {
        self.lanes[p.index()].iter()
    }

    /// Pop the front request at one priority.
    pub fn pop_front_at(&mut self, p: Priority) -> Option<QueuedRequest> {
        let r = self.lanes[p.index()].pop_front();
        if r.is_some() {
            self.len -= 1;
        }
        r
    }

    /// Remove the request at FIFO position `idx` within priority `p`'s
    /// queue. Diagnostic/test helper — O(idx) link walk plus an index
    /// memmove; production removal goes through the `take_*_fit_at`
    /// selectors or `pop_front_at`, never this.
    pub fn remove_at(&mut self, p: Priority, idx: usize) -> Option<QueuedRequest> {
        let lane = &mut self.lanes[p.index()];
        let mut slot = lane.head;
        for _ in 0..idx {
            if slot == NIL {
                return None;
            }
            slot = lane.slab[slot as usize].next;
        }
        if slot == NIL {
            return None;
        }
        lane.unfit(slot);
        let req = lane.unlink(slot);
        self.len -= 1;
        Some(req)
    }

    /// **LongestFit** (Algorithm 2's selection): the request at priority
    /// `p` with the longest predicted duration strictly below `idle`;
    /// FIFO order breaks ties. O(log n) via the fit index.
    pub fn take_longest_fit_at(
        &mut self,
        p: Priority,
        idle: Duration,
    ) -> Option<(QueuedRequest, Duration)> {
        let lane = &mut self.lanes[p.index()];
        // Entries [0..i) have predicted < idle; the last of them has the
        // max fitting duration and — stamps sorting descending within a
        // duration — the oldest arrival among its ties.
        let i = lane.fit.partition_point(|&(d, _, _)| d < idle);
        if i == 0 {
            return None;
        }
        // A zero-duration maximum means only zero-SK requests fit; the
        // replaced scan's strict `predicted > best` (best starting at
        // zero) never selected those — preserve that exactly.
        if lane.fit[i - 1].0.is_zero() {
            return None;
        }
        let taken = lane.take_fit(i - 1);
        self.len -= 1;
        Some(taken)
    }

    /// **ShortestFit** ablation: shortest predicted duration strictly
    /// below `idle`; FIFO order breaks ties.
    pub fn take_shortest_fit_at(
        &mut self,
        p: Priority,
        idle: Duration,
    ) -> Option<(QueuedRequest, Duration)> {
        let lane = &mut self.lanes[p.index()];
        let &(d0, _, _) = lane.fit.first()?;
        if d0 >= idle {
            return None;
        }
        // Oldest among the d0 ties = last element of the d0 run.
        let i = lane.fit.partition_point(|&(d, _, _)| d <= d0);
        let taken = lane.take_fit(i - 1);
        self.len -= 1;
        Some(taken)
    }

    /// **FirstFit** ablation: the oldest profiled request fitting `idle`
    /// (FIFO scan — this policy is inherently order-dependent).
    pub fn take_first_fit_at(
        &mut self,
        p: Priority,
        idle: Duration,
    ) -> Option<(QueuedRequest, Duration)> {
        let lane = &mut self.lanes[p.index()];
        let mut slot = lane.head;
        while slot != NIL {
            let (next, predicted) = {
                let sl = &lane.slab[slot as usize];
                (sl.next, sl.req.as_ref().and_then(|r| r.predicted))
            };
            if let Some(d) = predicted {
                if d < idle {
                    lane.unfit(slot);
                    let req = lane.unlink(slot);
                    self.len -= 1;
                    return Some((req, d));
                }
            }
            slot = next;
        }
        None
    }

    /// Pop the overall-highest-priority request (Q0→Q9 scan, FIFO within
    /// a queue) — the plain priority dispatch used when draining.
    pub fn pop_highest(&mut self) -> Option<QueuedRequest> {
        let p = self.highest_nonempty()?;
        self.pop_front_at(p)
    }

    /// Drain every request at exactly priority `p`, FIFO order.
    pub fn drain_at(&mut self, p: Priority) -> Vec<QueuedRequest> {
        let out = self.lanes[p.index()].drain();
        self.len -= out.len();
        out
    }

    /// Remove every queued request matching `pred`, preserving FIFO
    /// order among the survivors. Returns the removed requests in
    /// priority-then-FIFO order.
    ///
    /// Lifecycle path, not the hot path: the daemon uses this to purge a
    /// departed service's parked launches on `Disconnect`
    /// (DESIGN.md §Daemon) so they cannot sit in the queues forever.
    /// Cost is O(total · fit-index memmove) in the worst case, which is
    /// fine at client-churn frequency.
    pub fn purge_where<F: FnMut(&KernelLaunch) -> bool>(
        &mut self,
        mut pred: F,
    ) -> Vec<QueuedRequest> {
        let mut out = Vec::new();
        for lane in &mut self.lanes {
            // Collect first (walking links), then unlink one by one —
            // `unfit` needs the slot still live to find its fit entry.
            let mut doomed = Vec::new();
            let mut slot = lane.head;
            while slot != NIL {
                let sl = &lane.slab[slot as usize];
                if pred(&sl.req.as_ref().expect("linked slots are live").launch) {
                    doomed.push(slot);
                }
                slot = sl.next;
            }
            for slot in doomed {
                lane.unfit(slot);
                out.push(lane.unlink(slot));
                self.len -= 1;
            }
        }
        out
    }

    /// Whether a launch of service `key` with kernel sequence `seq` is
    /// parked anywhere. Recovery-path lookup (`ReleaseQuery`), O(n).
    pub fn contains(&self, key: &crate::core::TaskKey, seq: u32) -> bool {
        self.lanes.iter().any(|lane| {
            lane.iter()
                .any(|r| r.launch.seq == seq && &r.launch.task_key == key)
        })
    }

    /// Remove every queued request (e.g. on reset). Returns them in
    /// priority-then-FIFO order.
    pub fn drain_all(&mut self) -> Vec<QueuedRequest> {
        let mut out = Vec::with_capacity(self.len);
        for p in Priority::ALL {
            out.extend(self.drain_at(p));
        }
        out
    }

    /// Debug check: every lane's fit index and links agree with its
    /// slots.
    #[cfg(test)]
    fn check_consistency(&self) {
        for lane in &self.lanes {
            assert_eq!(lane.iter().count(), lane.live, "link/live desync");
            let profiled = lane.iter().filter(|r| r.predicted.is_some()).count();
            assert_eq!(lane.fit.len(), profiled, "fit index out of sync");
            assert!(
                lane.fit
                    .windows(2)
                    .all(|w| (w[0].0, !w[0].1) < (w[1].0, !w[1].1)),
                "fit index out of order"
            );
            for &(d, stamp, slot) in &lane.fit {
                let sl = &lane.slab[slot as usize];
                assert_eq!(sl.stamp, stamp);
                assert_eq!(sl.req.as_ref().and_then(|r| r.predicted), Some(d));
            }
            assert_eq!(
                lane.free.len() + lane.live,
                lane.slab.len(),
                "slab leak"
            );
        }
        assert_eq!(self.len, self.lanes.iter().map(|l| l.live).sum::<usize>());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{
        Dim3, Duration, KernelHandle, KernelId, TaskHandle, TaskId, TaskKey,
    };

    fn launch(prio: Priority, seq: u32) -> KernelLaunch {
        KernelLaunch {
            task_key: TaskKey::new(format!("svc{}", prio.index())),
            task_handle: TaskHandle::UNBOUND,
            task_id: TaskId(0),
            kernel: KernelId::new("k", Dim3::x(1), Dim3::x(32)),
            kernel_handle: KernelHandle::UNBOUND,
            priority: prio,
            seq,
            true_duration: Duration::from_micros(10),
            issued_at: SimTime::ZERO,
        }
    }

    #[test]
    fn scan_order_is_q0_to_q9() {
        let mut q = PriorityQueues::new();
        q.push(launch(Priority::P5, 0), SimTime::ZERO);
        q.push(launch(Priority::P2, 0), SimTime::ZERO);
        q.push(launch(Priority::P8, 0), SimTime::ZERO);
        assert_eq!(q.len(), 3);
        assert_eq!(q.highest_nonempty(), Some(Priority::P2));
        assert_eq!(q.pop_highest().unwrap().launch.priority, Priority::P2);
        assert_eq!(q.pop_highest().unwrap().launch.priority, Priority::P5);
        assert_eq!(q.pop_highest().unwrap().launch.priority, Priority::P8);
        assert!(q.pop_highest().is_none());
        assert!(q.is_empty());
        q.check_consistency();
    }

    #[test]
    fn fifo_within_priority() {
        let mut q = PriorityQueues::new();
        q.push(launch(Priority::P3, 1), SimTime(1));
        q.push(launch(Priority::P3, 2), SimTime(2));
        q.push(launch(Priority::P3, 3), SimTime(3));
        assert_eq!(q.len_at(Priority::P3), 3);
        assert_eq!(q.pop_front_at(Priority::P3).unwrap().launch.seq, 1);
        assert_eq!(q.pop_front_at(Priority::P3).unwrap().launch.seq, 2);
        assert_eq!(q.pop_front_at(Priority::P3).unwrap().launch.seq, 3);
    }

    #[test]
    fn remove_at_specific_index() {
        let mut q = PriorityQueues::new();
        q.push(launch(Priority::P1, 10), SimTime::ZERO);
        q.push(launch(Priority::P1, 11), SimTime::ZERO);
        q.push(launch(Priority::P1, 12), SimTime::ZERO);
        let r = q.remove_at(Priority::P1, 1).unwrap();
        assert_eq!(r.launch.seq, 11);
        assert_eq!(q.len(), 2);
        let seqs: Vec<u32> = q.iter_at(Priority::P1).map(|r| r.launch.seq).collect();
        assert_eq!(seqs, vec![10, 12]);
        q.check_consistency();
        // Removing past the end is a no-op.
        assert!(q.remove_at(Priority::P1, 5).is_none());
    }

    #[test]
    fn drains() {
        let mut q = PriorityQueues::new();
        q.push(launch(Priority::P0, 0), SimTime::ZERO);
        q.push(launch(Priority::P4, 1), SimTime::ZERO);
        q.push(launch(Priority::P4, 2), SimTime::ZERO);
        let at4 = q.drain_at(Priority::P4);
        assert_eq!(at4.len(), 2);
        assert_eq!(q.len(), 1);
        let rest = q.drain_all();
        assert_eq!(rest.len(), 1);
        assert!(q.is_empty());
    }

    #[test]
    fn purge_where_removes_matching_and_keeps_fifo() {
        let mut q = PriorityQueues::new();
        // Interleave two services at one priority plus one at another,
        // with a mix of profiled and unprofiled requests.
        let mut mk = |key: &str, prio: Priority, seq: u32, us: Option<u64>| {
            let mut l = launch(prio, seq);
            l.task_key = TaskKey::new(key);
            q.push_predicted(l, us.map(Duration::from_micros), SimTime::ZERO);
        };
        mk("gone", Priority::P4, 0, Some(100));
        mk("stay", Priority::P4, 1, Some(200));
        mk("gone", Priority::P4, 2, None);
        mk("stay", Priority::P4, 3, None);
        mk("gone", Priority::P7, 4, Some(300));
        let purged = q.purge_where(|l| l.task_key == TaskKey::new("gone"));
        assert_eq!(purged.len(), 3);
        assert_eq!(q.len(), 2);
        assert!(!q.contains(&TaskKey::new("gone"), 0));
        assert!(q.contains(&TaskKey::new("stay"), 1));
        let seqs: Vec<u32> = q.iter_at(Priority::P4).map(|r| r.launch.seq).collect();
        assert_eq!(seqs, vec![1, 3], "survivors keep FIFO order");
        q.check_consistency();
        // The fit index forgot the purged profiled request too.
        assert!(q
            .take_longest_fit_at(Priority::P7, Duration::from_micros(500))
            .is_none());
        // Purging nothing is a no-op.
        assert!(q.purge_where(|l| l.task_key == TaskKey::new("gone")).is_empty());
        q.check_consistency();
    }

    fn push_us(q: &mut PriorityQueues, p: Priority, seq: u32, us: u64) {
        q.push_predicted(
            launch(p, seq),
            Some(Duration::from_micros(us)),
            SimTime::ZERO,
        );
    }

    #[test]
    fn longest_fit_is_strict_and_fifo_tiebroken() {
        let mut q = PriorityQueues::new();
        push_us(&mut q, Priority::P5, 0, 100);
        push_us(&mut q, Priority::P5, 1, 400);
        push_us(&mut q, Priority::P5, 2, 400); // tie: seq 1 is older
        push_us(&mut q, Priority::P5, 3, 900);
        let (req, d) = q
            .take_longest_fit_at(Priority::P5, Duration::from_micros(500))
            .unwrap();
        assert_eq!(d, Duration::from_micros(400));
        assert_eq!(req.launch.seq, 1, "FIFO tie-break: oldest 400us wins");
        q.check_consistency();
        // Strict bound: a 400us request does not fit a 400us window.
        let (req, _) = q
            .take_longest_fit_at(Priority::P5, Duration::from_micros(400))
            .unwrap();
        assert_eq!(req.launch.seq, 0, "only the 100us request fits");
        assert!(q
            .take_longest_fit_at(Priority::P5, Duration::from_micros(100))
            .is_none());
        assert_eq!(q.len(), 2);
        q.check_consistency();
    }

    #[test]
    fn shortest_fit_and_first_fit() {
        let build = || {
            let mut q = PriorityQueues::new();
            push_us(&mut q, Priority::P5, 0, 250);
            push_us(&mut q, Priority::P5, 1, 100);
            push_us(&mut q, Priority::P5, 2, 100); // tie: seq 1 older
            push_us(&mut q, Priority::P5, 3, 400);
            q
        };
        let idle = Duration::from_micros(500);
        let (req, d) = build().take_shortest_fit_at(Priority::P5, idle).unwrap();
        assert_eq!((req.launch.seq, d), (1, Duration::from_micros(100)));
        let (req, d) = build().take_first_fit_at(Priority::P5, idle).unwrap();
        assert_eq!((req.launch.seq, d), (0, Duration::from_micros(250)));
        // Nothing fits a tiny window under any policy.
        let tiny = Duration::from_micros(50);
        assert!(build().take_shortest_fit_at(Priority::P5, tiny).is_none());
        assert!(build().take_first_fit_at(Priority::P5, tiny).is_none());
        assert!(build().take_longest_fit_at(Priority::P5, tiny).is_none());
    }

    /// Parity with the replaced scan: `predicted > best` (best starting
    /// at zero) never picked zero-SK requests for LongestFit, while
    /// Shortest/FirstFit did select them.
    #[test]
    fn zero_duration_predictions_match_legacy_scan() {
        let mut q = PriorityQueues::new();
        push_us(&mut q, Priority::P3, 0, 0);
        assert!(q
            .take_longest_fit_at(Priority::P3, Duration::from_micros(500))
            .is_none());
        assert!(q
            .take_shortest_fit_at(Priority::P3, Duration::from_micros(500))
            .is_some());
        push_us(&mut q, Priority::P3, 1, 0);
        assert!(q
            .take_first_fit_at(Priority::P3, Duration::from_micros(500))
            .is_some());
        // With a positive candidate present, LongestFit picks it.
        push_us(&mut q, Priority::P3, 2, 0);
        push_us(&mut q, Priority::P3, 3, 40);
        let (req, d) = q
            .take_longest_fit_at(Priority::P3, Duration::from_micros(500))
            .unwrap();
        assert_eq!((req.launch.seq, d), (3, Duration::from_micros(40)));
        q.check_consistency();
    }

    #[test]
    fn unprofiled_requests_invisible_to_fit_index() {
        let mut q = PriorityQueues::new();
        q.push(launch(Priority::P2, 0), SimTime::ZERO); // no prediction
        push_us(&mut q, Priority::P2, 1, 50);
        let (req, _) = q
            .take_longest_fit_at(Priority::P2, Duration::from_micros(500))
            .unwrap();
        assert_eq!(req.launch.seq, 1);
        assert!(q
            .take_longest_fit_at(Priority::P2, Duration::from_micros(500))
            .is_none());
        // The unprofiled request still drains in FIFO order.
        assert_eq!(q.pop_front_at(Priority::P2).unwrap().launch.seq, 0);
        assert!(q.is_empty());
        q.check_consistency();
    }

    /// Interleaved pushes, fit-takes and pops keep the slab, links and
    /// fit index in sync (freelist reuse, FIFO preservation).
    #[test]
    fn mixed_operations_stay_consistent() {
        let mut q = PriorityQueues::new();
        let mut seq = 0u32;
        for round in 0..60u64 {
            for _ in 0..3 {
                push_us(&mut q, Priority::P4, seq, 10 + (seq as u64 * 37) % 500);
                seq += 1;
            }
            match round % 3 {
                0 => {
                    q.take_longest_fit_at(Priority::P4, Duration::from_micros(400));
                }
                1 => {
                    q.pop_front_at(Priority::P4);
                    q.take_shortest_fit_at(Priority::P4, Duration::from_micros(600));
                }
                _ => {
                    q.take_first_fit_at(Priority::P4, Duration::from_micros(200));
                    q.remove_at(Priority::P4, 0);
                }
            }
            q.check_consistency();
        }
        // FIFO order survives: seqs of remaining requests ascend.
        let seqs: Vec<u32> = q.iter_at(Priority::P4).map(|r| r.launch.seq).collect();
        let mut sorted = seqs.clone();
        sorted.sort_unstable();
        assert_eq!(seqs, sorted, "FIFO order broken: {seqs:?}");
        let drained = q.drain_all();
        assert_eq!(drained.len(), seqs.len());
        q.check_consistency();
        assert!(q.is_empty());
    }

    /// A preempted remnant re-enters its lane indexed by the *remaining*
    /// duration: it fits windows the full kernel would not, and loses
    /// FIFO seniority (tail re-entry) to same-duration peers.
    #[test]
    fn remnant_reindexes_by_remaining_duration() {
        let mut q = PriorityQueues::new();
        push_us(&mut q, Priority::P6, 0, 900); // full-size peer: never fits below
        let mut remnant = launch(Priority::P6, 1);
        remnant.true_duration = Duration::from_micros(900);
        q.push_remnant(remnant, Duration::from_micros(150), SimTime(5_000));
        q.check_consistency();
        // A 200 µs window only admits the remnant.
        let (req, d) = q
            .take_longest_fit_at(Priority::P6, Duration::from_micros(200))
            .unwrap();
        assert_eq!(req.launch.seq, 1);
        assert_eq!(d, Duration::from_micros(150), "indexed by remaining time");
        assert_eq!(req.enqueued_at, SimTime(5_000));
        assert_eq!(q.len_at(Priority::P6), 1, "original peer still parked");
        q.check_consistency();
    }

    /// The slab never grows past the high-water mark of live requests:
    /// sustained enqueue/select churn reuses freed slots.
    #[test]
    fn slab_is_bounded_by_peak_live() {
        let mut q = PriorityQueues::new();
        for i in 0..8 {
            push_us(&mut q, Priority::P5, i, 100 + i as u64);
        }
        for i in 8..5_000u32 {
            let (req, d) = q
                .take_longest_fit_at(Priority::P5, Duration::from_micros(1_000))
                .unwrap();
            let _ = req;
            push_us(&mut q, Priority::P5, i, d.nanos() / 1_000);
        }
        assert_eq!(q.len_at(Priority::P5), 8);
        assert_eq!(q.lanes[Priority::P5.index()].slab.len(), 8, "slab grew");
        q.check_consistency();
    }
}
