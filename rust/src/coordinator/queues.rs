//! The ten priority message queues Q0–Q9 (paper Fig 7).
//!
//! Each waiting kernel request sits in the queue matching its task's
//! priority. Within a queue, requests keep FIFO order. The scheduler
//! always scans Q0 → Q9, so high-priority requests are always considered
//! first — the structural guarantee behind the paper's "high-priority
//! tasks will be scheduled first".

use crate::core::{KernelLaunch, Priority, SimTime, NUM_PRIORITIES};
use std::collections::VecDeque;

/// A kernel request waiting in a priority queue.
#[derive(Debug, Clone)]
pub struct QueuedRequest {
    pub launch: KernelLaunch,
    /// When the request entered the queue (for wait metrics).
    pub enqueued_at: SimTime,
    /// Profiled execution time `SK`, resolved **once** at enqueue time so
    /// the BestPrioFit scan is a pure comparison loop (no hashing or
    /// string work on the hot path — see EXPERIMENTS.md §Perf).
    pub predicted: Option<crate::core::Duration>,
}

/// The Q0–Q9 message-queue array.
#[derive(Debug, Default)]
pub struct PriorityQueues {
    queues: [VecDeque<QueuedRequest>; NUM_PRIORITIES],
    len: usize,
}

impl PriorityQueues {
    pub fn new() -> PriorityQueues {
        PriorityQueues::default()
    }

    /// Enqueue a request into the queue of its priority (prediction
    /// unresolved; BestPrioFit will fall back to a store lookup).
    pub fn push(&mut self, launch: KernelLaunch, now: SimTime) {
        self.push_predicted(launch, None, now);
    }

    /// Enqueue with the profiled duration pre-resolved (hot path).
    pub fn push_predicted(
        &mut self,
        launch: KernelLaunch,
        predicted: Option<crate::core::Duration>,
        now: SimTime,
    ) {
        let idx = launch.priority.index();
        self.queues[idx].push_back(QueuedRequest {
            launch,
            enqueued_at: now,
            predicted,
        });
        self.len += 1;
    }

    /// Total queued requests across all priorities.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of requests queued at one priority.
    pub fn len_at(&self, p: Priority) -> usize {
        self.queues[p.index()].len()
    }

    /// Highest (numerically smallest) non-empty priority, scanning
    /// Q0 → Q9.
    pub fn highest_nonempty(&self) -> Option<Priority> {
        Priority::ALL
            .into_iter()
            .find(|p| !self.queues[p.index()].is_empty())
    }

    /// Iterate requests at one priority in FIFO order.
    pub fn iter_at(&self, p: Priority) -> impl Iterator<Item = &QueuedRequest> {
        self.queues[p.index()].iter()
    }

    /// Pop the front request at one priority.
    pub fn pop_front_at(&mut self, p: Priority) -> Option<QueuedRequest> {
        let r = self.queues[p.index()].pop_front();
        if r.is_some() {
            self.len -= 1;
        }
        r
    }

    /// Remove the request at position `idx` within priority `p`'s queue
    /// (used by BestPrioFit after it has chosen a specific request).
    pub fn remove_at(&mut self, p: Priority, idx: usize) -> Option<QueuedRequest> {
        let r = self.queues[p.index()].remove(idx);
        if r.is_some() {
            self.len -= 1;
        }
        r
    }

    /// Pop the overall-highest-priority request (Q0→Q9 scan, FIFO within
    /// a queue) — the plain priority dispatch used when draining.
    pub fn pop_highest(&mut self) -> Option<QueuedRequest> {
        let p = self.highest_nonempty()?;
        self.pop_front_at(p)
    }

    /// Drain every request at exactly priority `p`, FIFO order.
    pub fn drain_at(&mut self, p: Priority) -> Vec<QueuedRequest> {
        let q = &mut self.queues[p.index()];
        self.len -= q.len();
        q.drain(..).collect()
    }

    /// Remove every queued request (e.g. on reset). Returns them in
    /// priority-then-FIFO order.
    pub fn drain_all(&mut self) -> Vec<QueuedRequest> {
        let mut out = Vec::with_capacity(self.len);
        for p in Priority::ALL {
            out.extend(self.queues[p.index()].drain(..));
        }
        self.len = 0;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dim3, Duration, KernelId, TaskId, TaskKey};

    fn launch(prio: Priority, seq: u32) -> KernelLaunch {
        KernelLaunch {
            task_key: TaskKey::new(format!("svc{}", prio.index())),
            task_id: TaskId(0),
            kernel: KernelId::new("k", Dim3::x(1), Dim3::x(32)),
            priority: prio,
            seq,
            true_duration: Duration::from_micros(10),
            issued_at: SimTime::ZERO,
        }
    }

    #[test]
    fn scan_order_is_q0_to_q9() {
        let mut q = PriorityQueues::new();
        q.push(launch(Priority::P5, 0), SimTime::ZERO);
        q.push(launch(Priority::P2, 0), SimTime::ZERO);
        q.push(launch(Priority::P8, 0), SimTime::ZERO);
        assert_eq!(q.len(), 3);
        assert_eq!(q.highest_nonempty(), Some(Priority::P2));
        assert_eq!(q.pop_highest().unwrap().launch.priority, Priority::P2);
        assert_eq!(q.pop_highest().unwrap().launch.priority, Priority::P5);
        assert_eq!(q.pop_highest().unwrap().launch.priority, Priority::P8);
        assert!(q.pop_highest().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn fifo_within_priority() {
        let mut q = PriorityQueues::new();
        q.push(launch(Priority::P3, 1), SimTime(1));
        q.push(launch(Priority::P3, 2), SimTime(2));
        q.push(launch(Priority::P3, 3), SimTime(3));
        assert_eq!(q.len_at(Priority::P3), 3);
        assert_eq!(q.pop_front_at(Priority::P3).unwrap().launch.seq, 1);
        assert_eq!(q.pop_front_at(Priority::P3).unwrap().launch.seq, 2);
        assert_eq!(q.pop_front_at(Priority::P3).unwrap().launch.seq, 3);
    }

    #[test]
    fn remove_at_specific_index() {
        let mut q = PriorityQueues::new();
        q.push(launch(Priority::P1, 10), SimTime::ZERO);
        q.push(launch(Priority::P1, 11), SimTime::ZERO);
        q.push(launch(Priority::P1, 12), SimTime::ZERO);
        let r = q.remove_at(Priority::P1, 1).unwrap();
        assert_eq!(r.launch.seq, 11);
        assert_eq!(q.len(), 2);
        let seqs: Vec<u32> = q.iter_at(Priority::P1).map(|r| r.launch.seq).collect();
        assert_eq!(seqs, vec![10, 12]);
    }

    #[test]
    fn drains() {
        let mut q = PriorityQueues::new();
        q.push(launch(Priority::P0, 0), SimTime::ZERO);
        q.push(launch(Priority::P4, 1), SimTime::ZERO);
        q.push(launch(Priority::P4, 2), SimTime::ZERO);
        let at4 = q.drain_at(Priority::P4);
        assert_eq!(at4.len(), 2);
        assert_eq!(q.len(), 1);
        let rest = q.drain_all();
        assert_eq!(rest.len(), 1);
        assert!(q.is_empty());
    }
}
