//! **Algorithm 1 — the FIKIT procedure** (paper Fig 9): fill a
//! high-priority task's predicted inter-kernel idle gap with
//! lower-priority kernels chosen by
//! [`best_prio_fit`](super::best_prio_fit::best_prio_fit).
//!
//! A [`FillWindow`] is opened when the GPU-holding task's kernel
//! completes and its profiled following gap `SG` exceeds the small-gap
//! threshold ε (0.1 ms — the typical cost of just launching a kernel, so
//! smaller gaps are not worth filling). The window carries:
//!
//! * `budget` — the remaining idle time per Algorithm 1's accounting
//!   (`idleTime -= fillKrnTime` for every fill launched), and
//! * `predicted_end` — the wall-clock end of the predicted gap, so fills
//!   triggered *late* in the window (by newly arriving low-priority
//!   requests) cannot overrun into the predicted arrival of the holder's
//!   next kernel.
//!
//! The window is closed early by the feedback mechanism (see
//! [`super::feedback`]) when the holder's next kernel actually arrives.

use super::best_prio_fit::{select_fit, FillPolicy, Fit};
use super::queues::PriorityQueues;
use crate::core::{Duration, Error, SimTime, TaskHandle};
use std::fmt;
use std::str::FromStr;

/// Default small-gap threshold ε: "a kernel launched on the GPU typically
/// costs 0.1 ms to 2 ms; the function avoids filling negligible idle gaps
/// smaller than 0.1 ms" (paper, Algorithm 1 commentary).
pub const DEFAULT_EPSILON: Duration = Duration(100_000);

/// Default modeled cost of interrupting an in-flight kernel (driver-level
/// stop + context drain + relaunch bookkeeping): 20 µs, in the band
/// real-time GPU preemption work reports for kernel-boundary interrupts
/// (arXiv 2401.16529). Charged as *dead* device time, never as busy.
pub const DEFAULT_PREEMPT_COST: Duration = Duration(20_000);

/// Default slice granularity for [`PreemptionPolicy::Split`]: a running
/// fill kernel may be shortened only at 250 µs boundaries from its start
/// (the modeled sub-kernel checkpoint interval).
pub const DEFAULT_SPLIT_SLICE: Duration = Duration(250_000);

/// Default executed-fraction threshold for [`PreemptionPolicy::Hybrid`]:
/// below it the partial work is cheap to discard (evict), at or above it
/// the kernel is worth finishing to its next slice boundary (split).
pub const DEFAULT_HYBRID_THRESHOLD: f64 = 0.5;

/// What the scheduler may do to an in-flight low-priority fill kernel
/// when a high-priority launch would otherwise miss its gap by more than
/// the modeled preemption cost (DESIGN.md §8).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum PreemptionPolicy {
    /// Never reclaim in-flight fills — the paper's baseline behaviour
    /// ("overhead 2" stands in full). Byte-identical to the pre-preemption
    /// simulator.
    #[default]
    None,
    /// Cancel the fill outright: partial execution is wasted (stays
    /// busy), the *full* kernel re-queues with its original prediction.
    Evict,
    /// Shorten the fill at the next `min_slice` boundary from its start;
    /// the executed prefix is kept and the remnant re-queues indexed by
    /// its remaining duration.
    Split {
        /// Slice granularity (> 0); cuts land on `start + k·min_slice`.
        min_slice: Duration,
    },
    /// Evict when the executed fraction at the cut is below `threshold`
    /// (little work to waste), split otherwise (too much to throw away).
    Hybrid {
        /// Executed-fraction pivot in `(0, 1]`.
        threshold: f64,
    },
}

impl PreemptionPolicy {
    /// A `Split` policy with the default slice granularity.
    pub fn split() -> PreemptionPolicy {
        PreemptionPolicy::Split {
            min_slice: DEFAULT_SPLIT_SLICE,
        }
    }

    /// A `Hybrid` policy with the default executed-fraction threshold.
    pub fn hybrid() -> PreemptionPolicy {
        PreemptionPolicy::Hybrid {
            threshold: DEFAULT_HYBRID_THRESHOLD,
        }
    }

    /// Stable short name (the config/CLI token, without parameters).
    pub fn name(&self) -> &'static str {
        match self {
            PreemptionPolicy::None => "none",
            PreemptionPolicy::Evict => "evict",
            PreemptionPolicy::Split { .. } => "split",
            PreemptionPolicy::Hybrid { .. } => "hybrid",
        }
    }
}

impl fmt::Display for PreemptionPolicy {
    /// Round-trippable token: `none`, `evict`, `split:<µs>`,
    /// `hybrid:<threshold>` — what `ExperimentConfig::to_json` persists.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PreemptionPolicy::None => write!(f, "none"),
            PreemptionPolicy::Evict => write!(f, "evict"),
            PreemptionPolicy::Split { min_slice } => {
                write!(f, "split:{}", min_slice.nanos() / 1_000)
            }
            PreemptionPolicy::Hybrid { threshold } => write!(f, "hybrid:{threshold}"),
        }
    }
}

impl FromStr for PreemptionPolicy {
    type Err = Error;

    fn from_str(s: &str) -> Result<PreemptionPolicy, Error> {
        let (kind, param) = match s.split_once(':') {
            Some((k, p)) => (k, Some(p)),
            None => (s, None),
        };
        match kind {
            "none" => match param {
                None => Ok(PreemptionPolicy::None),
                Some(p) => Err(Error::Config(format!(
                    "preempt policy 'none' takes no parameter (got ':{p}')"
                ))),
            },
            "evict" => match param {
                None => Ok(PreemptionPolicy::Evict),
                Some(p) => Err(Error::Config(format!(
                    "preempt policy 'evict' takes no parameter (got ':{p}')"
                ))),
            },
            "split" => {
                let min_slice = match param {
                    None => DEFAULT_SPLIT_SLICE,
                    Some(p) => {
                        let us = p.parse::<u64>().map_err(|_| {
                            Error::Config(format!(
                                "bad split slice '{p}' (want microseconds as an integer)"
                            ))
                        })?;
                        Duration::from_micros(us)
                    }
                };
                if min_slice.is_zero() {
                    return Err(Error::Config("split slice must be > 0".into()));
                }
                Ok(PreemptionPolicy::Split { min_slice })
            }
            "hybrid" => {
                let threshold = match param {
                    None => DEFAULT_HYBRID_THRESHOLD,
                    Some(p) => p.parse::<f64>().map_err(|_| {
                        Error::Config(format!("bad hybrid threshold '{p}' (want a float)"))
                    })?,
                };
                if !(threshold > 0.0 && threshold <= 1.0) {
                    return Err(Error::Config(format!(
                        "hybrid threshold must be in (0, 1] (got {threshold})"
                    )));
                }
                Ok(PreemptionPolicy::Hybrid { threshold })
            }
            other => Err(Error::Config(format!(
                "unknown preempt policy '{other}' (want none, evict, split[:us] \
                 or hybrid[:threshold])"
            ))),
        }
    }
}

/// An open gap-filling window for the GPU-holding task.
#[derive(Debug, Clone)]
pub struct FillWindow {
    /// The task whose inter-kernel gap is being filled (interned handle;
    /// holder comparisons on the hot path are integer compares).
    pub holder: TaskHandle,
    /// When the gap began (holder kernel completion time).
    pub opened_at: SimTime,
    /// Predicted end of the gap: `opened_at + SG[kernel]`.
    pub predicted_end: SimTime,
    /// Remaining fill budget (Algorithm 1's `idleTime` variable).
    pub budget: Duration,
    /// Fills launched from this window.
    pub fills: u32,
}

impl FillWindow {
    /// Open a window for a predicted gap, or return `None` when the gap
    /// is at-or-below ε (Algorithm 1 lines 6–8: skip small gaps).
    pub fn open(
        holder: TaskHandle,
        now: SimTime,
        predicted_gap: Duration,
        epsilon: Duration,
    ) -> Option<FillWindow> {
        if predicted_gap <= epsilon {
            return None;
        }
        Some(FillWindow {
            holder,
            opened_at: now,
            predicted_end: now + predicted_gap,
            budget: predicted_gap,
            fills: 0,
        })
    }

    /// Idle time still fillable as of `now`: the Algorithm-1 budget,
    /// further capped by the wall-clock remainder of the predicted gap.
    pub fn remaining(&self, now: SimTime) -> Duration {
        let wall = self.predicted_end - now; // saturating
        self.budget.min(wall)
    }

    /// Is the window exhausted at `now`?
    pub fn is_exhausted(&self, now: SimTime) -> bool {
        self.remaining(now).is_zero()
    }

    /// Force-close the window (feedback early stop).
    pub fn close(&mut self) {
        self.budget = Duration::ZERO;
    }
}

/// Run the FIKIT procedure (Algorithm 1 lines 9–16) against an open
/// window: repeatedly select fitting kernels and charge their *predicted*
/// durations to the budget. Returns the fills to launch, in order.
pub fn fikit_fill(
    window: &mut FillWindow,
    now: SimTime,
    queues: &mut PriorityQueues,
) -> Vec<Fit> {
    fikit_fill_with(window, now, queues, FillPolicy::LongestFit)
}

/// Policy-parameterized variant (fill-policy ablation).
pub fn fikit_fill_with(
    window: &mut FillWindow,
    now: SimTime,
    queues: &mut PriorityQueues,
    policy: FillPolicy,
) -> Vec<Fit> {
    let mut fills = Vec::new();
    // While we have a gap (line 9)...
    loop {
        let remaining = window.remaining(now);
        if remaining.is_zero() {
            break;
        }
        // ...find the best fitting kernel request (line 10). Predictions
        // were resolved at enqueue time; no profile store is consulted.
        let Some(fit) = select_fit(queues, remaining, policy) else {
            break; // no suitable kernel (lines 11-13)
        };
        // Launch it and charge the budget (lines 14-15).
        window.budget = window.budget.saturating_sub(fit.predicted);
        window.fills += 1;
        fills.push(fit);
    }
    fills
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dim3, KernelHandle, KernelId, KernelLaunch, Priority, TaskId, TaskKey};

    const HOLDER: TaskHandle = TaskHandle::UNBOUND;

    fn kid(name: &str) -> KernelId {
        KernelId::new(name, Dim3::x(1), Dim3::x(64))
    }

    fn launch(key: &str, kernel: &str, prio: Priority) -> KernelLaunch {
        KernelLaunch {
            task_key: TaskKey::new(key),
            task_handle: TaskHandle::UNBOUND,
            task_id: TaskId(0),
            kernel: kid(kernel),
            kernel_handle: KernelHandle::UNBOUND,
            priority: prio,
            seq: 0,
            true_duration: Duration::from_micros(1),
            issued_at: SimTime::ZERO,
        }
    }

    /// Enqueue with the prediction pre-resolved (as the scheduler does
    /// from the attach-time ResolvedProfile).
    fn push(q: &mut PriorityQueues, key: &str, kernel: &str, prio: Priority, us: u64) {
        q.push_predicted(
            launch(key, kernel, prio),
            Some(Duration::from_micros(us)),
            SimTime::ZERO,
        );
    }

    #[test]
    fn small_gaps_are_skipped() {
        assert!(FillWindow::open(
            HOLDER,
            SimTime::ZERO,
            Duration::from_micros(100),
            DEFAULT_EPSILON
        )
        .is_none());
        assert!(
            FillWindow::open(HOLDER, SimTime::ZERO, DEFAULT_EPSILON, DEFAULT_EPSILON).is_none()
        );
        assert!(FillWindow::open(
            HOLDER,
            SimTime::ZERO,
            Duration::from_micros(101),
            DEFAULT_EPSILON
        )
        .is_some());
    }

    #[test]
    fn fills_until_budget_exhausted() {
        // Gap of 1ms; queued kernels of 400us each (one per fill round,
        // as in the real system where each waiting task holds one
        // pending request).
        let mut w = FillWindow::open(
            HOLDER,
            SimTime::ZERO,
            Duration::from_millis(1),
            DEFAULT_EPSILON,
        )
        .unwrap();
        let mut q = PriorityQueues::new();
        push(&mut q, "lo", "k400", Priority::P5, 400);
        push(&mut q, "lo", "k400", Priority::P5, 400);
        push(&mut q, "lo", "k400", Priority::P5, 400);

        let fills = fikit_fill(&mut w, SimTime::ZERO, &mut q);
        // 1000us budget: 400 + 400 launched; remaining 200us < 400 → stop.
        assert_eq!(fills.len(), 2);
        assert_eq!(w.fills, 2);
        assert_eq!(w.budget, Duration::from_micros(200));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn late_trigger_capped_by_wall_clock() {
        // 1ms predicted gap opened at t=0; a fill attempt at t=0.9ms can
        // only use the remaining 0.1ms of wall clock even though the
        // budget is still 1ms.
        let mut w = FillWindow::open(
            HOLDER,
            SimTime::ZERO,
            Duration::from_millis(1),
            DEFAULT_EPSILON,
        )
        .unwrap();
        let mut q = PriorityQueues::new();
        push(&mut q, "lo", "k400", Priority::P5, 400);

        let late = SimTime(900_000);
        assert_eq!(w.remaining(late), Duration::from_micros(100));
        let fills = fikit_fill(&mut w, late, &mut q);
        assert!(fills.is_empty(), "400us kernel must not fit 100us remainder");
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn close_stops_filling() {
        let mut w = FillWindow::open(
            HOLDER,
            SimTime::ZERO,
            Duration::from_millis(1),
            DEFAULT_EPSILON,
        )
        .unwrap();
        w.close();
        assert!(w.is_exhausted(SimTime::ZERO));
        let mut q = PriorityQueues::new();
        push(&mut q, "lo", "k", Priority::P5, 100);
        assert!(fikit_fill(&mut w, SimTime::ZERO, &mut q).is_empty());
    }

    #[test]
    fn preempt_tokens_round_trip() {
        for p in [
            PreemptionPolicy::None,
            PreemptionPolicy::Evict,
            PreemptionPolicy::Split {
                min_slice: Duration::from_micros(125),
            },
            PreemptionPolicy::Hybrid { threshold: 0.75 },
        ] {
            let token = p.to_string();
            assert_eq!(token.parse::<PreemptionPolicy>().unwrap(), p);
        }
    }

    #[test]
    fn bare_preempt_tokens_get_defaults() {
        assert_eq!(
            "split".parse::<PreemptionPolicy>().unwrap(),
            PreemptionPolicy::Split {
                min_slice: DEFAULT_SPLIT_SLICE
            }
        );
        assert_eq!(
            "hybrid".parse::<PreemptionPolicy>().unwrap(),
            PreemptionPolicy::Hybrid {
                threshold: DEFAULT_HYBRID_THRESHOLD
            }
        );
        assert_eq!("none".parse::<PreemptionPolicy>().unwrap(), PreemptionPolicy::None);
        assert_eq!(PreemptionPolicy::default(), PreemptionPolicy::None);
    }

    #[test]
    fn bad_preempt_tokens_are_rejected() {
        assert!("pause".parse::<PreemptionPolicy>().is_err());
        assert!("none:1".parse::<PreemptionPolicy>().is_err());
        assert!("evict:now".parse::<PreemptionPolicy>().is_err());
        assert!("split:0".parse::<PreemptionPolicy>().is_err());
        assert!("split:fast".parse::<PreemptionPolicy>().is_err());
        assert!("hybrid:0".parse::<PreemptionPolicy>().is_err());
        assert!("hybrid:1.5".parse::<PreemptionPolicy>().is_err());
    }

    #[test]
    fn priority_order_respected_across_fills() {
        let mut w = FillWindow::open(
            HOLDER,
            SimTime::ZERO,
            Duration::from_millis(1),
            DEFAULT_EPSILON,
        )
        .unwrap();
        let mut q = PriorityQueues::new();
        push(&mut q, "low", "k", Priority::P8, 300);
        push(&mut q, "mid", "k", Priority::P4, 300);

        let fills = fikit_fill(&mut w, SimTime::ZERO, &mut q);
        assert_eq!(fills.len(), 2);
        assert_eq!(fills[0].launch.task_key, TaskKey::new("mid"));
        assert_eq!(fills[1].launch.task_key, TaskKey::new("low"));
    }
}
