//! The simulation driver: runs a set of services on the simulated GPU
//! under a [`Mode`] and produces an [`ExperimentReport`].
//!
//! This is where the three execution modes differ:
//!
//! * **Sharing** — every launch goes straight to the device FIFO in
//!   launch order (NVIDIA default time-slice sharing).
//! * **Exclusive** — a global lock serializes *tasks* in arrival order
//!   (the paper's "external program orchestrates tasks sequentially").
//! * **Fikit** — launches are routed through the
//!   [`FikitScheduler`](super::scheduler::FikitScheduler); services
//!   without profiles are first measured (profiling pass), exactly the
//!   paper's measurement → sharing lifecycle (Fig 3).

use super::best_prio_fit::{plan_preempt, PreemptAction};
use super::fikit::PreemptionPolicy;
use super::scheduler::{FikitScheduler, SchedulerConfig, SchedulerStats, Submission};
use super::Mode;
use crate::config::{ExperimentConfig, ServiceConfig};
use crate::core::{
    Duration, Interner, KernelLaunch, LaunchSource, Result, SimTime, TaskId, TaskKey,
};
use crate::metrics::{JctStats, TextTable, Timeline, TimelinePoint};
use crate::profile::{
    OnlineRefiner, ProfileStore, RefinerStats, ResolvedProfile, SymbolResolver, TaskProfile,
};
use crate::simulator::{
    DeviceStats, Event, EventQueue, KernelArena, ProcessAction, RecordSlot, ServiceProcess,
    SimDevice, Stage, TaskOutcome,
};
use crate::workload::{InvocationPattern, Service};
use std::collections::{HashMap, VecDeque};

/// Per-service results of an experiment.
#[derive(Debug, Clone)]
pub struct ServiceReport {
    pub key: TaskKey,
    pub model: crate::workload::ModelKind,
    pub priority: crate::core::Priority,
    pub jct: JctStats,
    pub completed: usize,
    /// Per-arrival JCT timeline (Fig 21 material).
    pub timeline: Timeline,
}

/// Full results of one experiment run.
#[derive(Debug)]
pub struct ExperimentReport {
    pub mode: Mode,
    pub services: Vec<ServiceReport>,
    pub outcomes: Vec<TaskOutcome>,
    pub device: DeviceStats,
    pub scheduler: Option<SchedulerStats>,
    /// Online refinement counters (FIKIT mode with `cfg.online.enabled`).
    pub refiner: Option<RefinerStats>,
    /// Simulated time at which the run ended.
    pub sim_end: SimTime,
    /// Events processed (sim-perf metric).
    pub events: u64,
    /// Real wall-clock time the simulation took.
    pub wall: std::time::Duration,
}

impl ExperimentReport {
    /// Report for one service by task key.
    pub fn service(&self, key: &TaskKey) -> Option<&ServiceReport> {
        self.services.iter().find(|s| &s.key == key)
    }

    /// JCT stats of the first service matching `priority`.
    pub fn by_priority(&self, priority: crate::core::Priority) -> Option<&ServiceReport> {
        self.services.iter().find(|s| s.priority == priority)
    }

    /// Outcomes restricted to arrivals inside `[0, window_end]` — the
    /// paper's "fully overlapping window" methodology (§4.5.1 collects
    /// only the first 16 s where both services were active).
    pub fn jct_in_window(&self, key: &TaskKey, window_end: SimTime) -> JctStats {
        JctStats::from_durations(
            self.outcomes
                .iter()
                .filter(|o| &o.task_key == key && o.arrival <= window_end)
                .map(|o| o.jct())
                .collect(),
        )
    }

    /// Simulated time at which either service stopped having tasks
    /// in flight — the overlap window end used by §4.5.1.
    pub fn overlap_end(&self) -> SimTime {
        self.services
            .iter()
            .map(|s| {
                s.timeline
                    .points
                    .last()
                    .map(|p| p.arrival + p.jct)
                    .unwrap_or(SimTime::ZERO)
            })
            .min()
            .unwrap_or(SimTime::ZERO)
    }

    /// Human-readable summary table.
    pub fn summary(&self) -> String {
        let mut t = TextTable::new(&[
            "service", "prio", "tasks", "mean JCT", "p95", "CV", "total",
        ]);
        for s in &self.services {
            t.row(vec![
                s.key.to_string(),
                s.priority.to_string(),
                s.completed.to_string(),
                format!("{:.3}ms", s.jct.mean_ms()),
                format!("{:.3}ms", s.jct.p95.as_millis_f64()),
                format!("{:.3}", s.jct.cv),
                format!("{:.3}s", s.jct.total.as_secs_f64()),
            ]);
        }
        let mut out = format!("mode={} sim_end={} events={}\n", self.mode, self.sim_end, self.events);
        out.push_str(&t.render());
        if let Some(sched) = &self.scheduler {
            out.push_str(&format!(
                "scheduler: direct={} queued={} fills={} drained={} preemptions={} windows={} early_stops={}\n",
                sched.direct,
                sched.queued,
                sched.fills,
                sched.drained,
                sched.preemptions,
                sched.feedback.windows,
                sched.feedback.early_stops,
            ));
            // Kernel-level preemption line only when the tier fired:
            // under `PreemptionPolicy::None` (and in runs where the
            // probe never triggered) the summary stays byte-identical
            // to pre-preemption reports.
            if sched.preempt.requeues > 0 {
                let p = &sched.preempt;
                out.push_str(&format!(
                    "preempt: evictions={} cuts={} splits={} requeues={} reclaimed={} wasted={}\n",
                    p.evictions, p.cuts, p.splits, p.requeues, p.reclaimed, p.wasted,
                ));
            }
        }
        if let Some(r) = &self.refiner {
            out.push_str(&format!(
                "refiner: obs={}+{} drifts={} snapshots={} max_epoch={}\n",
                r.exec_observations,
                r.gap_observations,
                r.drifts,
                r.snapshots_published,
                r.max_epoch,
            ));
        }
        out
    }
}

/// Result of profiling one service (measurement stage).
#[derive(Debug)]
pub struct ProfilingResult {
    pub profile: TaskProfile,
    /// JCTs of the measurement-stage runs (Fig 15 material).
    pub outcomes: Vec<TaskOutcome>,
}

/// Reusable event-core storage: the event wheel's buckets/overflow heap
/// and the kernel-record arena's slab. A [`GpuSim`] built with
/// [`GpuSim::with_scratch`] takes the storage and
/// [`GpuSim::reclaim_scratch`] / [`run_with_profiles_scratch`] return it
/// cleared — so a multi-run sweep (fig13–21, `fikit drift`, cluster solo
/// baselines) allocates the event core once instead of per run.
#[derive(Debug, Default)]
pub struct SimScratch {
    events: EventQueue,
    arena: KernelArena,
}

impl SimScratch {
    pub fn new() -> SimScratch {
        SimScratch::default()
    }
}

/// Derive a per-service seed from the experiment seed (splitmix64 step —
/// decorrelates services without external deps).
fn derive_seed(root: u64, idx: u64, salt: u64) -> u64 {
    let mut z = root
        .wrapping_add(salt)
        .wrapping_add(idx.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Run the measurement stage for one service: solo on the GPU, `runs`
/// back-to-back tasks with kernel timing events (paper Fig 6).
pub fn profile_service(cfg: &ExperimentConfig, svc: &ServiceConfig) -> Result<ProfilingResult> {
    profile_service_scratch(cfg, svc, &mut SimScratch::new())
}

/// [`profile_service`] with caller-owned event-core storage — sweeps
/// profiling many services reuse one [`SimScratch`] across passes.
pub fn profile_service_scratch(
    cfg: &ExperimentConfig,
    svc: &ServiceConfig,
    scratch: &mut SimScratch,
) -> Result<ProfilingResult> {
    let runs = cfg.measurement.runs;
    let service = Service {
        pattern: InvocationPattern::BackToBack { count: runs },
        ..svc.to_service()
    };
    let solo = ExperimentConfig {
        mode: Mode::Sharing, // solo: direct submission, no co-tenant
        services: vec![svc.clone()],
        ..cfg.clone()
    };
    let empty_store = ProfileStore::new();
    let mut sim = GpuSim::with_scratch(&solo, &empty_store, scratch)?;
    // Replace the process with a measuring-stage one.
    let measuring_proc = sim.make_process(&service, 0, Stage::Measuring);
    sim.procs[0] = measuring_proc;
    sim.rebind(0);
    sim.run();
    let profile = sim.procs[0]
        .finish_measurement()
        .ok_or_else(|| crate::core::Error::Invariant("measurement did not complete".into()))?;
    let outcomes = std::mem::take(&mut sim.outcomes);
    sim.reclaim_scratch(scratch);
    Ok(ProfilingResult { profile, outcomes })
}

/// Run a full experiment. In FIKIT mode, services are profiled first
/// (measurement stage) exactly as the paper's lifecycle prescribes.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<ExperimentReport> {
    run_experiment_scratch(cfg, &mut SimScratch::new())
}

/// [`run_experiment`] with caller-owned event-core storage.
pub fn run_experiment_scratch(
    cfg: &ExperimentConfig,
    scratch: &mut SimScratch,
) -> Result<ExperimentReport> {
    cfg.validate()?;
    let mut store = ProfileStore::new();
    if cfg.mode == Mode::Fikit {
        for svc in &cfg.services {
            store.insert(profile_service_scratch(cfg, svc, scratch)?.profile);
        }
    }
    run_with_profiles_scratch(cfg, &store, scratch)
}

/// Run an experiment against an existing profile store (lets experiments
/// amortize one profiling pass across many runs, like a real deployment).
pub fn run_with_profiles(cfg: &ExperimentConfig, store: &ProfileStore) -> Result<ExperimentReport> {
    run_with_profiles_scratch(cfg, store, &mut SimScratch::new())
}

/// [`run_with_profiles`] with caller-owned event-core storage.
pub fn run_with_profiles_scratch(
    cfg: &ExperimentConfig,
    store: &ProfileStore,
    scratch: &mut SimScratch,
) -> Result<ExperimentReport> {
    cfg.validate()?;
    if cfg.mode == Mode::Fikit {
        for svc in &cfg.services {
            let key = svc.to_service().key;
            store.require(&key)?;
        }
    }
    let start = std::time::Instant::now();
    let mut sim = GpuSim::with_scratch(cfg, store, scratch)?;
    sim.run();
    Ok(sim.into_report_reclaiming(start.elapsed(), Some(scratch)))
}

/// What detaching a service left behind (DESIGN.md §8: departures drain,
/// they never cut a task mid-kernel).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DetachOutcome {
    /// The service was idle: nothing left on this GPU.
    Idle,
    /// A task is still in flight; it will run to completion (and only
    /// then is the service fully gone from this GPU).
    Draining,
}

/// An in-flight gap-fill kernel the preempt probe may still reclaim
/// (ADR-007). Tracked only in FIKIT mode with a non-`None`
/// [`PreemptionPolicy`]; the vec is in submission (= device FIFO tail)
/// order and is cleared the moment a non-fill launch is priced in —
/// after that, nothing behind the direct kernel can move up anyway.
struct LiveFill {
    /// Arena slot of the fill's in-flight record.
    rec: RecordSlot,
    /// Owning process slot.
    svc: usize,
    /// The original launch, kept whole so an eviction can re-queue it
    /// verbatim (clone cost is refcount bumps — ids are `Arc<str>`).
    launch: KernelLaunch,
    /// The profiled `SK` the fill was parked with; an evicted whole
    /// re-enters the queues at the same index. (A split remnant is
    /// re-indexed by its remaining duration instead.)
    predicted: Option<Duration>,
    /// Modeled device-side span.
    started_at: SimTime,
    finished_at: SimTime,
}

/// The discrete-event simulation state of **one GPU**: its device FIFO,
/// its hosted service processes, and (in FIKIT mode) its coordinator.
///
/// Two ways to drive it:
///
/// * the one-shot path ([`run_experiment`] / [`run_with_profiles`])
///   builds a `GpuSim` from a config and runs it to completion — every
///   paper experiment uses this;
/// * the **dynamic** path keeps the sim alive and interleaves
///   [`GpuSim::run_until`] with [`GpuSim::attach`] /
///   [`GpuSim::detach`] calls — services come and go mid-run, which is
///   what the cluster churn loop (DESIGN.md §8) is built on.
pub struct GpuSim<'a> {
    cfg: &'a ExperimentConfig,
    store: &'a ProfileStore,
    procs: Vec<ServiceProcess>,
    device: SimDevice,
    events: EventQueue,
    /// In-flight `KernelRecord`s; `KernelDone` events carry slots into
    /// this arena (ADR-003).
    arena: KernelArena,
    scheduler: Option<FikitScheduler>,
    /// Sharing-stage profile refiner (FIKIT mode with online refinement
    /// enabled). Fed from the event loop; its published snapshots are
    /// swapped into the scheduler between events (DESIGN.md §9).
    refiner: Option<OnlineRefiner>,
    outcomes: Vec<TaskOutcome>,
    /// Remaining follow-up arrivals for BackToBack patterns.
    b2b_remaining: Vec<u32>,
    /// Services that departed: no new arrivals, in-flight tasks drain.
    detached: Vec<bool>,
    /// Key → newest process slot. Technically derivable from the
    /// interner + `handle_to_idx`, but kept as a direct map for the
    /// cold paths (attach/detach/can_attach/report) that start from a
    /// string key — the submit hot path never touches it.
    key_to_idx: HashMap<TaskKey, usize>,
    /// Per-sim identity interner (append-only; see `core::Interner`).
    /// Services and their kernel ids are interned once at attach; every
    /// later per-launch structure works on the dense handles.
    interner: Interner,
    /// TaskHandle → newest process slot hosting that key. The submit
    /// path's process lookup (`handle_to_idx[launch.task_handle]`) is an
    /// array index, not a string-keyed map probe.
    handle_to_idx: Vec<usize>,
    /// Exclusive modes: pending task order + lock state. Entries are
    /// (svc, priority, arrival seq); plain Exclusive picks by arrival,
    /// SoftExclusive by (priority, arrival).
    excl_queue: VecDeque<(usize, crate::core::Priority, u64)>,
    excl_seq: u64,
    excl_locked: bool,
    /// In-flight fills the preempt probe may reclaim (ADR-007). Always
    /// empty under [`PreemptionPolicy::None`] and outside FIKIT mode.
    live_fills: Vec<LiveFill>,
    /// Preempted launches awaiting re-dispatch, keyed by
    /// `(svc, task_id, seq)`. A matching re-submission must NOT
    /// re-pipeline its process (`on_submitted` already ran when the
    /// kernel was first submitted).
    requeued: Vec<(usize, TaskId, u32)>,
    events_processed: u64,
    sim_now: SimTime,
}

impl<'a> GpuSim<'a> {
    /// Build a sim hosting `cfg.services` (which may be empty for a
    /// dynamic fleet GPU that receives services via [`GpuSim::attach`]).
    pub fn new(cfg: &'a ExperimentConfig, store: &'a ProfileStore) -> Result<GpuSim<'a>> {
        GpuSim::with_scratch(cfg, store, &mut SimScratch::new())
    }

    /// [`GpuSim::new`], but the event wheel and kernel arena take their
    /// storage from `scratch` (left empty). Pair with
    /// [`GpuSim::reclaim_scratch`] or [`run_with_profiles_scratch`] to
    /// hand the warm storage back for the next run.
    pub fn with_scratch(
        cfg: &'a ExperimentConfig,
        store: &'a ProfileStore,
        scratch: &mut SimScratch,
    ) -> Result<GpuSim<'a>> {
        let mut events = std::mem::take(&mut scratch.events);
        events.clear();
        let mut arena = std::mem::take(&mut scratch.arena);
        arena.clear();
        let scheduler = (cfg.mode == Mode::Fikit).then(|| {
            FikitScheduler::new(SchedulerConfig {
                epsilon: cfg.epsilon,
                feedback: cfg.feedback,
                fill_policy: cfg.fill_policy,
            })
        });

        let refiner = (cfg.mode == Mode::Fikit && cfg.online.enabled)
            .then(|| OnlineRefiner::new(cfg.online.clone()));

        let mut sim = GpuSim {
            cfg,
            store,
            procs: Vec::new(),
            device: SimDevice::new(cfg.device.clone()),
            events,
            arena,
            scheduler,
            refiner,
            outcomes: Vec::new(),
            b2b_remaining: Vec::new(),
            detached: Vec::new(),
            key_to_idx: HashMap::new(),
            interner: Interner::new(),
            handle_to_idx: Vec::new(),
            excl_queue: VecDeque::new(),
            excl_seq: 0,
            excl_locked: false,
            live_fills: Vec::new(),
            requeued: Vec::new(),
            events_processed: 0,
            sim_now: SimTime::ZERO,
        };
        for svc_cfg in &cfg.services {
            sim.register_service(svc_cfg, SimTime::ZERO)?;
        }
        Ok(sim)
    }

    /// Attach a service to this GPU at time `at` (≥ the sim clock): its
    /// arrival pattern starts ticking from `at`. In FIKIT mode the
    /// service's profile must already be in the store — the cluster
    /// layer profiles offline, exactly the paper's lifecycle.
    ///
    /// A key that was previously detached *and* fully drained may be
    /// reused (service migrating back); an undrained or live key is
    /// rejected so in-flight kernel completions can never be routed to
    /// the wrong process.
    pub fn attach(&mut self, svc_cfg: &ServiceConfig, at: SimTime) -> Result<usize> {
        if at < self.sim_now {
            return Err(crate::core::Error::Invariant(format!(
                "attach at {at} is before the sim clock {}",
                self.sim_now
            )));
        }
        self.register_service(svc_cfg, at)
    }

    /// Detach a service: queued arrivals are dropped, no new arrivals are
    /// accepted, and any in-flight task drains to completion under the
    /// normal scheduling rules.
    pub fn detach(&mut self, key: &TaskKey) -> Result<DetachOutcome> {
        let idx = *self.key_to_idx.get(key).ok_or_else(|| {
            crate::core::Error::Invariant(format!("detach of unknown service {key}"))
        })?;
        if !self.detached[idx] {
            self.detached[idx] = true;
            self.procs[idx].clear_arrivals();
            // Exclusive modes: its waiting (never-started) entries are
            // dropped lazily by `excl_try_start` — detach itself is O(1)
            // instead of an O(n) queue scan per departure.
            if !self.procs[idx].is_active() {
                // Idle departure: no task will ever complete for this
                // service, so release its resolved profile now (the
                // draining case does this in `on_task_completed`).
                if let Some(sched) = self.scheduler.as_mut() {
                    sched.unregister_service(self.procs[idx].task_handle());
                }
                if let Some(refiner) = self.refiner.as_mut() {
                    refiner.unregister(self.procs[idx].task_handle());
                }
            }
        }
        Ok(if self.procs[idx].is_active() {
            DetachOutcome::Draining
        } else {
            DetachOutcome::Idle
        })
    }

    /// Could a service with this key be attached right now? False while
    /// a live instance or an undrained (still in-flight) detached
    /// predecessor holds the key.
    pub fn can_attach(&self, key: &TaskKey) -> bool {
        match self.key_to_idx.get(key) {
            None => true,
            Some(&idx) => self.detached[idx] && !self.procs[idx].is_active(),
        }
    }

    /// Is this service still draining an in-flight task?
    pub fn is_draining(&self, key: &TaskKey) -> bool {
        self.key_to_idx
            .get(key)
            .is_some_and(|&idx| self.detached[idx] && self.procs[idx].is_active())
    }

    /// Number of attached (non-departed) services.
    pub fn live_services(&self) -> usize {
        self.detached.iter().filter(|d| !**d).count()
    }

    /// The sim clock (time of the last processed event, or the last
    /// `run_until` bound if later).
    pub fn now(&self) -> SimTime {
        self.sim_now
    }

    /// All completed tasks so far, in completion order. The cluster loop
    /// keeps a cursor into this to harvest new outcomes per epoch.
    pub fn outcomes(&self) -> &[TaskOutcome] {
        &self.outcomes
    }

    /// Device-side counters (busy time, fill time, queue stats).
    pub fn device_stats(&self) -> &DeviceStats {
        self.device.stats()
    }

    /// Scheduler counters (FIKIT mode only).
    pub fn scheduler_stats(&self) -> Option<&SchedulerStats> {
        self.scheduler.as_ref().map(|s| s.stats())
    }

    /// The online refiner, when enabled (drift experiments read its
    /// stats and error windows through this).
    pub fn refiner(&self) -> Option<&OnlineRefiner> {
        self.refiner.as_ref()
    }

    /// Inject gap interference into a hosted service: traces of its
    /// future tasks sample CPU-side think gaps scaled by `scale`
    /// (DESIGN.md §9 — the in-sim stand-in for co-location contention
    /// shifting real gaps). The offline profile is deliberately NOT
    /// updated: the divergence is exactly what the online refiner must
    /// detect and re-converge on (`fikit drift`).
    pub fn inject_gap_scale(&mut self, key: &TaskKey, scale: f64) -> Result<()> {
        let idx = *self.key_to_idx.get(key).ok_or_else(|| {
            crate::core::Error::Invariant(format!("gap injection on unknown service {key}"))
        })?;
        self.procs[idx].set_gap_scale(scale);
        Ok(())
    }

    /// No events left: every attached service is quiescent.
    pub fn is_idle(&self) -> bool {
        self.events.is_empty()
    }

    /// Common attach path for initial and mid-run services.
    fn register_service(&mut self, svc_cfg: &ServiceConfig, at: SimTime) -> Result<usize> {
        let service = svc_cfg.to_service();
        if let Some(&existing) = self.key_to_idx.get(&service.key) {
            if !self.detached[existing] || self.procs[existing].is_active() {
                return Err(crate::core::Error::Invariant(format!(
                    "service key {} is already attached to this GPU",
                    service.key
                )));
            }
        }
        let idx = self.procs.len();
        let handle = self.interner.intern_task(&service.key);
        if let Some(sched) = self.scheduler.as_mut() {
            // FIKIT mode shares against preloaded profiles, resolved to
            // dense handle-indexed tables ONCE here — the scheduler never
            // touches the string-keyed store again for this service.
            let profile = self.store.require(&service.key)?;
            let resolved = ResolvedProfile::resolve(profile, &mut self.interner);
            if let Some(refiner) = self.refiner.as_mut() {
                refiner.register(handle, &resolved);
            }
            sched.register_service(handle, resolved);
        }
        self.key_to_idx.insert(service.key.clone(), idx);
        if handle.index() >= self.handle_to_idx.len() {
            self.handle_to_idx.resize(handle.index() + 1, usize::MAX);
        }
        self.handle_to_idx[handle.index()] = idx;
        self.b2b_remaining.push(0);
        self.detached.push(false);
        // Initial arrivals per pattern, offset to the attach time.
        match service.pattern {
            InvocationPattern::BackToBack { count } => {
                if count > 0 {
                    self.events.push(at, Event::TaskArrival { svc: idx });
                    self.b2b_remaining[idx] = count - 1;
                }
            }
            InvocationPattern::Every { interval, count } => {
                for i in 0..count {
                    let t = at + Duration::from_nanos(interval.nanos() * i as u64);
                    self.events.push(t, Event::TaskArrival { svc: idx });
                }
            }
            InvocationPattern::ContinuousUntil { .. } => {
                self.events.push(at, Event::TaskArrival { svc: idx });
            }
        }
        let mut proc = self.make_process(&service, idx, Stage::Sharing);
        proc.bind(handle, &mut self.interner);
        self.procs.push(proc);
        Ok(idx)
    }

    /// Re-bind a replaced process slot to its interned identities (used
    /// when a measurement-stage process is swapped in).
    fn rebind(&mut self, idx: usize) {
        let key = self.procs[idx].service.key.clone();
        let handle = self.interner.intern_task(&key);
        self.procs[idx].bind(handle, &mut self.interner);
    }

    /// Build a service process with the experiment's cost models applied.
    fn make_process(&self, service: &Service, idx: usize, stage: Stage) -> ServiceProcess {
        let resolver = SymbolResolver::new(self.cfg.symbols.clone());
        let seed_salt = match stage {
            Stage::Measuring => 0x4D45_4153, // "MEAS": decorrelate from sharing runs
            Stage::Sharing => 0,
        };
        let mut proc = ServiceProcess::new(
            service.clone(),
            derive_seed(self.cfg.seed, idx as u64, seed_salt),
            resolver,
            stage,
            self.cfg.measurement.clone(),
        );
        // Per-launch CPU-side overhead: base driver cost + symbol lookup
        // (+ hook interception in FIKIT mode).
        let mut overhead = self.cfg.hook.base_launch_overhead + self.cfg.symbols.lookup_cost();
        if self.cfg.mode == Mode::Fikit || stage == Stage::Measuring {
            overhead += self.cfg.hook.interception_overhead;
        }
        proc.per_launch_overhead = overhead;
        proc
    }

    /// Submit a launch to the device, schedule its completion event, and
    /// let the owning process pipeline its next issue (async launch-ahead
    /// resumes the moment the held/direct launch reaches the device).
    fn submit(&mut self, launch: crate::core::KernelLaunch, source: LaunchSource, now: SimTime) {
        // Dense-table process lookup: launches inside a sim always carry
        // a bound handle (processes are bound at attach).
        debug_assert!(launch.task_handle.is_bound(), "unbound launch in sim");
        let svc = self.handle_to_idx[launch.task_handle.index()];
        let preempting =
            self.cfg.mode == Mode::Fikit && self.cfg.preempt != PreemptionPolicy::None;
        let tracked = if preempting {
            if source == LaunchSource::GapFill {
                // Reclaimable until a non-fill launch is priced in.
                Some(launch.clone())
            } else {
                // A direct/drain launch may reclaim in-flight fills
                // *before* its own device pricing; whatever survives
                // is queued ahead of it and no longer the device tail.
                self.maybe_preempt(&launch, now);
                self.live_fills.clear();
                None
            }
        } else {
            None
        };
        let (l_tid, l_seq) = (launch.task_id, launch.seq);
        let record = self.device.submit(launch, now, source);
        let (started_at, finished_at) = (record.started_at, record.finished_at);
        let rec = self.arena.insert(record);
        self.events
            .push(finished_at, Event::KernelDone { svc, rec });
        if let Some(launch) = tracked {
            let predicted = self
                .scheduler
                .as_ref()
                .expect("fills only exist in fikit mode")
                .predicted_sk(&launch);
            self.live_fills.push(LiveFill {
                rec,
                svc,
                launch,
                predicted,
                started_at,
                finished_at,
            });
        }
        // A preempted launch re-entering the device already pipelined
        // its owner's next issue when it was first submitted.
        let resubmit = !self.requeued.is_empty()
            && self
                .requeued
                .iter()
                .position(|&(s, tid, sq)| s == svc && tid == l_tid && sq == l_seq)
                .map(|pos| {
                    self.requeued.swap_remove(pos);
                })
                .is_some();
        if !resubmit {
            if let Some(next_issue) = self.procs[svc].on_submitted(now) {
                self.events.push(next_issue, Event::IssueKernel { svc });
            }
        }
    }

    fn submit_all(&mut self, subs: Vec<Submission>, now: SimTime) {
        for sub in subs {
            self.submit(sub.launch, sub.source, now);
        }
    }

    /// The preempt probe (ADR-007): `launch` (direct or drain) is about
    /// to be priced into the device model. While in-flight fill kernels
    /// delay its projected start by more than the modeled preemption
    /// cost, reclaim them from the tail inward:
    ///
    /// * a fill whose modeled start is still ahead of the probe point is
    ///   **evicted** whole — full rollback, nothing executed, no penalty;
    /// * the fill actually running at the probe point is **cut** or
    ///   **split** per [`PreemptionPolicy`], paying `preempt_cost` and
    ///   (for a cut) discarding the executed prefix.
    ///
    /// Every reclaimed launch re-enters the priority queues via
    /// [`FikitScheduler::park_preempted`]; its stale `KernelDone` event
    /// stays in the wheel and is swallowed by the arena tombstone.
    /// Under `MpsSpatial` fills never delay the probe's start
    /// (`projected_start` = readiness), so the probe is inert there.
    fn maybe_preempt(&mut self, launch: &KernelLaunch, now: SimTime) {
        let policy = self.cfg.preempt;
        let cost = self.cfg.preempt_cost;
        let ready = now + self.cfg.device.launch_latency;
        loop {
            let Some(lf) = self.live_fills.last() else { return };
            // Only a strictly higher-priority launch may reclaim work.
            if !launch.priority.is_higher_than(lf.launch.priority) {
                return;
            }
            // Would the launch start late enough to pay for a preempt?
            if self.device.projected_start(now).since(ready) <= cost {
                return;
            }
            let (rec, svc, started_at, finished_at) =
                (lf.rec, lf.svc, lf.started_at, lf.finished_at);
            if ready <= started_at {
                // Not yet started at the probe point: evict it whole and
                // re-examine what is now the tail.
                let ok = {
                    let record = self.arena.get(rec).expect("live fill has a record");
                    self.device.preempt(record, started_at, Duration::ZERO)
                };
                if !ok {
                    return;
                }
                let record = self.arena.cancel(rec);
                let lf = self.live_fills.pop().expect("checked non-empty");
                let sched = self
                    .scheduler
                    .as_mut()
                    .expect("preempt probe only runs in fikit mode");
                {
                    let st = sched.preempt_stats_mut();
                    st.evictions += 1;
                    st.reclaimed += record.finished_at.since(record.started_at);
                }
                self.requeued.push((svc, lf.launch.task_id, lf.launch.seq));
                sched.park_preempted(lf.launch, lf.predicted, now);
                continue;
            }
            // The tail fill is (modeled as) running at the probe point.
            let action = plan_preempt(policy, ready, started_at, finished_at);
            let cut_at = match action {
                PreemptAction::Skip => return,
                // Defensive: `ready > started_at` here, so the planner
                // cannot ask for a whole-kernel cancel.
                PreemptAction::Cancel => started_at,
                PreemptAction::Cut { cut_at } | PreemptAction::Split { cut_at } => cut_at,
            };
            // Strict improvement: the launch must start earlier even
            // after paying the preemption penalty.
            if cut_at + cost >= finished_at {
                return;
            }
            let ok = {
                let record = self.arena.get(rec).expect("live fill has a record");
                self.device.preempt(record, cut_at, cost)
            };
            if !ok {
                return;
            }
            let record = self.arena.cancel(rec);
            let lf = self.live_fills.pop().expect("checked non-empty");
            let sched = self
                .scheduler
                .as_mut()
                .expect("preempt probe only runs in fikit mode");
            sched.preempt_stats_mut().reclaimed += record.finished_at.since(cut_at);
            if let PreemptAction::Split { .. } = action {
                sched.preempt_stats_mut().splits += 1;
                // The unexecuted suffix re-enters the queues as a
                // remnant indexed by its remaining device time; its
                // true duration shrinks proportionally (device time =
                // true duration × compute scaling).
                let remaining = record.finished_at.since(cut_at);
                let total = record.finished_at.since(record.started_at);
                let mut remnant = lf.launch;
                let num = remaining.nanos() as u128 * remnant.true_duration.nanos() as u128;
                remnant.true_duration =
                    Duration::from_nanos(((num / total.nanos() as u128) as u64).max(1));
                self.requeued.push((svc, remnant.task_id, remnant.seq));
                sched.park_preempted(remnant, Some(remaining), now);
            } else {
                {
                    let st = sched.preempt_stats_mut();
                    st.cuts += 1;
                    st.wasted += cut_at.since(record.started_at);
                }
                // The executed prefix is discarded: the original launch
                // re-queues whole, at its original prediction.
                self.requeued.push((svc, lf.launch.task_id, lf.launch.seq));
                sched.park_preempted(lf.launch, lf.predicted, now);
            }
            // Only the device tail is reclaimable, and the cut kernel
            // keeps its prefix there — nothing behind it can move up.
            return;
        }
    }

    /// Try to start the next queued task of `svc` per mode rules.
    fn maybe_start(&mut self, svc: usize, now: SimTime) {
        match self.cfg.mode {
            Mode::Sharing | Mode::Fikit => {
                if let Some(issue_at) = self.procs[svc].try_start_task(now) {
                    if let Some(sched) = self.scheduler.as_mut() {
                        sched.task_started(
                            self.procs[svc].task_handle(),
                            self.procs[svc].priority(),
                            now,
                        );
                    }
                    self.events.push(issue_at, Event::IssueKernel { svc });
                }
            }
            Mode::Exclusive | Mode::SoftExclusive => self.excl_try_start(now),
        }
    }

    /// Exclusive modes: start the next waiting task if the lock is free.
    /// Plain Exclusive picks the earliest arrival (the paper's external
    /// orchestrator); SoftExclusive picks by priority then arrival (the
    /// paper's §5 software-defined exclusive mode).
    fn excl_try_start(&mut self, now: SimTime) {
        if self.excl_locked {
            return;
        }
        // Entries of departed services are dropped lazily here instead of
        // by an O(n) retain per detach. Plain Exclusive only ever consumes
        // the front, so purging the front is amortized O(1); SoftExclusive
        // scans the whole queue anyway, so folding the purge into its scan
        // adds no asymptotic cost and keeps stale entries from piling up
        // behind a starved front entry.
        let pick = match self.cfg.mode {
            Mode::SoftExclusive => {
                let detached = &self.detached;
                self.excl_queue.retain(|&(s, _, _)| !detached[s]);
                self.excl_queue
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, (_, prio, seq))| (*prio, *seq))
                    .map(|(pos, _)| pos)
            }
            _ => {
                while self
                    .excl_queue
                    .front()
                    .is_some_and(|&(s, _, _)| self.detached[s])
                {
                    self.excl_queue.pop_front();
                }
                (!self.excl_queue.is_empty()).then_some(0)
            }
        };
        let Some(pos) = pick else { return };
        let (svc, _, _) = self.excl_queue.remove(pos).expect("pos valid");
        let issue_at = self
            .procs[svc]
            .try_start_task(now)
            .expect("exclusive queue entry must be startable");
        self.excl_locked = true;
        self.events.push(issue_at, Event::IssueKernel { svc });
    }

    /// Run to completion (all arrival patterns exhausted), subject to the
    /// config's optional horizon.
    fn run(&mut self) {
        let bound = self
            .cfg
            .horizon
            .map_or(SimTime::MAX, |h| SimTime::ZERO + h);
        while let Some((now, event)) = self.events.pop_if_before(bound) {
            self.sim_now = now;
            self.events_processed += 1;
            self.handle_event(event, now);
        }
    }

    /// Process every event with timestamp ≤ `bound`, then advance the sim
    /// clock to `bound`. The dynamic cluster loop calls this between
    /// fleet events (arrivals, departures, QoS scans) so all GPUs stay in
    /// step on the fleet clock. The config's optional horizon caps the
    /// bound, matching [`GpuSim::run`]'s behavior on the same config.
    pub fn run_until(&mut self, bound: SimTime) {
        let bound = match self.cfg.horizon {
            Some(h) => bound.min(SimTime::ZERO + h),
            None => bound,
        };
        while let Some((now, event)) = self.events.pop_if_before(bound) {
            self.sim_now = now;
            self.events_processed += 1;
            self.handle_event(event, now);
        }
        if bound != SimTime::MAX && bound > self.sim_now {
            self.sim_now = bound;
        }
    }

    /// One event-loop step (shared by [`GpuSim::run`] and
    /// [`GpuSim::run_until`]).
    fn handle_event(&mut self, event: Event, now: SimTime) {
        match event {
            Event::TaskArrival { svc } => {
                if self.detached[svc] {
                    // The service departed before this arrival fired.
                    return;
                }
                self.procs[svc].enqueue_arrival(now);
                if matches!(self.cfg.mode, Mode::Exclusive | Mode::SoftExclusive) {
                    let prio = self.procs[svc].priority();
                    let seq = self.excl_seq;
                    self.excl_seq += 1;
                    self.excl_queue.push_back((svc, prio, seq));
                }
                self.maybe_start(svc, now);
            }
            Event::IssueKernel { svc } => {
                let launch = self.procs[svc].issue_next(now);
                match self.cfg.mode {
                    Mode::Sharing | Mode::Exclusive | Mode::SoftExclusive => {
                        self.submit(launch, LaunchSource::Direct, now);
                    }
                    Mode::Fikit => {
                        let subs = self
                            .scheduler
                            .as_mut()
                            .expect("fikit mode has scheduler")
                            .on_launch(launch, now);
                        self.submit_all(subs, now);
                    }
                }
            }
            Event::KernelDone { svc, rec } => {
                // A tombstoned slot is a stale completion of a preempted
                // kernel: popping it reconciles the lazy deletion
                // (ADR-003's no-random-removal wheel), nothing else.
                let Some(record) = self.arena.take_if_live(rec) else {
                    return;
                };
                if !self.live_fills.is_empty() {
                    // A fill that ran to completion is no longer
                    // reclaimable. Ordered removal: the vec must stay in
                    // device-FIFO-tail order for the preempt probe.
                    if let Some(pos) = self.live_fills.iter().position(|lf| lf.rec == rec) {
                        self.live_fills.remove(pos);
                    }
                }
                // Scheduler reacts first (fill windows open on holder
                // kernel completions).
                if let Some(sched) = self.scheduler.as_mut() {
                    let subs = sched.on_kernel_done(&record, now);
                    self.submit_all(subs, now);
                }
                let (th, kh, exec, finished) = (
                    record.task_handle,
                    record.kernel_handle,
                    record.exec_time(),
                    record.finished_at,
                );
                match self.procs[svc].on_kernel_done(record, now) {
                    ProcessAction::IssueAt(t) => {
                        // Sync completion: the process resumes at `t`, so
                        // the observed post-kernel think gap is `t −
                        // finished` — the non-intrusive sharing-stage
                        // signal the refiner learns SG drift from
                        // (DESIGN.md §9; no timing events involved).
                        self.refine(th, kh, exec, Some(t.since(finished)));
                        self.events.push(t, Event::IssueKernel { svc });
                    }
                    ProcessAction::None => {
                        // Pipelined (async) completion: no attributable
                        // device-idle gap — learn the exec time only.
                        self.refine(th, kh, exec, None);
                    }
                    ProcessAction::TaskCompleted(outcome) => {
                        self.refine(th, kh, exec, None);
                        self.on_task_completed(svc, outcome, now);
                    }
                }
            }
        }
    }

    /// Feed one completed kernel to the refiner; when the observation
    /// trips drift, swap the refreshed snapshot into the scheduler —
    /// the epoch swap happens here, between events, so no launch ever
    /// sees a half-written table (DESIGN.md §9).
    fn refine(
        &mut self,
        th: crate::core::TaskHandle,
        kh: crate::core::KernelHandle,
        exec: Duration,
        gap_after: Option<Duration>,
    ) {
        let Some(refiner) = self.refiner.as_mut() else {
            return;
        };
        if let Some(snapshot) = refiner.observe(th, kh, exec, gap_after) {
            if let Some(sched) = self.scheduler.as_mut() {
                sched.refresh_service(th, snapshot);
            }
        }
    }

    fn on_task_completed(&mut self, svc: usize, outcome: TaskOutcome, now: SimTime) {
        self.outcomes.push(outcome);

        if let Some(sched) = self.scheduler.as_mut() {
            let drains = sched.task_finished(self.procs[svc].task_handle(), now);
            self.submit_all(drains, now);
        }

        // A detached service that just drained its last task is gone for
        // good (no new arrivals can exist): release its resolved profile
        // so churn-heavy sims hold per-service state only for live
        // services. A later re-attach re-registers under the same handle.
        if self.detached[svc] && !self.procs[svc].is_active() {
            if let Some(sched) = self.scheduler.as_mut() {
                sched.unregister_service(self.procs[svc].task_handle());
            }
            if let Some(refiner) = self.refiner.as_mut() {
                refiner.unregister(self.procs[svc].task_handle());
            }
        }

        // Pattern follow-up arrivals (suppressed once the service has
        // departed — its closed loop ends with the drained task).
        match self.procs[svc].service.pattern {
            InvocationPattern::BackToBack { .. } => {
                if self.b2b_remaining[svc] > 0 && !self.detached[svc] {
                    self.b2b_remaining[svc] -= 1;
                    self.events.push(now, Event::TaskArrival { svc });
                }
            }
            InvocationPattern::ContinuousUntil { until } => {
                if now < until && !self.detached[svc] {
                    self.events.push(now, Event::TaskArrival { svc });
                }
            }
            InvocationPattern::Every { .. } => {}
        }

        if matches!(self.cfg.mode, Mode::Exclusive | Mode::SoftExclusive) {
            self.excl_locked = false;
            self.excl_try_start(now);
        } else {
            // The same service may have queued arrivals (overrun of an
            // Every pattern): start the next one.
            self.maybe_start(svc, now);
        }
    }

    /// Hand the event-core storage back to `scratch` (cleared, capacity
    /// intact) and drop the rest of the sim. Callers that keep the sim's
    /// measurements (outcomes, refiner stats) must extract them first.
    pub fn reclaim_scratch(mut self, scratch: &mut SimScratch) {
        self.events.clear();
        self.arena.clear();
        scratch.events = std::mem::take(&mut self.events);
        scratch.arena = std::mem::take(&mut self.arena);
    }

    fn into_report_reclaiming(
        mut self,
        wall: std::time::Duration,
        scratch: Option<&mut SimScratch>,
    ) -> ExperimentReport {
        if let Some(scratch) = scratch {
            self.events.clear();
            self.arena.clear();
            scratch.events = std::mem::take(&mut self.events);
            scratch.arena = std::mem::take(&mut self.arena);
        }
        let mut services = Vec::with_capacity(self.procs.len());
        for (idx, proc) in self.procs.iter().enumerate() {
            // A reattached key leaves its superseded predecessor slot in
            // `procs`; report each key once, via its newest slot (which
            // aggregates every outcome recorded under the key).
            if self.key_to_idx.get(proc.key()) != Some(&idx) {
                continue;
            }
            let key = proc.key().clone();
            let mine: Vec<&TaskOutcome> =
                self.outcomes.iter().filter(|o| o.task_key == key).collect();
            let jcts: Vec<Duration> = mine.iter().map(|o| o.jct()).collect();
            let timeline = Timeline::new(
                mine.iter()
                    .map(|o| TimelinePoint {
                        arrival: o.arrival,
                        jct: o.jct(),
                    })
                    .collect(),
            );
            services.push(ServiceReport {
                key,
                model: proc.service.model,
                priority: proc.priority(),
                jct: JctStats::from_durations(jcts),
                completed: mine.len(),
                timeline,
            });
        }
        ExperimentReport {
            mode: self.cfg.mode,
            services,
            outcomes: self.outcomes,
            device: self.device.stats().clone(),
            scheduler: self.scheduler.map(|s| s.into_stats()),
            refiner: self.refiner.map(|r| r.into_stats()),
            sim_end: self.sim_now,
            events: self.events_processed,
            wall,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Priority;
    use crate::workload::ModelKind;

    fn two_service_cfg(mode: Mode, tasks: u32) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.mode = mode;
        cfg.measurement.runs = 5;
        cfg.services
            .push(ServiceConfig::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0).tasks(tasks));
        cfg.services
            .push(ServiceConfig::new(ModelKind::FcnResnet50, Priority::P2).tasks(tasks));
        cfg
    }

    #[test]
    fn solo_exclusive_jct_matches_trace() {
        let mut cfg = ExperimentConfig::default();
        cfg.mode = Mode::Sharing;
        cfg.services
            .push(ServiceConfig::new(ModelKind::Alexnet, Priority::P0).tasks(20));
        let report = run_experiment(&cfg).unwrap();
        let svc = &report.services[0];
        assert_eq!(svc.completed, 20);
        // Solo on the device: mean JCT ≈ spec JCT + per-kernel overheads.
        let expect = ModelKind::Alexnet.spec().mean_jct().as_millis_f64();
        let got = svc.jct.mean_ms();
        assert!(
            (got - expect).abs() / expect < 0.25,
            "solo JCT {got:.3}ms vs spec {expect:.3}ms"
        );
    }

    #[test]
    fn fikit_speeds_up_high_priority_vs_sharing() {
        let share = run_experiment(&two_service_cfg(Mode::Sharing, 30)).unwrap();
        let fikit = run_experiment(&two_service_cfg(Mode::Fikit, 30)).unwrap();

        let hp_share = &share.by_priority(Priority::P0).unwrap().jct;
        let hp_fikit = &fikit.by_priority(Priority::P0).unwrap().jct;
        let speedup = crate::metrics::speedup(hp_share, hp_fikit);
        assert!(
            speedup > 1.2,
            "FIKIT must beat sharing for high-prio: speedup {speedup:.2} (share {:.2}ms fikit {:.2}ms)",
            hp_share.mean_ms(),
            hp_fikit.mean_ms()
        );

        // FIKIT high-prio should be close to exclusive-solo JCT.
        let mut solo_cfg = ExperimentConfig::default();
        solo_cfg.mode = Mode::Sharing;
        solo_cfg
            .services
            .push(ServiceConfig::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0).tasks(30));
        let solo = run_experiment(&solo_cfg).unwrap();
        let ratio = hp_fikit.mean_ms() / solo.services[0].jct.mean_ms();
        assert!(
            ratio < 1.35,
            "FIKIT high-prio within 35% of exclusive: ratio {ratio:.2}"
        );

        // Scheduler actually filled gaps.
        let sched = fikit.scheduler.as_ref().unwrap();
        assert!(sched.fills > 0, "no gap fills happened");
        assert!(sched.feedback.windows > 0);
    }

    #[test]
    fn sharing_mode_interleaves_fifo() {
        let report = run_experiment(&two_service_cfg(Mode::Sharing, 10)).unwrap();
        assert!(report.scheduler.is_none());
        assert_eq!(report.services.len(), 2);
        // Both services complete all tasks.
        assert!(report.services.iter().all(|s| s.completed == 10));
    }

    #[test]
    fn exclusive_mode_serializes_tasks() {
        let report = run_experiment(&two_service_cfg(Mode::Exclusive, 5)).unwrap();
        // No two tasks overlap: outcomes sorted by start must not overlap.
        let mut spans: Vec<(SimTime, SimTime)> = report
            .outcomes
            .iter()
            .map(|o| (o.started, o.finished))
            .collect();
        spans.sort();
        for w in spans.windows(2) {
            assert!(
                w[0].1 <= w[1].0 + Duration::from_micros(10),
                "exclusive tasks overlapped: {:?}",
                w
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_experiment(&two_service_cfg(Mode::Fikit, 10)).unwrap();
        let b = run_experiment(&two_service_cfg(Mode::Fikit, 10)).unwrap();
        assert_eq!(a.sim_end, b.sim_end);
        assert_eq!(a.events, b.events);
        for (sa, sb) in a.services.iter().zip(&b.services) {
            assert_eq!(sa.jct.mean, sb.jct.mean);
        }
    }

    /// The online-refinement loop end to end: faithful observations
    /// keep the offline profile (epoch 0); injected gap interference is
    /// detected and a refreshed snapshot is swapped into the scheduler.
    #[test]
    fn online_refinement_detects_injected_gap_drift() {
        let mut cfg = two_service_cfg(Mode::Fikit, 40);
        cfg.online.enabled = true;
        cfg.validate().unwrap();
        let mut store = ProfileStore::new();
        for svc in &cfg.services {
            store.insert(profile_service(&cfg, svc).unwrap().profile);
        }
        let hi_key = cfg.services[0].to_service().key;

        // Phase A: no interference — estimates converge, no (or nearly
        // no) drift against the freshly measured profile.
        let mut sim = GpuSim::new(&cfg, &store).unwrap();
        sim.run_until(SimTime(200_000_000));
        let drifts_a = sim.refiner().unwrap().stats().drifts;

        // Phase B: inject 2x gap interference on the high-prio service.
        sim.inject_gap_scale(&hi_key, 2.0).unwrap();
        sim.run_until(SimTime::MAX);
        let stats = sim.refiner().unwrap().stats();
        assert!(
            stats.drifts > drifts_a,
            "injected interference undetected: {} drifts before, {} after",
            drifts_a,
            stats.drifts
        );
        assert!(stats.snapshots_published >= 1, "no snapshot published");
        assert!(stats.max_epoch >= 1);
        assert!(stats.gap_observations > 0 && stats.exec_observations > 0);
        // The refinement cost stays inside the paper's 5 % budget.
        let overhead = sim.refiner().unwrap().modeled_overhead();
        assert!(
            overhead.as_secs_f64() / sim.now().as_secs_f64() < 0.05,
            "refinement overhead {overhead} vs sim {}",
            sim.now()
        );
    }

    /// Online refinement is deterministic and default-off: with the
    /// switch off the refiner never exists, and two refined runs agree.
    #[test]
    fn online_refinement_default_off_and_deterministic() {
        let cfg = two_service_cfg(Mode::Fikit, 10);
        let report = run_experiment(&cfg).unwrap();
        assert!(report.refiner.is_none(), "refiner must be opt-in");

        let run = || {
            let mut cfg = two_service_cfg(Mode::Fikit, 15);
            cfg.online.enabled = true;
            run_experiment(&cfg).unwrap()
        };
        let (a, b) = (run(), run());
        let (ra, rb) = (a.refiner.unwrap(), b.refiner.unwrap());
        assert_eq!(ra.exec_observations, rb.exec_observations);
        assert_eq!(ra.gap_observations, rb.gap_observations);
        assert_eq!(ra.drifts, rb.drifts);
        assert_eq!(ra.snapshots_published, rb.snapshots_published);
        assert_eq!(a.sim_end, b.sim_end);
    }

    /// The preempt probe reclaims overrunning fills under `Evict`: the
    /// machinery fires, every task still completes, and the device
    /// kernel counter obeys the conservation identity (each cut/split
    /// leaves one counted partial execution behind; evictions of
    /// unstarted fills roll back entirely).
    #[test]
    fn evict_policy_reclaims_overrunning_fills() {
        let none = run_experiment(&two_service_cfg(Mode::Fikit, 30)).unwrap();
        let mut cfg = two_service_cfg(Mode::Fikit, 30);
        cfg.preempt = PreemptionPolicy::Evict;
        let evict = run_experiment(&cfg).unwrap();

        assert!(evict.services.iter().all(|s| s.completed == 30));
        let p = &evict.scheduler.as_ref().unwrap().preempt;
        assert!(p.requeues > 0, "probe never fired on an overrunning fill");
        assert_eq!(p.requeues, p.evictions + p.cuts + p.splits);
        assert_eq!(p.splits, 0, "evict never splits");
        assert_eq!(
            evict.device.kernels,
            none.device.kernels + p.cuts + p.splits,
            "conservation: re-queued cuts re-execute exactly once"
        );

        // Reclaiming fills must not hurt the high-priority service
        // (small tolerance: later fill dynamics differ between runs).
        let hp_none = none.by_priority(Priority::P0).unwrap().jct.mean_ms();
        let hp_evict = evict.by_priority(Priority::P0).unwrap().jct.mean_ms();
        assert!(
            hp_evict <= hp_none * 1.05,
            "evict must not slow the high-prio service: {hp_evict:.3}ms vs {hp_none:.3}ms"
        );

        // The None-policy run reports no preempt activity at all, and
        // its summary never grows the extra line.
        let p0 = &none.scheduler.as_ref().unwrap().preempt;
        assert_eq!(p0.requeues + p0.evictions + p0.cuts + p0.splits, 0);
        assert!(!none.summary().contains("preempt:"));
        assert!(evict.summary().contains("preempt: evictions="));
    }

    /// Preemptive scheduling stays deterministic: two identical hybrid
    /// runs agree on every counter and JCT.
    #[test]
    fn preemptive_runs_are_deterministic() {
        let run = || {
            let mut cfg = two_service_cfg(Mode::Fikit, 20);
            cfg.preempt = PreemptionPolicy::hybrid();
            run_experiment(&cfg).unwrap()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.sim_end, b.sim_end);
        assert_eq!(a.events, b.events);
        let (pa, pb) = (
            &a.scheduler.as_ref().unwrap().preempt,
            &b.scheduler.as_ref().unwrap().preempt,
        );
        assert_eq!(pa.evictions, pb.evictions);
        assert_eq!(pa.cuts, pb.cuts);
        assert_eq!(pa.splits, pb.splits);
        assert_eq!(pa.reclaimed, pb.reclaimed);
        for (sa, sb) in a.services.iter().zip(&b.services) {
            assert_eq!(sa.jct.mean, sb.jct.mean);
        }
    }

    #[test]
    fn profiling_produces_ready_profiles() {
        let cfg = two_service_cfg(Mode::Fikit, 5);
        let res = profile_service(&cfg, &cfg.services[0]).unwrap();
        assert!(res.profile.is_ready(cfg.measurement.runs));
        assert_eq!(res.outcomes.len(), cfg.measurement.runs as usize);
        assert!(res.profile.num_unique() > 0);
    }
}
