//! Library-wide error type.

use thiserror::Error;

/// Errors surfaced by FIKIT subsystems.
#[derive(Debug, Error)]
pub enum Error {
    /// Parsing user input (CLI args, config fields) failed.
    #[error("parse error: {0}")]
    Parse(String),

    /// Configuration is structurally invalid.
    #[error("config error: {0}")]
    Config(String),

    /// A profile lookup missed (task has no measurement data).
    #[error("no profile for task key {0:?}")]
    MissingProfile(String),

    /// A kernel id lookup missed inside a profile.
    #[error("profile for {task:?} has no statistics for kernel {kernel:?}")]
    MissingKernelStats { task: String, kernel: String },

    /// Artifact manifest / HLO loading problems.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Wire-protocol encode/decode failure.
    #[error("protocol error: {0}")]
    Protocol(String),

    /// Admission explicitly shed by the scheduler fleet (every visible
    /// node full): the request was *answered*, not lost. Carries the
    /// daemon's reason so callers can distinguish graceful load
    /// shedding from transport failures.
    #[error("admission shed: {0}")]
    Shed(String),

    /// Simulation invariant violated (a bug, surfaced loudly).
    #[error("simulation invariant violated: {0}")]
    Invariant(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),
}

/// Convenience alias used across the crate.
pub type Result<T, E = Error> = std::result::Result<T, E>;
