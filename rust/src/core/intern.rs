//! Dense identity interning for the scheduler hot path.
//!
//! Scheduling decisions happen per kernel launch, and ε = 0.1 ms gaps
//! (DESIGN.md §Perf) leave no room for string work per decision. The
//! [`Interner`] maps every [`KernelId`] and [`TaskKey`] a simulation will
//! ever route to a dense `u32` handle **once, at service-attach time**;
//! from then on every per-launch structure (queued requests, resolved
//! profiles, holder tracking) is keyed by handle, so the steady-state
//! `IssueKernel → enqueue → BestPrioFit` loop does zero hashing and zero
//! allocation. Canonical strings survive only at persistence boundaries
//! (profile JSON, wire protocol, reports).
//!
//! Invariants (DESIGN.md §Perf "hot-path data structures"):
//!
//! * **Append-only, per simulation** — handles are never recycled or
//!   remapped while a sim lives; a handle minted at attach time stays
//!   valid (and means the same identity) for the whole run.
//! * **Dense** — handle `h` indexes slot `h` of any side table sized by
//!   [`Interner::kernel_count`] / [`Interner::task_count`], so lookups
//!   are plain array indexing.
//! * **Deterministic** — interning the same identities in the same order
//!   yields the same handles (no randomized iteration is involved), which
//!   keeps experiment replays byte-identical.

use super::ids::{KernelId, TaskKey};
use std::collections::HashMap;

/// Dense per-sim handle for a [`KernelId`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct KernelHandle(u32);

/// Dense per-sim handle for a [`TaskKey`] (one per attached service).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskHandle(u32);

macro_rules! handle_impl {
    ($name:ident) => {
        impl $name {
            /// Sentinel for identities that never went through an
            /// interner (boundary constructions, tests). Unbound handles
            /// miss every side table, so the scheduler treats their
            /// owners as unprofiled — never selected for gap filling.
            pub const UNBOUND: $name = $name(u32::MAX);

            /// Slot index into a dense side table.
            #[inline]
            pub fn index(self) -> usize {
                self.0 as usize
            }

            /// `false` for [`Self::UNBOUND`].
            #[inline]
            pub fn is_bound(self) -> bool {
                self != Self::UNBOUND
            }

            /// Rebuild from a slot index (inverse of [`Self::index`]).
            pub fn from_index(idx: usize) -> $name {
                debug_assert!(idx < u32::MAX as usize, "handle space exhausted");
                $name(idx as u32)
            }
        }

        impl std::fmt::Display for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                if self.is_bound() {
                    write!(f, "#{}", self.0)
                } else {
                    write!(f, "#unbound")
                }
            }
        }
    };
}

handle_impl!(KernelHandle);
handle_impl!(TaskHandle);

/// The per-sim identity interner (see module docs for the invariants).
#[derive(Debug, Default)]
pub struct Interner {
    kernels: Vec<KernelId>,
    kernel_index: HashMap<KernelId, KernelHandle>,
    tasks: Vec<TaskKey>,
    task_index: HashMap<TaskKey, TaskHandle>,
}

impl Interner {
    pub fn new() -> Interner {
        Interner::default()
    }

    /// Handle for a kernel id, minting one on first sight. Hashes the id
    /// (string content) — call at attach/registration time only.
    pub fn intern_kernel(&mut self, id: &KernelId) -> KernelHandle {
        if let Some(&h) = self.kernel_index.get(id) {
            return h;
        }
        let h = KernelHandle::from_index(self.kernels.len());
        self.kernels.push(id.clone());
        self.kernel_index.insert(id.clone(), h);
        h
    }

    /// Handle for a task key, minting one on first sight.
    pub fn intern_task(&mut self, key: &TaskKey) -> TaskHandle {
        if let Some(&h) = self.task_index.get(key) {
            return h;
        }
        let h = TaskHandle::from_index(self.tasks.len());
        self.tasks.push(key.clone());
        self.task_index.insert(key.clone(), h);
        h
    }

    /// Non-minting lookup.
    pub fn kernel_handle(&self, id: &KernelId) -> Option<KernelHandle> {
        self.kernel_index.get(id).copied()
    }

    /// Non-minting lookup.
    pub fn task_handle(&self, key: &TaskKey) -> Option<TaskHandle> {
        self.task_index.get(key).copied()
    }

    /// Resolve a handle back to its kernel id (reporting boundary).
    pub fn kernel(&self, h: KernelHandle) -> Option<&KernelId> {
        self.kernels.get(h.index())
    }

    /// Resolve a handle back to its task key (reporting boundary).
    pub fn task(&self, h: TaskHandle) -> Option<&TaskKey> {
        self.tasks.get(h.index())
    }

    /// Number of interned kernel ids — the size any kernel-handle-indexed
    /// side table must have to cover every handle minted so far.
    pub fn kernel_count(&self) -> usize {
        self.kernels.len()
    }

    /// Number of interned task keys.
    pub fn task_count(&self) -> usize {
        self.tasks.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Dim3;

    fn kid(name: &str) -> KernelId {
        KernelId::new(name, Dim3::x(4), Dim3::x(64))
    }

    #[test]
    fn handles_are_dense_and_stable() {
        let mut i = Interner::new();
        let a = i.intern_kernel(&kid("a"));
        let b = i.intern_kernel(&kid("b"));
        assert_eq!(a.index(), 0);
        assert_eq!(b.index(), 1);
        // Re-interning is idempotent.
        assert_eq!(i.intern_kernel(&kid("a")), a);
        assert_eq!(i.kernel_count(), 2);
        assert_eq!(i.kernel(a), Some(&kid("a")));
        assert_eq!(i.kernel_handle(&kid("b")), Some(b));
        assert_eq!(i.kernel_handle(&kid("c")), None);
    }

    #[test]
    fn task_handles_independent_of_kernel_handles() {
        let mut i = Interner::new();
        let t = i.intern_task(&TaskKey::new("svc"));
        let k = i.intern_kernel(&kid("k"));
        assert_eq!(t.index(), 0);
        assert_eq!(k.index(), 0);
        assert_eq!(i.task(t), Some(&TaskKey::new("svc")));
        assert_eq!(i.task_count(), 1);
    }

    #[test]
    fn unbound_sentinel_misses_everything() {
        let i = Interner::new();
        assert!(!KernelHandle::UNBOUND.is_bound());
        assert!(!TaskHandle::UNBOUND.is_bound());
        assert!(i.kernel(KernelHandle::UNBOUND).is_none());
        assert!(i.task(TaskHandle::UNBOUND).is_none());
        assert!(KernelHandle::from_index(3).is_bound());
        assert_eq!(format!("{}", TaskHandle::from_index(3)), "#3");
        assert_eq!(format!("{}", TaskHandle::UNBOUND), "#unbound");
    }

    #[test]
    fn dim_only_ids_are_distinct_identities() {
        // Erased-name ids (release-build frameworks) collide exactly when
        // their dims collide — matching the string-keyed behavior.
        let mut i = Interner::new();
        let a = i.intern_kernel(&KernelId::new("", Dim3::x(1), Dim3::x(32)));
        let b = i.intern_kernel(&KernelId::new("", Dim3::x(2), Dim3::x(32)));
        let c = i.intern_kernel(&KernelId::new("", Dim3::x(1), Dim3::x(32)));
        assert_ne!(a, b);
        assert_eq!(a, c);
    }
}
