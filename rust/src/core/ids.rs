//! Kernel and task identity.
//!
//! The paper's key identification mechanism (§3.2, Fig 4): a **Kernel ID**
//! is the triple *(kernel function name, grid dimensions, block
//! dimensions)*. The function name is only observable when the hosting ML
//! framework was rebuilt with exported dynamic symbols (the `-rdynamic`
//! recompile); grid/block dims come straight from the intercepted launch
//! call. The ID deliberately does **not** capture kernel *inputs* — the
//! paper trades identification precision for generality (inputs are
//! `void*` at the CUDA runtime layer), and compensates with averaged
//! statistics plus runtime feedback.

use std::fmt;
use std::sync::Arc;

/// A 3-D launch dimension (CUDA `dim3` analogue).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Dim3 {
    pub x: u32,
    pub y: u32,
    pub z: u32,
}

impl Dim3 {
    pub const fn new(x: u32, y: u32, z: u32) -> Dim3 {
        Dim3 { x, y, z }
    }

    /// 1-D helper.
    pub const fn x(x: u32) -> Dim3 {
        Dim3 { x, y: 1, z: 1 }
    }

    /// Total number of elements (threads per block / blocks per grid).
    pub fn count(self) -> u64 {
        self.x as u64 * self.y as u64 * self.z as u64
    }
}

impl fmt::Display for Dim3 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "({},{},{})", self.x, self.y, self.z)
    }
}

/// The paper's Kernel ID: function name + grid dims + block dims.
///
/// The name is an `Arc<str>` — kernel ids are copied into every launch
/// message, queue entry and profile record on the hot path, so cloning
/// must be a refcount bump, not a string allocation.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct KernelId {
    /// Demangled kernel function name (empty if symbols were unavailable,
    /// i.e. the framework was *not* the `-rdynamic` rebuild).
    pub name: Arc<str>,
    /// Grid dimensions of the launch.
    pub grid: Dim3,
    /// Thread-block dimensions of the launch.
    pub block: Dim3,
}

impl KernelId {
    pub fn new(name: impl Into<Arc<str>>, grid: Dim3, block: Dim3) -> KernelId {
        KernelId {
            name: name.into(),
            grid,
            block,
        }
    }

    /// Total threads launched — a proxy for the kernel's parallelization
    /// level, which together with the name characterizes its compute
    /// intensity (paper §3.2).
    pub fn total_threads(&self) -> u64 {
        self.grid.count() * self.block.count()
    }

    /// True if the kernel function name could be resolved (i.e. the
    /// `-rdynamic` framework rebuild was in use). Without a name, kernels
    /// from different call sites collide and profiling is meaningless —
    /// the scheduler refuses to enter sharing stage for such tasks.
    pub fn has_symbol(&self) -> bool {
        !self.name.is_empty()
    }

    /// Stable string form used as a JSON map key in persisted profiles.
    ///
    /// Allocates — must never be reachable from the scheduler fill loop
    /// (DESIGN.md §Perf); debug builds count every call so tests can
    /// assert the hot path stays canonical-free.
    pub fn canonical(&self) -> String {
        #[cfg(debug_assertions)]
        canonical_audit::bump();
        format!(
            "{}|g{}x{}x{}|b{}x{}x{}",
            self.name,
            self.grid.x,
            self.grid.y,
            self.grid.z,
            self.block.x,
            self.block.y,
            self.block.z
        )
    }

    /// Parse the canonical form back (inverse of [`KernelId::canonical`]).
    pub fn from_canonical(s: &str) -> Option<KernelId> {
        let mut parts = s.rsplitn(3, '|');
        let block = parts.next()?.strip_prefix('b')?;
        let grid = parts.next()?.strip_prefix('g')?;
        let name = parts.next()?;
        let parse3 = |s: &str| -> Option<Dim3> {
            let mut it = s.split('x').map(|v| v.parse::<u32>().ok());
            Some(Dim3::new(it.next()??, it.next()??, it.next()??))
        };
        Some(KernelId {
            name: name.into(),
            grid: parse3(grid)?,
            block: parse3(block)?,
        })
    }
}

/// Debug-build call counter for [`KernelId::canonical`]. The zero-
/// allocation acceptance test ([`crate::coordinator::best_prio_fit`]
/// callers, `tests/hotpath_alloc.rs`) snapshots this around the fill loop
/// to prove no canonical-string work is reachable from it.
#[cfg(debug_assertions)]
pub mod canonical_audit {
    use std::sync::atomic::{AtomicU64, Ordering};

    static CALLS: AtomicU64 = AtomicU64::new(0);

    pub(super) fn bump() {
        CALLS.fetch_add(1, Ordering::Relaxed);
    }

    /// Total `canonical()` calls in this process so far.
    pub fn count() -> u64 {
        CALLS.load(Ordering::Relaxed)
    }
}

impl fmt::Display for KernelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}<<<{},{}>>>", self.name, self.grid, self.block)
    }
}

/// Unique identifier of one *task* — one invocation of a service (e.g. a
/// single inference request). Monotonic per simulation run.
#[derive(
    Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default,
)]
pub struct TaskId(pub u64);

impl fmt::Display for TaskId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "task#{}", self.0)
    }
}

/// The paper's *Task Key*: the unique identifier of a **service** (process
/// name + startup parameters), used as the key for profiled data. All
/// tasks issued by the same service share one TaskKey and thus one
/// profile.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskKey(pub Arc<str>);

impl TaskKey {
    pub fn new(key: impl Into<Arc<str>>) -> TaskKey {
        TaskKey(key.into())
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }
}

impl fmt::Display for TaskKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl From<&str> for TaskKey {
    fn from(s: &str) -> TaskKey {
        TaskKey::new(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dim3_counts() {
        assert_eq!(Dim3::new(2, 3, 4).count(), 24);
        assert_eq!(Dim3::x(256).count(), 256);
    }

    #[test]
    fn kernel_id_canonical_round_trip() {
        let k = KernelId::new(
            "void at::native::vectorized_elementwise_kernel<4, float>",
            Dim3::new(1024, 1, 1),
            Dim3::new(128, 2, 1),
        );
        let c = k.canonical();
        let back = KernelId::from_canonical(&c).unwrap();
        assert_eq!(back, k);
        assert_eq!(back.total_threads(), 1024 * 256);
        assert!(back.has_symbol());
    }

    #[test]
    fn kernel_id_without_symbol() {
        let k = KernelId::new("", Dim3::x(1), Dim3::x(32));
        assert!(!k.has_symbol());
        // Canonical form still round-trips with an empty name.
        assert_eq!(KernelId::from_canonical(&k.canonical()).unwrap(), k);
    }

    #[test]
    fn canonical_rejects_garbage() {
        assert!(KernelId::from_canonical("nonsense").is_none());
        assert!(KernelId::from_canonical("k|g1x1|b1x1x1").is_none());
    }

    /// Property-style sweep: `from_canonical(canonical())` is the
    /// identity for every awkward name shape the wire can produce —
    /// names containing the `|` separator, the `x` dimension separator,
    /// empty names, and combinations (the parser splits from the right,
    /// so separators inside the name must never confuse it).
    #[test]
    fn canonical_round_trip_is_identity_for_awkward_names() {
        let names = [
            "",
            "x",
            "xxx",
            "|",
            "||",
            "a|b",
            "k|g1x2x3|b4x5x6", // a name that *looks* like a canonical tail
            "vec<4, float>|x",
            "op_x|gx|bx",
            "trailing|",
            "|leading",
            "1x2x3",
            "g1x1x1",
            "b128x1x1",
        ];
        let dims = [
            (Dim3::x(1), Dim3::x(32)),
            (Dim3::new(1024, 2, 3), Dim3::new(128, 4, 1)),
            (Dim3::new(0, 0, 0), Dim3::new(0, 0, 0)),
            (Dim3::new(u32::MAX, 1, 1), Dim3::new(1, 1, u32::MAX)),
        ];
        for name in names {
            for (grid, block) in dims {
                let k = KernelId::new(name, grid, block);
                let c = k.canonical();
                let back = KernelId::from_canonical(&c)
                    .unwrap_or_else(|| panic!("canonical {c:?} failed to parse"));
                assert_eq!(back, k, "round trip broke for name {name:?}");
                assert_eq!(back.canonical(), c, "second trip not stable");
            }
        }
    }

    #[test]
    fn kernel_id_clone_is_cheap_shared_name() {
        let k = KernelId::new("kern", Dim3::x(1), Dim3::x(1));
        let k2 = k.clone();
        assert!(Arc::ptr_eq(&k.name, &k2.name));
    }
}
