//! Kernel launch descriptors — the unit of work flowing through the whole
//! system: hook client → scheduler queues → device queue → completion
//! records.

use super::{Duration, KernelHandle, KernelId, Priority, SimTime, TaskHandle, TaskId, TaskKey};

/// Where a launch entered the device queue from — used by metrics to
/// attribute device busy time and by the feedback mechanism to account
/// for un-recallable fill kernels (paper Fig 12, "overhead 2").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaunchSource {
    /// Launched directly because its task currently holds the GPU (or
    /// because the mode has no scheduler, e.g. default sharing).
    Direct,
    /// Launched by the FIKIT procedure to fill a predicted idle gap.
    GapFill,
    /// Launched while draining queues after the holding task finished.
    Drain,
}

/// A single kernel launch request as intercepted by the hook client.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelLaunch {
    /// The service this launch belongs to.
    pub task_key: TaskKey,
    /// Interned handle of `task_key` — the identity the scheduler hot
    /// path uses (integer compares and dense-table lookups; the string
    /// key is only read at reporting/persistence boundaries).
    /// [`TaskHandle::UNBOUND`] for launches built outside a sim.
    pub task_handle: TaskHandle,
    /// The specific task (invocation) within the service.
    pub task_id: TaskId,
    /// The paper's Kernel ID for this launch.
    pub kernel: KernelId,
    /// Interned handle of `kernel`, resolved once at service-attach time
    /// (never per launch). [`KernelHandle::UNBOUND`] outside a sim.
    pub kernel_handle: KernelHandle,
    /// Priority inherited from the task.
    pub priority: Priority,
    /// Sequence number of this kernel within its task (0-based).
    pub seq: u32,
    /// True device-side execution duration. In simulation this is drawn
    /// from the workload trace; the scheduler must NOT read it (it only
    /// knows profiled averages) — it is consumed by the device model.
    pub true_duration: Duration,
    /// CPU-side timestamp at which the hook intercepted the launch.
    pub issued_at: SimTime,
}

impl KernelLaunch {
    /// Total kernels of the owning task, if this is the last one.
    /// (Tracked externally; helper predicate kept for readability.)
    pub fn is_first(&self) -> bool {
        self.seq == 0
    }
}

/// A completed kernel execution, as recorded by the device.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelRecord {
    pub task_key: TaskKey,
    /// Interned task identity, carried over from the launch so completion
    /// handling (holder checks, SG lookups) stays hash-free.
    pub task_handle: TaskHandle,
    pub task_id: TaskId,
    pub kernel: KernelId,
    /// Interned kernel identity, carried over from the launch.
    pub kernel_handle: KernelHandle,
    pub priority: Priority,
    pub seq: u32,
    pub source: LaunchSource,
    /// When the launch was issued by the CPU side.
    pub issued_at: SimTime,
    /// When the device actually began executing the kernel.
    pub started_at: SimTime,
    /// When the device finished executing the kernel.
    pub finished_at: SimTime,
}

impl KernelRecord {
    /// Device-side execution duration.
    pub fn exec_time(&self) -> Duration {
        self.finished_at - self.started_at
    }

    /// Time spent waiting in queues (issue → device start).
    pub fn queue_delay(&self) -> Duration {
        self.started_at - self.issued_at
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Dim3;

    fn record() -> KernelRecord {
        KernelRecord {
            task_key: TaskKey::new("svc"),
            task_handle: TaskHandle::UNBOUND,
            task_id: TaskId(1),
            kernel: KernelId::new("k", Dim3::x(8), Dim3::x(64)),
            kernel_handle: KernelHandle::UNBOUND,
            priority: Priority::P0,
            seq: 3,
            source: LaunchSource::Direct,
            issued_at: SimTime(1_000),
            started_at: SimTime(4_000),
            finished_at: SimTime(9_000),
        }
    }

    #[test]
    fn record_durations() {
        let r = record();
        assert_eq!(r.exec_time(), Duration(5_000));
        assert_eq!(r.queue_delay(), Duration(3_000));
    }

    #[test]
    fn launch_clone_round_trip() {
        let l = KernelLaunch {
            task_key: TaskKey::new("svc"),
            task_handle: TaskHandle::UNBOUND,
            task_id: TaskId(7),
            kernel: KernelId::new("k", Dim3::x(8), Dim3::x(64)),
            kernel_handle: KernelHandle::UNBOUND,
            priority: Priority::P3,
            seq: 0,
            true_duration: Duration::from_micros(250),
            issued_at: SimTime(42),
        };
        let cloned = l.clone();
        assert_eq!(cloned, l);
        assert!(l.is_first());
    }
}
