//! Shared vocabulary types used across every FIKIT subsystem.
//!
//! These mirror the paper's §3.2 definitions: a *kernel* is identified by a
//! [`KernelId`] (function name + grid dims + block dims); a *task* (one
//! invocation of a hosted service, e.g. one inference) belongs to a service
//! identified by a [`TaskKey`]; tasks carry a [`Priority`] in `P0..=P9`
//! (P0 highest). Simulated time is a [`SimTime`] in integer nanoseconds.

mod error;
mod ids;
mod intern;
mod launch;
mod time;

pub use error::{Error, Result};
pub use ids::{Dim3, KernelId, TaskId, TaskKey};
#[cfg(debug_assertions)]
pub use ids::canonical_audit;
pub use intern::{Interner, KernelHandle, TaskHandle};
pub use launch::{KernelLaunch, KernelRecord, LaunchSource};
pub use time::{Duration, SimTime};


/// Task priority. `P0` is the highest priority, `P9` the lowest — matching
/// the paper's queues Q0 (highest) through Q9 (lowest).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u8)]
pub enum Priority {
    P0 = 0,
    P1 = 1,
    P2 = 2,
    P3 = 3,
    P4 = 4,
    P5 = 5,
    P6 = 6,
    P7 = 7,
    P8 = 8,
    P9 = 9,
}

/// Number of priority levels (queues Q0–Q9 in the paper's Fig 7).
pub const NUM_PRIORITIES: usize = 10;

impl Priority {
    /// All priorities from highest (`P0`) to lowest (`P9`).
    pub const ALL: [Priority; NUM_PRIORITIES] = [
        Priority::P0,
        Priority::P1,
        Priority::P2,
        Priority::P3,
        Priority::P4,
        Priority::P5,
        Priority::P6,
        Priority::P7,
        Priority::P8,
        Priority::P9,
    ];

    /// Queue index: 0 for the highest priority, 9 for the lowest.
    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    /// Construct from a queue index; `None` if out of range.
    pub fn from_index(idx: usize) -> Option<Priority> {
        Priority::ALL.get(idx).copied()
    }

    /// `true` if `self` is strictly higher priority (lower index) than `other`.
    #[inline]
    pub fn is_higher_than(self, other: Priority) -> bool {
        (self as u8) < (other as u8)
    }

    /// The highest priority.
    pub const HIGHEST: Priority = Priority::P0;
    /// The lowest priority.
    pub const LOWEST: Priority = Priority::P9;
}

impl std::fmt::Display for Priority {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "P{}", self.index())
    }
}

impl std::str::FromStr for Priority {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        let t = s.trim().trim_start_matches(['p', 'P', 'q', 'Q']);
        let idx: usize = t
            .parse()
            .map_err(|_| Error::Parse(format!("invalid priority: {s:?}")))?;
        Priority::from_index(idx).ok_or_else(|| Error::Parse(format!("priority out of range: {s:?}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_ordering_matches_queue_scan_order() {
        assert!(Priority::P0.is_higher_than(Priority::P1));
        assert!(Priority::P0.is_higher_than(Priority::P9));
        assert!(!Priority::P9.is_higher_than(Priority::P9));
        assert!(!Priority::P5.is_higher_than(Priority::P3));
        // Ord: P0 < P9 so sorting ascending scans highest-priority first,
        // exactly the Q0 -> Q9 scan of the paper.
        let mut v = vec![Priority::P7, Priority::P0, Priority::P3];
        v.sort();
        assert_eq!(v, vec![Priority::P0, Priority::P3, Priority::P7]);
    }

    #[test]
    fn priority_round_trips_through_index_and_str() {
        for p in Priority::ALL {
            assert_eq!(Priority::from_index(p.index()), Some(p));
            assert_eq!(p.to_string().parse::<Priority>().unwrap(), p);
        }
        assert_eq!(Priority::from_index(10), None);
        assert!("P10".parse::<Priority>().is_err());
        assert!("x".parse::<Priority>().is_err());
        assert_eq!("q3".parse::<Priority>().unwrap(), Priority::P3);
    }
}
