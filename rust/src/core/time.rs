//! Virtual time. All simulation timestamps and durations are integer
//! nanoseconds: deterministic arithmetic, total ordering, and enough range
//! (u64 ns ≈ 584 years) for any experiment.

use std::ops::{Add, AddAssign, Sub, SubAssign};

/// A point in simulated time (nanoseconds since simulation start).
#[derive(
    Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default,
)]
pub struct SimTime(pub u64);

/// A span of simulated time (nanoseconds).
#[derive(
    Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default,
)]
pub struct Duration(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);
    pub const MAX: SimTime = SimTime(u64::MAX);

    #[inline]
    pub fn nanos(self) -> u64 {
        self.0
    }

    #[inline]
    pub fn as_micros_f64(self) -> f64 {
        self.0 as f64 / 1_000.0
    }

    #[inline]
    pub fn as_millis_f64(self) -> f64 {
        self.0 as f64 / 1_000_000.0
    }

    #[inline]
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1_000_000_000.0
    }

    /// Duration from `earlier` to `self`; saturates at zero if `earlier`
    /// is in the future (never panics in release-mode metric paths).
    #[inline]
    pub fn since(self, earlier: SimTime) -> Duration {
        Duration(self.0.saturating_sub(earlier.0))
    }

    #[inline]
    pub fn max(self, other: SimTime) -> SimTime {
        SimTime(self.0.max(other.0))
    }

    #[inline]
    pub fn min(self, other: SimTime) -> SimTime {
        SimTime(self.0.min(other.0))
    }
}

impl Duration {
    pub const ZERO: Duration = Duration(0);

    #[inline]
    pub fn nanos(self) -> u64 {
        self.0
    }

    pub fn from_nanos(ns: u64) -> Duration {
        Duration(ns)
    }

    pub fn from_micros(us: u64) -> Duration {
        Duration(us * 1_000)
    }

    pub fn from_millis(ms: u64) -> Duration {
        Duration(ms * 1_000_000)
    }

    pub fn from_secs(s: u64) -> Duration {
        Duration(s * 1_000_000_000)
    }

    /// Construct from fractional milliseconds (rounds to nearest ns).
    pub fn from_millis_f64(ms: f64) -> Duration {
        Duration((ms * 1_000_000.0).round().max(0.0) as u64)
    }

    /// Construct from fractional microseconds (rounds to nearest ns).
    pub fn from_micros_f64(us: f64) -> Duration {
        Duration((us * 1_000.0).round().max(0.0) as u64)
    }

    #[inline]
    pub fn as_millis_f64(self) -> f64 {
        self.0 as f64 / 1_000_000.0
    }

    #[inline]
    pub fn as_micros_f64(self) -> f64 {
        self.0 as f64 / 1_000.0
    }

    #[inline]
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1_000_000_000.0
    }

    #[inline]
    pub fn saturating_sub(self, other: Duration) -> Duration {
        Duration(self.0.saturating_sub(other.0))
    }

    #[inline]
    pub fn is_zero(self) -> bool {
        self.0 == 0
    }

    /// Scale by a non-negative factor (rounds to nearest ns).
    pub fn scale(self, factor: f64) -> Duration {
        debug_assert!(factor >= 0.0, "negative duration scale");
        Duration((self.0 as f64 * factor).round().max(0.0) as u64)
    }
}

impl Add<Duration> for SimTime {
    type Output = SimTime;
    #[inline]
    fn add(self, rhs: Duration) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign<Duration> for SimTime {
    #[inline]
    fn add_assign(&mut self, rhs: Duration) {
        self.0 += rhs.0;
    }
}

impl Sub<SimTime> for SimTime {
    type Output = Duration;
    #[inline]
    fn sub(self, rhs: SimTime) -> Duration {
        Duration(self.0.saturating_sub(rhs.0))
    }
}

impl Add for Duration {
    type Output = Duration;
    #[inline]
    fn add(self, rhs: Duration) -> Duration {
        Duration(self.0 + rhs.0)
    }
}

impl AddAssign for Duration {
    #[inline]
    fn add_assign(&mut self, rhs: Duration) {
        self.0 += rhs.0;
    }
}

impl Sub for Duration {
    type Output = Duration;
    #[inline]
    fn sub(self, rhs: Duration) -> Duration {
        Duration(self.0.saturating_sub(rhs.0))
    }
}

impl SubAssign for Duration {
    #[inline]
    fn sub_assign(&mut self, rhs: Duration) {
        self.0 = self.0.saturating_sub(rhs.0);
    }
}

impl std::iter::Sum for Duration {
    fn sum<I: Iterator<Item = Duration>>(iter: I) -> Duration {
        Duration(iter.map(|d| d.0).sum())
    }
}

impl std::fmt::Display for SimTime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.3}ms", self.as_millis_f64())
    }
}

impl std::fmt::Display for Duration {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.0 >= 1_000_000 {
            write!(f, "{:.3}ms", self.as_millis_f64())
        } else if self.0 >= 1_000 {
            write!(f, "{:.3}us", self.as_micros_f64())
        } else {
            write!(f, "{}ns", self.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_arithmetic() {
        let t0 = SimTime::ZERO;
        let t1 = t0 + Duration::from_millis(2);
        assert_eq!(t1.nanos(), 2_000_000);
        assert_eq!(t1 - t0, Duration::from_millis(2));
        assert_eq!(t0 - t1, Duration::ZERO); // saturating
        assert_eq!(t1.since(t0).as_millis_f64(), 2.0);
    }

    #[test]
    fn duration_conversions_and_scaling() {
        assert_eq!(Duration::from_millis_f64(0.1).nanos(), 100_000);
        assert_eq!(Duration::from_micros(5).nanos(), 5_000);
        assert_eq!(Duration::from_millis(3).scale(1.5).nanos(), 4_500_000);
        assert_eq!(Duration::from_millis(3).scale(0.0), Duration::ZERO);
        let sum: Duration = [Duration::from_millis(1), Duration::from_micros(500)]
            .into_iter()
            .sum();
        assert_eq!(sum.nanos(), 1_500_000);
    }

    #[test]
    fn display_picks_sensible_units() {
        assert_eq!(Duration::from_nanos(12).to_string(), "12ns");
        assert_eq!(Duration::from_micros(12).to_string(), "12.000us");
        assert_eq!(Duration::from_millis(12).to_string(), "12.000ms");
    }
}
