//! The perf benchmark suites, shared by the `cargo bench` target
//! (`benches/scheduler_hotpath.rs`) and the `fikit bench` CLI
//! subcommand — producing the `BENCH_sched.json` (scheduler hot path)
//! and `BENCH_sim.json` (simulator event core) artifacts, the measured
//! perf trajectory of the repo (DESIGN.md §Perf).
//!
//! Each case may declare a **budget** (mean ns, or an events/sec floor
//! for rate cases); `scripts/check_bench.py` fails the build when a
//! budgeted case misses it. The scheduler headline budget comes straight
//! from the paper's ε: a BestPrioFit decision at 512 queued requests
//! must stay ≤ 1 µs mean, three orders of magnitude under the smallest
//! gap worth filling. The simulator headline is fleet-scale capacity: a
//! full deterministic run must push ≥ 500 k events/s through the
//! calendar-wheel core (ADR-003).
//!
//! Regenerate both artifacts from the repo root with ONE command:
//!
//! ```text
//! cargo run --manifest-path rust/Cargo.toml --release -- bench --json
//! ```
//!
//! (or `BENCH_JSON=../BENCH_sched.json cargo bench --bench
//! scheduler_hotpath` — cargo runs bench binaries with cwd at the
//! package root `rust/`, and `check_bench.py` reads the repo root).

use crate::config::{ExperimentConfig, ServiceConfig};
use crate::coordinator::best_prio_fit::best_prio_fit;
use crate::coordinator::driver::{run_experiment_scratch, SimScratch};
use crate::coordinator::fikit::{fikit_fill, FillWindow, DEFAULT_EPSILON};
use crate::coordinator::queues::PriorityQueues;
use crate::coordinator::Mode;
use crate::core::{
    Dim3, Duration, Interner, KernelId, KernelLaunch, Priority, Result, SimTime, TaskHandle,
    TaskId, TaskKey,
};
use crate::profile::{ResolvedProfile, TaskProfile};
use crate::simulator::{BaselineHeapQueue, CalendarWheel};
use crate::util::bench::{black_box, BenchResult, Bencher};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::ModelKind;
use std::collections::BTreeMap;

/// Schema version of `BENCH_*.json` (bump on shape changes, in lockstep
/// with `scripts/check_bench.py`).
pub const BENCH_JSON_VERSION: u64 = 1;

/// A suite's results plus per-case budgets.
pub struct SuiteReport {
    /// Suite name, emitted as the artifact's `suite` field.
    pub suite: &'static str,
    pub results: Vec<BenchResult>,
    /// Case name → mean-ns budget. Only budgeted cases are gated.
    pub budgets: BTreeMap<String, u64>,
    /// Case name → `(events_per_sec, floor)` throughput gates. The case
    /// also appears in `results` (its mean is the per-run wall time);
    /// this map adds the derived rate and its declared floor.
    pub rates: BTreeMap<String, (u64, u64)>,
    /// Rendered text table (for terminal output).
    pub table: String,
}

impl SuiteReport {
    /// Budget violations, empty when every gated case is within budget.
    pub fn violations(&self) -> Vec<String> {
        let mut out = Vec::new();
        for r in &self.results {
            if let Some(&budget) = self.budgets.get(&r.name) {
                let mean = r.mean.as_nanos() as u64;
                if mean > budget {
                    out.push(format!(
                        "{}: mean {}ns exceeds budget {}ns",
                        r.name, mean, budget
                    ));
                }
            }
        }
        for (name, &(rate, floor)) in &self.rates {
            if rate < floor {
                out.push(format!(
                    "{name}: {rate} events/s under budget {floor} events/s"
                ));
            }
        }
        out
    }

    /// The `BENCH_*.json` document.
    pub fn to_json(&self) -> Json {
        let cases = self
            .results
            .iter()
            .map(|r| {
                let mut case = r.to_json();
                if let Some(&budget) = self.budgets.get(&r.name) {
                    case = case.set("budget_ns", budget);
                }
                if let Some(&(rate, floor)) = self.rates.get(&r.name) {
                    case = case
                        .set("events_per_sec", rate)
                        .set("budget_events_per_sec", floor);
                }
                case
            })
            .collect();
        Json::obj()
            .set("version", BENCH_JSON_VERSION)
            .set("suite", self.suite)
            .set("cases", Json::Arr(cases))
    }

    /// Write the JSON artifact (pretty, trailing newline).
    pub fn write_json(&self, path: &str) -> Result<()> {
        let mut text = self.to_json().encode_pretty();
        text.push('\n');
        std::fs::write(path, text)?;
        Ok(())
    }
}

/// Kernel id `i` of the bench world (`kernel_i`, fixed dims).
pub fn bench_kernel_id(i: usize) -> KernelId {
    KernelId::new(format!("kernel_{i}"), Dim3::x(64), Dim3::x(256))
}

/// The bench world: 8 services × 32 kernels, profiles resolved through
/// an interner exactly like the driver does at attach time. Shared with
/// the zero-allocation acceptance test (`tests/hotpath_alloc.rs`) so
/// both gates measure the same attach-time-resolution fixture.
pub struct BenchWorld {
    pub interner: Interner,
    /// Attach-time resolutions, indexed by service (= task handle).
    pub resolved: Vec<ResolvedProfile>,
}

/// One bench-world profile: kernels `kernel_0..kernel_31` with
/// `SK(kernel_k) = 20 + 13k mod 300` µs and a uniform `sg_us` gap. The
/// single source of the fixture formula — both the resolved world and
/// the string-keyed comparison case build from here.
pub fn bench_profile(key: TaskKey, sg_us: u64) -> TaskProfile {
    let mut p = TaskProfile::new(key);
    for k in 0..32 {
        p.record(
            &bench_kernel_id(k),
            Duration::from_micros(20 + (k as u64 * 13) % 300),
            Some(Duration::from_micros(sg_us)),
        );
    }
    p.finish_run(32);
    p
}

/// Build the world; `sg_us` is the profiled following gap of every
/// kernel (the fill-window size the holder's completions open).
pub fn bench_world(sg_us: u64) -> BenchWorld {
    let mut interner = Interner::new();
    let mut resolved = Vec::new();
    for svc in 0..8usize {
        let key = TaskKey::new(format!("svc{svc}"));
        interner.intern_task(&key);
        let p = bench_profile(key, sg_us);
        resolved.push(ResolvedProfile::resolve(&p, &mut interner));
    }
    BenchWorld { interner, resolved }
}

impl BenchWorld {
    /// Launch `i`: service `svc{i % 8}`, kernel `kernel_{i % 32}`, with
    /// bound handles (interner lookups hit — nothing is minted after
    /// [`bench_world`] returns).
    pub fn launch(&mut self, i: usize, prio: Priority) -> KernelLaunch {
        let key = TaskKey::new(format!("svc{}", i % 8));
        let kernel = bench_kernel_id(i % 32);
        KernelLaunch {
            task_handle: self.interner.intern_task(&key),
            kernel_handle: self.interner.intern_kernel(&kernel),
            task_key: key,
            task_id: TaskId(i as u64),
            kernel,
            priority: prio,
            seq: i as u32,
            true_duration: Duration::from_micros(50),
            issued_at: SimTime(i as u64),
        }
    }

    /// Production path: predictions resolved at enqueue from the
    /// attach-time ResolvedProfile, exactly like `FikitScheduler`.
    pub fn filled_queues(&mut self, n: usize) -> PriorityQueues {
        let mut q = PriorityQueues::new();
        let mut rng = Rng::new(42);
        for i in 0..n {
            let prio = Priority::from_index(1 + rng.index(9)).unwrap();
            let l = self.launch(i, prio);
            let predicted = self.resolved[l.task_handle.index()].sk(l.kernel_handle);
            debug_assert!(predicted.is_some());
            q.push_predicted(l, predicted, SimTime(i as u64));
        }
        q
    }
}

/// The pre-index selection loop (full FIFO scan per priority), kept so
/// every `BENCH_sched.json` carries its own before/after comparison —
/// `best_prio_fit/scan_linear_*` vs `best_prio_fit/select_*`.
fn linear_longest_fit(queues: &PriorityQueues, idle: Duration) -> Option<(Priority, Duration)> {
    for p in Priority::ALL {
        let mut best = Duration::ZERO;
        let mut found = false;
        for req in queues.iter_at(p) {
            let Some(d) = req.predicted else { continue };
            if d < idle && d > best {
                best = d;
                found = true;
            }
        }
        if found {
            return Some((p, best));
        }
    }
    None
}

/// Run the hot-path suite. `quick` trades fidelity for ~100 ms/case.
pub fn run_hotpath_suite(quick: bool) -> SuiteReport {
    let mut b = if quick { Bencher::quick() } else { Bencher::new() };
    let mut budgets = BTreeMap::new();
    let mut w = bench_world(40);

    // --- queue operations ---
    b.bench("queues/push_pop_n16", {
        let mut pool: Vec<KernelLaunch> = (0..16).map(|i| w.launch(i, Priority::P5)).collect();
        move || {
            let mut q = PriorityQueues::new();
            for l in pool.drain(..) {
                q.push_predicted(l, Some(Duration::from_micros(50)), SimTime(0));
            }
            while let Some(r) = q.pop_highest() {
                pool.push(r.launch);
            }
            black_box(pool.len())
        }
    });

    // --- BestPrioFit decision cost vs queue depth (the core decision).
    // Steady state: an idle window smaller than every profiled SK, so
    // the full priority walk happens but nothing is removed.
    for n in [8usize, 64, 512, 2048] {
        let mut q = w.filled_queues(n);
        b.bench(&format!("best_prio_fit/select_n{n}"), || {
            black_box(best_prio_fit(&mut q, Duration::from_nanos(1)))
        });
        budgets.insert(format!("best_prio_fit/select_n{n}"), 1_000);
        // Before/after trajectory: the old full-scan selection.
        let q = w.filled_queues(n);
        b.bench(&format!("best_prio_fit/scan_linear_n{n}"), || {
            black_box(linear_longest_fit(&q, Duration::from_nanos(1)))
        });
    }
    // Successful fit: select + remove, then re-queue to keep the state
    // stable across iterations. n512 is gated alongside select_n512 so
    // the 1 µs-class budget also covers the decision's *mutation* work
    // (fit-index memmove + unlink + re-insert), not just the probe.
    for n in [64usize, 512] {
        let mut q = w.filled_queues(n);
        let name = format!("best_prio_fit/fit_and_requeue_n{n}");
        b.bench(&name, || {
            if let Some(fit) = best_prio_fit(&mut q, Duration::from_micros(500)) {
                let predicted = fit.predicted;
                q.push_predicted(fit.launch, Some(predicted), SimTime(0));
            }
        });
        budgets.insert(name, 2_000);
    }

    // --- full FIKIT fill window (Algorithm 1 loop). The fixture is
    // built ONCE and drained fills are re-queued per iteration, so the
    // gated number measures the fill loop, not fixture construction. ---
    {
        let mut q = w.filled_queues(64);
        b.bench("fikit_fill/window_1ms_q64", || {
            let mut win = FillWindow::open(
                TaskHandle::from_index(0),
                SimTime::ZERO,
                Duration::from_millis(1),
                DEFAULT_EPSILON,
            )
            .unwrap();
            let fills = fikit_fill(&mut win, SimTime::ZERO, &mut q);
            let n = fills.len();
            for fit in fills {
                let predicted = fit.predicted;
                q.push_predicted(fit.launch, Some(predicted), SimTime(0));
            }
            black_box(n)
        });
        budgets.insert("fikit_fill/window_1ms_q64".to_string(), 50_000);
    }

    // --- preemption decision cycle (ADR-007): submit a fill, probe the
    // policy against its (start, finish), cut the in-flight record,
    // re-queue the remnant, then drain the stale completion through the
    // arena tombstone — the full extra work a high-priority launch pays
    // when it reclaims an overrunning fill mid-execution. ---
    {
        use crate::coordinator::best_prio_fit::{plan_preempt, PreemptAction};
        use crate::coordinator::fikit::{PreemptionPolicy, DEFAULT_PREEMPT_COST};
        use crate::core::LaunchSource;
        use crate::simulator::{DeviceConfig, KernelArena, SimDevice};
        let mut device = SimDevice::new(DeviceConfig::default());
        let mut arena = KernelArena::new();
        let mut q = w.filled_queues(64);
        let fill = w.launch(0, Priority::P5);
        let mut t = 0u64;
        b.bench("preempt/decide", move || {
            // Spaced so the device is always idle again by the next
            // iteration: every cycle sees the same geometry.
            t += 200_000;
            let now = SimTime(t);
            let rec = device.submit(fill.clone(), now, LaunchSource::GapFill);
            let (started, finished) = (rec.started_at, rec.finished_at);
            let slot = arena.insert(rec);
            // A high-priority launch lands mid-execution (fraction 0.6 of
            // the 50 µs fill): Evict plans Cut{ready}.
            let ready = now + Duration::from_micros(35);
            let mut reclaimed = false;
            if let PreemptAction::Cut { cut_at } | PreemptAction::Split { cut_at } =
                plan_preempt(PreemptionPolicy::Evict, ready, started, finished)
            {
                let live = arena.get(slot).expect("fill is live");
                if device.preempt(live, cut_at, DEFAULT_PREEMPT_COST) {
                    let _ = arena.cancel(slot);
                    q.push_predicted(fill.clone(), Some(Duration::from_micros(20)), cut_at);
                    black_box(q.pop_highest());
                    reclaimed = true;
                }
            }
            // The stale completion pops through the tombstone, freeing
            // the slot for reuse next iteration.
            black_box(arena.take_if_live(slot).is_none() == reclaimed)
        });
        budgets.insert("preempt/decide".to_string(), 2_000);
    }

    // --- learned-interference hot path (ADR-006): the per-completion
    // EWMA observe + the per-scan predicted-dilation blend, both O(1)
    // probes of the dense pair tables and allocation-free in steady
    // state (gated by tests/hotpath_alloc.rs). ---
    {
        use crate::cluster::InterferenceModel;
        let mut model = InterferenceModel::default();
        let mut i = 0usize;
        b.bench("interference/observe_and_predict", move || {
            let victim = ModelKind::ALL[i % ModelKind::COUNT];
            let aggressor = ModelKind::ALL[(i / ModelKind::COUNT) % ModelKind::COUNT];
            i += 1;
            model.observe(victim, aggressor, 1.25);
            black_box(model.high_slowdown(victim, aggressor))
        });
        budgets.insert("interference/observe_and_predict".to_string(), 500);
    }

    // --- per-completion profile lookups: resolved (hot path) vs the
    // string-keyed store probe it replaced ---
    {
        let rp = w.resolved[0].clone();
        let h = w.interner.kernel_handle(&bench_kernel_id(7)).unwrap();
        b.bench("profile/sg_lookup_resolved", || black_box(rp.sg(h)));
        budgets.insert("profile/sg_lookup_resolved".to_string(), 200);

        let p = bench_profile(TaskKey::new("svc0"), 40);
        let k7 = bench_kernel_id(7);
        b.bench("profile/sg_lookup_store", || black_box(p.sg(&k7)));
    }

    let table = b.report();
    SuiteReport {
        suite: "scheduler_hotpath",
        results: b.results().to_vec(),
        budgets,
        rates: BTreeMap::new(),
        table,
    }
}

/// The deterministic fixture behind the `sim/events_per_sec` headline:
/// a two-tenant contended run (high-priority Alexnet vs low-priority
/// VGG16) on the default sharing path — no measurement stage, so every
/// benched nanosecond is the event core, device model, and service
/// loops.
fn sim_headline_config() -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        mode: Mode::Sharing,
        seed: 0xBE7C,
        ..ExperimentConfig::default()
    };
    cfg.services
        .push(ServiceConfig::new(ModelKind::Alexnet, Priority::P0).tasks(12));
    cfg.services
        .push(ServiceConfig::new(ModelKind::Vgg16, Priority::P5).tasks(12));
    cfg
}

/// Floor for the `sim/events_per_sec` headline (events per second a
/// full run must sustain through the calendar-wheel event core).
pub const SIM_EVENTS_PER_SEC_FLOOR: u64 = 500_000;

/// Run the simulator event-core suite (`BENCH_sim.json`). `quick`
/// trades fidelity for ~100 ms/case.
pub fn run_sim_suite(quick: bool) -> SuiteReport {
    let mut b = if quick { Bencher::quick() } else { Bencher::new() };
    let mut rates = BTreeMap::new();

    // --- event-wheel push/pop vs the old binary heap (the before/after
    // trajectory of ADR-003). Dense band: 256 events ~1.5 µs apart, the
    // shape a busy device queue produces. ---
    const BURST: usize = 256;
    b.bench("wheel/push_pop_burst_n256", {
        let mut wheel: CalendarWheel<u64> = CalendarWheel::default();
        let mut t = 0u64;
        move || {
            for i in 0..BURST {
                t += 1_500;
                wheel.push(SimTime(t), i as u64);
            }
            let mut sum = 0u64;
            while let Some((_, v)) = wheel.pop() {
                sum += v;
            }
            black_box(sum)
        }
    });
    b.bench("wheel/heap_push_pop_burst_n256", {
        let mut heap: BaselineHeapQueue<u64> = BaselineHeapQueue::new();
        let mut t = 0u64;
        move || {
            for i in 0..BURST {
                t += 1_500;
                heap.push(SimTime(t), i as u64);
            }
            let mut sum = 0u64;
            while let Some((_, v)) = heap.pop() {
                sum += v;
            }
            black_box(sum)
        }
    });
    // Far-future mix: every 4th event lands ~200 ms out, exercising the
    // overflow ring and its refill on cursor advance.
    b.bench("wheel/far_future_mix_n256", {
        let mut wheel: CalendarWheel<u64> = CalendarWheel::default();
        let mut t = 0u64;
        move || {
            for i in 0..BURST {
                t += 1_500;
                let at = if i % 4 == 0 { t + 200_000_000 } else { t };
                wheel.push(SimTime(at), i as u64);
            }
            let mut sum = 0u64;
            while let Some((_, v)) = wheel.pop() {
                sum += v;
            }
            black_box(sum)
        }
    });

    // --- headline: events/sec of a full deterministic run. The event
    // count is fixed by the seed; the rate divides it by the measured
    // mean wall time. Scratch reuse keeps every iteration allocation-
    // stable, exactly like the fig-sweep callers. ---
    let cfg = sim_headline_config();
    let mut scratch = SimScratch::new();
    let events = run_experiment_scratch(&cfg, &mut scratch)
        .expect("sim bench fixture runs")
        .events;
    b.bench("sim/events_per_sec", || {
        black_box(
            run_experiment_scratch(&cfg, &mut scratch)
                .expect("sim bench fixture runs")
                .events,
        )
    });
    let mean_ns = b
        .results()
        .last()
        .expect("headline case just ran")
        .mean
        .as_nanos()
        .max(1) as u64;
    let rate = (events as u128 * 1_000_000_000 / mean_ns as u128) as u64;
    rates.insert(
        "sim/events_per_sec".to_string(),
        (rate, SIM_EVENTS_PER_SEC_FLOOR),
    );

    let table = b.report();
    SuiteReport {
        suite: "sim_core",
        results: b.results().to_vec(),
        budgets: BTreeMap::new(),
        rates,
        table,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::KernelHandle;

    #[test]
    fn suite_runs_and_serializes() {
        let report = run_hotpath_suite(true);
        assert!(report.results.len() >= 10);
        let doc = report.to_json();
        assert_eq!(doc.req_u64("version").unwrap(), BENCH_JSON_VERSION);
        assert_eq!(doc.req_str("suite").unwrap(), "scheduler_hotpath");
        let cases = doc.req_arr("cases").unwrap();
        assert_eq!(cases.len(), report.results.len());
        // The headline gate is present and budgeted at 1us.
        let gate = cases
            .iter()
            .find(|c| c.req_str("name").unwrap() == "best_prio_fit/select_n512")
            .expect("headline case missing");
        assert_eq!(gate.req_u64("budget_ns").unwrap(), 1_000);
        // The preemption decision cycle is present and budgeted.
        let preempt = cases
            .iter()
            .find(|c| c.req_str("name").unwrap() == "preempt/decide")
            .expect("preempt decision case missing");
        assert_eq!(preempt.req_u64("budget_ns").unwrap(), 2_000);
        // Round-trips through the JSON substrate.
        let parsed = Json::parse(&doc.encode_pretty()).unwrap();
        assert_eq!(parsed, doc);
    }

    #[test]
    fn violations_flag_over_budget_cases() {
        let mut report = run_hotpath_suite(true);
        // Force a violation.
        let name = report.results[0].name.clone();
        report.budgets.insert(name, 0);
        assert!(!report.violations().is_empty());
    }

    #[test]
    fn sim_suite_emits_events_per_sec_headline() {
        let report = run_sim_suite(true);
        let doc = report.to_json();
        assert_eq!(doc.req_str("suite").unwrap(), "sim_core");
        let cases = doc.req_arr("cases").unwrap();
        let headline = cases
            .iter()
            .find(|c| c.req_str("name").unwrap() == "sim/events_per_sec")
            .expect("headline case missing");
        assert!(headline.req_u64("events_per_sec").unwrap() > 0);
        assert_eq!(
            headline.req_u64("budget_events_per_sec").unwrap(),
            SIM_EVENTS_PER_SEC_FLOOR
        );
        // Both wheel comparison cases made it into the artifact.
        for name in ["wheel/push_pop_burst_n256", "wheel/heap_push_pop_burst_n256"] {
            assert!(cases.iter().any(|c| c.req_str("name").unwrap() == name));
        }
    }

    #[test]
    fn rate_floors_gate_violations() {
        let mut report = run_sim_suite(true);
        let (rate, _) = report.rates["sim/events_per_sec"];
        // An unreachable floor flags; the measured rate passes itself.
        report
            .rates
            .insert("sim/events_per_sec".to_string(), (rate, u64::MAX));
        assert!(!report.violations().is_empty());
        report
            .rates
            .insert("sim/events_per_sec".to_string(), (rate, rate));
        assert!(report.violations().is_empty());
    }

    #[test]
    fn world_predictions_match_store_values() {
        // The dense resolved lookup returns exactly what the string-keyed
        // profile would: by construction SK(kernel_k) = 20 + 13k % 300.
        let mut w = bench_world(40);
        let l = w.launch(7, Priority::P3);
        let got = w.resolved[l.task_handle.index()].sk(l.kernel_handle).unwrap();
        assert_eq!(got, Duration::from_micros(20 + (7 * 13) % 300));
    }

    #[test]
    fn unbound_handles_never_resolve() {
        let w = bench_world(40);
        assert!(w.resolved[0].sk(KernelHandle::UNBOUND).is_none());
    }
}
