//! JCT statistics and report formatting.
//!
//! Every paper figure reduces to ratios of JCT statistics between modes;
//! this module owns those reductions: mean/σ/CV (Table 3), percentiles,
//! speedup ratios (Figs 16–20), and per-arrival timelines (Fig 21).
//! The [`fleet`] submodule extends them across devices and time for the
//! dynamic cluster simulation (windowed fleet-wide QoS trajectories).

pub mod fleet;

pub use fleet::{FleetMetrics, FleetSample, FleetWindowStats};

use crate::core::{Duration, SimTime};

/// Summary statistics over a set of job completion times.
#[derive(Debug, Clone, Default)]
pub struct JctStats {
    /// Number of completed tasks.
    pub count: usize,
    /// Mean JCT.
    pub mean: Duration,
    /// Population standard deviation.
    pub std: Duration,
    /// Coefficient of variation σ/μ (Table 3's stability metric).
    pub cv: f64,
    /// Fastest completion.
    pub min: Duration,
    /// Slowest completion.
    pub max: Duration,
    /// Median (nearest-rank).
    pub p50: Duration,
    /// 95th percentile (nearest-rank).
    pub p95: Duration,
    /// 99th percentile (nearest-rank).
    pub p99: Duration,
    /// Σ of all JCTs.
    pub total: Duration,
}

impl JctStats {
    /// Compute from a set of durations. Empty input yields zeros.
    pub fn from_durations(mut jcts: Vec<Duration>) -> JctStats {
        if jcts.is_empty() {
            return JctStats::default();
        }
        jcts.sort();
        let n = jcts.len();
        let total_ns: u128 = jcts.iter().map(|d| d.nanos() as u128).sum();
        let mean = total_ns as f64 / n as f64;
        let var = jcts
            .iter()
            .map(|d| {
                let x = d.nanos() as f64 - mean;
                x * x
            })
            .sum::<f64>()
            / n as f64;
        let std = var.sqrt();
        // Nearest-rank percentile: idx = ceil(q·n) − 1.
        let pct = |q: f64| -> Duration {
            let idx = (q * n as f64).ceil() as usize;
            jcts[idx.saturating_sub(1).min(n - 1)]
        };
        JctStats {
            count: n,
            mean: Duration::from_nanos(mean.round() as u64),
            std: Duration::from_nanos(std.round() as u64),
            cv: if mean > 0.0 { std / mean } else { 0.0 },
            min: jcts[0],
            max: jcts[n - 1],
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            total: Duration::from_nanos(total_ns.min(u64::MAX as u128) as u64),
        }
    }

    /// Mean JCT in milliseconds.
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_millis_f64()
    }
}

/// Ratio of two mean JCTs — `speedup(share, fikit) > 1` means FIKIT is
/// faster (the paper's Figs 16/19 metric).
pub fn speedup(baseline: &JctStats, candidate: &JctStats) -> f64 {
    if candidate.mean.nanos() == 0 {
        return 0.0;
    }
    baseline.mean.nanos() as f64 / candidate.mean.nanos() as f64
}

/// Percentage difference of `candidate` relative to `baseline`
/// (the Fig 13/14/15 metric: `(cand - base) / base * 100`).
pub fn pct_diff(baseline: &JctStats, candidate: &JctStats) -> f64 {
    if baseline.mean.nanos() == 0 {
        return 0.0;
    }
    (candidate.mean.nanos() as f64 - baseline.mean.nanos() as f64)
        / baseline.mean.nanos() as f64
        * 100.0
}

/// Fixed-count windows of a prediction-error stream — the convergence
/// trajectory of the online profile refiner (DESIGN.md §9): each closed
/// window holds the mean error of `per` consecutive observations, so a
/// drift injection is visible as one window spiking and later windows
/// recovering rather than being averaged away (the same design as the
/// fleet QoS windows in [`fleet`]).
#[derive(Debug, Clone, Default)]
pub struct WindowedError {
    per: u64,
    cur_n: u64,
    cur_sum: f64,
    closed: Vec<f64>,
}

impl WindowedError {
    /// A tracker closing a window every `per` observations (`per ≥ 1`).
    pub fn new(per: u64) -> WindowedError {
        WindowedError {
            per: per.max(1),
            ..Default::default()
        }
    }

    /// Record one error observation (e.g. a relative prediction error).
    pub fn record(&mut self, err: f64) {
        self.cur_sum += err;
        self.cur_n += 1;
        if self.cur_n >= self.per {
            self.closed.push(self.cur_sum / self.cur_n as f64);
            self.cur_n = 0;
            self.cur_sum = 0.0;
        }
    }

    /// Mean error per closed window, in observation order.
    pub fn windows(&self) -> &[f64] {
        &self.closed
    }

    /// Total observations recorded (closed windows plus the partial one).
    pub fn observations(&self) -> u64 {
        self.closed.len() as u64 * self.per + self.cur_n
    }
}

/// One point of a per-arrival JCT timeline (Fig 21).
#[derive(Debug, Clone)]
pub struct TimelinePoint {
    /// When the task's invocation arrived.
    pub arrival: SimTime,
    /// Its job completion time.
    pub jct: Duration,
}

/// A per-service JCT timeline with its stability statistics.
#[derive(Debug, Clone)]
pub struct Timeline {
    /// Points sorted by arrival time.
    pub points: Vec<TimelinePoint>,
}

impl Timeline {
    pub fn new(mut points: Vec<TimelinePoint>) -> Timeline {
        points.sort_by_key(|p| p.arrival);
        Timeline { points }
    }

    pub fn stats(&self) -> JctStats {
        JctStats::from_durations(self.points.iter().map(|p| p.jct).collect())
    }

    /// Render a compact sparkline of the JCT series (for CLI output).
    pub fn sparkline(&self) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        if self.points.is_empty() {
            return String::new();
        }
        let min = self.points.iter().map(|p| p.jct.nanos()).min().unwrap();
        let max = self.points.iter().map(|p| p.jct.nanos()).max().unwrap();
        let span = (max - min).max(1);
        self.points
            .iter()
            .map(|p| {
                let idx = ((p.jct.nanos() - min) * 7 / span) as usize;
                BARS[idx.min(7)]
            })
            .collect()
    }
}

/// Minimal fixed-width text table for experiment output.
#[derive(Debug, Default)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(header: &[&str]) -> TextTable {
        TextTable {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(cols) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for (i, w) in widths.iter().enumerate() {
                let empty = String::new();
                let c = cells.get(i).unwrap_or(&empty);
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<1$}|", "", w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    #[test]
    fn stats_basic() {
        let s = JctStats::from_durations(vec![ms(10), ms(20), ms(30), ms(40)]);
        assert_eq!(s.count, 4);
        assert_eq!(s.mean, ms(25));
        assert_eq!(s.min, ms(10));
        assert_eq!(s.max, ms(40));
        assert_eq!(s.total, ms(100));
        // σ of {10,20,30,40} (population) ≈ 11.18ms
        assert!((s.std.as_millis_f64() - 11.1803).abs() < 0.01);
        assert!((s.cv - 0.4472).abs() < 0.001);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = JctStats::from_durations(vec![]);
        assert_eq!(s.count, 0);
        assert_eq!(s.mean, Duration::ZERO);
        assert_eq!(s.cv, 0.0);
    }

    #[test]
    fn speedup_and_pct_diff() {
        let base = JctStats::from_durations(vec![ms(100)]);
        let fast = JctStats::from_durations(vec![ms(25)]);
        assert!((speedup(&base, &fast) - 4.0).abs() < 1e-9);
        assert!((pct_diff(&base, &fast) + 75.0).abs() < 1e-9);
        let slow = JctStats::from_durations(vec![ms(105)]);
        assert!((pct_diff(&base, &slow) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn percentiles() {
        let jcts: Vec<Duration> = (1..=100).map(ms).collect();
        let s = JctStats::from_durations(jcts);
        assert_eq!(s.p50, ms(50));
        assert_eq!(s.p95, ms(95));
        assert_eq!(s.p99, ms(99));
    }

    #[test]
    fn timeline_sorted_and_sparkline() {
        let t = Timeline::new(vec![
            TimelinePoint { arrival: SimTime(2), jct: ms(20) },
            TimelinePoint { arrival: SimTime(1), jct: ms(10) },
            TimelinePoint { arrival: SimTime(3), jct: ms(40) },
        ]);
        assert_eq!(t.points[0].arrival, SimTime(1));
        let spark = t.sparkline();
        assert_eq!(spark.chars().count(), 3);
        assert!(spark.starts_with('▁'));
        assert!(spark.ends_with('█'));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = TextTable::new(&["model", "jct(ms)"]);
        t.row(vec!["alexnet".into(), "1.4".into()]);
        t.row(vec!["vgg16".into(), "5.8".into()]);
        let out = t.render();
        assert!(out.contains("| model   | jct(ms) |"));
        assert!(out.lines().count() == 4);
    }
}
