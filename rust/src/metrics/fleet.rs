//! Fleet-wide metrics: windowed JCT/slowdown aggregation **across
//! devices** for the dynamic cluster simulation (DESIGN.md §8).
//!
//! The single-GPU [`JctStats`](super::JctStats) summarizes one service on
//! one device over a whole run. A serving fleet needs two more axes:
//!
//! * **across devices** — one headline number for "how are the
//!   high-priority tenants doing fleet-wide right now";
//! * **across time** — churn makes QoS a *trajectory*: a migration at
//!   t=4s should be visible as window 4's slowdown dropping, not be
//!   averaged away over the full run.
//!
//! [`FleetMetrics`] collects one [`FleetSample`] per completed task
//! (tagged with device, priority, and its slowdown vs the service's solo
//! baseline) and reduces them into fixed-width [`FleetWindowStats`]
//! buckets.

use super::{JctStats, TextTable};
use crate::core::{Duration, Priority, SimTime};

/// High-priority classes are P0–P2, matching the cluster layer's QoS
/// definition (the paper's inserted real-time tasks all sit in this
/// band).
pub fn is_high_priority(p: Priority) -> bool {
    (p as u8) <= 2
}

/// One completed task, as the fleet sees it.
#[derive(Debug, Clone)]
pub struct FleetSample {
    /// Device the task ran on.
    pub gpu: usize,
    /// Priority of the owning service.
    pub priority: Priority,
    /// Fleet time at which the task's invocation arrived.
    pub arrival: SimTime,
    /// Job completion time of the task.
    pub jct: Duration,
    /// JCT / the service's solo-baseline mean JCT (1.0 = unharmed).
    pub slowdown: f64,
}

/// Aggregate statistics of one fixed-width time window.
#[derive(Debug, Clone)]
pub struct FleetWindowStats {
    /// Window ordinal (0 = `[0, width)`).
    pub index: usize,
    /// Inclusive window start.
    pub start: SimTime,
    /// High-priority completions in the window (fleet-wide).
    pub high: JctStats,
    /// Mean high-priority slowdown (1.0 when no high-priority task
    /// completed in the window).
    pub high_mean_slowdown: f64,
    /// p99 high-priority slowdown (tail QoS; 1.0 when empty).
    pub high_p99_slowdown: f64,
    /// Low-priority completions in the window (fleet-wide).
    pub low_completed: usize,
    /// Low-priority completion rate over the window, tasks/second.
    pub low_throughput_per_s: f64,
}

/// Fleet-wide sample collector with fixed-width windowed reduction.
#[derive(Debug, Clone)]
pub struct FleetMetrics {
    window: Duration,
    samples: Vec<FleetSample>,
    /// Per-device sample indices in recording order. Within one device
    /// samples arrive completion-ordered (each GPU sim emits outcomes in
    /// completion order and harvests are chronological), which lets
    /// [`FleetMetrics::samples_in`] binary-search the trailing window
    /// instead of walking the whole history on every QoS scan.
    per_gpu: Vec<Vec<usize>>,
}

impl FleetMetrics {
    /// A collector bucketing by `window`-wide intervals of fleet time.
    pub fn new(window: Duration) -> FleetMetrics {
        assert!(!window.is_zero(), "fleet metrics window must be non-zero");
        FleetMetrics {
            window,
            samples: Vec::new(),
            per_gpu: Vec::new(),
        }
    }

    /// Window width.
    pub fn window(&self) -> Duration {
        self.window
    }

    /// Record one completed task. Per device, calls must come in
    /// non-decreasing completion-time order (`arrival + jct`) — the
    /// churn harvester guarantees this; the trailing-window lookup of
    /// [`FleetMetrics::samples_in`] relies on it.
    pub fn record(&mut self, sample: FleetSample) {
        if sample.gpu >= self.per_gpu.len() {
            self.per_gpu.resize_with(sample.gpu + 1, Vec::new);
        }
        self.per_gpu[sample.gpu].push(self.samples.len());
        self.samples.push(sample);
    }

    /// Total samples recorded.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// No samples recorded yet?
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// All recorded samples.
    pub fn samples(&self) -> &[FleetSample] {
        &self.samples
    }

    /// Mean slowdown across every high-priority completion (1.0 if none).
    pub fn high_mean_slowdown(&self) -> f64 {
        mean_slowdown(self.high_slowdowns())
    }

    /// p99 slowdown across every high-priority completion (1.0 if none).
    pub fn high_p99_slowdown(&self) -> f64 {
        percentile(self.high_slowdowns(), 0.99)
    }

    /// Total low-priority completions.
    pub fn low_completed(&self) -> usize {
        self.samples
            .iter()
            .filter(|s| !is_high_priority(s.priority))
            .count()
    }

    /// Low-priority completions per second of fleet time up to `end`.
    pub fn low_throughput_per_s(&self, end: SimTime) -> f64 {
        let secs = end.as_secs_f64();
        if secs <= 0.0 {
            0.0
        } else {
            self.low_completed() as f64 / secs
        }
    }

    /// Samples restricted to arrivals in `(from, to]` — the trailing-
    /// window slice the QoS scanner evaluates per device.
    ///
    /// Cost: O(log n + window) rather than O(history). A sample with
    /// `arrival > from` necessarily completed after `from` (completion ≥
    /// arrival), and the per-device index is completion-ordered, so only
    /// the suffix past the last sample completed at or before `from`
    /// needs scanning.
    pub fn samples_in(&self, gpu: usize, from: SimTime, to: SimTime) -> Vec<&FleetSample> {
        let Some(idxs) = self.per_gpu.get(gpu) else {
            return Vec::new();
        };
        let start = idxs.partition_point(|&i| {
            let s = &self.samples[i];
            s.arrival + s.jct <= from
        });
        idxs[start..]
            .iter()
            .map(|&i| &self.samples[i])
            .filter(|s| s.arrival > from && s.arrival <= to)
            .collect()
    }

    /// Reduce into fixed-width windows covering `[0, end)`.
    pub fn windows(&self, end: SimTime) -> Vec<FleetWindowStats> {
        let width = self.window.nanos();
        let count = (end.nanos().div_ceil(width)).max(1) as usize;
        let mut out = Vec::with_capacity(count);
        for index in 0..count {
            let start = SimTime(width * index as u64);
            let stop = start + self.window;
            let in_window = |s: &&FleetSample| s.arrival >= start && s.arrival < stop;
            let highs: Vec<&FleetSample> = self
                .samples
                .iter()
                .filter(in_window)
                .filter(|s| is_high_priority(s.priority))
                .collect();
            let lows = self
                .samples
                .iter()
                .filter(in_window)
                .filter(|s| !is_high_priority(s.priority))
                .count();
            let slowdowns: Vec<f64> = highs.iter().map(|s| s.slowdown).collect();
            out.push(FleetWindowStats {
                index,
                start,
                high: JctStats::from_durations(highs.iter().map(|s| s.jct).collect()),
                high_mean_slowdown: mean_slowdown(slowdowns.clone()),
                high_p99_slowdown: percentile(slowdowns, 0.99),
                low_completed: lows,
                low_throughput_per_s: lows as f64 / self.window.as_secs_f64(),
            });
        }
        out
    }

    /// Render the windowed trajectory as a table (experiment output).
    pub fn summary_table(&self, end: SimTime) -> TextTable {
        let mut t = TextTable::new(&[
            "window",
            "t (s)",
            "H done",
            "H mean slow",
            "H p99 slow",
            "L done",
            "L thr (/s)",
        ]);
        for w in self.windows(end) {
            t.row(vec![
                w.index.to_string(),
                format!("{:.1}", w.start.as_secs_f64()),
                w.high.count.to_string(),
                format!("{:.2}x", w.high_mean_slowdown),
                format!("{:.2}x", w.high_p99_slowdown),
                w.low_completed.to_string(),
                format!("{:.1}", w.low_throughput_per_s),
            ]);
        }
        t
    }

    fn high_slowdowns(&self) -> Vec<f64> {
        self.samples
            .iter()
            .filter(|s| is_high_priority(s.priority))
            .map(|s| s.slowdown)
            .collect()
    }
}

fn mean_slowdown(vals: Vec<f64>) -> f64 {
    if vals.is_empty() {
        1.0
    } else {
        vals.iter().sum::<f64>() / vals.len() as f64
    }
}

/// Nearest-rank percentile over raw f64 values (1.0 for empty input —
/// the neutral slowdown).
fn percentile(mut vals: Vec<f64>, q: f64) -> f64 {
    if vals.is_empty() {
        return 1.0;
    }
    vals.sort_by(|a, b| a.partial_cmp(b).expect("slowdowns are finite"));
    let idx = (q * vals.len() as f64).ceil() as usize;
    vals[idx.saturating_sub(1).min(vals.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(gpu: usize, prio: Priority, at_ms: u64, jct_ms: u64, slow: f64) -> FleetSample {
        FleetSample {
            gpu,
            priority: prio,
            arrival: SimTime(at_ms * 1_000_000),
            jct: Duration::from_millis(jct_ms),
            slowdown: slow,
        }
    }

    #[test]
    fn windows_bucket_by_arrival_time() {
        let mut m = FleetMetrics::new(Duration::from_secs(1));
        m.record(sample(0, Priority::P0, 100, 30, 1.1));
        m.record(sample(1, Priority::P0, 1_500, 35, 2.0));
        m.record(sample(0, Priority::P6, 200, 10, 3.0));
        m.record(sample(0, Priority::P6, 1_700, 10, 3.0));
        let w = m.windows(SimTime(2_000_000_000));
        assert_eq!(w.len(), 2);
        assert_eq!(w[0].high.count, 1);
        assert!((w[0].high_mean_slowdown - 1.1).abs() < 1e-9);
        assert_eq!(w[0].low_completed, 1);
        assert!((w[1].high_mean_slowdown - 2.0).abs() < 1e-9);
        assert!((w[1].low_throughput_per_s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn empty_windows_report_neutral_slowdown() {
        let m = FleetMetrics::new(Duration::from_secs(1));
        let w = m.windows(SimTime(3_000_000_000));
        assert_eq!(w.len(), 3);
        for win in w {
            assert_eq!(win.high_mean_slowdown, 1.0);
            assert_eq!(win.high_p99_slowdown, 1.0);
            assert_eq!(win.low_completed, 0);
        }
    }

    #[test]
    fn fleet_rollups() {
        let mut m = FleetMetrics::new(Duration::from_millis(500));
        for i in 0..100 {
            m.record(sample(i % 4, Priority::P1, i, 20, 1.0 + i as f64 / 100.0));
        }
        m.record(sample(0, Priority::P9, 10, 5, 4.0));
        assert_eq!(m.len(), 101);
        assert_eq!(m.low_completed(), 1);
        // Mean of 1.0..1.99 ≈ 1.495.
        assert!((m.high_mean_slowdown() - 1.495).abs() < 0.01);
        assert!(m.high_p99_slowdown() >= 1.98);
        assert!(m.low_throughput_per_s(SimTime(1_000_000_000)) > 0.9);
    }

    #[test]
    fn trailing_slice_filters_by_gpu_and_time() {
        let mut m = FleetMetrics::new(Duration::from_secs(1));
        m.record(sample(0, Priority::P0, 100, 30, 1.2));
        m.record(sample(1, Priority::P0, 150, 30, 1.8));
        m.record(sample(0, Priority::P0, 900, 30, 1.4));
        let slice = m.samples_in(0, SimTime(500_000_000), SimTime(1_000_000_000));
        assert_eq!(slice.len(), 1);
        assert!((slice[0].slowdown - 1.4).abs() < 1e-9);
    }

    #[test]
    fn priority_band_split() {
        assert!(is_high_priority(Priority::P0));
        assert!(is_high_priority(Priority::P2));
        assert!(!is_high_priority(Priority::P3));
        assert!(!is_high_priority(Priority::P9));
    }
}
