//! The PJRT runtime: loads AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and executes them as real kernels from the
//! Rust request path. Python never runs at serving time — `make
//! artifacts` produced the HLO text once; everything here is
//! xla-crate/PJRT.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (names, files,
//!   tensor specs, self-check vectors).
//! * [`executor`] — `PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//!   → `compile` → `execute`, one compiled executable per artifact,
//!   plus a load-time numeric self-check against the manifest.
//! * [`engine`] — the real-time serving engine used by the e2e example:
//!   services whose "kernels" are PJRT executions, scheduled through the
//!   same FIKIT queues/BestPrioFit logic as the simulator.

pub mod engine;
pub mod executor;
pub mod manifest;

pub use engine::{EngineConfig, EngineReport, RealTimeEngine, RtService};
pub use executor::{LoadedArtifact, PjrtRuntime};
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
