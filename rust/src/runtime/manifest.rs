//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the Rust runtime.

use crate::core::{Error, Result};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Shape + dtype of one tensor argument/result.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<TensorSpec> {
        let shape = v
            .req_arr("shape")?
            .iter()
            .map(|d| {
                d.as_u64()
                    .map(|d| d as usize)
                    .ok_or_else(|| Error::Artifact("bad shape dim".into()))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec {
            shape,
            dtype: v.req_str("dtype")?.to_string(),
        })
    }
}

/// Load-time numeric self-check parameters (see aot.py `_rand_inputs`).
#[derive(Debug, Clone)]
pub struct CheckVector {
    /// Seed folded into the deterministic input formula.
    pub seed: u64,
    /// Expected mean(|output|) across outputs.
    pub mean_abs: f64,
}

/// One AOT artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    pub tags: Vec<String>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub check: CheckVector,
}

impl ArtifactSpec {
    pub fn has_tag(&self, tag: &str) -> bool {
        self.tags.iter().any(|t| t == tag)
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            ))
        })?;
        let doc = Json::parse(&text)?;
        let version = doc.req_u64("version")?;
        if version != 1 {
            return Err(Error::Artifact(format!(
                "manifest version {version} unsupported"
            )));
        }
        let artifacts = doc
            .req_arr("artifacts")?
            .iter()
            .map(|a| {
                let check = a.require("check")?;
                Ok(ArtifactSpec {
                    name: a.req_str("name")?.to_string(),
                    file: a.req_str("file")?.to_string(),
                    tags: a
                        .req_arr("tags")?
                        .iter()
                        .filter_map(|t| t.as_str().map(str::to_string))
                        .collect(),
                    inputs: a
                        .req_arr("inputs")?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<Vec<_>>>()?,
                    outputs: a
                        .req_arr("outputs")?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<Vec<_>>>()?,
                    check: CheckVector {
                        seed: check.req_u64("seed")?,
                        mean_abs: check.req_f64("mean_abs")?,
                    },
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Artifacts carrying a tag (e.g. `"kernel"`, `"model"`).
    pub fn with_tag<'a>(&'a self, tag: &'a str) -> impl Iterator<Item = &'a ArtifactSpec> {
        self.artifacts.iter().filter(move |a| a.has_tag(tag))
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

/// The deterministic test-input formula shared with aot.py:
/// `value[i] = sin(0.001 · (i+1) · (arg_idx+3) + seed)`.
pub fn test_input(spec: &TensorSpec, arg_idx: usize, seed: u64) -> Vec<f32> {
    let n = spec.element_count();
    (0..n)
        .map(|i| {
            (0.001 * (i as f64 + 1.0) * (arg_idx as f64 + 3.0) + seed as f64).sin() as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("fikit-manifest-{tag}-{}", std::process::id()))
    }

    #[test]
    fn parses_well_formed_manifest() {
        let dir = tmp("ok");
        write_manifest(
            &dir,
            r#"{
              "version": 1,
              "artifacts": [{
                "name": "matmul_2x2",
                "file": "matmul_2x2.hlo.txt",
                "tags": ["kernel", "matmul"],
                "inputs": [{"shape": [2, 2], "dtype": "float32"},
                           {"shape": [2, 2], "dtype": "float32"}],
                "outputs": [{"shape": [2, 2], "dtype": "float32"}],
                "check": {"seed": 1234, "mean_abs": 0.5}
              }]
            }"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.get("matmul_2x2").unwrap();
        assert!(a.has_tag("kernel"));
        assert_eq!(a.inputs[0].element_count(), 4);
        assert_eq!(m.with_tag("matmul").count(), 1);
        assert!(m.hlo_path(a).ends_with("matmul_2x2.hlo.txt"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let err = Manifest::load(tmp("missing")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn bad_version_rejected() {
        let dir = tmp("ver");
        write_manifest(&dir, r#"{"version": 9, "artifacts": []}"#);
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn test_input_formula_matches_python() {
        // First elements of sin(0.001*(i+1)*(0+3) + 1234) computed with
        // python/numpy — pins the cross-language contract.
        let spec = TensorSpec {
            shape: vec![2, 2],
            dtype: "float32".into(),
        };
        let vals = test_input(&spec, 0, 1234);
        let expect = [
            (0.003f64 + 1234.0).sin() as f32,
            (0.006f64 + 1234.0).sin() as f32,
            (0.009f64 + 1234.0).sin() as f32,
            (0.012f64 + 1234.0).sin() as f32,
        ];
        assert_eq!(vals, expect);
    }
}
