//! PJRT execution of AOT artifacts.
//!
//! One [`PjrtRuntime`] owns the PJRT CPU client and a compiled
//! executable per artifact. HLO **text** is the interchange format (the
//! xla crate's XLA rejects jax≥0.5 serialized protos — ids overflow
//! i32; the text parser reassigns them).

use super::manifest::{test_input, ArtifactSpec, Manifest};
use crate::core::{Error, Result};
use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration as StdDuration, Instant};

/// A compiled artifact plus its spec and load/verify telemetry.
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    /// Wall time spent compiling the HLO.
    pub compile_time: StdDuration,
}

/// The PJRT CPU runtime.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    loaded: HashMap<String, LoadedArtifact>,
}

fn xerr(e: xla::Error) -> Error {
    Error::Runtime(e.to_string())
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        Ok(PjrtRuntime {
            client,
            loaded: HashMap::new(),
        })
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact from a manifest.
    pub fn load(&mut self, manifest: &Manifest, name: &str) -> Result<&LoadedArtifact> {
        if !self.loaded.contains_key(name) {
            let spec = manifest
                .get(name)
                .ok_or_else(|| Error::Artifact(format!("unknown artifact {name:?}")))?
                .clone();
            let path = manifest.hlo_path(&spec);
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&path).map_err(xerr)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(xerr)?;
            self.loaded.insert(
                name.to_string(),
                LoadedArtifact {
                    spec,
                    exe,
                    compile_time: t0.elapsed(),
                },
            );
        }
        Ok(&self.loaded[name])
    }

    /// Load + compile every artifact in the manifest.
    pub fn load_all(&mut self, manifest: &Manifest) -> Result<()> {
        for spec in &manifest.artifacts {
            self.load(manifest, &spec.name)?;
        }
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.loaded.contains_key(name)
    }

    pub fn loaded_names(&self) -> Vec<&str> {
        self.loaded.keys().map(|s| s.as_str()).collect()
    }

    /// Execute an artifact with f32 inputs in manifest argument order.
    /// Returns the flattened f32 outputs.
    pub fn execute_f32(&self, name: &str, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let art = self
            .loaded
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("artifact {name:?} not loaded")))?;
        if inputs.len() != art.spec.inputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: expected {} inputs, got {}",
                art.spec.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (vals, spec) in inputs.iter().zip(&art.spec.inputs) {
            if vals.len() != spec.element_count() {
                return Err(Error::Runtime(format!(
                    "{name}: input element count {} != spec {}",
                    vals.len(),
                    spec.element_count()
                )));
            }
            let dims: Vec<i64> = spec.shape.iter().map(|d| *d as i64).collect();
            let lit = xla::Literal::vec1(vals).reshape(&dims).map_err(xerr)?;
            literals.push(lit);
        }

        let result = art.exe.execute::<xla::Literal>(&literals).map_err(xerr)?;
        let first = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::Runtime(format!("{name}: empty execution result")))?;
        let root = first.to_literal_sync().map_err(xerr)?;
        // aot.py lowers with return_tuple=True.
        let parts = root.to_tuple().map_err(xerr)?;
        parts
            .into_iter()
            .map(|lit| lit.to_vec::<f32>().map_err(xerr))
            .collect()
    }

    /// Execute with the manifest's deterministic test inputs and return
    /// (outputs, mean |output|).
    pub fn execute_check(&self, name: &str) -> Result<(Vec<Vec<f32>>, f64)> {
        let art = self
            .loaded
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("artifact {name:?} not loaded")))?;
        let inputs: Vec<Vec<f32>> = art
            .spec
            .inputs
            .iter()
            .enumerate()
            .map(|(ai, spec)| test_input(spec, ai, art.spec.check.seed))
            .collect();
        let outputs = self.execute_f32(name, &inputs)?;
        let mean_abs = {
            let mut per_output = Vec::with_capacity(outputs.len());
            for o in &outputs {
                let sum: f64 = o.iter().map(|v| v.abs() as f64).sum();
                per_output.push(sum / o.len().max(1) as f64);
            }
            per_output.iter().sum::<f64>() / per_output.len().max(1) as f64
        };
        Ok((outputs, mean_abs))
    }

    /// Self-verify a loaded artifact against the manifest's expected
    /// mean-abs fingerprint (relative tolerance `tol`).
    pub fn verify(&self, name: &str, tol: f64) -> Result<f64> {
        let expected = self.loaded[name].spec.check.mean_abs;
        let (_, got) = self.execute_check(name)?;
        let rel = (got - expected).abs() / expected.abs().max(1e-12);
        if rel > tol {
            return Err(Error::Runtime(format!(
                "{name}: self-check mismatch — mean|out| {got:.6} vs manifest {expected:.6} (rel {rel:.2e})"
            )));
        }
        Ok(rel)
    }

    /// Verify every loaded artifact.
    pub fn verify_all(&self, tol: f64) -> Result<()> {
        let mut names: Vec<&String> = self.loaded.keys().collect();
        names.sort();
        for name in names {
            self.verify(name, tol)?;
        }
        Ok(())
    }
}

/// Convenience: load a manifest dir and compile everything.
pub fn load_runtime(artifacts_dir: impl AsRef<Path>) -> Result<(Manifest, PjrtRuntime)> {
    let manifest = Manifest::load(artifacts_dir)?;
    let mut rt = PjrtRuntime::cpu()?;
    rt.load_all(&manifest)?;
    Ok((manifest, rt))
}
