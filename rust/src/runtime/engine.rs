//! Real-time FIKIT serving over real compute (the e2e example's core).
//!
//! This engine proves all three layers compose: hosted services issue
//! inference requests whose kernels are **PJRT executions of the
//! AOT-compiled JAX/Pallas artifacts**, and the FIKIT scheduler — the
//! *same* priority queues, BestPrioFit and fill-window logic as the
//! simulator — decides execution order in wall-clock time.
//!
//! Topology (mirrors the paper's deployment):
//!
//! * one **service thread** per hosted service = the paper's hooked
//!   client process: per request it sends each kernel launch to the
//!   engine and blocks until released/completed, sleeping its think-time
//!   gaps in between (CPU post-processing);
//! * one **engine thread** = scheduler + GPU: routes launches (holder →
//!   run now; lower priority → queue), opens a fill window after each
//!   holder kernel using profiled gaps, fills with BestPrioFit, and
//!   early-stops the moment the holder's next launch arrives (feedback).
//!
//! Execution is synchronous on the engine thread — the single CPU PJRT
//! stream is the FIFO device queue.

use super::executor::PjrtRuntime;
use super::manifest::{test_input, Manifest};
use crate::coordinator::best_prio_fit::best_prio_fit;
use crate::coordinator::fikit::FillWindow;
use crate::coordinator::queues::PriorityQueues;
use crate::coordinator::Mode;
use crate::core::{
    Dim3, Duration, Error, KernelHandle, KernelId, KernelLaunch, Priority, Result, SimTime,
    TaskHandle, TaskId, TaskKey,
};
use crate::metrics::JctStats;
use crate::profile::{ProfileStore, TaskProfile};
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration as StdDuration, Instant};

/// One kernel step of a real-time service: an artifact execution plus
/// the CPU think-time gap after it.
#[derive(Debug, Clone)]
pub struct RtKernelStep {
    /// Artifact name (must exist in the manifest).
    pub artifact: String,
    /// CPU-side post-processing time after this kernel completes.
    pub think_gap: StdDuration,
}

/// A hosted real-time service.
#[derive(Debug, Clone)]
pub struct RtService {
    pub key: TaskKey,
    pub priority: Priority,
    /// Kernel sequence of one request.
    pub steps: Vec<RtKernelStep>,
    /// Number of requests to serve.
    pub requests: u32,
    /// Pause between requests (ZERO = back-to-back).
    pub inter_request: StdDuration,
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Fikit (priority + gap filling) or Sharing (FIFO arrival order).
    pub mode: Mode,
    /// Profiling runs per service before serving.
    pub profile_runs: u32,
    /// Small-gap threshold ε.
    pub epsilon: Duration,
    /// Online sharing-stage profile refinement (DESIGN.md §9): learn
    /// from the wall-clock executions the engine already performs (real
    /// CPU load shifts them) and shadow the offline store with refined
    /// predictions. Off by default.
    pub online: crate::profile::OnlineConfig,
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig {
            mode: Mode::Fikit,
            profile_runs: 3,
            epsilon: crate::coordinator::fikit::DEFAULT_EPSILON,
            online: crate::profile::OnlineConfig::default(),
        }
    }
}

/// Per-service serving results.
#[derive(Debug)]
pub struct RtServiceReport {
    pub key: TaskKey,
    pub priority: Priority,
    pub jct: JctStats,
    pub completed: u32,
}

/// Full engine run results.
#[derive(Debug)]
pub struct EngineReport {
    pub mode: Mode,
    pub services: Vec<RtServiceReport>,
    pub fills: u64,
    pub windows: u64,
    pub early_stops: u64,
    pub kernels_executed: u64,
    /// Refined profiles republished by the online refiner during
    /// serving (0 with refinement off).
    pub profiles_refined: u64,
    pub wall: StdDuration,
}

impl EngineReport {
    pub fn service(&self, key: &TaskKey) -> Option<&RtServiceReport> {
        self.services.iter().find(|s| &s.key == key)
    }
}

// ---- wire messages between service threads and the engine thread ----

enum RtMsg {
    Launch {
        svc: usize,
        seq: u32,
        step: usize,
    },
    RequestStart {
        svc: usize,
    },
    RequestEnd {
        svc: usize,
    },
    ServiceDone,
}

/// The real-time engine.
pub struct RealTimeEngine {
    cfg: EngineConfig,
    services: Vec<RtService>,
    runtime: PjrtRuntime,
    /// Pre-generated deterministic inputs per artifact.
    inputs: HashMap<String, Vec<Vec<f32>>>,
    /// Kernel ids per (svc, step).
    kernel_ids: Vec<Vec<KernelId>>,
}

impl RealTimeEngine {
    /// Build an engine: loads + compiles every artifact referenced by the
    /// services.
    pub fn new(
        cfg: EngineConfig,
        services: Vec<RtService>,
        manifest: &Manifest,
    ) -> Result<RealTimeEngine> {
        let mut runtime = PjrtRuntime::cpu()?;
        let mut inputs = HashMap::new();
        for svc in &services {
            for step in &svc.steps {
                let art = runtime.load(manifest, &step.artifact)?;
                if !inputs.contains_key(&step.artifact) {
                    let vals: Vec<Vec<f32>> = art
                        .spec
                        .inputs
                        .iter()
                        .enumerate()
                        .map(|(ai, spec)| test_input(spec, ai, art.spec.check.seed))
                        .collect();
                    inputs.insert(step.artifact.clone(), vals);
                }
            }
        }
        let kernel_ids = services
            .iter()
            .map(|svc| {
                svc.steps
                    .iter()
                    .map(|s| KernelId::new(s.artifact.as_str(), Dim3::x(1), Dim3::x(256)))
                    .collect()
            })
            .collect();
        Ok(RealTimeEngine {
            cfg,
            services,
            runtime,
            inputs,
            kernel_ids,
        })
    }

    fn execute(&self, artifact: &str) -> Result<StdDuration> {
        let t0 = Instant::now();
        self.runtime.execute_f32(artifact, &self.inputs[artifact])?;
        Ok(t0.elapsed())
    }

    /// Measurement stage: run each service's kernel sequence solo,
    /// recording per-kernel execution times and the configured think
    /// gaps — the real-time analogue of the paper's profiling phase.
    pub fn profile(&self) -> Result<ProfileStore> {
        let mut store = ProfileStore::new();
        for (si, svc) in self.services.iter().enumerate() {
            let mut profile = TaskProfile::new(svc.key.clone());
            for _ in 0..self.cfg.profile_runs.max(1) {
                for (step_idx, step) in svc.steps.iter().enumerate() {
                    let exec = self.execute(&step.artifact)?;
                    let gap = (step_idx + 1 < svc.steps.len())
                        .then(|| Duration::from_nanos(step.think_gap.as_nanos() as u64));
                    profile.record(
                        &self.kernel_ids[si][step_idx],
                        Duration::from_nanos(exec.as_nanos() as u64),
                        gap,
                    );
                }
                profile.finish_run(svc.steps.len());
            }
            store.insert(profile);
        }
        Ok(store)
    }

    /// Run the serving phase: spawn service threads, schedule + execute
    /// on this thread until all services finish.
    pub fn serve(self, profiles: &ProfileStore) -> Result<EngineReport> {
        let t_start = Instant::now();
        let epoch = Instant::now();
        let now_sim = |at: Instant| SimTime(at.duration_since(epoch).as_nanos() as u64);

        let (tx, rx): (Sender<RtMsg>, Receiver<RtMsg>) = channel();
        // Per-service release channels (engine → service).
        let mut release_txs = Vec::new();
        let mut handles = Vec::new();
        for (si, svc) in self.services.iter().cloned().enumerate() {
            let (rel_tx, rel_rx) = channel::<()>();
            release_txs.push(rel_tx);
            let tx = tx.clone();
            handles.push(std::thread::spawn(move || {
                service_thread(si, svc, tx, rel_rx)
            }));
        }
        drop(tx);

        // ---- engine scheduling state ----
        let mut queues = PriorityQueues::new();
        let mut active: HashMap<usize, Priority> = HashMap::new();
        let mut window: Option<FillWindow> = None;
        // Online refinement (DESIGN.md §9): wall-clock executions feed
        // the refiner; refined profiles shadow the offline store for
        // every later SK/SG lookup.
        let mut refiner = crate::profile::KeyedRefiner::new(self.cfg.online.clone());
        let mut refined = ProfileStore::new();
        let mut profiles_refined = 0u64;
        let mut fills = 0u64;
        let mut windows = 0u64;
        let mut early_stops = 0u64;
        let mut kernels = 0u64;
        let mut done = 0usize;
        // Map queued launches back to (svc, step) via task_id/seq encoding.
        let svc_of_key: HashMap<TaskKey, usize> = self
            .services
            .iter()
            .enumerate()
            .map(|(i, s)| (s.key.clone(), i))
            .collect();

        let holder = |active: &HashMap<usize, Priority>| -> Option<(usize, Priority)> {
            active
                .iter()
                .min_by_key(|(svc, p)| (**p, **svc))
                .map(|(s, p)| (*s, *p))
        };

        while done < self.services.len() {
            // Serve pending fills while a window is open.
            if self.cfg.mode == Mode::Fikit {
                while let Some(w) = window.as_mut() {
                    let now = now_sim(Instant::now());
                    let remaining = w.remaining(now);
                    if remaining.is_zero() {
                        window = None;
                        break;
                    }
                    let Some(fit) = best_prio_fit(&mut queues, remaining) else {
                        break;
                    };
                    w.budget = w.budget.saturating_sub(fit.predicted);
                    let svc = svc_of_key[&fit.launch.task_key];
                    let step = fit.launch.seq as usize;
                    self.execute(&self.services[svc].steps[step].artifact)?;
                    kernels += 1;
                    fills += 1;
                    release_txs[svc].send(()).ok();
                }
            }

            // Liveness: any queued kernel not blocked by a strictly
            // higher-priority active task runs now (covers holder
            // changes, holder completion, and end-of-stream drains).
            loop {
                let Some(p) = queues.highest_nonempty() else { break };
                let blocked = active.values().any(|ap| ap.is_higher_than(p));
                if blocked {
                    break;
                }
                let req = queues.pop_front_at(p).expect("nonempty");
                let s = svc_of_key[&req.launch.task_key];
                let step = req.launch.seq as usize;
                self.execute(&self.services[s].steps[step].artifact)?;
                kernels += 1;
                release_txs[s].send(()).ok();
            }

            // Wait for the next client message.
            let msg = match rx.recv_timeout(StdDuration::from_millis(20)) {
                Ok(m) => m,
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => break,
            };
            match msg {
                RtMsg::RequestStart { svc } => {
                    active.insert(svc, self.services[svc].priority);
                }
                RtMsg::RequestEnd { svc } => {
                    active.remove(&svc);
                    window = None;
                    // Inter-request idle must not be learned as a gap.
                    refiner.clear_pending(&self.services[svc].key);
                }
                RtMsg::ServiceDone => {
                    done += 1;
                }
                RtMsg::Launch {
                    svc, seq, step, ..
                } => {
                    let (hsvc, hprio) = holder(&active).unwrap_or((svc, self.services[svc].priority));
                    let my_prio = self.services[svc].priority;
                    let is_holder_class =
                        self.cfg.mode != Mode::Fikit || svc == hsvc || my_prio == hprio;
                    if is_holder_class {
                        // Feedback: the holder's next launch ends the gap.
                        if window.take().is_some() {
                            early_stops += 1;
                        }
                        // This arrival closes the service's pending
                        // completion→launch gap observation.
                        let key = self.services[svc].key.clone();
                        refiner.observe_next_launch(&key, now_sim(Instant::now()));
                        let exec = self.execute(&self.services[svc].steps[step].artifact)?;
                        kernels += 1;
                        // Fold the real (wall-clock) execution into the
                        // online SK estimate and arm the gap observation.
                        refiner.observe_exec(
                            &key,
                            &self.kernel_ids[svc][step],
                            Duration::from_nanos(exec.as_nanos() as u64),
                            now_sim(Instant::now()),
                            refined.get(&key).or_else(|| profiles.get(&key)),
                        );
                        for p in refiner.take_refined(profiles) {
                            profiles_refined += 1;
                            refined.insert(p);
                        }
                        release_txs[svc].send(()).ok();
                        // Open a fill window for the profiled think gap
                        // (refined predictions shadow the offline store).
                        if self.cfg.mode == Mode::Fikit {
                            let kid = &self.kernel_ids[svc][step];
                            let gap = refined
                                .get(&self.services[svc].key)
                                .and_then(|p| p.sg(kid))
                                .or_else(|| {
                                    profiles.get(&self.services[svc].key).and_then(|p| p.sg(kid))
                                });
                            if let Some(g) = gap {
                                let now = now_sim(Instant::now());
                                // The engine's service index doubles as a
                                // dense task handle (one slot per service).
                                window = FillWindow::open(
                                    TaskHandle::from_index(svc),
                                    now,
                                    g,
                                    self.cfg.epsilon,
                                );
                                if window.is_some() {
                                    windows += 1;
                                }
                            }
                        }
                    } else {
                        // Lower priority: park in the message queues.
                        // Any pending gap observation is stale the
                        // moment the service stops being holder-class —
                        // its completion→launch deltas now include hold
                        // time, not think time (fill/drain executions
                        // below never re-arm it).
                        refiner.clear_pending(&self.services[svc].key);
                        let launch = KernelLaunch {
                            task_key: self.services[svc].key.clone(),
                            task_handle: TaskHandle::from_index(svc),
                            task_id: TaskId(seq as u64),
                            kernel: self.kernel_ids[svc][step].clone(),
                            kernel_handle: KernelHandle::UNBOUND,
                            priority: my_prio,
                            seq: step as u32,
                            true_duration: Duration::ZERO,
                            issued_at: now_sim(Instant::now()),
                        };
                        let predicted = refined
                            .get(&self.services[svc].key)
                            .and_then(|p| p.sk(&launch.kernel))
                            .or_else(|| {
                                profiles
                                    .get(&self.services[svc].key)
                                    .and_then(|p| p.sk(&launch.kernel))
                            });
                        queues.push_predicted(launch, predicted, now_sim(Instant::now()));
                    }
                }
            }
        }

        // Collect service results.
        let mut reports = Vec::new();
        for (handle, svc) in handles.into_iter().zip(&self.services) {
            let jcts = handle
                .join()
                .map_err(|_| Error::Runtime("service thread panicked".into()))?;
            reports.push(RtServiceReport {
                key: svc.key.clone(),
                priority: svc.priority,
                completed: jcts.len() as u32,
                jct: JctStats::from_durations(jcts),
            });
        }
        Ok(EngineReport {
            mode: self.cfg.mode,
            services: reports,
            fills,
            windows,
            early_stops,
            kernels_executed: kernels,
            profiles_refined,
            wall: t_start.elapsed(),
        })
    }
}

/// The hooked client process: issues launches, blocks on releases,
/// sleeps think gaps, measures per-request JCT.
fn service_thread(
    si: usize,
    svc: RtService,
    tx: Sender<RtMsg>,
    releases: Receiver<()>,
) -> Vec<Duration> {
    let mut jcts = Vec::with_capacity(svc.requests as usize);
    for req in 0..svc.requests {
        let t0 = Instant::now();
        if tx.send(RtMsg::RequestStart { svc: si }).is_err() {
            break;
        }
        for (step_idx, step) in svc.steps.iter().enumerate() {
            if tx
                .send(RtMsg::Launch {
                    svc: si,
                    seq: req,
                    step: step_idx,
                })
                .is_err()
            {
                return jcts;
            }
            // Block until the engine has executed the kernel.
            if releases.recv().is_err() {
                return jcts;
            }
            if step.think_gap > StdDuration::ZERO && step_idx + 1 < svc.steps.len() {
                std::thread::sleep(step.think_gap);
            }
        }
        tx.send(RtMsg::RequestEnd { svc: si }).ok();
        jcts.push(Duration::from_nanos(t0.elapsed().as_nanos() as u64));
        if svc.inter_request > StdDuration::ZERO {
            std::thread::sleep(svc.inter_request);
        }
    }
    tx.send(RtMsg::ServiceDone).ok();
    jcts
}
