//! # FIKIT — Filling Inter-Kernel Idle Time
//!
//! A full-system reproduction of *"FIKIT: Priority-Based Real-time GPU
//! Multi-tasking Scheduling with Kernel Identification"* (Wu, 2023) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! The library provides:
//!
//! * [`core`] — shared vocabulary types ([`core::KernelId`],
//!   [`core::TaskKey`], [`core::Priority`], virtual time).
//! * [`profile`] — the paper's kernel-identification and offline
//!   measurement pipeline: per-KernelID execution time (`SK`) and
//!   post-kernel idle gap (`SG`) statistics — plus the sharing-stage
//!   online refinement loop (EWMA drift detection, epoch-versioned
//!   snapshot republish; DESIGN.md §9).
//! * [`simulator`] — a discrete-event GPU device simulator reproducing the
//!   FIFO device queue, NVIDIA default time-slice sharing and exclusive
//!   modes the paper baselines against.
//! * [`workload`] — calibrated kernel-trace models of the twelve DNNs in
//!   the paper's Table 1, plus service/invocation-pattern abstractions.
//! * [`coordinator`] — the FIKIT scheduler itself: ten priority queues,
//!   the `FIKIT` gap-filling procedure (Algorithm 1), `BestPrioFit`
//!   (Algorithm 2), and the real-time feedback early-stop (Fig 12).
//! * [`hook`] — the CUDA-hook-analogue interception layer and the
//!   client↔scheduler wire protocol (in-proc, UDP and deterministic
//!   lossy transports; versioned loss-tolerant framing).
//! * [`daemon`] — the standalone scheduler daemon's control plane:
//!   per-GPU scheduling shards behind a placement registry, with an
//!   idempotent-retransmit wire layer (DESIGN.md §Daemon).
//! * [`runtime`] — the PJRT bridge that loads AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes them as real kernels.
//! * [`metrics`] — JCT statistics, speedups, coefficients of variation,
//!   timelines.
//! * [`experiments`] — one module per paper table/figure; the bench
//!   harness regenerates the full evaluation section.
//! * [`cluster`] — the paper's §5 cluster-level proposal, grown into a
//!   dynamic serving fleet: compatibility-aware placement, service
//!   churn, and reactive QoS migration (see `DESIGN.md` §8).
//!
//! ## Quickstart
//!
//! ```no_run
//! use fikit::prelude::*;
//!
//! // Two services sharing one simulated GPU: a high-priority detector and
//! // a low-priority segmenter.
//! let mut cfg = ExperimentConfig::default();
//! cfg.services.push(ServiceConfig::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0).tasks(50));
//! cfg.services.push(ServiceConfig::new(ModelKind::FcnResnet50, Priority::P2).tasks(50));
//! cfg.mode = Mode::Fikit;
//! let report = fikit::coordinator::driver::run_experiment(&cfg).unwrap();
//! println!("{}", report.summary());
//! ```

pub mod benchsuite;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod core;
pub mod daemon;
pub mod experiments;
pub mod hook;
pub mod metrics;
pub mod profile;
pub mod runtime;
pub mod server;
pub mod simulator;
pub mod util;
pub mod workload;

/// Convenience re-exports for the common public API surface.
pub mod prelude {
    pub use crate::config::{ExperimentConfig, HookConfig, ServiceConfig};
    pub use crate::simulator::DeviceConfig;
    pub use crate::coordinator::driver::{run_experiment, ExperimentReport};
    pub use crate::coordinator::Mode;
    pub use crate::core::{KernelId, Priority, SimTime, TaskKey};
    pub use crate::metrics::JctStats;
    pub use crate::profile::{ProfileStore, TaskProfile};
    pub use crate::workload::{ModelKind, Service};
}
