//! `fikit` — the command-line launcher.
//!
//! Subcommands:
//!
//! * `run`         — run a sharing experiment (inline flags or `--config`)
//! * `experiment`  — regenerate one paper table/figure by id
//! * `profile`     — measurement-stage a service and persist its profile
//! * `serve`       — start the UDP scheduler daemon
//! * `list-models` — print the calibrated model zoo
//! * `verify-artifacts` — load + self-check every AOT artifact via PJRT

use fikit::config::{ExperimentConfig, ServiceConfig};
use fikit::coordinator::driver::{profile_service, run_experiment};
use fikit::coordinator::Mode;
use fikit::core::{Priority, Result};
use fikit::experiments::{self, Options};
use fikit::metrics::TextTable;
use fikit::profile::ProfileStore;
use fikit::server::{SchedulerServer, ServerConfig};
use fikit::util::cli::Args;
use fikit::workload::ModelKind;

const USAGE: &str = "\
fikit — FIKIT: priority-based real-time GPU multi-tasking scheduling
        (full-system reproduction; see README.md)

USAGE:
  fikit run [--config exp.json] [--mode fikit|sharing|exclusive]
            [--high MODEL] [--low MODEL] [--tasks N] [--seed S]
            [--backend timesliced|mps[:dilation]|mig[:slices]]
            [--preempt none|evict|split[:us]|hybrid[:t]]
  fikit experiment <id|all> [--scale F] [--seed S] [--json out.json]
        ids: fig13 fig14 fig15 table2 fig16 fig18 fig19 fig21 ablation_feedback
             ablation_fill_policy cluster_churn drift interference preemption
  fikit preempt [--scale F] [--seed S] [--json [PATH]]
        preemption Pareto acceptance sweep: combos A-J + continuous
        inserts under none/evict/split/hybrid; asserts the hybrid arm
        keeps fill-only's high-priority speedup with the low-priority
        JCT ratio inside the paper's 0.86-1.0 band; --json writes
        PARETO_preempt.json (or PATH)
  fikit drift [--scale F] [--seed S]
        online-refinement acceptance run: inject gap interference
        mid-run, show drift detection + re-convergence + <=5% overhead
  fikit interference [--scale F] [--seed S]
        interference-learning acceptance run (ADR-006): a disguised
        aggressor is planted in a churn trace and the learned-dilation
        eviction (worst-aggressor) races the symptom-based baseline
        (noisiest-victim) across every concurrency backend
  fikit profile --model MODEL [--runs T] [--out profiles.json]
  fikit serve [--bind ADDR] [--profiles profiles.json] [--devices N]
              [--capacity C] [--placement bestmatch|leastloaded|roundrobin]
              [--online] [--save-profiles PATH] [--journal DIR]
              [--advertise NAME] [--peers n1=host:port,...] [--beacon-ms N]
              [--run-for-ms N]
        one scheduling shard per device; services are routed to shards
        by the placement policy's capacity accounting; --online refines
        SK/SG from sharing-stage traffic and --save-profiles persists
        the refined store periodically (every 30s); --journal write-ahead
        journals session lifecycle into DIR and replays it on startup so
        a restarted daemon keeps every admitted session (ADR-004);
        --advertise + --peers federate daemons into a fleet: each node
        beacons capacity/health every --beacon-ms (default 100) and
        over-capacity registers are redirected to the best live peer or
        shed with an explicit RetryAfter (ADR-005); --run-for-ms bounds
        the run and prints the shutdown accounting line (rejected,
        redirected, shed, unroutable counts)
  fikit cluster [--gpus N] [--policy bestmatch|leastloaded|roundrobin]
                [--compat compat.json] [--measure-compat]
  fikit cluster-churn [--gpus N] [--capacity C] [--policy P] [--mode M]
                      [--seed S] [--secs T] [--bound X] [--no-migration]
                      [--cold-start] [--online] [--sim-threads N]
                      [--backend timesliced|mps[:dilation]|mig[:slices]]
                      [--eviction aggressor|victim] [--learn-interference]
        --sim-threads advances device shards on N worker threads between
        fleet events; the report is byte-identical for every N;
        --backend selects the device concurrency model (ADR-006),
        --learn-interference updates pairwise dilation online from
        completions, and --eviction picks what the QoS scanner deports:
        the predicted worst aggressor (default) or the noisiest victim
  fikit bench [--quick] [--json [PATH]]
        runs the scheduler hot-path + simulator event-core suites; --json
        writes BENCH_sched.json (or PATH) plus BENCH_sim.json alongside
        it and fails if any case misses its declared budget
  fikit list-models
  fikit verify-artifacts [--dir artifacts]
";

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<()> {
    match args.pos(0) {
        Some("run") => cmd_run(args),
        Some("experiment") => cmd_experiment(args),
        Some("drift") => cmd_drift(args),
        Some("interference") => cmd_interference(args),
        Some("preempt") => cmd_preempt(args),
        Some("profile") => cmd_profile(args),
        Some("serve") => cmd_serve(args),
        Some("cluster") => cmd_cluster(args),
        Some("cluster-churn") => cmd_cluster_churn(args),
        Some("bench") => cmd_bench(args),
        Some("list-models") => cmd_list_models(),
        Some("verify-artifacts") => cmd_verify_artifacts(args),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = if let Some(path) = args.opt("config") {
        ExperimentConfig::from_json_file(path)?
    } else {
        let mode: Mode = args.opt("mode").unwrap_or("fikit").parse()?;
        let high: ModelKind = args
            .opt("high")
            .unwrap_or("keypointrcnn_resnet50_fpn")
            .parse()?;
        let low: ModelKind = args.opt("low").unwrap_or("fcn_resnet50").parse()?;
        let tasks: u32 = args.opt_parse("tasks", 200u32)?;
        let mut cfg = ExperimentConfig {
            mode,
            seed: args.opt_parse("seed", 0xF1C1u64)?,
            ..ExperimentConfig::default()
        };
        if let Some(token) = args.opt("backend") {
            cfg.device.backend = token.parse()?;
        }
        if let Some(token) = args.opt("preempt") {
            cfg.preempt = token.parse()?;
        }
        cfg.services
            .push(ServiceConfig::new(high, Priority::P0).tasks(tasks).with_key("high"));
        cfg.services
            .push(ServiceConfig::new(low, Priority::P3).tasks(tasks).with_key("low"));
        cfg
    };
    let report = run_experiment(&cfg)?;
    println!("{}", report.summary());
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let id = args.pos(1).unwrap_or("all").to_string();
    let opts = Options {
        scale: args.opt_parse("scale", 1.0f64)?,
        seed: args.opt_parse("seed", 0xF1C1u64)?,
    };
    let ids: Vec<&str> = if id == "all" {
        experiments::ALL.to_vec()
    } else {
        vec![id.as_str()]
    };
    let mut failed = 0;
    let mut exported = Vec::new();
    for id in ids {
        let t0 = std::time::Instant::now();
        let result = experiments::run(id, opts)?;
        println!("{}", result.render());
        println!("  ({:.2}s)\n", t0.elapsed().as_secs_f64());
        if !result.all_checks_pass() {
            failed += 1;
        }
        exported.push(result);
    }
    if let Some(path) = args.opt("json") {
        use fikit::util::json::Json;
        let doc = Json::obj().set(
            "experiments",
            Json::Arr(
                exported
                    .iter()
                    .map(|r| {
                        let mut series = Json::obj();
                        for (k, v) in &r.series {
                            series = series.set(k, *v);
                        }
                        Json::obj()
                            .set("id", r.id)
                            .set("title", r.title)
                            .set("passed", r.all_checks_pass())
                            .set("series", series)
                            .set(
                                "checks",
                                Json::Arr(
                                    r.checks
                                        .iter()
                                        .map(|c| {
                                            Json::obj()
                                                .set("name", c.name.as_str())
                                                .set("passed", c.passed)
                                                .set("detail", c.detail.as_str())
                                        })
                                        .collect(),
                                ),
                            )
                    })
                    .collect(),
            ),
        );
        std::fs::write(path, doc.encode_pretty())?;
        println!("wrote machine-readable results -> {path}");
    }
    if failed > 0 {
        return Err(fikit::core::Error::Invariant(format!(
            "{failed} experiment(s) had failing shape checks"
        )));
    }
    Ok(())
}

/// Run the online-refinement acceptance experiment (`experiments::drift`):
/// converge → inject gap interference → detect drift → re-converge, with
/// the accounted refinement overhead held to the paper's 5 % budget.
fn cmd_drift(args: &Args) -> Result<()> {
    let opts = Options {
        scale: args.opt_parse("scale", 1.0f64)?,
        seed: args.opt_parse("seed", 0xF1C1u64)?,
    };
    let result = experiments::run("drift", opts)?;
    println!("{}", result.render());
    if result.all_checks_pass() {
        Ok(())
    } else {
        Err(fikit::core::Error::Invariant(
            "drift experiment has failing shape checks".into(),
        ))
    }
}

/// Run the interference-learning acceptance experiment
/// (`experiments::interference`): plant a disguised aggressor, learn its
/// pairwise dilation online, and show aggressor-eviction holds the
/// high-priority slowdown at or below the victim-symptom baseline on
/// every concurrency backend (ADR-006).
fn cmd_interference(args: &Args) -> Result<()> {
    let opts = Options {
        scale: args.opt_parse("scale", 1.0f64)?,
        seed: args.opt_parse("seed", 0xF1C1u64)?,
    };
    let result = experiments::run("interference", opts)?;
    println!("{}", result.render());
    if result.all_checks_pass() {
        Ok(())
    } else {
        Err(fikit::core::Error::Invariant(
            "interference experiment has failing shape checks".into(),
        ))
    }
}

/// Run the preemption Pareto acceptance sweep (`experiments::preemption`)
/// and optionally write the machine-readable `PARETO_preempt.json`
/// artifact: one `{workload, policy, high_speedup, low_ratio}` point per
/// arm, plus the band and the shape-check verdicts
/// (`scripts/check_bench.py` validates the shape when the file exists).
fn cmd_preempt(args: &Args) -> Result<()> {
    let opts = Options {
        scale: args.opt_parse("scale", 1.0f64)?,
        seed: args.opt_parse("seed", 0xF1C1u64)?,
    };
    let result = experiments::run("preemption", opts)?;
    println!("{}", result.render());

    let json_path = args
        .opt("json")
        .map(str::to_string)
        .or_else(|| args.flag("json").then(|| "PARETO_preempt.json".to_string()));
    if let Some(path) = json_path {
        use fikit::util::json::Json;
        // The series come in (high_speedup, low_ratio) pairs per
        // workload×policy arm — re-join them into Pareto points.
        let mut points = Vec::new();
        for (name, speedup) in &result.series {
            let mut parts = name.split('/');
            let (Some("preempt"), Some(workload), Some(policy), Some("high_speedup")) =
                (parts.next(), parts.next(), parts.next(), parts.next())
            else {
                continue;
            };
            let ratio = result
                .series_value(&format!("preempt/{workload}/{policy}/low_ratio"))
                .unwrap_or(0.0);
            points.push(
                Json::obj()
                    .set("workload", workload)
                    .set("policy", policy)
                    .set("high_speedup", *speedup)
                    .set("low_ratio", ratio),
            );
        }
        let doc = Json::obj()
            .set("experiment", result.id)
            .set("passed", result.all_checks_pass())
            .set(
                "band",
                Json::obj()
                    .set("low", experiments::preemption::LOW_RATIO_BAND.0)
                    .set("high", experiments::preemption::LOW_RATIO_BAND.1),
            )
            .set("points", Json::Arr(points))
            .set(
                "checks",
                Json::Arr(
                    result
                        .checks
                        .iter()
                        .map(|c| {
                            Json::obj()
                                .set("name", c.name.as_str())
                                .set("passed", c.passed)
                                .set("detail", c.detail.as_str())
                        })
                        .collect(),
                ),
            );
        std::fs::write(&path, doc.encode_pretty())?;
        println!("wrote Pareto artifact -> {path}");
    }
    if result.all_checks_pass() {
        Ok(())
    } else {
        Err(fikit::core::Error::Invariant(
            "preemption sweep has failing shape checks".into(),
        ))
    }
}

fn cmd_profile(args: &Args) -> Result<()> {
    let model: ModelKind = args
        .opt("model")
        .ok_or_else(|| fikit::core::Error::Parse("--model required".into()))?
        .parse()?;
    let runs: u32 = args.opt_parse("runs", 20u32)?;
    let out = args.opt("out").unwrap_or("profiles.json");

    let mut cfg = ExperimentConfig::default();
    cfg.measurement.runs = runs;
    let svc = ServiceConfig::new(model, Priority::P0).tasks(runs);
    cfg.services.push(svc.clone());
    let result = profile_service(&cfg, &svc)?;
    println!(
        "profiled {model}: {} unique kernel ids over {} runs",
        result.profile.num_unique(),
        result.profile.runs
    );

    let mut store = if std::path::Path::new(out).exists() {
        ProfileStore::load(out)?
    } else {
        ProfileStore::new()
    };
    store.insert(result.profile);
    store.save(out)?;
    println!("saved profile store -> {out} ({} services)", store.len());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let bind = args.opt("bind").unwrap_or("127.0.0.1:7700").to_string();
    let profiles = match args.opt("profiles") {
        Some(path) => ProfileStore::load(path)?,
        None => ProfileStore::new(),
    };
    let devices: usize = args.opt_parse("devices", 1usize)?;
    if devices == 0 {
        return Err(fikit::core::Error::Parse("--devices must be ≥ 1".into()));
    }
    let mut cfg = ServerConfig {
        bind,
        devices,
        capacity: args.opt_parse("capacity", 32usize)?,
        policy: args.opt("placement").unwrap_or("leastloaded").parse()?,
        ..Default::default()
    };
    cfg.online.enabled = args.flag("online");
    cfg.journal = args.opt("journal").map(std::path::PathBuf::from);
    cfg.node = args.opt("advertise").map(str::to_string);
    if let Some(peers) = args.opt("peers") {
        if cfg.node.is_none() {
            return Err(fikit::core::Error::Parse(
                "--peers requires --advertise NAME (a beacon needs a sender)".into(),
            ));
        }
        for entry in peers.split(',').filter(|e| !e.is_empty()) {
            let Some((name, addr)) = entry.split_once('=') else {
                return Err(fikit::core::Error::Parse(format!(
                    "--peers entry {entry:?} is not name=host:port"
                )));
            };
            cfg.peers.push((name.to_string(), addr.to_string()));
        }
    }
    let beacon_ms: u64 = args.opt_parse("beacon-ms", 100u64)?;
    if beacon_ms == 0 {
        return Err(fikit::core::Error::Parse("--beacon-ms must be ≥ 1".into()));
    }
    cfg.fleet.beacon_interval = fikit::core::Duration::from_millis(beacon_ms);
    let run_for_ms: u64 = args.opt_parse("run-for-ms", 0u64)?;
    let deadline = if run_for_ms > 0 {
        Some(std::time::Duration::from_millis(run_for_ms))
    } else {
        None
    };
    let save_path = args.opt("save-profiles").map(str::to_string);
    let policy = cfg.policy;
    let capacity = cfg.capacity;
    let online = cfg.online.enabled;
    let journal = cfg.journal.clone();
    let node = cfg.node.clone();
    let peer_count = cfg.peers.len();
    let mut server = SchedulerServer::bind(cfg, profiles)?;
    println!(
        "fikit scheduler daemon listening on {} ({} device shard(s), capacity {}/device, {:?} placement, online refinement {})",
        server.local_addr()?,
        devices,
        capacity,
        policy,
        if online { "on" } else { "off" },
    );
    if let Some(name) = &node {
        println!(
            "fleet node {name:?}: beaconing to {peer_count} peer(s) every {beacon_ms} ms"
        );
    }
    if let Some(dir) = &journal {
        println!(
            "session journal -> {} ({} live session(s) replayed)",
            dir.display(),
            server.daemon().clients(),
        );
    }
    match (&save_path, deadline) {
        (None, d) => server.run_for(d)?,
        // A daemon is stopped by killing it (there is no clean-shutdown
        // signal path without external deps), so "persist on exit"
        // would never run. Persist periodically instead: at most one
        // save interval of refined knowledge is ever lost.
        (Some(path), d) => {
            const SAVE_EVERY: std::time::Duration = std::time::Duration::from_secs(30);
            println!("persisting profile store (incl. refined epochs) -> {path} every {}s",
                SAVE_EVERY.as_secs());
            let start = std::time::Instant::now();
            loop {
                let slice = match d {
                    None => SAVE_EVERY,
                    Some(total) => {
                        let left = total.saturating_sub(start.elapsed());
                        if left.is_zero() {
                            break;
                        }
                        SAVE_EVERY.min(left)
                    }
                };
                server.run_for(Some(slice))?;
                server.save_profiles(path)?;
            }
        }
    }
    // Shutdown accounting (reached with --run-for-ms): every rejected
    // or unroutable interaction is surfaced — sheds are explicit in the
    // stats line exactly as they are explicit on the wire.
    let s = server.stats();
    let d = server.daemon_stats();
    println!(
        "shutdown: clients={} holds={} releases=(immediate {}, filled {}, drained {}, purged {}) \
         rejected_capacity={} redirects={} sheds={} releases_unroutable={} decode_errors={} \
         beacons=(sent {}, received {}, stale {}) live_peers={}",
        server.daemon().clients(),
        s.holds,
        s.releases_immediate,
        s.releases_filled,
        s.releases_drained,
        s.purged_launches,
        d.rejected_capacity,
        d.redirects,
        d.sheds,
        d.releases_unroutable,
        d.decode_errors,
        d.beacons_sent,
        d.beacons_received,
        d.beacons_stale,
        server.daemon().live_peers(),
    );
    Ok(())
}

fn cmd_cluster(args: &Args) -> Result<()> {
    use fikit::cluster::{run_cluster, ClusterConfig, CompatMatrix, PlacementPolicy, ServiceRequest};

    let gpus: usize = args.opt_parse("gpus", 2usize)?;
    let policy: PlacementPolicy = args.opt("policy").unwrap_or("bestmatch").parse()?;
    let tasks: u32 = args.opt_parse("tasks", 30u32)?;

    // Compatibility matrix: loaded, freshly measured, or predicted.
    let models = [
        ModelKind::KeypointRcnnResnet50Fpn,
        ModelKind::FasterrcnnResnet50Fpn,
        ModelKind::FcnResnet50,
        ModelKind::Resnet101,
        ModelKind::Vgg16,
    ];
    let compat = if let Some(path) = args.opt("compat") {
        if std::path::Path::new(path).exists() {
            CompatMatrix::load(path)?
        } else if args.flag("measure-compat") {
            let m = CompatMatrix::measure(&models, 10, 7)?;
            m.save(path)?;
            println!("measured {} pairs -> {path}", m.len());
            m
        } else {
            CompatMatrix::new() // prediction fallback
        }
    } else if args.flag("measure-compat") {
        CompatMatrix::measure(&models, 10, 7)?
    } else {
        CompatMatrix::new()
    };

    // A representative mixed-tenant fleet workload.
    let mut cfg = ClusterConfig::new(gpus, policy);
    cfg.requests = vec![
        ServiceRequest::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0, tasks),
        ServiceRequest::new(ModelKind::FasterrcnnResnet50Fpn, Priority::P0, tasks),
        ServiceRequest::new(ModelKind::FcnResnet50, Priority::P5, tasks),
        ServiceRequest::new(ModelKind::Resnet101, Priority::P6, tasks),
        ServiceRequest::new(ModelKind::Vgg16, Priority::P7, tasks),
    ];
    let report = run_cluster(&cfg, &compat)?;
    println!("policy={policy:?} gpus={gpus}");
    println!("{}", report.summary());
    Ok(())
}

fn cmd_cluster_churn(args: &Args) -> Result<()> {
    use fikit::cluster::{run_churn, ChurnConfig, CompatMatrix, PlacementPolicy};
    use fikit::core::Duration;
    use fikit::workload::{ArrivalProcess, MixEntry};

    let gpus: usize = args.opt_parse("gpus", 3usize)?;
    let capacity: usize = args.opt_parse("capacity", 2usize)?;
    let policy: PlacementPolicy = args.opt("policy").unwrap_or("bestmatch").parse()?;
    let mode: Mode = args.opt("mode").unwrap_or("fikit").parse()?;
    let secs: f64 = args.opt_parse("secs", 2.0f64)?;

    // A representative mixed-priority churn workload.
    let mix = vec![
        MixEntry::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0, 1.0),
        MixEntry::new(ModelKind::FasterrcnnResnet50Fpn, Priority::P1, 1.0),
        MixEntry::new(ModelKind::FcnResnet50, Priority::P5, 2.0),
        MixEntry::new(ModelKind::Resnet101, Priority::P6, 2.0),
        MixEntry::new(ModelKind::Vgg16, Priority::P7, 1.0),
    ];
    let arrivals = ArrivalProcess::Poisson {
        mean_interarrival: Duration::from_millis(300),
        mean_lifetime: Duration::from_millis(600),
        mix,
        horizon: Duration::from_millis_f64(secs * 1_000.0),
    };
    let mut cfg = ChurnConfig::new(gpus, policy, arrivals);
    cfg.capacity = capacity;
    cfg.mode = mode;
    cfg.seed = args.opt_parse("seed", 0xF1C1u64)?;
    cfg.qos.high_slowdown_bound = args.opt_parse("bound", 1.5f64)?;
    cfg.qos.migration = !args.flag("no-migration");
    cfg.cold_start = args.flag("cold-start");
    cfg.online = args.flag("online");
    cfg.sim_threads = args.opt_parse("sim-threads", 1usize)?;
    if let Some(token) = args.opt("backend") {
        cfg.backend = token.parse()?;
    }
    if let Some(token) = args.opt("eviction") {
        cfg.qos.eviction = token.parse()?;
    }
    cfg.learn_interference = args.flag("learn-interference");

    let report = run_churn(&cfg, &CompatMatrix::new())?;
    println!(
        "policy={policy:?} mode={mode} gpus={gpus} capacity={capacity} migration={} cold_start={} backend={} eviction={:?} learn={}",
        cfg.qos.migration, cfg.cold_start, cfg.backend, cfg.qos.eviction, cfg.learn_interference
    );
    println!("{}", report.summary());
    Ok(())
}

/// Run the scheduler hot-path + simulator event-core bench suites and
/// (optionally) write the `BENCH_sched.json` / `BENCH_sim.json`
/// perf-trajectory artifacts. The single documented regeneration
/// command, from the repo root:
///
/// ```text
/// cargo run --manifest-path rust/Cargo.toml --release -- bench --json
/// ```
fn cmd_bench(args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    let sched = fikit::benchsuite::run_hotpath_suite(quick);
    println!("{}", sched.table);
    let sim = fikit::benchsuite::run_sim_suite(quick);
    println!("{}", sim.table);

    let json_path = args
        .opt("json")
        .map(str::to_string)
        .or_else(|| args.flag("json").then(|| "BENCH_sched.json".to_string()));
    if let Some(path) = json_path {
        sched.write_json(&path)?;
        println!("wrote bench results -> {path}");
        // BENCH_sim.json lands next to the scheduler artifact.
        let sim_path = std::path::Path::new(&path)
            .with_file_name("BENCH_sim.json")
            .to_string_lossy()
            .into_owned();
        sim.write_json(&sim_path)?;
        println!("wrote bench results -> {sim_path}");
    }

    let mut violations = sched.violations();
    violations.extend(sim.violations());
    for v in &violations {
        eprintln!("BUDGET VIOLATION: {v}");
    }
    if violations.is_empty() {
        Ok(())
    } else {
        Err(fikit::core::Error::Invariant(format!(
            "{} bench case(s) over budget",
            violations.len()
        )))
    }
}

fn cmd_list_models() -> Result<()> {
    let mut t = TextTable::new(&[
        "model", "class", "kernels", "exec (ms)", "sync idle (ms)", "JCT (ms)", "gap share",
        "stalls",
    ]);
    for kind in ModelKind::ALL {
        let spec = kind.spec();
        t.row(vec![
            kind.name().to_string(),
            format!("{:?}", kind.class()),
            spec.kernel_count().to_string(),
            format!("{:.2}", spec.mean_exec().as_millis_f64()),
            format!("{:.2}", spec.mean_sync_gap().as_millis_f64()),
            format!("{:.2}", spec.mean_jct().as_millis_f64()),
            format!("{:.2}", spec.gap_share()),
            spec.sync_points().to_string(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_verify_artifacts(args: &Args) -> Result<()> {
    let dir = args.opt("dir").unwrap_or("artifacts");
    let (manifest, rt) = fikit::runtime::executor::load_runtime(dir)?;
    println!(
        "loaded {} artifacts on platform {:?}",
        manifest.artifacts.len(),
        rt.platform()
    );
    let mut t = TextTable::new(&["artifact", "inputs", "outputs", "self-check rel err"]);
    for spec in &manifest.artifacts {
        let rel = rt.verify(&spec.name, 1e-3)?;
        t.row(vec![
            spec.name.clone(),
            spec.inputs.len().to_string(),
            spec.outputs.len().to_string(),
            format!("{rel:.2e}"),
        ]);
    }
    println!("{}", t.render());
    println!("all artifacts verified OK");
    Ok(())
}
