//! Multi-GPU cluster simulation — static and **dynamic**.
//!
//! Two entry points (DESIGN.md §8):
//!
//! * [`run_cluster`] — the one-shot batch run: place a fixed request set
//!   with a policy, run each GPU's tenant set through the single-GPU
//!   FIKIT simulator, report fleet-wide QoS. This is the paper's §5
//!   proposal evaluated in vitro.
//! * [`run_churn`] — the serving version: a fleet-level event loop where
//!   services *arrive over time* (seeded Poisson or scripted trace,
//!   [`ArrivalProcess`]), are placed incrementally by the live
//!   [`FleetState`], run on per-GPU [`GpuSim`] coordinators via
//!   mid-run attach, and *depart* (drain, detach). A periodic QoS scan
//!   watches each device's trailing-window high-priority slowdown and —
//!   when it exceeds the configured bound — reactively **migrates** the
//!   most disruptive low-priority tenant to the policy's best other
//!   device.
//!
//! The churn loop is **bulk-synchronous parallel** (DESIGN.md §Perf):
//! between consecutive fleet events every per-GPU sim is independent, so
//! [`ShardCtrl`] advances the device shards to the next fleet-event time
//! (the *merge horizon*) on `sim_threads` worker threads, then the main
//! thread runs all fleet-level logic — harvest, placement, migration —
//! serially in device order. Reports are byte-identical across thread
//! counts because the merge order never depends on thread interleaving.

use super::compat::CompatMatrix;
use super::placement::{FleetState, Placement, PlacementPolicy, Resident, ServiceRequest};
use crate::config::{ExperimentConfig, ServiceConfig};
use crate::coordinator::driver::{
    profile_service_scratch, run_experiment_scratch, GpuSim, SimScratch,
};
use crate::coordinator::Mode;
use crate::core::{Duration, Priority, Result, SimTime, TaskKey};
use crate::metrics::fleet::is_high_priority;
use crate::metrics::{FleetMetrics, FleetSample, JctStats, TextTable};
use crate::profile::ProfileStore;
use crate::simulator::CalendarWheel;
use crate::workload::{ArrivalProcess, InvocationPattern, ModelKind};
use std::collections::BTreeMap;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Barrier, Mutex};

/// Cluster experiment description (static batch run).
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of identical devices in the fleet.
    pub gpus: usize,
    /// Placement policy under test.
    pub policy: PlacementPolicy,
    /// The request set, in arrival order.
    pub requests: Vec<ServiceRequest>,
    /// Per-GPU scheduling mode.
    pub mode: Mode,
    /// Root seed.
    pub seed: u64,
}

impl ClusterConfig {
    /// A config with no requests yet.
    pub fn new(gpus: usize, policy: PlacementPolicy) -> ClusterConfig {
        ClusterConfig {
            gpus,
            policy,
            requests: Vec::new(),
            mode: Mode::Fikit,
            seed: 0xF1C1,
        }
    }
}

/// Per-service outcome across the cluster.
#[derive(Debug, Clone)]
pub struct ClusterServiceOutcome {
    /// Device the service ran on.
    pub gpu: usize,
    /// Model the service ran.
    pub model: crate::workload::ModelKind,
    /// Its task priority.
    pub priority: Priority,
    /// JCT statistics over its completed tasks.
    pub jct: JctStats,
    /// Mean JCT / solo mean JCT (1.0 = unharmed by sharing).
    pub slowdown: f64,
}

/// Fleet-wide results of a static batch run.
#[derive(Debug)]
pub struct ClusterReport {
    /// The placement decision that was simulated.
    pub placement: Placement,
    /// One outcome per placed service.
    pub services: Vec<ClusterServiceOutcome>,
}

impl ClusterReport {
    /// Mean slowdown of high-priority (P0–P2) services — the headline
    /// QoS number a placement policy is judged on.
    pub fn high_priority_slowdown(&self) -> f64 {
        let highs: Vec<f64> = self
            .services
            .iter()
            .filter(|s| is_high_priority(s.priority))
            .map(|s| s.slowdown)
            .collect();
        if highs.is_empty() {
            1.0
        } else {
            highs.iter().sum::<f64>() / highs.len() as f64
        }
    }

    /// Worst-case high-priority slowdown (tail QoS).
    pub fn worst_high_priority_slowdown(&self) -> f64 {
        self.services
            .iter()
            .filter(|s| is_high_priority(s.priority))
            .map(|s| s.slowdown)
            .fold(1.0, f64::max)
    }

    /// Human-readable per-service table plus the headline QoS line.
    pub fn summary(&self) -> String {
        let mut t = TextTable::new(&["gpu", "model", "prio", "mean JCT (ms)", "slowdown"]);
        let mut rows: Vec<&ClusterServiceOutcome> = self.services.iter().collect();
        rows.sort_by_key(|s| (s.gpu, s.priority));
        for s in rows {
            t.row(vec![
                s.gpu.to_string(),
                s.model.name().to_string(),
                s.priority.to_string(),
                format!("{:.2}", s.jct.mean_ms()),
                format!("{:.2}x", s.slowdown),
            ]);
        }
        format!(
            "{}mean high-prio slowdown: {:.2}x (worst {:.2}x)\n",
            t.render(),
            self.high_priority_slowdown(),
            self.worst_high_priority_slowdown()
        )
    }
}

/// Run the full static cluster experiment: place, then simulate each GPU.
pub fn run_cluster(cfg: &ClusterConfig, compat: &CompatMatrix) -> Result<ClusterReport> {
    let placement = cfg.policy.place(&cfg.requests, cfg.gpus, compat);

    // One event-core scratch reused across every run in this experiment.
    let mut scratch = SimScratch::new();

    // Solo baselines per distinct model (for slowdown normalization).
    let mut solo_ms: std::collections::BTreeMap<&'static str, f64> = Default::default();
    for req in &cfg.requests {
        let name = req.model.name();
        if !solo_ms.contains_key(name) {
            solo_ms.insert(
                name,
                solo_mean_ms(req.model, req.tasks.min(50), cfg.seed, &mut scratch)?,
            );
        }
    }

    let mut services = Vec::with_capacity(cfg.requests.len());
    for gpu in 0..cfg.gpus {
        let tenant_idxs = placement.on_gpu(gpu);
        if tenant_idxs.is_empty() {
            continue;
        }
        let mut gpu_cfg = ExperimentConfig {
            mode: cfg.mode,
            seed: cfg.seed ^ (gpu as u64) << 32,
            ..ExperimentConfig::default()
        };
        gpu_cfg.measurement.runs = 5;
        for &idx in &tenant_idxs {
            let req = &cfg.requests[idx];
            gpu_cfg.services.push(
                ServiceConfig::new(req.model, req.priority)
                    .tasks(req.tasks)
                    .with_key(&format!("svc{idx}")),
            );
        }
        let report = run_experiment_scratch(&gpu_cfg, &mut scratch)?;
        for &idx in &tenant_idxs {
            let req = &cfg.requests[idx];
            let svc = report
                .service(&crate::core::TaskKey::new(format!("svc{idx}").as_str()))
                .ok_or_else(|| crate::core::Error::Invariant("missing service".into()))?;
            let solo = solo_ms[req.model.name()];
            services.push(ClusterServiceOutcome {
                gpu,
                model: req.model,
                priority: req.priority,
                jct: svc.jct.clone(),
                slowdown: svc.jct.mean_ms() / solo,
            });
        }
    }
    Ok(ClusterReport {
        placement,
        services,
    })
}

/// Mean solo JCT of `model` (no co-tenant, default sharing path) — the
/// denominator of every slowdown in this module.
fn solo_mean_ms(model: ModelKind, tasks: u32, seed: u64, scratch: &mut SimScratch) -> Result<f64> {
    let mut solo = ExperimentConfig {
        mode: Mode::Sharing,
        seed,
        ..ExperimentConfig::default()
    };
    solo.services
        .push(ServiceConfig::new(model, Priority::P0).tasks(tasks.max(3)));
    Ok(run_experiment_scratch(&solo, scratch)?.services[0].jct.mean_ms())
}

// ---------------------------------------------------------------------
// Dynamic serving: churn + reactive migration
// ---------------------------------------------------------------------

/// QoS policy of the churn loop: when is a device "in violation", how
/// often do we look, and do we act on it.
#[derive(Debug, Clone)]
pub struct QosConfig {
    /// A device violates QoS when the mean high-priority slowdown of its
    /// trailing [`QosConfig::window`] exceeds this bound.
    pub high_slowdown_bound: f64,
    /// How often the fleet scans every device.
    pub scan_interval: Duration,
    /// Trailing window the scan evaluates.
    pub window: Duration,
    /// Whether a violating device triggers a reactive migration of its
    /// most disruptive low-priority tenant.
    pub migration: bool,
}

impl Default for QosConfig {
    fn default() -> QosConfig {
        QosConfig {
            high_slowdown_bound: 1.5,
            scan_interval: Duration::from_millis(250),
            window: Duration::from_millis(1_000),
            migration: true,
        }
    }
}

/// Dynamic cluster serving experiment description.
#[derive(Debug, Clone)]
pub struct ChurnConfig {
    /// Number of identical devices.
    pub gpus: usize,
    /// Max concurrent services per device.
    pub capacity: usize,
    /// Placement policy for arrivals *and* migration targets.
    pub policy: PlacementPolicy,
    /// Per-GPU scheduling mode.
    pub mode: Mode,
    /// Root seed (drives the arrival process and every GPU sim).
    pub seed: u64,
    /// The service churn schedule generator.
    pub arrivals: ArrivalProcess,
    /// QoS scanning and migration policy.
    pub qos: QosConfig,
    /// Fleet metrics bucket width (trajectory reporting).
    pub metrics_window: Duration,
    /// Cold-start admission (DESIGN.md §9): services enter sharing
    /// stage with a same-model **prior** instead of blocking on an
    /// exclusive measurement pass, and the per-GPU online refiner
    /// converges the prior against observed behaviour. Off = the
    /// paper's strict measurement-first lifecycle.
    pub cold_start: bool,
    /// Enable per-GPU online profile refinement even without cold-start
    /// admission (implied by `cold_start`).
    pub online: bool,
    /// Worker threads advancing device shards between fleet events
    /// (clamped to `[1, gpus]`). The report is byte-identical for every
    /// value — threads only split the shard-advance work, never the
    /// fleet-level decisions (DESIGN.md §Perf).
    pub sim_threads: usize,
}

impl ChurnConfig {
    /// A config with sensible defaults around the given arrival process.
    pub fn new(gpus: usize, policy: PlacementPolicy, arrivals: ArrivalProcess) -> ChurnConfig {
        ChurnConfig {
            gpus,
            capacity: 3,
            policy,
            mode: Mode::Fikit,
            seed: 0xF1C1,
            arrivals,
            qos: QosConfig::default(),
            metrics_window: Duration::from_millis(1_000),
            cold_start: false,
            online: false,
            sim_threads: 1,
        }
    }
}

/// Lifetime summary of one service instance in a churn run.
#[derive(Debug, Clone)]
pub struct ChurnServiceOutcome {
    /// Schedule-order instance id.
    pub id: u64,
    /// Model the service ran.
    pub model: ModelKind,
    /// Its task priority.
    pub priority: Priority,
    /// When it asked to be placed.
    pub arrived: SimTime,
    /// When it departed (equals `arrived` for rejected services).
    pub departed: SimTime,
    /// Tasks it completed over its lifetime.
    pub completed: usize,
    /// Mean slowdown over its completions (1.0 if it completed nothing).
    pub mean_slowdown: f64,
    /// Times it was migrated between devices.
    pub migrations: u32,
    /// True when the fleet was at capacity and the service was refused.
    pub rejected: bool,
}

/// Results of a dynamic churn run.
#[derive(Debug)]
pub struct ChurnReport {
    /// One entry per scheduled service instance.
    pub services: Vec<ChurnServiceOutcome>,
    /// Fleet-wide windowed samples (trajectory of QoS over the run).
    pub fleet: FleetMetrics,
    /// Fleet time at which the last GPU went quiescent.
    pub sim_end: SimTime,
    /// QoS scans performed (one per device per scan tick).
    pub scans: usize,
    /// Scans that found a device over the slowdown bound.
    pub qos_violations: usize,
    /// Reactive migrations executed.
    pub migrations: usize,
    /// Arrivals refused because no device had capacity.
    pub rejected: usize,
    /// Services admitted into sharing stage on a cold-start prior
    /// (no exclusive measurement; DESIGN.md §9).
    pub cold_starts: usize,
    /// Total completed tasks fleet-wide.
    pub completed_total: usize,
}

impl ChurnReport {
    /// Mean slowdown across every high-priority completion.
    pub fn high_mean_slowdown(&self) -> f64 {
        self.fleet.high_mean_slowdown()
    }

    /// Low-priority completions per second of fleet time.
    pub fn low_throughput_per_s(&self) -> f64 {
        self.fleet.low_throughput_per_s(self.sim_end)
    }

    /// Human-readable run summary: headline counters plus the windowed
    /// QoS trajectory.
    pub fn summary(&self) -> String {
        let mut out = format!(
            "services={} rejected={} cold_starts={} completed={} migrations={} qos_violations={}/{} \
             high mean slowdown={:.2}x low throughput={:.1}/s sim_end={:.2}s\n",
            self.services.len(),
            self.rejected,
            self.cold_starts,
            self.completed_total,
            self.migrations,
            self.qos_violations,
            self.scans,
            self.high_mean_slowdown(),
            self.low_throughput_per_s(),
            self.sim_end.as_secs_f64(),
        );
        out.push_str(&self.fleet.summary_table(self.sim_end).render());
        out
    }
}

/// Fleet-level events, processed in `(time, seq)` order.
#[derive(Debug, Clone, Copy)]
enum FleetEvent {
    /// Schedule entry `idx` arrives and requests placement.
    Arrive(usize),
    /// Service instance `id` departs (drain + detach).
    Depart(u64),
    /// Periodic QoS scan over every device.
    Scan,
}

/// Book-keeping for one live service instance.
struct LiveService {
    key: TaskKey,
    cfg: ServiceConfig,
    gpu: usize,
}

/// Bulk-synchronous shard coordinator (DESIGN.md §Perf).
///
/// Device sims are striped across `workers + 1` stripes; stripe 0 is run
/// by the main thread, stripes `1..=workers` by persistent worker
/// threads. One round = main stores the **merge horizon**, releases the
/// workers at the start barrier, runs its own stripe, and rejoins at the
/// end barrier — after which every shard sits at the horizon and all
/// worker mutations are visible to the main thread (the barrier is the
/// synchronization edge). Determinism across thread counts is free:
/// shards share nothing, every shard reaches the same horizons in the
/// same sequence, and all cross-shard logic stays on the main thread.
struct ShardCtrl {
    barrier: Barrier,
    /// Next merge horizon as raw nanos (`SimTime::MAX` = final drain).
    horizon: AtomicU64,
    shutdown: AtomicBool,
    workers: usize,
}

impl ShardCtrl {
    fn new(workers: usize) -> ShardCtrl {
        ShardCtrl {
            barrier: Barrier::new(workers + 1),
            horizon: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            workers,
        }
    }

    /// Advance every shard to `to` and return once all have arrived.
    fn advance(&self, sims: &[Mutex<GpuSim>], to: SimTime) {
        self.horizon.store(to.nanos(), Ordering::Relaxed);
        self.barrier.wait(); // release workers into this round
        self.run_stripe(sims, 0, to);
        self.barrier.wait(); // every stripe done, mutations published
    }

    fn run_stripe(&self, sims: &[Mutex<GpuSim>], stripe: usize, to: SimTime) {
        let stride = self.workers + 1;
        for sim in sims.iter().skip(stripe).step_by(stride) {
            sim.lock().expect("sim shard lock").run_until(to);
        }
    }

    fn worker_loop(&self, sims: &[Mutex<GpuSim>], worker: usize) {
        loop {
            self.barrier.wait();
            if self.shutdown.load(Ordering::Relaxed) {
                return;
            }
            let to = SimTime(self.horizon.load(Ordering::Relaxed));
            self.run_stripe(sims, worker + 1, to);
            self.barrier.wait();
        }
    }

    /// Release the workers into a final round told to exit. Idempotent,
    /// so the [`StopGuard`] can fire on both success and error paths.
    fn stop(&self) {
        if !self.shutdown.swap(true, Ordering::Relaxed) && self.workers > 0 {
            self.barrier.wait();
        }
    }
}

/// Shuts the shard workers down when dropped — including on the `?`
/// early-return paths of the serving loop, so `thread::scope` can join.
struct StopGuard<'a>(&'a ShardCtrl);

impl Drop for StopGuard<'_> {
    fn drop(&mut self) {
        self.0.stop();
    }
}

/// Run the dynamic cluster serving simulation.
///
/// Deterministic for a fixed config: the arrival schedule, every GPU
/// sim, and the scan cadence all derive from `cfg.seed`.
pub fn run_churn(cfg: &ChurnConfig, compat: &CompatMatrix) -> Result<ChurnReport> {
    assert!(cfg.gpus > 0, "cluster has no GPUs");
    let schedule = cfg.arrivals.generate(cfg.seed);

    // --- offline phase: solo baselines + profiles (paper lifecycle) ---
    // One event-core scratch serves every offline run back to back.
    let mut scratch = SimScratch::new();
    let mut solo_ms: BTreeMap<&'static str, f64> = BTreeMap::new();
    let mut store = ProfileStore::new();
    let mut model_profiles: HashMap<&'static str, crate::profile::TaskProfile> = HashMap::new();
    for arrival in &schedule {
        let name = arrival.model.name();
        if !solo_ms.contains_key(name) {
            solo_ms.insert(
                name,
                solo_mean_ms(arrival.model, 12, cfg.seed, &mut scratch)?,
            );
        }
        if cfg.mode == Mode::Fikit && !model_profiles.contains_key(name) {
            let profile = if cfg.cold_start {
                // Cold-start admission (DESIGN.md §9): no exclusive
                // measurement pass — the instance enters sharing stage
                // on a same-model prior (origin = Prior) and the
                // per-GPU online refiner converges it while serving.
                arrival
                    .model
                    .spec()
                    .structural_profile(TaskKey::new(name))
            } else {
                let mut base = ExperimentConfig {
                    seed: cfg.seed,
                    ..ExperimentConfig::default()
                };
                base.measurement.runs = 5;
                let svc = ServiceConfig::new(arrival.model, Priority::P0);
                profile_service_scratch(&base, &svc, &mut scratch)?.profile
            };
            model_profiles.insert(name, profile);
        }
    }
    // Each instance shares its model's measured profile under its own key.
    if cfg.mode == Mode::Fikit {
        for (idx, arrival) in schedule.iter().enumerate() {
            let mut profile = model_profiles[arrival.model.name()].clone();
            profile.task_key = TaskKey::new(format!("svc{idx}").as_str());
            store.insert(profile);
        }
    }

    // --- per-GPU sims ---
    let refine = (cfg.online || cfg.cold_start) && cfg.mode == Mode::Fikit;
    let gpu_cfgs: Vec<ExperimentConfig> = (0..cfg.gpus)
        .map(|g| {
            let mut c = ExperimentConfig {
                mode: cfg.mode,
                seed: cfg.seed ^ (g as u64) << 32,
                ..ExperimentConfig::default()
            };
            c.measurement.runs = 5;
            // Cold-start priors are only safe to serve on because the
            // refiner converges them; plain online refinement is an
            // opt-in QoS improvement under drift.
            c.online.enabled = refine;
            c
        })
        .collect();
    let mut sims: Vec<Mutex<GpuSim>> = Vec::with_capacity(cfg.gpus);
    for gpu_cfg in &gpu_cfgs {
        sims.push(Mutex::new(GpuSim::with_scratch(
            gpu_cfg,
            &store,
            &mut scratch,
        )?));
    }
    let mut harvested: Vec<usize> = vec![0; cfg.gpus];

    // --- fleet event queue ---
    // Fleet events ride the same calendar-queue wheel as device events
    // (ADR-003); its insertion counter is the deterministic tie-break.
    // Coarser geometry than the device queue: fleet events are ms-scale
    // (scans, arrivals), so 2^20 ns ≈ 1.05 ms ticks × 1024 buckets spans
    // ≈ 1.07 s before the overflow ring takes over.
    let mut fleet_q: CalendarWheel<FleetEvent> = CalendarWheel::with_geometry(20, 1024);
    for (idx, arrival) in schedule.iter().enumerate() {
        fleet_q.push(arrival.at, FleetEvent::Arrive(idx));
    }
    let churn_end = schedule
        .iter()
        .map(|a| a.departs_at())
        .max()
        .unwrap_or(SimTime::ZERO);
    if !cfg.qos.scan_interval.is_zero() {
        let mut t = SimTime::ZERO + cfg.qos.scan_interval;
        while t <= churn_end {
            fleet_q.push(t, FleetEvent::Scan);
            t = t + cfg.qos.scan_interval;
        }
    }

    // --- fleet state + accounting ---
    let mut fleet = FleetState::new(cfg.gpus, cfg.capacity);
    let mut live: HashMap<u64, LiveService> = HashMap::new();
    let mut key_to_id: HashMap<TaskKey, u64> = HashMap::new();
    let mut metrics = FleetMetrics::new(cfg.metrics_window);
    let mut services: Vec<ChurnServiceOutcome> = schedule
        .iter()
        .enumerate()
        .map(|(idx, a)| ChurnServiceOutcome {
            id: idx as u64,
            model: a.model,
            priority: a.priority,
            arrived: a.at,
            departed: a.departs_at(),
            completed: 0,
            mean_slowdown: 1.0,
            migrations: 0,
            rejected: false,
        })
        .collect();
    let mut slowdown_sums: Vec<f64> = vec![0.0; schedule.len()];
    let mut scans = 0usize;
    let mut qos_violations = 0usize;
    let mut migrations = 0usize;
    let mut rejected = 0usize;
    let mut cold_starts = 0usize;

    // --- the serving loop (bulk-synchronous across device shards) ---
    let threads = cfg.sim_threads.max(1).min(cfg.gpus);
    let ctrl = ShardCtrl::new(threads - 1);
    std::thread::scope(|scope| -> Result<()> {
        let guard = StopGuard(&ctrl);
        for w in 0..ctrl.workers {
            let ctrl = &ctrl;
            let sims = &sims[..];
            scope.spawn(move || ctrl.worker_loop(sims, w));
        }

        while let Some((t, ev)) = fleet_q.pop() {
            // Bring every GPU up to the fleet clock, then harvest
            // completions so scan decisions see everything that finished
            // before `t`. Workers park at the barrier in between, so the
            // main thread mutates sims below without contention.
            ctrl.advance(&sims, t);
            harvest(
                &sims,
                &mut harvested,
                &key_to_id,
                &schedule,
                &solo_ms,
                &mut metrics,
                &mut services,
                &mut slowdown_sums,
            );

            match ev {
                FleetEvent::Arrive(idx) => {
                    let arrival = &schedule[idx];
                    let id = idx as u64;
                    let resident = Resident::per_task(id, arrival.model, arrival.priority);
                    match fleet.place(cfg.policy, resident, compat) {
                        None => {
                            rejected += 1;
                            services[idx].rejected = true;
                            services[idx].departed = arrival.at;
                        }
                        Some(gpu) => {
                            if cfg.cold_start && cfg.mode == Mode::Fikit {
                                cold_starts += 1;
                            }
                            let key = TaskKey::new(format!("svc{idx}").as_str());
                            let mut svc_cfg = ServiceConfig::new(arrival.model, arrival.priority)
                                .with_key(key.as_str());
                            svc_cfg.pattern = InvocationPattern::ContinuousUntil {
                                until: SimTime::MAX,
                            };
                            sims[gpu].lock().expect("sim shard lock").attach(&svc_cfg, t)?;
                            key_to_id.insert(key.clone(), id);
                            live.insert(
                                id,
                                LiveService {
                                    key,
                                    cfg: svc_cfg,
                                    gpu,
                                },
                            );
                            fleet_q.push(arrival.departs_at(), FleetEvent::Depart(id));
                        }
                    }
                }
                FleetEvent::Depart(id) => {
                    if let Some(svc) = live.remove(&id) {
                        fleet.evict(id);
                        sims[svc.gpu].lock().expect("sim shard lock").detach(&svc.key)?;
                        services[id as usize].departed = t;
                    }
                }
                FleetEvent::Scan => {
                    for gpu in 0..cfg.gpus {
                        scans += 1;
                        let from = SimTime(t.nanos().saturating_sub(cfg.qos.window.nanos()));
                        let slice = metrics.samples_in(gpu, from, t);
                        let highs: Vec<f64> = slice
                            .iter()
                            .filter(|smp| is_high_priority(smp.priority))
                            .map(|smp| smp.slowdown)
                            .collect();
                        if highs.is_empty() {
                            continue;
                        }
                        let mean = highs.iter().sum::<f64>() / highs.len() as f64;
                        if mean <= cfg.qos.high_slowdown_bound {
                            continue;
                        }
                        qos_violations += 1;
                        if !cfg.qos.migration {
                            continue;
                        }
                        // Victim: the low-priority resident predicted to
                        // hurt the device's high-priority tenants the most.
                        let victim = pick_victim(&fleet, gpu, compat);
                        let Some(victim_id) = victim else { continue };
                        let Some((vfrom, vto)) = fleet.migrate(victim_id, cfg.policy, compat)
                        else {
                            continue; // nowhere to go; keep suffering
                        };
                        let svc = live.get_mut(&victim_id).expect("victim is live");
                        if !sims[vto].lock().expect("sim shard lock").can_attach(&svc.key) {
                            // A drained-enough slot isn't available on the
                            // target (the service lived there moments ago
                            // and its last task is still in flight): undo.
                            fleet.force_move(victim_id, vfrom);
                            continue;
                        }
                        sims[vfrom].lock().expect("sim shard lock").detach(&svc.key)?;
                        sims[vto].lock().expect("sim shard lock").attach(&svc.cfg, t)?;
                        svc.gpu = vto;
                        migrations += 1;
                        services[victim_id as usize].migrations += 1;
                    }
                }
            }
        }

        // Drain: departures all processed; let in-flight tasks finish.
        ctrl.advance(&sims, SimTime::MAX);
        harvest(
            &sims,
            &mut harvested,
            &key_to_id,
            &schedule,
            &solo_ms,
            &mut metrics,
            &mut services,
            &mut slowdown_sums,
        );
        drop(guard);
        Ok(())
    })?;

    for (idx, svc) in services.iter_mut().enumerate() {
        if svc.completed > 0 {
            svc.mean_slowdown = slowdown_sums[idx] / svc.completed as f64;
        }
    }
    let sim_end = sims
        .iter()
        .map(|s| s.lock().expect("sim shard lock").now())
        .max()
        .unwrap_or(SimTime::ZERO)
        .max(churn_end);
    let completed_total = services.iter().map(|s| s.completed).sum();
    Ok(ChurnReport {
        services,
        fleet: metrics,
        sim_end,
        scans,
        qos_violations,
        migrations,
        rejected,
        cold_starts,
        completed_total,
    })
}

/// Pull new task outcomes out of every GPU sim into the fleet metrics.
/// Runs on the main thread only, in device-index order — part of the
/// deterministic merge (DESIGN.md §Perf).
#[allow(clippy::too_many_arguments)]
fn harvest(
    sims: &[Mutex<GpuSim>],
    harvested: &mut [usize],
    key_to_id: &HashMap<TaskKey, u64>,
    schedule: &[crate::workload::ServiceArrival],
    solo_ms: &BTreeMap<&'static str, f64>,
    metrics: &mut FleetMetrics,
    services: &mut [ChurnServiceOutcome],
    slowdown_sums: &mut [f64],
) {
    for (gpu, sim) in sims.iter().enumerate() {
        let sim = sim.lock().expect("sim shard lock");
        let outcomes = sim.outcomes();
        for outcome in &outcomes[harvested[gpu]..] {
            let Some(&id) = key_to_id.get(&outcome.task_key) else {
                continue; // not a churn-managed service (defensive)
            };
            let idx = id as usize;
            let model = schedule[idx].model;
            let jct_ms = outcome.jct().as_millis_f64();
            let slowdown = (jct_ms / solo_ms[model.name()]).max(0.0);
            services[idx].completed += 1;
            slowdown_sums[idx] += slowdown;
            metrics.record(FleetSample {
                gpu,
                priority: outcome.priority,
                arrival: outcome.arrival,
                jct: outcome.jct(),
                slowdown,
            });
        }
        harvested[gpu] = outcomes.len();
    }
}

/// The low-priority tenant on `gpu` with the worst predicted impact on
/// the device's high-priority residents (`None` if the device hosts no
/// low-priority service or no high-priority service to protect).
fn pick_victim(fleet: &FleetState, gpu: usize, compat: &CompatMatrix) -> Option<u64> {
    let residents = fleet.residents_on(gpu);
    let highs: Vec<&Resident> = residents
        .iter()
        .filter(|r| is_high_priority(r.priority))
        .collect();
    if highs.is_empty() {
        return None;
    }
    residents
        .iter()
        .filter(|r| !is_high_priority(r.priority))
        .map(|r| {
            let impact = highs
                .iter()
                .map(|h| compat.get(h.model, r.model).high_slowdown)
                .fold(1.0, f64::max);
            (r.id, impact)
        })
        .max_by(|a, b| a.1.partial_cmp(&b.1).expect("impacts are finite"))
        .map(|(id, _)| id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{MixEntry, ModelKind, ServiceArrival};

    fn requests() -> Vec<ServiceRequest> {
        vec![
            ServiceRequest::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0, 15),
            ServiceRequest::new(ModelKind::FasterrcnnResnet50Fpn, Priority::P0, 15),
            ServiceRequest::new(ModelKind::FcnResnet50, Priority::P5, 15),
            ServiceRequest::new(ModelKind::Resnet101, Priority::P6, 15),
        ]
    }

    #[test]
    fn cluster_runs_and_reports() {
        let mut cfg = ClusterConfig::new(2, PlacementPolicy::BestMatch);
        cfg.requests = requests();
        let report = run_cluster(&cfg, &CompatMatrix::new()).unwrap();
        assert_eq!(report.services.len(), 4);
        assert!(report.high_priority_slowdown() >= 1.0);
        assert!(report.summary().contains("mean high-prio slowdown"));
    }

    #[test]
    fn best_match_no_worse_than_round_robin_on_qos() {
        // The compatibility-aware policy must protect high-priority
        // tenants at least as well as naive spreading for this workload.
        let run = |policy| {
            let mut cfg = ClusterConfig::new(2, policy);
            cfg.requests = requests();
            run_cluster(&cfg, &CompatMatrix::new()).unwrap()
        };
        let bm = run(PlacementPolicy::BestMatch);
        let rr = run(PlacementPolicy::RoundRobin);
        assert!(
            bm.worst_high_priority_slowdown() <= rr.worst_high_priority_slowdown() * 1.1,
            "BestMatch {:.2}x vs RoundRobin {:.2}x",
            bm.worst_high_priority_slowdown(),
            rr.worst_high_priority_slowdown()
        );
    }

    #[test]
    fn empty_gpu_tolerated() {
        let mut cfg = ClusterConfig::new(4, PlacementPolicy::LeastLoaded);
        cfg.requests = vec![ServiceRequest::new(ModelKind::Alexnet, Priority::P0, 5)];
        let report = run_cluster(&cfg, &CompatMatrix::new()).unwrap();
        assert_eq!(report.services.len(), 1);
    }

    // ----- dynamic churn -----

    /// A short scripted churn: one high-priority detector and two
    /// low-priority fillers overlapping on a small fleet.
    fn small_trace() -> ArrivalProcess {
        ArrivalProcess::Trace(vec![
            ServiceArrival::new(
                SimTime::ZERO,
                ModelKind::KeypointRcnnResnet50Fpn,
                Priority::P0,
                Duration::from_millis(400),
            ),
            ServiceArrival::new(
                SimTime(50_000_000),
                ModelKind::FcnResnet50,
                Priority::P5,
                Duration::from_millis(300),
            ),
            ServiceArrival::new(
                SimTime(100_000_000),
                ModelKind::Vgg16,
                Priority::P7,
                Duration::from_millis(250),
            ),
        ])
    }

    #[test]
    fn churn_run_completes_and_accounts_every_service() {
        let mut cfg = ChurnConfig::new(2, PlacementPolicy::BestMatch, small_trace());
        cfg.qos.scan_interval = Duration::from_millis(100);
        cfg.qos.window = Duration::from_millis(200);
        let report = run_churn(&cfg, &CompatMatrix::new()).unwrap();
        assert_eq!(report.services.len(), 3);
        assert_eq!(report.rejected, 0);
        // Every service got GPU time.
        for svc in &report.services {
            assert!(svc.completed > 0, "{:?} completed nothing", svc.model);
            assert!(svc.departed > svc.arrived);
        }
        assert_eq!(
            report.completed_total,
            report.services.iter().map(|s| s.completed).sum::<usize>()
        );
        assert!(report.sim_end >= SimTime(350_000_000));
        assert!(report.summary().contains("qos_violations"));
    }

    #[test]
    fn churn_is_deterministic_per_seed() {
        let mix = vec![
            MixEntry::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0, 1.0),
            MixEntry::new(ModelKind::FcnResnet50, Priority::P5, 1.0),
            MixEntry::new(ModelKind::Vgg16, Priority::P7, 1.0),
        ];
        let arrivals = ArrivalProcess::Poisson {
            mean_interarrival: Duration::from_millis(120),
            mean_lifetime: Duration::from_millis(250),
            mix,
            horizon: Duration::from_millis(800),
        };
        let mut cfg = ChurnConfig::new(2, PlacementPolicy::BestMatch, arrivals);
        cfg.seed = 0xC0FFEE;
        let a = run_churn(&cfg, &CompatMatrix::new()).unwrap();
        let b = run_churn(&cfg, &CompatMatrix::new()).unwrap();
        assert_eq!(a.completed_total, b.completed_total);
        assert_eq!(a.qos_violations, b.qos_violations);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.sim_end, b.sim_end);
        assert_eq!(a.fleet.len(), b.fleet.len());
    }

    /// Cold-start admission: no exclusive measurement happens, every
    /// placed service enters sharing on a prior, the online refiner is
    /// live, and the fleet still completes work deterministically.
    #[test]
    fn cold_start_admission_serves_on_priors() {
        let mut cfg = ChurnConfig::new(2, PlacementPolicy::BestMatch, small_trace());
        cfg.cold_start = true;
        cfg.qos.scan_interval = Duration::from_millis(100);
        cfg.qos.window = Duration::from_millis(200);
        let report = run_churn(&cfg, &CompatMatrix::new()).unwrap();
        assert_eq!(report.cold_starts, 3, "every placed service cold-started");
        for svc in &report.services {
            assert!(svc.completed > 0, "{:?} completed nothing", svc.model);
        }
        assert!(report.summary().contains("cold_starts=3"));

        // Deterministic under the fixed seed, like the measured path.
        let replay = run_churn(&cfg, &CompatMatrix::new()).unwrap();
        assert_eq!(report.completed_total, replay.completed_total);
        assert_eq!(report.sim_end, replay.sim_end);

        // The strict lifecycle performs no cold starts.
        let mut strict = ChurnConfig::new(2, PlacementPolicy::BestMatch, small_trace());
        strict.qos.scan_interval = Duration::from_millis(100);
        strict.qos.window = Duration::from_millis(200);
        let strict_report = run_churn(&strict, &CompatMatrix::new()).unwrap();
        assert_eq!(strict_report.cold_starts, 0);
    }

    #[test]
    fn capacity_overflow_rejects_instead_of_overpacking() {
        // 1 GPU × capacity 1, two overlapping services: the second is
        // rejected, not squeezed in.
        let arrivals = ArrivalProcess::Trace(vec![
            ServiceArrival::new(
                SimTime::ZERO,
                ModelKind::Alexnet,
                Priority::P0,
                Duration::from_millis(200),
            ),
            ServiceArrival::new(
                SimTime(50_000_000),
                ModelKind::Vgg16,
                Priority::P5,
                Duration::from_millis(100),
            ),
        ]);
        let mut cfg = ChurnConfig::new(1, PlacementPolicy::LeastLoaded, arrivals);
        cfg.capacity = 1;
        let report = run_churn(&cfg, &CompatMatrix::new()).unwrap();
        assert_eq!(report.rejected, 1);
        assert!(report.services[1].rejected);
        assert_eq!(report.services[1].completed, 0);
        assert!(report.services[0].completed > 0);
    }

    #[test]
    fn departures_free_capacity_for_replacement() {
        // Same 1×1 fleet, but the second service arrives after the first
        // departs: both run.
        let arrivals = ArrivalProcess::Trace(vec![
            ServiceArrival::new(
                SimTime::ZERO,
                ModelKind::Alexnet,
                Priority::P0,
                Duration::from_millis(100),
            ),
            ServiceArrival::new(
                SimTime(150_000_000),
                ModelKind::Vgg16,
                Priority::P5,
                Duration::from_millis(100),
            ),
        ]);
        let mut cfg = ChurnConfig::new(1, PlacementPolicy::LeastLoaded, arrivals);
        cfg.capacity = 1;
        let report = run_churn(&cfg, &CompatMatrix::new()).unwrap();
        assert_eq!(report.rejected, 0);
        assert!(report.services[0].completed > 0);
        assert!(report.services[1].completed > 0);
    }
}
