//! Multi-GPU cluster simulation — static and **dynamic**.
//!
//! Two entry points (DESIGN.md §8):
//!
//! * [`run_cluster`] — the one-shot batch run: place a fixed request set
//!   with a policy, run each GPU's tenant set through the single-GPU
//!   FIKIT simulator, report fleet-wide QoS. This is the paper's §5
//!   proposal evaluated in vitro.
//! * [`run_churn`] — the serving version: a fleet-level event loop where
//!   services *arrive over time* (seeded Poisson or scripted trace,
//!   [`ArrivalProcess`]), are placed incrementally by the live
//!   [`FleetState`], run on per-GPU [`GpuSim`] coordinators via
//!   mid-run attach, and *depart* (drain, detach). A periodic QoS scan
//!   watches each device's trailing-window high-priority slowdown and —
//!   when it exceeds the configured bound — reactively **migrates** a
//!   low-priority tenant chosen by the [`EvictionStrategy`]: the
//!   interference model's predicted worst aggressor (default), or the
//!   observed noisiest victim (baseline). With
//!   [`ChurnConfig::learn_interference`] the harvest loop feeds every
//!   completion back into the [`InterferenceModel`] by co-residency
//!   attribution, and devices run the configured
//!   [`ConcurrencyBackend`] (ADR-006).
//!
//! The churn loop is **bulk-synchronous parallel** (DESIGN.md §Perf):
//! between consecutive fleet events every per-GPU sim is independent, so
//! [`ShardCtrl`] advances the device shards to the next fleet-event time
//! (the *merge horizon*) on `sim_threads` worker threads, then the main
//! thread runs all fleet-level logic — harvest, placement, migration —
//! serially in device order. Reports are byte-identical across thread
//! counts because the merge order never depends on thread interleaving.

use super::compat::{CompatMatrix, InterferenceModel};
use super::control::FleetConfig;
use super::placement::{FleetState, Placement, PlacementPolicy, Resident, ServiceRequest};
use crate::config::{ExperimentConfig, ServiceConfig};
use crate::coordinator::driver::{
    profile_service_scratch, run_experiment_scratch, GpuSim, SimScratch,
};
use crate::coordinator::Mode;
use crate::core::{Dim3, Duration, Error, KernelId, Priority, Result, SimTime, TaskId, TaskKey};
use crate::daemon::{DaemonConfig, JournalConfig, SchedulerDaemon};
use crate::hook::client::{HookClient, LaunchDecision};
use crate::hook::transport::{GatedTransport, LossyNet};
use crate::metrics::fleet::is_high_priority;
use crate::metrics::{FleetMetrics, FleetSample, JctStats, TextTable};
use crate::profile::{ProfileStore, SymbolResolver, SymbolTableModel, TaskProfile};
use crate::simulator::{CalendarWheel, ConcurrencyBackend};
use crate::workload::{ArrivalProcess, InvocationPattern, ModelKind};
use std::collections::BTreeMap;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};
use std::time::{Duration as StdDuration, Instant};

/// Cluster experiment description (static batch run).
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of identical devices in the fleet.
    pub gpus: usize,
    /// Placement policy under test.
    pub policy: PlacementPolicy,
    /// The request set, in arrival order.
    pub requests: Vec<ServiceRequest>,
    /// Per-GPU scheduling mode.
    pub mode: Mode,
    /// Root seed.
    pub seed: u64,
}

impl ClusterConfig {
    /// A config with no requests yet.
    pub fn new(gpus: usize, policy: PlacementPolicy) -> ClusterConfig {
        ClusterConfig {
            gpus,
            policy,
            requests: Vec::new(),
            mode: Mode::Fikit,
            seed: 0xF1C1,
        }
    }
}

/// Per-service outcome across the cluster.
#[derive(Debug, Clone)]
pub struct ClusterServiceOutcome {
    /// Device the service ran on.
    pub gpu: usize,
    /// Model the service ran.
    pub model: crate::workload::ModelKind,
    /// Its task priority.
    pub priority: Priority,
    /// JCT statistics over its completed tasks.
    pub jct: JctStats,
    /// Mean JCT / solo mean JCT (1.0 = unharmed by sharing).
    pub slowdown: f64,
}

/// Fleet-wide results of a static batch run.
#[derive(Debug)]
pub struct ClusterReport {
    /// The placement decision that was simulated.
    pub placement: Placement,
    /// One outcome per placed service.
    pub services: Vec<ClusterServiceOutcome>,
}

impl ClusterReport {
    /// Mean slowdown of high-priority (P0–P2) services — the headline
    /// QoS number a placement policy is judged on.
    pub fn high_priority_slowdown(&self) -> f64 {
        let highs: Vec<f64> = self
            .services
            .iter()
            .filter(|s| is_high_priority(s.priority))
            .map(|s| s.slowdown)
            .collect();
        if highs.is_empty() {
            1.0
        } else {
            highs.iter().sum::<f64>() / highs.len() as f64
        }
    }

    /// Worst-case high-priority slowdown (tail QoS).
    pub fn worst_high_priority_slowdown(&self) -> f64 {
        self.services
            .iter()
            .filter(|s| is_high_priority(s.priority))
            .map(|s| s.slowdown)
            .fold(1.0, f64::max)
    }

    /// Human-readable per-service table plus the headline QoS line.
    pub fn summary(&self) -> String {
        let mut t = TextTable::new(&["gpu", "model", "prio", "mean JCT (ms)", "slowdown"]);
        let mut rows: Vec<&ClusterServiceOutcome> = self.services.iter().collect();
        rows.sort_by_key(|s| (s.gpu, s.priority));
        for s in rows {
            t.row(vec![
                s.gpu.to_string(),
                s.model.name().to_string(),
                s.priority.to_string(),
                format!("{:.2}", s.jct.mean_ms()),
                format!("{:.2}x", s.slowdown),
            ]);
        }
        format!(
            "{}mean high-prio slowdown: {:.2}x (worst {:.2}x)\n",
            t.render(),
            self.high_priority_slowdown(),
            self.worst_high_priority_slowdown()
        )
    }
}

/// Run the full static cluster experiment: place, then simulate each GPU.
pub fn run_cluster(cfg: &ClusterConfig, compat: &CompatMatrix) -> Result<ClusterReport> {
    // Static runs have no completion stream to learn from: the model is
    // pure priors, so placement behaves exactly like the offline matrix.
    let model = InterferenceModel::with_priors(compat.clone());
    let placement = cfg.policy.place(&cfg.requests, cfg.gpus, &model);

    // One event-core scratch reused across every run in this experiment.
    let mut scratch = SimScratch::new();

    // Solo baselines per distinct model (for slowdown normalization).
    let mut solo_ms: std::collections::BTreeMap<&'static str, f64> = Default::default();
    for req in &cfg.requests {
        let name = req.model.name();
        if !solo_ms.contains_key(name) {
            solo_ms.insert(
                name,
                solo_mean_ms(req.model, req.tasks.min(50), cfg.seed, &mut scratch)?,
            );
        }
    }

    let mut services = Vec::with_capacity(cfg.requests.len());
    for gpu in 0..cfg.gpus {
        let tenant_idxs = placement.on_gpu(gpu);
        if tenant_idxs.is_empty() {
            continue;
        }
        let mut gpu_cfg = ExperimentConfig {
            mode: cfg.mode,
            seed: cfg.seed ^ (gpu as u64) << 32,
            ..ExperimentConfig::default()
        };
        gpu_cfg.measurement.runs = 5;
        for &idx in &tenant_idxs {
            let req = &cfg.requests[idx];
            gpu_cfg.services.push(
                ServiceConfig::new(req.model, req.priority)
                    .tasks(req.tasks)
                    .with_key(&format!("svc{idx}")),
            );
        }
        let report = run_experiment_scratch(&gpu_cfg, &mut scratch)?;
        for &idx in &tenant_idxs {
            let req = &cfg.requests[idx];
            let svc = report
                .service(&crate::core::TaskKey::new(format!("svc{idx}").as_str()))
                .ok_or_else(|| crate::core::Error::Invariant("missing service".into()))?;
            let solo = solo_ms[req.model.name()];
            services.push(ClusterServiceOutcome {
                gpu,
                model: req.model,
                priority: req.priority,
                jct: svc.jct.clone(),
                slowdown: svc.jct.mean_ms() / solo,
            });
        }
    }
    Ok(ClusterReport {
        placement,
        services,
    })
}

/// Mean solo JCT of `model` (no co-tenant, default sharing path) — the
/// denominator of every slowdown in this module.
fn solo_mean_ms(model: ModelKind, tasks: u32, seed: u64, scratch: &mut SimScratch) -> Result<f64> {
    let mut solo = ExperimentConfig {
        mode: Mode::Sharing,
        seed,
        ..ExperimentConfig::default()
    };
    solo.services
        .push(ServiceConfig::new(model, Priority::P0).tasks(tasks.max(3)));
    Ok(run_experiment_scratch(&solo, scratch)?.services[0].jct.mean_ms())
}

// ---------------------------------------------------------------------
// Dynamic serving: churn + reactive migration
// ---------------------------------------------------------------------

/// Which low-priority tenant a violating device expels (DESIGN.md §8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EvictionStrategy {
    /// Evict the tenant the [`InterferenceModel`] *predicts* hurts the
    /// device's high-priority residents most — priors blended with
    /// online-learned dilation, so a quiet-looking tenant with a learned
    /// record of aggression is still the one that goes.
    #[default]
    WorstAggressor,
    /// Evict the low-priority tenant with the worst *observed* mean
    /// slowdown over its own completions — the naive baseline that
    /// relocates the suffering victim and leaves the aggressor behind.
    NoisiestVictim,
}

impl std::str::FromStr for EvictionStrategy {
    type Err = Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "aggressor" | "worst-aggressor" => Ok(EvictionStrategy::WorstAggressor),
            "victim" | "noisiest-victim" => Ok(EvictionStrategy::NoisiestVictim),
            other => Err(Error::Parse(format!("unknown eviction strategy {other:?}"))),
        }
    }
}

/// QoS policy of the churn loop: when is a device "in violation", how
/// often do we look, and do we act on it.
#[derive(Debug, Clone)]
pub struct QosConfig {
    /// A device violates QoS when the mean high-priority slowdown of its
    /// trailing [`QosConfig::window`] exceeds this bound.
    pub high_slowdown_bound: f64,
    /// How often the fleet scans every device.
    pub scan_interval: Duration,
    /// Trailing window the scan evaluates.
    pub window: Duration,
    /// Whether a violating device triggers a reactive migration of its
    /// most disruptive low-priority tenant.
    pub migration: bool,
    /// How the migration victim is chosen.
    pub eviction: EvictionStrategy,
}

impl Default for QosConfig {
    fn default() -> QosConfig {
        QosConfig {
            high_slowdown_bound: 1.5,
            scan_interval: Duration::from_millis(250),
            window: Duration::from_millis(1_000),
            migration: true,
            eviction: EvictionStrategy::WorstAggressor,
        }
    }
}

/// Dynamic cluster serving experiment description.
#[derive(Debug, Clone)]
pub struct ChurnConfig {
    /// Number of identical devices.
    pub gpus: usize,
    /// Max concurrent services per device.
    pub capacity: usize,
    /// Placement policy for arrivals *and* migration targets.
    pub policy: PlacementPolicy,
    /// Per-GPU scheduling mode.
    pub mode: Mode,
    /// Root seed (drives the arrival process and every GPU sim).
    pub seed: u64,
    /// The service churn schedule generator.
    pub arrivals: ArrivalProcess,
    /// QoS scanning and migration policy.
    pub qos: QosConfig,
    /// Fleet metrics bucket width (trajectory reporting).
    pub metrics_window: Duration,
    /// Cold-start admission (DESIGN.md §9): services enter sharing
    /// stage with a same-model **prior** instead of blocking on an
    /// exclusive measurement pass, and the per-GPU online refiner
    /// converges the prior against observed behaviour. Off = the
    /// paper's strict measurement-first lifecycle.
    pub cold_start: bool,
    /// Enable per-GPU online profile refinement even without cold-start
    /// admission (implied by `cold_start`).
    pub online: bool,
    /// Worker threads advancing device shards between fleet events
    /// (clamped to `[1, gpus]`). The report is byte-identical for every
    /// value — threads only split the shard-advance work, never the
    /// fleet-level decisions (DESIGN.md §Perf).
    pub sim_threads: usize,
    /// Hardware concurrency backend of every device (ADR-006). Slowdowns
    /// stay normalized to an exclusive full device (TimeSliced solo), so
    /// e.g. MIG's per-slice dilation is visible in the numbers rather
    /// than hidden in the denominator.
    pub backend: ConcurrencyBackend,
    /// Feed harvested completions into the [`InterferenceModel`] via
    /// co-residency attribution, so placement and eviction act on
    /// learned pairwise dilation instead of priors alone. Off = the
    /// pre-learning behaviour, byte for byte.
    pub learn_interference: bool,
    /// Interference injection: `(schedule index, gap scale)` — the
    /// designated service's CPU-side gaps are scaled at attach
    /// (`GpuSim::inject_gap_scale`; scale < 1.0 = a denser, more
    /// aggressive kernel stream). The identification scenario's planted
    /// aggressor.
    pub aggressor: Option<(usize, f64)>,
    /// Kernel-level preemption policy of every device's FIKIT tier
    /// (ADR-007). The default, `None`, is the pre-preemption behaviour
    /// byte for byte.
    pub preempt: crate::coordinator::fikit::PreemptionPolicy,
}

impl ChurnConfig {
    /// A config with sensible defaults around the given arrival process.
    pub fn new(gpus: usize, policy: PlacementPolicy, arrivals: ArrivalProcess) -> ChurnConfig {
        ChurnConfig {
            gpus,
            capacity: 3,
            policy,
            mode: Mode::Fikit,
            seed: 0xF1C1,
            arrivals,
            qos: QosConfig::default(),
            metrics_window: Duration::from_millis(1_000),
            cold_start: false,
            online: false,
            sim_threads: 1,
            backend: ConcurrencyBackend::TimeSliced,
            learn_interference: false,
            aggressor: None,
            preempt: crate::coordinator::fikit::PreemptionPolicy::None,
        }
    }
}

/// Lifetime summary of one service instance in a churn run.
#[derive(Debug, Clone)]
pub struct ChurnServiceOutcome {
    /// Schedule-order instance id.
    pub id: u64,
    /// Model the service ran.
    pub model: ModelKind,
    /// Its task priority.
    pub priority: Priority,
    /// When it asked to be placed.
    pub arrived: SimTime,
    /// When it departed (equals `arrived` for rejected services).
    pub departed: SimTime,
    /// Tasks it completed over its lifetime.
    pub completed: usize,
    /// Mean slowdown over its completions (1.0 if it completed nothing).
    pub mean_slowdown: f64,
    /// Times it was migrated between devices.
    pub migrations: u32,
    /// True when the fleet was at capacity and the service was refused.
    pub rejected: bool,
}

/// Results of a dynamic churn run.
#[derive(Debug)]
pub struct ChurnReport {
    /// One entry per scheduled service instance.
    pub services: Vec<ChurnServiceOutcome>,
    /// Fleet-wide windowed samples (trajectory of QoS over the run).
    pub fleet: FleetMetrics,
    /// Fleet time at which the last GPU went quiescent.
    pub sim_end: SimTime,
    /// QoS scans performed (one per device per scan tick).
    pub scans: usize,
    /// Scans that found a device over the slowdown bound.
    pub qos_violations: usize,
    /// Reactive migrations executed.
    pub migrations: usize,
    /// Arrivals refused because no device had capacity.
    pub rejected: usize,
    /// Services admitted into sharing stage on a cold-start prior
    /// (no exclusive measurement; DESIGN.md §9).
    pub cold_starts: usize,
    /// Total completed tasks fleet-wide.
    pub completed_total: usize,
    /// The interference model at end of run: pure priors when
    /// `learn_interference` was off, otherwise priors plus every learned
    /// `(victim, aggressor)` dilation pair — inspect with
    /// [`InterferenceModel::learned`], persist with
    /// [`InterferenceModel::save`].
    pub interference: InterferenceModel,
}

impl ChurnReport {
    /// Mean slowdown across every high-priority completion.
    pub fn high_mean_slowdown(&self) -> f64 {
        self.fleet.high_mean_slowdown()
    }

    /// Low-priority completions per second of fleet time.
    pub fn low_throughput_per_s(&self) -> f64 {
        self.fleet.low_throughput_per_s(self.sim_end)
    }

    /// Human-readable run summary: headline counters plus the windowed
    /// QoS trajectory.
    pub fn summary(&self) -> String {
        let mut out = format!(
            "services={} rejected={} cold_starts={} completed={} migrations={} qos_violations={}/{} \
             interference_obs={} high mean slowdown={:.2}x low throughput={:.1}/s sim_end={:.2}s\n",
            self.services.len(),
            self.rejected,
            self.cold_starts,
            self.completed_total,
            self.migrations,
            self.qos_violations,
            self.scans,
            self.interference.observations(),
            self.high_mean_slowdown(),
            self.low_throughput_per_s(),
            self.sim_end.as_secs_f64(),
        );
        out.push_str(&self.fleet.summary_table(self.sim_end).render());
        out
    }
}

/// Fleet-level events, processed in `(time, seq)` order.
#[derive(Debug, Clone, Copy)]
enum FleetEvent {
    /// Schedule entry `idx` arrives and requests placement.
    Arrive(usize),
    /// Service instance `id` departs (drain + detach).
    Depart(u64),
    /// Periodic QoS scan over every device.
    Scan,
}

/// Book-keeping for one live service instance.
struct LiveService {
    key: TaskKey,
    cfg: ServiceConfig,
    gpu: usize,
    /// CPU-gap multiplier re-applied on every (re-)attach: injected
    /// aggression is a property of the service, not of the device it
    /// happens to sit on, so it follows the service through migration.
    gap_scale: f64,
}

/// Bulk-synchronous shard coordinator (DESIGN.md §Perf).
///
/// Device sims are striped across `workers + 1` stripes; stripe 0 is run
/// by the main thread, stripes `1..=workers` by persistent worker
/// threads. One round = main stores the **merge horizon**, releases the
/// workers at the start barrier, runs its own stripe, and rejoins at the
/// end barrier — after which every shard sits at the horizon and all
/// worker mutations are visible to the main thread (the barrier is the
/// synchronization edge). Determinism across thread counts is free:
/// shards share nothing, every shard reaches the same horizons in the
/// same sequence, and all cross-shard logic stays on the main thread.
struct ShardCtrl {
    barrier: Barrier,
    /// Next merge horizon as raw nanos (`SimTime::MAX` = final drain).
    horizon: AtomicU64,
    shutdown: AtomicBool,
    workers: usize,
}

impl ShardCtrl {
    fn new(workers: usize) -> ShardCtrl {
        ShardCtrl {
            barrier: Barrier::new(workers + 1),
            horizon: AtomicU64::new(0),
            shutdown: AtomicBool::new(false),
            workers,
        }
    }

    /// Advance every shard to `to` and return once all have arrived.
    fn advance(&self, sims: &[Mutex<GpuSim>], to: SimTime) {
        self.horizon.store(to.nanos(), Ordering::Relaxed);
        self.barrier.wait(); // release workers into this round
        self.run_stripe(sims, 0, to);
        self.barrier.wait(); // every stripe done, mutations published
    }

    fn run_stripe(&self, sims: &[Mutex<GpuSim>], stripe: usize, to: SimTime) {
        let stride = self.workers + 1;
        for sim in sims.iter().skip(stripe).step_by(stride) {
            sim.lock().expect("sim shard lock").run_until(to);
        }
    }

    fn worker_loop(&self, sims: &[Mutex<GpuSim>], worker: usize) {
        loop {
            self.barrier.wait();
            if self.shutdown.load(Ordering::Relaxed) {
                return;
            }
            let to = SimTime(self.horizon.load(Ordering::Relaxed));
            self.run_stripe(sims, worker + 1, to);
            self.barrier.wait();
        }
    }

    /// Release the workers into a final round told to exit. Idempotent,
    /// so the [`StopGuard`] can fire on both success and error paths.
    fn stop(&self) {
        if !self.shutdown.swap(true, Ordering::Relaxed) && self.workers > 0 {
            self.barrier.wait();
        }
    }
}

/// Shuts the shard workers down when dropped — including on the `?`
/// early-return paths of the serving loop, so `thread::scope` can join.
struct StopGuard<'a>(&'a ShardCtrl);

impl Drop for StopGuard<'_> {
    fn drop(&mut self) {
        self.0.stop();
    }
}

/// Run the dynamic cluster serving simulation.
///
/// Deterministic for a fixed config: the arrival schedule, every GPU
/// sim, and the scan cadence all derive from `cfg.seed`.
pub fn run_churn(cfg: &ChurnConfig, compat: &CompatMatrix) -> Result<ChurnReport> {
    assert!(cfg.gpus > 0, "cluster has no GPUs");
    let schedule = cfg.arrivals.generate(cfg.seed);

    // --- offline phase: solo baselines + profiles (paper lifecycle) ---
    // One event-core scratch serves every offline run back to back.
    let mut scratch = SimScratch::new();
    let mut solo_ms: BTreeMap<&'static str, f64> = BTreeMap::new();
    let mut store = ProfileStore::new();
    let mut model_profiles: HashMap<&'static str, crate::profile::TaskProfile> = HashMap::new();
    for arrival in &schedule {
        let name = arrival.model.name();
        if !solo_ms.contains_key(name) {
            solo_ms.insert(
                name,
                solo_mean_ms(arrival.model, 12, cfg.seed, &mut scratch)?,
            );
        }
        if cfg.mode == Mode::Fikit && !model_profiles.contains_key(name) {
            let profile = if cfg.cold_start {
                // Cold-start admission (DESIGN.md §9): no exclusive
                // measurement pass — the instance enters sharing stage
                // on a same-model prior (origin = Prior) and the
                // per-GPU online refiner converges it while serving.
                arrival
                    .model
                    .spec()
                    .structural_profile(TaskKey::new(name))
            } else {
                let mut base = ExperimentConfig {
                    seed: cfg.seed,
                    ..ExperimentConfig::default()
                };
                base.measurement.runs = 5;
                let svc = ServiceConfig::new(arrival.model, Priority::P0);
                profile_service_scratch(&base, &svc, &mut scratch)?.profile
            };
            model_profiles.insert(name, profile);
        }
    }
    // Each instance shares its model's measured profile under its own key.
    if cfg.mode == Mode::Fikit {
        for (idx, arrival) in schedule.iter().enumerate() {
            let mut profile = model_profiles[arrival.model.name()].clone();
            profile.task_key = TaskKey::new(format!("svc{idx}").as_str());
            store.insert(profile);
        }
    }

    // --- per-GPU sims ---
    let refine = (cfg.online || cfg.cold_start) && cfg.mode == Mode::Fikit;
    let gpu_cfgs: Vec<ExperimentConfig> = (0..cfg.gpus)
        .map(|g| {
            let mut c = ExperimentConfig {
                mode: cfg.mode,
                seed: cfg.seed ^ (g as u64) << 32,
                ..ExperimentConfig::default()
            };
            c.measurement.runs = 5;
            // Cold-start priors are only safe to serve on because the
            // refiner converges them; plain online refinement is an
            // opt-in QoS improvement under drift.
            c.online.enabled = refine;
            c.device.backend = cfg.backend;
            c.preempt = cfg.preempt;
            c
        })
        .collect();
    let mut sims: Vec<Mutex<GpuSim>> = Vec::with_capacity(cfg.gpus);
    for gpu_cfg in &gpu_cfgs {
        sims.push(Mutex::new(GpuSim::with_scratch(
            gpu_cfg,
            &store,
            &mut scratch,
        )?));
    }
    let mut harvested: Vec<usize> = vec![0; cfg.gpus];

    // --- fleet event queue ---
    // Fleet events ride the same calendar-queue wheel as device events
    // (ADR-003); its insertion counter is the deterministic tie-break.
    // Coarser geometry than the device queue: fleet events are ms-scale
    // (scans, arrivals), so 2^20 ns ≈ 1.05 ms ticks × 1024 buckets spans
    // ≈ 1.07 s before the overflow ring takes over.
    let mut fleet_q: CalendarWheel<FleetEvent> = CalendarWheel::with_geometry(20, 1024);
    for (idx, arrival) in schedule.iter().enumerate() {
        fleet_q.push(arrival.at, FleetEvent::Arrive(idx));
    }
    let churn_end = schedule
        .iter()
        .map(|a| a.departs_at())
        .max()
        .unwrap_or(SimTime::ZERO);
    if !cfg.qos.scan_interval.is_zero() {
        let mut t = SimTime::ZERO + cfg.qos.scan_interval;
        while t <= churn_end {
            fleet_q.push(t, FleetEvent::Scan);
            t = t + cfg.qos.scan_interval;
        }
    }

    // --- fleet state + accounting ---
    let mut fleet = FleetState::new(cfg.gpus, cfg.capacity);
    let mut model = InterferenceModel::with_priors(compat.clone());
    let mut live: HashMap<u64, LiveService> = HashMap::new();
    let mut key_to_id: HashMap<TaskKey, u64> = HashMap::new();
    let mut metrics = FleetMetrics::new(cfg.metrics_window);
    let mut services: Vec<ChurnServiceOutcome> = schedule
        .iter()
        .enumerate()
        .map(|(idx, a)| ChurnServiceOutcome {
            id: idx as u64,
            model: a.model,
            priority: a.priority,
            arrived: a.at,
            departed: a.departs_at(),
            completed: 0,
            mean_slowdown: 1.0,
            migrations: 0,
            rejected: false,
        })
        .collect();
    let mut slowdown_sums: Vec<f64> = vec![0.0; schedule.len()];
    let mut scans = 0usize;
    let mut qos_violations = 0usize;
    let mut migrations = 0usize;
    let mut rejected = 0usize;
    let mut cold_starts = 0usize;

    // --- the serving loop (bulk-synchronous across device shards) ---
    let threads = cfg.sim_threads.max(1).min(cfg.gpus);
    let ctrl = ShardCtrl::new(threads - 1);
    std::thread::scope(|scope| -> Result<()> {
        let guard = StopGuard(&ctrl);
        for w in 0..ctrl.workers {
            let ctrl = &ctrl;
            let sims = &sims[..];
            scope.spawn(move || ctrl.worker_loop(sims, w));
        }

        while let Some((t, ev)) = fleet_q.pop() {
            // Bring every GPU up to the fleet clock, then harvest
            // completions so scan decisions see everything that finished
            // before `t`. Workers park at the barrier in between, so the
            // main thread mutates sims below without contention.
            ctrl.advance(&sims, t);
            harvest(
                &sims,
                &mut harvested,
                &key_to_id,
                &schedule,
                &solo_ms,
                &mut metrics,
                &mut services,
                &mut slowdown_sums,
                &fleet,
                cfg.learn_interference.then_some(&mut model),
            );

            match ev {
                FleetEvent::Arrive(idx) => {
                    let arrival = &schedule[idx];
                    let id = idx as u64;
                    let resident = Resident::per_task(id, arrival.model, arrival.priority);
                    match fleet.place(cfg.policy, resident, &model) {
                        None => {
                            rejected += 1;
                            services[idx].rejected = true;
                            services[idx].departed = arrival.at;
                        }
                        Some(gpu) => {
                            if cfg.cold_start && cfg.mode == Mode::Fikit {
                                cold_starts += 1;
                            }
                            let key = TaskKey::new(format!("svc{idx}").as_str());
                            let mut svc_cfg = ServiceConfig::new(arrival.model, arrival.priority)
                                .with_key(key.as_str());
                            svc_cfg.pattern = InvocationPattern::ContinuousUntil {
                                until: SimTime::MAX,
                            };
                            let gap_scale = match cfg.aggressor {
                                Some((agg_idx, scale)) if agg_idx == idx => scale,
                                _ => 1.0,
                            };
                            {
                                let mut sim = sims[gpu].lock().expect("sim shard lock");
                                sim.attach(&svc_cfg, t)?;
                                if gap_scale != 1.0 {
                                    sim.inject_gap_scale(&key, gap_scale)?;
                                }
                            }
                            key_to_id.insert(key.clone(), id);
                            live.insert(
                                id,
                                LiveService {
                                    key,
                                    cfg: svc_cfg,
                                    gpu,
                                    gap_scale,
                                },
                            );
                            fleet_q.push(arrival.departs_at(), FleetEvent::Depart(id));
                        }
                    }
                }
                FleetEvent::Depart(id) => {
                    if let Some(svc) = live.remove(&id) {
                        fleet.evict(id);
                        sims[svc.gpu].lock().expect("sim shard lock").detach(&svc.key)?;
                        services[id as usize].departed = t;
                    }
                }
                FleetEvent::Scan => {
                    for gpu in 0..cfg.gpus {
                        scans += 1;
                        let from = SimTime(t.nanos().saturating_sub(cfg.qos.window.nanos()));
                        let slice = metrics.samples_in(gpu, from, t);
                        let highs: Vec<f64> = slice
                            .iter()
                            .filter(|smp| is_high_priority(smp.priority))
                            .map(|smp| smp.slowdown)
                            .collect();
                        if highs.is_empty() {
                            continue;
                        }
                        let mean = highs.iter().sum::<f64>() / highs.len() as f64;
                        if mean <= cfg.qos.high_slowdown_bound {
                            continue;
                        }
                        qos_violations += 1;
                        if !cfg.qos.migration {
                            continue;
                        }
                        // Victim: chosen by the configured eviction
                        // strategy — predicted worst aggressor (learned
                        // model) or observed noisiest victim (baseline).
                        let victim = pick_victim(
                            &fleet,
                            gpu,
                            &model,
                            cfg.qos.eviction,
                            &services,
                            &slowdown_sums,
                        );
                        let Some(victim_id) = victim else { continue };
                        let Some((vfrom, vto)) = fleet.migrate(victim_id, cfg.policy, &model)
                        else {
                            continue; // nowhere to go; keep suffering
                        };
                        let svc = live.get_mut(&victim_id).expect("victim is live");
                        if !sims[vto].lock().expect("sim shard lock").can_attach(&svc.key) {
                            // A drained-enough slot isn't available on the
                            // target (the service lived there moments ago
                            // and its last task is still in flight): undo.
                            fleet.force_move(victim_id, vfrom);
                            continue;
                        }
                        sims[vfrom].lock().expect("sim shard lock").detach(&svc.key)?;
                        {
                            let mut sim = sims[vto].lock().expect("sim shard lock");
                            sim.attach(&svc.cfg, t)?;
                            if svc.gap_scale != 1.0 {
                                sim.inject_gap_scale(&svc.key, svc.gap_scale)?;
                            }
                        }
                        svc.gpu = vto;
                        migrations += 1;
                        services[victim_id as usize].migrations += 1;
                    }
                }
            }
        }

        // Drain: departures all processed; let in-flight tasks finish.
        ctrl.advance(&sims, SimTime::MAX);
        harvest(
            &sims,
            &mut harvested,
            &key_to_id,
            &schedule,
            &solo_ms,
            &mut metrics,
            &mut services,
            &mut slowdown_sums,
            &fleet,
            cfg.learn_interference.then_some(&mut model),
        );
        drop(guard);
        Ok(())
    })?;

    for (idx, svc) in services.iter_mut().enumerate() {
        if svc.completed > 0 {
            svc.mean_slowdown = slowdown_sums[idx] / svc.completed as f64;
        }
    }
    let sim_end = sims
        .iter()
        .map(|s| s.lock().expect("sim shard lock").now())
        .max()
        .unwrap_or(SimTime::ZERO)
        .max(churn_end);
    let completed_total = services.iter().map(|s| s.completed).sum();
    Ok(ChurnReport {
        services,
        fleet: metrics,
        sim_end,
        scans,
        qos_violations,
        migrations,
        rejected,
        cold_starts,
        completed_total,
        interference: model,
    })
}

/// Pull new task outcomes out of every GPU sim into the fleet metrics.
/// Runs on the main thread only, in device-index order — part of the
/// deterministic merge (DESIGN.md §Perf).
///
/// When `model` is `Some`, every harvested completion is also fed into
/// the interference model by **co-residency attribution**: the
/// completing service is the victim, and each *other* service resident
/// on its device at harvest time is charged as an aggressor with the
/// observed slowdown. Attribution is deliberately coarse (a co-tenant
/// that departed mid-task escapes blame) — the EWMA is built to average
/// that noise out, and the whole pass stays allocation-free.
#[allow(clippy::too_many_arguments)]
fn harvest(
    sims: &[Mutex<GpuSim>],
    harvested: &mut [usize],
    key_to_id: &HashMap<TaskKey, u64>,
    schedule: &[crate::workload::ServiceArrival],
    solo_ms: &BTreeMap<&'static str, f64>,
    metrics: &mut FleetMetrics,
    services: &mut [ChurnServiceOutcome],
    slowdown_sums: &mut [f64],
    fleet: &FleetState,
    mut model: Option<&mut InterferenceModel>,
) {
    for (gpu, sim) in sims.iter().enumerate() {
        let sim = sim.lock().expect("sim shard lock");
        let outcomes = sim.outcomes();
        for outcome in &outcomes[harvested[gpu]..] {
            let Some(&id) = key_to_id.get(&outcome.task_key) else {
                continue; // not a churn-managed service (defensive)
            };
            let idx = id as usize;
            let victim_model = schedule[idx].model;
            let jct_ms = outcome.jct().as_millis_f64();
            let slowdown = (jct_ms / solo_ms[victim_model.name()]).max(0.0);
            services[idx].completed += 1;
            slowdown_sums[idx] += slowdown;
            if let Some(model) = model.as_deref_mut() {
                for aggressor in fleet.residents_on(gpu) {
                    if aggressor.id != id {
                        model.observe(victim_model, aggressor.model, slowdown);
                    }
                }
            }
            metrics.record(FleetSample {
                gpu,
                priority: outcome.priority,
                arrival: outcome.arrival,
                jct: outcome.jct(),
                slowdown,
            });
        }
        harvested[gpu] = outcomes.len();
    }
}

/// The low-priority tenant a violating device expels (`None` if the
/// device hosts no low-priority service or no high-priority service to
/// protect).
///
/// * [`EvictionStrategy::WorstAggressor`] — the resident the
///   interference model *predicts* hurts the device's high-priority
///   tenants most (priors blended with learned dilation).
/// * [`EvictionStrategy::NoisiestVictim`] — the resident with the worst
///   *observed* mean slowdown over its own completions; the baseline
///   that tends to relocate the sufferer and leave the aggressor.
fn pick_victim(
    fleet: &FleetState,
    gpu: usize,
    model: &InterferenceModel,
    strategy: EvictionStrategy,
    services: &[ChurnServiceOutcome],
    slowdown_sums: &[f64],
) -> Option<u64> {
    let residents = fleet.residents_on(gpu);
    let highs: Vec<&Resident> = residents
        .iter()
        .filter(|r| is_high_priority(r.priority))
        .collect();
    if highs.is_empty() {
        return None;
    }
    residents
        .iter()
        .filter(|r| !is_high_priority(r.priority))
        .map(|r| {
            let badness = match strategy {
                EvictionStrategy::WorstAggressor => highs
                    .iter()
                    .map(|h| model.high_slowdown(h.model, r.model))
                    .fold(1.0, f64::max),
                EvictionStrategy::NoisiestVictim => {
                    let idx = r.id as usize;
                    match services[idx].completed {
                        0 => 1.0,
                        n => slowdown_sums[idx] / n as f64,
                    }
                }
            };
            (r.id, badness)
        })
        .max_by(|a, b| a.1.partial_cmp(&b.1).expect("badness is finite"))
        .map(|(id, _)| id)
}

// ---------------------------------------------------------------------
// Node-failure churn: the federation fault-injection harness
// ---------------------------------------------------------------------

/// Scripted node-failure scenario over a **real** federated daemon fleet
/// (DESIGN.md §Fleet-federation): N journaled `SchedulerDaemon`s, each on
/// its own seeded [`LossyNet`] fabric, exchanging beacons over gated
/// peer links, serving real [`HookClient`]s that follow redirects and
/// fail over. Faults are injected mid-traffic: an abrupt **kill** (the
/// daemon's process image vanishes; only its ADR-004 journal survives),
/// an optional journal **restart**, and a **partition** (the node's
/// whole fabric drops both directions, its outgoing beacon links are
/// severed, then everything heals).
///
/// [`run_node_churn`] asserts the robustness invariants inline — every
/// clean node conserves held launches and drains to zero sessions, and
/// no client operation exceeds `max_op_bound` (bounded failover
/// latency) — and returns the per-client outcomes for scenario-level
/// assertions. Unlike [`run_churn`] this harness runs real threads over
/// wall-clock time: outcomes are convergent, not bit-deterministic.
#[derive(Debug, Clone)]
pub struct NodeChurnConfig {
    /// Root seed for the per-node lossy fabrics.
    pub seed: u64,
    /// Fleet size (≥ 2; every node knows every other node).
    pub nodes: usize,
    /// Device shards per node.
    pub devices_per_node: usize,
    /// Admission capacity per device.
    pub capacity: usize,
    /// Client sessions, assigned round-robin to home nodes; every
    /// client holds failover endpoints on every node.
    pub clients: usize,
    /// Tasks per client session.
    pub tasks_per_client: u32,
    /// Kernel launches per task.
    pub kernels_per_task: u32,
    /// Datagram drop rate of every fabric, per mille.
    pub drop_permille: u32,
    /// Client-side think time after each kernel, to keep sessions
    /// in flight when the faults land.
    pub kernel_pace: StdDuration,
    /// Node killed abruptly `kill_after` into the run.
    pub kill_node: Option<usize>,
    pub kill_after: StdDuration,
    /// Restart the killed node from its journal this long after the
    /// kill (`None` = it stays dead).
    pub restart_after: Option<StdDuration>,
    /// Node partitioned (fabric + beacon links cut both ways)
    /// `partition_after` into the run, healed `partition_for` later.
    pub partition_node: Option<usize>,
    pub partition_after: StdDuration,
    pub partition_for: StdDuration,
    /// Control-plane cadence. The liveness window
    /// (`beacon_interval × miss_limit`) must comfortably exceed the
    /// serve-slice + recv-timeout jitter (~50 ms) or liveness flaps.
    pub beacon_interval: Duration,
    pub miss_limit: u32,
    /// Hard bound on any single client operation, failover included.
    pub max_op_bound: StdDuration,
}

impl NodeChurnConfig {
    /// Baseline: 3 nodes, 6 clients, 20% loss, no faults scheduled.
    pub fn new(seed: u64) -> NodeChurnConfig {
        NodeChurnConfig {
            seed,
            nodes: 3,
            devices_per_node: 1,
            capacity: 3,
            clients: 6,
            tasks_per_client: 4,
            kernels_per_task: 6,
            drop_permille: 200,
            kernel_pace: StdDuration::from_millis(10),
            kill_node: None,
            kill_after: StdDuration::from_millis(1_000),
            restart_after: None,
            partition_node: None,
            partition_after: StdDuration::from_millis(500),
            partition_for: StdDuration::from_millis(1_500),
            beacon_interval: Duration::from_millis(25),
            miss_limit: 8,
            max_op_bound: StdDuration::from_secs(8),
        }
    }
}

/// How one client session ended. There is no silent third state: any
/// other error fails the whole run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeChurnOutcome {
    /// Every task ran to completion (possibly on a failover node).
    Completed,
    /// The session ended with an explicit shed reply (`RetryAfter`, or
    /// a redirect chain the client could not resolve).
    Shed,
}

/// Results of one [`run_node_churn`] scenario.
#[derive(Debug)]
pub struct NodeChurnReport {
    /// Per-client outcome, indexed by client id.
    pub outcomes: Vec<NodeChurnOutcome>,
    pub completed: usize,
    pub shed: usize,
    /// Endpoint switches forced by unresponsive nodes, fleet-wide.
    pub failovers: u64,
    /// Longest single client operation observed (failover included).
    pub max_op_latency: StdDuration,
    /// Sessions the restarted node re-admitted from its journal.
    pub rejoined_sessions: usize,
    /// Peer restarts detected by clean survivors' fleet views.
    pub restarts_observed: u64,
    /// `Redirect` answers issued by daemons (clean nodes only).
    pub redirects: u64,
    /// `RetryAfter` shed answers issued by daemons (clean nodes only).
    pub sheds: u64,
    /// Each node's live-peer count at shutdown (`None` = node dead).
    pub live_peers: Vec<Option<usize>>,
    /// Datagrams dropped fleet-wide as `(client→daemon, daemon→client)`.
    pub dropped: (u64, u64),
}

/// Orchestrator→node fault switchboard.
#[derive(Default)]
struct NodeCtl {
    kill: AtomicBool,
    restart: AtomicBool,
    partition: AtomicBool,
    stop: AtomicBool,
}

/// What one node thread hands back at shutdown.
struct NodeEnd {
    daemon: Option<SchedulerDaemon>,
    rejoined: usize,
    live_peers: Option<usize>,
    /// Fault target (killed or partitioned): its sessions may have been
    /// abandoned mid-flight, so drain/conservation asserts don't apply.
    faulted: bool,
}

/// The synthetic kernel each client launches (matches its profile).
fn churn_kernel(high: bool) -> KernelId {
    KernelId::new(if high { "hk" } else { "lk" }, Dim3::x(8), Dim3::x(128))
}

/// Every client key gets a ready profile so sessions enter sharing
/// stage: even clients are high-priority holders (long gaps → fill
/// windows), odd ones low-priority fillers.
fn churn_profiles(clients: usize) -> ProfileStore {
    let mut store = ProfileStore::new();
    for c in 0..clients {
        let high = c % 2 == 0;
        let mut p = TaskProfile::new(TaskKey::new(format!("svc{c}").as_str()));
        p.record(
            &churn_kernel(high),
            Duration::from_micros(if high { 300 } else { 500 }),
            Some(Duration::from_micros(if high { 5_000 } else { 30 })),
        );
        p.finish_run(1);
        store.insert(p);
    }
    store
}

/// One node's serve loop: slices of real serving with the fault
/// switchboard checked between slices.
fn run_node(
    i: usize,
    cfg: &NodeChurnConfig,
    nets: &[Arc<LossyNet>],
    ctl: &NodeCtl,
    dir: &std::path::Path,
) -> Result<NodeEnd> {
    let mk = || -> Result<(SchedulerDaemon, Vec<Arc<AtomicBool>>)> {
        let dcfg = DaemonConfig {
            devices: cfg.devices_per_node,
            capacity: cfg.capacity,
            node: Some(format!("n{i}")),
            fleet: FleetConfig {
                beacon_interval: cfg.beacon_interval,
                miss_limit: cfg.miss_limit,
                retry_after_ms: 100,
            },
            ..DaemonConfig::default()
        };
        let mut d = SchedulerDaemon::with_journal(
            dcfg,
            churn_profiles(cfg.clients),
            dir,
            JournalConfig {
                fsync: false,
                snapshot_every: 64,
            },
        )?;
        let mut gates = Vec::new();
        for (j, net) in nets.iter().enumerate() {
            if j == i {
                continue;
            }
            // Beacons from node i enter node j's fabric as a synthetic
            // client; the gate models severing that one link.
            let (link, gate) = GatedTransport::new(net.client_endpoint(100 + i as u16));
            gates.push(gate);
            d.add_peer_link(Box::new(link));
        }
        Ok((d, gates))
    };

    let server_t = nets[i].server_endpoint();
    let mut inst = Some(mk()?);
    let mut rejoined = 0usize;
    while !ctl.stop.load(Ordering::SeqCst) {
        if ctl.kill.swap(false, Ordering::SeqCst) {
            // Abrupt death: no drain, no goodbye — in-memory sessions
            // vanish with the image; only the journal survives.
            inst = None;
        }
        if inst.is_none() && ctl.restart.swap(false, Ordering::SeqCst) {
            let re = mk()?;
            rejoined = re.0.clients();
            inst = Some(re);
        }
        let Some((daemon, gates)) = inst.as_mut() else {
            std::thread::sleep(StdDuration::from_millis(5));
            continue;
        };
        // Apply the desired partition state to this node's fabric
        // (cuts inbound traffic and its own replies) and to its
        // outgoing beacon links (cuts what peers hear from it).
        let partitioned = ctl.partition.load(Ordering::SeqCst);
        nets[i].set_partitioned(partitioned);
        for g in gates.iter() {
            g.store(!partitioned, Ordering::SeqCst);
        }
        daemon.serve(&server_t, Some(StdDuration::from_millis(30)), false)?;
    }
    let faulted = cfg.kill_node == Some(i) || cfg.partition_node == Some(i);
    let live_peers = inst.as_ref().map(|(d, _)| d.live_peers());
    Ok(NodeEnd {
        daemon: inst.map(|(d, _)| d),
        rejoined,
        live_peers,
        faulted,
    })
}

/// One client session: register (following redirects), run every task
/// stop-and-wait, disconnect. Returns the outcome, failover count, and
/// the longest single operation.
fn run_client(
    c: usize,
    cfg: &NodeChurnConfig,
    nets: &[Arc<LossyNet>],
) -> (Result<NodeChurnOutcome>, u64, StdDuration) {
    let home = c % cfg.nodes;
    let high = c % 2 == 0;
    let kernel = churn_kernel(high);
    let mut client = HookClient::new(
        nets[home].client_endpoint(9000 + c as u16),
        TaskKey::new(format!("svc{c}").as_str()),
        if high { Priority::P0 } else { Priority::P5 },
        SymbolResolver::new(SymbolTableModel::default()),
    )
    .with_primary_name(&format!("n{home}"));
    for k in 1..cfg.nodes {
        let j = (home + k) % cfg.nodes;
        client.add_endpoint(&format!("n{j}"), nets[j].client_endpoint(9000 + c as u16));
    }
    // Short attempts, many of them: convergence under loss needs
    // retries; endpoint death is declared after the full budget.
    client.set_retry(StdDuration::from_millis(40), 25);
    client.set_release_deadline(StdDuration::from_secs(20));

    let mut max_op = StdDuration::ZERO;
    macro_rules! op {
        ($e:expr) => {{
            let t0 = Instant::now();
            let r = $e;
            max_op = max_op.max(t0.elapsed());
            r
        }};
    }
    let mut session = || -> Result<NodeChurnOutcome> {
        op!(client.register())?;
        for task in 0..cfg.tasks_per_client {
            let tid = TaskId(u64::from(task));
            op!(client.task_start(tid))?;
            for seq in 0..cfg.kernels_per_task {
                match op!(client.intercept_launch(&kernel, tid, seq, SimTime(0)))? {
                    LaunchDecision::LaunchNow => {}
                    LaunchDecision::Held => op!(client.wait_release(seq))?,
                }
                if high {
                    op!(client.report_completion(
                        tid,
                        seq,
                        Duration::from_micros(300),
                        SimTime(1)
                    ))?;
                }
                std::thread::sleep(cfg.kernel_pace);
            }
            op!(client.task_end(tid))?;
        }
        // Best-effort: the daemon treats Disconnect idempotently and
        // the fleet may be shutting down around the final ack.
        let _ = op!(client.disconnect());
        Ok(NodeChurnOutcome::Completed)
    };
    let outcome = match session() {
        Ok(o) => Ok(o),
        // An explicit shed is a legal, accounted end state — the
        // whole point of graceful load shedding.
        Err(Error::Shed(_)) => Ok(NodeChurnOutcome::Shed),
        Err(e) => Err(e),
    };
    (outcome, client.failovers(), max_op)
}

/// Run the scripted node-failure churn scenario. Panics on invariant
/// violations (lost sessions, broken conservation, unbounded failover
/// latency); returns the outcome accounting for scenario asserts.
pub fn run_node_churn(cfg: &NodeChurnConfig) -> Result<NodeChurnReport> {
    assert!(cfg.nodes >= 2, "a fleet needs at least two nodes");
    assert!(cfg.kill_node.map_or(true, |k| k < cfg.nodes));
    assert!(cfg.partition_node.map_or(true, |p| p < cfg.nodes));

    let nets: Vec<Arc<LossyNet>> = (0..cfg.nodes)
        .map(|i| LossyNet::new(cfg.seed ^ ((i as u64 + 1) << 40), cfg.drop_permille))
        .collect();
    let ctls: Vec<NodeCtl> = (0..cfg.nodes).map(|_| NodeCtl::default()).collect();
    let dirs: Vec<std::path::PathBuf> = (0..cfg.nodes)
        .map(|i| {
            let d = std::env::temp_dir().join(format!(
                "fikit-node-churn-{}-{:x}-{i}",
                std::process::id(),
                cfg.seed
            ));
            let _ = std::fs::remove_dir_all(&d);
            d
        })
        .collect();

    // Fault schedule, ordered by wall-clock offset.
    let mut events: Vec<(StdDuration, usize, u8)> = Vec::new();
    if let Some(k) = cfg.kill_node {
        events.push((cfg.kill_after, k, 0));
        if let Some(after) = cfg.restart_after {
            events.push((cfg.kill_after + after, k, 1));
        }
    }
    if let Some(p) = cfg.partition_node {
        events.push((cfg.partition_after, p, 2));
        events.push((cfg.partition_after + cfg.partition_for, p, 3));
    }
    events.sort_by_key(|e| e.0);
    let last_event = events.last().map(|e| e.0).unwrap_or_default();

    let mut node_ends: Vec<Result<NodeEnd>> = Vec::new();
    let mut client_results: Vec<(Result<NodeChurnOutcome>, u64, StdDuration)> = Vec::new();
    std::thread::scope(|scope| {
        let node_handles: Vec<_> = (0..cfg.nodes)
            .map(|i| {
                let (nets, ctl, dir) = (&nets, &ctls[i], &dirs[i]);
                scope.spawn(move || run_node(i, cfg, nets, ctl, dir))
            })
            .collect();
        let client_handles: Vec<_> = (0..cfg.clients)
            .map(|c| {
                let nets = &nets;
                scope.spawn(move || run_client(c, cfg, nets))
            })
            .collect();

        let start = Instant::now();
        let wait_until = |t: StdDuration| {
            let now = start.elapsed();
            if t > now {
                std::thread::sleep(t - now);
            }
        };
        for (at, node, what) in events {
            wait_until(at);
            match what {
                0 => ctls[node].kill.store(true, Ordering::SeqCst),
                1 => ctls[node].restart.store(true, Ordering::SeqCst),
                2 => ctls[node].partition.store(true, Ordering::SeqCst),
                _ => ctls[node].partition.store(false, Ordering::SeqCst),
            }
        }
        for h in client_handles {
            client_results.push(h.join().expect("client thread panicked"));
        }
        // Settle past the last scheduled fault plus a few liveness
        // windows, so restarted/healed nodes re-enter every fleet view
        // before it is sampled.
        let settle = StdDuration::from_nanos(
            cfg.beacon_interval.nanos() * (u64::from(cfg.miss_limit) + 4),
        ) + StdDuration::from_millis(200);
        wait_until(last_event + settle);
        for ctl in &ctls {
            ctl.stop.store(true, Ordering::SeqCst);
        }
        for h in node_handles {
            node_ends.push(h.join().expect("node thread panicked"));
        }
    });
    for d in &dirs {
        let _ = std::fs::remove_dir_all(d);
    }

    let mut outcomes = Vec::new();
    let mut failovers = 0u64;
    let mut max_op = StdDuration::ZERO;
    for (r, f, m) in client_results {
        // No silent loss: every session either completed or was shed
        // explicitly — anything else fails the scenario here.
        outcomes.push(r?);
        failovers += f;
        max_op = max_op.max(m);
    }
    assert!(
        max_op <= cfg.max_op_bound,
        "failover latency unbounded: slowest op took {max_op:?} (bound {:?})",
        cfg.max_op_bound
    );

    let mut rejoined_sessions = 0usize;
    let mut restarts_observed = 0u64;
    let mut redirects = 0u64;
    let mut sheds = 0u64;
    let mut live_peers = Vec::new();
    for (i, end) in node_ends.into_iter().enumerate() {
        let end = end?;
        live_peers.push(end.live_peers);
        if end.rejoined > 0 {
            rejoined_sessions = end.rejoined;
        }
        let Some(d) = end.daemon else { continue };
        if end.faulted {
            continue; // abandoned sessions: drain asserts don't apply
        }
        // Conservation on every clean node: each held launch was
        // released exactly one way — filled, drained, or purged with
        // its disconnecting session. No duplicates, nothing lost.
        let s = d.stats_total();
        assert_eq!(
            s.holds,
            s.releases_filled + s.releases_drained + s.purged_launches,
            "node {i}: held-launch conservation broken"
        );
        assert_eq!(d.clients(), 0, "node {i}: sessions leaked past disconnect");
        restarts_observed += d.fleet_view().restarts_observed();
        redirects += d.stats().redirects;
        sheds += d.stats().sheds;
    }

    let completed = outcomes
        .iter()
        .filter(|o| **o == NodeChurnOutcome::Completed)
        .count();
    let dropped = nets.iter().map(|n| n.dropped()).fold((0, 0), |acc, d| {
        (acc.0 + d.0, acc.1 + d.1)
    });
    Ok(NodeChurnReport {
        shed: outcomes.len() - completed,
        completed,
        outcomes,
        failovers,
        max_op_latency: max_op,
        rejoined_sessions,
        restarts_observed,
        redirects,
        sheds,
        live_peers,
        dropped,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{MixEntry, ModelKind, ServiceArrival};

    fn requests() -> Vec<ServiceRequest> {
        vec![
            ServiceRequest::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0, 15),
            ServiceRequest::new(ModelKind::FasterrcnnResnet50Fpn, Priority::P0, 15),
            ServiceRequest::new(ModelKind::FcnResnet50, Priority::P5, 15),
            ServiceRequest::new(ModelKind::Resnet101, Priority::P6, 15),
        ]
    }

    #[test]
    fn cluster_runs_and_reports() {
        let mut cfg = ClusterConfig::new(2, PlacementPolicy::BestMatch);
        cfg.requests = requests();
        let report = run_cluster(&cfg, &CompatMatrix::new()).unwrap();
        assert_eq!(report.services.len(), 4);
        assert!(report.high_priority_slowdown() >= 1.0);
        assert!(report.summary().contains("mean high-prio slowdown"));
    }

    #[test]
    fn best_match_no_worse_than_round_robin_on_qos() {
        // The compatibility-aware policy must protect high-priority
        // tenants at least as well as naive spreading for this workload.
        let run = |policy| {
            let mut cfg = ClusterConfig::new(2, policy);
            cfg.requests = requests();
            run_cluster(&cfg, &CompatMatrix::new()).unwrap()
        };
        let bm = run(PlacementPolicy::BestMatch);
        let rr = run(PlacementPolicy::RoundRobin);
        assert!(
            bm.worst_high_priority_slowdown() <= rr.worst_high_priority_slowdown() * 1.1,
            "BestMatch {:.2}x vs RoundRobin {:.2}x",
            bm.worst_high_priority_slowdown(),
            rr.worst_high_priority_slowdown()
        );
    }

    #[test]
    fn empty_gpu_tolerated() {
        let mut cfg = ClusterConfig::new(4, PlacementPolicy::LeastLoaded);
        cfg.requests = vec![ServiceRequest::new(ModelKind::Alexnet, Priority::P0, 5)];
        let report = run_cluster(&cfg, &CompatMatrix::new()).unwrap();
        assert_eq!(report.services.len(), 1);
    }

    // ----- dynamic churn -----

    /// A short scripted churn: one high-priority detector and two
    /// low-priority fillers overlapping on a small fleet.
    fn small_trace() -> ArrivalProcess {
        ArrivalProcess::Trace(vec![
            ServiceArrival::new(
                SimTime::ZERO,
                ModelKind::KeypointRcnnResnet50Fpn,
                Priority::P0,
                Duration::from_millis(400),
            ),
            ServiceArrival::new(
                SimTime(50_000_000),
                ModelKind::FcnResnet50,
                Priority::P5,
                Duration::from_millis(300),
            ),
            ServiceArrival::new(
                SimTime(100_000_000),
                ModelKind::Vgg16,
                Priority::P7,
                Duration::from_millis(250),
            ),
        ])
    }

    #[test]
    fn churn_run_completes_and_accounts_every_service() {
        let mut cfg = ChurnConfig::new(2, PlacementPolicy::BestMatch, small_trace());
        cfg.qos.scan_interval = Duration::from_millis(100);
        cfg.qos.window = Duration::from_millis(200);
        let report = run_churn(&cfg, &CompatMatrix::new()).unwrap();
        assert_eq!(report.services.len(), 3);
        assert_eq!(report.rejected, 0);
        // Every service got GPU time.
        for svc in &report.services {
            assert!(svc.completed > 0, "{:?} completed nothing", svc.model);
            assert!(svc.departed > svc.arrived);
        }
        assert_eq!(
            report.completed_total,
            report.services.iter().map(|s| s.completed).sum::<usize>()
        );
        assert!(report.sim_end >= SimTime(350_000_000));
        assert!(report.summary().contains("qos_violations"));
    }

    #[test]
    fn churn_is_deterministic_per_seed() {
        let mix = vec![
            MixEntry::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0, 1.0),
            MixEntry::new(ModelKind::FcnResnet50, Priority::P5, 1.0),
            MixEntry::new(ModelKind::Vgg16, Priority::P7, 1.0),
        ];
        let arrivals = ArrivalProcess::Poisson {
            mean_interarrival: Duration::from_millis(120),
            mean_lifetime: Duration::from_millis(250),
            mix,
            horizon: Duration::from_millis(800),
        };
        let mut cfg = ChurnConfig::new(2, PlacementPolicy::BestMatch, arrivals);
        cfg.seed = 0xC0FFEE;
        let a = run_churn(&cfg, &CompatMatrix::new()).unwrap();
        let b = run_churn(&cfg, &CompatMatrix::new()).unwrap();
        assert_eq!(a.completed_total, b.completed_total);
        assert_eq!(a.qos_violations, b.qos_violations);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.sim_end, b.sim_end);
        assert_eq!(a.fleet.len(), b.fleet.len());
    }

    /// Cold-start admission: no exclusive measurement happens, every
    /// placed service enters sharing on a prior, the online refiner is
    /// live, and the fleet still completes work deterministically.
    #[test]
    fn cold_start_admission_serves_on_priors() {
        let mut cfg = ChurnConfig::new(2, PlacementPolicy::BestMatch, small_trace());
        cfg.cold_start = true;
        cfg.qos.scan_interval = Duration::from_millis(100);
        cfg.qos.window = Duration::from_millis(200);
        let report = run_churn(&cfg, &CompatMatrix::new()).unwrap();
        assert_eq!(report.cold_starts, 3, "every placed service cold-started");
        for svc in &report.services {
            assert!(svc.completed > 0, "{:?} completed nothing", svc.model);
        }
        assert!(report.summary().contains("cold_starts=3"));

        // Deterministic under the fixed seed, like the measured path.
        let replay = run_churn(&cfg, &CompatMatrix::new()).unwrap();
        assert_eq!(report.completed_total, replay.completed_total);
        assert_eq!(report.sim_end, replay.sim_end);

        // The strict lifecycle performs no cold starts.
        let mut strict = ChurnConfig::new(2, PlacementPolicy::BestMatch, small_trace());
        strict.qos.scan_interval = Duration::from_millis(100);
        strict.qos.window = Duration::from_millis(200);
        let strict_report = run_churn(&strict, &CompatMatrix::new()).unwrap();
        assert_eq!(strict_report.cold_starts, 0);
    }

    /// The backend seam must be invisible when unused: a default config
    /// (implicit TimeSliced, no learning) and an explicitly spelled-out
    /// one produce identical reports.
    #[test]
    fn default_config_equals_explicit_timesliced() {
        let mut implicit = ChurnConfig::new(2, PlacementPolicy::BestMatch, small_trace());
        implicit.qos.scan_interval = Duration::from_millis(100);
        implicit.qos.window = Duration::from_millis(200);
        let mut explicit = implicit.clone();
        explicit.backend = ConcurrencyBackend::TimeSliced;
        explicit.qos.eviction = EvictionStrategy::WorstAggressor;
        let a = run_churn(&implicit, &CompatMatrix::new()).unwrap();
        let b = run_churn(&explicit, &CompatMatrix::new()).unwrap();
        assert_eq!(a.completed_total, b.completed_total);
        assert_eq!(a.sim_end, b.sim_end);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.qos_violations, b.qos_violations);
        assert_eq!(a.fleet.len(), b.fleet.len());
        // Learning off: the model never saw an observation.
        assert_eq!(a.interference.observations(), 0);
    }

    /// Every backend serves the same trace to completion,
    /// deterministically.
    #[test]
    fn churn_runs_on_every_backend() {
        for backend in [
            ConcurrencyBackend::TimeSliced,
            ConcurrencyBackend::mps(),
            ConcurrencyBackend::mig(2),
        ] {
            let mut cfg = ChurnConfig::new(2, PlacementPolicy::BestMatch, small_trace());
            cfg.backend = backend;
            cfg.learn_interference = true;
            let a = run_churn(&cfg, &CompatMatrix::new()).unwrap();
            for svc in &a.services {
                assert!(svc.completed > 0, "{:?} idle under {backend}", svc.model);
            }
            let b = run_churn(&cfg, &CompatMatrix::new()).unwrap();
            assert_eq!(a.completed_total, b.completed_total, "{backend} nondeterministic");
            assert_eq!(a.sim_end, b.sim_end, "{backend} nondeterministic");
            assert_eq!(
                a.interference.epoch(),
                b.interference.epoch(),
                "{backend} learned differently across identical runs"
            );
        }
    }

    /// The identification scenario (ADR-006): a planted dense aggressor
    /// joins a device hosting a high-priority detector and a benign
    /// gappy filler under MPS. The learned model must (a) rank the
    /// aggressor's dilation above the benign tenant's, and (b) get it
    /// migrated away while the benign tenant stays put.
    #[test]
    fn injected_aggressor_is_identified_and_migrated() {
        const HIGH: ModelKind = ModelKind::KeypointRcnnResnet50Fpn;
        const BENIGN: ModelKind = ModelKind::FcosResnet50Fpn;
        const AGGRESSOR: ModelKind = ModelKind::Googlenet;
        // RoundRobin pins the cast: even indexes land on GPU 0 (the
        // protected device), odd ones on GPU 1.
        let arrivals = ArrivalProcess::Trace(vec![
            ServiceArrival::new(SimTime::ZERO, HIGH, Priority::P0, Duration::from_millis(3_000)),
            ServiceArrival::new(
                SimTime(10_000_000),
                ModelKind::Resnet50,
                Priority::P4,
                Duration::from_millis(2_800),
            ),
            ServiceArrival::new(
                SimTime(100_000_000),
                BENIGN,
                Priority::P5,
                Duration::from_millis(2_600),
            ),
            ServiceArrival::new(
                SimTime(110_000_000),
                ModelKind::Resnet50,
                Priority::P4,
                Duration::from_millis(2_500),
            ),
            ServiceArrival::new(
                SimTime(800_000_000),
                AGGRESSOR,
                Priority::P6,
                Duration::from_millis(1_800),
            ),
        ]);
        let mut cfg = ChurnConfig::new(2, PlacementPolicy::RoundRobin, arrivals);
        cfg.mode = Mode::Sharing; // raw MPS: no FIKIT holds muffling the overlap
        cfg.backend = ConcurrencyBackend::MpsSpatial { dilation: 0.5 };
        cfg.learn_interference = true;
        cfg.aggressor = Some((4, 0.1)); // 10x denser kernel stream
        cfg.qos.scan_interval = Duration::from_millis(100);
        cfg.qos.window = Duration::from_millis(400);
        cfg.qos.high_slowdown_bound = 1.2;
        cfg.qos.eviction = EvictionStrategy::WorstAggressor;
        let report = run_churn(&cfg, &CompatMatrix::new()).unwrap();

        // (a) learned ranking: the aggressor's EWMA dilation against the
        // high-priority victim dominates the benign tenant's.
        let (agg_dilation, agg_n) = report
            .interference
            .learned(HIGH, AGGRESSOR)
            .expect("co-residency with the aggressor was observed");
        assert!(agg_n > 0);
        if let Some((benign_dilation, _)) = report.interference.learned(HIGH, BENIGN) {
            assert!(
                agg_dilation > benign_dilation,
                "aggressor ({agg_dilation:.2}) must out-rank benign ({benign_dilation:.2})"
            );
        }
        // (b) the scan evicted the aggressor, not the benign filler.
        assert!(
            report.services[4].migrations >= 1,
            "aggressor never migrated: {report:?}"
        );
        assert_eq!(
            report.services[2].migrations, 0,
            "benign tenant was wrongly evicted"
        );
    }

    #[test]
    fn capacity_overflow_rejects_instead_of_overpacking() {
        // 1 GPU × capacity 1, two overlapping services: the second is
        // rejected, not squeezed in.
        let arrivals = ArrivalProcess::Trace(vec![
            ServiceArrival::new(
                SimTime::ZERO,
                ModelKind::Alexnet,
                Priority::P0,
                Duration::from_millis(200),
            ),
            ServiceArrival::new(
                SimTime(50_000_000),
                ModelKind::Vgg16,
                Priority::P5,
                Duration::from_millis(100),
            ),
        ]);
        let mut cfg = ChurnConfig::new(1, PlacementPolicy::LeastLoaded, arrivals);
        cfg.capacity = 1;
        let report = run_churn(&cfg, &CompatMatrix::new()).unwrap();
        assert_eq!(report.rejected, 1);
        assert!(report.services[1].rejected);
        assert_eq!(report.services[1].completed, 0);
        assert!(report.services[0].completed > 0);
    }

    #[test]
    fn departures_free_capacity_for_replacement() {
        // Same 1×1 fleet, but the second service arrives after the first
        // departs: both run.
        let arrivals = ArrivalProcess::Trace(vec![
            ServiceArrival::new(
                SimTime::ZERO,
                ModelKind::Alexnet,
                Priority::P0,
                Duration::from_millis(100),
            ),
            ServiceArrival::new(
                SimTime(150_000_000),
                ModelKind::Vgg16,
                Priority::P5,
                Duration::from_millis(100),
            ),
        ]);
        let mut cfg = ChurnConfig::new(1, PlacementPolicy::LeastLoaded, arrivals);
        cfg.capacity = 1;
        let report = run_churn(&cfg, &CompatMatrix::new()).unwrap();
        assert_eq!(report.rejected, 0);
        assert!(report.services[0].completed > 0);
        assert!(report.services[1].completed > 0);
    }

    #[test]
    fn node_fleet_serves_without_faults() {
        // Two federated nodes, 10% loss, no faults scheduled: every
        // session completes on its home node, nobody fails over, and
        // both fleet views see each other alive at shutdown.
        let mut cfg = NodeChurnConfig::new(0x51ee7);
        cfg.nodes = 2;
        cfg.clients = 2;
        cfg.tasks_per_client = 2;
        cfg.kernels_per_task = 3;
        cfg.drop_permille = 100;
        cfg.kernel_pace = StdDuration::from_millis(2);
        let report = run_node_churn(&cfg).unwrap();
        assert_eq!(report.completed, 2, "outcomes: {:?}", report.outcomes);
        assert_eq!(report.shed, 0);
        assert_eq!(report.failovers, 0, "no faults, no failovers");
        for (i, lp) in report.live_peers.iter().enumerate() {
            assert_eq!(*lp, Some(1), "node {i} lost sight of its peer");
        }
    }

    #[test]
    fn fleet_full_register_sheds_explicitly() {
        // Three clients race for a fleet with total capacity two
        // (2 nodes × 1 slot). Whatever order the race resolves in —
        // RetryAfter, or a redirect chain that ping-pongs until the
        // client's redirect-loop bound trips — the loser ends with an
        // explicit `Error::Shed`, never a hang or silent loss.
        let mut cfg = NodeChurnConfig::new(0xf0117);
        cfg.nodes = 2;
        cfg.capacity = 1;
        cfg.clients = 3;
        cfg.tasks_per_client = 2;
        cfg.kernels_per_task = 4;
        cfg.drop_permille = 0;
        cfg.kernel_pace = StdDuration::from_millis(5);
        let report = run_node_churn(&cfg).unwrap();
        assert_eq!(
            (report.completed, report.shed),
            (2, 1),
            "outcomes: {:?}",
            report.outcomes
        );
    }
}
