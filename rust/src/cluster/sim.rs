//! Multi-GPU cluster simulation: place services with a policy, run each
//! GPU's tenant set through the single-GPU FIKIT simulator, and report
//! fleet-wide QoS.

use super::compat::CompatMatrix;
use super::placement::{Placement, PlacementPolicy, ServiceRequest};
use crate::config::{ExperimentConfig, ServiceConfig};
use crate::coordinator::driver::run_experiment;
use crate::coordinator::Mode;
use crate::core::{Priority, Result};
use crate::metrics::{JctStats, TextTable};

/// Cluster experiment description.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub gpus: usize,
    pub policy: PlacementPolicy,
    pub requests: Vec<ServiceRequest>,
    pub mode: Mode,
    pub seed: u64,
}

impl ClusterConfig {
    pub fn new(gpus: usize, policy: PlacementPolicy) -> ClusterConfig {
        ClusterConfig {
            gpus,
            policy,
            requests: Vec::new(),
            mode: Mode::Fikit,
            seed: 0xF1C1,
        }
    }
}

/// Per-service outcome across the cluster.
#[derive(Debug, Clone)]
pub struct ClusterServiceOutcome {
    pub gpu: usize,
    pub model: crate::workload::ModelKind,
    pub priority: Priority,
    pub jct: JctStats,
    /// Mean JCT / solo mean JCT (1.0 = unharmed by sharing).
    pub slowdown: f64,
}

/// Fleet-wide results.
#[derive(Debug)]
pub struct ClusterReport {
    pub placement: Placement,
    pub services: Vec<ClusterServiceOutcome>,
}

impl ClusterReport {
    /// Mean slowdown of high-priority (P0–P2) services — the headline
    /// QoS number a placement policy is judged on.
    pub fn high_priority_slowdown(&self) -> f64 {
        let highs: Vec<f64> = self
            .services
            .iter()
            .filter(|s| (s.priority as u8) <= 2)
            .map(|s| s.slowdown)
            .collect();
        if highs.is_empty() {
            1.0
        } else {
            highs.iter().sum::<f64>() / highs.len() as f64
        }
    }

    /// Worst-case high-priority slowdown (tail QoS).
    pub fn worst_high_priority_slowdown(&self) -> f64 {
        self.services
            .iter()
            .filter(|s| (s.priority as u8) <= 2)
            .map(|s| s.slowdown)
            .fold(1.0, f64::max)
    }

    pub fn summary(&self) -> String {
        let mut t = TextTable::new(&["gpu", "model", "prio", "mean JCT (ms)", "slowdown"]);
        let mut rows: Vec<&ClusterServiceOutcome> = self.services.iter().collect();
        rows.sort_by_key(|s| (s.gpu, s.priority));
        for s in rows {
            t.row(vec![
                s.gpu.to_string(),
                s.model.name().to_string(),
                s.priority.to_string(),
                format!("{:.2}", s.jct.mean_ms()),
                format!("{:.2}x", s.slowdown),
            ]);
        }
        format!(
            "{}mean high-prio slowdown: {:.2}x (worst {:.2}x)\n",
            t.render(),
            self.high_priority_slowdown(),
            self.worst_high_priority_slowdown()
        )
    }
}

/// Run the full cluster experiment: place, then simulate each GPU.
pub fn run_cluster(cfg: &ClusterConfig, compat: &CompatMatrix) -> Result<ClusterReport> {
    let placement = cfg.policy.place(&cfg.requests, cfg.gpus, compat);

    // Solo baselines per distinct model (for slowdown normalization).
    let mut solo_ms: std::collections::BTreeMap<&'static str, f64> = Default::default();
    for req in &cfg.requests {
        let name = req.model.name();
        if !solo_ms.contains_key(name) {
            let mut solo = ExperimentConfig {
                mode: Mode::Sharing,
                seed: cfg.seed,
                ..ExperimentConfig::default()
            };
            solo.services
                .push(ServiceConfig::new(req.model, Priority::P0).tasks(req.tasks.min(50)));
            solo_ms.insert(name, run_experiment(&solo)?.services[0].jct.mean_ms());
        }
    }

    let mut services = Vec::with_capacity(cfg.requests.len());
    for gpu in 0..cfg.gpus {
        let tenant_idxs = placement.on_gpu(gpu);
        if tenant_idxs.is_empty() {
            continue;
        }
        let mut gpu_cfg = ExperimentConfig {
            mode: cfg.mode,
            seed: cfg.seed ^ (gpu as u64) << 32,
            ..ExperimentConfig::default()
        };
        gpu_cfg.measurement.runs = 5;
        for &idx in &tenant_idxs {
            let req = &cfg.requests[idx];
            gpu_cfg.services.push(
                ServiceConfig::new(req.model, req.priority)
                    .tasks(req.tasks)
                    .with_key(&format!("svc{idx}")),
            );
        }
        let report = run_experiment(&gpu_cfg)?;
        for &idx in &tenant_idxs {
            let req = &cfg.requests[idx];
            let svc = report
                .service(&crate::core::TaskKey::new(format!("svc{idx}").as_str()))
                .ok_or_else(|| crate::core::Error::Invariant("missing service".into()))?;
            let solo = solo_ms[req.model.name()];
            services.push(ClusterServiceOutcome {
                gpu,
                model: req.model,
                priority: req.priority,
                jct: svc.jct.clone(),
                slowdown: svc.jct.mean_ms() / solo,
            });
        }
    }
    Ok(ClusterReport {
        placement,
        services,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ModelKind;

    fn requests() -> Vec<ServiceRequest> {
        vec![
            ServiceRequest::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0, 15),
            ServiceRequest::new(ModelKind::FasterrcnnResnet50Fpn, Priority::P0, 15),
            ServiceRequest::new(ModelKind::FcnResnet50, Priority::P5, 15),
            ServiceRequest::new(ModelKind::Resnet101, Priority::P6, 15),
        ]
    }

    #[test]
    fn cluster_runs_and_reports() {
        let mut cfg = ClusterConfig::new(2, PlacementPolicy::BestMatch);
        cfg.requests = requests();
        let report = run_cluster(&cfg, &CompatMatrix::new()).unwrap();
        assert_eq!(report.services.len(), 4);
        assert!(report.high_priority_slowdown() >= 1.0);
        assert!(report.summary().contains("mean high-prio slowdown"));
    }

    #[test]
    fn best_match_no_worse_than_round_robin_on_qos() {
        // The compatibility-aware policy must protect high-priority
        // tenants at least as well as naive spreading for this workload.
        let run = |policy| {
            let mut cfg = ClusterConfig::new(2, policy);
            cfg.requests = requests();
            run_cluster(&cfg, &CompatMatrix::new()).unwrap()
        };
        let bm = run(PlacementPolicy::BestMatch);
        let rr = run(PlacementPolicy::RoundRobin);
        assert!(
            bm.worst_high_priority_slowdown() <= rr.worst_high_priority_slowdown() * 1.1,
            "BestMatch {:.2}x vs RoundRobin {:.2}x",
            bm.worst_high_priority_slowdown(),
            rr.worst_high_priority_slowdown()
        );
    }

    #[test]
    fn empty_gpu_tolerated() {
        let mut cfg = ClusterConfig::new(4, PlacementPolicy::LeastLoaded);
        cfg.requests = vec![ServiceRequest::new(ModelKind::Alexnet, Priority::P0, 5)];
        let report = run_cluster(&cfg, &CompatMatrix::new()).unwrap();
        assert_eq!(report.services.len(), 1);
    }
}
