//! Pairwise interference knowledge: the offline combination-compatibility
//! matrix (paper §5) plus the online-learned [`InterferenceModel`]
//! (ADR-006) built on top of it.
//!
//! For an ordered pair `(high, low)` the [`CompatMatrix`] stores how well
//! the two models share a GPU under FIKIT: the high-priority slowdown vs
//! solo and the low-priority effective throughput. Two ways to obtain it:
//!
//! * [`CompatMatrix::measure`] — run the actual pairwise FIKIT
//!   simulation for every pair, self-pairs included (the paper's
//!   "prepare combinations of potential models and measure"). Expensive
//!   but exact; done offline, persisted as JSON, preloaded by the
//!   placement policy.
//! * [`CompatMatrix::predict`] — a zero-measurement analytic estimate
//!   from the models' profiles alone: the low model fits into the high
//!   model's sync-stall budget proportionally to how many of its kernels
//!   fit the gap sizes. Used when a pair was never measured.
//!
//! Both are *priors*: frozen at load time, blind to the deployment's
//! actual concurrency backend and co-location mix. The
//! [`InterferenceModel`] keeps them as the cold-start estimate and folds
//! in **observed** pairwise dilation online — every harvested completion
//! whose service shared a device attributes its slowdown to the models
//! co-resident at the time (EWMA per ordered `(victim, aggressor)`
//! pair). Placement and the churn QoS scan consult the blended estimate,
//! so eviction targets the *predicted worst aggressor* instead of the
//! currently-noisiest victim (DESIGN.md §8). Storage is dense
//! `ModelKind::COUNT²` arrays: lookups and updates are plain indexed
//! reads/writes — no hashing, no allocation — because the placement scan
//! performs O(residents²) of them per decision.

use crate::config::{ExperimentConfig, ServiceConfig};
use crate::coordinator::driver::run_experiment;
use crate::coordinator::Mode;
use crate::core::{Error, Priority, Result};
use crate::util::json::Json;
use crate::workload::ModelKind;
use std::path::Path;

/// Number of models — the dense table dimension.
const N: usize = ModelKind::COUNT;

/// Compatibility of one ordered (high, low) pair.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CompatEntry {
    /// High-priority JCT under FIKIT sharing / solo JCT (≥1; closer to 1
    /// is better).
    pub high_slowdown: f64,
    /// Low-priority throughput under FIKIT sharing relative to solo
    /// (0..1; higher = more scavenged idle time).
    pub low_throughput: f64,
}

impl CompatEntry {
    /// Scalar goodness used for placement ranking: protect the
    /// high-priority tenant first, then reward background throughput.
    pub fn score(&self) -> f64 {
        // slowdown 1.0 → 1.0; 2.0 → 0.5. Background throughput worth
        // up to +0.5.
        (1.0 / self.high_slowdown) + 0.5 * self.low_throughput
    }
}

/// The preloaded pairwise matrix, keyed by (high model, low model) —
/// stored densely by [`ModelKind::index`] so a lookup is two array
/// indexes, not two `String` allocations (the placement scan does
/// O(residents²) lookups per decision).
#[derive(Debug, Clone)]
pub struct CompatMatrix {
    entries: [[Option<CompatEntry>; N]; N],
}

impl Default for CompatMatrix {
    fn default() -> CompatMatrix {
        CompatMatrix {
            entries: [[None; N]; N],
        }
    }
}

impl CompatMatrix {
    pub fn new() -> CompatMatrix {
        CompatMatrix::default()
    }

    /// Number of measured (stored) pairs.
    pub fn len(&self) -> usize {
        self.entries
            .iter()
            .flat_map(|row| row.iter())
            .filter(|e| e.is_some())
            .count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn insert(&mut self, high: ModelKind, low: ModelKind, entry: CompatEntry) {
        self.entries[high.index()][low.index()] = Some(entry);
    }

    /// The stored entry alone — `None` when the pair was never measured
    /// (loaded). Lets callers distinguish measurement from prediction.
    pub fn lookup(&self, high: ModelKind, low: ModelKind) -> Option<CompatEntry> {
        self.entries[high.index()][low.index()]
    }

    /// Look up a measured entry; falls back to the analytic prediction.
    pub fn get(&self, high: ModelKind, low: ModelKind) -> CompatEntry {
        self.entries[high.index()][low.index()]
            .unwrap_or_else(|| Self::predict(high, low))
    }

    /// Analytic prediction from model structure only (no measurement):
    /// the low model's mean kernel must fit the high model's typical
    /// stall to be fillable; the high model suffers in proportion to the
    /// low model's launch-ahead backlog relative to its own stall budget.
    pub fn predict(high: ModelKind, low: ModelKind) -> CompatEntry {
        let h = high.spec();
        let l = low.spec();
        // Typical fillable stall of the high model.
        let stalls = h.sync_points().max(1) as f64;
        let mean_stall_us = h.mean_sync_gap().as_micros_f64() / stalls;
        // Mean kernel size of the low model.
        let mean_low_kernel_us =
            l.mean_exec().as_micros_f64() / l.kernel_count().max(1) as f64;
        // Fillability: how many low kernels fit one stall (saturating).
        let fits = if mean_low_kernel_us <= 0.0 {
            0.0
        } else {
            (mean_stall_us / mean_low_kernel_us).min(50.0)
        };
        let fillable_us = (fits * mean_low_kernel_us * stalls)
            .min(h.mean_sync_gap().as_micros_f64());
        let low_throughput = (fillable_us / l.mean_jct().as_micros_f64().max(1.0)).min(1.0);
        // High-priority pain: overhead-2 style — the expected residual of
        // one low kernel per stall, plus task-entry backlog pressure from
        // dense co-tenants.
        let overhead2_us = stalls * (mean_low_kernel_us / 2.0);
        let backlog_pressure = l.mean_exec().as_micros_f64()
            / (l.mean_jct().as_micros_f64().max(1.0))
            * 0.1
            * h.mean_jct().as_micros_f64();
        let high_slowdown =
            1.0 + (overhead2_us + backlog_pressure) / h.mean_jct().as_micros_f64().max(1.0);
        CompatEntry {
            high_slowdown,
            low_throughput,
        }
    }

    /// Measure one pair by running the actual FIKIT simulation (solo
    /// baselines + shared run). `high == low` is a valid pair: two
    /// instances of the same model sharing a device — common in real
    /// fleets — measured exactly like a heterogeneous pair.
    pub fn measure_pair(
        high: ModelKind,
        low: ModelKind,
        tasks: u32,
        seed: u64,
    ) -> Result<CompatEntry> {
        let solo = |model: ModelKind| -> Result<f64> {
            let mut cfg = ExperimentConfig {
                mode: Mode::Sharing,
                seed,
                ..ExperimentConfig::default()
            };
            cfg.services
                .push(ServiceConfig::new(model, Priority::P0).tasks(tasks));
            Ok(run_experiment(&cfg)?.services[0].jct.mean_ms())
        };
        let high_solo = solo(high)?;
        let low_solo = solo(low)?;

        let mut cfg = ExperimentConfig {
            mode: Mode::Fikit,
            seed,
            ..ExperimentConfig::default()
        };
        cfg.measurement.runs = 5;
        cfg.services
            .push(ServiceConfig::new(high, Priority::P0).tasks(tasks).with_key("h"));
        cfg.services
            .push(ServiceConfig::new(low, Priority::P4).tasks(tasks).with_key("l"));
        let shared = run_experiment(&cfg)?;
        let h_shared = shared
            .service(&crate::core::TaskKey::new("h"))
            .map(|s| s.jct.mean_ms())
            .unwrap_or(f64::NAN);
        let l_shared = shared
            .service(&crate::core::TaskKey::new("l"))
            .map(|s| s.jct.mean_ms())
            .unwrap_or(f64::NAN);
        Ok(CompatEntry {
            high_slowdown: (h_shared / high_solo).max(1.0),
            low_throughput: (low_solo / l_shared).clamp(0.0, 1.0),
        })
    }

    /// Measure every ordered pair from `models` — including self-pairs,
    /// so homogeneous co-location gets a measured entry instead of
    /// silently falling back to [`CompatMatrix::predict`] (the offline
    /// campaign).
    pub fn measure(models: &[ModelKind], tasks: u32, seed: u64) -> Result<CompatMatrix> {
        let mut m = CompatMatrix::new();
        for &high in models {
            for &low in models {
                m.insert(high, low, Self::measure_pair(high, low, tasks, seed)?);
            }
        }
        Ok(m)
    }

    // ----- persistence -----

    pub fn to_json(&self) -> Json {
        let mut arr = Vec::with_capacity(self.len());
        for high in ModelKind::ALL {
            for low in ModelKind::ALL {
                if let Some(e) = self.entries[high.index()][low.index()] {
                    arr.push(
                        Json::obj()
                            .set("high", high.name())
                            .set("low", low.name())
                            .set("high_slowdown", e.high_slowdown)
                            .set("low_throughput", e.low_throughput),
                    );
                }
            }
        }
        Json::obj().set("version", 1u64).set("pairs", Json::Arr(arr))
    }

    pub fn from_json(v: &Json) -> Result<CompatMatrix> {
        let mut m = CompatMatrix::new();
        for p in v.req_arr("pairs")? {
            let high: ModelKind = p.req_str("high")?.parse()?;
            let low: ModelKind = p.req_str("low")?.parse()?;
            m.insert(
                high,
                low,
                CompatEntry {
                    high_slowdown: p.req_f64("high_slowdown")?,
                    low_throughput: p.req_f64("low_throughput")?,
                },
            );
        }
        Ok(m)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json().encode_pretty())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<CompatMatrix> {
        let text = std::fs::read_to_string(path.as_ref())?;
        CompatMatrix::from_json(&Json::parse(&text)?)
    }
}

/// EWMA smoothing for observed pairwise dilation. Deliberately heavier
/// than the profile refiner's per-kernel alpha: co-residency attribution
/// is noisy (every co-resident shares the blame for one observation), so
/// the estimate should turn over in tens of completions, not units.
pub const DEFAULT_INTERFERENCE_ALPHA: f64 = 0.2;

/// Prior pseudo-count: the blend weight of the offline prior against `n`
/// online observations is `prior_weight / (n + prior_weight)`. Four
/// observations already outvote the prior.
const PRIOR_WEIGHT: f64 = 4.0;

/// The learned interference model (ADR-006): offline priors resolved
/// densely at construction, plus an online EWMA **dilation** estimate
/// per ordered `(victim, aggressor)` model pair, fed by co-residency
/// attribution — when a completed task's slowdown is harvested, every
/// model co-resident on its device is charged with that slowdown.
///
/// Lookups ([`InterferenceModel::high_slowdown`],
/// [`InterferenceModel::score`]) blend the learned estimate with the
/// prior by sample count, so an unobserved pair behaves exactly like the
/// static matrix and a well-observed pair reflects the deployment's
/// actual backend and mix. Every path — observe and lookup — is flat
/// array arithmetic: allocation-free in steady state (gated by
/// `tests/hotpath_alloc.rs`).
#[derive(Debug, Clone)]
pub struct InterferenceModel {
    priors: CompatMatrix,
    /// Priors resolved through measured-else-predicted once, so steady-
    /// state lookups never re-run the analytic predictor.
    prior_slowdown: [[f64; N]; N],
    prior_throughput: [[f64; N]; N],
    /// EWMA of observed victim slowdown per (victim, aggressor) pair.
    dilation: [[f64; N]; N],
    samples: [[u32; N]; N],
    alpha: f64,
    /// Interference epoch: version counter of the learned estimates,
    /// bumped once per folded observation. Consumers can cheaply detect
    /// "the model moved since I last ranked placements".
    epoch: u64,
}

impl Default for InterferenceModel {
    fn default() -> InterferenceModel {
        InterferenceModel::with_priors(CompatMatrix::new())
    }
}

impl InterferenceModel {
    /// Build from offline priors (measured matrix or empty → analytic
    /// predictions). The prior tables are resolved once, here.
    pub fn with_priors(priors: CompatMatrix) -> InterferenceModel {
        let mut prior_slowdown = [[1.0; N]; N];
        let mut prior_throughput = [[0.0; N]; N];
        for high in ModelKind::ALL {
            for low in ModelKind::ALL {
                let e = priors.get(high, low);
                prior_slowdown[high.index()][low.index()] = e.high_slowdown;
                prior_throughput[high.index()][low.index()] = e.low_throughput;
            }
        }
        InterferenceModel {
            priors,
            prior_slowdown,
            prior_throughput,
            dilation: [[1.0; N]; N],
            samples: [[0; N]; N],
            alpha: DEFAULT_INTERFERENCE_ALPHA,
            epoch: 0,
        }
    }

    /// The offline priors this model was built from.
    pub fn priors(&self) -> &CompatMatrix {
        &self.priors
    }

    /// Fold one co-residency observation: `victim`'s task completed with
    /// `slowdown` (JCT / solo JCT) while `aggressor` was resident on the
    /// same device. Allocation-free: two array writes and an EWMA step.
    pub fn observe(&mut self, victim: ModelKind, aggressor: ModelKind, slowdown: f64) {
        if !slowdown.is_finite() || slowdown <= 0.0 {
            return; // defensive: never poison the estimate
        }
        let (v, a) = (victim.index(), aggressor.index());
        let n = self.samples[v][a];
        if n == 0 {
            // First observation seeds the EWMA instead of decaying from
            // the 1.0 placeholder.
            self.dilation[v][a] = slowdown;
        } else {
            self.dilation[v][a] += self.alpha * (slowdown - self.dilation[v][a]);
        }
        self.samples[v][a] = n.saturating_add(1);
        self.epoch += 1;
    }

    /// Blended high-priority slowdown estimate for `high` hosted next to
    /// `low`: the offline prior when the pair was never observed, the
    /// learned EWMA once observations dominate (`n / (n + 4)` weight).
    pub fn high_slowdown(&self, high: ModelKind, low: ModelKind) -> f64 {
        let (h, l) = (high.index(), low.index());
        let n = self.samples[h][l] as f64;
        if n == 0.0 {
            return self.prior_slowdown[h][l];
        }
        let w = n / (n + PRIOR_WEIGHT);
        w * self.dilation[h][l] + (1.0 - w) * self.prior_slowdown[h][l]
    }

    /// Placement-ranking score for hosting `low` next to `high` — the
    /// [`CompatEntry::score`] shape with the learned slowdown blended in
    /// (throughput stays a prior: the online signal observes harm, not
    /// scavenged progress).
    pub fn score(&self, high: ModelKind, low: ModelKind) -> f64 {
        (1.0 / self.high_slowdown(high, low))
            + 0.5 * self.prior_throughput[high.index()][low.index()]
    }

    /// The raw learned estimate, if any: `(EWMA dilation, samples)`.
    pub fn learned(&self, victim: ModelKind, aggressor: ModelKind) -> Option<(f64, u32)> {
        let (v, a) = (victim.index(), aggressor.index());
        match self.samples[v][a] {
            0 => None,
            n => Some((self.dilation[v][a], n)),
        }
    }

    /// Total observations folded so far.
    pub fn observations(&self) -> u64 {
        self.epoch
    }

    /// Current interference epoch (see the field docs).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    // ----- persistence -----

    /// Versioned JSON image: priors plus every learned pair.
    pub fn to_json(&self) -> Json {
        let mut learned = Vec::new();
        for victim in ModelKind::ALL {
            for aggressor in ModelKind::ALL {
                let (v, a) = (victim.index(), aggressor.index());
                if self.samples[v][a] > 0 {
                    learned.push(
                        Json::obj()
                            .set("victim", victim.name())
                            .set("aggressor", aggressor.name())
                            .set("dilation", self.dilation[v][a])
                            .set("samples", self.samples[v][a] as u64),
                    );
                }
            }
        }
        Json::obj()
            .set("version", 2u64)
            .set("alpha", self.alpha)
            .set("epoch", self.epoch)
            .set("priors", self.priors.to_json())
            .set("learned", Json::Arr(learned))
    }

    pub fn from_json(v: &Json) -> Result<InterferenceModel> {
        let version = v.req_u64("version")?;
        if version != 2 {
            return Err(Error::Parse(format!(
                "interference model version {version} is not supported (want 2)"
            )));
        }
        let priors = CompatMatrix::from_json(v.require("priors")?)?;
        let mut model = InterferenceModel::with_priors(priors);
        model.alpha = v.req_f64("alpha")?;
        model.epoch = v.req_u64("epoch")?;
        for p in v.req_arr("learned")? {
            let victim: ModelKind = p.req_str("victim")?.parse()?;
            let aggressor: ModelKind = p.req_str("aggressor")?.parse()?;
            let (vi, ai) = (victim.index(), aggressor.index());
            model.dilation[vi][ai] = p.req_f64("dilation")?;
            model.samples[vi][ai] = p.req_u64("samples")? as u32;
        }
        Ok(model)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json().encode_pretty())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<InterferenceModel> {
        let text = std::fs::read_to_string(path.as_ref())?;
        InterferenceModel::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prediction_prefers_gappy_hosts_and_small_fillers() {
        // A gappy detector hosts background work well…
        let good = CompatMatrix::predict(
            ModelKind::KeypointRcnnResnet50Fpn,
            ModelKind::FcnResnet50,
        );
        // …a dense classifier has almost nothing to give.
        let bad = CompatMatrix::predict(ModelKind::Vgg16, ModelKind::Resnet101);
        assert!(good.low_throughput > bad.low_throughput);
        assert!(good.score() > bad.score());
        assert!(good.high_slowdown >= 1.0 && bad.high_slowdown >= 1.0);
    }

    #[test]
    fn measured_pair_matches_expectations() {
        let e = CompatMatrix::measure_pair(
            ModelKind::KeypointRcnnResnet50Fpn,
            ModelKind::FcnResnet50,
            8,
            7,
        )
        .unwrap();
        assert!(e.high_slowdown < 1.5, "high barely slowed: {e:?}");
        assert!(e.low_throughput > 0.1, "low makes progress: {e:?}");
    }

    #[test]
    fn measure_includes_self_pairs() {
        // Homogeneous co-location is common in fleets; the campaign must
        // produce a *measured* self-pair entry, not a predict() fallback.
        let m = CompatMatrix::measure(&[ModelKind::Alexnet], 3, 11).unwrap();
        assert_eq!(m.len(), 1);
        assert!(
            m.lookup(ModelKind::Alexnet, ModelKind::Alexnet).is_some(),
            "self-pair was skipped — homogeneous placement would silently \
             fall back to prediction"
        );
    }

    #[test]
    fn matrix_persistence_round_trip() {
        let mut m = CompatMatrix::new();
        m.insert(
            ModelKind::Alexnet,
            ModelKind::Vgg16,
            CompatEntry {
                high_slowdown: 1.07,
                low_throughput: 0.42,
            },
        );
        let dir = std::env::temp_dir().join(format!("fikit-compat-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("compat.json");
        m.save(&path).unwrap();
        let loaded = CompatMatrix::load(&path).unwrap();
        assert_eq!(loaded.len(), 1);
        let e = loaded.get(ModelKind::Alexnet, ModelKind::Vgg16);
        assert!((e.high_slowdown - 1.07).abs() < 1e-9);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn get_falls_back_to_prediction() {
        let m = CompatMatrix::new();
        let e = m.get(ModelKind::Alexnet, ModelKind::Vgg16);
        assert_eq!(e, CompatMatrix::predict(ModelKind::Alexnet, ModelKind::Vgg16));
        assert!(m.lookup(ModelKind::Alexnet, ModelKind::Vgg16).is_none());
    }

    #[test]
    fn unobserved_model_equals_priors() {
        let mut priors = CompatMatrix::new();
        priors.insert(
            ModelKind::Vgg16,
            ModelKind::Alexnet,
            CompatEntry {
                high_slowdown: 1.33,
                low_throughput: 0.2,
            },
        );
        let model = InterferenceModel::with_priors(priors.clone());
        for high in ModelKind::ALL {
            for low in ModelKind::ALL {
                let prior = priors.get(high, low);
                assert_eq!(model.high_slowdown(high, low), prior.high_slowdown);
                assert!((model.score(high, low) - prior.score()).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn observations_pull_estimate_off_the_prior() {
        let mut model = InterferenceModel::default();
        let (v, a) = (ModelKind::KeypointRcnnResnet50Fpn, ModelKind::Googlenet);
        let prior = model.high_slowdown(v, a);
        for _ in 0..32 {
            model.observe(v, a, 3.0);
        }
        let learned = model.high_slowdown(v, a);
        assert!(
            learned > prior && learned > 2.5,
            "32 consistent observations of 3.0 must dominate the prior \
             (prior {prior:.3}, got {learned:.3})"
        );
        // An untouched pair is still pure prior.
        let other = (ModelKind::Vgg16, ModelKind::Alexnet);
        assert_eq!(
            model.high_slowdown(other.0, other.1),
            InterferenceModel::default().high_slowdown(other.0, other.1)
        );
        assert_eq!(model.observations(), 32);
    }

    #[test]
    fn degenerate_observations_are_dropped() {
        let mut model = InterferenceModel::default();
        let (v, a) = (ModelKind::Vgg16, ModelKind::Alexnet);
        model.observe(v, a, f64::NAN);
        model.observe(v, a, f64::INFINITY);
        model.observe(v, a, -2.0);
        model.observe(v, a, 0.0);
        assert_eq!(model.learned(v, a), None);
        assert_eq!(model.epoch(), 0);
    }

    #[test]
    fn model_persistence_round_trip() {
        let mut priors = CompatMatrix::new();
        priors.insert(
            ModelKind::Alexnet,
            ModelKind::Vgg16,
            CompatEntry {
                high_slowdown: 1.07,
                low_throughput: 0.42,
            },
        );
        let mut model = InterferenceModel::with_priors(priors);
        for _ in 0..10 {
            model.observe(ModelKind::Alexnet, ModelKind::Googlenet, 2.5);
        }
        let dir =
            std::env::temp_dir().join(format!("fikit-interference-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.json");
        model.save(&path).unwrap();
        let loaded = InterferenceModel::load(&path).unwrap();
        assert_eq!(loaded.epoch(), model.epoch());
        assert_eq!(
            loaded.learned(ModelKind::Alexnet, ModelKind::Googlenet),
            model.learned(ModelKind::Alexnet, ModelKind::Googlenet)
        );
        assert_eq!(
            loaded.high_slowdown(ModelKind::Alexnet, ModelKind::Vgg16),
            model.high_slowdown(ModelKind::Alexnet, ModelKind::Vgg16)
        );
        // Bad version fails loudly.
        let doc = model.to_json().set("version", 3u64);
        assert!(InterferenceModel::from_json(&doc).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
