//! The pairwise combination-compatibility matrix (paper §5).
//!
//! For an ordered pair `(high, low)` the matrix stores how well the two
//! models share a GPU under FIKIT: the high-priority slowdown vs solo
//! and the low-priority effective throughput. Two ways to obtain it:
//!
//! * [`CompatMatrix::measure`] — run the actual pairwise FIKIT
//!   simulation for every pair (the paper's "prepare combinations of
//!   potential models and measure"). Expensive but exact; done offline,
//!   persisted as JSON, preloaded by the placement policy.
//! * [`CompatMatrix::predict`] — a zero-measurement analytic estimate
//!   from the models' profiles alone: the low model fits into the high
//!   model's sync-stall budget proportionally to how many of its kernels
//!   fit the gap sizes. Used when a pair was never measured.

use crate::config::{ExperimentConfig, ServiceConfig};
use crate::coordinator::driver::run_experiment;
use crate::coordinator::Mode;
use crate::core::{Priority, Result};
use crate::util::json::Json;
use crate::workload::ModelKind;
use std::collections::BTreeMap;
use std::path::Path;

/// Compatibility of one ordered (high, low) pair.
#[derive(Debug, Clone, PartialEq)]
pub struct CompatEntry {
    /// High-priority JCT under FIKIT sharing / solo JCT (≥1; closer to 1
    /// is better).
    pub high_slowdown: f64,
    /// Low-priority throughput under FIKIT sharing relative to solo
    /// (0..1; higher = more scavenged idle time).
    pub low_throughput: f64,
}

impl CompatEntry {
    /// Scalar goodness used for placement ranking: protect the
    /// high-priority tenant first, then reward background throughput.
    pub fn score(&self) -> f64 {
        // slowdown 1.0 → 1.0; 2.0 → 0.5. Background throughput worth
        // up to +0.5.
        (1.0 / self.high_slowdown) + 0.5 * self.low_throughput
    }
}

/// The preloaded pairwise matrix, keyed by (high model, low model).
#[derive(Debug, Clone, Default)]
pub struct CompatMatrix {
    entries: BTreeMap<(String, String), CompatEntry>,
}

impl CompatMatrix {
    pub fn new() -> CompatMatrix {
        CompatMatrix::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn insert(&mut self, high: ModelKind, low: ModelKind, entry: CompatEntry) {
        self.entries
            .insert((high.name().to_string(), low.name().to_string()), entry);
    }

    /// Look up a measured entry; falls back to the analytic prediction.
    pub fn get(&self, high: ModelKind, low: ModelKind) -> CompatEntry {
        self.entries
            .get(&(high.name().to_string(), low.name().to_string()))
            .cloned()
            .unwrap_or_else(|| Self::predict(high, low))
    }

    /// Analytic prediction from model structure only (no measurement):
    /// the low model's mean kernel must fit the high model's typical
    /// stall to be fillable; the high model suffers in proportion to the
    /// low model's launch-ahead backlog relative to its own stall budget.
    pub fn predict(high: ModelKind, low: ModelKind) -> CompatEntry {
        let h = high.spec();
        let l = low.spec();
        // Typical fillable stall of the high model.
        let stalls = h.sync_points().max(1) as f64;
        let mean_stall_us = h.mean_sync_gap().as_micros_f64() / stalls;
        // Mean kernel size of the low model.
        let mean_low_kernel_us =
            l.mean_exec().as_micros_f64() / l.kernel_count().max(1) as f64;
        // Fillability: how many low kernels fit one stall (saturating).
        let fits = if mean_low_kernel_us <= 0.0 {
            0.0
        } else {
            (mean_stall_us / mean_low_kernel_us).min(50.0)
        };
        let fillable_us = (fits * mean_low_kernel_us * stalls)
            .min(h.mean_sync_gap().as_micros_f64());
        let low_throughput = (fillable_us / l.mean_jct().as_micros_f64().max(1.0)).min(1.0);
        // High-priority pain: overhead-2 style — the expected residual of
        // one low kernel per stall, plus task-entry backlog pressure from
        // dense co-tenants.
        let overhead2_us = stalls * (mean_low_kernel_us / 2.0);
        let backlog_pressure = l.mean_exec().as_micros_f64()
            / (l.mean_jct().as_micros_f64().max(1.0))
            * 0.1
            * h.mean_jct().as_micros_f64();
        let high_slowdown =
            1.0 + (overhead2_us + backlog_pressure) / h.mean_jct().as_micros_f64().max(1.0);
        CompatEntry {
            high_slowdown,
            low_throughput,
        }
    }

    /// Measure one pair by running the actual FIKIT simulation (solo
    /// baselines + shared run).
    pub fn measure_pair(
        high: ModelKind,
        low: ModelKind,
        tasks: u32,
        seed: u64,
    ) -> Result<CompatEntry> {
        let solo = |model: ModelKind| -> Result<f64> {
            let mut cfg = ExperimentConfig {
                mode: Mode::Sharing,
                seed,
                ..ExperimentConfig::default()
            };
            cfg.services
                .push(ServiceConfig::new(model, Priority::P0).tasks(tasks));
            Ok(run_experiment(&cfg)?.services[0].jct.mean_ms())
        };
        let high_solo = solo(high)?;
        let low_solo = solo(low)?;

        let mut cfg = ExperimentConfig {
            mode: Mode::Fikit,
            seed,
            ..ExperimentConfig::default()
        };
        cfg.measurement.runs = 5;
        cfg.services
            .push(ServiceConfig::new(high, Priority::P0).tasks(tasks).with_key("h"));
        cfg.services
            .push(ServiceConfig::new(low, Priority::P4).tasks(tasks).with_key("l"));
        let shared = run_experiment(&cfg)?;
        let h_shared = shared
            .service(&crate::core::TaskKey::new("h"))
            .map(|s| s.jct.mean_ms())
            .unwrap_or(f64::NAN);
        let l_shared = shared
            .service(&crate::core::TaskKey::new("l"))
            .map(|s| s.jct.mean_ms())
            .unwrap_or(f64::NAN);
        Ok(CompatEntry {
            high_slowdown: (h_shared / high_solo).max(1.0),
            low_throughput: (low_solo / l_shared).clamp(0.0, 1.0),
        })
    }

    /// Measure every ordered pair from `models` (the offline campaign).
    pub fn measure(models: &[ModelKind], tasks: u32, seed: u64) -> Result<CompatMatrix> {
        let mut m = CompatMatrix::new();
        for &high in models {
            for &low in models {
                if high == low {
                    continue;
                }
                m.insert(high, low, Self::measure_pair(high, low, tasks, seed)?);
            }
        }
        Ok(m)
    }

    // ----- persistence -----

    pub fn to_json(&self) -> Json {
        let mut arr = Vec::with_capacity(self.entries.len());
        for ((h, l), e) in &self.entries {
            arr.push(
                Json::obj()
                    .set("high", h.as_str())
                    .set("low", l.as_str())
                    .set("high_slowdown", e.high_slowdown)
                    .set("low_throughput", e.low_throughput),
            );
        }
        Json::obj().set("version", 1u64).set("pairs", Json::Arr(arr))
    }

    pub fn from_json(v: &Json) -> Result<CompatMatrix> {
        let mut m = CompatMatrix::new();
        for p in v.req_arr("pairs")? {
            let high: ModelKind = p.req_str("high")?.parse()?;
            let low: ModelKind = p.req_str("low")?.parse()?;
            m.insert(
                high,
                low,
                CompatEntry {
                    high_slowdown: p.req_f64("high_slowdown")?,
                    low_throughput: p.req_f64("low_throughput")?,
                },
            );
        }
        Ok(m)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json().encode_pretty())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<CompatMatrix> {
        let text = std::fs::read_to_string(path.as_ref())?;
        CompatMatrix::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prediction_prefers_gappy_hosts_and_small_fillers() {
        // A gappy detector hosts background work well…
        let good = CompatMatrix::predict(
            ModelKind::KeypointRcnnResnet50Fpn,
            ModelKind::FcnResnet50,
        );
        // …a dense classifier has almost nothing to give.
        let bad = CompatMatrix::predict(ModelKind::Vgg16, ModelKind::Resnet101);
        assert!(good.low_throughput > bad.low_throughput);
        assert!(good.score() > bad.score());
        assert!(good.high_slowdown >= 1.0 && bad.high_slowdown >= 1.0);
    }

    #[test]
    fn measured_pair_matches_expectations() {
        let e = CompatMatrix::measure_pair(
            ModelKind::KeypointRcnnResnet50Fpn,
            ModelKind::FcnResnet50,
            8,
            7,
        )
        .unwrap();
        assert!(e.high_slowdown < 1.5, "high barely slowed: {e:?}");
        assert!(e.low_throughput > 0.1, "low makes progress: {e:?}");
    }

    #[test]
    fn matrix_persistence_round_trip() {
        let mut m = CompatMatrix::new();
        m.insert(
            ModelKind::Alexnet,
            ModelKind::Vgg16,
            CompatEntry {
                high_slowdown: 1.07,
                low_throughput: 0.42,
            },
        );
        let dir = std::env::temp_dir().join(format!("fikit-compat-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("compat.json");
        m.save(&path).unwrap();
        let loaded = CompatMatrix::load(&path).unwrap();
        assert_eq!(loaded.len(), 1);
        let e = loaded.get(ModelKind::Alexnet, ModelKind::Vgg16);
        assert!((e.high_slowdown - 1.07).abs() < 1e-9);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn get_falls_back_to_prediction() {
        let m = CompatMatrix::new();
        let e = m.get(ModelKind::Alexnet, ModelKind::Vgg16);
        assert_eq!(e, CompatMatrix::predict(ModelKind::Alexnet, ModelKind::Vgg16));
    }
}
