//! Placement policies: which GPU should a newly arriving service land
//! on? (Paper §5: "when a task request arrives, the policy finds the GPU
//! on which its optimal matching task resides using the preloaded
//! measurement data".)
//!
//! Two API layers:
//!
//! * [`FleetState`] — the **incremental** interface a live cluster uses:
//!   place one service at a time into the current resident set, evict a
//!   departing service, and pick migration targets. Capacity-aware — a
//!   device never hosts more than its configured number of services.
//! * [`PlacementPolicy::place`] — the one-shot batch interface (all
//!   requests known up front); it is a thin loop over the incremental
//!   path, so both layers share one scoring implementation
//!   (DESIGN.md §8).
//!
//! Both layers score pairs through the [`InterferenceModel`] (ADR-006):
//! offline compatibility priors blended with online-learned pairwise
//! dilation, so a model with zero observations ranks exactly like the
//! static matrix did and a learning deployment steers placement by what
//! it has actually seen.

use super::compat::InterferenceModel;
use crate::core::Priority;
use crate::metrics::fleet::is_high_priority;
use crate::workload::ModelKind;

/// A service asking to be placed.
#[derive(Debug, Clone)]
pub struct ServiceRequest {
    /// Model the service runs.
    pub model: ModelKind,
    /// Task priority (P0 highest).
    pub priority: Priority,
    /// Back-to-back tasks the service will issue.
    pub tasks: u32,
}

impl ServiceRequest {
    /// Convenience constructor.
    pub fn new(model: ModelKind, priority: Priority, tasks: u32) -> ServiceRequest {
        ServiceRequest {
            model,
            priority,
            tasks,
        }
    }
}

/// A placement decision: service index → GPU index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    /// `assignments[i]` is the GPU hosting request `i`.
    pub assignments: Vec<usize>,
    /// Number of devices placed onto.
    pub gpus: usize,
}

impl Placement {
    /// Services assigned to one GPU.
    pub fn on_gpu(&self, gpu: usize) -> Vec<usize> {
        self.assignments
            .iter()
            .enumerate()
            .filter(|(_, g)| **g == gpu)
            .map(|(i, _)| i)
            .collect()
    }
}

/// Available placement policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Spread by arrival order, ignoring workloads (the naive k8s
    /// default).
    RoundRobin,
    /// Place each service on the GPU with the least total device-time
    /// demand so far (classic load balancing, workload-blind).
    LeastLoaded,
    /// The paper's proposal: place each service where the pairwise
    /// compatibility with the residents is best — high-priority services
    /// seek gappy low-priority residents to scavenge; low-priority
    /// services seek gappy high-priority hosts.
    BestMatch,
}

impl std::str::FromStr for PlacementPolicy {
    type Err = crate::core::Error;
    fn from_str(s: &str) -> crate::core::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "roundrobin" | "round-robin" | "rr" => Ok(PlacementPolicy::RoundRobin),
            "leastloaded" | "least-loaded" | "ll" => Ok(PlacementPolicy::LeastLoaded),
            "bestmatch" | "best-match" | "bm" => Ok(PlacementPolicy::BestMatch),
            other => Err(crate::core::Error::Parse(format!(
                "unknown placement policy {other:?}"
            ))),
        }
    }
}

/// One service currently resident on a GPU (the incremental-placement
/// view of a live fleet).
#[derive(Debug, Clone)]
pub struct Resident {
    /// Cluster-unique service instance id.
    pub id: u64,
    /// Model the service runs.
    pub model: ModelKind,
    /// Priority of its tasks.
    pub priority: Priority,
    /// Device-time demand used for load accounting, in milliseconds.
    /// Batch placement uses total demand (`mean_exec × tasks`); the churn
    /// loop uses per-task demand since lifetimes are open-ended.
    pub demand_ms: f64,
}

impl Resident {
    /// A resident with per-task demand derived from the model spec.
    pub fn per_task(id: u64, model: ModelKind, priority: Priority) -> Resident {
        Resident {
            id,
            model,
            priority,
            demand_ms: model.spec().mean_exec().as_millis_f64(),
        }
    }
}

/// Live per-GPU occupancy: the mutable state behind incremental
/// place / evict / migrate decisions.
#[derive(Debug, Clone)]
pub struct FleetState {
    capacity: usize,
    residents: Vec<Vec<Resident>>,
    load_ms: Vec<f64>,
    /// RoundRobin cursor (next GPU to try).
    rr_next: usize,
}

impl FleetState {
    /// An empty fleet of `gpus` devices, each hosting at most `capacity`
    /// concurrent services.
    pub fn new(gpus: usize, capacity: usize) -> FleetState {
        assert!(gpus > 0, "cluster has no GPUs");
        assert!(capacity > 0, "GPU capacity must be at least 1");
        FleetState {
            capacity,
            residents: vec![Vec::new(); gpus],
            load_ms: vec![0.0; gpus],
            rr_next: 0,
        }
    }

    /// Number of devices.
    pub fn gpus(&self) -> usize {
        self.residents.len()
    }

    /// Per-device service capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Services currently resident on `gpu`.
    pub fn residents_on(&self, gpu: usize) -> &[Resident] {
        &self.residents[gpu]
    }

    /// Accumulated demand on `gpu` in milliseconds.
    pub fn load_ms(&self, gpu: usize) -> f64 {
        self.load_ms[gpu]
    }

    /// Total residents across the fleet.
    pub fn total_residents(&self) -> usize {
        self.residents.iter().map(Vec::len).sum()
    }

    /// Whether `gpu` can take one more service.
    pub fn has_room(&self, gpu: usize) -> bool {
        self.residents[gpu].len() < self.capacity
    }

    /// Whether every device is at capacity — the saturation probe behind
    /// the scheduler daemon's `Register` rejections (DESIGN.md §Daemon);
    /// also useful for back-pressure telemetry.
    pub fn is_full(&self) -> bool {
        (0..self.gpus()).all(|g| !self.has_room(g))
    }

    /// The GPU hosting service `id`, if it is resident anywhere.
    pub fn gpu_of(&self, id: u64) -> Option<usize> {
        self.residents
            .iter()
            .position(|rs| rs.iter().any(|r| r.id == id))
    }

    /// Place one arriving service per `policy`. Returns the chosen GPU,
    /// or `None` if every device is at capacity (the caller queues or
    /// rejects the request).
    pub fn place(
        &mut self,
        policy: PlacementPolicy,
        resident: Resident,
        model: &InterferenceModel,
    ) -> Option<usize> {
        let gpu = self.pick(policy, &resident, model, None)?;
        self.insert(gpu, resident);
        Some(gpu)
    }

    /// Update a resident's model/priority/demand **in place** (it keeps
    /// its device): the re-registration path, where a service announces
    /// new parameters but must not be re-placed mid-life — its
    /// scheduling state lives on its current device. Load accounting is
    /// adjusted by the demand delta. Returns `false` if the id is
    /// unknown.
    pub fn requalify(
        &mut self,
        id: u64,
        model: ModelKind,
        priority: Priority,
        demand_ms: f64,
    ) -> bool {
        let Some(gpu) = self.gpu_of(id) else {
            return false;
        };
        let r = self.residents[gpu]
            .iter_mut()
            .find(|r| r.id == id)
            .expect("gpu_of found it");
        let delta = demand_ms - r.demand_ms;
        r.model = model;
        r.priority = priority;
        r.demand_ms = demand_ms;
        self.load_ms[gpu] = (self.load_ms[gpu] + delta).max(0.0);
        true
    }

    /// Re-seat a service on a **known** device, bypassing policy — the
    /// journal-recovery path (DESIGN.md §Daemon): a restarted daemon
    /// restores each resident to the GPU recorded in its snapshot, not
    /// wherever today's policy would put it. Returns `false` — with the
    /// state unchanged — if `gpu` is out of range, full, or already
    /// hosts service `id`.
    pub fn admit_at(&mut self, gpu: usize, resident: Resident) -> bool {
        if gpu >= self.gpus() || !self.has_room(gpu) || self.gpu_of(resident.id).is_some() {
            return false;
        }
        self.insert(gpu, resident);
        true
    }

    /// Remove a departing service. Returns the GPU it occupied.
    pub fn evict(&mut self, id: u64) -> Option<usize> {
        let gpu = self.gpu_of(id)?;
        let pos = self.residents[gpu].iter().position(|r| r.id == id)?;
        let r = self.residents[gpu].remove(pos);
        self.load_ms[gpu] = (self.load_ms[gpu] - r.demand_ms).max(0.0);
        Some(gpu)
    }

    /// Re-place service `id` onto the best device *other than its
    /// current one* per `policy`. Returns `(from, to)` on success; `None`
    /// (state unchanged) when no other device has room.
    pub fn migrate(
        &mut self,
        id: u64,
        policy: PlacementPolicy,
        model: &InterferenceModel,
    ) -> Option<(usize, usize)> {
        let from = self.gpu_of(id)?;
        let pos = self.residents[from].iter().position(|r| r.id == id)?;
        let resident = self.residents[from][pos].clone();
        let to = self.pick(policy, &resident, model, Some(from))?;
        self.evict(id);
        self.insert(to, resident);
        Some((from, to))
    }

    /// Move a resident to a specific device, bypassing policy scoring
    /// (rollback path: a migration target refused the service because a
    /// previous instance is still draining there). Returns `false` —
    /// with the state unchanged — if the service is unknown or `to` has
    /// no room.
    pub fn force_move(&mut self, id: u64, to: usize) -> bool {
        let Some(from) = self.gpu_of(id) else {
            return false;
        };
        if from == to {
            return true;
        }
        if !self.has_room(to) {
            return false;
        }
        let pos = self.residents[from]
            .iter()
            .position(|r| r.id == id)
            .expect("gpu_of found it");
        let r = self.residents[from].remove(pos);
        self.load_ms[from] = (self.load_ms[from] - r.demand_ms).max(0.0);
        self.insert(to, r);
        true
    }

    /// Worst *predicted* high-priority slowdown on `gpu` given its
    /// current residents: every high-priority (P0–P2) resident's
    /// predicted slowdown is scored against each of its co-tenants, and
    /// the worst value wins. `1.0` when no high-priority service is
    /// co-located with anything.
    ///
    /// For the senior member of a pair this is exactly the compat
    /// entry's semantics (host slowed by filler). A *junior* high-band
    /// member (e.g. a P1 tenant beside a P0 host) suffers at least as
    /// much; the flipped-orientation entry is the best available
    /// predictor for it, so both orientations are consulted whenever the
    /// victim is in the high band.
    pub fn predicted_high_slowdown(&self, gpu: usize, model: &InterferenceModel) -> f64 {
        let rs = &self.residents[gpu];
        let mut worst = 1.0f64;
        for (i, victim) in rs.iter().enumerate() {
            if !is_high_priority(victim.priority) {
                continue;
            }
            for (j, other) in rs.iter().enumerate() {
                if i == j {
                    continue;
                }
                worst = worst.max(model.high_slowdown(victim.model, other.model));
            }
        }
        worst
    }

    /// Fleet-wide worst predicted high-priority slowdown.
    pub fn worst_predicted_high_slowdown(&self, model: &InterferenceModel) -> f64 {
        (0..self.gpus())
            .map(|g| self.predicted_high_slowdown(g, model))
            .fold(1.0, f64::max)
    }

    fn insert(&mut self, gpu: usize, resident: Resident) {
        debug_assert!(self.has_room(gpu), "placement exceeded GPU capacity");
        self.load_ms[gpu] += resident.demand_ms;
        self.residents[gpu].push(resident);
    }

    /// Choose a GPU for `resident` per `policy`, skipping full devices
    /// and `exclude` (migration source). `None` if nothing has room.
    fn pick(
        &mut self,
        policy: PlacementPolicy,
        resident: &Resident,
        model: &InterferenceModel,
        exclude: Option<usize>,
    ) -> Option<usize> {
        let gpus = self.gpus();
        match policy {
            PlacementPolicy::RoundRobin => {
                for step in 0..gpus {
                    let g = (self.rr_next + step) % gpus;
                    if self.has_room(g) && Some(g) != exclude {
                        self.rr_next = (g + 1) % gpus;
                        return Some(g);
                    }
                }
                None
            }
            PlacementPolicy::LeastLoaded => {
                let mut best: Option<usize> = None;
                for g in 0..gpus {
                    if !self.has_room(g) || Some(g) == exclude {
                        continue;
                    }
                    if best.map_or(true, |b| self.load_ms[g] < self.load_ms[b]) {
                        best = Some(g);
                    }
                }
                best
            }
            PlacementPolicy::BestMatch => {
                let mut best: Option<(usize, f64)> = None;
                for g in 0..gpus {
                    if !self.has_room(g) || Some(g) == exclude {
                        continue;
                    }
                    let mut score = if self.residents[g].is_empty() {
                        // Empty GPU: always preferable to co-location
                        // (pair scores cap at 1/1.0 + 0.5·1.0 = 1.5).
                        2.0
                    } else {
                        self.residents[g]
                            .iter()
                            .map(|r| pair_score(resident, r, model))
                            .fold(f64::INFINITY, f64::min)
                    };
                    // Load tiebreak: 1ms of queued demand ≈ −1e-5.
                    score -= self.load_ms[g] * 1e-5;
                    if best.map_or(true, |(_, s)| score > s) {
                        best = Some((g, score));
                    }
                }
                best.map(|(g, _)| g)
            }
        }
    }
}

impl PlacementPolicy {
    /// Place `requests` (in arrival order) onto `gpus` devices with
    /// unbounded per-device capacity — the one-shot batch interface,
    /// implemented as a loop over [`FleetState::place`].
    pub fn place(
        self,
        requests: &[ServiceRequest],
        gpus: usize,
        model: &InterferenceModel,
    ) -> Placement {
        let mut fleet = FleetState::new(gpus, usize::MAX);
        let assignments = requests
            .iter()
            .enumerate()
            .map(|(idx, req)| {
                let demand_ms =
                    req.model.spec().mean_exec().as_millis_f64() * req.tasks as f64;
                let resident = Resident {
                    id: idx as u64,
                    model: req.model,
                    priority: req.priority,
                    demand_ms,
                };
                fleet
                    .place(self, resident, model)
                    .expect("unbounded capacity always has room")
            })
            .collect();
        Placement { assignments, gpus }
    }
}

/// Compatibility score between an arriving service and one resident,
/// oriented by priority (the higher-priority one is the "host" whose
/// gaps get filled).
fn pair_score(a: &Resident, b: &Resident, model: &InterferenceModel) -> f64 {
    let (high, low) = if a.priority.is_higher_than(b.priority) {
        (a.model, b.model)
    } else if b.priority.is_higher_than(a.priority) {
        (b.model, a.model)
    } else {
        // Equal priority: FIFO sharing; prefer pairing dense with gappy
        // anyway (use both orientations, take the mean).
        return (model.score(a.model, b.model) + model.score(b.model, a.model)) / 2.0;
    };
    model.score(high, low)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reqs() -> Vec<ServiceRequest> {
        vec![
            ServiceRequest::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0, 50),
            ServiceRequest::new(ModelKind::MaskrcnnResnet50Fpn, Priority::P0, 50),
            ServiceRequest::new(ModelKind::FcnResnet50, Priority::P5, 50),
            ServiceRequest::new(ModelKind::Resnet101, Priority::P5, 50),
        ]
    }

    #[test]
    fn round_robin_spreads_by_index() {
        let p = PlacementPolicy::RoundRobin.place(&reqs(), 2, &InterferenceModel::default());
        assert_eq!(p.assignments, vec![0, 1, 0, 1]);
        assert_eq!(p.on_gpu(0), vec![0, 2]);
    }

    #[test]
    fn least_loaded_balances_demand() {
        let requests = vec![
            ServiceRequest::new(ModelKind::MaskrcnnResnet50Fpn, Priority::P0, 100), // heavy
            ServiceRequest::new(ModelKind::Alexnet, Priority::P0, 10),              // light
            ServiceRequest::new(ModelKind::Alexnet, Priority::P5, 10),              // light
        ];
        let p = PlacementPolicy::LeastLoaded.place(&requests, 2, &InterferenceModel::default());
        // The two light ones pile onto the other GPU.
        assert_eq!(p.assignments[0], 0);
        assert_eq!(p.assignments[1], 1);
        assert_eq!(p.assignments[2], 1);
    }

    #[test]
    fn best_match_pairs_gappy_hosts_with_dense_fillers() {
        // Two high-priority detectors arrive first (one per GPU), then a
        // dense low-priority service: BestMatch should co-locate it with
        // a detector host (both are; any is fine), and a second gappy
        // low-priority detector-like service should avoid doubling up
        // where compatibility is worse.
        let requests = vec![
            ServiceRequest::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0, 50),
            ServiceRequest::new(ModelKind::Vgg16, Priority::P0, 50), // dense host: bad gaps
            ServiceRequest::new(ModelKind::FcnResnet50, Priority::P5, 50),
        ];
        let p = PlacementPolicy::BestMatch.place(&requests, 2, &InterferenceModel::default());
        // The detector and the vgg host land on different GPUs first.
        assert_ne!(p.assignments[0], p.assignments[1]);
        // The background service joins the *gappy* detector, not vgg.
        assert_eq!(
            p.assignments[2], p.assignments[0],
            "background filler should pick the gappy host"
        );
    }

    #[test]
    fn policy_parses() {
        assert_eq!("bm".parse::<PlacementPolicy>().unwrap(), PlacementPolicy::BestMatch);
        assert_eq!("rr".parse::<PlacementPolicy>().unwrap(), PlacementPolicy::RoundRobin);
        assert!("x".parse::<PlacementPolicy>().is_err());
    }

    // ----- incremental FleetState -----

    #[test]
    fn capacity_is_never_exceeded() {
        let compat = InterferenceModel::default();
        let mut fleet = FleetState::new(2, 2);
        for id in 0..4 {
            let r = Resident::per_task(id, ModelKind::Resnet50, Priority::P4);
            assert!(fleet.place(PlacementPolicy::LeastLoaded, r, &compat).is_some());
        }
        // Fleet is full: a fifth service is refused, not squeezed in.
        assert!(fleet.is_full());
        let r = Resident::per_task(99, ModelKind::Alexnet, Priority::P0);
        assert!(fleet.place(PlacementPolicy::LeastLoaded, r, &compat).is_none());
        assert_eq!(fleet.residents_on(0).len(), 2);
        assert_eq!(fleet.residents_on(1).len(), 2);
    }

    #[test]
    fn evict_frees_room_and_load() {
        let compat = InterferenceModel::default();
        let mut fleet = FleetState::new(1, 1);
        let r = Resident::per_task(7, ModelKind::Vgg16, Priority::P3);
        let demand = r.demand_ms;
        fleet.place(PlacementPolicy::RoundRobin, r, &compat).unwrap();
        assert!((fleet.load_ms(0) - demand).abs() < 1e-9);
        assert!(!fleet.has_room(0));
        assert_eq!(fleet.evict(7), Some(0));
        assert_eq!(fleet.load_ms(0), 0.0);
        assert!(fleet.has_room(0));
        assert_eq!(fleet.evict(7), None, "double evict is a no-op");
    }

    #[test]
    fn requalify_updates_in_place_without_moving() {
        let compat = InterferenceModel::default();
        let mut fleet = FleetState::new(2, 2);
        fleet
            .place(
                PlacementPolicy::RoundRobin,
                Resident::per_task(5, ModelKind::Alexnet, Priority::P5),
                &compat,
            )
            .unwrap();
        let gpu = fleet.gpu_of(5).unwrap();
        let new_demand = ModelKind::Vgg16.spec().mean_exec().as_millis_f64();
        assert!(fleet.requalify(5, ModelKind::Vgg16, Priority::P0, new_demand));
        assert_eq!(fleet.gpu_of(5), Some(gpu), "requalify never re-places");
        assert!((fleet.load_ms(gpu) - new_demand).abs() < 1e-9, "load delta applied");
        let r = &fleet.residents_on(gpu)[0];
        assert_eq!(r.model, ModelKind::Vgg16);
        assert_eq!(r.priority, Priority::P0);
        // Unknown id → no-op.
        assert!(!fleet.requalify(99, ModelKind::Vgg16, Priority::P0, 1.0));
    }

    #[test]
    fn migrate_moves_off_the_current_gpu() {
        let compat = InterferenceModel::default();
        let mut fleet = FleetState::new(2, 2);
        // A high-priority detector on GPU 0, a dense filler beside it.
        fleet
            .place(
                PlacementPolicy::RoundRobin,
                Resident::per_task(0, ModelKind::KeypointRcnnResnet50Fpn, Priority::P0),
                &compat,
            )
            .unwrap();
        let vgg = Resident::per_task(1, ModelKind::Vgg16, Priority::P7);
        // Force co-location for the test.
        fleet.insert(0, vgg);
        let (from, to) = fleet.migrate(1, PlacementPolicy::BestMatch, &compat).unwrap();
        assert_eq!(from, 0);
        assert_eq!(to, 1);
        assert_eq!(fleet.gpu_of(1), Some(1));
        assert_eq!(fleet.residents_on(0).len(), 1);
    }

    #[test]
    fn migrate_with_nowhere_to_go_is_a_no_op() {
        let compat = InterferenceModel::default();
        let mut fleet = FleetState::new(2, 1);
        fleet
            .place(
                PlacementPolicy::RoundRobin,
                Resident::per_task(0, ModelKind::Resnet50, Priority::P0),
                &compat,
            )
            .unwrap();
        fleet
            .place(
                PlacementPolicy::RoundRobin,
                Resident::per_task(1, ModelKind::Vgg16, Priority::P7),
                &compat,
            )
            .unwrap();
        // Both GPUs are full: service 1 has no migration target.
        assert_eq!(fleet.migrate(1, PlacementPolicy::BestMatch, &compat), None);
        assert_eq!(fleet.gpu_of(1), Some(1), "failed migration left state intact");
    }

    #[test]
    fn round_robin_skips_full_gpus() {
        let compat = InterferenceModel::default();
        let mut fleet = FleetState::new(3, 1);
        let g0 = fleet
            .place(
                PlacementPolicy::RoundRobin,
                Resident::per_task(0, ModelKind::Alexnet, Priority::P4),
                &compat,
            )
            .unwrap();
        assert_eq!(g0, 0);
        // Evicting nothing: next services take 1 and 2, then the wheel
        // finds no room anywhere.
        assert_eq!(
            fleet
                .place(
                    PlacementPolicy::RoundRobin,
                    Resident::per_task(1, ModelKind::Alexnet, Priority::P4),
                    &compat,
                )
                .unwrap(),
            1
        );
        assert_eq!(
            fleet
                .place(
                    PlacementPolicy::RoundRobin,
                    Resident::per_task(2, ModelKind::Alexnet, Priority::P4),
                    &compat,
                )
                .unwrap(),
            2
        );
        assert!(fleet
            .place(
                PlacementPolicy::RoundRobin,
                Resident::per_task(3, ModelKind::Alexnet, Priority::P4),
                &compat,
            )
            .is_none());
    }

    #[test]
    fn learned_dilation_steers_best_match_away() {
        // Priors say the gappy detector on GPU 1 is the better host for
        // a dense background filler. Then the model *observes* that this
        // filler murders the detector — BestMatch must flip to GPU 0.
        let mut model = InterferenceModel::default();
        let mut fleet = FleetState::new(2, 2);
        fleet.insert(0, Resident::per_task(0, ModelKind::Vgg16, Priority::P0));
        fleet.insert(
            1,
            Resident::per_task(1, ModelKind::KeypointRcnnResnet50Fpn, Priority::P0),
        );
        let filler = || Resident::per_task(2, ModelKind::Googlenet, Priority::P7);
        let mut cold = fleet.clone();
        assert_eq!(
            cold.place(PlacementPolicy::BestMatch, filler(), &model),
            Some(1),
            "priors prefer the gappy detector host"
        );
        for _ in 0..32 {
            model.observe(ModelKind::KeypointRcnnResnet50Fpn, ModelKind::Googlenet, 6.0);
        }
        assert_eq!(
            fleet.place(PlacementPolicy::BestMatch, filler(), &model),
            Some(0),
            "learned dilation overrides the prior"
        );
    }

    #[test]
    fn predicted_slowdown_flags_bad_colocation() {
        let compat = InterferenceModel::default();
        let mut fleet = FleetState::new(2, 2);
        fleet.insert(
            0,
            Resident::per_task(0, ModelKind::KeypointRcnnResnet50Fpn, Priority::P0),
        );
        fleet.insert(0, Resident::per_task(1, ModelKind::Vgg16, Priority::P7));
        fleet.insert(
            1,
            Resident::per_task(2, ModelKind::FasterrcnnResnet50Fpn, Priority::P0),
        );
        // GPU 0 hosts a high-prio detector with a dense co-tenant; GPU 1's
        // detector runs alone.
        assert!(fleet.predicted_high_slowdown(0, &compat) > 1.0);
        assert_eq!(fleet.predicted_high_slowdown(1, &compat), 1.0);
        assert_eq!(
            fleet.worst_predicted_high_slowdown(&compat),
            fleet.predicted_high_slowdown(0, &compat)
        );
    }
}
