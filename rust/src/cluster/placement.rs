//! Placement policies: which GPU should a newly arriving service land
//! on? (Paper §5: "when a task request arrives, the policy finds the GPU
//! on which its optimal matching task resides using the preloaded
//! measurement data".)

use super::compat::CompatMatrix;
use crate::core::Priority;
use crate::workload::ModelKind;

/// A service asking to be placed.
#[derive(Debug, Clone)]
pub struct ServiceRequest {
    pub model: ModelKind,
    pub priority: Priority,
    /// Back-to-back tasks the service will issue.
    pub tasks: u32,
}

impl ServiceRequest {
    pub fn new(model: ModelKind, priority: Priority, tasks: u32) -> ServiceRequest {
        ServiceRequest {
            model,
            priority,
            tasks,
        }
    }
}

/// A placement decision: service index → GPU index.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Placement {
    pub assignments: Vec<usize>,
    pub gpus: usize,
}

impl Placement {
    /// Services assigned to one GPU.
    pub fn on_gpu(&self, gpu: usize) -> Vec<usize> {
        self.assignments
            .iter()
            .enumerate()
            .filter(|(_, g)| **g == gpu)
            .map(|(i, _)| i)
            .collect()
    }
}

/// Available placement policies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Spread by index, ignoring workloads (the naive k8s default).
    RoundRobin,
    /// Place each service on the GPU with the least total device-time
    /// demand so far (classic load balancing, workload-blind).
    LeastLoaded,
    /// The paper's proposal: place each service where the pairwise
    /// compatibility with the residents is best — high-priority services
    /// seek gappy low-priority residents to scavenge; low-priority
    /// services seek gappy high-priority hosts.
    BestMatch,
}

impl std::str::FromStr for PlacementPolicy {
    type Err = crate::core::Error;
    fn from_str(s: &str) -> crate::core::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "roundrobin" | "round-robin" | "rr" => Ok(PlacementPolicy::RoundRobin),
            "leastloaded" | "least-loaded" | "ll" => Ok(PlacementPolicy::LeastLoaded),
            "bestmatch" | "best-match" | "bm" => Ok(PlacementPolicy::BestMatch),
            other => Err(crate::core::Error::Parse(format!(
                "unknown placement policy {other:?}"
            ))),
        }
    }
}

impl PlacementPolicy {
    /// Place `requests` (in arrival order) onto `gpus` devices.
    pub fn place(
        self,
        requests: &[ServiceRequest],
        gpus: usize,
        compat: &CompatMatrix,
    ) -> Placement {
        assert!(gpus > 0, "cluster has no GPUs");
        let mut assignments = Vec::with_capacity(requests.len());
        // Per-GPU state for the online policies.
        let mut load_ms = vec![0.0f64; gpus];
        let mut residents: Vec<Vec<usize>> = vec![Vec::new(); gpus];

        for (idx, req) in requests.iter().enumerate() {
            let demand_ms =
                req.model.spec().mean_exec().as_millis_f64() * req.tasks as f64;
            let gpu = match self {
                PlacementPolicy::RoundRobin => idx % gpus,
                PlacementPolicy::LeastLoaded => {
                    (0..gpus)
                        .min_by(|a, b| load_ms[*a].partial_cmp(&load_ms[*b]).unwrap())
                        .unwrap()
                }
                PlacementPolicy::BestMatch => {
                    // Score each GPU by the worst pairwise compatibility
                    // the new service would create with residents
                    // (bottleneck metric), with a mild load tiebreak.
                    let mut best_gpu = 0;
                    let mut best_score = f64::MIN;
                    for g in 0..gpus {
                        let mut score = if residents[g].is_empty() {
                            // Empty GPU: always preferable to co-location
                            // (scores cap at 1/1.0 + 0.5·1.0 = 1.5).
                            2.0
                        } else {
                            residents[g]
                                .iter()
                                .map(|&r| {
                                    let other = &requests[r];
                                    pair_score(req, other, compat)
                                })
                                .fold(f64::INFINITY, f64::min)
                        };
                        // Load tiebreak: 1ms of queued demand ≈ −1e-5.
                        score -= load_ms[g] * 1e-5;
                        if score > best_score {
                            best_score = score;
                            best_gpu = g;
                        }
                    }
                    best_gpu
                }
            };
            assignments.push(gpu);
            load_ms[gpu] += demand_ms;
            residents[gpu].push(idx);
        }
        Placement { assignments, gpus }
    }
}

/// Compatibility score between a new request and one resident, oriented
/// by priority (the higher-priority one is the "host" whose gaps get
/// filled).
fn pair_score(a: &ServiceRequest, b: &ServiceRequest, compat: &CompatMatrix) -> f64 {
    let (high, low) = if a.priority.is_higher_than(b.priority) {
        (a.model, b.model)
    } else if b.priority.is_higher_than(a.priority) {
        (b.model, a.model)
    } else {
        // Equal priority: FIFO sharing; prefer pairing dense with gappy
        // anyway (use both orientations, take the mean).
        let e1 = compat.get(a.model, b.model);
        let e2 = compat.get(b.model, a.model);
        return (e1.score() + e2.score()) / 2.0;
    };
    compat.get(high, low).score()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reqs() -> Vec<ServiceRequest> {
        vec![
            ServiceRequest::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0, 50),
            ServiceRequest::new(ModelKind::MaskrcnnResnet50Fpn, Priority::P0, 50),
            ServiceRequest::new(ModelKind::FcnResnet50, Priority::P5, 50),
            ServiceRequest::new(ModelKind::Resnet101, Priority::P5, 50),
        ]
    }

    #[test]
    fn round_robin_spreads_by_index() {
        let p = PlacementPolicy::RoundRobin.place(&reqs(), 2, &CompatMatrix::new());
        assert_eq!(p.assignments, vec![0, 1, 0, 1]);
        assert_eq!(p.on_gpu(0), vec![0, 2]);
    }

    #[test]
    fn least_loaded_balances_demand() {
        let requests = vec![
            ServiceRequest::new(ModelKind::MaskrcnnResnet50Fpn, Priority::P0, 100), // heavy
            ServiceRequest::new(ModelKind::Alexnet, Priority::P0, 10),              // light
            ServiceRequest::new(ModelKind::Alexnet, Priority::P5, 10),              // light
        ];
        let p = PlacementPolicy::LeastLoaded.place(&requests, 2, &CompatMatrix::new());
        // The two light ones pile onto the other GPU.
        assert_eq!(p.assignments[0], 0);
        assert_eq!(p.assignments[1], 1);
        assert_eq!(p.assignments[2], 1);
    }

    #[test]
    fn best_match_pairs_gappy_hosts_with_dense_fillers() {
        // Two high-priority detectors arrive first (one per GPU), then a
        // dense low-priority service: BestMatch should co-locate it with
        // a detector host (both are; any is fine), and a second gappy
        // low-priority detector-like service should avoid doubling up
        // where compatibility is worse.
        let requests = vec![
            ServiceRequest::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0, 50),
            ServiceRequest::new(ModelKind::Vgg16, Priority::P0, 50), // dense host: bad gaps
            ServiceRequest::new(ModelKind::FcnResnet50, Priority::P5, 50),
        ];
        let p = PlacementPolicy::BestMatch.place(&requests, 2, &CompatMatrix::new());
        // The detector and the vgg host land on different GPUs first.
        assert_ne!(p.assignments[0], p.assignments[1]);
        // The background service joins the *gappy* detector, not vgg.
        assert_eq!(
            p.assignments[2], p.assignments[0],
            "background filler should pick the gappy host"
        );
    }

    #[test]
    fn policy_parses() {
        assert_eq!("bm".parse::<PlacementPolicy>().unwrap(), PlacementPolicy::BestMatch);
        assert_eq!("rr".parse::<PlacementPolicy>().unwrap(), PlacementPolicy::RoundRobin);
        assert!("x".parse::<PlacementPolicy>().is_err());
    }
}
