//! Cluster-level GPU task scheduling — the paper's §5 "Future Work",
//! implemented.
//!
//! > *"We also need to implement a cluster-level scheduling policy to
//! > decide which concurrent tasks should be allocated to share the same
//! > GPU device … We can prepare combinations of potential models and
//! > measure their enhancement and impact in their JCT when sharing on
//! > the same device. These measurements will be preloaded for
//! > prediction in a cluster-level scheduling policy."*
//!
//! Components:
//!
//! * [`compat`] — the **combination compatibility matrix**: measured (or
//!   profile-predicted) high-priority slowdown and low-priority
//!   throughput for every model pair, built exactly the way the paper
//!   proposes (offline pairwise measurement, preloaded at scheduling
//!   time).
//! * [`placement`] — placement policies that assign arriving services to
//!   GPUs: the compatibility-aware **BestMatch** policy vs the
//!   **LeastLoaded** and **RoundRobin** baselines.
//! * [`sim`] — a multi-GPU cluster simulation that drives per-GPU FIKIT
//!   simulations from a placement decision and reports fleet-wide QoS.

pub mod compat;
pub mod placement;
pub mod sim;

pub use compat::{CompatEntry, CompatMatrix};
pub use placement::{Placement, PlacementPolicy, ServiceRequest};
pub use sim::{run_cluster, ClusterConfig, ClusterReport};
