//! Cluster-level GPU task scheduling — the paper's §5 "Future Work",
//! implemented and extended to a live serving fleet.
//!
//! > *"We also need to implement a cluster-level scheduling policy to
//! > decide which concurrent tasks should be allocated to share the same
//! > GPU device … We can prepare combinations of potential models and
//! > measure their enhancement and impact in their JCT when sharing on
//! > the same device. These measurements will be preloaded for
//! > prediction in a cluster-level scheduling policy."*
//!
//! Components (DESIGN.md §8):
//!
//! * [`compat`] — pairwise interference knowledge. The **combination
//!   compatibility matrix**: measured (or profile-predicted)
//!   high-priority slowdown and low-priority throughput for every model
//!   pair, built exactly the way the paper proposes (offline pairwise
//!   measurement, preloaded at scheduling time). Layered on top, the
//!   [`InterferenceModel`] (ADR-006) keeps that matrix as a prior and
//!   learns per-pair dilation online from co-residency-attributed
//!   completions, so placement and eviction track the deployment's
//!   actual backend and mix.
//! * [`placement`] — placement policies that assign arriving services to
//!   GPUs: the compatibility-aware **BestMatch** policy vs the
//!   **LeastLoaded** and **RoundRobin** baselines. Two layers: the
//!   incremental, capacity-aware [`placement::FleetState`] a live fleet
//!   mutates (place / evict / migrate), and the one-shot batch
//!   [`PlacementPolicy::place`] built on top of it.
//! * [`sim`] — the cluster simulations. [`run_cluster`] is the static
//!   batch run (fixed tenant set per GPU); [`run_churn`] is the
//!   **dynamic serving loop**: services arrive over time (Poisson or
//!   scripted), attach to per-GPU FIKIT coordinators mid-run, depart by
//!   draining, and get reactively migrated when a device's trailing
//!   high-priority slowdown exceeds the QoS bound.
//! * [`control`] — the federation control plane (DESIGN.md
//!   §Fleet-federation): [`FleetView`] folds peer capacity/health
//!   beacons with missed-beacon failure detection and answers the
//!   shed-vs-redirect question for over-capacity admissions;
//!   [`sim::run_node_churn`] is its fault-injection harness (node
//!   kill/restart/partition over the lossy fabric).

pub mod compat;
pub mod control;
pub mod placement;
pub mod sim;

pub use compat::{CompatEntry, CompatMatrix, InterferenceModel};
pub use control::{FleetConfig, FleetView, PeerState};
pub use placement::{FleetState, Placement, PlacementPolicy, Resident, ServiceRequest};
pub use sim::{
    run_churn, run_cluster, run_node_churn, ChurnConfig, ChurnReport, ChurnServiceOutcome,
    ClusterConfig, ClusterReport, EvictionStrategy, NodeChurnConfig, NodeChurnOutcome,
    NodeChurnReport, QosConfig,
};
