//! Fleet control plane: the node-local view of peer daemons.
//!
//! Every daemon in a federated fleet periodically emits a capacity/
//! health beacon ([`crate::hook::PeerMsg::Beacon`], emitted by
//! `daemon::beacon::Beaconer`); every daemon also folds the beacons it
//! *receives* into a [`FleetView`]. The view answers the two control-
//! plane questions admission needs (DESIGN.md §Fleet-federation):
//!
//! * **Is this peer alive?** — missed-beacon failure detection: a peer
//!   is live while its newest beacon arrived within
//!   `beacon_interval × miss_limit` of now, by the *receiver's* clock
//!   (no cross-node clock agreement is assumed).
//! * **Where should an over-capacity `Register` go?** —
//!   [`FleetView::best_redirect`] picks the live, non-draining peer
//!   with the most free slots (deterministic name tie-break); when no
//!   such peer exists the daemon sheds with `RetryAfter` instead.
//!
//! Beacons ride a lossy fabric, so the fold is monotone: each peer
//! carries a per-node beacon `seq`, and only a *newer* seq updates the
//! entry (state **and** arrival time). Duplicated, reordered or delayed
//! beacons are counted and dropped — they can never regress a peer's
//! capacity picture or extend its liveness, so liveness cannot flap
//! from fabric noise alone (ADR-005).

use crate::core::{Duration, SimTime};
use crate::hook::PeerMsg;
use std::collections::BTreeMap;

/// Control-plane tuning for one node.
#[derive(Debug, Clone, Copy)]
pub struct FleetConfig {
    /// Cadence of outgoing beacons (and the unit of failure detection).
    pub beacon_interval: Duration,
    /// Consecutive missed beacon intervals before a peer is declared
    /// dead. 3 tolerates two in-flight losses at 20% drop with ~1%
    /// false-positive odds per window (ADR-005 derives the number).
    pub miss_limit: u32,
    /// Back-off hint carried by `RetryAfter` shed replies.
    pub retry_after_ms: u64,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            beacon_interval: Duration::from_millis(100),
            miss_limit: 3,
            retry_after_ms: 250,
        }
    }
}

impl FleetConfig {
    /// The liveness horizon: a peer whose newest beacon is older than
    /// this is considered dead.
    pub fn liveness_window(&self) -> Duration {
        Duration::from_nanos(self.beacon_interval.nanos() * u64::from(self.miss_limit.max(1)))
    }

    /// Seq regression at or beyond this is a peer **restart**, not a
    /// stale delivery. A restarted daemon's `Beaconer` counts from 1
    /// again; without this rule its beacons would be dropped as stale
    /// forever and the node could never rejoin the fleet (ADR-005).
    /// Fabric reordering can only regress by however many beacons fit
    /// in the delivery spread — a handful at most — so several whole
    /// liveness windows' worth of beacons cleanly separates the cases.
    pub fn restart_seq_gap(&self) -> u64 {
        u64::from(4 * self.miss_limit.max(1))
    }
}

/// Last-known state of one peer, as advertised by its newest beacon.
#[derive(Debug, Clone)]
pub struct PeerState {
    pub node: String,
    /// Newest beacon seq folded in; lower-or-equal seqs are stale.
    pub last_seq: u64,
    /// Receiver-local arrival time of that beacon (drives liveness).
    pub last_seen: SimTime,
    pub devices: u32,
    pub capacity: u32,
    pub residents: u32,
    pub draining: bool,
}

impl PeerState {
    /// Advertised free admission slots.
    pub fn free_slots(&self) -> u32 {
        (self.devices * self.capacity).saturating_sub(self.residents)
    }
}

/// One node's eventually-consistent picture of its peers.
#[derive(Debug)]
pub struct FleetView {
    cfg: FleetConfig,
    peers: BTreeMap<String, PeerState>,
    /// Duplicated / reordered / delayed beacons dropped by the seq
    /// guard. Monotonically interesting: fabric noise, not errors.
    stale_beacons: u64,
    /// Peer restarts detected by the seq-regression rule
    /// ([`FleetConfig::restart_seq_gap`]).
    restarts_observed: u64,
}

impl FleetView {
    pub fn new(cfg: FleetConfig) -> FleetView {
        FleetView {
            cfg,
            peers: BTreeMap::new(),
            stale_beacons: 0,
            restarts_observed: 0,
        }
    }

    pub fn config(&self) -> &FleetConfig {
        &self.cfg
    }

    /// Fold one received beacon in at receiver-local time `now`.
    /// Returns `false` (and counts) when the beacon is stale — a
    /// duplicate or an older reordering of something already folded.
    pub fn observe(&mut self, beacon: &PeerMsg, now: SimTime) -> bool {
        let PeerMsg::Beacon {
            node,
            seq,
            sent_at_ns: _,
            devices,
            capacity,
            residents,
            draining,
        } = beacon;
        if let Some(p) = self.peers.get_mut(node) {
            if *seq <= p.last_seq {
                // Small regressions are fabric noise; a regression of
                // several liveness windows' worth of beacons means the
                // peer restarted and its seq counter began again — fold
                // it in or the node could never rejoin the fleet.
                if p.last_seq - *seq < self.cfg.restart_seq_gap() {
                    self.stale_beacons += 1;
                    return false;
                }
                self.restarts_observed += 1;
            }
            p.last_seq = *seq;
            p.last_seen = now;
            p.devices = *devices;
            p.capacity = *capacity;
            p.residents = *residents;
            p.draining = *draining;
        } else {
            self.peers.insert(
                node.clone(),
                PeerState {
                    node: node.clone(),
                    last_seq: *seq,
                    last_seen: now,
                    devices: *devices,
                    capacity: *capacity,
                    residents: *residents,
                    draining: *draining,
                },
            );
        }
        true
    }

    /// Missed-beacon failure detection: seen recently enough?
    pub fn is_alive(&self, node: &str, now: SimTime) -> bool {
        self.peers
            .get(node)
            .is_some_and(|p| now.nanos().saturating_sub(p.last_seen.nanos())
                <= self.cfg.liveness_window().nanos())
    }

    /// The live, non-draining peer with the most advertised free slots
    /// (ties broken by node name, so two nodes rejecting the same burst
    /// redirect deterministically). `None` → shed with `RetryAfter`.
    pub fn best_redirect(&self, now: SimTime) -> Option<&str> {
        self.peers
            .values()
            .filter(|p| !p.draining && p.free_slots() > 0 && self.is_alive(&p.node, now))
            .max_by(|a, b| {
                a.free_slots()
                    .cmp(&b.free_slots())
                    // BTreeMap iterates name-ascending; prefer the
                    // *smaller* name on equal slots, so invert here
                    // (max_by keeps the later of equal elements).
                    .then_with(|| b.node.cmp(&a.node))
            })
            .map(|p| p.node.as_str())
    }

    pub fn peer(&self, node: &str) -> Option<&PeerState> {
        self.peers.get(node)
    }

    pub fn live_peers(&self, now: SimTime) -> usize {
        self.peers
            .keys()
            .filter(|n| self.is_alive(n, now))
            .count()
    }

    pub fn stale_beacons(&self) -> u64 {
        self.stale_beacons
    }

    /// Peer restarts detected (beacon seq regressed past the
    /// [`FleetConfig::restart_seq_gap`] threshold).
    pub fn restarts_observed(&self) -> u64 {
        self.restarts_observed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn beacon(node: &str, seq: u64, residents: u32) -> PeerMsg {
        PeerMsg::Beacon {
            node: node.into(),
            seq,
            sent_at_ns: seq * 100,
            devices: 1,
            capacity: 4,
            residents,
            draining: false,
        }
    }

    fn cfg() -> FleetConfig {
        FleetConfig {
            beacon_interval: Duration::from_millis(100),
            miss_limit: 3,
            retry_after_ms: 250,
        }
    }

    #[test]
    fn newer_beacon_updates_stale_is_dropped() {
        let mut v = FleetView::new(cfg());
        let t = |ms: u64| SimTime(ms * 1_000_000);
        assert!(v.observe(&beacon("a", 1, 0), t(0)));
        assert!(v.observe(&beacon("a", 2, 3), t(100)));
        // Duplicate and reordered deliveries are dropped and cannot
        // regress state or liveness.
        assert!(!v.observe(&beacon("a", 2, 0), t(150)));
        assert!(!v.observe(&beacon("a", 1, 0), t(200)));
        assert_eq!(v.stale_beacons(), 2);
        let p = v.peer("a").unwrap();
        assert_eq!(p.residents, 3);
        assert_eq!(p.last_seen, t(100));
        assert_eq!(p.free_slots(), 1);
    }

    #[test]
    fn liveness_uses_window_and_heals() {
        let mut v = FleetView::new(cfg());
        let t = |ms: u64| SimTime(ms * 1_000_000);
        v.observe(&beacon("a", 1, 0), t(0));
        assert!(v.is_alive("a", t(300))); // exactly at the window edge
        assert!(!v.is_alive("a", t(301))); // one tick past → dead
        assert_eq!(v.best_redirect(t(301)), None);
        // Partition heals: one fresh beacon re-enters placement.
        v.observe(&beacon("a", 2, 1), t(900));
        assert!(v.is_alive("a", t(1000)));
        assert_eq!(v.best_redirect(t(1000)), Some("a"));
        assert!(!v.is_alive("never-seen", t(0)));
    }

    #[test]
    fn best_redirect_prefers_free_slots_then_name() {
        let mut v = FleetView::new(cfg());
        let t = SimTime(0);
        v.observe(&beacon("b", 1, 1), t); // 3 free
        v.observe(&beacon("a", 1, 2), t); // 2 free
        assert_eq!(v.best_redirect(t), Some("b"));
        v.observe(&beacon("a", 2, 1), t); // tie at 3 free → name order
        assert_eq!(v.best_redirect(t), Some("a"));
        // Draining and full peers are never redirect targets.
        v.observe(
            &PeerMsg::Beacon {
                node: "a".into(),
                seq: 3,
                sent_at_ns: 0,
                devices: 1,
                capacity: 4,
                residents: 1,
                draining: true,
            },
            t,
        );
        assert_eq!(v.best_redirect(t), Some("b"));
        v.observe(&beacon("b", 2, 4), t); // full
        assert_eq!(v.best_redirect(t), None);
    }

    /// Property sweep: any seeded interleaving of duplicated, reordered
    /// and delayed (but within-window) deliveries of the same beacon
    /// stream keeps the peer live throughout, converges to the newest
    /// state, and never lets a stale delivery extend `last_seen`.
    #[test]
    fn fabric_noise_never_flaps_liveness() {
        for seed in [1u64, 7, 42, 1234] {
            let mut rng = Rng::new(seed);
            let c = cfg();
            let mut v = FleetView::new(c);
            // Ground truth: beacon k emitted at k*interval, residents k%5.
            let emit =
                |k: u64| (beacon("a", k + 1, (k % 5) as u32), k * c.beacon_interval.nanos());
            // Build a delivery schedule: every beacon delivered 1–3
            // times, each copy delayed 0..half-a-window, then sort by
            // delivery time (which reorders aggressively).
            let mut deliveries: Vec<(u64, u64)> = Vec::new(); // (deliver_at, k)
            for k in 0..40u64 {
                let copies = 1 + rng.below(3);
                for _ in 0..copies {
                    let delay = rng.below(c.liveness_window().nanos() / 2);
                    deliveries.push((emit(k).1 + delay, k));
                }
            }
            deliveries.sort_unstable();
            let mut newest_applied = 0u64;
            for (at, k) in deliveries {
                let (b, _) = emit(k);
                let applied = v.observe(&b, SimTime(at));
                assert_eq!(
                    applied,
                    k + 1 > newest_applied,
                    "seed {seed}: seq guard must accept exactly the newer-seq deliveries"
                );
                newest_applied = newest_applied.max(k + 1);
                // Once the stream has started, the peer stays live at
                // every delivery instant: delays are < half a window and
                // beacons keep arriving.
                assert!(
                    v.is_alive("a", SimTime(at)),
                    "seed {seed}: liveness flapped at {at}ns"
                );
            }
            assert_eq!(v.peer("a").unwrap().last_seq, 40);
            assert_eq!(v.restarts_observed(), 0, "seed {seed}: noise is not a restart");
        }
    }

    /// A restarted peer's beacon seq counts from 1 again; the large
    /// regression is folded in as a restart (so the node rejoins the
    /// fleet), while small regressions stay stale-dropped.
    #[test]
    fn restart_seq_regression_rejoins_peer() {
        let mut v = FleetView::new(cfg()); // miss_limit 3 → gap 12
        let t = |ms: u64| SimTime(ms * 1_000_000);
        for seq in 1..=40u64 {
            v.observe(&beacon("a", seq, 2), t(seq * 100));
        }
        // Node "a" dies and restarts: first beacon of the new
        // incarnation regresses 40 → 1.
        assert!(v.observe(&beacon("a", 1, 0), t(9_000)));
        assert_eq!(v.restarts_observed(), 1);
        let p = v.peer("a").unwrap();
        assert_eq!((p.last_seq, p.residents), (1, 0));
        assert!(v.is_alive("a", t(9_100)));
        // The new incarnation's stream then advances normally...
        assert!(v.observe(&beacon("a", 2, 1), t(9_100)));
        // ...and small regressions are still fabric noise.
        assert!(!v.observe(&beacon("a", 1, 0), t(9_150)));
        assert_eq!(v.stale_beacons(), 1);
        assert_eq!(v.restarts_observed(), 1);
    }
}
