//! Experiment / deployment configuration.
//!
//! Everything an experiment needs is captured in one serializable
//! [`ExperimentConfig`] — loadable from a JSON file (the
//! `fikit run --config` path), constructible programmatically (the bench
//! harness), always seeded and therefore reproducible.

use crate::coordinator::Mode;
use crate::core::{Duration, Error, Priority, Result, SimTime, TaskKey};
use crate::profile::{MeasurementConfig, SymbolTableModel};
use crate::simulator::{ConcurrencyBackend, DeviceConfig};
use crate::util::json::Json;
use crate::workload::{InvocationPattern, ModelKind, Service};
use std::path::Path;

/// One hosted service in an experiment.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Which model the service runs.
    pub model: ModelKind,
    /// Task priority (P0 highest).
    pub priority: Priority,
    /// Arrival pattern.
    pub pattern: InvocationPattern,
    /// Optional explicit task key (defaults to `model@priority`).
    pub key: Option<String>,
}

impl ServiceConfig {
    pub fn new(model: ModelKind, priority: Priority) -> ServiceConfig {
        ServiceConfig {
            model,
            priority,
            pattern: InvocationPattern::BackToBack { count: 100 },
            key: None,
        }
    }

    /// Issue `count` back-to-back tasks.
    pub fn tasks(mut self, count: u32) -> ServiceConfig {
        self.pattern = InvocationPattern::BackToBack { count };
        self
    }

    /// Issue a task every `interval_ms`, `count` times.
    pub fn every_ms(mut self, interval_ms: u64, count: u32) -> ServiceConfig {
        self.pattern = InvocationPattern::Every {
            interval: Duration::from_millis(interval_ms),
            count,
        };
        self
    }

    /// Run back-to-back until the simulation clock passes `until_ms`.
    pub fn continuous_ms(mut self, until_ms: u64) -> ServiceConfig {
        self.pattern = InvocationPattern::ContinuousUntil {
            until: SimTime(until_ms * 1_000_000),
        };
        self
    }

    pub fn with_key(mut self, key: &str) -> ServiceConfig {
        self.key = Some(key.to_string());
        self
    }

    /// Materialize into a workload [`Service`].
    pub fn to_service(&self) -> Service {
        let mut s = Service::new(self.model, self.priority, self.pattern);
        if let Some(key) = &self.key {
            s = s.with_key(TaskKey::new(key.as_str()));
        }
        s
    }
}

/// Per-launch CPU-side costs of the FIKIT machinery.
#[derive(Debug, Clone)]
pub struct HookConfig {
    /// CPU cost of the hook intercepting one launch and (for held
    /// kernels) round-tripping to the scheduler. The paper's design keeps
    /// this ≈1–2 µs by resolving all kernel statistics offline.
    pub interception_overhead: Duration,
    /// Base CPU launch-path overhead present in *every* mode (driver
    /// call, stream bookkeeping).
    pub base_launch_overhead: Duration,
}

impl Default for HookConfig {
    fn default() -> HookConfig {
        HookConfig {
            interception_overhead: Duration::from_nanos(1_500),
            base_launch_overhead: Duration::from_nanos(800),
        }
    }
}

/// The full description of one experiment run.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Scheduling mode under test.
    pub mode: Mode,
    /// The sharing services.
    pub services: Vec<ServiceConfig>,
    /// Device timing model.
    pub device: DeviceConfig,
    /// Hook cost model.
    pub hook: HookConfig,
    /// `-rdynamic` symbol-table model (drives Fig 13 and kernel-name
    /// availability).
    pub symbols: SymbolTableModel,
    /// Measurement-stage cost model and `T`.
    pub measurement: MeasurementConfig,
    /// Enable the runtime feedback early stop (ablation switch).
    pub feedback: bool,
    /// Online sharing-stage profile refinement (DESIGN.md §9). Disabled
    /// by default: the paper's frozen-offline-profile behaviour.
    pub online: crate::profile::OnlineConfig,
    /// Within-priority fill selection rule (ablation; paper: LongestFit).
    pub fill_policy: crate::coordinator::best_prio_fit::FillPolicy,
    /// In-flight fill reclamation policy (DESIGN.md §8). Default `None`:
    /// the paper's non-preemptive behaviour, byte-identical reports.
    pub preempt: crate::coordinator::fikit::PreemptionPolicy,
    /// Modeled cost of one preemption (driver stop + relaunch), charged
    /// as dead device time at the cut.
    pub preempt_cost: Duration,
    /// Small-gap threshold ε for Algorithm 1.
    pub epsilon: Duration,
    /// Root RNG seed — all service trace generators derive from it.
    pub seed: u64,
    /// Hard stop for the simulation clock (safety net; `None` = run to
    /// completion of all arrival patterns).
    pub horizon: Option<Duration>,
}

fn default_epsilon() -> Duration {
    crate::coordinator::fikit::DEFAULT_EPSILON
}
fn default_seed() -> u64 {
    0xF1C1
}

impl Default for ExperimentConfig {
    fn default() -> ExperimentConfig {
        ExperimentConfig {
            mode: Mode::Fikit,
            services: Vec::new(),
            device: DeviceConfig::default(),
            hook: HookConfig::default(),
            symbols: SymbolTableModel::default(),
            measurement: MeasurementConfig::default(),
            feedback: true,
            online: crate::profile::OnlineConfig::default(),
            fill_policy: crate::coordinator::best_prio_fit::FillPolicy::LongestFit,
            preempt: crate::coordinator::fikit::PreemptionPolicy::None,
            preempt_cost: crate::coordinator::fikit::DEFAULT_PREEMPT_COST,
            epsilon: default_epsilon(),
            seed: default_seed(),
            horizon: None,
        }
    }
}

impl ExperimentConfig {
    /// Validate structural soundness.
    pub fn validate(&self) -> Result<()> {
        if self.services.is_empty() {
            return Err(crate::core::Error::Config("no services configured".into()));
        }
        let mut keys: Vec<String> = self
            .services
            .iter()
            .map(|s| s.to_service().key.to_string())
            .collect();
        keys.sort();
        keys.dedup();
        if keys.len() != self.services.len() {
            return Err(crate::core::Error::Config(
                "duplicate service task keys; use `key` to disambiguate".into(),
            ));
        }
        Ok(())
    }

    /// Load and validate a JSON config file.
    pub fn from_json_file(path: impl AsRef<Path>) -> Result<ExperimentConfig> {
        let text = std::fs::read_to_string(path.as_ref())?;
        let cfg = ExperimentConfig::from_json(&Json::parse(&text)?)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize to a JSON value.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("mode", self.mode.to_string())
            .set(
                "services",
                Json::Arr(self.services.iter().map(|s| s.to_json()).collect()),
            )
            .set("launch_latency_ns", self.device.launch_latency.nanos())
            .set("compute_scale", self.device.compute_scale)
            .set("backend", self.device.backend.to_string())
            .set(
                "hook",
                Json::obj()
                    .set("interception_ns", self.hook.interception_overhead.nanos())
                    .set("base_launch_ns", self.hook.base_launch_overhead.nanos()),
            )
            .set(
                "symbols",
                Json::obj()
                    .set("exported", self.symbols.symbols_exported)
                    .set("table_size", self.symbols.table_size)
                    .set("base_lookup_ns", self.symbols.base_lookup.nanos()),
            )
            .set(
                "measurement",
                Json::obj()
                    .set("runs", self.measurement.runs)
                    .set("event_overhead_ns", self.measurement.event_overhead.nanos())
                    .set("sync_stall_factor", self.measurement.sync_stall_factor),
            )
            .set("feedback", self.feedback)
            .set(
                "online",
                Json::obj()
                    .set("enabled", self.online.enabled)
                    .set("alpha", self.online.alpha)
                    .set("z", self.online.z)
                    .set("min_samples", self.online.min_samples)
                    .set("shrink", self.online.shrink)
                    .set("band_floor_frac", self.online.band_floor_frac)
                    .set("cost_per_obs_ns", self.online.cost_per_obs.nanos())
                    .set("track_errors", self.online.track_errors)
                    .set("error_window", self.online.error_window),
            )
            .set(
                "fill_policy",
                match self.fill_policy {
                    crate::coordinator::best_prio_fit::FillPolicy::LongestFit => "longest",
                    crate::coordinator::best_prio_fit::FillPolicy::FirstFit => "first",
                    crate::coordinator::best_prio_fit::FillPolicy::ShortestFit => "shortest",
                },
            )
            .set("preempt", self.preempt.to_string())
            .set("preempt_cost_ns", self.preempt_cost.nanos())
            .set("epsilon_ns", self.epsilon.nanos())
            .set("seed", self.seed)
            .set(
                "horizon_ns",
                match self.horizon {
                    Some(h) => Json::from(h.nanos()),
                    None => Json::Null,
                },
            )
    }

    /// Parse from a JSON value. Missing optional fields take defaults.
    pub fn from_json(v: &Json) -> Result<ExperimentConfig> {
        let defaults = ExperimentConfig::default();
        let mode: Mode = v.req_str("mode")?.parse()?;
        let services = v
            .req_arr("services")?
            .iter()
            .map(ServiceConfig::from_json)
            .collect::<Result<Vec<_>>>()?;
        let device = DeviceConfig {
            launch_latency: v
                .get("launch_latency_ns")
                .and_then(Json::as_u64)
                .map(Duration::from_nanos)
                .unwrap_or(defaults.device.launch_latency),
            compute_scale: v
                .get("compute_scale")
                .and_then(Json::as_f64)
                .unwrap_or(1.0),
            // Absent in pre-seam configs: default to the paper's FIFO
            // model so old JSON replays unchanged.
            backend: match v.get("backend").and_then(Json::as_str) {
                Some(token) => token.parse()?,
                None => ConcurrencyBackend::TimeSliced,
            },
        };
        let hook = match v.get("hook") {
            Some(h) => HookConfig {
                interception_overhead: Duration::from_nanos(h.req_u64("interception_ns")?),
                base_launch_overhead: Duration::from_nanos(h.req_u64("base_launch_ns")?),
            },
            None => defaults.hook.clone(),
        };
        let symbols = match v.get("symbols") {
            Some(s) => SymbolTableModel {
                symbols_exported: s.req_bool("exported")?,
                table_size: s.req_u64("table_size")?,
                base_lookup: Duration::from_nanos(s.req_u64("base_lookup_ns")?),
            },
            None => defaults.symbols.clone(),
        };
        let measurement = match v.get("measurement") {
            Some(m) => MeasurementConfig {
                runs: m.req_u64("runs")? as u32,
                event_overhead: Duration::from_nanos(m.req_u64("event_overhead_ns")?),
                sync_stall_factor: m.req_f64("sync_stall_factor")?,
            },
            None => defaults.measurement.clone(),
        };
        let online = match v.get("online") {
            Some(o) => {
                let d = crate::profile::OnlineConfig::default();
                crate::profile::OnlineConfig {
                    enabled: o.get("enabled").and_then(Json::as_bool).unwrap_or(d.enabled),
                    alpha: o.get("alpha").and_then(Json::as_f64).unwrap_or(d.alpha),
                    z: o.get("z").and_then(Json::as_f64).unwrap_or(d.z),
                    min_samples: o
                        .get("min_samples")
                        .and_then(Json::as_u64)
                        .map(|n| n as u32)
                        .unwrap_or(d.min_samples),
                    shrink: o.get("shrink").and_then(Json::as_f64).unwrap_or(d.shrink),
                    band_floor_frac: o
                        .get("band_floor_frac")
                        .and_then(Json::as_f64)
                        .unwrap_or(d.band_floor_frac),
                    cost_per_obs: o
                        .get("cost_per_obs_ns")
                        .and_then(Json::as_u64)
                        .map(Duration::from_nanos)
                        .unwrap_or(d.cost_per_obs),
                    track_errors: o
                        .get("track_errors")
                        .and_then(Json::as_bool)
                        .unwrap_or(d.track_errors),
                    error_window: o
                        .get("error_window")
                        .and_then(Json::as_u64)
                        .map(|n| n as u32)
                        .unwrap_or(d.error_window),
                }
            }
            None => defaults.online.clone(),
        };
        Ok(ExperimentConfig {
            mode,
            services,
            device,
            hook,
            symbols,
            measurement,
            feedback: v.get("feedback").and_then(Json::as_bool).unwrap_or(true),
            online,
            fill_policy: match v.get("fill_policy").and_then(Json::as_str) {
                Some(p) => p.parse()?,
                None => Default::default(),
            },
            // Absent in pre-preemption configs: default to None so old
            // JSON replays byte-identically.
            preempt: match v.get("preempt").and_then(Json::as_str) {
                Some(token) => token.parse()?,
                None => Default::default(),
            },
            preempt_cost: v
                .get("preempt_cost_ns")
                .and_then(Json::as_u64)
                .map(Duration::from_nanos)
                .unwrap_or(defaults.preempt_cost),
            epsilon: v
                .get("epsilon_ns")
                .and_then(Json::as_u64)
                .map(Duration::from_nanos)
                .unwrap_or_else(default_epsilon),
            seed: v.get("seed").and_then(Json::as_u64).unwrap_or_else(default_seed),
            horizon: v
                .get("horizon_ns")
                .and_then(Json::as_u64)
                .map(Duration::from_nanos),
        })
    }
}

impl ServiceConfig {
    /// Serialize to JSON.
    pub fn to_json(&self) -> Json {
        let pattern = match self.pattern {
            InvocationPattern::BackToBack { count } => {
                Json::obj().set("kind", "back_to_back").set("count", count)
            }
            InvocationPattern::Every { interval, count } => Json::obj()
                .set("kind", "every")
                .set("interval_ns", interval.nanos())
                .set("count", count),
            InvocationPattern::ContinuousUntil { until } => Json::obj()
                .set("kind", "continuous_until")
                .set("until_ns", until.nanos()),
        };
        let mut obj = Json::obj()
            .set("model", self.model.name())
            .set("priority", self.priority.to_string())
            .set("pattern", pattern);
        if let Some(key) = &self.key {
            obj = obj.set("key", key.as_str());
        }
        obj
    }

    /// Parse from JSON.
    pub fn from_json(v: &Json) -> Result<ServiceConfig> {
        let model: ModelKind = v.req_str("model")?.parse()?;
        let priority: Priority = v.req_str("priority")?.parse()?;
        let p = v.require("pattern")?;
        let pattern = match p.req_str("kind")? {
            "back_to_back" => InvocationPattern::BackToBack {
                count: p.req_u64("count")? as u32,
            },
            "every" => InvocationPattern::Every {
                interval: Duration::from_nanos(p.req_u64("interval_ns")?),
                count: p.req_u64("count")? as u32,
            },
            "continuous_until" => InvocationPattern::ContinuousUntil {
                until: SimTime(p.req_u64("until_ns")?),
            },
            other => {
                return Err(Error::Parse(format!("unknown pattern kind {other:?}")));
            }
        };
        Ok(ServiceConfig {
            model,
            priority,
            pattern,
            key: v.get("key").and_then(Json::as_str).map(str::to_string),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_round_trips_through_json() {
        let mut cfg = ExperimentConfig::default();
        cfg.services
            .push(ServiceConfig::new(ModelKind::Alexnet, Priority::P0).tasks(10));
        cfg.services
            .push(ServiceConfig::new(ModelKind::Vgg16, Priority::P2).every_ms(1000, 5));
        cfg.services
            .push(ServiceConfig::new(ModelKind::Resnet50, Priority::P4).continuous_ms(5_000));
        cfg.horizon = Some(Duration::from_secs(30));
        cfg.online.enabled = true;
        cfg.online.band_floor_frac = 0.2;
        cfg.online.cost_per_obs = Duration::from_nanos(275);
        cfg.online.track_errors = true;
        cfg.online.error_window = 48;
        cfg.device.backend = ConcurrencyBackend::MpsSpatial { dilation: 0.25 };
        cfg.preempt = crate::coordinator::fikit::PreemptionPolicy::Hybrid { threshold: 0.4 };
        cfg.preempt_cost = Duration::from_micros(35);
        cfg.validate().unwrap();

        let text = cfg.to_json().encode_pretty();
        let back = ExperimentConfig::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.services.len(), 3);
        assert_eq!(back.device.backend, cfg.device.backend);
        assert_eq!(back.preempt, cfg.preempt);
        assert_eq!(back.preempt_cost, cfg.preempt_cost);
        assert!(back.online.enabled);
        assert_eq!(back.online.band_floor_frac, 0.2);
        assert_eq!(back.online.cost_per_obs, Duration::from_nanos(275));
        assert!(back.online.track_errors);
        assert_eq!(back.online.error_window, 48);
        assert_eq!(back.online.alpha, cfg.online.alpha);
        assert_eq!(back.mode, Mode::Fikit);
        assert_eq!(back.seed, cfg.seed);
        assert_eq!(back.horizon, cfg.horizon);
        assert_eq!(back.services[1].pattern, cfg.services[1].pattern);
        assert_eq!(back.services[2].pattern, cfg.services[2].pattern);
        assert_eq!(back.epsilon, cfg.epsilon);
        assert_eq!(
            back.measurement.sync_stall_factor,
            cfg.measurement.sync_stall_factor
        );
    }

    #[test]
    fn config_without_backend_field_defaults_to_timesliced() {
        // Pre-seam configs have no "backend" key; they must keep
        // meaning the paper's FIFO model.
        let mut cfg = ExperimentConfig::default();
        cfg.services
            .push(ServiceConfig::new(ModelKind::Alexnet, Priority::P0).tasks(1));
        let mut json = cfg.to_json();
        if let Json::Obj(map) = &mut json {
            map.remove("backend");
        }
        let back = ExperimentConfig::from_json(&json).unwrap();
        assert_eq!(back.device.backend, ConcurrencyBackend::TimeSliced);
    }

    #[test]
    fn config_without_preempt_fields_defaults_to_none() {
        // Pre-preemption configs have no "preempt" keys; they must keep
        // meaning the non-preemptive scheduler.
        let mut cfg = ExperimentConfig::default();
        cfg.services
            .push(ServiceConfig::new(ModelKind::Alexnet, Priority::P0).tasks(1));
        let mut json = cfg.to_json();
        if let Json::Obj(map) = &mut json {
            map.remove("preempt");
            map.remove("preempt_cost_ns");
        }
        let back = ExperimentConfig::from_json(&json).unwrap();
        assert_eq!(back.preempt, crate::coordinator::fikit::PreemptionPolicy::None);
        assert_eq!(back.preempt_cost, crate::coordinator::fikit::DEFAULT_PREEMPT_COST);
    }

    #[test]
    fn config_file_round_trip() {
        let mut cfg = ExperimentConfig::default();
        cfg.services
            .push(ServiceConfig::new(ModelKind::Alexnet, Priority::P0).tasks(3));
        let dir = std::env::temp_dir().join(format!("fikit-cfg-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("exp.json");
        std::fs::write(&path, cfg.to_json().encode_pretty()).unwrap();
        let back = ExperimentConfig::from_json_file(&path).unwrap();
        assert_eq!(back.services.len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn duplicate_keys_rejected() {
        let mut cfg = ExperimentConfig::default();
        cfg.services
            .push(ServiceConfig::new(ModelKind::Alexnet, Priority::P0));
        cfg.services
            .push(ServiceConfig::new(ModelKind::Alexnet, Priority::P0));
        assert!(cfg.validate().is_err());
        // Disambiguating with explicit keys fixes it.
        cfg.services[1] = ServiceConfig::new(ModelKind::Alexnet, Priority::P0).with_key("alex2");
        cfg.validate().unwrap();
    }

    #[test]
    fn empty_services_rejected() {
        assert!(ExperimentConfig::default().validate().is_err());
    }
}
