//! Services and invocation patterns.
//!
//! A *service* is one hosted ML endpoint (a model + a priority + a
//! [`TaskKey`]); a *task* is one invocation of it (one inference). The
//! paper's experiment schemes use three arrival patterns, all modelled
//! here: back-to-back batches (schemes I–III, Table 2, Figs 16–18),
//! periodic insertion every 1 s (Figs 19–21), and continuous background
//! streams.

use super::models::ModelKind;
use crate::core::{Duration, Priority, SimTime, TaskKey};

/// When a service issues its tasks.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InvocationPattern {
    /// Issue `count` tasks back-to-back: task *n+1* arrives the moment
    /// task *n* completes (the "run 1000 inferences" pattern).
    BackToBack { count: u32 },
    /// Issue a task every `interval`, `count` times (the "A issues a
    /// high-priority task every 1 second, 100 tasks" pattern). If a task
    /// overruns the interval, the next arrival queues behind it.
    Every { interval: Duration, count: u32 },
    /// Back-to-back tasks until the simulation clock passes `until`
    /// (the "runs continuously in the background" pattern).
    ContinuousUntil { until: SimTime },
}

impl InvocationPattern {
    /// Upper bound on tasks this pattern can produce (`None` = unbounded
    /// until the time horizon).
    pub fn task_limit(&self) -> Option<u32> {
        match self {
            InvocationPattern::BackToBack { count } | InvocationPattern::Every { count, .. } => {
                Some(*count)
            }
            InvocationPattern::ContinuousUntil { .. } => None,
        }
    }
}

/// A hosted inference service.
#[derive(Debug, Clone)]
pub struct Service {
    /// Unique service identity — the paper's Task Key (process name +
    /// startup parameters).
    pub key: TaskKey,
    /// Which model the service runs.
    pub model: ModelKind,
    /// Priority of every task the service issues.
    pub priority: Priority,
    /// Arrival pattern.
    pub pattern: InvocationPattern,
}

impl Service {
    pub fn new(model: ModelKind, priority: Priority, pattern: InvocationPattern) -> Service {
        Service {
            key: TaskKey::new(format!("{}@{}", model.name(), priority)),
            model,
            priority,
            pattern,
        }
    }

    /// Override the task key (needed when the same model appears twice in
    /// one experiment).
    pub fn with_key(mut self, key: impl Into<TaskKey>) -> Service {
        self.key = key.into();
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pattern_limits() {
        assert_eq!(InvocationPattern::BackToBack { count: 10 }.task_limit(), Some(10));
        assert_eq!(
            InvocationPattern::Every {
                interval: Duration::from_secs(1),
                count: 100
            }
            .task_limit(),
            Some(100)
        );
        assert_eq!(
            InvocationPattern::ContinuousUntil { until: SimTime(1) }.task_limit(),
            None
        );
    }

    #[test]
    fn service_key_derivation() {
        let s = Service::new(
            ModelKind::Alexnet,
            Priority::P0,
            InvocationPattern::BackToBack { count: 1 },
        );
        assert_eq!(s.key.as_str(), "alexnet@P0");
    }
}
