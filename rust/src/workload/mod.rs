//! Workloads: calibrated kernel-trace models of the paper's DNN services.
//!
//! The paper evaluates twelve torchvision networks (Table 1) on an RTX
//! 3090. That hardware/driver substrate does not exist here, so each
//! network is modelled as a **kernel trace**: an ordered sequence of
//! `(KernelId, execution time, following CPU-side gap)` entries with
//! seeded log-normal jitter. The traces are calibrated at the *structure*
//! level — kernel counts, duration scales, and the gap share of total
//! runtime — which is exactly what the paper's scheduling results depend
//! on (detection-head models have many small kernels separated by large
//! CPU-side gaps; dense classifiers are back-to-back GEMMs).
//!
//! See DESIGN.md §2 for the substitution rationale.

mod arrivals;
mod models;
mod service;
mod trace;

pub use arrivals::{ArrivalProcess, MixEntry, ServiceArrival};
pub use models::{ModelClass, ModelKind, ModelSpec, Segment};
pub use service::{InvocationPattern, Service};
pub use trace::{KernelTrace, TraceGenerator, TraceKernel};
