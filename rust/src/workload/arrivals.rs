//! Fleet-level arrival processes: *which services* show up at the
//! cluster, *when*, and *for how long*.
//!
//! The single-GPU simulator models task arrivals within one service
//! (see [`InvocationPattern`](super::InvocationPattern)); this module
//! models the layer above — **service churn**: whole services arriving
//! at the fleet, living for a while, and departing. Two generators are
//! provided, mirroring the seeded-sampler idiom of
//! [`TraceGenerator`](super::TraceGenerator):
//!
//! * [`ArrivalProcess::Poisson`] — seeded memoryless arrivals with
//!   exponential lifetimes and a weighted model/priority mix. The same
//!   seed always yields the same schedule, so every churn experiment is
//!   replayable (DESIGN.md §8).
//! * [`ArrivalProcess::Trace`] — an explicit, hand-written schedule for
//!   scripted scenarios (the "rescue" scenario of the cluster-churn
//!   experiment pins exact arrival times to make the migration effect
//!   deterministic and inspectable).

use crate::core::{Duration, Priority, SimTime};
use crate::util::rng::Rng;
use crate::workload::ModelKind;

/// One scheduled service arrival: the service appears at [`ServiceArrival::at`]
/// and departs at `at + lifetime` (its last in-flight task is drained,
/// never cut mid-kernel — the device is non-preemptive, DESIGN.md §6).
#[derive(Debug, Clone, PartialEq)]
pub struct ServiceArrival {
    /// Fleet time at which the service requests placement.
    pub at: SimTime,
    /// Model the service runs.
    pub model: ModelKind,
    /// Priority of every task the service issues.
    pub priority: Priority,
    /// How long the service stays before departing.
    pub lifetime: Duration,
}

impl ServiceArrival {
    /// Convenience constructor.
    pub fn new(at: SimTime, model: ModelKind, priority: Priority, lifetime: Duration) -> Self {
        ServiceArrival {
            at,
            model,
            priority,
            lifetime,
        }
    }

    /// Fleet time at which the service departs.
    pub fn departs_at(&self) -> SimTime {
        self.at + self.lifetime
    }
}

/// One entry of a Poisson workload mix: a candidate service type and its
/// relative arrival weight.
#[derive(Debug, Clone)]
pub struct MixEntry {
    /// Model of services drawn from this entry.
    pub model: ModelKind,
    /// Priority of services drawn from this entry.
    pub priority: Priority,
    /// Relative arrival rate (weights need not sum to 1).
    pub weight: f64,
}

impl MixEntry {
    /// Convenience constructor.
    pub fn new(model: ModelKind, priority: Priority, weight: f64) -> MixEntry {
        MixEntry {
            model,
            priority,
            weight,
        }
    }
}

/// A generator of service-churn schedules.
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    /// Memoryless arrivals: exponential inter-arrival times with the
    /// given mean, exponential lifetimes, and a weighted service mix.
    /// Generation stops at `horizon` (services arriving later than the
    /// horizon are not emitted; lifetimes may extend past it).
    Poisson {
        /// Mean time between consecutive service arrivals.
        mean_interarrival: Duration,
        /// Mean service lifetime.
        mean_lifetime: Duration,
        /// Weighted candidate service types (must be non-empty).
        mix: Vec<MixEntry>,
        /// No arrivals are generated at or after this fleet time.
        horizon: Duration,
    },
    /// An explicit schedule (scripted scenarios, replayed traces).
    Trace(Vec<ServiceArrival>),
}

impl ArrivalProcess {
    /// Materialize the schedule. Deterministic per `seed`; the output is
    /// sorted by arrival time (ties keep generation order).
    pub fn generate(&self, seed: u64) -> Vec<ServiceArrival> {
        match self {
            ArrivalProcess::Trace(list) => {
                let mut out = list.clone();
                out.sort_by_key(|a| a.at);
                out
            }
            ArrivalProcess::Poisson {
                mean_interarrival,
                mean_lifetime,
                mix,
                horizon,
            } => {
                assert!(!mix.is_empty(), "Poisson arrival mix is empty");
                let total_weight: f64 = mix.iter().map(|e| e.weight.max(0.0)).sum();
                assert!(total_weight > 0.0, "Poisson arrival mix has zero weight");
                let mut rng = Rng::new(seed ^ 0xA221_7A15);
                let mut out = Vec::new();
                let mut t = SimTime::ZERO;
                loop {
                    let step = rng.exponential(mean_interarrival.nanos() as f64);
                    t = t + Duration::from_nanos(step.round().max(1.0) as u64);
                    if t.nanos() >= horizon.nanos() {
                        break;
                    }
                    // Weighted mix draw.
                    let mut pick = rng.f64() * total_weight;
                    let mut chosen = &mix[0];
                    for entry in mix {
                        let w = entry.weight.max(0.0);
                        if pick < w {
                            chosen = entry;
                            break;
                        }
                        pick -= w;
                        chosen = entry;
                    }
                    let life = rng.exponential(mean_lifetime.nanos() as f64);
                    out.push(ServiceArrival {
                        at: t,
                        model: chosen.model,
                        priority: chosen.priority,
                        // Floor at 1ms so every service gets a chance to
                        // run at least part of one task.
                        lifetime: Duration::from_nanos(life.round().max(1_000_000.0) as u64),
                    });
                }
                out
            }
        }
    }

    /// Latest departure in the generated schedule (drain deadline for a
    /// churn run). `SimTime::ZERO` for an empty schedule.
    pub fn last_departure(&self, seed: u64) -> SimTime {
        self.generate(seed)
            .iter()
            .map(ServiceArrival::departs_at)
            .max()
            .unwrap_or(SimTime::ZERO)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mix() -> Vec<MixEntry> {
        vec![
            MixEntry::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0, 1.0),
            MixEntry::new(ModelKind::FcnResnet50, Priority::P5, 2.0),
            MixEntry::new(ModelKind::Vgg16, Priority::P7, 1.0),
        ]
    }

    #[test]
    fn poisson_is_deterministic_per_seed() {
        let p = ArrivalProcess::Poisson {
            mean_interarrival: Duration::from_millis(200),
            mean_lifetime: Duration::from_secs(1),
            mix: mix(),
            horizon: Duration::from_secs(5),
        };
        let a = p.generate(42);
        let b = p.generate(42);
        assert_eq!(a, b);
        assert!(!a.is_empty());
        let c = p.generate(43);
        assert_ne!(a, c);
    }

    #[test]
    fn poisson_respects_horizon_and_ordering() {
        let p = ArrivalProcess::Poisson {
            mean_interarrival: Duration::from_millis(100),
            mean_lifetime: Duration::from_millis(500),
            mix: mix(),
            horizon: Duration::from_secs(2),
        };
        let schedule = p.generate(7);
        for w in schedule.windows(2) {
            assert!(w[0].at <= w[1].at, "arrivals unsorted");
        }
        for a in &schedule {
            assert!(a.at.nanos() < 2_000_000_000, "arrival past horizon");
            assert!(a.lifetime >= Duration::from_millis(1));
        }
    }

    #[test]
    fn poisson_interarrival_mean_roughly_matches() {
        let p = ArrivalProcess::Poisson {
            mean_interarrival: Duration::from_millis(50),
            mean_lifetime: Duration::from_millis(200),
            mix: mix(),
            horizon: Duration::from_secs(60),
        };
        let schedule = p.generate(11);
        assert!(schedule.len() > 500, "expected ~1200 arrivals, got {}", schedule.len());
        let mean_gap_ms = schedule.last().unwrap().at.as_millis_f64() / schedule.len() as f64;
        assert!(
            (mean_gap_ms - 50.0).abs() < 10.0,
            "mean inter-arrival {mean_gap_ms:.1}ms vs 50ms target"
        );
    }

    #[test]
    fn mix_weights_bias_the_draw() {
        let p = ArrivalProcess::Poisson {
            mean_interarrival: Duration::from_millis(20),
            mean_lifetime: Duration::from_millis(100),
            mix: mix(),
            horizon: Duration::from_secs(30),
        };
        let schedule = p.generate(3);
        let fcn = schedule
            .iter()
            .filter(|a| a.model == ModelKind::FcnResnet50)
            .count();
        let kp = schedule
            .iter()
            .filter(|a| a.model == ModelKind::KeypointRcnnResnet50Fpn)
            .count();
        // fcn has 2x the weight of keypointrcnn.
        assert!(fcn > kp, "weighted mix ignored: fcn {fcn} vs kp {kp}");
    }

    #[test]
    fn trace_schedule_is_sorted_and_passthrough() {
        let t = ArrivalProcess::Trace(vec![
            ServiceArrival::new(
                SimTime(2_000),
                ModelKind::Vgg16,
                Priority::P7,
                Duration::from_millis(5),
            ),
            ServiceArrival::new(
                SimTime(1_000),
                ModelKind::Alexnet,
                Priority::P0,
                Duration::from_millis(5),
            ),
        ]);
        let s = t.generate(0);
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].model, ModelKind::Alexnet);
        assert_eq!(s[1].departs_at(), SimTime(2_000) + Duration::from_millis(5));
        assert_eq!(t.last_departure(0), s[1].departs_at());
    }
}
