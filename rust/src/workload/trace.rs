//! Kernel traces: the concrete per-inference sequence of
//! `(KernelId, exec, gap)` entries a service process replays.
//!
//! A [`TraceGenerator`] samples a fresh jittered trace per task from a
//! [`ModelSpec`](super::ModelSpec) using a seeded ChaCha RNG — the same
//! seed always yields the same sequence of traces, making every
//! experiment deterministic. Jitter is log-normal: multiplicative,
//! strictly positive, heavier upper tail — the shape of real kernel-time
//! variation the paper's Fig 5 illustrates (same KernelID, different
//! durations).

use super::models::ModelSpec;
use crate::core::{Dim3, Duration, KernelId};
use crate::util::rng::Rng;
use std::sync::Arc;

/// One kernel entry of a concrete (already jittered) trace.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceKernel {
    pub kernel: KernelId,
    /// Index of the generator segment this kernel was sampled from —
    /// stable across tasks, so per-segment side tables (resolved kernel
    /// ids, interned handles) can be indexed without hashing at issue
    /// time.
    pub seg: u32,
    /// True device execution duration for this occurrence.
    pub exec: Duration,
    /// CPU-side think time after this kernel (post-completion for sync
    /// kernels, post-launch pacing for async ones; 0 after the last).
    pub gap_after: Duration,
    /// Whether the CPU blocks on this kernel's completion before
    /// continuing (sync stall) or launches ahead (async).
    pub sync: bool,
}

/// A complete per-task trace.
#[derive(Debug, Clone, Default)]
pub struct KernelTrace {
    pub kernels: Vec<TraceKernel>,
}

impl KernelTrace {
    pub fn len(&self) -> usize {
        self.kernels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kernels.is_empty()
    }

    /// Fully-serialized walltime of this trace: Σ exec + Σ gaps (what a
    /// measurement-stage run costs, modulo event overheads).
    pub fn serialized_walltime(&self) -> Duration {
        self.kernels.iter().map(|k| k.exec + k.gap_after).sum()
    }

    /// Approximate exclusive-mode (pipelined) JCT: execution plus the
    /// sync-stall gaps; async pacing gaps overlap device execution.
    pub fn exclusive_jct(&self) -> Duration {
        let exec: Duration = self.kernels.iter().map(|k| k.exec).sum();
        let stalls: Duration = self
            .kernels
            .iter()
            .filter(|k| k.sync)
            .map(|k| k.gap_after)
            .sum();
        exec + stalls
    }

    /// Device busy time of this trace.
    pub fn total_exec(&self) -> Duration {
        self.kernels.iter().map(|k| k.exec).sum()
    }
}

/// Internal segment form with an owned kernel name (models.rs keeps
/// `&'static str` for the zoo; generated/custom workloads need owned).
#[derive(Debug, Clone)]
pub struct Segment {
    pub kernel_name: Arc<str>,
    pub count: u32,
    pub exec: Duration,
    pub exec_jitter: f64,
    pub gap: Duration,
    pub gap_jitter: f64,
    pub sync: bool,
    pub grid: Dim3,
    pub block: Dim3,
}

/// Seeded per-service trace sampler.
pub struct TraceGenerator {
    segments: Vec<Segment>,
    rng: Rng,
    /// Pre-built kernel ids, one per segment (shared Arc names).
    ids: Vec<KernelId>,
    /// Multiplier on sampled CPU-side gaps — the interference-injection
    /// knob (DESIGN.md §9): co-location contention inflates a service's
    /// real think gaps, which is exactly the drift the online refiner
    /// must detect. 1.0 = no interference.
    gap_scale: f64,
}

impl TraceGenerator {
    /// Build a generator for a model spec with the given seed.
    pub fn new(spec: &ModelSpec, seed: u64) -> TraceGenerator {
        let segments: Vec<Segment> = spec.segments.iter().map(|s| s.to_trace_segment()).collect();
        TraceGenerator::from_segments(segments, seed)
    }

    /// Pre-built kernel ids, one per segment, in segment order — the
    /// targets of [`TraceKernel::seg`].
    pub fn ids(&self) -> &[KernelId] {
        &self.ids
    }

    /// Build from raw segments (custom workloads, tests).
    pub fn from_segments(segments: Vec<Segment>, seed: u64) -> TraceGenerator {
        let ids = segments
            .iter()
            .map(|s| KernelId::new(s.kernel_name.clone(), s.grid, s.block))
            .collect();
        TraceGenerator {
            segments,
            rng: Rng::new(seed),
            ids,
            gap_scale: 1.0,
        }
    }

    /// Inject (or clear) gap interference: future traces sample their
    /// CPU-side gaps scaled by `scale`. Exec times and the RNG stream
    /// are untouched, so a run with `scale = 1.0` is bit-identical to
    /// one that never called this.
    pub fn set_gap_scale(&mut self, scale: f64) {
        self.gap_scale = scale.max(0.0);
    }

    /// Sample one jittered duration around `mean` with log-normal σ
    /// (the distribution mean equals the segment mean — see
    /// [`Rng::lognormal_with_mean`]).
    fn sample(rng: &mut Rng, mean: Duration, sigma: f64) -> Duration {
        if mean.is_zero() {
            return Duration::ZERO;
        }
        if sigma <= 0.0 {
            return mean;
        }
        let v = rng.lognormal_with_mean(mean.nanos() as f64, sigma);
        Duration::from_nanos(v.round().max(1.0) as u64)
    }

    /// Generate the trace for the next task of this service.
    pub fn next_trace(&mut self) -> KernelTrace {
        let mut kernels = Vec::with_capacity(
            self.segments.iter().map(|s| s.count as usize).sum::<usize>(),
        );
        for (si, (seg, id)) in self.segments.iter().zip(&self.ids).enumerate() {
            for _ in 0..seg.count {
                let exec = Self::sample(&mut self.rng, seg.exec, seg.exec_jitter);
                let mut gap = Self::sample(&mut self.rng, seg.gap, seg.gap_jitter);
                if self.gap_scale != 1.0 {
                    gap = gap.scale(self.gap_scale);
                }
                kernels.push(TraceKernel {
                    kernel: id.clone(),
                    seg: si as u32,
                    exec,
                    gap_after: gap,
                    sync: seg.sync,
                });
            }
        }
        // The final kernel has no following gap within the task.
        if let Some(last) = kernels.last_mut() {
            last.gap_after = Duration::ZERO;
        }
        KernelTrace { kernels }
    }

    /// Uniform jitter helper for tests / arrival processes.
    pub fn uniform_ms(&mut self, lo: f64, hi: f64) -> Duration {
        Duration::from_millis_f64(self.rng.range_f64(lo, hi))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ModelKind;

    #[test]
    fn traces_are_deterministic_per_seed() {
        let spec = ModelKind::Resnet50.spec();
        let mut a = TraceGenerator::new(&spec, 42);
        let mut b = TraceGenerator::new(&spec, 42);
        for _ in 0..3 {
            assert_eq!(a.next_trace().kernels, b.next_trace().kernels);
        }
        let mut c = TraceGenerator::new(&spec, 43);
        assert_ne!(a.next_trace().kernels, c.next_trace().kernels);
    }

    #[test]
    fn trace_shape_matches_spec() {
        let spec = ModelKind::Vgg16.spec();
        let mut g = TraceGenerator::new(&spec, 7);
        let t = g.next_trace();
        assert_eq!(t.len() as u32, spec.kernel_count());
        assert_eq!(t.kernels.last().unwrap().gap_after, Duration::ZERO);
    }

    #[test]
    fn jitter_preserves_mean_roughly() {
        let spec = ModelKind::KeypointRcnnResnet50Fpn.spec();
        let mut g = TraceGenerator::new(&spec, 1);
        let n = 50;
        let mut total = 0f64;
        for _ in 0..n {
            total += g.next_trace().exclusive_jct().as_millis_f64();
        }
        let mean = total / n as f64;
        let expected = spec.mean_jct().as_millis_f64();
        let rel = (mean - expected).abs() / expected;
        // Log-normal with the calibrated sigmas: sample mean within 5%.
        assert!(rel < 0.05, "mean {mean:.2}ms vs expected {expected:.2}ms");
    }

    #[test]
    fn gap_scale_inflates_only_gaps() {
        let spec = ModelKind::KeypointRcnnResnet50Fpn.spec();
        let mut base = TraceGenerator::new(&spec, 9);
        let mut scaled = TraceGenerator::new(&spec, 9);
        scaled.set_gap_scale(2.0);
        let a = base.next_trace();
        let b = scaled.next_trace();
        assert_eq!(a.total_exec(), b.total_exec(), "exec untouched");
        for (ka, kb) in a.kernels.iter().zip(&b.kernels) {
            assert_eq!(kb.gap_after, ka.gap_after.scale(2.0));
        }
        // Clearing the injection restores the shared RNG stream exactly.
        scaled.set_gap_scale(1.0);
        assert_eq!(base.next_trace().kernels, scaled.next_trace().kernels);
    }

    #[test]
    fn zero_jitter_is_exact() {
        let seg = Segment {
            kernel_name: "k".into(),
            count: 4,
            exec: Duration::from_micros(100),
            exec_jitter: 0.0,
            gap: Duration::from_micros(10),
            gap_jitter: 0.0,
            sync: true,
            grid: Dim3::x(1),
            block: Dim3::x(32),
        };
        let mut g = TraceGenerator::from_segments(vec![seg], 0);
        let t = g.next_trace();
        assert!(t.kernels.iter().all(|k| k.exec == Duration::from_micros(100)));
        assert_eq!(t.kernels[0].gap_after, Duration::from_micros(10));
        assert_eq!(t.serialized_walltime(), Duration::from_micros(4 * 100 + 3 * 10));
        assert_eq!(t.exclusive_jct(), Duration::from_micros(4 * 100 + 3 * 10));
    }
}
