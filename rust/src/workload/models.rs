//! Calibrated kernel-trace specifications for the paper's model zoo
//! (Table 1, plus GoogLeNet which appears in Fig 13).
//!
//! Each model is a sequence of **segments**; a segment describes a run of
//! similar kernels (e.g. "backbone residual-block GEMMs", "RPN proposal
//! filtering", "NMS + post-processing"). Two kinds of segments matter:
//!
//! * **async segments** (`sync = false`) — the CPU launches these kernels
//!   open-loop (CUDA streams are asynchronous): the tiny `gap` is just
//!   CPU launch pacing, and the device queue stays full. This is how the
//!   dense convolution/GEMM body of every network behaves.
//! * **sync segments** (`sync = true`) — the CPU must read results back
//!   before proceeding (proposal filtering, NMS thresholds, keypoint
//!   decoding): the launch loop *blocks* on kernel completion and then
//!   spends a large CPU-side `gap` before the next launch. These are the
//!   paper's Fig 1 inter-kernel device-idle gaps — the resource FIKIT
//!   scavenges.
//!
//! The absolute numbers are order-of-magnitude calibrations against
//! public RTX-3090 latencies for these torchvision models; what the
//! experiments depend on is the *structure*: R-CNN-family detectors have
//! dozens of large sync stalls (low GPU saturation), dense classifiers
//! have almost none (near-full saturation), segmentation sits in between.

use super::trace::Segment as TraceSegment;
use crate::core::{Dim3, Duration};

/// Broad structural class of a model — used in docs/analysis and for
/// picking good sharing combinations (paper §5 "What tasks are suitable
/// for sharing a GPU").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelClass {
    /// Detection models with heavy CPU-side post-processing: large gaps.
    GappyDetector,
    /// Dense feed-forward classifier: near-saturating kernel stream.
    DenseClassifier,
    /// Segmentation: dense backbone + moderately gappy head.
    Segmentation,
}

/// The twelve networks of the paper's Table 1 (+ GoogLeNet from Fig 13).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[allow(missing_docs)]
pub enum ModelKind {
    FcnResnet50,
    FcnResnet101,
    MaskrcnnResnet50Fpn,
    Deeplabv3Resnet50,
    Deeplabv3Resnet101,
    KeypointRcnnResnet50Fpn,
    Resnet50,
    Resnet101,
    FcosResnet50Fpn,
    FasterrcnnResnet50Fpn,
    Alexnet,
    Vgg16,
    Googlenet,
}

impl ModelKind {
    /// Every model in the zoo.
    pub const ALL: [ModelKind; 13] = [
        ModelKind::FcnResnet50,
        ModelKind::FcnResnet101,
        ModelKind::MaskrcnnResnet50Fpn,
        ModelKind::Deeplabv3Resnet50,
        ModelKind::Deeplabv3Resnet101,
        ModelKind::KeypointRcnnResnet50Fpn,
        ModelKind::Resnet50,
        ModelKind::Resnet101,
        ModelKind::FcosResnet50Fpn,
        ModelKind::FasterrcnnResnet50Fpn,
        ModelKind::Alexnet,
        ModelKind::Vgg16,
        ModelKind::Googlenet,
    ];

    /// Number of models in the zoo (`ALL.len()`), for dense
    /// per-model-pair tables such as the cluster interference model.
    pub const COUNT: usize = ModelKind::ALL.len();

    /// Dense position of this model in [`ModelKind::ALL`] — a stable
    /// array index, so pairwise state can live in flat
    /// `[[_; COUNT]; COUNT]` tables with no hashing or allocation on
    /// lookup (the placement scan is O(residents²) lookups per
    /// decision).
    pub fn index(self) -> usize {
        self as usize
    }

    /// The torchvision-style model name used in the paper's figures.
    pub fn name(self) -> &'static str {
        match self {
            ModelKind::FcnResnet50 => "fcn_resnet50",
            ModelKind::FcnResnet101 => "fcn_resnet101",
            ModelKind::MaskrcnnResnet50Fpn => "maskrcnn_resnet50_fpn",
            ModelKind::Deeplabv3Resnet50 => "deeplabv3_resnet50",
            ModelKind::Deeplabv3Resnet101 => "deeplabv3_resnet101",
            ModelKind::KeypointRcnnResnet50Fpn => "keypointrcnn_resnet50_fpn",
            ModelKind::Resnet50 => "resnet50",
            ModelKind::Resnet101 => "resnet101",
            ModelKind::FcosResnet50Fpn => "fcos_resnet50_fpn",
            ModelKind::FasterrcnnResnet50Fpn => "fasterrcnn_resnet50_fpn",
            ModelKind::Alexnet => "alexnet",
            ModelKind::Vgg16 => "vgg16",
            ModelKind::Googlenet => "googlenet",
        }
    }

    /// Parse a paper-style model name.
    pub fn from_name(s: &str) -> Option<ModelKind> {
        ModelKind::ALL.iter().copied().find(|m| m.name() == s)
    }

    pub fn class(self) -> ModelClass {
        match self {
            ModelKind::MaskrcnnResnet50Fpn
            | ModelKind::KeypointRcnnResnet50Fpn
            | ModelKind::FasterrcnnResnet50Fpn
            | ModelKind::FcosResnet50Fpn => ModelClass::GappyDetector,
            ModelKind::Resnet50
            | ModelKind::Resnet101
            | ModelKind::Alexnet
            | ModelKind::Vgg16
            | ModelKind::Googlenet => ModelClass::DenseClassifier,
            ModelKind::FcnResnet50
            | ModelKind::FcnResnet101
            | ModelKind::Deeplabv3Resnet50
            | ModelKind::Deeplabv3Resnet101 => ModelClass::Segmentation,
        }
    }

    /// The calibrated trace specification for this model.
    pub fn spec(self) -> ModelSpec {
        spec_for(self)
    }
}

impl std::fmt::Display for ModelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for ModelKind {
    type Err = crate::core::Error;
    fn from_str(s: &str) -> crate::core::Result<ModelKind> {
        ModelKind::from_name(s)
            .ok_or_else(|| crate::core::Error::Parse(format!("unknown model: {s:?}")))
    }
}

/// A named run of similar kernels within a model's trace.
#[derive(Debug, Clone)]
pub struct Segment {
    /// Kernel function name (the `-rdynamic`-resolved symbol).
    pub kernel_name: &'static str,
    /// Number of consecutive launches of this kernel.
    pub count: u32,
    /// Mean device execution time per launch.
    pub exec: Duration,
    /// Log-normal jitter σ of execution time (0 = deterministic).
    pub exec_jitter: f64,
    /// Mean CPU-side gap after each launch (launch pacing when async,
    /// result post-processing when sync).
    pub gap: Duration,
    /// Log-normal jitter σ of the gap.
    pub gap_jitter: f64,
    /// Whether the CPU blocks on this kernel's completion before
    /// spending `gap` and issuing the next launch (see module docs).
    pub sync: bool,
    /// Launch grid dims.
    pub grid: Dim3,
    /// Launch block dims.
    pub block: Dim3,
}

impl Segment {
    /// Async (launch-ahead) segment: tiny CPU pacing gap.
    fn conv(kernel_name: &'static str, count: u32, exec_us: f64, grid: u32, block: u32) -> Segment {
        Segment {
            kernel_name,
            count,
            exec: Duration::from_micros_f64(exec_us),
            exec_jitter: 0.08,
            gap: Duration::from_micros_f64(3.0),
            gap_jitter: 0.3,
            sync: false,
            grid: Dim3::x(grid),
            block: Dim3::x(block),
        }
    }

    /// Sync stall segment: the CPU waits for results, post-processes for
    /// `gap_us`, then continues — the paper's fillable inter-kernel gap.
    fn stall(kernel_name: &'static str, count: u32, exec_us: f64, gap_us: f64) -> Segment {
        Segment {
            kernel_name,
            count,
            exec: Duration::from_micros_f64(exec_us),
            exec_jitter: 0.15,
            gap: Duration::from_micros_f64(gap_us),
            gap_jitter: 0.35,
            sync: true,
            grid: Dim3::x(32),
            block: Dim3::x(64),
        }
    }

    pub(crate) fn to_trace_segment(&self) -> TraceSegment {
        TraceSegment {
            kernel_name: self.kernel_name.into(),
            count: self.count,
            exec: self.exec,
            exec_jitter: self.exec_jitter,
            gap: self.gap,
            gap_jitter: self.gap_jitter,
            sync: self.sync,
            grid: self.grid,
            block: self.block,
        }
    }
}

/// Full trace specification of one model.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub kind: ModelKind,
    pub segments: Vec<Segment>,
}

impl ModelSpec {
    /// Total number of kernels per inference.
    pub fn kernel_count(&self) -> u32 {
        self.segments.iter().map(|s| s.count).sum()
    }

    /// Mean device execution time per inference (sum of segment means).
    pub fn mean_exec(&self) -> Duration {
        self.segments
            .iter()
            .map(|s| Duration::from_nanos(s.exec.nanos() * s.count as u64))
            .sum()
    }

    /// Mean CPU-side *sync* gap time per inference — device idle in
    /// exclusive mode (async pacing gaps overlap with execution).
    pub fn mean_sync_gap(&self) -> Duration {
        self.segments
            .iter()
            .filter(|s| s.sync)
            .map(|s| Duration::from_nanos(s.gap.nanos() * s.count as u64))
            .sum()
    }

    /// Number of sync stall points per inference.
    pub fn sync_points(&self) -> u32 {
        self.segments.iter().filter(|s| s.sync).map(|s| s.count).sum()
    }

    /// Approximate exclusive-mode JCT: execution + sync stalls (async
    /// launch pacing hides behind execution).
    pub fn mean_jct(&self) -> Duration {
        self.mean_exec() + self.mean_sync_gap()
    }

    /// Fraction of exclusive-mode wall time the device sits idle —
    /// the "gap share" FIKIT scavenges.
    pub fn gap_share(&self) -> f64 {
        let total = self.mean_jct().nanos() as f64;
        if total == 0.0 {
            0.0
        } else {
            self.mean_sync_gap().nanos() as f64 / total
        }
    }

    /// A zero-measurement **cold-start prior** for this model
    /// (DESIGN.md §9): per-segment mean exec as `SK` and, for sync
    /// segments, the mean think gap as `SG`. In a real fleet this prior
    /// is a same-model profile borrowed from another instance; in the
    /// simulation the segment means play that role (they are what a
    /// sibling's measurement converges to). Marked `origin = Prior` so
    /// admission and persistence can tell it from measured data; the
    /// online refiner converges it against the service's actual
    /// behaviour once it is serving.
    pub fn structural_profile(&self, key: crate::core::TaskKey) -> crate::profile::TaskProfile {
        let mut p = crate::profile::TaskProfile::new(key);
        for seg in &self.segments {
            let id = crate::core::KernelId::new(seg.kernel_name, seg.grid, seg.block);
            // Async kernels back-to-back on the device: no fillable gap.
            let gap = seg.sync.then_some(seg.gap);
            for _ in 0..seg.count {
                p.record(&id, seg.exec, gap);
            }
        }
        p.finish_run(self.kernel_count() as usize);
        p.origin = crate::profile::ProfileOrigin::Prior;
        p
    }
}

/// Calibrated specs (exec/gap in µs). Approximate structure:
///
/// | model                      | kernels | exec(ms) | sync idle(ms) | JCT(ms) | gap share |
/// |----------------------------|---------|----------|---------------|---------|-----------|
/// | keypointrcnn_resnet50_fpn  |   ~790  |   12.9   |     17.9      |  ~30.8  |   0.58    |
/// | maskrcnn_resnet50_fpn      |   ~870  |   15.6   |     18.8      |  ~34.4  |   0.55    |
/// | fasterrcnn_resnet50_fpn    |   ~720  |   11.6   |     12.9      |  ~24.5  |   0.53    |
/// | fcos_resnet50_fpn          |   ~650  |   10.4   |      9.2      |  ~19.6  |   0.47    |
/// | fcn_resnet50               |   ~240  |   13.9   |      1.7      |  ~15.6  |   0.11    |
/// | fcn_resnet101              |   ~410  |   21.4   |      1.7      |  ~23.1  |   0.07    |
/// | deeplabv3_resnet50         |   ~280  |   12.2   |      2.0      |  ~14.2  |   0.14    |
/// | deeplabv3_resnet101        |   ~450  |   18.6   |      2.0      |  ~20.6  |   0.10    |
/// | resnet50                   |   ~176  |    5.1   |      0.7      |   ~5.8  |   0.12    |
/// | resnet101                  |   ~346  |    9.7   |      0.7      |  ~10.4  |   0.07    |
/// | vgg16                      |    ~46  |    5.5   |      0.3      |   ~5.8  |   0.05    |
/// | alexnet                    |    ~24  |    1.05  |      0.36     |   ~1.4  |   0.26    |
/// | googlenet                  |   ~153  |    3.3   |      0.7      |   ~4.0  |   0.18    |
fn spec_for(kind: ModelKind) -> ModelSpec {
    use ModelKind::*;
    let segments = match kind {
        KeypointRcnnResnet50Fpn => vec![
            Segment::conv("resnet50_fpn_backbone_conv", 160, 34.0, 512, 256),
            Segment::conv("fpn_lateral_topdown", 40, 22.0, 128, 256),
            Segment::conv("rpn_head_conv", 60, 16.0, 256, 128),
            Segment::stall("rpn_proposal_filter", 8, 15.0, 700.0),
            Segment::stall("nms_kernel", 12, 10.0, 600.0),
            Segment::conv("roi_align", 180, 8.0, 96, 128),
            Segment::conv("box_head_gemm", 90, 15.0, 256, 256),
            Segment::conv("keypoint_head_conv", 230, 11.0, 128, 128),
            Segment::stall("keypoint_postprocess", 10, 8.0, 450.0),
        ],
        MaskrcnnResnet50Fpn => vec![
            Segment::conv("resnet50_fpn_backbone_conv", 160, 34.0, 512, 256),
            Segment::conv("fpn_lateral_topdown", 40, 22.0, 128, 256),
            Segment::conv("rpn_head_conv", 60, 16.0, 256, 128),
            Segment::stall("rpn_proposal_filter", 8, 15.0, 700.0),
            Segment::stall("nms_kernel", 12, 10.0, 600.0),
            Segment::conv("roi_align", 160, 8.0, 96, 128),
            Segment::conv("box_head_gemm", 90, 15.0, 256, 256),
            Segment::conv("mask_head_conv", 220, 20.0, 192, 128),
            Segment::stall("mask_postprocess", 12, 8.0, 500.0),
        ],
        FasterrcnnResnet50Fpn => vec![
            Segment::conv("resnet50_fpn_backbone_conv", 160, 34.0, 512, 256),
            Segment::conv("fpn_lateral_topdown", 40, 22.0, 128, 256),
            Segment::conv("rpn_head_conv", 60, 16.0, 256, 128),
            Segment::stall("rpn_proposal_filter", 8, 15.0, 700.0),
            Segment::stall("nms_kernel", 10, 10.0, 550.0),
            Segment::conv("roi_align", 160, 8.0, 96, 128),
            Segment::conv("box_head_gemm", 150, 12.0, 256, 256),
            Segment::stall("box_postprocess", 6, 8.0, 300.0),
        ],
        FcosResnet50Fpn => vec![
            Segment::conv("resnet50_fpn_backbone_conv", 160, 34.0, 512, 256),
            Segment::conv("fpn_lateral_topdown", 40, 22.0, 128, 256),
            Segment::conv("fcos_head_conv", 300, 8.0, 128, 128),
            Segment::conv("fcos_centerness", 130, 4.0, 64, 128),
            Segment::stall("nms_kernel", 16, 6.0, 575.0),
        ],
        FcnResnet50 => vec![
            Segment::conv("resnet50_backbone_conv", 170, 57.0, 512, 256),
            Segment::conv("fcn_head_conv", 40, 72.0, 384, 256),
            Segment::conv("bilinear_upsample", 27, 45.0, 256, 256),
            Segment::stall("segmap_readback", 3, 25.0, 550.0),
        ],
        FcnResnet101 => vec![
            Segment::conv("resnet101_backbone_conv", 340, 51.0, 512, 256),
            Segment::conv("fcn_head_conv", 40, 72.0, 384, 256),
            Segment::conv("bilinear_upsample", 27, 45.0, 256, 256),
            Segment::stall("segmap_readback", 3, 25.0, 550.0),
        ],
        Deeplabv3Resnet50 => vec![
            Segment::conv("resnet50_backbone_conv", 170, 44.0, 512, 256),
            Segment::conv("aspp_atrous_conv", 70, 55.0, 384, 256),
            Segment::conv("bilinear_upsample", 36, 20.0, 256, 256),
            Segment::stall("segmap_readback", 4, 20.0, 500.0),
        ],
        Deeplabv3Resnet101 => vec![
            Segment::conv("resnet101_backbone_conv", 340, 38.0, 512, 256),
            Segment::conv("aspp_atrous_conv", 70, 55.0, 384, 256),
            Segment::conv("bilinear_upsample", 36, 20.0, 256, 256),
            Segment::stall("segmap_readback", 4, 20.0, 500.0),
        ],
        Resnet50 => vec![
            Segment::conv("resnet50_conv_gemm", 110, 36.0, 512, 256),
            Segment::conv("batchnorm_relu", 55, 16.0, 256, 256),
            Segment::conv("fc_gemm", 9, 30.0, 128, 256),
            Segment::stall("logits_readback", 2, 10.0, 350.0),
        ],
        Resnet101 => vec![
            Segment::conv("resnet101_conv_gemm", 220, 34.0, 512, 256),
            Segment::conv("batchnorm_relu", 115, 18.0, 256, 256),
            Segment::conv("fc_gemm", 9, 30.0, 128, 256),
            Segment::stall("logits_readback", 2, 10.0, 350.0),
        ],
        Vgg16 => vec![
            Segment::conv("vgg_conv_gemm", 26, 172.0, 1024, 256),
            Segment::conv("maxpool", 10, 36.0, 256, 256),
            Segment::conv("fc_gemm", 9, 72.0, 512, 256),
            Segment::stall("logits_readback", 1, 15.0, 300.0),
        ],
        Alexnet => vec![
            Segment::conv("alexnet_conv_gemm", 10, 68.0, 512, 256),
            Segment::conv("maxpool", 6, 18.0, 128, 256),
            Segment::conv("fc_gemm", 6, 42.0, 256, 256),
            Segment::stall("logits_readback", 2, 5.0, 180.0),
        ],
        Googlenet => vec![
            Segment::conv("inception_conv_gemm", 110, 22.0, 256, 256),
            Segment::conv("inception_concat", 30, 14.0, 128, 256),
            Segment::conv("fc_gemm", 10, 22.0, 128, 256),
            Segment::stall("logits_readback", 3, 8.0, 230.0),
        ],
    };
    ModelSpec { kind, segments }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_matches_position_in_all() {
        for (i, kind) in ModelKind::ALL.iter().enumerate() {
            assert_eq!(kind.index(), i, "{kind} index drifted from ALL order");
        }
        assert_eq!(ModelKind::COUNT, ModelKind::ALL.len());
    }

    #[test]
    fn all_models_have_specs() {
        for kind in ModelKind::ALL {
            let spec = kind.spec();
            assert!(spec.kernel_count() > 0, "{kind} has no kernels");
            assert!(spec.mean_exec() > Duration::ZERO);
            assert!(
                spec.sync_points() > 0,
                "{kind} needs at least one sync point (task-boundary readback)"
            );
            assert!(spec.gap_share() > 0.0 && spec.gap_share() < 1.0);
        }
    }

    #[test]
    fn detectors_are_gappier_than_classifiers() {
        let kp = ModelKind::KeypointRcnnResnet50Fpn.spec().gap_share();
        let mask = ModelKind::MaskrcnnResnet50Fpn.spec().gap_share();
        let vgg = ModelKind::Vgg16.spec().gap_share();
        let rn101 = ModelKind::Resnet101.spec().gap_share();
        assert!(kp > 0.45, "keypointrcnn gap share {kp}");
        assert!(mask > 0.45, "maskrcnn gap share {mask}");
        assert!(vgg < 0.12, "vgg16 gap share {vgg}");
        assert!(rn101 < 0.15, "resnet101 gap share {rn101}");
    }

    #[test]
    fn detectors_have_many_fillable_stalls() {
        // FIKIT needs gaps > ε = 0.1ms to fill; the detector stalls are
        // the fillable resource.
        for kind in [
            ModelKind::KeypointRcnnResnet50Fpn,
            ModelKind::MaskrcnnResnet50Fpn,
            ModelKind::FasterrcnnResnet50Fpn,
            ModelKind::FcosResnet50Fpn,
        ] {
            let spec = kind.spec();
            assert!(spec.sync_points() >= 15, "{kind}: {} stalls", spec.sync_points());
            for seg in spec.segments.iter().filter(|s| s.sync) {
                assert!(
                    seg.gap > Duration::from_micros(150),
                    "{kind}/{}: sync gap {} too small to fill",
                    seg.kernel_name,
                    seg.gap
                );
            }
        }
    }

    #[test]
    fn jct_calibration_order_of_magnitude() {
        // Sanity-band checks against public RTX-3090 latencies.
        let ms = |k: ModelKind| k.spec().mean_jct().as_millis_f64();
        assert!((20.0..45.0).contains(&ms(ModelKind::KeypointRcnnResnet50Fpn)));
        assert!((25.0..50.0).contains(&ms(ModelKind::MaskrcnnResnet50Fpn)));
        assert!((3.0..10.0).contains(&ms(ModelKind::Resnet50)));
        assert!((0.8..3.0).contains(&ms(ModelKind::Alexnet)));
        assert!((3.0..10.0).contains(&ms(ModelKind::Vgg16)));
        // resnet101 roughly 2x resnet50.
        let r = ms(ModelKind::Resnet101) / ms(ModelKind::Resnet50);
        assert!((1.4..2.6).contains(&r), "r101/r50 = {r}");
    }

    /// The cold-start prior covers exactly the kernels a service's
    /// traces will launch, with the segment means as predictions.
    #[test]
    fn structural_prior_matches_trace_kernels() {
        use crate::core::TaskKey;
        let spec = ModelKind::KeypointRcnnResnet50Fpn.spec();
        let prior = spec.structural_profile(TaskKey::new("svc"));
        assert_eq!(prior.origin, crate::profile::ProfileOrigin::Prior);
        assert!(prior.is_ready(1));
        assert_eq!(prior.num_unique(), spec.segments.len());
        for seg in &spec.segments {
            let id = crate::core::KernelId::new(seg.kernel_name, seg.grid, seg.block);
            assert_eq!(prior.sk(&id), Some(seg.exec));
            if seg.sync {
                assert_eq!(prior.sg(&id), Some(seg.gap));
            } else {
                assert_eq!(prior.sg(&id), None);
            }
        }
    }

    #[test]
    fn name_round_trip() {
        for kind in ModelKind::ALL {
            assert_eq!(ModelKind::from_name(kind.name()), Some(kind));
            assert_eq!(kind.name().parse::<ModelKind>().unwrap(), kind);
        }
        assert!(ModelKind::from_name("nope").is_none());
    }
}
