//! SK/SG statistics (paper §3.2, "Data acquisition and statistical
//! output during the measurement phase").
//!
//! For each unique kernel ID `j` in the set `S_UID`:
//!
//! ```text
//! SK_j = Σ_t Σ_i K_{ID_{t,i}} · δ(ID_{t,i}, j)  /  Σ_t Σ_i δ(ID_{t,i}, j)
//! SG_j = Σ_t Σ_i G_{ID_{t,i}} · δ(ID_{t,i}, j)  /  Σ_t Σ_i δ(ID_{t,i}, j)
//! ```
//!
//! i.e. plain Kronecker-delta means over every occurrence of the ID,
//! within and across the `T` measured runs. We additionally keep min/max
//! and variance (Welford) — the scheduler only consumes the means, but the
//! extra moments power the stability analyses (Table 3) and tests.

use crate::core::{Duration, KernelId, TaskKey};
use crate::util::json::Json;
use std::collections::HashMap;

/// Running summary of a stream of durations (count, mean, M2, min, max).
/// Uses Welford's online algorithm: numerically stable, single pass.
#[derive(Debug, Clone, PartialEq)]
pub struct StatSummary {
    pub count: u64,
    pub mean_ns: f64,
    m2: f64,
    pub min_ns: u64,
    pub max_ns: u64,
}

impl Default for StatSummary {
    fn default() -> StatSummary {
        StatSummary::new()
    }
}

impl StatSummary {
    pub fn new() -> StatSummary {
        StatSummary {
            count: 0,
            mean_ns: 0.0,
            m2: 0.0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }

    /// Record one observation.
    pub fn record(&mut self, d: Duration) {
        let x = d.nanos() as f64;
        self.count += 1;
        let delta = x - self.mean_ns;
        self.mean_ns += delta / self.count as f64;
        self.m2 += delta * (x - self.mean_ns);
        self.min_ns = self.min_ns.min(d.nanos());
        self.max_ns = self.max_ns.max(d.nanos());
    }

    /// Mean as a [`Duration`] (rounded to ns). Zero if empty.
    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            Duration::from_nanos(self.mean_ns.round().max(0.0) as u64)
        }
    }

    /// Population variance in ns². Zero if fewer than 2 samples.
    pub fn variance(&self) -> f64 {
        if self.count < 2 {
            0.0
        } else {
            self.m2 / self.count as f64
        }
    }

    /// Population standard deviation in ns.
    pub fn stddev_ns(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Coefficient of variation (σ/μ). Zero for an empty/degenerate stream.
    pub fn cv(&self) -> f64 {
        if self.mean_ns <= 0.0 {
            0.0
        } else {
            self.stddev_ns() / self.mean_ns
        }
    }

    /// Serialize to JSON (persistence format of the profile store).
    /// An empty summary serializes as `{count: 0}` (its sentinel
    /// `min_ns = u64::MAX` is not representable as a JSON int).
    pub fn to_json(&self) -> Json {
        if self.count == 0 {
            return Json::obj().set("count", 0u64);
        }
        Json::obj()
            .set("count", self.count)
            .set("mean_ns", self.mean_ns)
            .set("m2", self.m2)
            .set("min_ns", self.min_ns)
            .set("max_ns", self.max_ns)
    }

    /// Parse from JSON.
    pub fn from_json(v: &Json) -> crate::core::Result<StatSummary> {
        if v.req_u64("count")? == 0 {
            return Ok(StatSummary::new());
        }
        Ok(StatSummary {
            count: v.req_u64("count")?,
            mean_ns: v.req_f64("mean_ns")?,
            m2: v.req_f64("m2")?,
            min_ns: v.req_u64("min_ns")?,
            max_ns: v.req_u64("max_ns")?,
        })
    }

    /// Build a summary from precomputed moments — the online refiner's
    /// bridge from EWMA estimates into the persistable store format
    /// (`profile/online.rs`; min/max degenerate to the mean since the
    /// EWMA does not track extremes).
    pub fn from_moments(count: u64, mean_ns: f64, variance: f64) -> StatSummary {
        let mean_ns = mean_ns.max(0.0);
        StatSummary {
            count,
            mean_ns,
            m2: variance.max(0.0) * count as f64,
            min_ns: mean_ns.round() as u64,
            max_ns: mean_ns.round() as u64,
        }
    }

    /// Merge another summary into this one (parallel-merge form of
    /// Welford; used when combining per-run partials).
    pub fn merge(&mut self, other: &StatSummary) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            *self = other.clone();
            return;
        }
        let n1 = self.count as f64;
        let n2 = other.count as f64;
        let delta = other.mean_ns - self.mean_ns;
        let total = n1 + n2;
        self.mean_ns += delta * n2 / total;
        self.m2 += other.m2 + delta * delta * n1 * n2 / total;
        self.count += other.count;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }
}

/// Execution-time and following-gap statistics for one kernel ID.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct KernelStats {
    /// `SK_j` accumulator — device execution time.
    pub exec: StatSummary,
    /// `SG_j` accumulator — device idle gap *after* this kernel.
    pub gap: StatSummary,
}

/// The profiled result of one service: `TaskKey = (SK, SG)` in the
/// paper's notation, i.e. per-unique-kernel-ID statistics gathered over
/// `T` measurement runs.
///
/// Storage is a dense **slab**: kernel ids live in `ids` (append-only,
/// slot = local handle), stats in the parallel `stats` vector, and
/// `index` maps a [`KernelId`] to its slot. Lookups hash the structured
/// id directly — no canonical-string allocation anywhere near a lookup;
/// canonical strings exist only inside [`TaskProfile::to_json`] /
/// [`TaskProfile::from_json`] (DESIGN.md §Perf).
#[derive(Debug, Clone)]
pub struct TaskProfile {
    pub task_key: TaskKey,
    /// Number of measured runs `T` that produced this profile.
    pub runs: u32,
    /// Refinement version: 0 for a freshly measured profile, bumped by
    /// every online-refinement publish (DESIGN.md §9; persisted since
    /// store format v2 — see `rust/docs/profile-format.md`).
    pub epoch: u64,
    /// Provenance of the numbers (measured / refined / cold-start prior).
    pub origin: crate::profile::ProfileOrigin,
    /// Slab of unique kernel ids, in first-observation order.
    ids: Vec<KernelId>,
    /// Per-kernel statistics, parallel to `ids`.
    stats: Vec<KernelStats>,
    /// Kernel id → slab slot.
    index: HashMap<KernelId, u32>,
    /// Mean number of kernels per run (used for sanity checks / metrics).
    pub mean_kernels_per_run: f64,
}

impl TaskProfile {
    pub fn new(task_key: TaskKey) -> TaskProfile {
        TaskProfile {
            task_key,
            runs: 0,
            epoch: 0,
            origin: crate::profile::ProfileOrigin::Measured,
            ids: Vec::new(),
            stats: Vec::new(),
            index: HashMap::new(),
            mean_kernels_per_run: 0.0,
        }
    }

    /// Slab slot of a kernel id, if it was ever observed.
    #[inline]
    fn slot(&self, kernel: &KernelId) -> Option<usize> {
        self.index.get(kernel).map(|&s| s as usize)
    }

    fn slot_or_insert(&mut self, kernel: &KernelId) -> usize {
        if let Some(s) = self.slot(kernel) {
            return s;
        }
        let s = self.ids.len();
        self.ids.push(kernel.clone());
        self.stats.push(KernelStats::default());
        self.index.insert(kernel.clone(), s as u32);
        s
    }

    /// Record one kernel occurrence: its execution time and, if it was
    /// followed by another kernel in the same run, the idle gap after it.
    pub fn record(&mut self, kernel: &KernelId, exec: Duration, gap_after: Option<Duration>) {
        let s = self.slot_or_insert(kernel);
        let entry = &mut self.stats[s];
        entry.exec.record(exec);
        if let Some(g) = gap_after {
            entry.gap.record(g);
        }
    }

    /// Mark one full measured run complete (`t`-th of `T`), with the
    /// number of kernels it contained.
    pub fn finish_run(&mut self, kernels_in_run: usize) {
        let n = self.runs as f64;
        self.mean_kernels_per_run =
            (self.mean_kernels_per_run * n + kernels_in_run as f64) / (n + 1.0);
        self.runs += 1;
    }

    /// The set of unique kernel IDs, `S_UID`, in first-observation order.
    /// (Clones are `Arc` refcount bumps.)
    pub fn unique_ids(&self) -> impl Iterator<Item = KernelId> + '_ {
        self.ids.iter().cloned()
    }

    /// Number of unique kernel IDs, `|S_UID|`.
    pub fn num_unique(&self) -> usize {
        self.ids.len()
    }

    /// `SK_j`: predicted execution time for kernel `j`. `None` if the
    /// kernel was never observed during measurement.
    pub fn sk(&self, kernel: &KernelId) -> Option<Duration> {
        self.slot(kernel).map(|s| self.stats[s].exec.mean())
    }

    /// `SG_j`: predicted idle gap after kernel `j`.
    pub fn sg(&self, kernel: &KernelId) -> Option<Duration> {
        self.slot(kernel)
            .filter(|&s| self.stats[s].gap.count > 0)
            .map(|s| self.stats[s].gap.mean())
    }

    /// Full statistics for a kernel id.
    pub fn stats_for(&self, kernel: &KernelId) -> Option<&KernelStats> {
        self.slot(kernel).map(|s| &self.stats[s])
    }

    /// Overwrite (or insert) a kernel's statistics — the online
    /// refiner's publish path installs converged sharing-stage
    /// estimates here (`profile/online.rs`).
    pub fn set_kernel_stats(&mut self, kernel: &KernelId, stats: KernelStats) {
        let s = self.slot_or_insert(kernel);
        self.stats[s] = stats;
    }

    /// Whether this profile has enough runs to be used for sharing-stage
    /// scheduling. The paper uses `T ∈ [10, 1000]`.
    pub fn is_ready(&self, min_runs: u32) -> bool {
        self.runs >= min_runs && !self.stats.is_empty()
    }

    // ----- JSON persistence (see profile/store.rs) -----

    /// Serialize to a JSON value. Kernels are keyed by canonical string,
    /// sorted, so output is byte-stable regardless of observation order —
    /// this is the only place (besides [`TaskProfile::from_json`]) where
    /// canonical strings are materialized.
    pub fn to_json(&self) -> Json {
        let mut entries: Vec<(String, &KernelStats)> = self
            .ids
            .iter()
            .zip(&self.stats)
            .map(|(id, v)| (id.canonical(), v))
            .collect();
        entries.sort_by(|(a, _), (b, _)| a.cmp(b));
        let mut stats = Json::obj();
        for (k, v) in entries {
            stats = stats.set(
                &k,
                Json::obj()
                    .set("exec", v.exec.to_json())
                    .set("gap", v.gap.to_json()),
            );
        }
        Json::obj()
            .set("task_key", self.task_key.as_str())
            .set("runs", self.runs)
            .set("epoch", self.epoch)
            .set("origin", self.origin.as_str())
            .set("mean_kernels_per_run", self.mean_kernels_per_run)
            .set("stats", stats)
    }

    /// Parse from a JSON value. Kernels enter the slab in sorted-canonical
    /// order (the JSON object's key order), so a freshly-loaded profile
    /// has a deterministic slab layout.
    pub fn from_json(v: &Json) -> crate::core::Result<TaskProfile> {
        let mut profile = TaskProfile::new(TaskKey::new(v.req_str("task_key")?));
        if let Some(obj) = v.require("stats")?.as_obj() {
            for (k, entry) in obj {
                let id = KernelId::from_canonical(k).ok_or_else(|| {
                    crate::core::Error::Parse(format!("bad canonical kernel id {k:?}"))
                })?;
                let s = profile.slot_or_insert(&id);
                profile.stats[s] = KernelStats {
                    exec: StatSummary::from_json(entry.require("exec")?)?,
                    gap: StatSummary::from_json(entry.require("gap")?)?,
                };
            }
        }
        profile.runs = v.req_u64("runs")? as u32;
        // Format v1 predates epochs/origins (profile-format.md §compat):
        // absent fields default to a freshly measured profile.
        profile.epoch = v.get("epoch").and_then(Json::as_u64).unwrap_or(0);
        profile.origin = v
            .get("origin")
            .and_then(Json::as_str)
            .and_then(crate::profile::ProfileOrigin::parse)
            .unwrap_or_default();
        profile.mean_kernels_per_run = v.req_f64("mean_kernels_per_run")?;
        Ok(profile)
    }

    /// Merge another profile for the same task key (e.g. partials from
    /// parallel measurement shards).
    pub fn merge(&mut self, other: &TaskProfile) {
        debug_assert_eq!(self.task_key, other.task_key);
        let n1 = self.runs as f64;
        let n2 = other.runs as f64;
        if n1 + n2 > 0.0 {
            self.mean_kernels_per_run = (self.mean_kernels_per_run * n1
                + other.mean_kernels_per_run * n2)
                / (n1 + n2);
        }
        self.runs += other.runs;
        for (id, v) in other.ids.iter().zip(&other.stats) {
            let s = self.slot_or_insert(id);
            let e = &mut self.stats[s];
            e.exec.merge(&v.exec);
            e.gap.merge(&v.gap);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Dim3;

    fn kid(name: &str) -> KernelId {
        KernelId::new(name, Dim3::x(4), Dim3::x(128))
    }

    #[test]
    fn stat_summary_mean_var() {
        let mut s = StatSummary::new();
        for v in [2u64, 4, 4, 4, 5, 5, 7, 9] {
            s.record(Duration::from_nanos(v));
        }
        assert_eq!(s.count, 8);
        assert!((s.mean_ns - 5.0).abs() < 1e-9);
        assert!((s.variance() - 4.0).abs() < 1e-9);
        assert!((s.stddev_ns() - 2.0).abs() < 1e-9);
        assert_eq!(s.min_ns, 2);
        assert_eq!(s.max_ns, 9);
        assert!((s.cv() - 0.4).abs() < 1e-9);
    }

    #[test]
    fn stat_summary_merge_equals_sequential() {
        let vals = [10u64, 20, 30, 40, 50, 60, 70];
        let mut all = StatSummary::new();
        for v in vals {
            all.record(Duration::from_nanos(v));
        }
        let mut a = StatSummary::new();
        let mut b = StatSummary::new();
        for v in &vals[..3] {
            a.record(Duration::from_nanos(*v));
        }
        for v in &vals[3..] {
            b.record(Duration::from_nanos(*v));
        }
        a.merge(&b);
        assert_eq!(a.count, all.count);
        assert!((a.mean_ns - all.mean_ns).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-6);
    }

    /// Reproduces the paper's worked example: a task measured T=2 times,
    /// kernel id `j` occurring twice per run; SK_j is the mean of the four
    /// occurrences.
    #[test]
    fn sk_is_kronecker_delta_mean_across_runs() {
        let mut p = TaskProfile::new(TaskKey::new("svc"));
        let j = kid("j");
        let other = kid("other");
        // Run 1: j at positions 1 and 5.
        p.record(&j, Duration::from_micros(100), Some(Duration::from_micros(10)));
        p.record(&other, Duration::from_micros(7), Some(Duration::from_micros(1)));
        p.record(&j, Duration::from_micros(200), Some(Duration::from_micros(20)));
        p.finish_run(3);
        // Run 2: j at positions 2 and 6.
        p.record(&j, Duration::from_micros(300), Some(Duration::from_micros(30)));
        p.record(&j, Duration::from_micros(400), None); // last kernel: no gap after
        p.finish_run(2);

        assert_eq!(p.runs, 2);
        assert_eq!(p.num_unique(), 2);
        assert_eq!(p.sk(&j).unwrap(), Duration::from_micros(250));
        // Gap mean over the three observed gaps (last kernel has none).
        assert_eq!(p.sg(&j).unwrap(), Duration::from_micros(20));
        assert_eq!(p.sk(&kid("missing")), None);
        assert!((p.mean_kernels_per_run - 2.5).abs() < 1e-9);
    }

    #[test]
    fn sg_none_when_gap_never_observed() {
        let mut p = TaskProfile::new(TaskKey::new("svc"));
        let j = kid("tail");
        p.record(&j, Duration::from_micros(5), None);
        p.finish_run(1);
        assert!(p.sk(&j).is_some());
        assert_eq!(p.sg(&j), None);
    }

    #[test]
    fn readiness_threshold() {
        let mut p = TaskProfile::new(TaskKey::new("svc"));
        assert!(!p.is_ready(1));
        p.record(&kid("k"), Duration::from_micros(5), None);
        p.finish_run(1);
        assert!(p.is_ready(1));
        assert!(!p.is_ready(10));
    }

    #[test]
    fn profile_merge() {
        let j = kid("j");
        let mut a = TaskProfile::new(TaskKey::new("svc"));
        a.record(&j, Duration::from_micros(10), Some(Duration::from_micros(2)));
        a.finish_run(1);
        let mut b = TaskProfile::new(TaskKey::new("svc"));
        b.record(&j, Duration::from_micros(30), Some(Duration::from_micros(4)));
        b.finish_run(1);
        a.merge(&b);
        assert_eq!(a.runs, 2);
        assert_eq!(a.sk(&j).unwrap(), Duration::from_micros(20));
        assert_eq!(a.sg(&j).unwrap(), Duration::from_micros(3));
    }

    #[test]
    fn unique_ids_round_trip() {
        let mut p = TaskProfile::new(TaskKey::new("svc"));
        p.record(&kid("a"), Duration::from_micros(1), None);
        p.record(&kid("b"), Duration::from_micros(1), None);
        p.finish_run(2);
        let mut names: Vec<String> = p.unique_ids().map(|k| k.name.to_string()).collect();
        names.sort();
        assert_eq!(names, vec!["a", "b"]);
    }
}
