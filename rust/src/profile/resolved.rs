//! Attach-time profile resolution: [`TaskProfile`] → [`ResolvedProfile`].
//!
//! The scheduler consults profiled data (`SK` per enqueue, `SG` per
//! holder completion) on every kernel event. A [`ResolvedProfile`] is the
//! profile flattened into a handle-sorted table, built **once** when a
//! service attaches to a GPU (`coordinator/driver.rs`), so steady-state
//! lookups are a short binary probe over the service's own kernels —
//! zero hashing, zero allocation (DESIGN.md §Perf).
//!
//! Handle assignment is deterministic: kernels are interned in sorted
//! canonical order, independent of the profile's in-memory observation
//! order. A profile saved to JSON and loaded back therefore resolves to
//! the **same handles** (see the stability test below) — side tables
//! built before a persistence round trip stay valid after it.

use super::statistics::TaskProfile;
use crate::core::{Duration, Interner, KernelHandle};

/// One service's predictions, keyed by interned kernel handle.
///
/// Storage is a handle-sorted compact table — O(k) memory for a
/// k-kernel service regardless of how many kernels the sim-global
/// interner has minted (a dense global-handle-indexed table would make
/// every *live* profile scale with total-services-ever-attached in
/// churn runs). Lookups are a binary search over the service's own
/// `(handle, SK, SG)` triples: k ≈ tens, so ~5 branch-predictable
/// probes of 24-byte rows — no hashing, no allocation.
#[derive(Debug, Clone, Default)]
pub struct ResolvedProfile {
    /// Sorted by handle: `(handle, SK, SG)`; `SG` is `None` when the
    /// kernel never had a following gap.
    entries: Vec<(KernelHandle, Duration, Option<Duration>)>,
    /// Snapshot version: 0 for the attach-time offline resolution,
    /// bumped by every online-refinement publish (DESIGN.md §9 — the
    /// "profile epoch" of the double-buffer swap).
    epoch: u64,
}

impl ResolvedProfile {
    /// Flatten `profile` against `interner`, minting handles for any
    /// kernel ids not seen before. This is the one place profile lookup
    /// still does string work (sorting canonicals for determinism) — it
    /// runs at attach time, never per launch.
    pub fn resolve(profile: &TaskProfile, interner: &mut Interner) -> ResolvedProfile {
        let mut ids: Vec<_> = profile.unique_ids().collect();
        ids.sort_by_cached_key(|id| id.canonical());
        let mut entries: Vec<(KernelHandle, Duration, Option<Duration>)> = ids
            .iter()
            .map(|id| {
                let h = interner.intern_kernel(id);
                let sk = profile.sk(id).expect("unique_ids entries have stats");
                (h, sk, profile.sg(id))
            })
            .collect();
        entries.sort_unstable_by_key(|&(h, _, _)| h);
        ResolvedProfile { entries, epoch: 0 }
    }

    /// Build a refreshed snapshot from already-handle-sorted rows — the
    /// online refiner's publish path (`profile/online.rs`).
    pub fn from_rows(
        rows: Vec<(KernelHandle, Duration, Option<Duration>)>,
        epoch: u64,
    ) -> ResolvedProfile {
        debug_assert!(
            rows.windows(2).all(|w| w[0].0 < w[1].0),
            "snapshot rows must be strictly handle-sorted"
        );
        ResolvedProfile {
            entries: rows,
            epoch,
        }
    }

    /// Snapshot version (0 = offline attach-time resolution).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Iterate `(handle, SK, SG)` rows in handle order (the refiner
    /// seeds its estimates from this).
    pub fn rows(
        &self,
    ) -> impl Iterator<Item = (KernelHandle, Duration, Option<Duration>)> + '_ {
        self.entries.iter().copied()
    }

    #[inline]
    fn row(&self, h: KernelHandle) -> Option<&(KernelHandle, Duration, Option<Duration>)> {
        self.entries
            .binary_search_by_key(&h, |&(eh, _, _)| eh)
            .ok()
            .map(|i| &self.entries[i])
    }

    /// Predicted execution time `SK` for an interned kernel.
    #[inline]
    pub fn sk(&self, h: KernelHandle) -> Option<Duration> {
        self.row(h).map(|&(_, sk, _)| sk)
    }

    /// Predicted following idle gap `SG` for an interned kernel.
    #[inline]
    pub fn sg(&self, h: KernelHandle) -> Option<Duration> {
        self.row(h).and_then(|&(_, _, sg)| sg)
    }

    /// Number of observed kernels in this resolution.
    pub fn observed(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dim3, KernelId, TaskKey};
    use crate::profile::ProfileStore;

    fn kid(name: &str) -> KernelId {
        KernelId::new(name, Dim3::x(8), Dim3::x(128))
    }

    fn profile(keys: &[(&str, u64, Option<u64>)]) -> TaskProfile {
        let mut p = TaskProfile::new(TaskKey::new("svc"));
        for (name, sk_us, sg_us) in keys {
            p.record(
                &kid(name),
                Duration::from_micros(*sk_us),
                sg_us.map(Duration::from_micros),
            );
        }
        p.finish_run(keys.len());
        p
    }

    #[test]
    fn resolves_sk_and_sg_by_handle() {
        let p = profile(&[("a", 100, Some(40)), ("b", 250, None)]);
        let mut interner = Interner::new();
        let rp = ResolvedProfile::resolve(&p, &mut interner);
        let ha = interner.kernel_handle(&kid("a")).unwrap();
        let hb = interner.kernel_handle(&kid("b")).unwrap();
        assert_eq!(rp.sk(ha), Some(Duration::from_micros(100)));
        assert_eq!(rp.sg(ha), Some(Duration::from_micros(40)));
        assert_eq!(rp.sk(hb), Some(Duration::from_micros(250)));
        assert_eq!(rp.sg(hb), None, "never-gapped kernel has no SG");
        assert_eq!(rp.observed(), 2);
        // A handle minted later (another service's kernel) is unobserved.
        let hc = interner.intern_kernel(&kid("c"));
        assert_eq!(rp.sk(hc), None);
        assert_eq!(rp.sk(KernelHandle::UNBOUND), None);
    }

    /// Satellite acceptance: interner handles are stable across a
    /// save/load of the profile store JSON. The slab order of a loaded
    /// profile differs from the measured one (sorted vs observation
    /// order); resolution must still mint identical handles.
    #[test]
    fn handles_stable_across_store_save_load() {
        // Observation order deliberately unsorted vs canonical order.
        let p = profile(&[("zeta", 10, Some(5)), ("alpha", 20, None), ("mid", 30, Some(1))]);
        let mut store = ProfileStore::new();
        store.insert(p);

        let dir = std::env::temp_dir().join(format!("fikit-rp-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("profiles.json");
        store.save(&path).unwrap();
        let loaded = ProfileStore::load(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();

        let key = TaskKey::new("svc");
        let mut i1 = Interner::new();
        let rp1 = ResolvedProfile::resolve(store.get(&key).unwrap(), &mut i1);
        let mut i2 = Interner::new();
        let rp2 = ResolvedProfile::resolve(loaded.get(&key).unwrap(), &mut i2);

        for name in ["zeta", "alpha", "mid"] {
            let h1 = i1.kernel_handle(&kid(name)).unwrap();
            let h2 = i2.kernel_handle(&kid(name)).unwrap();
            assert_eq!(h1, h2, "handle for {name} drifted across save/load");
            assert_eq!(rp1.sk(h1), rp2.sk(h2));
            assert_eq!(rp1.sg(h1), rp2.sg(h2));
        }
        assert_eq!(i1.kernel_count(), i2.kernel_count());
    }
}
