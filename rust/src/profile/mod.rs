//! Kernel measurement and profiling: offline (paper §3.2) plus online
//! sharing-stage refinement (DESIGN.md §9).
//!
//! FIKIT's core enabler is moving kernel measurement *offline*: a new
//! service first runs a bounded number of times in **measurement stage**
//! (exclusive GPU, per-kernel timing events, 20–80 % JCT overhead), which
//! produces per-[`KernelId`](crate::core::KernelId) statistics:
//!
//! * `SK_j` — mean execution time of kernels with ID `j` across `T` runs,
//! * `SG_j` — mean device idle gap following kernels with ID `j`.
//!
//! These are keyed by the service's [`TaskKey`](crate::core::TaskKey) and
//! persisted; all later invocations run in **sharing stage**, where the
//! scheduler predicts gaps from `SG` and kernel durations from `SK` with
//! zero per-kernel *timing-event* cost. The predictions are not frozen,
//! though: the [`OnlineRefiner`] keeps learning from the completion and
//! launch events the scheduler already sees in sharing stage, detects
//! drift against a confidence band, and republishes epoch-versioned
//! [`ResolvedProfile`] snapshots — still without re-inserting any
//! kernel-timing instrumentation (the refinement loop's accounted cost
//! is bounded against the paper's 5 % overhead budget; see ADR-002).

mod measurement;
mod online;
mod resolved;
mod statistics;
mod store;
mod symbols;

pub use measurement::{MeasurementConfig, MeasurementRecorder};
pub use online::{Ewma, KeyedRefiner, OnlineConfig, OnlineRefiner, ProfileOrigin, RefinerStats};
pub use resolved::ResolvedProfile;
pub use statistics::{KernelStats, StatSummary, TaskProfile};
pub use store::ProfileStore;
pub use symbols::{SymbolResolver, SymbolTableModel};
