//! Offline kernel measurement and profiling (paper §3.2).
//!
//! FIKIT's core enabler is moving kernel measurement *offline*: a new
//! service first runs a bounded number of times in **measurement stage**
//! (exclusive GPU, per-kernel timing events, 20–80 % JCT overhead), which
//! produces per-[`KernelId`](crate::core::KernelId) statistics:
//!
//! * `SK_j` — mean execution time of kernels with ID `j` across `T` runs,
//! * `SG_j` — mean device idle gap following kernels with ID `j`.
//!
//! These are keyed by the service's [`TaskKey`](crate::core::TaskKey) and
//! persisted; all later invocations run in **sharing stage** where the
//! scheduler predicts gaps from `SG` and kernel durations from `SK` with
//! zero per-kernel measurement cost.

mod measurement;
mod resolved;
mod statistics;
mod store;
mod symbols;

pub use measurement::{MeasurementConfig, MeasurementRecorder};
pub use resolved::ResolvedProfile;
pub use statistics::{KernelStats, StatSummary, TaskProfile};
pub use store::ProfileStore;
pub use symbols::{SymbolResolver, SymbolTableModel};
