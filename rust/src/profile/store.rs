//! Persistent profile store: `TaskKey → TaskProfile`, the paper's
//! "profiled data ... loaded into memory" that the FIKIT scheduler
//! consults at sharing time. JSON on disk, hash map in memory.

use super::statistics::TaskProfile;
use crate::core::{Error, Result, TaskKey};
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::Path;

/// Current on-disk format. v2 added per-profile `epoch` and `origin`
/// (online refinement); v1 files load with both defaulted — the full
/// format and compatibility rules live in `rust/docs/profile-format.md`.
const STORE_VERSION: u64 = 2;
const OLDEST_READABLE_VERSION: u64 = 1;

/// In-memory registry of measured task profiles.
#[derive(Debug, Default)]
pub struct ProfileStore {
    profiles: HashMap<TaskKey, TaskProfile>,
}

impl ProfileStore {
    pub fn new() -> ProfileStore {
        ProfileStore::default()
    }

    /// Insert (or replace) a profile. Returns the previous profile for
    /// the same key, if any.
    pub fn insert(&mut self, profile: TaskProfile) -> Option<TaskProfile> {
        self.profiles.insert(profile.task_key.clone(), profile)
    }

    /// Look up the profile for a service.
    pub fn get(&self, key: &TaskKey) -> Option<&TaskProfile> {
        self.profiles.get(key)
    }

    /// Look up, returning a typed error on miss (the scheduler treats a
    /// miss as "task must enter measurement stage").
    pub fn require(&self, key: &TaskKey) -> Result<&TaskProfile> {
        self.get(key)
            .ok_or_else(|| Error::MissingProfile(key.to_string()))
    }

    /// Whether a service already has a ready profile (≥ `min_runs`).
    pub fn has_ready(&self, key: &TaskKey, min_runs: u32) -> bool {
        self.get(key).is_some_and(|p| p.is_ready(min_runs))
    }

    pub fn len(&self) -> usize {
        self.profiles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.profiles.is_empty()
    }

    pub fn keys(&self) -> impl Iterator<Item = &TaskKey> {
        self.profiles.keys()
    }

    pub fn remove(&mut self, key: &TaskKey) -> Option<TaskProfile> {
        self.profiles.remove(key)
    }

    /// The store's on-disk document as a JSON value (profiles sorted by
    /// task key for deterministic bytes). [`ProfileStore::save`] writes
    /// this to a file; the daemon's journal snapshots embed it directly
    /// (ADR-004).
    pub fn to_json(&self) -> Json {
        let mut profiles: Vec<&TaskProfile> = self.profiles.values().collect();
        profiles.sort_by(|a, b| a.task_key.cmp(&b.task_key));
        Json::obj().set("version", STORE_VERSION).set(
            "profiles",
            Json::Arr(profiles.iter().map(|p| p.to_json()).collect()),
        )
    }

    /// Inverse of [`ProfileStore::to_json`], with the version gate every
    /// load path shares: outside
    /// `OLDEST_READABLE_VERSION..=STORE_VERSION` → `Error::Config`.
    pub fn from_json(doc: &Json) -> Result<ProfileStore> {
        let version = doc.req_u64("version")?;
        if !(OLDEST_READABLE_VERSION..=STORE_VERSION).contains(&version) {
            return Err(Error::Config(format!(
                "profile store version {version} unsupported \
                 (readable: {OLDEST_READABLE_VERSION}..={STORE_VERSION})"
            )));
        }
        let mut store = ProfileStore::new();
        for p in doc.req_arr("profiles")? {
            store.insert(TaskProfile::from_json(p)?);
        }
        Ok(store)
    }

    /// Serialize every profile to a JSON file (atomic: write + rename).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, self.to_json().encode_pretty())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load a store previously written by [`ProfileStore::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<ProfileStore> {
        let text = std::fs::read_to_string(path.as_ref())?;
        ProfileStore::from_json(&Json::parse(&text)?)
    }

    /// Fold `other` into `self`, keeping the higher-`epoch` profile per
    /// key (ties keep `self`). This is the snapshot-vs-journal precedence
    /// rule of daemon recovery (ADR-004): a journaled epoch bump must
    /// never be regressed by an older snapshot or startup file, mirroring
    /// the refiner's own never-regress contract.
    pub fn merge_newer(&mut self, other: ProfileStore) {
        for (key, profile) in other.profiles {
            match self.profiles.get(&key) {
                Some(existing) if existing.epoch >= profile.epoch => {}
                _ => {
                    self.profiles.insert(key, profile);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dim3, Duration, KernelId};

    fn profile(key: &str, runs: u32) -> TaskProfile {
        let mut p = TaskProfile::new(TaskKey::new(key));
        for _ in 0..runs {
            p.record(
                &KernelId::new("k", Dim3::x(2), Dim3::x(64)),
                Duration::from_micros(120),
                Some(Duration::from_micros(30)),
            );
            p.finish_run(1);
        }
        p
    }

    #[test]
    fn insert_get_require() {
        let mut s = ProfileStore::new();
        assert!(s.is_empty());
        s.insert(profile("svcA", 5));
        assert_eq!(s.len(), 1);
        assert!(s.get(&TaskKey::new("svcA")).is_some());
        assert!(s.require(&TaskKey::new("svcB")).is_err());
        assert!(s.has_ready(&TaskKey::new("svcA"), 5));
        assert!(!s.has_ready(&TaskKey::new("svcA"), 6));
    }

    fn temp_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("fikit-store-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn save_load_round_trip() {
        let dir = temp_dir("rt");
        let path = dir.join("profiles.json");
        let mut s = ProfileStore::new();
        s.insert(profile("svcA", 3));
        s.insert(profile("svcB", 7));
        s.save(&path).unwrap();

        let loaded = ProfileStore::load(&path).unwrap();
        assert_eq!(loaded.len(), 2);
        let a = loaded.get(&TaskKey::new("svcA")).unwrap();
        assert_eq!(a.runs, 3);
        let k = KernelId::new("k", Dim3::x(2), Dim3::x(64));
        assert_eq!(a.sk(&k).unwrap(), Duration::from_micros(120));
        assert_eq!(a.sg(&k).unwrap(), Duration::from_micros(30));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Format v1 (no `epoch`/`origin` fields) still loads, with both
    /// defaulted — the compatibility rule of profile-format.md.
    #[test]
    fn v1_store_loads_with_defaulted_epoch_and_origin() {
        let dir = temp_dir("v1");
        let path = dir.join("profiles.json");
        let v1 = r#"{
            "version": 1,
            "profiles": [{
                "task_key": "legacy",
                "runs": 4,
                "mean_kernels_per_run": 1.0,
                "stats": {
                    "k|g2x1x1|b64x1x1": {
                        "exec": {"count": 4, "mean_ns": 120000.0, "m2": 0.0,
                                 "min_ns": 120000, "max_ns": 120000},
                        "gap": {"count": 0}
                    }
                }
            }]
        }"#;
        std::fs::write(&path, v1).unwrap();
        let loaded = ProfileStore::load(&path).unwrap();
        let p = loaded.get(&TaskKey::new("legacy")).unwrap();
        assert_eq!(p.epoch, 0);
        assert_eq!(p.origin, crate::profile::ProfileOrigin::Measured);
        assert_eq!(p.runs, 4);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A refined profile's epoch and origin survive the round trip (the
    /// daemon's restart-persistence contract).
    #[test]
    fn epoch_and_origin_round_trip() {
        let dir = temp_dir("epoch");
        let path = dir.join("profiles.json");
        let mut s = ProfileStore::new();
        let mut p = profile("svcA", 3);
        p.epoch = 7;
        p.origin = crate::profile::ProfileOrigin::Refined;
        s.insert(p);
        s.save(&path).unwrap();
        let loaded = ProfileStore::load(&path).unwrap();
        let p = loaded.get(&TaskKey::new("svcA")).unwrap();
        assert_eq!(p.epoch, 7);
        assert_eq!(p.origin, crate::profile::ProfileOrigin::Refined);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn version_mismatch_rejected() {
        let dir = temp_dir("ver");
        let path = dir.join("profiles.json");
        std::fs::write(&path, r#"{"version": 99, "profiles": []}"#).unwrap();
        let err = ProfileStore::load(&path).unwrap_err();
        assert!(
            matches!(err, Error::Config(_)),
            "version 99 must be a Config error, got {err:?}"
        );
        assert!(
            err.to_string().contains("99"),
            "error names the offending version: {err}"
        );
        // Version 0 predates OLDEST_READABLE_VERSION: same gate.
        std::fs::write(&path, r#"{"version": 0, "profiles": []}"#).unwrap();
        assert!(matches!(ProfileStore::load(&path), Err(Error::Config(_))));
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Truncated / malformed JSON fails as a load error, never a panic
    /// and never a silently empty store.
    #[test]
    fn truncated_json_fails_loudly() {
        let dir = temp_dir("trunc");
        let path = dir.join("profiles.json");
        let mut full = ProfileStore::new();
        full.insert(profile("svcA", 3));
        full.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // Cut the valid document at several byte offsets, including a
        // mid-token cut and an empty file. (The document ends in `}\n`,
        // so the shortest truncation that actually breaks it drops two
        // bytes — the closing brace, not just the newline.)
        for cut in [0, 1, text.len() / 2, text.len() - 2] {
            std::fs::write(&path, &text[..cut]).unwrap();
            let err = ProfileStore::load(&path).unwrap_err();
            assert!(
                matches!(err, Error::Parse(_)),
                "cut at {cut} must be a Parse error, got {err:?}"
            );
        }
        // Valid JSON missing the required keys is also loud.
        std::fs::write(&path, r#"{"not_a_store": true}"#).unwrap();
        assert!(ProfileStore::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Snapshot-vs-journal epoch precedence: merging never regresses a
    /// profile to an older epoch, whichever side is newer — the daemon
    /// recovery contract (ADR-004), mirroring the shard-refiner restart
    /// test in `daemon/mod.rs`.
    #[test]
    fn merge_newer_never_regresses_epochs() {
        let mut loaded = ProfileStore::new();
        let mut p = profile("svcA", 3);
        p.epoch = 5;
        loaded.insert(p);
        let mut stale_only = profile("svcB", 2);
        stale_only.epoch = 1;
        loaded.insert(stale_only);

        let mut journaled = ProfileStore::new();
        let mut older = profile("svcA", 9);
        older.epoch = 2;
        journaled.insert(older);
        let mut newer_b = profile("svcB", 4);
        newer_b.epoch = 3;
        journaled.insert(newer_b);
        let fresh = profile("svcC", 1);
        journaled.insert(fresh);

        loaded.merge_newer(journaled);
        assert_eq!(
            loaded.get(&TaskKey::new("svcA")).unwrap().epoch,
            5,
            "older journaled epoch must not regress the loaded profile"
        );
        assert_eq!(loaded.get(&TaskKey::new("svcA")).unwrap().runs, 3);
        assert_eq!(
            loaded.get(&TaskKey::new("svcB")).unwrap().epoch,
            3,
            "newer journaled epoch wins"
        );
        assert_eq!(loaded.get(&TaskKey::new("svcB")).unwrap().runs, 4);
        assert!(loaded.get(&TaskKey::new("svcC")).is_some(), "new keys merge in");

        // Equal epochs keep the receiver (no churn on ties).
        let mut tie = ProfileStore::new();
        let mut t = profile("svcA", 100);
        t.epoch = 5;
        tie.insert(t);
        loaded.merge_newer(tie);
        assert_eq!(loaded.get(&TaskKey::new("svcA")).unwrap().runs, 3);
    }

    #[test]
    fn replace_returns_previous() {
        let mut s = ProfileStore::new();
        assert!(s.insert(profile("svcA", 1)).is_none());
        let prev = s.insert(profile("svcA", 9)).unwrap();
        assert_eq!(prev.runs, 1);
        assert_eq!(s.get(&TaskKey::new("svcA")).unwrap().runs, 9);
        assert!(s.remove(&TaskKey::new("svcA")).is_some());
        assert!(s.is_empty());
    }
}
