//! Online profile refinement during sharing stage (DESIGN.md §9).
//!
//! The offline measurement stage (paper §3.2) freezes `SK`/`SG` once,
//! but co-location interference shifts real gaps over a service's
//! lifetime. This module keeps learning from the completion and launch
//! events the scheduler *already* observes in sharing stage — no timing
//! events are re-inserted, so the per-kernel measurement cost stays
//! zero — and republishes predictions when they drift:
//!
//! * per-kernel **EWMA mean + EWMA variance** of observed execution
//!   times and post-kernel think gaps ([`Ewma`]);
//! * **drift detection**: an estimate whose EWMA mean leaves the
//!   confidence band around the currently-published prediction
//!   (`z` standard errors, floored) marks the service *drifted*;
//! * **epoch publishing**: a drifted service's predictions are
//!   flattened into a fresh [`ResolvedProfile`] snapshot with a bumped
//!   epoch; the driver swaps it into the scheduler between events
//!   (single writer, no locks — the double-buffer swap of DESIGN.md §9).
//!   Published predictions are **confidence-aware**: `SG` is shrunk and
//!   `SK` padded by `shrink` standard errors, so low-confidence fills
//!   cannot delay the high-priority holder.
//!
//! The steady-state observation path (no drift) is allocation-free —
//! binary probe + in-place float updates — and is gated by
//! `tests/hotpath_alloc.rs` alongside the scheduler hot path.
//!
//! Two frontends share the estimator math:
//!
//! * [`OnlineRefiner`] — handle-indexed, used by the per-GPU simulation
//!   driver (`coordinator/driver.rs`);
//! * [`KeyedRefiner`] — string-keyed, used at the wire boundary by the
//!   daemon shards (`daemon/shard.rs`) and the real-compute runtime
//!   engine, where launches never carry interned handles.

use super::resolved::ResolvedProfile;
use super::statistics::{KernelStats, StatSummary, TaskProfile};
use crate::core::{Duration, KernelHandle, KernelId, SimTime, TaskHandle, TaskKey};
use crate::metrics::WindowedError;
use crate::profile::ProfileStore;
use std::collections::HashMap;

/// Where a profile's numbers came from (persisted; see
/// `rust/docs/profile-format.md`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ProfileOrigin {
    /// Exclusive measurement stage (paper §3.2).
    #[default]
    Measured,
    /// Online sharing-stage refinement (this module).
    Refined,
    /// Cold-start prior borrowed from same-model knowledge instead of
    /// blocking on exclusive measurement (DESIGN.md §9).
    Prior,
}

impl ProfileOrigin {
    pub fn as_str(self) -> &'static str {
        match self {
            ProfileOrigin::Measured => "measured",
            ProfileOrigin::Refined => "refined",
            ProfileOrigin::Prior => "prior",
        }
    }

    pub fn parse(s: &str) -> Option<ProfileOrigin> {
        match s {
            "measured" => Some(ProfileOrigin::Measured),
            "refined" => Some(ProfileOrigin::Refined),
            "prior" => Some(ProfileOrigin::Prior),
            _ => None,
        }
    }
}

/// Tuning knobs of the online refinement loop.
#[derive(Debug, Clone)]
pub struct OnlineConfig {
    /// Master switch. Off (the default) reproduces the paper's frozen
    /// offline-profile behaviour exactly.
    pub enabled: bool,
    /// EWMA smoothing factor α ∈ (0, 1]: weight of the newest sample.
    pub alpha: f64,
    /// Confidence band half-width in standard-error units: an estimate
    /// drifts when its EWMA mean leaves `± z·stderr` around the
    /// currently-published prediction.
    pub z: f64,
    /// Observations a kernel needs before its estimate can declare
    /// drift or be published.
    pub min_samples: u32,
    /// Confidence shrink in standard-error units applied at publish
    /// time: published `SG = mean − shrink·stderr` (usable gap shrinks
    /// when variance is high), published `SK = mean + shrink·stderr`.
    pub shrink: f64,
    /// Band floor as a fraction of the published prediction (guards
    /// against hair-trigger drift on near-zero-variance estimates).
    pub band_floor_frac: f64,
    /// Modeled CPU cost of one observation (EWMA update + drift check)
    /// — the overhead-accounting unit charged against the paper's 5 %
    /// budget (ADR-002 has the derivation).
    pub cost_per_obs: Duration,
    /// Record per-observation gap-prediction error into fixed-size
    /// windows (diagnostics for the drift experiment; allocates one
    /// `Vec` slot per closed window, so keep it off on zero-alloc-gated
    /// paths).
    pub track_errors: bool,
    /// Gap observations per error window when `track_errors` is on.
    pub error_window: u32,
}

impl Default for OnlineConfig {
    fn default() -> OnlineConfig {
        OnlineConfig {
            enabled: false,
            alpha: 0.2,
            z: 3.0,
            min_samples: 8,
            shrink: 1.0,
            band_floor_frac: 0.10,
            cost_per_obs: Duration::from_nanos(150),
            track_errors: false,
            error_window: 64,
        }
    }
}

/// Exponentially-weighted running mean and variance.
///
/// `var` tracks the EWMA variance of the *samples*; the standard error
/// of the EWMA *mean* is `std · sqrt(α / (2 − α))` (the steady-state
/// variance ratio of an exponential filter), which is what the
/// confidence band and the publish-time shrink use.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Ewma {
    pub mean: f64,
    var: f64,
    pub n: u64,
}

impl Ewma {
    /// Fold in one observation.
    #[inline]
    pub fn observe(&mut self, x: f64, alpha: f64) {
        self.n += 1;
        if self.n == 1 {
            self.mean = x;
            self.var = 0.0;
            return;
        }
        let d = x - self.mean;
        self.mean += alpha * d;
        self.var = (1.0 - alpha) * (self.var + alpha * d * d);
    }

    /// EWMA standard deviation of the samples.
    #[inline]
    pub fn std(&self) -> f64 {
        self.var.max(0.0).sqrt()
    }

    /// Standard error of the EWMA mean.
    #[inline]
    pub fn stderr(&self, alpha: f64) -> f64 {
        self.std() * (alpha / (2.0 - alpha)).sqrt()
    }
}

/// Confidence band half-width around a published prediction `base_ns`.
#[inline]
fn band_ns(base_ns: f64, est: &Ewma, cfg: &OnlineConfig) -> f64 {
    (cfg.z * est.stderr(cfg.alpha))
        .max(base_ns * cfg.band_floor_frac)
        .max(1_000.0) // never tighter than 1 µs
}

/// Has `est` drifted outside the band around `base_ns`?
#[inline]
fn drifted(base_ns: f64, est: &Ewma, cfg: &OnlineConfig) -> bool {
    est.n >= cfg.min_samples as u64 && (est.mean - base_ns).abs() > band_ns(base_ns, est, cfg)
}

#[inline]
fn dur(ns: f64) -> Duration {
    Duration::from_nanos(ns.max(0.0).round() as u64)
}

/// Counters of one refiner (simulation driver or daemon shard).
#[derive(Debug, Clone, Default)]
pub struct RefinerStats {
    /// Execution-time observations folded in.
    pub exec_observations: u64,
    /// Post-kernel gap observations folded in.
    pub gap_observations: u64,
    /// Observations dropped because the kernel was not in the service's
    /// published profile (never measured, never priored).
    pub unknown_kernel: u64,
    /// Per-kernel estimates that left their confidence band.
    pub drifts: u64,
    /// Snapshots published (epoch swaps handed to the scheduler).
    pub snapshots_published: u64,
    /// Highest epoch published by any service.
    pub max_epoch: u64,
}

impl RefinerStats {
    /// Modeled CPU time spent refining (overhead accounting against the
    /// paper's 5 % budget; see ADR-002).
    pub fn modeled_overhead(&self, cfg: &OnlineConfig) -> Duration {
        cfg.cost_per_obs
            .scale((self.exec_observations + self.gap_observations) as f64)
    }
}

/// One kernel's online estimate next to its currently-published
/// prediction.
#[derive(Debug, Clone)]
struct Row {
    handle: KernelHandle,
    /// Currently-published `SK` (offline value until the first epoch).
    base_sk: Duration,
    /// Currently-published `SG`.
    base_sg: Option<Duration>,
    exec: Ewma,
    gap: Ewma,
}

/// Online estimates of one service, mirroring its [`ResolvedProfile`].
#[derive(Debug, Clone)]
struct ServiceRefiner {
    /// Sorted by handle (same order as the resolved profile).
    rows: Vec<Row>,
    /// Snapshots published so far (0 = still on the offline profile).
    epoch: u64,
    /// A row drifted since the last publish.
    dirty: bool,
}

impl ServiceRefiner {
    fn new(baseline: &ResolvedProfile) -> ServiceRefiner {
        ServiceRefiner {
            rows: baseline
                .rows()
                .map(|(handle, sk, sg)| Row {
                    handle,
                    base_sk: sk,
                    base_sg: sg,
                    exec: Ewma::default(),
                    gap: Ewma::default(),
                })
                .collect(),
            epoch: baseline.epoch(),
            dirty: false,
        }
    }

    #[inline]
    fn row_mut(&mut self, h: KernelHandle) -> Option<&mut Row> {
        self.rows
            .binary_search_by_key(&h, |r| r.handle)
            .ok()
            .map(|i| &mut self.rows[i])
    }

    /// Flatten the current estimates into a publishable snapshot and
    /// advance the epoch. Published values become the new drift
    /// baselines (hysteresis: the next drift must leave the band around
    /// the *refreshed* prediction).
    fn publish(&mut self, cfg: &OnlineConfig) -> ResolvedProfile {
        self.epoch += 1;
        let min = cfg.min_samples as u64;
        let rows = self
            .rows
            .iter_mut()
            .map(|r| {
                if r.exec.n >= min {
                    r.base_sk = dur(r.exec.mean + cfg.shrink * r.exec.stderr(cfg.alpha));
                }
                if r.gap.n >= min {
                    r.base_sg = Some(dur(r.gap.mean - cfg.shrink * r.gap.stderr(cfg.alpha)));
                }
                (r.handle, r.base_sk, r.base_sg)
            })
            .collect();
        self.dirty = false;
        ResolvedProfile::from_rows(rows, self.epoch)
    }
}

/// Handle-indexed sharing-stage refiner: one per GPU sim, covering every
/// attached service (the driver feeds it from the event loop).
#[derive(Debug)]
pub struct OnlineRefiner {
    cfg: OnlineConfig,
    /// Indexed by [`TaskHandle`], like the scheduler's resolved table.
    services: Vec<Option<ServiceRefiner>>,
    stats: RefinerStats,
    errors: WindowedError,
}

impl OnlineRefiner {
    pub fn new(cfg: OnlineConfig) -> OnlineRefiner {
        let errors = WindowedError::new(cfg.error_window.max(1) as u64);
        OnlineRefiner {
            cfg,
            services: Vec::new(),
            stats: RefinerStats::default(),
            errors,
        }
    }

    /// Start refining a service from its attach-time baseline. Called
    /// by the driver right after it resolves the offline profile.
    pub fn register(&mut self, handle: TaskHandle, baseline: &ResolvedProfile) {
        let idx = handle.index();
        if idx >= self.services.len() {
            self.services.resize_with(idx + 1, || None);
        }
        self.services[idx] = Some(ServiceRefiner::new(baseline));
    }

    /// Drop a drained service's estimates (mirrors
    /// `FikitScheduler::unregister_service`).
    pub fn unregister(&mut self, handle: TaskHandle) {
        if let Some(slot) = self.services.get_mut(handle.index()) {
            *slot = None;
        }
    }

    /// Fold in one completed kernel: its observed execution time and —
    /// when the owning process immediately scheduled its next launch —
    /// the observed post-kernel think gap. Returns a fresh snapshot if
    /// this observation tripped drift (the caller swaps it into the
    /// scheduler). Steady state (no drift) allocates nothing.
    pub fn observe(
        &mut self,
        task: TaskHandle,
        kernel: KernelHandle,
        exec: Duration,
        gap_after: Option<Duration>,
    ) -> Option<ResolvedProfile> {
        if !self.cfg.enabled {
            return None;
        }
        let svc = self.services.get_mut(task.index())?.as_mut()?;
        let Some(row) = svc.row_mut(kernel) else {
            self.stats.unknown_kernel += 1;
            return None;
        };
        let mut tripped = false;

        self.stats.exec_observations += 1;
        row.exec.observe(exec.nanos() as f64, self.cfg.alpha);
        if drifted(row.base_sk.nanos() as f64, &row.exec, &self.cfg) {
            tripped = true;
        }

        if let Some(gap) = gap_after {
            self.stats.gap_observations += 1;
            let base_ns = row.base_sg.unwrap_or(Duration::ZERO).nanos() as f64;
            if self.cfg.track_errors && base_ns > 0.0 {
                self.errors
                    .record((gap.nanos() as f64 - base_ns).abs() / base_ns);
            }
            row.gap.observe(gap.nanos() as f64, self.cfg.alpha);
            if drifted(base_ns, &row.gap, &self.cfg) {
                tripped = true;
            }
        }

        if !tripped {
            return None;
        }
        self.stats.drifts += 1;
        svc.dirty = true;
        let snapshot = svc.publish(&self.cfg);
        self.stats.snapshots_published += 1;
        self.stats.max_epoch = self.stats.max_epoch.max(snapshot.epoch());
        Some(snapshot)
    }

    /// Counters accumulated so far.
    pub fn stats(&self) -> &RefinerStats {
        &self.stats
    }

    /// Consume, yielding the counters (end-of-run report).
    pub fn into_stats(self) -> RefinerStats {
        self.stats
    }

    /// Windowed gap-prediction error trajectory (only populated with
    /// `track_errors` on).
    pub fn error_windows(&self) -> &WindowedError {
        &self.errors
    }

    /// Modeled refinement overhead so far (see [`RefinerStats`]).
    pub fn modeled_overhead(&self) -> Duration {
        self.stats.modeled_overhead(&self.cfg)
    }

    /// Current epoch of a service (0 = never refreshed / unknown).
    pub fn epoch_of(&self, task: TaskHandle) -> u64 {
        self.services
            .get(task.index())
            .and_then(|s| s.as_ref())
            .map_or(0, |s| s.epoch)
    }
}

// ---------------------------------------------------------------------
// String-keyed frontend (daemon shards, runtime engine)
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Default)]
struct KeyedEstimate {
    base_sk: Option<Duration>,
    base_sg: Option<Duration>,
    exec: Ewma,
    gap: Ewma,
}

#[derive(Debug, Default)]
struct KeyedTask {
    kernels: HashMap<KernelId, KeyedEstimate>,
    /// Last completed holder kernel, awaiting the gap-closing launch.
    pending: Option<(KernelId, SimTime)>,
    epoch: u64,
    dirty: bool,
}

/// Wire-boundary refiner: learns from `Completion` exec times and
/// completion→next-launch arrival gaps, keyed by `(TaskKey, KernelId)`.
/// Lives on the cold side of the daemon (per-message hashing is already
/// paid there), so it may allocate freely.
#[derive(Debug)]
pub struct KeyedRefiner {
    cfg: OnlineConfig,
    tasks: HashMap<TaskKey, KeyedTask>,
    stats: RefinerStats,
}

impl KeyedRefiner {
    pub fn new(cfg: OnlineConfig) -> KeyedRefiner {
        KeyedRefiner {
            cfg,
            tasks: HashMap::new(),
            stats: RefinerStats::default(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    fn estimate<'a>(
        tasks: &'a mut HashMap<TaskKey, KeyedTask>,
        key: &TaskKey,
        kernel: &KernelId,
        base: Option<&TaskProfile>,
    ) -> &'a mut KeyedEstimate {
        let task = tasks.entry(key.clone()).or_default();
        task.kernels.entry(kernel.clone()).or_insert_with(|| {
            KeyedEstimate {
                base_sk: base.and_then(|p| p.sk(kernel)),
                base_sg: base.and_then(|p| p.sg(kernel)),
                ..Default::default()
            }
        })
    }

    /// A kernel of `key` completed with observed execution time `exec`
    /// (carried by the wire `Completion`); remember it as the pending
    /// gap source.
    pub fn observe_exec(
        &mut self,
        key: &TaskKey,
        kernel: &KernelId,
        exec: Duration,
        at: SimTime,
        base: Option<&TaskProfile>,
    ) {
        if !self.cfg.enabled {
            return;
        }
        let est = Self::estimate(&mut self.tasks, key, kernel, base);
        est.exec.observe(exec.nanos() as f64, self.cfg.alpha);
        let sk_drift = est
            .base_sk
            .is_some_and(|sk| drifted(sk.nanos() as f64, &est.exec, &self.cfg));
        self.stats.exec_observations += 1;
        let task = self.tasks.get_mut(key).expect("estimate() inserted task");
        task.pending = Some((kernel.clone(), at));
        if sk_drift {
            self.stats.drifts += 1;
            task.dirty = true;
        }
    }

    /// The service's next launch arrived at `now`: close the pending
    /// gap observation (the non-intrusive sharing-stage analogue of the
    /// measurement stage's `G_i = start(i+1) − finish(i)`).
    pub fn observe_next_launch(&mut self, key: &TaskKey, now: SimTime) {
        if !self.cfg.enabled {
            return;
        }
        let Some(task) = self.tasks.get_mut(key) else {
            return;
        };
        let Some((kernel, finished_at)) = task.pending.take() else {
            return;
        };
        if now <= finished_at {
            return; // clock skew / reordered wire events: skip
        }
        let gap = now.since(finished_at);
        let Some(est) = task.kernels.get_mut(&kernel) else {
            return;
        };
        let base_ns = est.base_sg.unwrap_or(Duration::ZERO).nanos() as f64;
        est.gap.observe(gap.nanos() as f64, self.cfg.alpha);
        self.stats.gap_observations += 1;
        if drifted(base_ns, &est.gap, &self.cfg) {
            self.stats.drifts += 1;
            task.dirty = true;
        }
    }

    /// Drop everything known about a departed service (bounds the maps
    /// by live services, like the shard's other teardown paths).
    pub fn forget(&mut self, key: &TaskKey) {
        self.tasks.remove(key);
    }

    /// Disarm the pending gap observation without dropping the learned
    /// estimates — called at task/request boundaries, where the
    /// completion→next-launch delta spans inter-request idle rather
    /// than a post-kernel think gap and must not pollute `SG`.
    pub fn clear_pending(&mut self, key: &TaskKey) {
        if let Some(task) = self.tasks.get_mut(key) {
            task.pending = None;
        }
    }

    /// Number of services currently tracked (leak probe).
    pub fn tracked_tasks(&self) -> usize {
        self.tasks.len()
    }

    pub fn stats(&self) -> &RefinerStats {
        &self.stats
    }

    /// Harvest refined profiles for every drifted service: the offline
    /// profile (or an empty one) with converged estimates overwritten,
    /// a bumped epoch and `origin = Refined`. Published values are
    /// confidence-shrunk exactly like [`OnlineRefiner`]'s snapshots.
    /// The caller persists/installs them (`daemon/mod.rs` shadows its
    /// store; `fikit serve --save-profiles` writes them to disk).
    pub fn take_refined(&mut self, offline: &ProfileStore) -> Vec<TaskProfile> {
        if !self.cfg.enabled {
            return Vec::new();
        }
        let min = self.cfg.min_samples as u64;
        let mut out = Vec::new();
        for (key, task) in self.tasks.iter_mut() {
            if !task.dirty {
                continue;
            }
            task.dirty = false;
            let mut profile = offline
                .get(key)
                .cloned()
                .unwrap_or_else(|| TaskProfile::new(key.clone()));
            // Epochs never regress: a restarted daemon resumes from the
            // persisted epoch of the loaded (possibly already-refined)
            // profile, not from this process's counter.
            task.epoch = task.epoch.max(profile.epoch) + 1;
            for (kid, est) in task.kernels.iter_mut() {
                if est.exec.n < min && est.gap.n < min {
                    continue;
                }
                let prev = profile.stats_for(kid).cloned().unwrap_or_default();
                let exec = if est.exec.n >= min {
                    let m = est.exec.mean + self.cfg.shrink * est.exec.stderr(self.cfg.alpha);
                    est.base_sk = Some(dur(m));
                    StatSummary::from_moments(est.exec.n, m, est.exec.std().powi(2))
                } else {
                    prev.exec
                };
                let gap = if est.gap.n >= min {
                    let m = (est.gap.mean - self.cfg.shrink * est.gap.stderr(self.cfg.alpha))
                        .max(0.0);
                    est.base_sg = Some(dur(m));
                    StatSummary::from_moments(est.gap.n, m, est.gap.std().powi(2))
                } else {
                    prev.gap
                };
                profile.set_kernel_stats(kid, KernelStats { exec, gap });
            }
            profile.epoch = task.epoch;
            profile.origin = ProfileOrigin::Refined;
            if profile.runs == 0 {
                // A refined profile must count as ready even when it
                // started from an empty (never-measured) baseline.
                profile.finish_run(task.kernels.len());
            }
            self.stats.snapshots_published += 1;
            self.stats.max_epoch = self.stats.max_epoch.max(task.epoch);
            out.push(profile);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{Dim3, Interner};
    use crate::util::rng::Rng;

    fn kid(name: &str) -> KernelId {
        KernelId::new(name, Dim3::x(2), Dim3::x(64))
    }

    fn enabled_cfg() -> OnlineConfig {
        OnlineConfig {
            enabled: true,
            ..Default::default()
        }
    }

    /// Baseline profile: kernel "k" with SK = 100 µs, SG = 500 µs.
    fn world() -> (OnlineRefiner, TaskHandle, KernelHandle) {
        let mut p = TaskProfile::new(TaskKey::new("svc"));
        p.record(
            &kid("k"),
            Duration::from_micros(100),
            Some(Duration::from_micros(500)),
        );
        p.finish_run(1);
        let mut interner = Interner::new();
        let th = interner.intern_task(&TaskKey::new("svc"));
        let rp = ResolvedProfile::resolve(&p, &mut interner);
        let kh = interner.kernel_handle(&kid("k")).unwrap();
        let mut r = OnlineRefiner::new(enabled_cfg());
        r.register(th, &rp);
        (r, th, kh)
    }

    #[test]
    fn ewma_tracks_mean_and_variance() {
        let mut e = Ewma::default();
        for _ in 0..200 {
            e.observe(100.0, 0.2);
        }
        assert!((e.mean - 100.0).abs() < 1e-9);
        assert!(e.std() < 1e-6, "constant stream has ~zero variance");
        let mut rng = Rng::new(7);
        let mut j = Ewma::default();
        for _ in 0..500 {
            j.observe(rng.range_f64(90.0, 110.0), 0.2);
        }
        assert!((j.mean - 100.0).abs() < 5.0);
        assert!(j.std() > 2.0 && j.std() < 12.0, "std {}", j.std());
        assert!(j.stderr(0.2) < j.std());
    }

    #[test]
    fn no_drift_on_faithful_observations() {
        let (mut r, th, kh) = world();
        let mut rng = Rng::new(11);
        for _ in 0..500 {
            let exec = Duration::from_nanos(rng.range_f64(95_000.0, 105_000.0) as u64);
            let gap = Duration::from_nanos(rng.range_f64(475_000.0, 525_000.0) as u64);
            assert!(r.observe(th, kh, exec, Some(gap)).is_none());
        }
        assert_eq!(r.stats().drifts, 0);
        assert_eq!(r.stats().snapshots_published, 0);
        assert_eq!(r.epoch_of(th), 0);
    }

    #[test]
    fn inflated_gaps_drift_and_publish_shrunk_prediction() {
        let (mut r, th, kh) = world();
        // Interference doubles the observed gap: 500 µs → 1 ms.
        let mut published = None;
        let mut detected_after = 0;
        for i in 0..200 {
            let snap = r.observe(
                th,
                kh,
                Duration::from_micros(100),
                Some(Duration::from_millis(1)),
            );
            if let Some(s) = snap {
                published = Some(s);
                detected_after = i + 1;
                break;
            }
        }
        let snap = published.expect("drift must be detected");
        assert!(
            detected_after <= 2 * OnlineConfig::default().min_samples as usize,
            "detected only after {detected_after} observations"
        );
        assert_eq!(snap.epoch(), 1);
        let sg = snap.sg(kh).expect("gap still predicted");
        // Published SG converged toward the new 1 ms truth (minus the
        // confidence shrink), far from the stale 500 µs.
        assert!(
            sg > Duration::from_micros(700),
            "published SG {sg} still near the stale prediction"
        );
        assert!(sg <= Duration::from_millis(1));
        assert_eq!(r.stats().snapshots_published, 1);
        assert_eq!(r.epoch_of(th), 1);

        // Steady observations at the new mean: the refreshed baseline
        // holds (hysteresis), no publish storm.
        for _ in 0..100 {
            r.observe(
                th,
                kh,
                Duration::from_micros(100),
                Some(Duration::from_millis(1)),
            );
        }
        assert!(
            r.stats().snapshots_published <= 3,
            "published {} times for one drift",
            r.stats().snapshots_published
        );
    }

    #[test]
    fn exec_drift_pads_published_sk() {
        let (mut r, th, kh) = world();
        let mut snap = None;
        for _ in 0..100 {
            if let Some(s) =
                r.observe(th, kh, Duration::from_micros(300), Some(Duration::from_micros(500)))
            {
                snap = Some(s);
                break;
            }
        }
        let snap = snap.expect("SK drift detected");
        let sk = snap.sk(kh).unwrap();
        assert!(sk >= Duration::from_micros(250), "SK {sk} not refreshed");
    }

    #[test]
    fn unknown_kernel_and_unregistered_service_are_noops() {
        let (mut r, th, _) = world();
        let ghost_kernel = KernelHandle::from_index(999);
        assert!(r
            .observe(th, ghost_kernel, Duration::from_micros(1), None)
            .is_none());
        assert_eq!(r.stats().unknown_kernel, 1);
        let ghost_task = TaskHandle::from_index(999);
        assert!(r
            .observe(ghost_task, ghost_kernel, Duration::from_micros(1), None)
            .is_none());
        r.unregister(th);
        assert!(r
            .observe(th, ghost_kernel, Duration::from_micros(1), None)
            .is_none());
    }

    #[test]
    fn disabled_refiner_observes_nothing() {
        let mut r = OnlineRefiner::new(OnlineConfig::default());
        let th = TaskHandle::from_index(0);
        let kh = KernelHandle::from_index(0);
        assert!(r.observe(th, kh, Duration::from_micros(1), None).is_none());
        assert_eq!(r.stats().exec_observations, 0);
    }

    #[test]
    fn overhead_accounting_scales_with_observations() {
        let (mut r, th, kh) = world();
        for _ in 0..100 {
            r.observe(
                th,
                kh,
                Duration::from_micros(100),
                Some(Duration::from_micros(500)),
            );
        }
        // 100 exec + 100 gap observations at 150 ns each.
        assert_eq!(r.modeled_overhead(), Duration::from_micros(30));
    }

    // ----- KeyedRefiner -----

    fn keyed_store() -> ProfileStore {
        let mut p = TaskProfile::new(TaskKey::new("svc"));
        p.record(
            &kid("k"),
            Duration::from_micros(100),
            Some(Duration::from_micros(500)),
        );
        p.finish_run(1);
        let mut store = ProfileStore::new();
        store.insert(p);
        store
    }

    #[test]
    fn keyed_refiner_learns_gap_drift_from_wire_events() {
        let store = keyed_store();
        let key = TaskKey::new("svc");
        let mut r = KeyedRefiner::new(enabled_cfg());
        let mut t = SimTime::ZERO;
        for _ in 0..40 {
            r.observe_exec(&key, &kid("k"), Duration::from_micros(100), t, store.get(&key));
            // The next launch arrives 1 ms later — twice the profiled gap.
            t = t + Duration::from_millis(1);
            r.observe_next_launch(&key, t);
            t = t + Duration::from_micros(100);
        }
        assert!(r.stats().drifts > 0, "wire-side drift undetected");
        let refined = r.take_refined(&store);
        assert_eq!(refined.len(), 1);
        let p = &refined[0];
        assert_eq!(p.origin, ProfileOrigin::Refined);
        assert_eq!(p.epoch, 1);
        let sg = p.sg(&kid("k")).unwrap();
        assert!(
            sg > Duration::from_micros(700),
            "refined SG {sg} did not move toward the observed 1 ms"
        );
        // Nothing more to take until the next drift.
        assert!(r.take_refined(&store).is_empty());
        assert_eq!(r.tracked_tasks(), 1);
        r.forget(&key);
        assert_eq!(r.tracked_tasks(), 0);
    }

    #[test]
    fn keyed_refiner_refines_from_empty_baseline() {
        // Cold start at the wire: no offline profile at all. The refiner
        // still converges and its published profile counts as ready.
        let store = ProfileStore::new();
        let key = TaskKey::new("new-svc");
        let mut r = KeyedRefiner::new(enabled_cfg());
        let mut t = SimTime::ZERO;
        for _ in 0..40 {
            r.observe_exec(&key, &kid("k"), Duration::from_micros(200), t, store.get(&key));
            t = t + Duration::from_micros(800);
            r.observe_next_launch(&key, t);
        }
        let refined = r.take_refined(&store);
        assert_eq!(refined.len(), 1);
        assert!(refined[0].is_ready(1));
        assert!(refined[0].sk(&kid("k")).unwrap() >= Duration::from_micros(190));
    }

    #[test]
    fn stale_pending_gap_is_skipped_on_clock_skew() {
        let store = keyed_store();
        let key = TaskKey::new("svc");
        let mut r = KeyedRefiner::new(enabled_cfg());
        r.observe_exec(&key, &kid("k"), Duration::from_micros(100), SimTime(1_000), store.get(&key));
        r.observe_next_launch(&key, SimTime(500)); // earlier than completion
        assert_eq!(r.stats().gap_observations, 0);
    }
}
