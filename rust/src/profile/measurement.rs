//! Measurement-stage recording (paper §3.2 "Measuring the execution and
//! idle time of kernel", Fig 6).
//!
//! During measurement a task runs **exclusively** on the GPU with a timing
//! event wrapped around every kernel (the CUDA-event analogue). Two
//! consequences, both modelled here and in the device/process models:
//!
//! 1. *Data*: per-kernel `(ID, K, G)` triples — execution time and the
//!    device-idle gap to the next kernel — accumulated into a
//!    [`TaskProfile`].
//! 2. *Cost*: per-kernel event insertion + the synchronization it forces
//!    destroys launch/execute overlap, slowing JCT by 20–80 % (the paper's
//!    measured 34.5–71.8 % in Fig 15). The cost model lives in
//!    [`MeasurementConfig`] and is consumed by the simulator's service
//!    process when a task runs in measuring stage.

use super::statistics::TaskProfile;
use crate::core::{Duration, KernelRecord, TaskKey};

/// Cost model and termination policy for the measurement stage.
#[derive(Debug, Clone)]
pub struct MeasurementConfig {
    /// Runs to measure before the profile is declared ready
    /// (`T ∈ [10, 1000]` in the paper).
    pub runs: u32,
    /// Fixed CPU/driver cost of inserting one pair of timing events
    /// around a kernel launch.
    pub event_overhead: Duration,
    /// Fraction of each kernel's execution that is *additionally* exposed
    /// on the critical path because the per-kernel synchronization
    /// prevents the CPU from running ahead (pipeline-serialization model).
    /// 0.0 = free measurement, 0.5 = every kernel effectively 1.5× longer
    /// end-to-end.
    pub sync_stall_factor: f64,
}

impl Default for MeasurementConfig {
    fn default() -> MeasurementConfig {
        MeasurementConfig {
            runs: 20,
            // ~5 µs per cudaEventRecord/Query pair round trip.
            event_overhead: Duration::from_micros(5),
            // Extra per-kernel critical-path exposure from the forced
            // synchronization; calibrated with the serialization effect
            // so models land in the paper's 34.5–71.8 % band (Fig 15).
            sync_stall_factor: 0.25,
        }
    }
}

impl MeasurementConfig {
    /// Extra critical-path time added to one kernel of duration `exec`
    /// when it is measured.
    pub fn per_kernel_overhead(&self, exec: Duration) -> Duration {
        self.event_overhead + exec.scale(self.sync_stall_factor)
    }
}

/// Accumulates completed-kernel records for tasks in measurement stage and
/// produces [`TaskProfile`]s.
///
/// Records must be fed **per task run, in device execution order** — the
/// recorder derives each inter-kernel gap as
/// `G_i = start(i+1) − finish(i)` (clamped at zero if the device queue
/// back-to-backed them).
#[derive(Debug, Default)]
pub struct MeasurementRecorder {
    profile: Option<TaskProfile>,
}

impl MeasurementRecorder {
    pub fn new(task_key: TaskKey) -> MeasurementRecorder {
        MeasurementRecorder {
            profile: Some(TaskProfile::new(task_key)),
        }
    }

    /// Ingest the ordered kernel records of one complete task run.
    pub fn ingest_run(&mut self, records: &[KernelRecord]) {
        let profile = self.profile.as_mut().expect("recorder already finished");
        for (i, rec) in records.iter().enumerate() {
            let gap_after = records.get(i + 1).map(|next| {
                // Device idle between consecutive kernels of this task.
                next.started_at - rec.finished_at
            });
            profile.record(&rec.kernel, rec.exec_time(), gap_after);
        }
        profile.finish_run(records.len());
    }

    /// Number of runs ingested so far.
    pub fn runs(&self) -> u32 {
        self.profile.as_ref().map_or(0, |p| p.runs)
    }

    /// Whether enough runs have been ingested per `cfg`.
    pub fn is_complete(&self, cfg: &MeasurementConfig) -> bool {
        self.runs() >= cfg.runs
    }

    /// Finish and return the profile. The recorder is consumed.
    pub fn finish(mut self) -> TaskProfile {
        self.profile.take().expect("recorder already finished")
    }

    /// Peek at the in-progress profile.
    pub fn profile(&self) -> &TaskProfile {
        self.profile.as_ref().expect("recorder already finished")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::{
        Dim3, KernelHandle, KernelId, LaunchSource, Priority, SimTime, TaskHandle, TaskId,
    };

    fn rec(name: &str, start_us: u64, end_us: u64) -> KernelRecord {
        KernelRecord {
            task_key: TaskKey::new("svc"),
            task_handle: TaskHandle::UNBOUND,
            task_id: TaskId(0),
            kernel: KernelId::new(name, Dim3::x(1), Dim3::x(32)),
            kernel_handle: KernelHandle::UNBOUND,
            priority: Priority::P0,
            seq: 0,
            source: LaunchSource::Direct,
            issued_at: SimTime(start_us * 1_000),
            started_at: SimTime(start_us * 1_000),
            finished_at: SimTime(end_us * 1_000),
        }
    }

    #[test]
    fn gaps_derived_from_consecutive_records() {
        let mut r = MeasurementRecorder::new(TaskKey::new("svc"));
        // k1: [0, 100us], idle 50us, k2: [150, 200us], idle 0, k1 again: [200, 300]
        r.ingest_run(&[rec("k1", 0, 100), rec("k2", 150, 200), rec("k1", 200, 300)]);
        let p = r.finish();
        let k1 = KernelId::new("k1", Dim3::x(1), Dim3::x(32));
        let k2 = KernelId::new("k2", Dim3::x(1), Dim3::x(32));
        // k1 exec: (100us + 100us)/2
        assert_eq!(p.sk(&k1).unwrap(), Duration::from_micros(100));
        // k1 gap: only the first occurrence has a following kernel → 50us.
        assert_eq!(p.sg(&k1).unwrap(), Duration::from_micros(50));
        // k2 gap: 0 (back-to-back).
        assert_eq!(p.sg(&k2).unwrap(), Duration::ZERO);
    }

    #[test]
    fn completion_threshold() {
        let cfg = MeasurementConfig {
            runs: 2,
            ..Default::default()
        };
        let mut r = MeasurementRecorder::new(TaskKey::new("svc"));
        r.ingest_run(&[rec("k", 0, 10)]);
        assert!(!r.is_complete(&cfg));
        r.ingest_run(&[rec("k", 0, 10)]);
        assert!(r.is_complete(&cfg));
        assert_eq!(r.runs(), 2);
    }

    #[test]
    fn overhead_model_scales_with_kernel_time() {
        let cfg = MeasurementConfig {
            runs: 10,
            event_overhead: Duration::from_micros(5),
            sync_stall_factor: 0.5,
        };
        let oh = cfg.per_kernel_overhead(Duration::from_micros(100));
        assert_eq!(oh, Duration::from_micros(55));
        // Zero-length kernels still pay the event cost.
        assert_eq!(
            cfg.per_kernel_overhead(Duration::ZERO),
            Duration::from_micros(5)
        );
    }
}
