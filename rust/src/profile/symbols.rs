//! The `-rdynamic` kernel-name resolution model (paper §3.2, Fig 4 and
//! experiment scheme I / Fig 13).
//!
//! No CUDA API exposes kernel function names for release-build frameworks;
//! FIKIT's fix is recompiling PyTorch/TensorFlow with `-rdynamic` so the
//! hook can symbolize the launch-site backtrace. Two observable effects:
//!
//! * **capability** — with symbols exported, the hook resolves the kernel
//!   function name (making [`KernelId`](crate::core::KernelId)s precise);
//!   without, names are empty and identification degenerates.
//! * **cost** — a larger dynamic symbol table means more hash collisions
//!   during symbol resolution; the paper measures the end-to-end effect at
//!   −2.38 %…+1.55 % (i.e. noise). We model a tiny per-launch lookup cost
//!   that scales logarithmically with table size, so fig13 reproduces the
//!   "indistinguishable from measurement error" conclusion.

use crate::core::{Duration, KernelId};

/// Cost/capability model of the dynamic symbol table the hook resolves
/// kernel names against.
#[derive(Debug, Clone)]
pub struct SymbolTableModel {
    /// Whether the framework was rebuilt with `-rdynamic` (symbols
    /// exported). Off = baseline release build.
    pub symbols_exported: bool,
    /// Number of dynamic symbols in the framework's table. Torch ~2.8e6
    /// symbols when exported; irrelevant when not exported.
    pub table_size: u64,
    /// Base cost of one backtrace capture + symbol lookup, at a nominal
    /// 1e6-entry table.
    pub base_lookup: Duration,
}

impl Default for SymbolTableModel {
    fn default() -> SymbolTableModel {
        SymbolTableModel {
            symbols_exported: true,
            table_size: 2_800_000,
            base_lookup: Duration::from_nanos(350),
        }
    }
}

impl SymbolTableModel {
    /// A release-build framework (no `-rdynamic`): names unresolvable.
    pub fn release_build() -> SymbolTableModel {
        SymbolTableModel {
            symbols_exported: false,
            table_size: 40_000, // only the default-exported symbols
            ..Default::default()
        }
    }

    /// Per-launch CPU cost of resolving the kernel name. Grows with
    /// log2(table size) — hash-bucket chains lengthen as the table grows
    /// (paper's cited Stack Overflow rationale). Sub-µs either way, hence
    /// Fig 13's "within measurement noise" result.
    pub fn lookup_cost(&self) -> Duration {
        let scale = ((self.table_size.max(2) as f64).log2() / (1_000_000f64).log2()).max(0.1);
        self.base_lookup.scale(scale)
    }
}

/// Resolves kernel names at interception time, applying the symbol-table
/// model. This is the piece of the hook client that turns a raw launch
/// (grid/block dims only) into a full [`KernelId`].
#[derive(Debug, Clone, Default)]
pub struct SymbolResolver {
    model: SymbolTableModel,
}

impl SymbolResolver {
    pub fn new(model: SymbolTableModel) -> SymbolResolver {
        SymbolResolver { model }
    }

    pub fn model(&self) -> &SymbolTableModel {
        &self.model
    }

    /// Resolve a kernel id given the true function name known to the
    /// workload model. Returns the (possibly name-erased) id plus the
    /// CPU-side resolution cost incurred.
    pub fn resolve(&self, id: &KernelId) -> (KernelId, Duration) {
        if self.model.symbols_exported {
            (id.clone(), self.model.lookup_cost())
        } else {
            // Release build: backtrace yields no kernel symbol. The hook
            // still pays a (cheaper) failed-lookup walk.
            let erased = KernelId::new("", id.grid, id.block);
            (erased, self.model.lookup_cost())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Dim3;

    #[test]
    fn exported_symbols_resolve_names() {
        let r = SymbolResolver::new(SymbolTableModel::default());
        let id = KernelId::new("gemm_f32", Dim3::x(64), Dim3::x(256));
        let (resolved, cost) = r.resolve(&id);
        assert_eq!(resolved, id);
        assert!(resolved.has_symbol());
        assert!(cost > Duration::ZERO);
    }

    #[test]
    fn release_build_erases_names() {
        let r = SymbolResolver::new(SymbolTableModel::release_build());
        let id = KernelId::new("gemm_f32", Dim3::x(64), Dim3::x(256));
        let (resolved, _) = r.resolve(&id);
        assert!(!resolved.has_symbol());
        assert_eq!(resolved.grid, id.grid);
        assert_eq!(resolved.block, id.block);
    }

    #[test]
    fn lookup_cost_grows_mildly_with_table_size() {
        let small = SymbolTableModel {
            table_size: 40_000,
            ..Default::default()
        };
        let big = SymbolTableModel {
            table_size: 2_800_000,
            ..Default::default()
        };
        assert!(big.lookup_cost() > small.lookup_cost());
        // Both sub-microsecond: the Fig 13 "noise" conclusion depends on it.
        assert!(big.lookup_cost() < Duration::from_micros(1));
    }
}
