//! **Fig 15** — experiment scheme III: FIKIT measuring stage vs the
//! default environment.
//!
//! Measuring every kernel (cudaEvent pairs + the synchronization they
//! force) destroys launch/execute pipelining: the paper reports
//! +34.52 %…+71.78 % JCT. This is exactly why FIKIT splits the lifecycle
//! into a bounded measuring stage and a long sharing stage — compare
//! with Fig 14's <5 %.

use super::combos::SINGLE_GROUPS;
use super::{ExperimentResult, Options, ShapeCheck};
use crate::config::{ExperimentConfig, ServiceConfig};
use crate::coordinator::driver::{profile_service_scratch, run_experiment_scratch, SimScratch};
use crate::coordinator::Mode;
use crate::core::{Priority, Result};
use crate::metrics::{JctStats, TextTable};

pub fn run(opts: Options) -> Result<ExperimentResult> {
    let tasks = opts.tasks(1000);
    let mut table = TextTable::new(&["model", "base JCT (ms)", "measuring JCT (ms)", "overhead %"]);
    let mut series = Vec::new();
    let mut max_oh = f64::MIN;
    let mut min_oh = f64::MAX;
    // One event-core scratch across the whole sweep.
    let mut scratch = SimScratch::new();

    for model in SINGLE_GROUPS {
        let mut cfg = ExperimentConfig {
            mode: Mode::Sharing,
            seed: opts.seed,
            ..ExperimentConfig::default()
        };
        cfg.measurement.runs = tasks;
        cfg.services
            .push(ServiceConfig::new(model, Priority::P0).tasks(tasks));

        // Base: plain solo run.
        let base = run_experiment_scratch(&cfg, &mut scratch)?.services[0]
            .jct
            .mean_ms();
        // Measuring stage: the profiling pass itself, same task count.
        let profiling = profile_service_scratch(&cfg, &cfg.services[0], &mut scratch)?;
        let measuring =
            JctStats::from_durations(profiling.outcomes.iter().map(|o| o.jct()).collect())
                .mean_ms();

        let overhead = (measuring - base) / base * 100.0;
        max_oh = max_oh.max(overhead);
        min_oh = min_oh.min(overhead);
        series.push((model.name().to_string(), overhead));
        table.row(vec![
            model.name().to_string(),
            format!("{base:.3}"),
            format!("{measuring:.3}"),
            format!("{overhead:+.2}%"),
        ]);
    }

    let checks = vec![
        ShapeCheck::new(
            "measurement is expensive",
            min_oh > 15.0,
            format!("min overhead {min_oh:.2}% (paper: ≥34.5%)"),
        ),
        ShapeCheck::new(
            "within the paper's magnitude band",
            max_oh < 110.0,
            format!("max overhead {max_oh:.2}% (paper: ≤71.8%)"),
        ),
        ShapeCheck::new(
            "staging is necessary",
            min_oh > 5.0,
            "measuring-stage cost dwarfs the <5% sharing-stage cost (Fig 14)".to_string(),
        ),
    ];

    Ok(ExperimentResult {
        id: "fig15",
        title: "Single-service JCT overhead, FIKIT measuring stage vs NVIDIA default (scheme III)",
        table,
        series,
        checks,
        notes: format!("{tasks} measured inferences per model"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig15_shape_holds_quick() {
        let r = run(Options::quick()).unwrap();
        assert_eq!(r.series.len(), 7);
        assert!(r.all_checks_pass(), "{}", r.render());
    }
}
