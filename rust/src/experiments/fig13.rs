//! **Fig 13** — experiment scheme I: `-rdynamic` vs base JCT difference.
//!
//! The paper recompiles PyTorch with `-rdynamic` so the hook can resolve
//! kernel names, and shows the JCT impact is indistinguishable from
//! measurement noise (−2.38 %…+1.55 % across seven model groups). Here
//! the "rdynamic environment" enables the symbol-table model (per-launch
//! symbol lookups, larger hash table) and each environment observes its
//! own run-to-run jitter — the reproduction target is the *noise band*,
//! not a systematic slowdown.

use super::combos::SINGLE_GROUPS;
use super::{ExperimentResult, Options, ShapeCheck};
use crate::config::{ExperimentConfig, ServiceConfig};
use crate::coordinator::driver::{run_experiment_scratch, SimScratch};
use crate::coordinator::Mode;
use crate::core::{Priority, Result};
use crate::metrics::TextTable;
use crate::profile::SymbolTableModel;

pub fn run(opts: Options) -> Result<ExperimentResult> {
    let tasks = opts.tasks(1000);
    let mut table = TextTable::new(&["model", "base JCT (ms)", "rdynamic JCT (ms)", "diff %"]);
    let mut series = Vec::new();
    let mut max_abs = 0.0f64;
    // One event-core scratch across all 14 runs of the sweep.
    let mut scratch = SimScratch::new();

    for (gi, model) in SINGLE_GROUPS.iter().enumerate() {
        let mut run_env = |symbols: SymbolTableModel, seed: u64| -> Result<f64> {
            let mut cfg = ExperimentConfig {
                mode: Mode::Sharing, // solo service, no scheduler attached
                seed,
                symbols,
                ..ExperimentConfig::default()
            };
            cfg.services
                .push(ServiceConfig::new(*model, Priority::P0).tasks(tasks));
            let report = run_experiment_scratch(&cfg, &mut scratch)?;
            Ok(report.services[0].jct.mean_ms())
        };

        // Different seeds per environment: two *separate measurement
        // campaigns*, as in the paper (run-to-run noise included).
        let base = run_env(SymbolTableModel::release_build(), opts.seed + gi as u64)?;
        let rdyn = run_env(SymbolTableModel::default(), opts.seed + 1000 + gi as u64)?;
        let diff = (rdyn - base) / base * 100.0;
        max_abs = max_abs.max(diff.abs());
        series.push((model.name().to_string(), diff));
        table.row(vec![
            model.name().to_string(),
            format!("{base:.3}"),
            format!("{rdyn:.3}"),
            format!("{diff:+.2}%"),
        ]);
    }

    let mixed_sign = series.iter().any(|(_, d)| *d > 0.0) && series.iter().any(|(_, d)| *d < 0.0);
    let checks = vec![
        ShapeCheck::new(
            "noise band",
            max_abs < 3.0,
            format!("max |diff| = {max_abs:.2}% (paper band −2.38%…+1.55%)"),
        ),
        ShapeCheck::new(
            "no systematic slowdown",
            mixed_sign || max_abs < 1.0,
            "differences change sign across models (pure noise)".to_string(),
        ),
    ];

    Ok(ExperimentResult {
        id: "fig13",
        title: "JCT difference, -rdynamic vs base (scheme I)",
        table,
        series,
        checks,
        notes: format!("{tasks} inferences per model per environment; independent seeds per environment"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig13_shape_holds_quick() {
        let r = run(Options::quick()).unwrap();
        assert_eq!(r.series.len(), 7);
        assert!(r.all_checks_pass(), "{}", r.render());
    }
}
