//! **Fig 16 + Fig 17** — high/low-priority JCT speedup of FIKIT over
//! default GPU sharing across the ten combos A–J (§4.5.1).
//!
//! Paper results: high-priority tasks accelerate by 1.32–16.41×, more
//! than half of the combos by >3.4× (Fig 16); low-priority tasks run at
//! a fraction of their sharing-mode rate, mostly <0.3× (Fig 17) — the
//! price of strict priority.

use super::combos::{run_combo_share_vs_fikit, windowed_mean_ms, COMBOS, HIGH_KEY, LOW_KEY};
use super::{ExperimentResult, Options, ShapeCheck};
use crate::core::Result;
use crate::metrics::TextTable;

pub fn run(opts: Options) -> Result<ExperimentResult> {
    let tasks = opts.tasks(1000).min(300); // overlap-window methodology saturates quickly
    let mut table = TextTable::new(&[
        "combo", "H model", "L model", "H share (ms)", "H FIKIT (ms)", "H speedup",
        "L speedup",
    ]);
    let mut series = Vec::new();
    let mut hi_speedups = Vec::new();
    let mut lo_speedups = Vec::new();

    for combo in &COMBOS {
        let (share, fikit) = run_combo_share_vs_fikit(combo, tasks, opts)?;
        let h_share = windowed_mean_ms(&share, HIGH_KEY);
        let h_fikit = windowed_mean_ms(&fikit, HIGH_KEY);
        let l_share = windowed_mean_ms(&share, LOW_KEY);
        let l_fikit = windowed_mean_ms(&fikit, LOW_KEY);
        let h_speedup = h_share / h_fikit;
        let l_speedup = l_share / l_fikit;
        hi_speedups.push(h_speedup);
        lo_speedups.push(l_speedup);
        series.push((format!("fig16/{}", combo.label), h_speedup));
        series.push((format!("fig17/{}", combo.label), l_speedup));
        table.row(vec![
            combo.label.to_string(),
            combo.high.name().to_string(),
            combo.low.name().to_string(),
            format!("{h_share:.2}"),
            format!("{h_fikit:.2}"),
            format!("{h_speedup:.2}x"),
            format!("{l_speedup:.2}x"),
        ]);
    }

    let min_h = hi_speedups.iter().cloned().fold(f64::INFINITY, f64::min);
    let max_h = hi_speedups.iter().cloned().fold(0.0, f64::max);
    let over_2x = hi_speedups.iter().filter(|s| **s > 2.0).count();
    let lo_below_1 = lo_speedups.iter().filter(|s| **s < 1.0).count();

    let checks = vec![
        ShapeCheck::new(
            "fig16: FIKIT wins for high priority in every combo",
            min_h > 1.0,
            format!("min speedup {min_h:.2}x (paper min 1.32x)"),
        ),
        ShapeCheck::new(
            "fig16: large speedups exist",
            max_h > 3.0,
            format!("max speedup {max_h:.2}x (paper max 16.41x)"),
        ),
        ShapeCheck::new(
            "fig16: majority accelerate substantially",
            over_2x * 2 >= COMBOS.len(),
            format!("{over_2x}/10 combos over 2x (paper: >half over 3.4x)"),
        ),
        ShapeCheck::new(
            "fig17: low priority pays in most combos",
            lo_below_1 >= 7,
            format!("{lo_below_1}/10 combos with low-prio speedup < 1 (paper: mostly <0.3)"),
        ),
    ];

    Ok(ExperimentResult {
        id: "fig16",
        title: "High/low-priority JCT speedup of FIKIT over default sharing, combos A–J",
        table,
        series,
        checks,
        notes: format!(
            "{tasks} inferences per service; JCTs collected in the fully-overlapping window (paper §4.5.1)"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig16_17_shape_holds_quick() {
        let r = run(Options::quick()).unwrap();
        assert_eq!(r.series.len(), 20);
        for c in &r.checks {
            assert!(c.passed, "{}\nfull report:\n{}", c.name, r.render());
        }
    }
}
