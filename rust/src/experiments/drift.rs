//! **Drift** — online profile refinement under injected interference
//! (DESIGN.md §9; `fikit drift`).
//!
//! The scenario: a high-priority detector and a low-priority segmenter
//! share one FIKIT GPU with online refinement enabled. Mid-run, gap
//! interference is injected into the detector — its real CPU think gaps
//! inflate 3× (the in-sim stand-in for co-location contention shifting
//! observed gaps) while the offline `SG` table stays stale. The
//! experiment tracks the windowed relative gap-prediction error
//! (`|observed − predicted| / predicted`, 24 observations per window)
//! through three phases:
//!
//! 1. **converged** — sharing against the freshly measured profile:
//!    the error floor is the workload's intrinsic log-normal jitter;
//! 2. **injected** — the first post-injection window spikes while
//!    predictions are stale;
//! 3. **re-converged** — the refiner detects the drift (EWMA mean
//!    leaves the confidence band), publishes refreshed epoch snapshots,
//!    and the error returns to the converged band.
//!
//! Shape checks pin detection (drift + snapshot counters move), the
//! spike, re-convergence (final windows back within 1.5× of the
//! converged floor), the ≤ 5 % accounted refinement overhead, and
//! deterministic replay. The zero-allocation guarantee of the
//! refinement path is enforced separately by `tests/hotpath_alloc.rs`.

use super::{ExperimentResult, Options, ShapeCheck};
use crate::config::{ExperimentConfig, ServiceConfig};
use crate::coordinator::driver::{profile_service_scratch, GpuSim, SimScratch};
use crate::coordinator::Mode;
use crate::core::{Priority, Result, SimTime, TaskKey};
use crate::metrics::TextTable;
use crate::profile::{ProfileStore, RefinerStats};
use crate::workload::ModelKind;

/// Gap inflation factor injected at the phase boundary.
const INJECTED_SCALE: f64 = 3.0;

/// One full scenario run: phase timings scale with `opts.scale`
/// (clamped so windows stay ≫ one detector JCT).
struct Outcome {
    /// Closed error windows, in observation order.
    windows: Vec<f64>,
    /// Number of windows closed before the injection.
    cut: usize,
    /// Refiner counters before the injection.
    before: RefinerStats,
    /// Final refiner counters.
    after: RefinerStats,
    /// Modeled refinement overhead as a fraction of simulated time.
    overhead_frac: f64,
    sim_end: SimTime,
}

fn scenario(opts: Options) -> Result<Outcome> {
    let k = opts.scale.clamp(0.25, 1.0);
    let phase_ms = (1_200.0 * k) as u64;

    let mut cfg = ExperimentConfig {
        mode: Mode::Fikit,
        seed: opts.seed,
        ..ExperimentConfig::default()
    };
    cfg.online.enabled = true;
    cfg.online.track_errors = true;
    cfg.online.error_window = 24;
    cfg.services.push(
        ServiceConfig::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0)
            .continuous_ms(2 * phase_ms)
            .with_key("detector"),
    );
    cfg.services.push(
        ServiceConfig::new(ModelKind::FcnResnet50, Priority::P5)
            .continuous_ms(2 * phase_ms)
            .with_key("segmenter"),
    );
    cfg.validate()?;

    // Offline measurement (the paper's lifecycle), then serve — the
    // measurement passes and the serving sim share one event-core
    // scratch.
    let mut scratch = SimScratch::new();
    let mut store = ProfileStore::new();
    for svc in &cfg.services {
        store.insert(profile_service_scratch(&cfg, svc, &mut scratch)?.profile);
    }
    let mut sim = GpuSim::with_scratch(&cfg, &store, &mut scratch)?;

    // Phase 1: converge against the measured profile.
    sim.run_until(SimTime(phase_ms * 1_000_000));
    let refiner = sim.refiner().expect("online refinement enabled");
    let cut = refiner.error_windows().windows().len();
    let before = refiner.stats().clone();

    // Phase 2+3: inject interference into the detector, run to the end.
    sim.inject_gap_scale(&TaskKey::new("detector"), INJECTED_SCALE)?;
    sim.run_until(SimTime::MAX);

    let refiner = sim.refiner().expect("online refinement enabled");
    let windows = refiner.error_windows().windows().to_vec();
    let after = refiner.stats().clone();
    let overhead_frac =
        refiner.modeled_overhead().as_secs_f64() / sim.now().as_secs_f64().max(1e-9);
    Ok(Outcome {
        windows,
        cut,
        before,
        after,
        overhead_frac,
        sim_end: sim.now(),
    })
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Run the drift experiment.
pub fn run(opts: Options) -> Result<ExperimentResult> {
    let a = scenario(opts)?;
    let b = scenario(opts)?; // replay for the determinism check

    // Converged floor: the last windows before the injection.
    let pre_slice = &a.windows[a.cut.saturating_sub(3)..a.cut.min(a.windows.len())];
    let pre = mean(pre_slice);
    // Spike: the first window that saw stale predictions.
    let spike = a.windows.get(a.cut).copied().unwrap_or(0.0);
    // Re-converged: the final windows of the run.
    let post_slice = &a.windows[a.windows.len().saturating_sub(3)..];
    let post = mean(post_slice);

    let drifts_new = a.after.drifts.saturating_sub(a.before.drifts);
    let snapshots_new = a
        .after
        .snapshots_published
        .saturating_sub(a.before.snapshots_published);

    let mut table = TextTable::new(&["phase", "windows", "mean rel err"]);
    table.row(vec![
        "converged (pre-injection)".into(),
        format!("{}", a.cut),
        format!("{pre:.3}"),
    ]);
    table.row(vec![
        "injected (first stale window)".into(),
        "1".into(),
        format!("{spike:.3}"),
    ]);
    table.row(vec![
        "re-converged (final)".into(),
        format!("{}", a.windows.len().saturating_sub(a.cut)),
        format!("{post:.3}"),
    ]);

    let series = vec![
        ("err/converged".to_string(), pre),
        ("err/spike".to_string(), spike),
        ("err/reconverged".to_string(), post),
        ("drifts".to_string(), drifts_new as f64),
        ("snapshots".to_string(), snapshots_new as f64),
        ("max_epoch".to_string(), a.after.max_epoch as f64),
        ("overhead_pct".to_string(), a.overhead_frac * 100.0),
        ("windows".to_string(), a.windows.len() as f64),
    ];

    let checks = vec![
        ShapeCheck::new(
            "enough windows on both sides of the injection",
            a.cut >= 4 && a.windows.len() >= a.cut + 4,
            format!("{} pre + {} post windows", a.cut, a.windows.len() - a.cut.min(a.windows.len())),
        ),
        ShapeCheck::new(
            "injected interference is detected as drift",
            drifts_new >= 1 && snapshots_new >= 1,
            format!("{drifts_new} drifts, {snapshots_new} snapshots after injection"),
        ),
        ShapeCheck::new(
            "stale predictions spike the error",
            spike > pre * 1.2,
            format!("spike {spike:.3} vs converged {pre:.3}"),
        ),
        ShapeCheck::new(
            "predictions re-converge within the confidence band",
            post <= (pre * 1.5).max(0.05) && post < spike,
            format!("final {post:.3} vs converged {pre:.3} (spike {spike:.3})"),
        ),
        ShapeCheck::new(
            "accounted refinement overhead within the 5% budget",
            a.overhead_frac * 100.0 <= 5.0,
            format!("{:.4}% of simulated time", a.overhead_frac * 100.0),
        ),
        ShapeCheck::new(
            "deterministic replay under the fixed seed",
            a.after.drifts == b.after.drifts
                && a.after.snapshots_published == b.after.snapshots_published
                && a.windows == b.windows
                && a.sim_end == b.sim_end,
            format!(
                "run A: ({}, {}, {} windows, end {}); run B: ({}, {}, {} windows, end {})",
                a.after.drifts,
                a.after.snapshots_published,
                a.windows.len(),
                a.sim_end,
                b.after.drifts,
                b.after.snapshots_published,
                b.windows.len(),
                b.sim_end
            ),
        ),
    ];

    let notes = format!(
        "gap interference x{INJECTED_SCALE} injected into the detector at the phase boundary; \
         error = |observed gap - published SG| / SG over {}-observation windows. \
         epochs published: {} (max epoch {}). The zero-alloc gate for the refinement \
         path runs in tests/hotpath_alloc.rs.",
        24, a.after.snapshots_published, a.after.max_epoch
    );

    Ok(ExperimentResult {
        id: "drift",
        title: "Online profile refinement: drift detection and re-convergence",
        table,
        series,
        checks,
        notes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drift_runs_quick() {
        let r = run(Options::quick()).unwrap();
        assert!(r.series.len() >= 8);
        assert!(r.all_checks_pass(), "{}", r.render());
    }
}
