//! **Cluster churn** — dynamic serving with arrivals, departures, and
//! reactive QoS migration (DESIGN.md §8; the serving-scale extension of
//! the paper's §5 cluster proposal).
//!
//! Two built-in scenarios:
//!
//! * **rescue** — a scripted trace that forces a workload-blind
//!   LeastLoaded placer into a bad co-location: a dense low-priority
//!   stream lands next to the high-priority detector because the only
//!   compatible device is momentarily full. Once capacity frees up, the
//!   QoS scanner migrates the offender away. Run twice (migration off /
//!   on) under a fixed seed, the scenario isolates exactly what reactive
//!   re-placement buys: the violation count drops and the high-priority
//!   slowdown trajectory recovers instead of staying pinned above the
//!   bound.
//! * **fikit-churn** — seeded Poisson arrivals over a 3-GPU fleet with
//!   per-GPU FIKIT coordinators and BestMatch placement: the steady-state
//!   serving regime (churn + kernel-granularity protection together).

use super::{ExperimentResult, Options, ShapeCheck};
use crate::cluster::{run_churn, ChurnConfig, ChurnReport, CompatMatrix, PlacementPolicy};
use crate::coordinator::Mode;
use crate::core::{Duration, Priority, Result, SimTime};
use crate::metrics::TextTable;
use crate::workload::{ArrivalProcess, MixEntry, ModelKind, ServiceArrival};

/// Time stretch: quick mode shrinks every duration proportionally, which
/// preserves the scenario logic (scan cadence, windows, and lifetimes
/// scale together). Floor keeps windows ≫ one detector JCT (~30 ms).
fn stretch(opts: Options) -> f64 {
    opts.scale.clamp(0.25, 1.0)
}

fn ms(v: f64) -> Duration {
    Duration::from_millis_f64(v)
}

/// The scripted rescue trace (times in fleet ms, scaled by `k`):
///
/// * t=0      keypointrcnn  P0, life 3000k — the protected tenant (GPU 0)
/// * t=10     vgg16         P7, life  400k — fills GPU 1...
/// * t=20     vgg16         P7, life 3000k — ...to capacity
/// * t=30     resnet101     P6, life 3000k — forced next to the detector
///
/// When the short-lived vgg departs (~400k), GPU 1 has room again and the
/// scanner can move resnet101 off the detector's device.
fn rescue_arrivals(k: f64) -> ArrivalProcess {
    ArrivalProcess::Trace(vec![
        ServiceArrival::new(
            SimTime::ZERO,
            ModelKind::KeypointRcnnResnet50Fpn,
            Priority::P0,
            ms(3_000.0 * k),
        ),
        ServiceArrival::new(
            SimTime(10_000_000),
            ModelKind::Vgg16,
            Priority::P7,
            ms(400.0 * k),
        ),
        ServiceArrival::new(
            SimTime(20_000_000),
            ModelKind::Vgg16,
            Priority::P7,
            ms(3_000.0 * k),
        ),
        ServiceArrival::new(
            SimTime(30_000_000),
            ModelKind::Resnet101,
            Priority::P6,
            ms(3_000.0 * k),
        ),
    ])
}

fn rescue_cfg(opts: Options, migration: bool) -> ChurnConfig {
    let k = stretch(opts);
    let mut cfg = ChurnConfig::new(2, PlacementPolicy::LeastLoaded, rescue_arrivals(k));
    cfg.capacity = 2;
    // Default sharing inside each GPU: the co-location pain is maximal,
    // so the experiment isolates the placement/migration effect.
    cfg.mode = Mode::Sharing;
    cfg.seed = opts.seed;
    cfg.qos.high_slowdown_bound = 1.3;
    cfg.qos.scan_interval = ms(250.0 * k);
    cfg.qos.window = ms(1_000.0 * k);
    cfg.qos.migration = migration;
    cfg.metrics_window = ms(500.0 * k);
    cfg
}

fn fikit_churn_cfg(opts: Options) -> ChurnConfig {
    let k = stretch(opts);
    let mix = vec![
        MixEntry::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0, 1.0),
        MixEntry::new(ModelKind::FasterrcnnResnet50Fpn, Priority::P1, 1.0),
        MixEntry::new(ModelKind::FcnResnet50, Priority::P5, 2.0),
        MixEntry::new(ModelKind::Resnet101, Priority::P6, 2.0),
        MixEntry::new(ModelKind::Vgg16, Priority::P7, 1.0),
    ];
    let arrivals = ArrivalProcess::Poisson {
        mean_interarrival: ms(300.0 * k),
        mean_lifetime: ms(600.0 * k),
        mix,
        horizon: ms(2_000.0 * k),
    };
    let mut cfg = ChurnConfig::new(3, PlacementPolicy::BestMatch, arrivals);
    cfg.capacity = 2;
    cfg.mode = Mode::Fikit;
    cfg.seed = opts.seed;
    cfg.qos.scan_interval = ms(250.0 * k);
    cfg.qos.window = ms(750.0 * k);
    cfg.metrics_window = ms(500.0 * k);
    cfg
}

fn row(t: &mut TextTable, name: &str, r: &ChurnReport) {
    t.row(vec![
        name.to_string(),
        r.services.len().to_string(),
        r.rejected.to_string(),
        r.completed_total.to_string(),
        format!("{}/{}", r.qos_violations, r.scans),
        r.migrations.to_string(),
        format!("{:.2}x", r.high_mean_slowdown()),
        format!("{:.1}", r.low_throughput_per_s()),
    ]);
}

/// Run the cluster-churn experiment.
pub fn run(opts: Options) -> Result<ExperimentResult> {
    let compat = CompatMatrix::new(); // analytic prediction fallback

    let no_mig = run_churn(&rescue_cfg(opts, false), &compat)?;
    let mig = run_churn(&rescue_cfg(opts, true), &compat)?;
    let mig_replay = run_churn(&rescue_cfg(opts, true), &compat)?;
    let fikit = run_churn(&fikit_churn_cfg(opts), &compat)?;

    let mut table = TextTable::new(&[
        "scenario",
        "services",
        "rejected",
        "completed",
        "QoS viol.",
        "migrations",
        "H mean slow",
        "L thr (/s)",
    ]);
    row(&mut table, "rescue (no migration)", &no_mig);
    row(&mut table, "rescue (migration)", &mig);
    row(&mut table, "fikit-churn (poisson)", &fikit);

    let series = vec![
        ("violations/no_migration".to_string(), no_mig.qos_violations as f64),
        ("violations/migration".to_string(), mig.qos_violations as f64),
        ("migrations".to_string(), mig.migrations as f64),
        ("h_slowdown/no_migration".to_string(), no_mig.high_mean_slowdown()),
        ("h_slowdown/migration".to_string(), mig.high_mean_slowdown()),
        ("low_thr/migration".to_string(), mig.low_throughput_per_s()),
        ("fikit/h_slowdown".to_string(), fikit.high_mean_slowdown()),
        ("fikit/completed".to_string(), fikit.completed_total as f64),
    ];

    let accepted_all_ran = fikit
        .services
        .iter()
        .filter(|s| !s.rejected)
        .all(|s| s.completed > 0);
    let checks = vec![
        ShapeCheck::new(
            "the bad co-location is detected",
            no_mig.qos_violations > 0,
            format!("{} violations without migration", no_mig.qos_violations),
        ),
        ShapeCheck::new(
            "reactive migration fires",
            mig.migrations >= 1,
            format!("{} migrations", mig.migrations),
        ),
        ShapeCheck::new(
            "migration reduces QoS bound violations",
            mig.qos_violations < no_mig.qos_violations,
            format!(
                "violations: {} with migration vs {} without",
                mig.qos_violations, no_mig.qos_violations
            ),
        ),
        ShapeCheck::new(
            "low-priority work keeps completing after migration",
            mig.low_throughput_per_s() > 0.0,
            format!("{:.1} low-prio tasks/s", mig.low_throughput_per_s()),
        ),
        ShapeCheck::new(
            "deterministic replay under the fixed seed",
            mig.qos_violations == mig_replay.qos_violations
                && mig.migrations == mig_replay.migrations
                && mig.completed_total == mig_replay.completed_total
                && mig.sim_end == mig_replay.sim_end,
            format!(
                "run A: ({}, {}, {}, {}); run B: ({}, {}, {}, {})",
                mig.qos_violations,
                mig.migrations,
                mig.completed_total,
                mig.sim_end,
                mig_replay.qos_violations,
                mig_replay.migrations,
                mig_replay.completed_total,
                mig_replay.sim_end
            ),
        ),
        ShapeCheck::new(
            "every accepted service in the poisson churn completes work",
            accepted_all_ran,
            format!(
                "{} services, {} rejected, {} tasks completed",
                fikit.services.len(),
                fikit.rejected,
                fikit.completed_total
            ),
        ),
    ];

    let notes = format!(
        "rescue: LeastLoaded forces resnet101 (P6) next to keypointrcnn (P0) while the \
         compatible device is full; once the short-lived vgg departs, the scanner \
         (bound {:.1}x) migrates it away. windowed trajectory (migration run):\n{}",
        rescue_cfg(opts, true).qos.high_slowdown_bound,
        mig.fleet.summary_table(mig.sim_end).render()
    );

    Ok(ExperimentResult {
        id: "cluster_churn",
        title: "Dynamic cluster serving: churn + reactive QoS migration",
        table,
        series,
        checks,
        notes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_churn_runs_quick() {
        let r = run(Options::quick()).unwrap();
        assert!(r.series.len() >= 8);
        assert!(r.all_checks_pass(), "{}", r.render());
    }
}
