//! **Ablation** — the runtime feedback early stop (paper Fig 12).
//!
//! Not a table in the paper's evaluation, but the mechanism §3.2 argues
//! is essential: without it, gap-prediction error propagates and the
//! scheduler keeps committing fill kernels after the real gap ended
//! (overhead 1). This ablation runs combo A with feedback on vs off and
//! quantifies the damage to the high-priority service.

use super::combos::{combo_config, profile_combo_scratch, windowed_mean_ms, COMBOS, HIGH_KEY};
use super::{ExperimentResult, Options, ShapeCheck};
use crate::coordinator::driver::{run_with_profiles_scratch, SimScratch};
use crate::coordinator::Mode;
use crate::core::Result;
use crate::metrics::TextTable;

pub fn run(opts: Options) -> Result<ExperimentResult> {
    let tasks = opts.tasks(300);
    let mut table = TextTable::new(&[
        "combo", "H JCT w/ feedback (ms)", "H JCT w/o feedback (ms)", "penalty %", "early stops",
    ]);
    let mut series = Vec::new();
    let mut penalties = Vec::new();
    // One event-core scratch across the on/off pairs.
    let mut scratch = SimScratch::new();

    for combo in COMBOS.iter().take(3) {
        let mut on_cfg = combo_config(combo, Mode::Fikit, tasks, opts);
        on_cfg.feedback = true;
        let profiles = profile_combo_scratch(&on_cfg, &mut scratch)?;
        let on = run_with_profiles_scratch(&on_cfg, &profiles, &mut scratch)?;

        let mut off_cfg = combo_config(combo, Mode::Fikit, tasks, opts);
        off_cfg.feedback = false;
        let off = run_with_profiles_scratch(&off_cfg, &profiles, &mut scratch)?;

        let h_on = windowed_mean_ms(&on, HIGH_KEY);
        let h_off = windowed_mean_ms(&off, HIGH_KEY);
        let penalty = (h_off - h_on) / h_on * 100.0;
        penalties.push(penalty);
        series.push((format!("penalty/{}", combo.label), penalty));
        let early = on
            .scheduler
            .as_ref()
            .map(|s| s.feedback.early_stops)
            .unwrap_or(0);
        table.row(vec![
            combo.label.to_string(),
            format!("{h_on:.2}"),
            format!("{h_off:.2}"),
            format!("{penalty:+.1}%"),
            early.to_string(),
        ]);
    }

    let max_penalty = penalties.iter().cloned().fold(f64::MIN, f64::max);
    let checks = vec![ShapeCheck::new(
        "feedback protects the high-priority service",
        max_penalty > 0.0,
        format!("disabling feedback costs up to {max_penalty:+.1}% high-prio JCT"),
    )];

    Ok(ExperimentResult {
        id: "ablation_feedback",
        title: "Ablation: runtime feedback early stop on/off (Fig 12 mechanism)",
        table,
        series,
        checks,
        notes: format!("combos A–C, {tasks} tasks per service, shared profiles across arms"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_runs_quick() {
        let r = run(Options::quick()).unwrap();
        assert_eq!(r.series.len(), 3);
        // Penalty may be small at tiny scale; just require the harness ran.
        assert!(!r.table.render().is_empty());
    }
}
