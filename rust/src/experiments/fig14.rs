//! **Fig 14** — experiment scheme II: single ML inference service under
//! FIKIT (sharing stage) vs the NVIDIA default environment.
//!
//! A profiled service served through the full FIKIT machinery (hook
//! interception + scheduler routing) with no co-tenant must cost almost
//! nothing extra: the paper measures +0.09 %…+4.93 %. The overhead here
//! comes from the hook's per-launch interception cost on the CPU launch
//! path.

use super::combos::SINGLE_GROUPS;
use super::{ExperimentResult, Options, ShapeCheck};
use crate::config::{ExperimentConfig, ServiceConfig};
use crate::coordinator::driver::{run_experiment_scratch, SimScratch};
use crate::coordinator::Mode;
use crate::core::{Priority, Result};
use crate::metrics::TextTable;

pub fn run(opts: Options) -> Result<ExperimentResult> {
    let tasks = opts.tasks(1000);
    let mut table = TextTable::new(&["model", "base JCT (ms)", "FIKIT JCT (ms)", "overhead %"]);
    let mut series = Vec::new();
    let mut max_oh = f64::MIN;
    let mut min_oh = f64::MAX;
    // One event-core scratch across the whole sweep.
    let mut scratch = SimScratch::new();

    for model in SINGLE_GROUPS {
        let mut run_mode = |mode: Mode| -> Result<f64> {
            let mut cfg = ExperimentConfig {
                mode,
                seed: opts.seed,
                ..ExperimentConfig::default()
            };
            cfg.measurement.runs = 5; // profiling pass size (FIKIT mode only)
            cfg.services
                .push(ServiceConfig::new(model, Priority::P0).tasks(tasks));
            let report = run_experiment_scratch(&cfg, &mut scratch)?;
            Ok(report.services[0].jct.mean_ms())
        };
        let base = run_mode(Mode::Sharing)?;
        let fikit = run_mode(Mode::Fikit)?;
        let overhead = (fikit - base) / base * 100.0;
        max_oh = max_oh.max(overhead);
        min_oh = min_oh.min(overhead);
        series.push((model.name().to_string(), overhead));
        table.row(vec![
            model.name().to_string(),
            format!("{base:.3}"),
            format!("{fikit:.3}"),
            format!("{overhead:+.2}%"),
        ]);
    }

    let checks = vec![
        ShapeCheck::new(
            "overhead under 5%",
            max_oh < 5.0,
            format!("max overhead {max_oh:.2}% (paper: 0.09%…4.93%)"),
        ),
        ShapeCheck::new(
            "overhead non-catastrophic everywhere",
            min_oh > -5.0,
            format!("min overhead {min_oh:.2}%"),
        ),
    ];

    Ok(ExperimentResult {
        id: "fig14",
        title: "Single-service JCT overhead, FIKIT sharing stage vs NVIDIA default (scheme II)",
        table,
        series,
        checks,
        notes: format!("{tasks} inferences per model; same seed both environments"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig14_shape_holds_quick() {
        let r = run(Options::quick()).unwrap();
        assert_eq!(r.series.len(), 7);
        assert!(r.all_checks_pass(), "{}", r.render());
    }
}
