//! **Ablation** — the within-priority fill selection rule.
//!
//! Algorithm 2 picks the *longest* fitting kernel ("best fit"). This
//! ablation compares it against FirstFit (FIFO fairness) and ShortestFit
//! (minimal overrun risk) on combo A across both sides of the trade:
//! high-priority protection (JCT) and low-priority progress (fills,
//! scavenged device time).

use super::combos::{base_config, profile_combo_scratch, windowed_mean_ms, HIGH_KEY};
use super::{ExperimentResult, Options, ShapeCheck};
use crate::config::{ExperimentConfig, ServiceConfig};
use crate::coordinator::best_prio_fit::FillPolicy;
use crate::coordinator::driver::{run_with_profiles_scratch, SimScratch};
use crate::coordinator::Mode;
use crate::core::{Priority, Result};
use crate::metrics::TextTable;
use crate::workload::ModelKind;

/// A gappy high-priority host plus three same-priority background
/// services with different kernel sizes — so every BestPrioFit scan has
/// several candidates and the within-priority rule actually matters.
fn ablation_config(tasks: u32, opts: Options) -> ExperimentConfig {
    let mut cfg = base_config(opts);
    cfg.mode = Mode::Fikit;
    cfg.services.push(
        ServiceConfig::new(ModelKind::KeypointRcnnResnet50Fpn, Priority::P0)
            .tasks(tasks)
            .with_key(HIGH_KEY),
    );
    for (model, key) in [
        (ModelKind::FcnResnet50, "low-fcn"),
        (ModelKind::Resnet101, "low-r101"),
        (ModelKind::Vgg16, "low-vgg"),
    ] {
        cfg.services.push(
            ServiceConfig::new(model, Priority::P4)
                .tasks(tasks)
                .with_key(key),
        );
    }
    cfg
}

pub fn run(opts: Options) -> Result<ExperimentResult> {
    let tasks = opts.tasks(200);

    let mut table = TextTable::new(&[
        "policy", "H JCT (ms)", "L mean JCT (ms)", "fills", "fill busy (ms)",
    ]);
    let mut series = Vec::new();
    let mut rows = Vec::new();
    // One event-core scratch across the three policy runs.
    let mut scratch = SimScratch::new();

    for (name, policy) in [
        ("longest (paper)", FillPolicy::LongestFit),
        ("first", FillPolicy::FirstFit),
        ("shortest", FillPolicy::ShortestFit),
    ] {
        let mut cfg = ablation_config(tasks, opts);
        cfg.fill_policy = policy;
        let profiles = profile_combo_scratch(&cfg, &mut scratch)?;
        let report = run_with_profiles_scratch(&cfg, &profiles, &mut scratch)?;
        let h = windowed_mean_ms(&report, HIGH_KEY);
        let l = ["low-fcn", "low-r101", "low-vgg"]
            .iter()
            .map(|k| windowed_mean_ms(&report, k))
            .sum::<f64>()
            / 3.0;
        let fills = report.scheduler.as_ref().map(|s| s.fills).unwrap_or(0);
        let fill_busy = report.device.fill_busy.as_millis_f64();
        series.push((format!("h_jct/{name}"), h));
        series.push((format!("fill_busy/{name}"), fill_busy));
        rows.push((name, h, l, fills, fill_busy));
        table.row(vec![
            name.to_string(),
            format!("{h:.2}"),
            format!("{l:.2}"),
            fills.to_string(),
            format!("{fill_busy:.1}"),
        ]);
    }

    let (_, h_long, _, _, busy_long) = rows[0];
    let (_, h_short, _, _, busy_short) = rows[2];
    let checks = vec![
        ShapeCheck::new(
            "longest-fit scavenges at least as much device time",
            busy_long >= busy_short * 0.95,
            format!("fill busy: longest {busy_long:.1}ms vs shortest {busy_short:.1}ms"),
        ),
        ShapeCheck::new(
            "high-priority protection comparable across policies",
            (h_long - h_short).abs() / h_long < 0.15,
            format!("H JCT: longest {h_long:.2}ms vs shortest {h_short:.2}ms"),
        ),
    ];

    Ok(ExperimentResult {
        id: "ablation_fill_policy",
        title: "Ablation: within-priority fill selection (Algorithm 2 LongestFit vs alternatives)",
        table,
        series,
        checks,
        notes: format!(
            "keypointrcnn (P0) + three P4 background services, {tasks} tasks each, shared profiles across arms"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_policy_ablation_runs_quick() {
        let r = run(Options::quick()).unwrap();
        assert_eq!(r.series.len(), 6);
        assert!(r.all_checks_pass(), "{}", r.render());
    }
}
