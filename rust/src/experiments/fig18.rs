//! **Fig 18** — low-priority JCT under exclusive mode vs FIKIT as the
//! high:low task ratio grows (§4.5.2).
//!
//! Exclusive mode serializes whole tasks by priority: each of B's tasks
//! waits for the `ratio` A-tasks issued since its predecessor, so B's
//! JCT grows linearly with the ratio (1:1 → 50:1) while FIKIT's stays
//! flat (B scavenges A's gaps continuously). The paper's plot is the
//! exclusive/FIKIT JCT ratio rising linearly from ≈1.
//!
//! Methodology follows the paper: exclusive mode cannot co-run two
//! services, so A and B are measured separately (solo runs) and B's
//! exclusive JCT is composed as `ratio × mean(JCT_A) + mean(JCT_B)`.

use super::combos::{base_config, HIGH_KEY, LOW_KEY};
use super::{ExperimentResult, Options, ShapeCheck};
use crate::config::ServiceConfig;
use crate::coordinator::driver::{
    run_experiment_scratch, run_with_profiles_scratch, SimScratch,
};
use crate::coordinator::Mode;
use crate::core::{Priority, Result, TaskKey};
use crate::metrics::TextTable;
use crate::workload::ModelKind;

pub const RATIOS: [u32; 6] = [1, 10, 20, 30, 40, 50];

pub fn run(opts: Options) -> Result<ExperimentResult> {
    let high = ModelKind::KeypointRcnnResnet50Fpn;
    let low = ModelKind::FcnResnet50;
    let b_tasks = opts.tasks(20);

    let mut table = TextTable::new(&[
        "A:B ratio", "B excl JCT (ms)", "B FIKIT JCT (ms)", "excl/FIKIT",
    ]);
    let mut series = Vec::new();
    let mut ratios_out = Vec::new();

    // One event-core scratch across the baselines and the ratio sweep.
    let mut scratch = SimScratch::new();

    // Solo baselines (measured once; the paper measures each service
    // separately and composes).
    let mut a_cfg = base_config(opts);
    a_cfg.mode = Mode::Sharing; // solo
    a_cfg
        .services
        .push(ServiceConfig::new(high, Priority::P0).tasks(b_tasks * 4).with_key(HIGH_KEY));
    let a_solo_mean = run_experiment_scratch(&a_cfg, &mut scratch)?.services[0]
        .jct
        .mean_ms();

    let mut b_cfg = base_config(opts);
    b_cfg.mode = Mode::Sharing; // solo
    b_cfg
        .services
        .push(ServiceConfig::new(low, Priority::P3).tasks(b_tasks).with_key(LOW_KEY));
    let b_solo_mean = run_experiment_scratch(&b_cfg, &mut scratch)?.services[0]
        .jct
        .mean_ms();

    for ratio in RATIOS {
        let a_tasks = b_tasks * ratio;

        // --- exclusive: tasks run in priority order, so each B task
        // waits for the `ratio` A tasks issued since its predecessor ---
        let b_excl_ms = ratio as f64 * a_solo_mean + b_solo_mean;

        // --- FIKIT: truly concurrent ---
        let mut f_cfg = base_config(opts);
        f_cfg.mode = Mode::Fikit;
        f_cfg
            .services
            .push(ServiceConfig::new(high, Priority::P0).tasks(a_tasks).with_key(HIGH_KEY));
        f_cfg
            .services
            .push(ServiceConfig::new(low, Priority::P3).tasks(b_tasks).with_key(LOW_KEY));
        let profiles = super::combos::profile_combo_scratch(&f_cfg, &mut scratch)?;
        let fikit = run_with_profiles_scratch(&f_cfg, &profiles, &mut scratch)?;
        let b_fikit_ms = fikit
            .service(&TaskKey::new(LOW_KEY))
            .map(|s| s.jct.mean_ms())
            .unwrap_or(f64::NAN);

        let r = b_excl_ms / b_fikit_ms;
        ratios_out.push(r);
        series.push((format!("ratio_{ratio}"), r));
        table.row(vec![
            format!("{ratio}:1"),
            format!("{b_excl_ms:.1}"),
            format!("{b_fikit_ms:.1}"),
            format!("{r:.2}x"),
        ]);
    }

    // Linear-trend check: ratio at 50:1 should be ≈50/10× the ratio at
    // 10:1 (within 2×), and monotone throughout.
    let monotone = ratios_out.windows(2).all(|w| w[1] > w[0]);
    let lin = ratios_out[5] / ratios_out[1];
    let checks = vec![
        ShapeCheck::new(
            "starts near parity",
            ratios_out[0] < 4.0,
            format!("1:1 ratio = {:.2}x (paper: close to FIKIT)", ratios_out[0]),
        ),
        ShapeCheck::new(
            "monotone growth with ratio",
            monotone,
            format!("ratios: {ratios_out:.2?}"),
        ),
        ShapeCheck::new(
            "linear trend",
            (2.5..10.0).contains(&lin),
            format!("ratio(50:1)/ratio(10:1) = {lin:.2} (linear → ≈5)"),
        ),
    ];

    Ok(ExperimentResult {
        id: "fig18",
        title: "Low-priority JCT: exclusive mode vs FIKIT across A:B task ratios",
        table,
        series,
        checks,
        notes: format!(
            "B issues {b_tasks} tasks; A issues ratio×{b_tasks}; exclusive composed per paper §4.5.2"
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig18_shape_holds_quick() {
        let r = run(Options::quick()).unwrap();
        assert_eq!(r.series.len(), RATIOS.len());
        assert!(r.all_checks_pass(), "{}", r.render());
    }
}
