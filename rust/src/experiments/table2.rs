//! **Table 2** — total execution time of two concurrently-issued
//! services under default sharing vs FIKIT.
//!
//! Service A: keypointrcnn_resnet50_fpn (high priority), service B:
//! fcn_resnet50 (low priority), 1000 inferences each. The paper's table:
//!
//! | mode    | service A | service B |
//! |---------|-----------|-----------|
//! | sharing | 38.16 s   | 16.02 s   |
//! | FIKIT   | 33.13 s   | 39.10 s   |
//!
//! Shape: FIKIT shortens A's total (priority protected) and lengthens
//! B's (it only scavenges gaps) — the totals *cross over* between modes.

use super::combos::{run_combo_share_vs_fikit, Combo, HIGH_KEY, LOW_KEY};
use super::{ExperimentResult, Options, ShapeCheck};
use crate::core::{Result, TaskKey};
use crate::metrics::TextTable;
use crate::workload::ModelKind;

pub fn run(opts: Options) -> Result<ExperimentResult> {
    let combo = Combo {
        label: "table2",
        high: ModelKind::KeypointRcnnResnet50Fpn,
        low: ModelKind::FcnResnet50,
    };
    let tasks = opts.tasks(1000);
    let (share, fikit) = run_combo_share_vs_fikit(&combo, tasks, opts)?;

    let total = |report: &crate::coordinator::driver::ExperimentReport, key: &str| -> f64 {
        report
            .service(&TaskKey::new(key))
            .map(|s| {
                s.timeline
                    .points
                    .last()
                    .map(|p| (p.arrival + p.jct).as_secs_f64())
                    .unwrap_or(0.0)
            })
            .unwrap_or(0.0)
    };

    let share_a = total(&share, HIGH_KEY);
    let share_b = total(&share, LOW_KEY);
    let fikit_a = total(&fikit, HIGH_KEY);
    let fikit_b = total(&fikit, LOW_KEY);

    let mut table = TextTable::new(&["mode", "service A total (s)", "service B total (s)"]);
    table.row(vec![
        "default sharing".into(),
        format!("{share_a:.3}"),
        format!("{share_b:.3}"),
    ]);
    table.row(vec![
        "FIKIT".into(),
        format!("{fikit_a:.3}"),
        format!("{fikit_b:.3}"),
    ]);

    let checks = vec![
        ShapeCheck::new(
            "FIKIT shortens A's total",
            fikit_a < share_a,
            format!("A: {share_a:.2}s (share) → {fikit_a:.2}s (FIKIT)"),
        ),
        ShapeCheck::new(
            "FIKIT lengthens B's total",
            fikit_b > share_b,
            format!("B: {share_b:.2}s (share) → {fikit_b:.2}s (FIKIT)"),
        ),
        ShapeCheck::new(
            "magnitudes: B pays substantially, A gains substantially",
            fikit_b / share_b > 1.3 && share_a / fikit_a > 1.05,
            format!(
                "B slowdown {:.2}x (paper 2.4x), A gain {:.2}x (paper 1.15x)",
                fikit_b / share_b,
                share_a / fikit_a
            ),
        ),
    ];

    Ok(ExperimentResult {
        id: "table2",
        title: "Total execution time of A (keypointrcnn, H) and B (fcn_resnet50, L)",
        table,
        series: vec![
            ("share_a_s".into(), share_a),
            ("share_b_s".into(), share_b),
            ("fikit_a_s".into(), fikit_a),
            ("fikit_b_s".into(), fikit_b),
        ],
        checks,
        notes: format!("{tasks} inferences per service, concurrent back-to-back issue"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_shape_holds_quick() {
        let r = run(Options::quick()).unwrap();
        assert!(r.all_checks_pass(), "{}", r.render());
    }
}
