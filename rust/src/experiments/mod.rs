//! The paper's evaluation section, regenerated.
//!
//! One module per table/figure (DESIGN.md §5 maps each to its workload
//! and parameters). Every experiment returns an [`ExperimentResult`]
//! carrying the rendered table, the raw series, and a set of **shape
//! checks** — the "who wins, by roughly what factor, where crossovers
//! fall" assertions that define a successful reproduction (absolute
//! numbers are not expected to match the authors' RTX 3090 testbed).
//!
//! Run all of them via `cargo bench --bench paper_experiments` or one at
//! a time via `fikit experiment <id>`.

pub mod cluster_churn;
pub mod combos;
pub mod drift;
pub mod fig13;
pub mod fig14;
pub mod fig15;
pub mod fig16_17;
pub mod fig18;
pub mod fig19_20;
pub mod fig21_table3;
pub mod fill_policy;
pub mod interference;
pub mod perf_ablation;
pub mod preemption;
pub mod table2;

use crate::core::Result;
use crate::metrics::TextTable;

/// Scaling knobs for experiment size.
#[derive(Debug, Clone, Copy)]
pub struct Options {
    /// Multiplier on task counts (1.0 = paper-scale where tractable).
    pub scale: f64,
    /// Root seed.
    pub seed: u64,
}

impl Default for Options {
    fn default() -> Options {
        Options {
            scale: 1.0,
            seed: 0xF1C1,
        }
    }
}

impl Options {
    /// Quick smoke-scale (CI): ~10× smaller.
    pub fn quick() -> Options {
        Options {
            scale: 0.1,
            ..Default::default()
        }
    }

    /// Scale a task count (minimum 3 so statistics exist).
    pub fn tasks(&self, paper_count: u32) -> u32 {
        ((paper_count as f64 * self.scale).round() as u32).max(3)
    }
}

/// One shape assertion of an experiment.
#[derive(Debug, Clone)]
pub struct ShapeCheck {
    pub name: String,
    pub passed: bool,
    pub detail: String,
}

impl ShapeCheck {
    pub fn new(name: &str, passed: bool, detail: String) -> ShapeCheck {
        ShapeCheck {
            name: name.to_string(),
            passed,
            detail,
        }
    }
}

/// The outcome of one experiment.
#[derive(Debug)]
pub struct ExperimentResult {
    pub id: &'static str,
    pub title: &'static str,
    pub table: TextTable,
    /// Named scalar series for programmatic consumption
    /// (e.g. per-combo speedups).
    pub series: Vec<(String, f64)>,
    pub checks: Vec<ShapeCheck>,
    /// Free-form notes (methodology, caveats).
    pub notes: String,
}

impl ExperimentResult {
    pub fn all_checks_pass(&self) -> bool {
        self.checks.iter().all(|c| c.passed)
    }

    /// Render the full report block.
    pub fn render(&self) -> String {
        let mut out = format!("== {} — {} ==\n", self.id, self.title);
        out.push_str(&self.table.render());
        if !self.notes.is_empty() {
            out.push_str(&format!("notes: {}\n", self.notes));
        }
        for c in &self.checks {
            out.push_str(&format!(
                "  [{}] {}: {}\n",
                if c.passed { "PASS" } else { "FAIL" },
                c.name,
                c.detail
            ));
        }
        out
    }

    pub fn series_value(&self, name: &str) -> Option<f64> {
        self.series
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| *v)
    }
}

/// All experiment ids, in paper order.
pub const ALL: &[&str] = &[
    "fig13",
    "fig14",
    "fig15",
    "table2",
    "fig16",
    "fig18",
    "fig19",
    "fig21",
    "ablation_feedback",
    "ablation_fill_policy",
    "cluster_churn",
    "drift",
    "interference",
    "preemption",
];

/// Run one experiment by id.
pub fn run(id: &str, opts: Options) -> Result<ExperimentResult> {
    match id {
        "fig13" => fig13::run(opts),
        "fig14" => fig14::run(opts),
        "fig15" => fig15::run(opts),
        "table2" => table2::run(opts),
        // fig16 and fig17 come from the same runs; one result carries both.
        "fig16" | "fig17" => fig16_17::run(opts),
        "fig18" => fig18::run(opts),
        "fig19" | "fig20" => fig19_20::run(opts),
        "fig21" | "table3" => fig21_table3::run(opts),
        "ablation_feedback" => perf_ablation::run(opts),
        "ablation_fill_policy" => fill_policy::run(opts),
        "cluster_churn" => cluster_churn::run(opts),
        "drift" => drift::run(opts),
        "interference" => interference::run(opts),
        "preemption" => preemption::run(opts),
        other => Err(crate::core::Error::Parse(format!(
            "unknown experiment {other:?}; known: {ALL:?}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_is_an_error() {
        assert!(run("nope", Options::quick()).is_err());
    }

    #[test]
    fn options_scaling() {
        let o = Options::quick();
        assert_eq!(o.tasks(1000), 100);
        assert_eq!(o.tasks(10), 3); // floor
        let full = Options::default();
        assert_eq!(full.tasks(1000), 1000);
    }
}
