//! The ten service combinations A–J used by Figs 16, 17, 19, 20, 21 and
//! Table 3, plus shared experiment plumbing.

use super::Options;
use crate::config::{ExperimentConfig, ServiceConfig};
use crate::coordinator::driver::{
    profile_service_scratch, run_with_profiles_scratch, ExperimentReport, SimScratch,
};
use crate::coordinator::Mode;
use crate::core::{Priority, Result};
use crate::profile::ProfileStore;
use crate::workload::ModelKind;

/// One paper combo: a high-priority and a low-priority service.
#[derive(Debug, Clone, Copy)]
pub struct Combo {
    pub label: &'static str,
    pub high: ModelKind,
    pub low: ModelKind,
}

/// The combos exactly as listed under Fig 16 of the paper.
pub const COMBOS: [Combo; 10] = [
    Combo { label: "A", high: ModelKind::KeypointRcnnResnet50Fpn, low: ModelKind::FcnResnet50 },
    Combo { label: "B", high: ModelKind::KeypointRcnnResnet50Fpn, low: ModelKind::FcosResnet50Fpn },
    Combo { label: "C", high: ModelKind::FasterrcnnResnet50Fpn, low: ModelKind::Deeplabv3Resnet101 },
    Combo { label: "D", high: ModelKind::FasterrcnnResnet50Fpn, low: ModelKind::FcnResnet50 },
    Combo { label: "E", high: ModelKind::KeypointRcnnResnet50Fpn, low: ModelKind::Deeplabv3Resnet101 },
    Combo { label: "F", high: ModelKind::Alexnet, low: ModelKind::Vgg16 },
    Combo { label: "G", high: ModelKind::MaskrcnnResnet50Fpn, low: ModelKind::FcnResnet50 },
    Combo { label: "H", high: ModelKind::MaskrcnnResnet50Fpn, low: ModelKind::KeypointRcnnResnet50Fpn },
    Combo { label: "I", high: ModelKind::MaskrcnnResnet50Fpn, low: ModelKind::FcosResnet50Fpn },
    Combo { label: "J", high: ModelKind::Deeplabv3Resnet50, low: ModelKind::Resnet101 },
];

/// The seven single-service model groups used by Figs 13–15 (the paper
/// names GoogLeNet, ResNet50, AlexNet and deeplabv3_resnet101 among its
/// "seven groups of common models").
pub const SINGLE_GROUPS: [ModelKind; 7] = [
    ModelKind::Googlenet,
    ModelKind::Resnet50,
    ModelKind::Alexnet,
    ModelKind::Deeplabv3Resnet101,
    ModelKind::Vgg16,
    ModelKind::FcnResnet50,
    ModelKind::MaskrcnnResnet50Fpn,
];

/// Standard keys for the two services of a combo.
pub const HIGH_KEY: &str = "svcA-high";
pub const LOW_KEY: &str = "svcB-low";

/// Base experiment config shared by combo experiments.
pub fn base_config(opts: Options) -> ExperimentConfig {
    ExperimentConfig {
        seed: opts.seed,
        ..ExperimentConfig::default()
    }
}

/// Config for a combo: both services issue `tasks` back-to-back
/// inferences concurrently (paper §4.5.1).
pub fn combo_config(combo: &Combo, mode: Mode, tasks: u32, opts: Options) -> ExperimentConfig {
    let mut cfg = base_config(opts);
    cfg.mode = mode;
    cfg.services.push(
        ServiceConfig::new(combo.high, Priority::P0)
            .tasks(tasks)
            .with_key(HIGH_KEY),
    );
    cfg.services.push(
        ServiceConfig::new(combo.low, Priority::P3)
            .tasks(tasks)
            .with_key(LOW_KEY),
    );
    cfg
}

/// Profile both services of a combo once and reuse across modes — the
/// deployment lifecycle (measurement is paid once per service, not per
/// experiment).
pub fn profile_combo(cfg: &ExperimentConfig) -> Result<ProfileStore> {
    profile_combo_scratch(cfg, &mut SimScratch::new())
}

/// [`profile_combo`] reusing a caller-owned event-core scratch — sweeps
/// calling this per ratio/combo pay the queue allocation once.
pub fn profile_combo_scratch(
    cfg: &ExperimentConfig,
    scratch: &mut SimScratch,
) -> Result<ProfileStore> {
    let mut store = ProfileStore::new();
    for svc in &cfg.services {
        store.insert(profile_service_scratch(cfg, svc, scratch)?.profile);
    }
    Ok(store)
}

/// Run one combo in both Sharing and Fikit modes over the same seeds,
/// returning `(sharing, fikit)` reports.
pub fn run_combo_share_vs_fikit(
    combo: &Combo,
    tasks: u32,
    opts: Options,
) -> Result<(ExperimentReport, ExperimentReport)> {
    run_combo_share_vs_fikit_scratch(combo, tasks, opts, &mut SimScratch::new())
}

/// [`run_combo_share_vs_fikit`] reusing a caller-owned scratch.
pub fn run_combo_share_vs_fikit_scratch(
    combo: &Combo,
    tasks: u32,
    opts: Options,
    scratch: &mut SimScratch,
) -> Result<(ExperimentReport, ExperimentReport)> {
    let fikit_cfg = combo_config(combo, Mode::Fikit, tasks, opts);
    let profiles = profile_combo_scratch(&fikit_cfg, scratch)?;
    let fikit = run_with_profiles_scratch(&fikit_cfg, &profiles, scratch)?;
    let share_cfg = combo_config(combo, Mode::Sharing, tasks, opts);
    let share = run_with_profiles_scratch(&share_cfg, &ProfileStore::new(), scratch)?;
    Ok((share, fikit))
}

/// Mean JCT (ms) of a service within the fully-overlapping window of a
/// report (paper §4.5.1 methodology).
pub fn windowed_mean_ms(report: &ExperimentReport, key: &str) -> f64 {
    let window = report.overlap_end();
    let stats = report.jct_in_window(&crate::core::TaskKey::new(key), window);
    if stats.count == 0 {
        // Degenerate window (very small runs): fall back to all tasks.
        report
            .service(&crate::core::TaskKey::new(key))
            .map(|s| s.jct.mean_ms())
            .unwrap_or(0.0)
    } else {
        stats.mean_ms()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combos_match_paper_listing() {
        assert_eq!(COMBOS.len(), 10);
        assert_eq!(COMBOS[0].label, "A");
        assert_eq!(COMBOS[5].high, ModelKind::Alexnet);
        assert_eq!(COMBOS[5].low, ModelKind::Vgg16);
        assert_eq!(COMBOS[9].high, ModelKind::Deeplabv3Resnet50);
        assert_eq!(COMBOS[9].low, ModelKind::Resnet101);
        // Labels unique.
        let mut labels: Vec<&str> = COMBOS.iter().map(|c| c.label).collect();
        labels.dedup();
        assert_eq!(labels.len(), 10);
    }

    #[test]
    fn combo_config_builds_two_prioritized_services() {
        let combo = &COMBOS[0];
        let cfg = combo_config(combo, Mode::Fikit, 10, Options::quick());
        cfg.validate().unwrap();
        assert_eq!(cfg.services.len(), 2);
        assert!(cfg.services[0].priority.is_higher_than(cfg.services[1].priority));
    }

    #[test]
    fn share_vs_fikit_smoke() {
        let (share, fikit) = run_combo_share_vs_fikit(&COMBOS[5], 6, Options::quick()).unwrap();
        assert_eq!(share.mode, Mode::Sharing);
        assert_eq!(fikit.mode, Mode::Fikit);
        assert!(windowed_mean_ms(&share, HIGH_KEY) > 0.0);
        assert!(windowed_mean_ms(&fikit, HIGH_KEY) > 0.0);
    }
}
