//! **Interference-aware eviction** — learned aggressor identification vs
//! victim-symptom migration (DESIGN.md §8, ADR-006).
//!
//! The scenario plants a disguised aggressor: a `googlenet` service whose
//! injected gap scale (0.1×) turns the normally gappy classifier into a
//! near-continuous occupant of its device. Against the *offline* compat
//! matrix, googlenet looks like a polite small filler — priors alone
//! would never finger it. Under an overlapping concurrency backend
//! (`MpsSpatial`) its true behaviour dilates the co-resident
//! high-priority detector past the QoS bound.
//!
//! Two eviction strategies race on the identical trace and seed:
//!
//! * **worst-aggressor** (the ADR-006 default) — the scanner evicts the
//!   low-priority resident with the highest *learned* predicted dilation
//!   on the device's high-priority tenants. With `learn_interference`
//!   on, the EWMA pulls the (detector, googlenet) cell off its innocent
//!   prior within a few windows, and the scanner migrates the actual
//!   culprit.
//! * **noisiest-victim** (the pre-ADR-006 behaviour) — the scanner
//!   evicts the low-priority resident with the worst *observed own*
//!   slowdown. An aggressor that monopolizes the device barely slows
//!   down itself, so this heuristic tends to deport an innocent
//!   bystander and leave the culprit co-resident with the detector.
//!
//! The race repeats across every [`ConcurrencyBackend`]: under
//! `TimeSliced` the backends are interference-free by construction and
//! the strategies tie; under `MpsSpatial` and `MigPartition` the
//! aggressor-eviction run must hold the high-priority slowdown at or
//! below the victim-eviction run.

use super::{ExperimentResult, Options, ShapeCheck};
use crate::cluster::{
    run_churn, ChurnConfig, ChurnReport, CompatMatrix, EvictionStrategy, PlacementPolicy,
};
use crate::coordinator::Mode;
use crate::core::{Duration, Priority, Result, SimTime};
use crate::metrics::TextTable;
use crate::simulator::ConcurrencyBackend;
use crate::workload::{ArrivalProcess, ModelKind, ServiceArrival};

const HIGH: ModelKind = ModelKind::KeypointRcnnResnet50Fpn;
const BENIGN: ModelKind = ModelKind::FcosResnet50Fpn;
const AGGRESSOR: ModelKind = ModelKind::Googlenet;
/// Trace index of the aggressor arrival (RoundRobin lands it on GPU 0
/// with the detector) and its injected gap scale.
const AGGRESSOR_IDX: usize = 4;
const AGGRESSOR_GAP_SCALE: f64 = 0.1;
/// MPS throughput dilation for the overlap runs: strong enough that a
/// near-continuous co-runner pushes the detector past the 1.2× QoS
/// bound (the default 0.15 models a politer MPS deployment and would
/// keep the aggressor under the bound — no scanner, no story).
const MPS_DILATION: f64 = 0.5;

/// Same proportional time stretch as the other churn experiments.
fn stretch(opts: Options) -> f64 {
    opts.scale.clamp(0.25, 1.0)
}

fn ms(v: f64) -> Duration {
    Duration::from_millis_f64(v)
}

/// The planted-aggressor trace (times scaled by `k`). RoundRobin over
/// 2 GPUs pins even arrivals to GPU 0, odd to GPU 1:
///
/// * t=0     keypointrcnn P0, life 3000k — the protected tenant (GPU 0)
/// * t=10k   resnet50     P4, life 2800k — background (GPU 1)
/// * t=100k  fcos         P5, life 2600k — benign gappy bystander (GPU 0)
/// * t=110k  resnet50     P4, life 2500k — background (GPU 1)
/// * t=800k  googlenet    P6, life 1800k — the disguised aggressor (GPU 0)
fn arrivals(k: f64) -> ArrivalProcess {
    let at = |v: f64| SimTime::ZERO + ms(v * k);
    ArrivalProcess::Trace(vec![
        ServiceArrival::new(SimTime::ZERO, HIGH, Priority::P0, ms(3_000.0 * k)),
        ServiceArrival::new(at(10.0), ModelKind::Resnet50, Priority::P4, ms(2_800.0 * k)),
        ServiceArrival::new(at(100.0), BENIGN, Priority::P5, ms(2_600.0 * k)),
        ServiceArrival::new(at(110.0), ModelKind::Resnet50, Priority::P4, ms(2_500.0 * k)),
        ServiceArrival::new(at(800.0), AGGRESSOR, Priority::P6, ms(1_800.0 * k)),
    ])
}

fn cfg(opts: Options, backend: ConcurrencyBackend, eviction: EvictionStrategy) -> ChurnConfig {
    let k = stretch(opts);
    let mut cfg = ChurnConfig::new(2, PlacementPolicy::RoundRobin, arrivals(k));
    cfg.capacity = 3;
    // Raw MPS sharing: no FIKIT holds muffling the overlap the backends
    // model — the experiment isolates the eviction decision.
    cfg.mode = Mode::Sharing;
    cfg.seed = opts.seed;
    cfg.backend = backend;
    cfg.learn_interference = true;
    cfg.aggressor = Some((AGGRESSOR_IDX, AGGRESSOR_GAP_SCALE));
    cfg.qos.high_slowdown_bound = 1.2;
    cfg.qos.scan_interval = ms(100.0 * k);
    cfg.qos.window = ms(400.0 * k);
    cfg.qos.eviction = eviction;
    cfg.metrics_window = ms(500.0 * k);
    cfg
}

/// The protected detector's mean slowdown (JCT ÷ solo) over the run.
fn high_slowdown(r: &ChurnReport) -> f64 {
    r.services[0].mean_slowdown
}

fn row(t: &mut TextTable, backend: &ConcurrencyBackend, strategy: &str, r: &ChurnReport) {
    t.row(vec![
        backend.to_string(),
        strategy.to_string(),
        format!("{}/{}", r.qos_violations, r.scans),
        r.migrations.to_string(),
        format!("{:.3}x", high_slowdown(r)),
        r.services[AGGRESSOR_IDX].migrations.to_string(),
        r.interference.observations().to_string(),
    ]);
}

/// Run the interference experiment.
pub fn run(opts: Options) -> Result<ExperimentResult> {
    let compat = CompatMatrix::new(); // analytic priors — googlenet looks benign
    let backends = [
        ConcurrencyBackend::TimeSliced,
        ConcurrencyBackend::MpsSpatial {
            dilation: MPS_DILATION,
        },
        ConcurrencyBackend::mig(2),
    ];

    let mut table = TextTable::new(&[
        "backend",
        "eviction",
        "QoS viol.",
        "migrations",
        "H slow",
        "aggr. moved",
        "obs",
    ]);
    let mut series = Vec::new();
    let mut checks = Vec::new();
    let mut mps_aggr: Option<ChurnReport> = None;

    for backend in &backends {
        let aggr = run_churn(&cfg(opts, *backend, EvictionStrategy::WorstAggressor), &compat)?;
        let victim = run_churn(&cfg(opts, *backend, EvictionStrategy::NoisiestVictim), &compat)?;
        row(&mut table, backend, "worst-aggressor", &aggr);
        row(&mut table, backend, "noisiest-victim", &victim);

        let (a, v) = (high_slowdown(&aggr), high_slowdown(&victim));
        series.push((format!("{}/h_slowdown/aggressor", backend.name()), a));
        series.push((format!("{}/h_slowdown/victim", backend.name()), v));
        series.push((
            format!("{}/migrations/aggressor", backend.name()),
            aggr.migrations as f64,
        ));
        checks.push(ShapeCheck::new(
            &format!("{}: aggressor-eviction no worse than victim-eviction", backend.name()),
            a <= v * 1.05,
            format!("high-prio slowdown {a:.3}x (aggressor) vs {v:.3}x (victim)"),
        ));
        if matches!(backend, ConcurrencyBackend::MpsSpatial { .. }) {
            mps_aggr = Some(aggr);
        }
    }

    let mps = mps_aggr.expect("mps backend is in the sweep");
    let learned = mps.interference.learned(HIGH, AGGRESSOR);
    let benign_dilation = mps
        .interference
        .learned(HIGH, BENIGN)
        .map(|(d, _)| d)
        .unwrap_or(1.0);
    series.push((
        "mps/learned_aggressor_dilation".to_string(),
        learned.map(|(d, _)| d).unwrap_or(0.0),
    ));

    checks.push(ShapeCheck::new(
        "the overlap backend exposes the aggressor to the QoS scanner",
        mps.qos_violations > 0,
        format!("{} violations under mps", mps.qos_violations),
    ));
    checks.push(ShapeCheck::new(
        "online learning ranks the aggressor above the benign bystander",
        learned.map(|(d, _)| d > benign_dilation).unwrap_or(false),
        format!(
            "learned (detector, googlenet) = {:?}, (detector, fcos) dilation = {benign_dilation:.3}",
            learned
        ),
    ));
    checks.push(ShapeCheck::new(
        "the scanner migrates the disguised aggressor, not the bystander",
        mps.services[AGGRESSOR_IDX].migrations >= 1 && mps.services[2].migrations == 0,
        format!(
            "googlenet moved {}x, fcos moved {}x",
            mps.services[AGGRESSOR_IDX].migrations, mps.services[2].migrations
        ),
    ));
    let replay = run_churn(
        &cfg(
            opts,
            ConcurrencyBackend::MpsSpatial {
                dilation: MPS_DILATION,
            },
            EvictionStrategy::WorstAggressor,
        ),
        &compat,
    )?;
    checks.push(ShapeCheck::new(
        "deterministic replay under the fixed seed",
        mps.completed_total == replay.completed_total
            && mps.sim_end == replay.sim_end
            && mps.migrations == replay.migrations
            && mps.interference.epoch() == replay.interference.epoch(),
        format!(
            "run A: ({}, {}, {}, {}); run B: ({}, {}, {}, {})",
            mps.completed_total,
            mps.sim_end,
            mps.migrations,
            mps.interference.epoch(),
            replay.completed_total,
            replay.sim_end,
            replay.migrations,
            replay.interference.epoch()
        ),
    ));

    let notes = format!(
        "googlenet arrives with gap scale {AGGRESSOR_GAP_SCALE} (near-continuous occupancy); \
         offline priors rate it a polite filler, so only the learned EWMA can finger it. \
         bound {:.1}x, eviction compares per-pair predicted dilation on the device's \
         high-priority tenants.",
        cfg(
            opts,
            ConcurrencyBackend::MpsSpatial {
                dilation: MPS_DILATION,
            },
            EvictionStrategy::WorstAggressor,
        )
        .qos
        .high_slowdown_bound
    );

    Ok(ExperimentResult {
        id: "interference",
        title: "Learned interference: aggressor eviction vs victim-symptom eviction",
        table,
        series,
        checks,
        notes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interference_runs_quick() {
        let r = run(Options::quick()).unwrap();
        assert!(r.series.len() >= 9);
        assert!(r.all_checks_pass(), "{}", r.render());
    }
}
