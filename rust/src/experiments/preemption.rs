//! **Preemption Pareto sweep** — fill-only vs preemptive vs hybrid
//! across the paper's evaluation workloads.
//!
//! The paper's "overhead 2" (§4.4) is an in-flight fill kernel that
//! cannot be recalled once submitted: a high-priority launch arriving
//! mid-fill waits out the overrun. [`PreemptionPolicy`] reclaims exactly
//! that tail. This sweep runs every combo A–J in batch mode plus the
//! Fig 21 continuous-insert workload under each policy and places each
//! arm on the Pareto plane:
//!
//! * **high-priority speedup** — sharing-mode H JCT / policy H JCT
//!   (bigger is better; `none` is the plain FIKIT speedup of Fig 16);
//! * **low-priority JCT ratio** — sharing-mode L JCT / policy L JCT
//!   (1.0 = background tenant unharmed; the paper's observed band for
//!   FIKIT sharing is 0.86–1.0).
//!
//! Acceptance: the hybrid point dominates — it keeps (or beats) the
//! fill-only high-priority speedup on every workload while its
//! low-priority ratio stays inside the 0.86–1.0 band.

use super::combos::{
    base_config, profile_combo_scratch, windowed_mean_ms, COMBOS, HIGH_KEY, LOW_KEY,
};
use super::{ExperimentResult, Options, ShapeCheck};
use crate::config::{ExperimentConfig, ServiceConfig};
use crate::coordinator::driver::{run_with_profiles_scratch, ExperimentReport, SimScratch};
use crate::coordinator::fikit::PreemptionPolicy;
use crate::coordinator::scheduler::PreemptStats;
use crate::coordinator::Mode;
use crate::core::{Priority, Result};
use crate::metrics::TextTable;
use crate::profile::ProfileStore;

/// The paper's low-priority JCT band under FIKIT sharing (Table 3 /
/// §4.5.4): background tenants retain 86–100 % of their sharing-mode
/// throughput. A preemption policy whose ratio drops below the floor is
/// spending the background tenant's time, not the idle gap's.
pub const LOW_RATIO_BAND: (f64, f64) = (0.86, 1.0);

/// The policy arms of the sweep, in escalation order.
fn policy_arms() -> [(&'static str, PreemptionPolicy); 4] {
    [
        ("none", PreemptionPolicy::None),
        ("evict", PreemptionPolicy::Evict),
        ("split", PreemptionPolicy::split()),
        ("hybrid", PreemptionPolicy::hybrid()),
    ]
}

/// One workload of the sweep: a named FIKIT config (the sharing baseline
/// is derived from it by flipping the mode).
struct Workload {
    label: String,
    cfg: ExperimentConfig,
}

fn workloads(opts: Options) -> Vec<Workload> {
    let tasks = opts.tasks(100);
    let mut out = Vec::new();
    // Combos A–J, batch mode (Fig 16 methodology).
    for combo in &COMBOS {
        let mut cfg = base_config(opts);
        cfg.mode = Mode::Fikit;
        cfg.services.push(
            ServiceConfig::new(combo.high, Priority::P0)
                .tasks(tasks)
                .with_key(HIGH_KEY),
        );
        cfg.services.push(
            ServiceConfig::new(combo.low, Priority::P3)
                .tasks(tasks)
                .with_key(LOW_KEY),
        );
        out.push(Workload {
            label: combo.label.to_string(),
            cfg,
        });
    }
    // Combo A under the Fig 21 continuous-insert methodology: A streams
    // high-priority work continuously, B inserts a low-priority task on
    // a fixed period — the workload where fills (and therefore
    // preemptable overruns) are densest.
    let inserts = opts.tasks(40);
    let interval_ms = 250u64;
    let combo = &COMBOS[0];
    let mut cfg = base_config(opts);
    cfg.mode = Mode::Fikit;
    cfg.services.push(
        ServiceConfig::new(combo.high, Priority::P0)
            .continuous_ms(interval_ms * (inserts as u64 + 1))
            .with_key(HIGH_KEY),
    );
    cfg.services.push(
        ServiceConfig::new(combo.low, Priority::P3)
            .every_ms(interval_ms, inserts)
            .with_key(LOW_KEY),
    );
    out.push(Workload {
        label: "A-cont".to_string(),
        cfg,
    });
    out
}

fn preempt_stats(report: &ExperimentReport) -> PreemptStats {
    report
        .scheduler
        .as_ref()
        .map(|s| s.preempt.clone())
        .unwrap_or_default()
}

pub fn run(opts: Options) -> Result<ExperimentResult> {
    let mut table = TextTable::new(&[
        "workload", "policy", "H speedup", "L ratio", "evict", "cut", "split", "requeues",
    ]);
    let mut series = Vec::new();
    // Per-workload Pareto points for the hybrid-dominates checks:
    // (label, none_speedup, hybrid_speedup, hybrid_low_ratio).
    let mut points = Vec::new();
    let mut preemptive_requeues = 0u64;
    // One event-core scratch across the whole sweep.
    let mut scratch = SimScratch::new();

    for w in workloads(opts) {
        // Profiles are measured once per workload and shared by all arms
        // (deployment lifecycle); the sharing baseline needs none.
        let profiles = profile_combo_scratch(&w.cfg, &mut scratch)?;
        let mut share_cfg = w.cfg.clone();
        share_cfg.mode = Mode::Sharing;
        let share = run_with_profiles_scratch(&share_cfg, &ProfileStore::new(), &mut scratch)?;
        let share_h = windowed_mean_ms(&share, HIGH_KEY);
        let share_l = windowed_mean_ms(&share, LOW_KEY);

        let mut none_speedup = 0.0;
        for (name, policy) in policy_arms() {
            let mut cfg = w.cfg.clone();
            cfg.preempt = policy;
            let report = run_with_profiles_scratch(&cfg, &profiles, &mut scratch)?;
            let h = windowed_mean_ms(&report, HIGH_KEY);
            let l = windowed_mean_ms(&report, LOW_KEY);
            let speedup = if h > 0.0 { share_h / h } else { 0.0 };
            let low_ratio = if l > 0.0 { share_l / l } else { 0.0 };
            let p = preempt_stats(&report);
            if policy != PreemptionPolicy::None {
                preemptive_requeues += p.requeues;
            }
            match name {
                "none" => none_speedup = speedup,
                "hybrid" => points.push((w.label.clone(), none_speedup, speedup, low_ratio)),
                _ => {}
            }
            series.push((format!("preempt/{}/{name}/high_speedup", w.label), speedup));
            series.push((format!("preempt/{}/{name}/low_ratio", w.label), low_ratio));
            table.row(vec![
                w.label.clone(),
                name.to_string(),
                format!("{speedup:.3}"),
                format!("{low_ratio:.3}"),
                p.evictions.to_string(),
                p.cuts.to_string(),
                p.splits.to_string(),
                p.requeues.to_string(),
            ]);
        }
    }

    let dominated: Vec<&(String, f64, f64, f64)> = points
        .iter()
        .filter(|(_, none, hybrid, _)| *hybrid < none * 0.99)
        .collect();
    let out_of_band: Vec<&(String, f64, f64, f64)> = points
        .iter()
        .filter(|(_, _, _, ratio)| *ratio < LOW_RATIO_BAND.0)
        .collect();
    let min_ratio = points
        .iter()
        .map(|(_, _, _, r)| *r)
        .fold(f64::INFINITY, f64::min);
    let checks = vec![
        ShapeCheck::new(
            "hybrid keeps fill-only's high-priority protection on every workload",
            dominated.is_empty(),
            if dominated.is_empty() {
                format!("{} workloads, hybrid ≥ 0.99× none on all", points.len())
            } else {
                format!(
                    "below fill-only on {:?}",
                    dominated.iter().map(|(l, ..)| l.as_str()).collect::<Vec<_>>()
                )
            },
        ),
        ShapeCheck::new(
            "hybrid low-priority JCT ratio inside the paper's 0.86–1.0 band",
            out_of_band.is_empty(),
            format!(
                "min ratio {min_ratio:.3} (floor {}); out of band: {:?}",
                LOW_RATIO_BAND.0,
                out_of_band.iter().map(|(l, ..)| l.as_str()).collect::<Vec<_>>()
            ),
        ),
        ShapeCheck::new(
            "preemption engine engages",
            preemptive_requeues > 0,
            format!("{preemptive_requeues} requeues across all preemptive arms"),
        ),
    ];

    Ok(ExperimentResult {
        id: "preemption",
        title: "Preemption Pareto sweep: fill-only vs evict/split/hybrid (reclaiming overhead 2)",
        table,
        series,
        checks,
        notes: "speedup = sharing H JCT / arm H JCT; ratio = sharing L JCT / arm L JCT; \
                combos A–J batch + combo A continuous-insert, shared profiles across arms"
            .to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preemption_pareto_holds_quick() {
        let r = run(Options::quick()).unwrap();
        // 11 workloads × 4 arms × 2 series.
        assert_eq!(r.series.len(), 88);
        assert!(r.all_checks_pass(), "{}", r.render());
    }
}
