//! **Fig 19 + Fig 20** — the preemption scenario (§4.5.3): service B's
//! low-priority tasks run continuously in the background; service A
//! inserts a high-priority task every second (100 total).
//!
//! * Fig 19: A's JCT under FIKIT vs default sharing — speedups up to
//!   15.77×, **except** combo J (deeplabv3_resnet50 + resnet101), which
//!   regresses (<1×): dense co-tenants leave no gaps worth the fill
//!   machinery, and the paper calls out that combination choice matters.
//! * Fig 20: B's JCT ratio FIKIT/sharing stays 0.86–1 — preemptive
//!   priority costs the background service almost nothing in this
//!   arrival pattern (A is idle most of each second).

use super::combos::{base_config, profile_combo_scratch, COMBOS, HIGH_KEY, LOW_KEY};
use super::{ExperimentResult, Options, ShapeCheck};
use crate::config::{ExperimentConfig, ServiceConfig};
use crate::coordinator::driver::{run_with_profiles_scratch, ExperimentReport, SimScratch};
use crate::coordinator::Mode;
use crate::core::{Priority, Result, TaskKey};
use crate::metrics::TextTable;
use crate::profile::ProfileStore;

fn preemption_config(
    combo: &super::combos::Combo,
    mode: Mode,
    inserts: u32,
    interval_ms: u64,
    opts: Options,
) -> ExperimentConfig {
    let mut cfg = base_config(opts);
    cfg.mode = mode;
    // A inserts a high-priority task every `interval_ms`.
    cfg.services.push(
        ServiceConfig::new(combo.high, Priority::P0)
            .every_ms(interval_ms, inserts)
            .with_key(HIGH_KEY),
    );
    // B runs continuously until past the last insert.
    let horizon_ms = interval_ms * (inserts as u64 + 1);
    cfg.services.push(
        ServiceConfig::new(combo.low, Priority::P3)
            .continuous_ms(horizon_ms)
            .with_key(LOW_KEY),
    );
    cfg
}

fn mean_ms(report: &ExperimentReport, key: &str) -> f64 {
    report
        .service(&TaskKey::new(key))
        .map(|s| s.jct.mean_ms())
        .unwrap_or(f64::NAN)
}

pub fn run(opts: Options) -> Result<ExperimentResult> {
    let inserts = opts.tasks(100);
    // Scale the insert interval down with task count so runs stay
    // tractable while preserving "A idle most of the time".
    let interval_ms = 250;

    let mut table = TextTable::new(&[
        "combo", "A share (ms)", "A FIKIT (ms)", "fig19 A speedup", "fig20 B ratio",
    ]);
    let mut series = Vec::new();
    let mut a_speedups = Vec::new();
    let mut b_ratios = Vec::new();
    // One event-core scratch across all ten combos (×2 modes).
    let mut scratch = SimScratch::new();

    for combo in &COMBOS {
        let fikit_cfg = preemption_config(combo, Mode::Fikit, inserts, interval_ms, opts);
        let profiles = profile_combo_scratch(&fikit_cfg, &mut scratch)?;
        let fikit = run_with_profiles_scratch(&fikit_cfg, &profiles, &mut scratch)?;
        let share_cfg = preemption_config(combo, Mode::Sharing, inserts, interval_ms, opts);
        let share = run_with_profiles_scratch(&share_cfg, &ProfileStore::new(), &mut scratch)?;

        let a_speedup = mean_ms(&share, HIGH_KEY) / mean_ms(&fikit, HIGH_KEY);
        // Fig 20: B's FIKIT/share JCT ratio (≈1 = unharmed).
        let b_ratio = mean_ms(&share, LOW_KEY) / mean_ms(&fikit, LOW_KEY);
        a_speedups.push(a_speedup);
        b_ratios.push(b_ratio);
        series.push((format!("fig19/{}", combo.label), a_speedup));
        series.push((format!("fig20/{}", combo.label), b_ratio));
        table.row(vec![
            combo.label.to_string(),
            format!("{:.2}", mean_ms(&share, HIGH_KEY)),
            format!("{:.2}", mean_ms(&fikit, HIGH_KEY)),
            format!("{a_speedup:.2}x"),
            format!("{b_ratio:.2}"),
        ]);
    }

    let wins = a_speedups.iter().filter(|s| **s > 1.0).count();
    let max_a = a_speedups.iter().cloned().fold(0.0, f64::max);
    let j_speedup = a_speedups[9];
    let mut sorted = a_speedups.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = (sorted[4] + sorted[5]) / 2.0;
    let b_min = b_ratios.iter().cloned().fold(f64::INFINITY, f64::min);

    let checks = vec![
        ShapeCheck::new(
            "fig19: preemption wins for most combos",
            wins >= 8,
            format!("{wins}/10 combos with A speedup > 1"),
        ),
        ShapeCheck::new(
            "fig19: large speedups exist",
            max_a > 3.0,
            format!("max A speedup {max_a:.2}x (paper: up to 15.77x)"),
        ),
        // The paper's J (deeplabv3_r50 + resnet101) *regresses* (<1x);
        // our simulator reproduces the direction — dense co-tenants give
        // FIKIT the least to work with — but not the absolute regression
        // (see EXPERIMENTS.md for the analysis of the residual gap).
        ShapeCheck::new(
            "fig19: dense-co-tenant combos benefit least",
            j_speedup < median,
            format!("combo J speedup {j_speedup:.2}x < median {median:.2}x (paper: J < 1)"),
        ),
        ShapeCheck::new(
            "fig20: background service barely harmed",
            b_min > 0.6,
            format!("min B ratio {b_min:.2} (paper: 0.86–1)"),
        ),
    ];

    Ok(ExperimentResult {
        id: "fig19",
        title: "Preemption scenario: A inserts high-priority tasks into a continuous low-priority stream",
        table,
        series,
        checks,
        notes: format!("{inserts} inserts every {interval_ms}ms; B continuous until past the last insert"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig19_20_shape_holds_quick() {
        let r = run(Options::quick()).unwrap();
        assert_eq!(r.series.len(), 20);
        assert!(r.all_checks_pass(), "{}", r.render());
    }
}
